//! Semi-supervised clustering on two-moons (paper §4.1).
//!
//! Generates the paper's dataset, minimizes the smoothness + label
//! objective with and without IAES, and reports clustering accuracy,
//! speedup, and the screening trajectory.
//!
//! ```bash
//! cargo run --release --example two_moons -- [p] [--mi]
//! ```

use sfm_screen::coordinator::experiments::{rejection_curve, run_variant, BenchConfig};
use sfm_screen::coordinator::jobs::{BackendChoice, WorkloadSpec};
use sfm_screen::prelude::*;
use sfm_screen::workloads::two_moons::TwoMoonsParams;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p: usize = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .and_then(|a| a.parse().ok())
        .unwrap_or(400);
    let use_mi = args.iter().any(|a| a == "--mi");

    let tm = TwoMoons::generate(TwoMoonsParams { p, ..Default::default() });
    println!(
        "two-moons: p = {p}, {} labeled, objective = {}",
        tm.labels.iter().filter(|l| l.is_some()).count(),
        if use_mi { "GP mutual information (exact)" } else { "Gaussian-kernel cut" }
    );

    let mut cfg = BenchConfig::default();
    cfg.quiet = true;
    cfg.backend = BackendChoice::Rust; // see BenchConfig::backend docs
    cfg.out_dir = std::env::temp_dir().join("two_moons_example");
    cfg.warmup(&[p]);
    let wl = WorkloadSpec::TwoMoons { p, use_mi, seed: tm.params.seed };

    let base = run_variant(&wl, RuleSet::none(), &cfg)?;
    let iaes = run_variant(&wl, RuleSet::all(), &cfg)?;

    assert!(
        (base.report.minimum - iaes.report.minimum).abs()
            < 1e-5 * (1.0 + base.report.minimum.abs()),
        "screening must be lossless"
    );

    let acc = tm.clustering_accuracy(&iaes.report.minimizer);
    let acc = acc.max(1.0 - acc);
    println!("clustering accuracy : {:.1}%", acc * 100.0);
    println!("minimum             : {:.4}", iaes.report.minimum);
    println!(
        "MinNorm alone       : {:>8.3} ms ({} iters)",
        base.wall.as_secs_f64() * 1e3,
        base.report.iters
    );
    println!(
        "IAES + MinNorm      : {:>8.3} ms ({} iters, {} triggers)",
        iaes.wall.as_secs_f64() * 1e3,
        iaes.report.iters,
        iaes.report.triggers.len()
    );
    println!(
        "speedup             : {:.2}x  (screening overhead {:.3} ms)",
        base.wall.as_secs_f64() / iaes.wall.as_secs_f64(),
        iaes.report.screen_time.as_secs_f64() * 1e3
    );

    // Screening trajectory (Figure 2's curve, textual).
    println!("\nrejection ratio over iterations:");
    let curve = rejection_curve(&iaes.report, p);
    let step = (curve.len() / 12).max(1);
    let last_idx = curve.len().saturating_sub(1);
    for (i, (it, ratio)) in curve.iter().enumerate() {
        if i % step != 0 && i != last_idx {
            continue;
        }
        let bars = (ratio * 50.0).round() as usize;
        println!("  iter {it:>5}  {:<50} {:.0}%", "#".repeat(bars), ratio * 100.0);
    }
    Ok(())
}
