//! End-to-end system validation — the EXPERIMENTS.md §E2E run.
//!
//! Exercises every layer on real (small) workloads and proves they
//! compose:
//!
//! 1. **L1/L2 via PJRT**: builds the two-moons affinity matrix with the
//!    AOT-compiled Pallas kernel and runs every screening trigger through
//!    the compiled screen kernel (when `make artifacts` has run; falls
//!    back to the rust backends otherwise, and says so).
//! 2. **L3**: solves the paper's two workloads (two-moons sizes + one
//!    segmentation scene) with MinNorm alone and with AES / IES / IAES.
//! 3. Verifies losslessness (identical minima) everywhere and reports the
//!    headline metric of the paper: the IAES speedup.
//!
//! ```bash
//! cargo run --release --example e2e_driver            # default sizes
//! cargo run --release --example e2e_driver -- --full  # paper sizes
//! ```

use sfm_screen::coordinator::experiments::{run_variant, BenchConfig};
use sfm_screen::coordinator::jobs::{BackendChoice, WorkloadSpec};
use sfm_screen::coordinator::report::{fnum, Table};
use sfm_screen::runtime::{AffinityExec, XlaScreener};
use sfm_screen::screening::iaes::{solve_sfm_with_screening, IaesOptions};
use sfm_screen::screening::RuleSet;
use sfm_screen::workloads::two_moons::{TwoMoons, TwoMoonsParams};

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let mut cfg = BenchConfig::default();
    cfg.quiet = true;
    cfg.out_dir = std::env::temp_dir().join("e2e_out");
    if full {
        cfg = cfg.full();
    }

    // ---- Layer status ----
    println!("== layer status ==");
    let xla_ok = match XlaScreener::at_default() {
        Ok(s) => {
            println!("L1/L2 screen kernel : XLA/PJRT (buckets {:?})", s.buckets());
            true
        }
        Err(_) => {
            println!("L1/L2 screen kernel : rust fallback (run `make artifacts`)");
            false
        }
    };
    match AffinityExec::at_default() {
        Ok(a) => println!("L1/L2 affinity      : XLA/PJRT (buckets {:?})", a.buckets()),
        Err(_) => println!("L1/L2 affinity      : rust fallback"),
    }

    // ---- Affinity built by the compiled Pallas kernel, fed into L3 ----
    if let Ok(aff) = AffinityExec::at_default() {
        let tm = TwoMoons::generate(TwoMoonsParams { p: 200, ..Default::default() });
        let k = aff.affinity(&tm.points, tm.params.alpha)?;
        let f = tm.kernel_cut_with_affinity(k);
        let rep = solve_sfm_with_screening(&f, &IaesOptions::default())?;
        let rust_rep =
            solve_sfm_with_screening(&tm.kernel_cut(), &IaesOptions::default())?;
        assert_eq!(rep.minimizer, rust_rep.minimizer, "kernel-built ≠ rust-built");
        println!(
            "affinity cross-check: minimizer identical via XLA-built K (|A*|={})",
            rep.minimizer.len()
        );
    }

    // ---- XLA screening on the hot path: prove composition ----
    {
        let mut xcfg = cfg.clone();
        xcfg.backend = BackendChoice::Auto;
        xcfg.warmup(&[400]);
        let wl = WorkloadSpec::TwoMoons { p: 400, use_mi: false, seed: cfg.seed };
        let x = run_variant(&wl, RuleSet::all(), &xcfg)?;
        let r = run_variant(&wl, RuleSet::all(), &cfg)?;
        assert_eq!(x.report.minimizer, r.report.minimizer,
            "xla and rust screening backends must agree");
        println!(
            "screen-backend cross-check: identical minimizer at p=400 \
             (xla {:.1} ms vs rust {:.1} ms — the rule is O(p) flops, so \
             PJRT call overhead dominates at CPU scale; see EXPERIMENTS.md §Perf)",
            x.wall.as_secs_f64() * 1e3,
            r.wall.as_secs_f64() * 1e3
        );
    }

    // ---- Headline: IAES speedups, both workloads (rust backend) ----
    println!("\n== two-moons (kernel-cut objective) ==");
    let mut t = Table::new(&["p", "MinNorm ms", "IAES ms", "speedup", "screened", "lossless"]);
    for &p in &cfg.sizes {
        let wl = WorkloadSpec::TwoMoons { p, use_mi: false, seed: cfg.seed };
        let base = run_variant(&wl, RuleSet::none(), &cfg)?;
        let iaes = run_variant(&wl, RuleSet::all(), &cfg)?;
        let lossless = (base.report.minimum - iaes.report.minimum).abs()
            < 1e-5 * (1.0 + base.report.minimum.abs());
        t.push_row(vec![
            p.to_string(),
            fnum(base.wall.as_secs_f64() * 1e3),
            fnum(iaes.wall.as_secs_f64() * 1e3),
            fnum(base.wall.as_secs_f64() / iaes.wall.as_secs_f64()),
            format!(
                "{}+{}",
                iaes.report.screened_active, iaes.report.screened_inactive
            ),
            lossless.to_string(),
        ]);
        assert!(lossless, "screening changed the optimum at p={p}");
    }
    println!("{}", t.render());

    println!("== image segmentation (one scene) ==");
    let wl = WorkloadSpec::Image { index: 0, scale: cfg.image_scale };
    let base = run_variant(&wl, RuleSet::none(), &cfg)?;
    let iaes = run_variant(&wl, RuleSet::all(), &cfg)?;
    let lossless = (base.report.minimum - iaes.report.minimum).abs()
        < 1e-5 * (1.0 + base.report.minimum.abs());
    assert!(lossless);
    println!(
        "image1: MinNorm {:.1} ms -> IAES {:.1} ms = {:.2}x speedup (lossless: {lossless})",
        base.wall.as_secs_f64() * 1e3,
        iaes.wall.as_secs_f64() * 1e3,
        base.wall.as_secs_f64() / iaes.wall.as_secs_f64(),
    );

    println!(
        "\nE2E OK — all layers composed ({} screening backend on the hot path).",
        if xla_ok { "XLA/PJRT" } else { "rust" }
    );
    Ok(())
}
