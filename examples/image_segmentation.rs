//! Image segmentation via SFM (paper §4.2).
//!
//! Generates a synthetic scene (GrabCut-instance stand-in), minimizes
//! `F(A) = u(A) + Σ_{i∈A, j∉A} exp(−‖x_i − x_j‖²)` with IAES screening,
//! and renders the recovered mask as ASCII art next to the ground truth.
//!
//! ```bash
//! cargo run --release --example image_segmentation -- [scale]
//! ```

use sfm_screen::prelude::*;
use sfm_screen::workloads::images::{ImageInstance, ImageParams};
use std::time::Instant;

fn render(h: usize, w: usize, mask: &[bool]) -> String {
    let mut out = String::new();
    // Downsample to at most 60 columns for the terminal.
    let stride = (w / 60).max(1);
    for r in (0..h).step_by(stride) {
        for c in (0..w).step_by(stride) {
            out.push(if mask[r * w + c] { '#' } else { '.' });
        }
        out.push('\n');
    }
    out
}

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(1.0);
    let img = ImageInstance::generate(
        "demo",
        ImageParams {
            h: (48.0 * scale) as usize,
            w: (42.0 * scale) as usize,
            fg_a: 0.28,
            fg_b: 0.24,
            fg_mean: 0.75,
            bg_mean: 0.30,
            noise: 0.06,
            texture: 0.08,
            beta: 0.35,
            seed: 2018,
        },
    );
    println!(
        "scene: {}x{} = {} pixels, {} edges (8-neighbor grid)",
        img.params.h,
        img.params.w,
        img.num_pixels(),
        img.num_edges()
    );

    let f = img.cut_fn();

    let t0 = Instant::now();
    let base = solve_sfm_with_screening(
        &f,
        &IaesOptions { rules: RuleSet::none(), ..Default::default() },
    )?;
    let t_base = t0.elapsed();

    let t1 = Instant::now();
    let iaes = solve_sfm_with_screening(&f, &IaesOptions::default())?;
    let t_iaes = t1.elapsed();

    assert!((base.minimum - iaes.minimum).abs() < 1e-5 * (1.0 + base.minimum.abs()));
    println!("cut value          : {:.3}", iaes.minimum);
    println!("IoU vs ground truth: {:.3}", img.iou(&iaes.minimizer));
    println!(
        "MinNorm alone      : {:>8.1} ms ({} iters)",
        t_base.as_secs_f64() * 1e3,
        base.iters
    );
    println!(
        "IAES + MinNorm     : {:>8.1} ms ({} iters) -> {:.2}x speedup",
        t_iaes.as_secs_f64() * 1e3,
        iaes.iters,
        t_base.as_secs_f64() / t_iaes.as_secs_f64()
    );
    println!(
        "screened           : {} active (fg), {} inactive (bg) — note the\n\
         paper's observation: the foreground is small, so IES does the\n\
         heavy lifting while AES alone would barely shrink the problem.",
        iaes.screened_active, iaes.screened_inactive
    );

    let mut mask = vec![false; img.num_pixels()];
    for &i in &iaes.minimizer {
        mask[i] = true;
    }
    // Write PPM renders next to the terminal output.
    use sfm_screen::coordinator::render::{grayscale, mask_overlay};
    let out = std::env::temp_dir().join("sfm_segmentation");
    grayscale(img.params.h, img.params.w, &img.pixels)
        .write_ppm(out.join("scene.ppm"))?;
    mask_overlay(img.params.h, img.params.w, &img.pixels, &mask)
        .write_ppm(out.join("segmentation.ppm"))?;
    println!("\nPPM renders: {}", out.display());
    println!("recovered segmentation        vs ground truth");
    let left = render(img.params.h, img.params.w, &mask);
    let right = render(img.params.h, img.params.w, &img.truth);
    for (a, b) in left.lines().zip(right.lines()) {
        println!("{a}   {b}");
    }
    Ok(())
}
