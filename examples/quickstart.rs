//! Quickstart: minimize a submodular function with safe element screening.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sfm_screen::prelude::*;
use sfm_screen::workloads::two_moons::TwoMoonsParams;

fn main() -> anyhow::Result<()> {
    // 1. Pick a submodular function — anything implementing `Submodular`
    //    works. Here: the paper's two-moons clustering objective
    //    (Gaussian-kernel cut + label unaries) on 200 points.
    let tm = TwoMoons::generate(TwoMoonsParams { p: 200, ..Default::default() });
    let f = tm.kernel_cut();

    // 2. Solve with IAES screening (Algorithm 2 of the paper): the
    //    min-norm-point solver runs on an ever-shrinking ground set as
    //    elements are certified in/out of the minimizer.
    let opts = IaesOptions::default(); // ε = 1e-6, ρ = 0.5, all four rules
    let report = solve_sfm_with_screening(&f, &opts)?;

    println!("minimum value   : {:.4}", report.minimum);
    println!("|A*|            : {}", report.minimizer.len());
    println!("iterations      : {}", report.iters);
    println!(
        "screened        : {} active, {} inactive (of {})",
        report.screened_active,
        report.screened_inactive,
        f.ground_size()
    );
    println!(
        "ground set emptied by screening alone: {}",
        report.emptied
    );

    // 3. Compare against the unscreened baseline — same optimum, more work.
    let baseline = solve_sfm_with_screening(
        &f,
        &IaesOptions { rules: RuleSet::none(), ..Default::default() },
    )?;
    assert!((baseline.minimum - report.minimum).abs() < 1e-6);
    println!(
        "baseline iters  : {} (screening is lossless: {:.4} == {:.4})",
        baseline.iters, baseline.minimum, report.minimum
    );

    // 4. The solvers are also usable directly, without screening:
    let g = IwataFn::new(500);
    let mut solver = MinNormPoint::new(&g, MinNormOptions::default(), None);
    for _ in 0..10_000 {
        if solver.step(&g).gap < 1e-9 {
            break;
        }
    }
    let w_star = solver.w();
    let a_min: Vec<usize> =
        (0..g.ground_size()).filter(|&j| w_star[j] > 0.0).collect();
    println!(
        "direct min-norm on iwata(500): gap {:.2e}, |{{w*>0}}| = {} (Fujishige's theorem)",
        solver.gap(),
        a_min.len()
    );
    Ok(())
}
