"""Pallas screening kernel vs the jnp oracle — the core L1 correctness
signal, swept over shapes and regimes with hypothesis."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import ref_screen
from compile.kernels.screen import (
    N_SCALARS,
    SCAL_FC,
    SCAL_FV,
    SCAL_GAP,
    SCAL_L1W,
    SCAL_MARGIN,
    SCAL_P,
    SCAL_SUMW,
    pick_block,
    screen_pallas,
    vmem_bytes_per_block,
)

OUT_NAMES = ("aes1", "ies1", "aes2", "ies2", "wmin", "wmax")


def run_both(w, p_hat, gap, f_v, f_c, margin=1e-10):
    """Pad, build the scalar bundle, run kernel + oracle."""
    p_pad = w.shape[0]
    valid = np.zeros(p_pad)
    valid[:p_hat] = 1.0
    w = np.asarray(w, dtype=np.float64) * valid
    sum_w = float(np.sum(w[:p_hat]))
    l1_w = float(np.sum(np.abs(w[:p_hat])))
    scal = np.zeros(N_SCALARS)
    scal[SCAL_GAP] = max(gap, 0.0)
    scal[SCAL_FV] = f_v
    scal[SCAL_FC] = f_c
    scal[SCAL_P] = p_hat
    scal[SCAL_MARGIN] = margin
    scal[SCAL_SUMW] = sum_w
    scal[SCAL_L1W] = l1_w
    got = screen_pallas(jnp.asarray(w), jnp.asarray(valid), jnp.asarray(scal))
    # Feed the oracle the *same* reduction values the kernel receives, so
    # the comparison isolates the element-wise math (summation order is
    # the caller's concern; rust supplies its own reductions identically).
    want = ref_screen(jnp.asarray(w), jnp.asarray(valid), scal[SCAL_GAP],
                      f_v, f_c, float(p_hat), margin,
                      sum_w=sum_w, l1_w=l1_w)
    return got, want


@hypothesis.settings(max_examples=40, deadline=None)
@hypothesis.given(
    p_hat=st.integers(min_value=2, max_value=96),
    pad_to=st.sampled_from([0, 1, 2]),  # 0: exact, else next pow2-ish
    gap=st.floats(min_value=0.0, max_value=5.0),
    fv_off=st.floats(min_value=-3.0, max_value=3.0),
    f_c=st.floats(min_value=-4.0, max_value=0.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_oracle(p_hat, pad_to, gap, fv_off, f_c, seed):
    rng = np.random.default_rng(seed)
    p_pad = p_hat if pad_to == 0 else 1 << (p_hat - 1).bit_length() + (pad_to - 1)
    p_pad = max(p_pad, p_hat)
    w = np.zeros(p_pad)
    w[:p_hat] = rng.normal(size=p_hat)
    f_v = -float(np.sum(w[:p_hat])) + fv_off
    got, want = run_both(w, p_hat, gap, f_v, f_c)
    # Extrema: the quadratic discriminant cancels catastrophically near
    # ball/plane tangency, and XLA may contract to FMA inside the jitted
    # kernel — allow a square-root-amplified tolerance there.
    for name, g, r in zip(OUT_NAMES[4:], got[4:], want[4:]):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(r), rtol=1e-6, atol=1e-7,
            err_msg=f"output {name}")
    # Masks: must agree exactly except within that same numerical band of
    # a decision boundary.
    wmin, wmax = np.asarray(want[4]), np.asarray(want[5])
    near = np.minimum(np.abs(wmin), np.abs(wmax)) < 1e-6
    for name, g, r in zip(OUT_NAMES[:4], got[:4], want[:4]):
        g, r = np.asarray(g), np.asarray(r)
        mismatch = (g != r) & ~near
        assert not mismatch.any(), f"{name} differs away from boundary"


@pytest.mark.parametrize("p_pad", [2, 8, 64, 256, 1024])
def test_shapes_and_padding(p_pad):
    p_hat = max(2, p_pad - 3)
    rng = np.random.default_rng(7)
    w = np.zeros(p_pad)
    w[:p_hat] = rng.normal(size=p_hat)
    got, _ = run_both(w, p_hat, 0.3, -float(np.sum(w[:p_hat])), -0.5)
    for name, g in zip(OUT_NAMES, got):
        g = np.asarray(g)
        assert g.shape == (p_pad,), name
        assert np.all(g[p_hat:] == 0.0), f"{name} pollutes padded lanes"


def test_masks_are_binary_and_disjoint():
    rng = np.random.default_rng(11)
    w = rng.normal(size=64)
    got, _ = run_both(w, 64, 0.05, -float(w.sum()), -0.4)
    aes1, ies1, aes2, ies2 = (np.asarray(g) for g in got[:4])
    for m in (aes1, ies1, aes2, ies2):
        assert set(np.unique(m)).issubset({0.0, 1.0})
    # An element certified active by rule 1 can't be certified inactive
    # by rule 1 (wmin > 0 and wmax < 0 are mutually exclusive).
    assert not np.any((aes1 > 0) & (ies1 > 0))


def test_tight_gap_decides_by_sign():
    w = np.array([0.5, -0.3, 1.2, -2.0])
    got, _ = run_both(w, 4, 1e-14, -float(w.sum()), 0.0)
    aes1, ies1 = np.asarray(got[0]), np.asarray(got[1])
    np.testing.assert_array_equal(aes1, [1.0, 0.0, 1.0, 0.0])
    np.testing.assert_array_equal(ies1, [0.0, 1.0, 0.0, 1.0])


def test_huge_gap_decides_nothing():
    rng = np.random.default_rng(3)
    w = rng.normal(size=32)
    got, _ = run_both(w, 32, 1e6, -float(w.sum()), 0.0)
    for name, m in zip(OUT_NAMES[:4], got[:4]):
        assert not np.any(np.asarray(m) > 0), name


def test_wmin_le_wmax_and_contains_center():
    rng = np.random.default_rng(5)
    w = rng.normal(size=48)
    got, _ = run_both(w, 48, 0.7, -float(w.sum()), -0.2)
    wmin, wmax = np.asarray(got[4]), np.asarray(got[5])
    assert np.all(wmin <= wmax + 1e-12)
    # The plane passes through w-hat here, so w-hat ∈ B ∩ P and each
    # coordinate must lie within its own extrema.
    assert np.all(wmin <= w + 1e-9)
    assert np.all(w <= wmax + 1e-9)


@pytest.mark.parametrize("p,expect", [(512, 512), (96, 32), (7, 7), (1024, 512)])
def test_pick_block(p, expect):
    blk = pick_block(p)
    assert p % blk == 0
    if p == 7:
        assert blk == 1
    else:
        assert blk == expect or p % expect != 0


def test_vmem_estimate_reasonable():
    # 512-lane f64 block: 8 streams -> 32 KiB — far under ~16 MiB VMEM.
    assert vmem_bytes_per_block(512) < 64 * 1024
