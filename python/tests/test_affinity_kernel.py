"""Pallas affinity kernel vs the jnp oracle."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.affinity import affinity_pallas, pick_block, vmem_bytes_per_block
from compile.kernels.ref import ref_affinity


def run_both(xs, ys, alpha):
    got = affinity_pallas(jnp.asarray(xs), jnp.asarray(ys),
                          jnp.asarray([alpha], dtype=jnp.float64))
    want = ref_affinity(jnp.asarray(xs), jnp.asarray(ys), alpha)
    return np.asarray(got), np.asarray(want)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    n=st.sampled_from([2, 3, 8, 16, 33, 64, 96]),
    alpha=st.floats(min_value=0.05, max_value=5.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_oracle(n, alpha, seed):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=n) * 2.0
    ys = rng.normal(size=n) * 2.0
    got, want = run_both(xs, ys, alpha)
    np.testing.assert_allclose(got, want, rtol=1e-13, atol=1e-14)


def test_symmetric_zero_diag_unit_range():
    rng = np.random.default_rng(1)
    n = 64
    got, _ = run_both(rng.normal(size=n), rng.normal(size=n), 1.5)
    np.testing.assert_allclose(got, got.T, rtol=0, atol=0)
    assert np.all(np.diag(got) == 0.0)
    assert np.all((got >= 0.0) & (got <= 1.0))


def test_identical_points_affinity_one():
    xs = np.zeros(4)
    ys = np.zeros(4)
    got, _ = run_both(xs, ys, 1.5)
    off_diag = got[~np.eye(4, dtype=bool)]
    np.testing.assert_allclose(off_diag, 1.0)


def test_distance_monotone():
    xs = np.array([0.0, 1.0, 5.0])
    ys = np.zeros(3)
    got, _ = run_both(xs, ys, 1.0)
    assert got[0, 1] > got[0, 2]


@pytest.mark.parametrize("n", [128, 256])
def test_block_tiling_matches_single_tile(n):
    # Force different tilings by comparing bucketed sizes against oracle.
    rng = np.random.default_rng(9)
    xs = rng.normal(size=n)
    ys = rng.normal(size=n)
    got, want = run_both(xs, ys, 1.5)
    np.testing.assert_allclose(got, want, rtol=1e-13, atol=1e-14)
    assert pick_block(n) == 128


def test_vmem_estimate():
    # 128x128 f64 tile ≈ 128 KiB + vectors — VMEM-friendly.
    assert vmem_bytes_per_block(128) < 256 * 1024
