"""AOT pipeline checks: the emitted HLO text must parse, compile on the
local CPU PJRT client, and reproduce the jitted model's numerics — the
exact contract the rust runtime relies on."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model


@pytest.fixture(scope="module")
def tmp_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build(out, screen_buckets=(64,), affinity_buckets=(256,), verbose=False)
    return out


def test_manifest_and_files(tmp_artifacts: pathlib.Path):
    names = sorted(p.name for p in tmp_artifacts.iterdir())
    assert "screen_p64.hlo.txt" in names
    assert "affinity_n256.hlo.txt" in names
    assert "manifest.txt" in names
    manifest = (tmp_artifacts / "manifest.txt").read_text()
    assert "screen 64" in manifest and "dtype f64" in manifest


def test_hlo_text_is_valid_entry(tmp_artifacts: pathlib.Path):
    text = (tmp_artifacts / "screen_p64.hlo.txt").read_text()
    assert "ENTRY" in text
    assert "f64" in text, "artifacts must be double precision"


def test_screen_artifact_parses_with_expected_signature(
    tmp_artifacts: pathlib.Path,
):
    """The HLO text must re-parse (the exact operation the rust loader
    performs via xla_extension) and expose the 7-parameter entry."""
    text = (tmp_artifacts / "screen_p64.hlo.txt").read_text()
    module = xc._xla.hlo_module_from_text(text)
    printed = module.to_string()
    layout = printed.splitlines()[0]
    # 2 vectors + 5 scalars in the entry layout:
    assert layout.count("f64[64]{0}") >= 2, layout
    assert layout.count("f64[]") == 5, layout


def test_affinity_artifact_parses_with_expected_signature(
    tmp_artifacts: pathlib.Path,
):
    text = (tmp_artifacts / "affinity_n256.hlo.txt").read_text()
    module = xc._xla.hlo_module_from_text(text)
    printed = module.to_string()
    layout = printed.splitlines()[0]
    assert layout.count("f64[256]{0}") >= 2, layout
    assert layout.count("f64[]") == 1, layout
    assert "f64[256,256]" in printed


def test_screen_aot_executable_matches_eager(tmp_artifacts: pathlib.Path):
    """jit-compile the exact lowering used for the artifact and compare
    against the eager model — numerics of the AOT path."""
    p = 64
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=p))
    valid = jnp.ones(p)
    args = (w, valid, jnp.float64(0.2), jnp.float64(-float(w.sum())),
            jnp.float64(-0.4), jnp.float64(p), jnp.float64(1e-10))
    compiled = jax.jit(model.screen_step).lower(*args).compile()
    got = compiled(*args)
    want = model.screen_step(*args)
    assert len(got) == len(want) == 6
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-14, atol=1e-14)


def test_affinity_aot_executable_matches_eager(tmp_artifacts: pathlib.Path):
    n = 256
    rng = np.random.default_rng(5)
    xs = jnp.asarray(rng.normal(size=n))
    ys = jnp.asarray(rng.normal(size=n))
    args = (xs, ys, jnp.float64(1.5))
    compiled = jax.jit(model.affinity).lower(*args).compile()
    got = np.asarray(compiled(*args))
    want = np.asarray(model.affinity(*args))
    np.testing.assert_allclose(got, want, rtol=1e-14, atol=1e-14)


def test_default_buckets_cover_paper_sizes():
    # Paper experiments reach p = 60 000 pixels; the ladder must cover it.
    assert max(aot.SCREEN_BUCKETS) >= 16384
    assert min(aot.SCREEN_BUCKETS) <= 256
