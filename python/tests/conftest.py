"""Shared pytest setup: force x64 before any jax import in the tests."""

import jax

jax.config.update("jax_enable_x64", True)
