"""Contract tests between the python AOT pipeline and the rust runtime:
artifact naming, bucket ladders, and input layout must match what
`rust/src/runtime/mod.rs` expects (screen_p{P}/affinity_n{N}, 7/3 inputs,
6/1 outputs, f64)."""

import pathlib
import re

from compile import aot

REPO = pathlib.Path(__file__).resolve().parents[2]


def test_artifact_stems_match_rust_parsers():
    """rust parses `screen_p{N}` / `affinity_n{N}` stems — the aot naming
    must keep that contract."""
    for p in aot.SCREEN_BUCKETS:
        stem = f"screen_p{p}"
        m = re.fullmatch(r"screen_p(\d+)", stem)
        assert m and int(m.group(1)) == p
    for n in aot.AFFINITY_BUCKETS:
        stem = f"affinity_n{n}"
        m = re.fullmatch(r"affinity_n(\d+)", stem)
        assert m and int(m.group(1)) == n


def test_rust_runtime_source_agrees_on_names():
    src = (REPO / "rust" / "src" / "runtime" / "mod.rs").read_text()
    assert 'format!("screen_p{bucket}")' in src
    assert 'format!("affinity_n{bucket}")' in src
    # rust builds exactly 7 inputs for screen and 3 for affinity.
    assert src.count("xla::Literal::scalar") >= 5


def test_bucket_ladders_are_sorted_and_padded_pow2ish():
    assert list(aot.SCREEN_BUCKETS) == sorted(aot.SCREEN_BUCKETS)
    assert list(aot.AFFINITY_BUCKETS) == sorted(aot.AFFINITY_BUCKETS)
    # Each bucket must be divisible by its Pallas block (whole-grid tiling).
    from compile.kernels.screen import pick_block as screen_block
    from compile.kernels.affinity import pick_block as affinity_block

    for p in aot.SCREEN_BUCKETS:
        assert p % screen_block(p) == 0
    for n in aot.AFFINITY_BUCKETS:
        assert n % affinity_block(n) == 0


def test_makefile_artifact_stamp_matches_manifest():
    mk = (REPO / "Makefile").read_text()
    assert "artifacts/manifest.txt" in mk, "make stamp must be the manifest"
    assert "compile.aot" in mk
