"""Mathematical soundness of the screening-rule oracle itself: the
closed forms must bound sampled feasible points (mirrors the rust
property tests, keeping the two codebases honest against each other)."""

import hypothesis
import hypothesis.strategies as st
import numpy as np

from compile.kernels.ref import ref_screen


def sample_ball_plane(rng, w, gap, f_v, k):
    """k points of B ∩ P (project center, random in-plane directions)."""
    p = len(w)
    r = np.sqrt(2.0 * gap)
    shift = (-f_v - w.sum()) / p
    center = w + shift
    dist = abs(shift) * np.sqrt(p)
    if dist > r:
        return np.empty((0, p))
    r_in = np.sqrt(r * r - dist * dist)
    pts = []
    for _ in range(k):
        d = rng.normal(size=p)
        d -= d.mean()
        n = np.linalg.norm(d)
        if n < 1e-12:
            pts.append(center)
            continue
        scale = rng.random() ** (1.0 / p) * r_in / n
        pts.append(center + scale * d)
    return np.array(pts)


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(
    p=st.integers(min_value=2, max_value=12),
    gap=st.floats(min_value=0.01, max_value=2.0),
    slack=st.floats(min_value=-0.7, max_value=0.7),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_lemma2_bounds_hold_on_samples(p, gap, slack, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=p)
    r = np.sqrt(2 * gap)
    f_v = -w.sum() + slack * r * np.sqrt(p)
    valid = np.ones(p)
    _, _, _, _, wmin, wmax = (
        np.asarray(a) for a in ref_screen(w, valid, gap, f_v, -0.3, float(p), 0.0)
    )
    pts = sample_ball_plane(rng, w, gap, f_v, 40)
    for pt in pts:
        assert np.all(pt >= wmin - 1e-7), "sampled point below wmin"
        assert np.all(pt <= wmax + 1e-7), "sampled point above wmax"


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(
    p=st.integers(min_value=2, max_value=12),
    gap=st.floats(min_value=0.01, max_value=2.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_rules_never_fire_on_feasible_sign(p, gap, seed):
    """If a point of B ∩ P has [w]_j ≤ 0, AES-1 must not certify j (and
    symmetrically for IES-1): certificates can never contradict an
    exhibited feasible point."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=p)
    r = np.sqrt(2 * gap)
    f_v = -w.sum() + 0.3 * r * np.sqrt(p)
    valid = np.ones(p)
    aes1, ies1, _, _, _, _ = (
        np.asarray(a) for a in ref_screen(w, valid, gap, f_v, -0.3, float(p), 0.0)
    )
    pts = sample_ball_plane(rng, w, gap, f_v, 60)
    for pt in pts:
        viol_a = (aes1 > 0) & (pt <= 0)
        viol_i = (ies1 > 0) & (pt >= 0)
        assert not viol_a.any(), "AES-1 contradicted by a feasible point"
        assert not viol_i.any(), "IES-1 contradicted by a feasible point"


def test_margin_monotone():
    """A larger margin can only shrink the certified sets."""
    rng = np.random.default_rng(17)
    p = 50
    w = rng.normal(size=p)
    valid = np.ones(p)
    f_v = -w.sum()
    small = ref_screen(w, valid, 0.01, f_v, -0.5, float(p), 1e-12)
    large = ref_screen(w, valid, 0.01, f_v, -0.5, float(p), 1e-2)
    for s, l in zip(small[:4], large[:4]):
        s, l = np.asarray(s), np.asarray(l)
        assert np.all(l <= s + 1e-12), "margin grew a certificate set"


def test_gap_monotone():
    """A smaller gap certifies at least as much (rules 1)."""
    rng = np.random.default_rng(23)
    p = 64
    w = rng.normal(size=p)
    valid = np.ones(p)
    f_v = -w.sum()
    tight = ref_screen(w, valid, 0.001, f_v, 0.0, float(p), 1e-10)
    loose = ref_screen(w, valid, 0.5, f_v, 0.0, float(p), 1e-10)
    for t, l in zip(tight[:2], loose[:2]):
        t, l = np.asarray(t), np.asarray(l)
        assert np.all(t >= l - 1e-12), "tighter gap lost a rule-1 certificate"
