"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
reference.

These mirror, bit-for-bit in f64, the rust reference implementation in
``rust/src/screening/rules.rs``; pytest checks the Pallas kernels against
them (and the rust integration tests check the compiled artifacts against
the rust rules), closing the three-way equivalence loop:

    pallas kernel  ==  jnp oracle  ==  rust rules
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_screen(w, valid, gap, f_v, f_c, p_hat, margin,
               sum_w=None, l1_w=None):
    """Element-wise screening rules (Lemma 2 + Lemma 3, Theorems 4-5).

    Args:
      w:      f64[P] padded primal iterate (junk beyond ``p_hat`` lanes,
              but ``valid`` masks it out of the reductions).
      valid:  f64[P] 1.0/0.0 lane mask.
      gap:    duality gap G(w, s) >= 0 (scalar).
      f_v:    F-hat(V-hat) (scalar).
      f_c:    best super-level-set value F-hat(C) (scalar).
      p_hat:  true ground-set size (scalar, >= 2 on this path).
      margin: strictness margin (scalar).

    Returns:
      (aes1, ies1, aes2, ies2, wmin, wmax) — masks as f64 0/1, all f64[P],
      padded lanes forced to 0.
    """
    w = jnp.asarray(w)
    valid = jnp.asarray(valid)
    gap = jnp.maximum(gap, 0.0)
    p = p_hat
    if sum_w is None:
        sum_w = jnp.sum(w * valid)
    if l1_w is None:
        l1_w = jnp.sum(jnp.abs(w) * valid)
    two_g = 2.0 * gap
    r = jnp.sqrt(two_g)
    omega_lo = f_v - 2.0 * f_c

    # ---- Lemma 2: extrema of [w]_j over B ∩ P ----
    sum_except = sum_w - w
    b = 2.0 * (sum_except + f_v - (p - 1.0) * w)
    c = (sum_except + f_v) ** 2 - (p - 1.0) * (two_g - w * w)
    disc = jnp.maximum(b * b - 4.0 * p * c, 0.0)
    sq = jnp.sqrt(disc)
    wmin = (-b - sq) / (2.0 * p)
    wmax = (-b + sq) / (2.0 * p)

    aes1 = wmin > margin
    ies1 = wmax < -margin

    # ---- Lemma 3: ℓ1 maxima over the sign-constrained half-balls ----
    safe_rad = jnp.sqrt(jnp.maximum(two_g - w * w, 0.0))
    sq_pm1 = jnp.sqrt(jnp.maximum(p - 1.0, 0.0))
    sq_2pg = jnp.sqrt(2.0 * p * gap)
    sq_2g_over_p = jnp.sqrt(two_g / p)

    l1max_nonpos = jnp.where(
        w - sq_2g_over_p < 0.0,
        l1_w - 2.0 * w + sq_2pg,
        l1_w - w + sq_pm1 * safe_rad,
    )
    aes2 = (w > 0.0) & (w <= r) & (l1max_nonpos < omega_lo - margin)

    l1max_nonneg = jnp.where(
        w + sq_2g_over_p > 0.0,
        l1_w + 2.0 * w + sq_2pg,
        l1_w + w + sq_pm1 * safe_rad,
    )
    ies2 = (w < 0.0) & (-w <= r) & (l1max_nonneg < omega_lo - margin)

    def to_f(m):
        return m.astype(w.dtype) * valid

    return (
        to_f(aes1),
        to_f(ies1),
        to_f(aes2),
        to_f(ies2),
        wmin * valid,
        wmax * valid,
    )


def ref_affinity(xs, ys, alpha):
    """Dense Gaussian affinity ``exp(-alpha * |xi-xj|^2)``, zero diagonal.

    Args:
      xs, ys: f64[N] point coordinates.
      alpha:  bandwidth (scalar).

    Returns:
      f64[N, N].
    """
    xs = jnp.asarray(xs)
    ys = jnp.asarray(ys)
    dx = xs[:, None] - xs[None, :]
    dy = ys[:, None] - ys[None, :]
    k = jnp.exp(-alpha * (dx * dx + dy * dy))
    n = xs.shape[0]
    return k * (1.0 - jnp.eye(n, dtype=xs.dtype))
