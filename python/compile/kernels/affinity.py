"""L1 Pallas kernel: tiled Gaussian affinity matrix.

Computes ``K[i, j] = exp(-alpha * ((x_i-x_j)^2 + (y_i-y_j)^2))`` with a
zero diagonal — the two-moons similarity matrix (paper §4.1, kernel
bandwidth α = 1.5).

TPU mapping (DESIGN.md §Hardware-Adaptation): the output is tiled into
``(B, B)`` VMEM blocks; each grid step loads only the `B` row coordinates
and `B` column coordinates (two tiny vectors), broadcasts them inside
VMEM, and writes one dense tile — the classic "pairwise op as outer
broadcast" pattern that keeps HBM traffic at O(N²) output + O(N·grid)
input. d = 2, so this is VPU work; no MXU involvement.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _affinity_block_kernel(alpha_ref, xi_ref, yi_ref, xj_ref, yj_ref, out_ref):
    """One (B, B) output tile."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    alpha = alpha_ref[0]
    xi = xi_ref[...]
    yi = yi_ref[...]
    xj = xj_ref[...]
    yj = yj_ref[...]
    dx = xi[:, None] - xj[None, :]
    dy = yi[:, None] - yj[None, :]
    k = jnp.exp(-alpha * (dx * dx + dy * dy))
    # Zero the global diagonal: lane (a, b) is global (i*B + a, j*B + b).
    blk = xi.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0) + i * blk
    cols = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1) + j * blk
    out_ref[...] = jnp.where(rows == cols, 0.0, k)


def pick_block(n: int) -> int:
    """Tile edge: 128 when possible (128×128 f64 tile = 128 KiB VMEM)."""
    for blk in (128, 64, 32, 16, 8, 4, 2, 1):
        if n % blk == 0:
            return blk
    return 1


@functools.partial(jax.jit, static_argnames=("interpret",))
def affinity_pallas(xs, ys, alpha, *, interpret: bool = True):
    """Tiled affinity matrix.

    Args:
      xs, ys: f64[N] coordinates (padded lanes produce harmless rows the
              caller crops).
      alpha:  f64[1] bandwidth.

    Returns:
      f64[N, N].
    """
    n = xs.shape[0]
    blk = pick_block(n)
    grid = (n // blk, n // blk)
    row_spec = pl.BlockSpec((blk,), lambda i, j: (i,))
    col_spec = pl.BlockSpec((blk,), lambda i, j: (j,))
    alpha_spec = pl.BlockSpec((1,), lambda i, j: (0,))
    out_spec = pl.BlockSpec((blk, blk), lambda i, j: (i, j))
    return pl.pallas_call(
        _affinity_block_kernel,
        grid=grid,
        in_specs=[alpha_spec, row_spec, row_spec, col_spec, col_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n, n), xs.dtype),
        interpret=interpret,
    )(alpha, xs, ys, xs, ys)


def vmem_bytes_per_block(block: int, dtype_bytes: int = 8) -> int:
    """VMEM estimate: one (B,B) output tile + four B-vectors + scalar."""
    return block * block * dtype_bytes + 4 * block * dtype_bytes + dtype_bytes
