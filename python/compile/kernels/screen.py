"""L1 Pallas kernel: the fused screening-rule evaluation.

One pass over the (padded) primal vector computes, per element, the
Lemma-2 closed-form extrema over B ∩ P and the Lemma-3 ℓ1-maximum tests
over B ∩ Ω, emitting the four rule masks plus the extrema — i.e. the
entire per-trigger screening math of the paper in a single VMEM-resident
sweep.

TPU mapping (DESIGN.md §Hardware-Adaptation): the two global reductions
(Σw, ‖w‖₁) are computed once at the L2 level and enter the kernel as
scalars, so the vector is read exactly once per trigger; each block of
``block`` lanes lives in VMEM while ~40 flops/element of rule math run on
the VPU. There is no matmul — the MXU is idle by design; the kernel is
bandwidth-bound and the win over a naive rule-by-rule implementation is
the 6→1 reduction in passes over HBM.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; structure, not wallclock, is what we optimize here (see
EXPERIMENTS.md §Perf for the roofline estimate).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Scalar-vector layout (single (8,) operand so the scalar bundle occupies
# one tiny VMEM block): gap, f_v, f_c, p_hat, margin, sum_w, l1_w, unused.
SCAL_GAP = 0
SCAL_FV = 1
SCAL_FC = 2
SCAL_P = 3
SCAL_MARGIN = 4
SCAL_SUMW = 5
SCAL_L1W = 6
N_SCALARS = 8


def _screen_block_kernel(w_ref, valid_ref, scal_ref, aes1_ref, ies1_ref,
                         aes2_ref, ies2_ref, wmin_ref, wmax_ref):
    """Per-block body: pure element-wise rule math."""
    w = w_ref[...]
    valid = valid_ref[...]
    gap = scal_ref[SCAL_GAP]
    f_v = scal_ref[SCAL_FV]
    f_c = scal_ref[SCAL_FC]
    p = scal_ref[SCAL_P]
    margin = scal_ref[SCAL_MARGIN]
    sum_w = scal_ref[SCAL_SUMW]
    l1_w = scal_ref[SCAL_L1W]

    two_g = 2.0 * gap
    r = jnp.sqrt(two_g)
    omega_lo = f_v - 2.0 * f_c

    # Lemma 2: quadratic p t^2 + b t + c <= 0 in t = [w]_j over B ∩ P.
    sum_except = sum_w - w
    b = 2.0 * (sum_except + f_v - (p - 1.0) * w)
    c = (sum_except + f_v) ** 2 - (p - 1.0) * (two_g - w * w)
    disc = jnp.maximum(b * b - 4.0 * p * c, 0.0)
    sq = jnp.sqrt(disc)
    wmin = (-b - sq) / (2.0 * p)
    wmax = (-b + sq) / (2.0 * p)

    aes1 = wmin > margin
    ies1 = wmax < -margin

    # Lemma 3: closed-form ℓ1 maxima over the sign-constrained half-balls.
    safe_rad = jnp.sqrt(jnp.maximum(two_g - w * w, 0.0))
    sq_pm1 = jnp.sqrt(jnp.maximum(p - 1.0, 0.0))
    sq_2pg = jnp.sqrt(2.0 * p * gap)
    sq_2g_over_p = jnp.sqrt(two_g / p)

    l1max_nonpos = jnp.where(
        w - sq_2g_over_p < 0.0,
        l1_w - 2.0 * w + sq_2pg,
        l1_w - w + sq_pm1 * safe_rad,
    )
    aes2 = (w > 0.0) & (w <= r) & (l1max_nonpos < omega_lo - margin)

    l1max_nonneg = jnp.where(
        w + sq_2g_over_p > 0.0,
        l1_w + 2.0 * w + sq_2pg,
        l1_w + w + sq_pm1 * safe_rad,
    )
    ies2 = (w < 0.0) & (-w <= r) & (l1max_nonneg < omega_lo - margin)

    dt = w.dtype
    aes1_ref[...] = aes1.astype(dt) * valid
    ies1_ref[...] = ies1.astype(dt) * valid
    aes2_ref[...] = aes2.astype(dt) * valid
    ies2_ref[...] = ies2.astype(dt) * valid
    wmin_ref[...] = wmin * valid
    wmax_ref[...] = wmax * valid


def pick_block(p: int) -> int:
    """Largest power-of-two block ≤ 512 dividing ``p`` (≈ 4 KiB f64 lanes,
    comfortably VMEM-resident next to the five outputs)."""
    for blk in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if p % blk == 0:
            return blk
    return 1


@functools.partial(jax.jit, static_argnames=("interpret",))
def screen_pallas(w, valid, scal, *, interpret: bool = True):
    """Run the fused screening kernel over a padded vector.

    Args:
      w:     f64[P] padded primal.
      valid: f64[P] lane mask.
      scal:  f64[8] scalar bundle (see module constants).

    Returns:
      Tuple of six f64[P]: aes1, ies1, aes2, ies2, wmin, wmax.
    """
    p = w.shape[0]
    blk = pick_block(p)
    grid = (p // blk,)
    vec_spec = pl.BlockSpec((blk,), lambda i: (i,))
    scal_spec = pl.BlockSpec((N_SCALARS,), lambda i: (0,))
    out_shape = tuple(
        jax.ShapeDtypeStruct((p,), w.dtype) for _ in range(6)
    )
    return pl.pallas_call(
        _screen_block_kernel,
        grid=grid,
        in_specs=[vec_spec, vec_spec, scal_spec],
        out_specs=tuple(vec_spec for _ in range(6)),
        out_shape=out_shape,
        interpret=interpret,
    )(w, valid, scal)


def vmem_bytes_per_block(block: int, dtype_bytes: int = 8) -> int:
    """VMEM footprint estimate: 2 input blocks + 6 output blocks + the
    scalar bundle (used by the §Perf roofline notes)."""
    return (2 + 6) * block * dtype_bytes + N_SCALARS * dtype_bytes
