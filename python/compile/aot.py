"""AOT pipeline: lower the L2 model to HLO **text** artifacts.

HLO text — not ``.serialize()`` protos — is the interchange format: jax
≥ 0.5 emits HloModuleProto with 64-bit instruction ids, which the
published ``xla`` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all f64, ``return_tuple=True``):

* ``screen_p{P}.hlo.txt``   for P in SCREEN_BUCKETS — the fused screening
  kernel; rust pads the reduced problem into the smallest bucket ≥ p̂.
* ``affinity_n{N}.hlo.txt`` for N in AFFINITY_BUCKETS — the two-moons
  similarity matrix builder.
* ``manifest.txt`` — bucket inventory + jax version, so `make artifacts`
  can skip rebuilds when inputs are unchanged.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import pathlib
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402

SCREEN_BUCKETS = (64, 256, 1024, 4096, 16384)
AFFINITY_BUCKETS = (256, 512, 1024, 2048)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_screen(p: int) -> str:
    vec = jax.ShapeDtypeStruct((p,), jnp.float64)
    scal = jax.ShapeDtypeStruct((), jnp.float64)
    lowered = jax.jit(model.screen_step).lower(
        vec, vec, scal, scal, scal, scal, scal
    )
    return to_hlo_text(lowered)


def lower_affinity(n: int) -> str:
    vec = jax.ShapeDtypeStruct((n,), jnp.float64)
    scal = jax.ShapeDtypeStruct((), jnp.float64)
    lowered = jax.jit(model.affinity).lower(vec, vec, scal)
    return to_hlo_text(lowered)


def build(out_dir: pathlib.Path, screen_buckets=SCREEN_BUCKETS,
          affinity_buckets=AFFINITY_BUCKETS, verbose: bool = True) -> list[str]:
    """Emit every artifact; returns the list of written stems."""
    out_dir.mkdir(parents=True, exist_ok=True)
    written = []
    for p in screen_buckets:
        stem = f"screen_p{p}"
        text = lower_screen(p)
        (out_dir / f"{stem}.hlo.txt").write_text(text)
        written.append(stem)
        if verbose:
            print(f"  {stem}: {len(text)} chars", file=sys.stderr)
    for n in affinity_buckets:
        stem = f"affinity_n{n}"
        text = lower_affinity(n)
        (out_dir / f"{stem}.hlo.txt").write_text(text)
        written.append(stem)
        if verbose:
            print(f"  {stem}: {len(text)} chars", file=sys.stderr)
    manifest = [
        f"jax {jax.__version__}",
        "dtype f64",
        *(f"screen {p}" for p in screen_buckets),
        *(f"affinity {n}" for n in affinity_buckets),
    ]
    (out_dir / "manifest.txt").write_text("\n".join(manifest) + "\n")
    return written


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--quick", action="store_true",
        help="only the smallest bucket of each kind (CI smoke)",
    )
    args = parser.parse_args()
    out = pathlib.Path(args.out_dir)
    if args.quick:
        written = build(out, screen_buckets=SCREEN_BUCKETS[:1],
                        affinity_buckets=AFFINITY_BUCKETS[:1])
    else:
        written = build(out)
    print(f"wrote {len(written)} artifacts to {out}")


if __name__ == "__main__":
    main()
