"""L2: the JAX compute graph composed from the L1 Pallas kernels.

Two entry points, both AOT-lowered by :mod:`compile.aot` to HLO text and
executed from rust via PJRT (python never runs on the request path):

* :func:`screen_step` — the per-trigger screening evaluation. The two
  global reductions (Σw, ‖w‖₁) are computed here with masked ``jnp``
  sums (XLA fuses them into the surrounding graph) and enter the fused
  Pallas kernel as scalars, so the vector is swept exactly once.
* :func:`affinity` — the two-moons Gaussian similarity matrix.

All math is f64: screening certificates must not flip under round-off
(the rust side additionally applies a strictness margin).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import affinity as affinity_kernel
from compile.kernels import screen as screen_kernel

jax.config.update("jax_enable_x64", True)


def screen_step(w, valid, gap, f_v, f_c, p_hat, margin):
    """Evaluate all four screening rules on a padded problem.

    Args:
      w:      f64[P] padded primal iterate.
      valid:  f64[P] 1.0/0.0 lane mask (first ``p_hat`` lanes valid).
      gap:    f64[] duality gap.
      f_v:    f64[] F-hat(V-hat).
      f_c:    f64[] best super-level-set value.
      p_hat:  f64[] true ground-set size.
      margin: f64[] strictness margin.

    Returns:
      (aes1, ies1, aes2, ies2, wmin, wmax): six f64[P] arrays; the masks
      are 0/1-valued and zero on padded lanes.
    """
    w = w * valid  # keep padded lanes inert even if the caller left junk
    sum_w = jnp.sum(w * valid)
    l1_w = jnp.sum(jnp.abs(w) * valid)
    scal = jnp.stack(
        [
            jnp.maximum(gap, 0.0),
            f_v,
            f_c,
            p_hat,
            margin,
            sum_w,
            l1_w,
            jnp.zeros_like(gap),
        ]
    )
    return screen_kernel.screen_pallas(w, valid, scal)


def affinity(xs, ys, alpha):
    """Gaussian affinity matrix via the tiled Pallas kernel.

    Args:
      xs, ys: f64[N] coordinates.
      alpha:  f64[] bandwidth.

    Returns:
      f64[N, N] with zero diagonal.
    """
    return affinity_kernel.affinity_pallas(xs, ys, jnp.reshape(alpha, (1,)))


def screen_step_reference(w, valid, gap, f_v, f_c, p_hat, margin):
    """jnp-oracle variant of :func:`screen_step` (pytest cross-check)."""
    from compile.kernels.ref import ref_screen

    return ref_screen(w * valid, valid, gap, f_v, f_c, p_hat, margin)
