# Developer conveniences. The offline build container has no rust
# toolchain — these targets are for CI / driver machines.

.PHONY: baseline bench test

# Record BENCH_micro.baseline.json at CI's smoke sizes so the
# compare_bench gate fails regressions instead of only self-diffing.
# CI uploads every run's fresh smoke trajectory as the `bench-baseline`
# artifact; this target produces the identical file locally. Commit the
# result at the repo root (see BENCHMARKS.md).
baseline:
	cd rust && SFM_BENCH_SIZES=64,128 cargo bench --bench micro
	cp BENCH_micro.json BENCH_micro.baseline.json
	@echo "baseline recorded at SFM_BENCH_SIZES=64,128 — commit BENCH_micro.baseline.json"

# Full-size micro trajectory (BENCH_micro.json at the repo root).
bench:
	cd rust && cargo bench --bench micro

test:
	cd rust && cargo build --release && cargo test -q
