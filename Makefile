# Developer conveniences. The offline build container has no rust
# toolchain — these targets are for CI / driver machines.

.PHONY: baseline bench test lint lint-explain miri tsan crash-resume

# Record BENCH_micro.baseline.json at CI's smoke sizes so the
# compare_bench gate fails regressions instead of only self-diffing.
# CI uploads every run's fresh smoke trajectory as the `bench-baseline`
# artifact; this target produces the identical file locally. Commit the
# result at the repo root (see BENCHMARKS.md).
baseline:
	cd rust && SFM_BENCH_SIZES=64,128 cargo bench --bench micro
	cp BENCH_micro.json BENCH_micro.baseline.json
	@echo "baseline recorded at SFM_BENCH_SIZES=64,128 — commit BENCH_micro.baseline.json"

# Full-size micro trajectory (BENCH_micro.json at the repo root).
bench:
	cd rust && cargo bench --bench micro

test:
	cd rust && cargo build --release && cargo test -q

# Invariant lint pass over the crate's own sources (see LINTS.md):
# SAFETY comments on unsafe sites, poison-adopting lock discipline,
# transitive hot-path allocation bans, panic-free serve job paths, and
# the boundary-coupling rule — all driven by the whole-crate call
# graph. Exits nonzero with file:line diagnostics (plus the offending
# call chain for transitive findings); also writes the machine-readable
# findings to lint-report.json, which CI uploads as an artifact.
lint:
	cd rust && cargo run --bin sfm_lint
	cd rust && cargo run --bin sfm_lint -- --json > ../lint-report.json

# Why is a function subject to the hot-path rules? Prints the shortest
# call chain from a hot root, e.g.:
#   make lint-explain FN=src/lovasz.rs::accumulate_pass
lint-explain:
	cd rust && cargo run --bin sfm_lint -- --explain '$(FN)'

# Crash-resume smoke (RELIABILITY.md): an armed failpoint kills a
# checkpointed solve at the 4th boundary; resuming from the snapshot it
# left behind must land on the uninterrupted run's minimizer. Mirrors
# the CI leg of the same name.
crash-resume:
	cd rust && cargo run --release --features failpoint --bin sfm-screen -- solve \
		--workload iwata --p 48 --quiet --json > /tmp/sfm_direct.json
	cd rust && ! SFM_FAILPOINT='iaes-iter=panic@4' cargo run --release --features failpoint \
		--bin sfm-screen -- solve --workload iwata --p 48 --quiet --checkpoint /tmp/sfm_ck.jsonl
	cd rust && cargo run --release --features failpoint --bin sfm-screen -- \
		checkpoint-check --file /tmp/sfm_ck.jsonl
	cd rust && cargo run --release --features failpoint --bin sfm-screen -- solve \
		--workload iwata --p 48 --quiet --json --resume /tmp/sfm_ck.jsonl > /tmp/sfm_resumed.json
	python3 -c "import json; d = json.load(open('/tmp/sfm_direct.json')); \
		r = json.load(open('/tmp/sfm_resumed.json')); \
		assert abs(d['minimum'] - r['minimum']) < 1e-6, (d['minimum'], r['minimum']); \
		assert d['minimizer'] == r['minimizer'], 'resumed minimizer diverged'"
	@echo "crash-resume smoke ok"

# Miri leg: interpret the unsafe fork-join and linalg cores under the
# aliasing/UB checker. SFM_PROP_CASES caps the property suites so the
# interpreter finishes in minutes; -Zmiri-disable-isolation permits the
# env read. Needs: rustup +nightly component add miri.
miri:
	cd rust && MIRIFLAGS="-Zmiri-disable-isolation" SFM_PROP_CASES=2 \
		cargo +nightly miri test --lib -- runtime::pool linalg::vecops linalg::cholesky

# ThreadSanitizer leg: race-check the parked worker pool and the serve
# loop. -Zbuild-std instruments std itself; RUST_TEST_THREADS=1 keeps
# harness interleaving out of the reports. Needs: rustup +nightly
# component add rust-src.
tsan:
	cd rust && RUSTFLAGS="-Zsanitizer=thread" RUST_TEST_THREADS=1 \
		cargo +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu --lib -- runtime::pool
	cd rust && RUSTFLAGS="-Zsanitizer=thread" RUST_TEST_THREADS=1 \
		cargo +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu \
		--test serve --test determinism
