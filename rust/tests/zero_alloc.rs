//! Steady-state zero-allocation certification for the solver hot loop.
//!
//! A thread-local counting allocator wraps the system allocator; each test
//! warms a workspace/solver to its high-water size, then asserts that
//! further hot-loop work performs **zero** heap allocations on this
//! thread. Thread-local counting keeps the tests independent of cargo's
//! parallel test execution.

// The `debug-invariants` checks allocate by design (fresh workspaces,
// claim logs), so the zero-allocation certification only holds for the
// default feature set — the whole suite is compiled out otherwise.
#![cfg(not(feature = "debug-invariants"))]

use sfm_screen::brute::brute_force_sfm;
use sfm_screen::lovasz::{greedy_base_vertex, GreedyWorkspace};
use sfm_screen::rng::Pcg64;
use sfm_screen::solvers::frankwolfe::{FrankWolfe, FwOptions};
use sfm_screen::solvers::minnorm::{MinNormOptions, MinNormPoint};
use sfm_screen::solvers::ProxSolver;
use sfm_screen::submodular::concave_card::ConcaveCardFn;
use sfm_screen::submodular::coverage::CoverageFn;
use sfm_screen::submodular::cut::CutFn;
use sfm_screen::submodular::facility::FacilityLocationFn;
use sfm_screen::submodular::gaussian_mi::GaussianMiFn;
use sfm_screen::submodular::iwata::IwataFn;
use sfm_screen::submodular::kernel_cut::KernelCutFn;
use sfm_screen::submodular::scaled::ScaledFn;
use sfm_screen::submodular::Submodular;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

mod common;

struct CountingAlloc;

thread_local! {
    static ALLOC_COUNT: Cell<u64> = const { Cell::new(0) };
}

// SAFETY: delegates every operation to the system allocator; the counter
// update is a plain thread-local store (try_with ignores TLS teardown).
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: forwards `layout` unchanged to `System.alloc`, whose
    // contract is identical to ours.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOC_COUNT.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    // SAFETY: forwards `layout` unchanged to `System.alloc_zeroed`.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOC_COUNT.try_with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    // SAFETY: `ptr`/`layout`/`new_size` come from our caller under the
    // `GlobalAlloc` contract and pass through to `System.realloc` as-is.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOC_COUNT.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: `ptr` was produced by the matching `System` allocation
    // above (every alloc path delegates), so handing it back is sound.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Allocations made by `f` on the current thread.
fn count_allocs(f: impl FnOnce()) -> u64 {
    let before = ALLOC_COUNT.with(|c| c.get());
    f();
    ALLOC_COUNT.with(|c| c.get()) - before
}

/// Warm a workspace on `f`, then assert that `passes` further greedy
/// passes with a drifting direction vector allocate nothing.
fn assert_greedy_zero_alloc(f: &dyn Submodular, label: &str) {
    let p = f.ground_size();
    let mut rng = Pcg64::seeded(0xA110C);
    let mut w = rng.normal_vec(p);
    let mut ws = GreedyWorkspace::new(p);
    let mut s = vec![0.0; p];
    for _ in 0..3 {
        greedy_base_vertex(f, &w, &mut ws, &mut s);
        for x in w.iter_mut() {
            *x += 0.01;
        }
    }
    let mut drift = 0.001;
    let n = count_allocs(|| {
        for _ in 0..5 {
            greedy_base_vertex(f, &w, &mut ws, &mut s);
            for x in w.iter_mut() {
                *x += drift;
                drift = -drift;
            }
        }
    });
    assert_eq!(n, 0, "{label}: greedy pass allocated {n} times after warm-up");
}

fn seeded_cut(p: usize, seed: u64) -> CutFn {
    let mut rng = Pcg64::seeded(seed);
    let mut edges = Vec::new();
    for i in 0..p {
        for j in (i + 1)..p {
            if rng.bernoulli(0.2) {
                edges.push((i, j, rng.uniform(0.0, 1.5)));
            }
        }
    }
    CutFn::from_edges(p, &edges, rng.uniform_vec(p, -1.5, 1.5))
}

fn seeded_kernel_cut(p: usize, seed: u64) -> KernelCutFn {
    let mut rng = Pcg64::seeded(seed);
    let mut k = vec![0.0; p * p];
    for i in 0..p {
        for j in (i + 1)..p {
            let w = rng.uniform(0.0, 1.0);
            k[i * p + j] = w;
            k[j * p + i] = w;
        }
    }
    KernelCutFn::new(p, k, rng.uniform_vec(p, -2.0, 2.0))
}

#[test]
fn greedy_pass_is_zero_alloc_for_every_oracle_family() {
    let p = 48;
    assert_greedy_zero_alloc(&seeded_cut(p, 1), "cut");
    assert_greedy_zero_alloc(&seeded_kernel_cut(p, 2), "kernel_cut");
    let mut rng = Pcg64::seeded(3);
    assert_greedy_zero_alloc(&CoverageFn::random(p, 100, 6, &mut rng), "coverage");
    let mut rng = Pcg64::seeded(4);
    assert_greedy_zero_alloc(
        &FacilityLocationFn::random(40, p, &mut rng),
        "facility",
    );
    let mut rng = Pcg64::seeded(5);
    let m = rng.uniform_vec(p, -1.0, 1.0);
    assert_greedy_zero_alloc(&ConcaveCardFn::sqrt(p, 1.5, m), "concave_card");
    assert_greedy_zero_alloc(&IwataFn::new(p), "iwata");
}

/// The pooled monolithic greedy steady state is allocation-free on the
/// **main thread and on every parked worker**: dispatching a pass over
/// the pool is one mutex round-trip + condvar wake per superblock, the
/// column-chunk grid writes disjoint slices of pre-sized buffers, and
/// the high-degree adjacency partials live in a warmed scratch vector.
/// Per-worker counters are sampled through the pool exactly like the
/// block solver's t = 4 certification below. The worker count follows
/// the monolithic `t` convention (`t − 1` workers + the calling
/// thread); `SFM_BENCH_THREADS` (CI's pooled leg) overrides `t = 4`.
#[test]
fn pooled_greedy_pass_is_zero_alloc() {
    use sfm_screen::runtime::pool::WorkerPool;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    let t = common::env_pool_threads().unwrap_or(4);
    let workers = t - 1;
    let pool = Arc::new(WorkerPool::new(workers));
    // Two pooled oracle families: the dense kernel-cut superblock sweep
    // (p above the pool gate) and the sparse-cut hub walk (degree above
    // the pooled-partials gate).
    let kernel = seeded_kernel_cut(160, 0xF00D);
    let mut hub_rng = Pcg64::seeded(0xF00E);
    let hub_edges: Vec<(usize, usize, f64)> =
        (1..4400).map(|j| (0usize, j, hub_rng.uniform(0.0, 1.0))).collect();
    let hub = CutFn::from_edges(4400, &hub_edges, hub_rng.uniform_vec(4400, -1.0, 1.0));
    let oracles: [(&dyn Submodular, &str); 2] = [(&kernel, "kernel-cut"), (&hub, "hub-cut")];
    for (f, label) in oracles {
        let p = f.ground_size();
        let mut rng = Pcg64::seeded(0xA110C + p as u64);
        let mut w = rng.normal_vec(p);
        let mut ws = GreedyWorkspace::new(p);
        ws.set_pool(Some(Arc::clone(&pool)));
        let mut s = vec![0.0; p];
        for _ in 0..3 {
            greedy_base_vertex(f, &w, &mut ws, &mut s);
            for x in w.iter_mut() {
                *x += 0.01;
            }
        }
        let before: Vec<AtomicU64> = (0..workers).map(|_| AtomicU64::new(0)).collect();
        let after: Vec<AtomicU64> = (0..workers).map(|_| AtomicU64::new(0)).collect();
        pool.run(&|wk| {
            before[wk].store(ALLOC_COUNT.with(|c| c.get()), Ordering::Relaxed);
        });
        let mut drift = 0.001;
        let main_allocs = count_allocs(|| {
            for _ in 0..5 {
                greedy_base_vertex(f, &w, &mut ws, &mut s);
                for x in w.iter_mut() {
                    *x += drift;
                    drift = -drift;
                }
            }
        });
        pool.run(&|wk| {
            after[wk].store(ALLOC_COUNT.with(|c| c.get()), Ordering::Relaxed);
        });
        assert_eq!(
            main_allocs, 0,
            "{label}: pooled pass allocated {main_allocs} times on the main thread"
        );
        for wk in 0..workers {
            let delta =
                after[wk].load(Ordering::Relaxed) - before[wk].load(Ordering::Relaxed);
            assert_eq!(delta, 0, "{label}: worker {wk} allocated {delta} times");
        }
    }
}

#[test]
fn greedy_pass_is_zero_alloc_for_gaussian_mi() {
    let mut rng = Pcg64::seeded(6);
    let points: Vec<[f64; 2]> = (0..24)
        .map(|_| [rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)])
        .collect();
    let m = rng.uniform_vec(24, -0.5, 0.5);
    let f = GaussianMiFn::from_points(&points, 1.5, 0.1, m);
    assert_greedy_zero_alloc(&f, "gaussian_mi");
}

#[test]
fn greedy_pass_is_zero_alloc_through_scaled_reduction() {
    let inner = seeded_cut(40, 7);
    let active = vec![1, 9];
    let kept: Vec<usize> = (0..40).filter(|i| ![1, 5, 9].contains(i)).collect();
    let scaled = ScaledFn::new(&inner, &active, kept);
    assert_greedy_zero_alloc(&scaled, "scaled(cut)");
}

/// Assert that `step` reaches a window of 20 consecutive calls with zero
/// allocations. Buffers grow to their high-water marks during convergence
/// (corral/atom-set growth IS allocation — that's state, not scratch), so
/// the steady state is found by measuring, not by guessing an iteration
/// count.
fn assert_eventually_zero_alloc(mut step: impl FnMut(), label: &str) {
    let mut last = u64::MAX;
    for _attempt in 0..6 {
        let n = count_allocs(|| {
            for _ in 0..20 {
                step();
            }
        });
        if n == 0 {
            return;
        }
        last = n;
        for _ in 0..2000 {
            step();
        }
    }
    panic!("{label}: still allocating ({last} allocs / 20 steps) after warm-up");
}

/// One full IAES-style restart cycle — cold rebuild at full size, a few
/// steps, ground-set contraction, projected-corral warm restart, a few
/// more steps — must settle to **zero** heap allocations once every
/// buffer has reached its high-water size. This certifies the
/// acceptance criterion that a solver restart across a contraction is
/// allocation-free at steady state (the engine-side id bookkeeping is
/// measured separately; this pins the solver + scaled-oracle path).
#[test]
fn warm_restart_across_contraction_is_zero_alloc() {
    let p = 48;
    let inner = seeded_kernel_cut(p, 4242);
    let kept_full: Vec<usize> = (0..p).collect();
    // Drop every fifth element; certify one of them active.
    let kept_small: Vec<usize> = (0..p).filter(|&i| i % 5 != 0).collect();
    let w_full = vec![0.0; p];
    let mut scaled = ScaledFn::new(&inner, &[], kept_full.clone());
    let mut solver = MinNormPoint::new(&scaled, MinNormOptions::default(), None);
    let mut map = sfm_screen::lovasz::ContractionMap::new();
    let mut w_surv: Vec<f64> = Vec::new();
    let mut round = || {
        scaled.set_reduction(&[], &kept_full);
        solver.reset(&scaled, &w_full);
        for _ in 0..6 {
            solver.step(&scaled);
        }
        w_surv.clear();
        w_surv.extend(kept_small.iter().map(|&i| solver.w()[i]));
        scaled.contract(&[0], &kept_small, &mut map);
        solver.reset_mapped(&scaled, &w_surv, &map);
        for _ in 0..6 {
            solver.step(&scaled);
        }
    };
    for _ in 0..4 {
        round();
    }
    let n = count_allocs(&mut round);
    assert_eq!(
        n, 0,
        "contraction warm-restart cycle allocated {n} times after warm-up"
    );
}

/// Steady-state tracing is allocation-free: the ring is pre-sized at
/// attach time, recording overwrites the oldest slot in place once
/// full, and the per-step phase-clock drain is plain arithmetic. A
/// traced solve round — steps with trace timing enabled, one phase
/// drain and one boundary record per step, exactly the engine's
/// cadence — must allocate nothing at the high-water mark. The tiny
/// ring capacity forces the wrap path into the measured window.
#[test]
fn traced_solve_steady_state_is_zero_alloc() {
    use sfm_screen::obs::{TraceEvent, TraceSink};
    let p = 48;
    let inner = seeded_kernel_cut(p, 4242);
    let kept_full: Vec<usize> = (0..p).collect();
    let w_full = vec![0.0; p];
    let mut scaled = ScaledFn::new(&inner, &[], kept_full.clone());
    let mut solver = MinNormPoint::new(&scaled, MinNormOptions::default(), None);
    solver.set_trace_timing(true);
    let sink = TraceSink::with_capacity(8);
    let mut iter = 0u64;
    let mut round = || {
        scaled.set_reduction(&[], &kept_full);
        solver.reset(&scaled, &w_full);
        for _ in 0..6 {
            let ev = solver.step(&scaled);
            let ph = solver.take_phase_ns();
            iter += 1;
            let mut tev = TraceEvent::default();
            tev.iter = iter;
            tev.gap = ev.gap;
            tev.greedy_ns = ph.oracle_ns;
            tev.kind_ns = ph.kind_ns;
            sink.record(&tev);
        }
    };
    for _ in 0..4 {
        round();
    }
    let n = count_allocs(&mut round);
    assert_eq!(n, 0, "traced steady-state round allocated {n} times after warm-up");
    let s = sink.summary();
    assert_eq!(s.events, iter, "summary must count every record, wrap included");
    assert!(s.dropped > 0, "the measured window must have wrapped the ring");
}

/// An attached-but-not-due checkpoint sink is bitwise inert in the hot
/// loop: the engine's per-boundary due check is two integer compares,
/// and only a *due* boundary builds a snapshot (which clones freely —
/// that cost is opt-in via the cadence). A solve round with a
/// checkpoint conf attached whose cadence never comes due — the
/// engine's exact boundary logic, same solver cadence as the traced
/// round above — must allocate nothing at the high-water mark.
#[test]
fn checkpoint_armed_solve_rounds_are_zero_alloc_when_not_due() {
    use sfm_screen::screening::checkpoint::{CheckpointConf, CheckpointSink};
    let p = 48;
    let inner = seeded_kernel_cut(p, 9933);
    let kept_full: Vec<usize> = (0..p).collect();
    let w_full = vec![0.0; p];
    let mut scaled = ScaledFn::new(&inner, &[], kept_full.clone());
    let mut solver = MinNormPoint::new(&scaled, MinNormOptions::default(), None);
    let ckpt = Some(CheckpointConf::new(CheckpointSink::in_memory(), usize::MAX));
    let mut total_iters = 0usize;
    let last_ckpt_iter = 0usize;
    let mut due = 0u64;
    let mut round = || {
        scaled.set_reduction(&[], &kept_full);
        solver.reset(&scaled, &w_full);
        for _ in 0..6 {
            // The engine's boundary due check, verbatim: attached, never
            // due at this cadence, so the snapshot branch never runs.
            if let Some(conf) = ckpt.as_ref() {
                if total_iters > last_ckpt_iter
                    && total_iters % conf.every.max(1) == 0
                {
                    due += 1;
                }
            }
            solver.step(&scaled);
            total_iters += 1;
        }
    };
    for _ in 0..4 {
        round();
    }
    let n = count_allocs(&mut round);
    assert_eq!(
        n, 0,
        "checkpoint-armed steady-state round allocated {n} times after warm-up"
    );
    assert_eq!(due, 0, "the cadence must never have come due in this test");
    let conf = ckpt.as_ref().unwrap();
    assert_eq!(conf.sink.written(), 0, "an inert sink must have stored nothing");
}

/// Same cycle for the Frank–Wolfe solver: with the atom keys interned in
/// a flat `IndexMat` and the hash-sorted id lookup replacing the old
/// owned-key HashMap, the FW contraction restart — including the
/// in-place key remap, rehash, duplicate merge, and atom regeneration —
/// must be allocation-free at the high-water mark (ROADMAP item).
#[test]
fn fw_warm_restart_across_contraction_is_zero_alloc() {
    let p = 36;
    let inner = seeded_kernel_cut(p, 777);
    let kept_full: Vec<usize> = (0..p).collect();
    let kept_small: Vec<usize> = (0..p).filter(|&i| i % 6 != 0).collect();
    let w_full = vec![0.0; p];
    let mut scaled = ScaledFn::new(&inner, &[], kept_full.clone());
    let mut fw = FrankWolfe::new(&scaled, FwOptions::default(), None);
    let mut map = sfm_screen::lovasz::ContractionMap::new();
    let mut w_surv: Vec<f64> = Vec::new();
    let mut round = || {
        scaled.set_reduction(&[], &kept_full);
        fw.reset(&scaled, &w_full);
        for _ in 0..8 {
            fw.step(&scaled);
        }
        w_surv.clear();
        w_surv.extend(kept_small.iter().map(|&i| fw.w()[i]));
        scaled.contract(&[0], &kept_small, &mut map);
        fw.reset_mapped(&scaled, &w_surv, &map);
        for _ in 0..8 {
            fw.step(&scaled);
        }
    };
    for _ in 0..4 {
        round();
    }
    let n = count_allocs(&mut round);
    assert_eq!(
        n, 0,
        "FW contraction warm-restart cycle allocated {n} times after warm-up"
    );
}

/// Steady-state rounds of the decomposable block solver at `threads = 1`
/// (one mutex-slotted component sweep + line search + global certificate
/// pass) must allocate nothing once the per-worker arena and every
/// component buffer reached working size — including the generic
/// component's translated-warm-dual path (`reset_translated` carries the
/// corral in place every round). The pooled `threads = 4` path is
/// certified separately below by sampling each worker's thread-local
/// counter through the pool.
#[test]
fn block_solver_rounds_are_zero_alloc_at_one_thread() {
    use sfm_screen::decompose::{
        BlockProxSolver, Component, DecomposableFn, DecomposeOptions,
    };
    let p = 24;
    let mut rng = Pcg64::seeded(888);
    let chain_edges: Vec<(usize, usize, f64)> =
        (0..p - 1).map(|i| (i, i + 1, rng.uniform(0.1, 1.0))).collect();
    let chain = CutFn::from_edges(p, &chain_edges, vec![0.0; p]);
    let g: Vec<f64> = (0..=p).map(|k| 1.2 * (k as f64).sqrt()).collect();
    let dec = DecomposableFn::new(
        p,
        vec![
            Component::generic(Box::new(chain), (0..p).collect()),
            Component::cardinality(g, rng.uniform_vec(p, -0.5, 0.5), (0..p).collect()),
            Component::modular(rng.uniform_vec(p, -1.0, 1.0), (0..p).collect()),
        ],
    );
    let mut solver =
        BlockProxSolver::new(&dec, DecomposeOptions { threads: 1, ..Default::default() });
    for _ in 0..30 {
        solver.step(&dec);
    }
    assert_eventually_zero_alloc(
        || {
            solver.step(&dec);
        },
        "BlockProxSolver::step",
    );
}

/// Pooled steady-state block rounds at `threads = 4` must be as
/// allocation-free as `threads = 1`: dispatching a job to the parked
/// worker pool is one mutex round-trip + condvar wake (no scoped-thread
/// spawn), the per-worker arenas are pre-sized to the largest component
/// (so work stealing cannot trigger a first-touch grow), and the
/// Gauss–Seidel grid round runs entirely on closed forms. The counting
/// allocator is per-thread, so the workers' own counters are sampled
/// through the pool before and after the measured window — main thread
/// AND every worker must report zero.
#[test]
fn block_solver_rounds_are_zero_alloc_at_four_threads() {
    use sfm_screen::decompose::builders::grid_cut_components;
    use sfm_screen::decompose::{BlockProxSolver, DecomposeOptions};
    use sfm_screen::workloads::grid::eight_neighbor_edges;
    use std::sync::atomic::{AtomicU64, Ordering};
    let (h, w) = (12, 12);
    let mut rng = Pcg64::seeded(999);
    let edges: Vec<(usize, usize, f64)> = eight_neighbor_edges(h, w)
        .into_iter()
        .map(|(a, b)| (a, b, rng.uniform(0.1, 1.0)))
        .collect();
    let unary = rng.uniform_vec(h * w, -1.0, 1.0);
    let dec = grid_cut_components(h, w, &edges, unary).unwrap();
    let mut solver =
        BlockProxSolver::new(&dec, DecomposeOptions { threads: 4, ..Default::default() });
    assert_eq!(solver.num_threads(), 4);
    assert!(solver.uses_gauss_seidel(), "grid decompositions are fully grouped");
    for _ in 0..30 {
        solver.step(&dec);
    }
    let before: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
    let after: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
    {
        let pool = solver.pool().expect("threads = 4 must own a parked pool");
        assert_eq!(pool.size(), 4);
        pool.run(&|wk| {
            before[wk].store(ALLOC_COUNT.with(|c| c.get()), Ordering::Relaxed);
        });
    }
    let main_allocs = count_allocs(|| {
        for _ in 0..20 {
            solver.step(&dec);
        }
    });
    {
        let pool = solver.pool().expect("pool still present");
        pool.run(&|wk| {
            after[wk].store(ALLOC_COUNT.with(|c| c.get()), Ordering::Relaxed);
        });
    }
    assert_eq!(
        main_allocs, 0,
        "t=4 block rounds allocated {main_allocs} times on the main thread"
    );
    for wk in 0..4 {
        let delta =
            after[wk].load(Ordering::Relaxed) - before[wk].load(Ordering::Relaxed);
        assert_eq!(delta, 0, "worker {wk} allocated {delta} times in steady state");
    }
}

#[test]
fn minnorm_steady_state_steps_are_zero_alloc() {
    let f = IwataFn::new(24);
    let mut solver = MinNormPoint::new(&f, MinNormOptions::default(), None);
    for _ in 0..200 {
        solver.step(&f);
    }
    assert_eventually_zero_alloc(
        || {
            solver.step(&f);
        },
        "MinNormPoint::step",
    );
}

#[test]
fn frankwolfe_steady_state_steps_are_zero_alloc() {
    let f = IwataFn::new(12);
    let mut fw = FrankWolfe::new(&f, FwOptions::default(), None);
    for _ in 0..3000 {
        fw.step(&f);
    }
    assert_eventually_zero_alloc(
        || {
            fw.step(&f);
        },
        "FrankWolfe::step",
    );
    // The solution is still correct after the counted steps.
    let brute = brute_force_sfm(&f, 1e-9);
    let a = sfm_screen::lovasz::sup_level_set(fw.w(), 0.0);
    assert_eq!(a, brute.minimal);
}
