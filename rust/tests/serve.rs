//! Integration tests for the resident solve service: the three-job
//! script CI pipes through `sfm-screen serve`, response correlation
//! across concurrent workers, default deadlines, and decomposed jobs.
//!
//! The failure matrix that needs injected faults (panic containment,
//! NaN gaps, slow-job queue overflow) lives in `tests/failpoints.rs`
//! behind `--features failpoint`.

use sfm_screen::coordinator::json::Json;
use sfm_screen::coordinator::serve::{ServeCore, ServeOptions};
use std::io::Write;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Shared capture buffer usable as a service sink.
#[derive(Clone, Default)]
struct Buf(Arc<Mutex<Vec<u8>>>);

impl Write for Buf {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(data);
        Ok(data.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl Buf {
    fn lines(&self) -> Vec<Json> {
        let raw = String::from_utf8(self.0.lock().unwrap().clone()).unwrap();
        raw.lines().map(|l| Json::parse(l).expect("response line parses")).collect()
    }
}

fn field<'a>(env: &'a Json, key: &str) -> &'a Json {
    env.get(key).unwrap_or_else(|| panic!("response missing `{key}`"))
}

fn status(env: &Json) -> &str {
    field(env, "status").as_str().unwrap()
}

fn by_id<'a>(lines: &'a [Json], id: &str) -> &'a Json {
    lines
        .iter()
        .find(|e| e.get("id").and_then(Json::as_str) == Some(id))
        .unwrap_or_else(|| panic!("no response with id `{id}`"))
}

/// The CI smoke script: a well-formed job, a malformed job, and a
/// deadline-zero job → exactly three structured responses with the
/// right statuses, and the service survives all of them.
#[test]
fn three_job_script_yields_three_structured_responses() {
    let buf = Buf::default();
    let core = ServeCore::start(&ServeOptions::default(), Box::new(buf.clone()));
    core.submit_line(r#"{"id": "good", "workload": {"kind": "iwata", "p": 24}}"#);
    core.submit_line(r#"{"id": "bad", "workload": {"kind": "iwata", "p": 24}, "epz": 0.1}"#);
    core.submit_line(
        r#"{"id": "late", "deadline_ms": 0, "workload": {"kind": "iwata", "p": 24}}"#,
    );
    core.finish();
    let lines = buf.lines();
    assert_eq!(lines.len(), 3);

    let good = by_id(&lines, "good");
    assert_eq!(status(good), "ok");
    assert!(matches!(field(good, "error"), Json::Null));
    assert_eq!(
        field(good, "report").get("converged").unwrap().as_bool(),
        Some(true)
    );

    let bad = by_id(&lines, "bad");
    assert_eq!(status(bad), "error");
    let err = field(bad, "error");
    assert_eq!(err.get("kind").unwrap().as_str(), Some("invalid"));
    let msg = err.get("message").unwrap().as_str().unwrap();
    assert!(msg.contains("epz"), "error must name the bad field: {msg}");

    let late = by_id(&lines, "late");
    assert_eq!(status(late), "partial");
    let report = field(late, "report");
    assert_eq!(report.get("cancel_reason").unwrap().as_str(), Some("deadline"));
    assert_eq!(report.get("converged").unwrap().as_bool(), Some(false));
}

/// Several concurrent workers, many jobs: every job gets exactly one
/// response, correlated by `id`, and identical specs produce identical
/// minima regardless of which worker ran them.
#[test]
fn concurrent_workers_answer_every_job_exactly_once() {
    let buf = Buf::default();
    let opts = ServeOptions { workers: 3, ..Default::default() };
    let core = ServeCore::start(&opts, Box::new(buf.clone()));
    for i in 0..9 {
        core.submit_line(&format!(
            r#"{{"id": "job-{i}", "workload": {{"kind": "iwata", "p": 28}}}}"#
        ));
    }
    core.finish();
    let lines = buf.lines();
    assert_eq!(lines.len(), 9);
    let first = field(by_id(&lines, "job-0"), "report")
        .get("minimum")
        .unwrap()
        .as_num()
        .unwrap();
    for i in 0..9 {
        let env = by_id(&lines, &format!("job-{i}"));
        assert_eq!(status(env), "ok");
        let min = field(env, "report").get("minimum").unwrap().as_num().unwrap();
        assert_eq!(min.to_bits(), first.to_bits(), "job-{i} diverged");
    }
    // Identical workloads reuse the cached oracle. Workers that race
    // the very first build may each miss once, so the floor is
    // 9 jobs − 3 workers = 6 hits, not 8.
    let hits = core.cache_hits();
    assert!(hits >= 6, "expected ≥6 cache hits, got {hits}");
}

/// `--deadline-ms` applies to requests that carry no deadline of their
/// own, and a per-request `deadline_ms` overrides it.
#[test]
fn default_deadline_applies_unless_request_overrides() {
    let buf = Buf::default();
    let opts = ServeOptions { default_deadline_ms: Some(0), ..Default::default() };
    let core = ServeCore::start(&opts, Box::new(buf.clone()));
    core.submit_line(r#"{"id": "inherits", "workload": {"kind": "iwata", "p": 24}}"#);
    let line =
        r#"{"id": "overrides", "deadline_ms": 60000, "workload": {"kind": "iwata", "p": 24}}"#;
    core.submit_line(line);
    core.finish();
    let lines = buf.lines();
    assert_eq!(lines.len(), 2);
    assert_eq!(status(by_id(&lines, "inherits")), "partial");
    assert_eq!(status(by_id(&lines, "overrides")), "ok");
}

/// Decomposed jobs run through the block solver and report the same
/// minimum as the monolithic solve of the same workload.
#[test]
fn decomposed_job_matches_monolithic_minimum() {
    let buf = Buf::default();
    let core = ServeCore::start(&ServeOptions::default(), Box::new(buf.clone()));
    let wl = r#""workload": {"kind": "two-moons", "p": 60, "seed": 11}"#;
    core.submit_line(&format!(r#"{{"id": "mono", {wl}}}"#));
    core.submit_line(&format!(r#"{{"id": "block", {wl}, "decompose": true}}"#));
    core.finish();
    let lines = buf.lines();
    assert_eq!(lines.len(), 2);
    let mono = by_id(&lines, "mono");
    let block = by_id(&lines, "block");
    assert_eq!(status(mono), "ok");
    assert_eq!(status(block), "ok");
    let m1 = field(mono, "report").get("minimum").unwrap().as_num().unwrap();
    let m2 = field(block, "report").get("minimum").unwrap().as_num().unwrap();
    assert!((m1 - m2).abs() < 1e-6, "monolithic {m1} vs decomposed {m2}");
}

/// Responses keep flowing while earlier jobs are still running: submit
/// a batch and verify every line is complete, parseable JSON (the sink
/// is line-buffered under a lock, so concurrent workers never tear).
#[test]
fn response_lines_never_interleave() {
    let buf = Buf::default();
    let opts = ServeOptions { workers: 4, ..Default::default() };
    let core = ServeCore::start(&opts, Box::new(buf.clone()));
    let t0 = Instant::now();
    for i in 0..12 {
        core.submit_line(&format!(
            r#"{{"id": "n{i}", "workload": {{"kind": "iwata", "p": {}}}}}"#,
            20 + (i % 4) * 4
        ));
    }
    core.finish();
    assert!(t0.elapsed() < Duration::from_secs(60), "service wedged");
    // Buf::lines() already Json::parse-checks every line.
    assert_eq!(buf.lines().len(), 12);
}
