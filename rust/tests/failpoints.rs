//! Fault-injection matrix (`--features failpoint`): every containment
//! boundary in the resident service and the IAES engine, driven by
//! deterministically armed fail-points.
//!
//! The fail-point registry is process-global, so CI runs this binary
//! with `--test-threads=1`; a serial guard keeps ad-hoc local runs
//! correct too.
#![cfg(feature = "failpoint")]

use sfm_screen::brute::brute_force_sfm;
use sfm_screen::coordinator::json::Json;
use sfm_screen::coordinator::serve::{ServeCore, ServeOptions};
use sfm_screen::decompose::builders::grid_cut_components;
use sfm_screen::decompose::{solve_decomposed, solve_decomposed_resumed, DecomposeOptions};
use sfm_screen::rng::Pcg64;
use sfm_screen::runtime::cancel::{CancelReason, CancelToken};
use sfm_screen::runtime::failpoint::{self, FpAction};
use sfm_screen::screening::checkpoint::{CheckpointConf, CheckpointSink};
use sfm_screen::screening::iaes::{IaesEngine, IaesOptions, NumericFault};
use sfm_screen::submodular::kernel_cut::KernelCutFn;
use sfm_screen::workloads::grid::eight_neighbor_edges;
use std::collections::HashSet;
use std::io::Write;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

fn serial() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Shared capture buffer usable as a service sink.
#[derive(Clone, Default)]
struct Buf(Arc<Mutex<Vec<u8>>>);

impl Write for Buf {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(data);
        Ok(data.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl Buf {
    fn lines(&self) -> Vec<Json> {
        let raw = String::from_utf8(self.0.lock().unwrap().clone()).unwrap();
        raw.lines().map(|l| Json::parse(l).expect("response line parses")).collect()
    }

    /// Complete response lines so far (safe to poll while workers write).
    fn newlines(&self) -> usize {
        self.0.lock().unwrap().iter().filter(|&&b| b == b'\n').count()
    }

    fn wait_for(&self, n: usize) {
        let deadline = Instant::now() + Duration::from_secs(60);
        while self.newlines() < n && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(self.newlines() >= n, "timed out waiting for {n} responses");
    }
}

fn by_id<'a>(lines: &'a [Json], id: &str) -> &'a Json {
    lines
        .iter()
        .find(|e| e.get("id").and_then(Json::as_str) == Some(id))
        .unwrap_or_else(|| panic!("no response with id `{id}`"))
}

fn status(env: &Json) -> &str {
    env.get("status").unwrap().as_str().unwrap()
}

fn error_kind(env: &Json) -> &str {
    env.get("error").unwrap().get("kind").unwrap().as_str().unwrap()
}

fn error_message(env: &Json) -> &str {
    env.get("error").unwrap().get("message").unwrap().as_str().unwrap()
}

fn random_kernel_cut(p: usize, rng: &mut Pcg64) -> KernelCutFn {
    let mut k = vec![0.0; p * p];
    for i in 0..p {
        for j in (i + 1)..p {
            let w = rng.uniform(0.0, 1.0);
            k[i * p + j] = w;
            k[j * p + i] = w;
        }
    }
    let unary = rng.uniform_vec(p, -2.0, 2.0);
    KernelCutFn::new(p, k, unary)
}

/// An injected panic in the greedy oracle is contained at the job
/// boundary: the poisoned job answers `kind: "panic"`, the worker
/// rebuilds its oracle pool, and later jobs on the same worker produce
/// correct results.
#[test]
fn oracle_panic_is_contained_and_the_pool_rebuilt() {
    let _g = serial();
    failpoint::reset();
    let direct = {
        let f = sfm_screen::submodular::iwata::IwataFn::new(26);
        sfm_screen::screening::iaes::solve_sfm_with_screening(&f, &IaesOptions::default())
            .unwrap()
    };
    let buf = Buf::default();
    let opts = ServeOptions { workers: 1, oracle_threads: 2, ..Default::default() };
    let core = ServeCore::start(&opts, Box::new(buf.clone()));
    failpoint::arm("oracle", FpAction::Panic, 1);
    core.submit_line(r#"{"id": "doomed", "workload": {"kind": "iwata", "p": 26}}"#);
    core.submit_line(r#"{"id": "after-1", "workload": {"kind": "iwata", "p": 26}}"#);
    core.submit_line(r#"{"id": "after-2", "workload": {"kind": "iwata", "p": 26}}"#);
    buf.wait_for(3);
    assert_eq!(core.pool_rebuilds(), 1, "one contained panic → one pool rebuild");
    core.finish();
    failpoint::reset();

    let lines = buf.lines();
    assert_eq!(lines.len(), 3);
    let doomed = by_id(&lines, "doomed");
    assert_eq!(status(doomed), "error");
    assert_eq!(error_kind(doomed), "panic");
    assert!(
        error_message(doomed).contains("failpoint `oracle`"),
        "panic message should surface: {}",
        error_message(doomed)
    );
    for id in ["after-1", "after-2"] {
        let env = by_id(&lines, id);
        assert_eq!(status(env), "ok", "{id} must be unaffected by the panic");
        let min = env.get("report").unwrap().get("minimum").unwrap().as_num().unwrap();
        assert_eq!(min.to_bits(), direct.minimum.to_bits(), "{id} diverged");
    }
}

/// The serve metrics registry lives outside the workers, so a contained
/// panic and the ensuing pool rebuild must not reset a single counter:
/// the poisoned job stays accounted as panicked + error, the follow-up
/// job as ok, and a stats line answered after the rebuild reports all
/// of it.
#[test]
fn metrics_survive_a_worker_panic_and_pool_rebuild() {
    let _g = serial();
    failpoint::reset();
    let buf = Buf::default();
    let opts = ServeOptions { workers: 1, oracle_threads: 2, ..Default::default() };
    let core = ServeCore::start(&opts, Box::new(buf.clone()));
    failpoint::arm("oracle", FpAction::Panic, 1);
    core.submit_line(r#"{"id": "doomed", "workload": {"kind": "iwata", "p": 26}}"#);
    core.submit_line(r#"{"id": "after", "workload": {"kind": "iwata", "p": 26}}"#);
    buf.wait_for(2);
    // The gauge covers queued + in-flight; wait for the worker to fully
    // retire both jobs so every histogram observation has landed.
    let t0 = Instant::now();
    while core.metrics().queue_depth.get() != 0 {
        assert!(t0.elapsed() < Duration::from_secs(30), "jobs never drained");
        std::thread::sleep(Duration::from_millis(2));
    }
    core.submit_line(r#"{"id": "stats", "op": "stats"}"#);
    buf.wait_for(3);
    core.finish();
    failpoint::reset();

    let m = core.metrics();
    assert_eq!(m.pool_rebuilds.get(), 1, "one contained panic → one rebuild");
    assert_eq!(m.jobs_panicked.get(), 1);
    assert_eq!(m.jobs_error.get(), 1);
    assert_eq!(m.jobs_ok.get(), 1);
    assert_eq!(m.jobs_accepted.get(), 2);
    assert_eq!(m.queue_depth.get(), 0);
    assert_eq!(m.wall_error.count(), 1);
    assert_eq!(m.wall_ok.count(), 1);
    assert_eq!(m.queue_wait.count(), 2, "both jobs observed a queue wait");

    let lines = buf.lines();
    let stats = by_id(&lines, "stats");
    assert_eq!(status(stats), "ok");
    let jobs = stats.get("stats").unwrap().get("jobs").unwrap();
    assert_eq!(jobs.get("panicked").unwrap().as_num(), Some(1.0));
    assert_eq!(jobs.get("ok").unwrap().as_num(), Some(1.0));
    assert_eq!(
        stats.get("stats").unwrap().get("pool_rebuilds").unwrap().as_num(),
        Some(1.0)
    );
}

/// A NaN injected into the duality gap is refused by the engine's
/// non-finite guard as a typed [`NumericFault`] — screening never sees
/// an undefined radius.
#[test]
fn nan_gap_is_a_typed_numeric_fault() {
    let _g = serial();
    failpoint::reset();
    let f = sfm_screen::submodular::iwata::IwataFn::new(24);
    failpoint::arm("iaes-gap", FpAction::Nan, 1);
    let err = IaesEngine::new(&f, IaesOptions::default()).run().unwrap_err();
    failpoint::reset();
    let fault = err.downcast_ref::<NumericFault>().expect("typed NumericFault");
    assert_eq!(fault.what, "duality gap");
    let msg = err.to_string();
    assert!(msg.contains("non-finite"), "{msg}");
    assert!(msg.contains("refusing to screen"), "{msg}");
}

/// The serve boundary classifies a NaN-gap failure as `kind: "numeric"`
/// and stays alive for the next job.
#[test]
fn nan_gap_yields_a_numeric_response_and_a_live_service() {
    let _g = serial();
    failpoint::reset();
    let buf = Buf::default();
    let core = ServeCore::start(&ServeOptions::default(), Box::new(buf.clone()));
    failpoint::arm("iaes-gap", FpAction::Nan, 1);
    core.submit_line(r#"{"id": "poisoned", "workload": {"kind": "iwata", "p": 24}}"#);
    core.submit_line(r#"{"id": "healthy", "workload": {"kind": "iwata", "p": 24}}"#);
    core.finish();
    failpoint::reset();

    let lines = buf.lines();
    assert_eq!(lines.len(), 2);
    let poisoned = by_id(&lines, "poisoned");
    assert_eq!(status(poisoned), "error");
    assert_eq!(error_kind(poisoned), "numeric");
    assert!(error_message(poisoned).contains("duality gap"));
    assert_eq!(status(by_id(&lines, "healthy")), "ok");
}

/// Deadline expiry mid-solve: slow every major iteration down, give the
/// solve a deadline a few iterations long, and verify that (a) the run
/// stops early with the deadline reason, (b) every certificate fired
/// before the stop respects the brute-force minimizer lattice — partial
/// safety is the whole point of boundary-only cancellation.
#[test]
fn deadline_expiry_mid_solve_keeps_screening_safe() {
    let _g = serial();
    let mut total_triggers = 0usize;
    for seed in [9101u64, 9102, 9103] {
        failpoint::reset();
        let mut rng = Pcg64::seeded(seed);
        let f = random_kernel_cut(16, &mut rng);
        let brute = brute_force_sfm(&f, 1e-7);
        failpoint::arm("iaes-iter", FpAction::Delay(Duration::from_millis(20)), 1);
        let opts = IaesOptions {
            eps: 1e-15,
            rho: 0.9,
            max_iters: 100_000,
            cancel: Some(CancelToken::with_deadline(Duration::from_millis(90))),
            ..Default::default()
        };
        let report = IaesEngine::new(&f, opts).run().unwrap();
        failpoint::reset();

        assert_eq!(
            report.cancel_reason,
            Some(CancelReason::DeadlineExpired),
            "seed {seed}: 20ms/iter against a 90ms deadline must expire"
        );
        assert!(!report.converged, "seed {seed}");
        assert!(
            report.iters < 100,
            "seed {seed}: deadline should stop the run within a handful of \
             iterations, got {}",
            report.iters
        );
        let minimal: std::collections::HashSet<usize> =
            brute.minimal.iter().copied().collect();
        let maximal: std::collections::HashSet<usize> =
            brute.maximal.iter().copied().collect();
        for trig in &report.triggers {
            total_triggers += trig.new_active_ids.len() + trig.new_inactive_ids.len();
            for &a in &trig.new_active_ids {
                assert!(
                    minimal.contains(&a),
                    "seed {seed}: active certificate {a} outside the minimal \
                     minimizer {:?} after an early stop",
                    brute.minimal
                );
            }
            for &n in &trig.new_inactive_ids {
                assert!(
                    !maximal.contains(&n),
                    "seed {seed}: inactive certificate {n} inside the maximal \
                     minimizer {:?} after an early stop",
                    brute.maximal
                );
            }
        }
    }
    // With ρ = 0.9 the gate fires within a few iterations; across three
    // seeds at least one certificate must have been exercised, or this
    // test silently stopped testing partial safety.
    assert!(total_triggers > 0, "no certificates fired before any deadline");
}

/// Explicit cancellation from another thread interrupts a slowed solve
/// promptly (at the next iteration boundary) with the `cancelled`
/// reason.
#[test]
fn explicit_cancel_interrupts_a_slow_solve() {
    let _g = serial();
    failpoint::reset();
    failpoint::arm("iaes-iter", FpAction::Delay(Duration::from_millis(25)), 1);
    let token = CancelToken::new();
    let handle = {
        let token = token.clone();
        let opts = IaesOptions {
            eps: 1e-15,
            max_iters: 100_000,
            cancel: Some(token),
            ..Default::default()
        };
        std::thread::spawn(move || {
            let f = sfm_screen::submodular::iwata::IwataFn::new(40);
            IaesEngine::new(&f, opts).run().unwrap()
        })
    };
    std::thread::sleep(Duration::from_millis(60));
    let t0 = Instant::now();
    token.cancel();
    let report = handle.join().unwrap();
    let latency = t0.elapsed();
    failpoint::reset();
    assert_eq!(report.cancel_reason, Some(CancelReason::Cancelled));
    assert!(!report.converged);
    // One iteration boundary away: the 25ms injected delay plus slack.
    assert!(
        latency < Duration::from_secs(5),
        "cancel took {latency:?} to be observed"
    );
}

/// With one worker stuck in a slow job, the bounded queue rejects the
/// overflowing submission with `queue_full` — and still answers every
/// admitted job.
#[test]
fn slow_job_overflows_the_bounded_queue() {
    let _g = serial();
    failpoint::reset();
    let buf = Buf::default();
    let opts = ServeOptions { workers: 1, queue_cap: 1, ..Default::default() };
    let core = ServeCore::start(&opts, Box::new(buf.clone()));
    failpoint::arm("serve-job", FpAction::Delay(Duration::from_millis(150)), 1);
    core.submit_line(r#"{"id": "slow", "workload": {"kind": "iwata", "p": 24}}"#);
    // Let the worker pop the job and enter the injected delay, so the
    // queue is empty for exactly one more admission.
    std::thread::sleep(Duration::from_millis(50));
    core.submit_line(r#"{"id": "queued", "workload": {"kind": "iwata", "p": 24}}"#);
    core.submit_line(r#"{"id": "over", "workload": {"kind": "iwata", "p": 24}}"#);
    core.finish();
    failpoint::reset();

    let lines = buf.lines();
    assert_eq!(lines.len(), 3);
    let over = by_id(&lines, "over");
    assert_eq!(status(over), "rejected");
    assert_eq!(error_kind(over), "queue_full");
    assert_eq!(status(by_id(&lines, "slow")), "ok");
    assert_eq!(status(by_id(&lines, "queued")), "ok");
}

/// Kill/resume matrix, monolithic arm: a solve killed by an injected
/// panic at the Nth major-iteration boundary leaves a valid checkpoint
/// behind; resuming from it reaches the brute-force-verified minimizer,
/// and the checkpoint's screened sets are subsets of the final ones
/// (resume loses no certificate and invents none).
#[test]
fn killed_monolithic_solve_resumes_to_the_brute_force_minimizer() {
    let _g = serial();
    let mut exercised = 0usize;
    for seed in [4401u64, 4402, 4403] {
        failpoint::reset();
        let mut rng = Pcg64::seeded(seed);
        let f = random_kernel_cut(14, &mut rng);
        let brute = brute_force_sfm(&f, 1e-7);
        let base = IaesOptions { eps: 1e-9, max_iters: 10_000, ..Default::default() };
        let clean = IaesEngine::new(&f, base.clone()).run().unwrap();
        let sink = CheckpointSink::in_memory();
        let mut armed = base.clone();
        armed.checkpoint = Some(CheckpointConf::new(sink.clone(), 1));
        failpoint::arm("iaes-iter", FpAction::Panic, 4);
        let killed = catch_unwind(AssertUnwindSafe(|| {
            IaesEngine::new(&f, armed).run().unwrap()
        }));
        failpoint::reset();
        if killed.is_ok() {
            // Converged before the 4th boundary — nothing to resume.
            continue;
        }
        exercised += 1;
        let ck = sink.latest().expect("a killed 4-iteration solve left a checkpoint");
        ck.validate().unwrap();
        assert_eq!(ck.iter, 3, "seed {seed}: capture precedes the 4th boundary hit");
        let resumed =
            IaesEngine::new(&f, base.clone()).resume_from(ck.clone()).unwrap().run().unwrap();
        assert!(
            (resumed.minimum - brute.minimum).abs() < 1e-6,
            "seed {seed}: resumed minimum {} vs brute {}",
            resumed.minimum,
            brute.minimum
        );
        assert_eq!(
            resumed.minimizer, brute.minimal,
            "seed {seed}: resumed run missed the minimal minimizer"
        );
        assert_eq!(
            resumed.minimizer, clean.minimizer,
            "seed {seed}: resumed and uninterrupted runs disagree"
        );
        // Checkpoint screened sets ⊆ final screened sets.
        let minimal: HashSet<usize> = brute.minimal.iter().copied().collect();
        let maximal: HashSet<usize> = brute.maximal.iter().copied().collect();
        for &a in &ck.active {
            assert!(minimal.contains(&a), "seed {seed}: checkpointed active {a} unsafe");
        }
        for &n in &ck.inactive {
            assert!(!maximal.contains(&n), "seed {seed}: checkpointed inactive {n} unsafe");
        }
        assert!(
            resumed.screened_active >= ck.active.len()
                && resumed.screened_inactive >= ck.inactive.len(),
            "seed {seed}: resumed run lost certified elements"
        );
    }
    assert!(exercised > 0, "no seed survived to the 4th boundary — matrix untested");
}

/// Kill/resume matrix, decomposed arm (t ∈ {1, 4}): the block-prox
/// solve killed mid-run resumes from its per-component checkpoint to
/// the same brute-force-verified minimal minimizer.
#[test]
fn killed_decomposed_solve_resumes_to_the_brute_force_minimizer() {
    let _g = serial();
    let (h, w) = (3, 4);
    let mut rng = Pcg64::seeded(4410);
    let edges: Vec<(usize, usize, f64)> = eight_neighbor_edges(h, w)
        .into_iter()
        .map(|(a, b)| (a, b, rng.uniform(0.0, 1.2)))
        .collect();
    let unary = rng.uniform_vec(h * w, -1.5, 1.5);
    let mono = sfm_screen::submodular::cut::CutFn::from_edges(h * w, &edges, unary.clone());
    let dec = grid_cut_components(h, w, &edges, unary).unwrap();
    let brute = brute_force_sfm(&mono, 1e-9);
    let base = IaesOptions { eps: 1e-10, max_iters: 30_000, ..Default::default() };
    let mut exercised = 0usize;
    for threads in [1usize, 4] {
        failpoint::reset();
        let dopts = DecomposeOptions { threads, ..Default::default() };
        let sink = CheckpointSink::in_memory();
        let mut armed = base.clone();
        armed.checkpoint = Some(CheckpointConf::new(sink.clone(), 1));
        failpoint::arm("iaes-iter", FpAction::Panic, 3);
        let killed = catch_unwind(AssertUnwindSafe(|| {
            solve_decomposed(&dec, &armed, dopts).unwrap()
        }));
        failpoint::reset();
        if killed.is_ok() {
            continue;
        }
        exercised += 1;
        let ck = sink.latest().expect("killed decomposed solve left a checkpoint");
        ck.validate().unwrap();
        assert!(
            ck.solver.as_ref().is_some_and(|s| !s.components.is_empty()),
            "t={threads}: decomposed checkpoint must carry component duals"
        );
        let resumed = solve_decomposed_resumed(&dec, &base, dopts, ck.clone()).unwrap();
        assert!(
            (resumed.minimum - brute.minimum).abs() < 1e-7,
            "t={threads}: resumed minimum {} vs brute {}",
            resumed.minimum,
            brute.minimum
        );
        assert_eq!(
            resumed.minimizer, brute.minimal,
            "t={threads}: resumed decomposed run missed the minimal minimizer"
        );
        let minimal: HashSet<usize> = brute.minimal.iter().copied().collect();
        let maximal: HashSet<usize> = brute.maximal.iter().copied().collect();
        for &a in &ck.active {
            assert!(minimal.contains(&a), "t={threads}: checkpointed active {a} unsafe");
        }
        for &n in &ck.inactive {
            assert!(!maximal.contains(&n), "t={threads}: checkpointed inactive {n} unsafe");
        }
    }
    assert!(exercised > 0, "no thread count survived to the 3rd boundary");
}

/// Serve retry, cold re-admission: a job killed by a panic *before* the
/// solve starts (so no checkpoint exists yet) is retried cold and
/// answers `status: "ok"` — the acceptance scenario for `--retries 1`.
#[test]
fn retried_panicked_job_answers_ok() {
    let _g = serial();
    failpoint::reset();
    let direct = {
        let f = sfm_screen::submodular::iwata::IwataFn::new(26);
        sfm_screen::screening::iaes::solve_sfm_with_screening(&f, &IaesOptions::default())
            .unwrap()
    };
    let buf = Buf::default();
    let opts =
        ServeOptions { workers: 1, retries: 1, retry_backoff_ms: 5, ..Default::default() };
    let core = ServeCore::start(&opts, Box::new(buf.clone()));
    failpoint::arm("serve-job", FpAction::Panic, 1);
    core.submit_line(r#"{"id": "flaky", "workload": {"kind": "iwata", "p": 26}}"#);
    core.finish();
    failpoint::reset();

    let lines = buf.lines();
    assert_eq!(lines.len(), 1);
    let flaky = by_id(&lines, "flaky");
    assert_eq!(status(flaky), "ok", "retried job must answer ok, not surface the panic");
    let min = flaky.get("report").unwrap().get("minimum").unwrap().as_num().unwrap();
    assert_eq!(min.to_bits(), direct.minimum.to_bits());
    let m = core.metrics();
    assert_eq!(m.jobs_retried.get(), 1);
    assert_eq!(m.jobs_panicked.get(), 1, "the contained panic stays accounted");
    assert_eq!(m.jobs_ok.get(), 1);
    assert_eq!(m.jobs_error.get(), 0, "a successful retry is not an error");
    assert_eq!(m.resumes.get(), 0, "panic before the solve → nothing to resume from");
}

/// Serve retry, warm re-admission: a job killed *mid-solve* leaves
/// in-memory boundary checkpoints behind; the retry resumes from the
/// last one (`resumes == 1`, `checkpoints_written > 0`) and still lands
/// on the uninterrupted solve's minimizer.
#[test]
fn retried_mid_solve_panic_resumes_from_its_checkpoint() {
    let _g = serial();
    failpoint::reset();
    let direct = {
        let f = sfm_screen::submodular::iwata::IwataFn::new(26);
        sfm_screen::screening::iaes::solve_sfm_with_screening(&f, &IaesOptions::default())
            .unwrap()
    };
    let buf = Buf::default();
    let opts =
        ServeOptions { workers: 1, retries: 1, retry_backoff_ms: 5, ..Default::default() };
    let core = ServeCore::start(&opts, Box::new(buf.clone()));
    failpoint::arm("iaes-iter", FpAction::Panic, 2);
    core.submit_line(r#"{"id": "killed", "workload": {"kind": "iwata", "p": 26}}"#);
    core.finish();
    failpoint::reset();

    let lines = buf.lines();
    assert_eq!(lines.len(), 1);
    let killed = by_id(&lines, "killed");
    assert_eq!(status(killed), "ok");
    let min = killed.get("report").unwrap().get("minimum").unwrap().as_num().unwrap();
    assert!(
        (min - direct.minimum).abs() < 1e-6,
        "resumed retry minimum {min} vs direct {}",
        direct.minimum
    );
    let m = core.metrics();
    assert_eq!(m.jobs_retried.get(), 1);
    assert_eq!(m.jobs_panicked.get(), 1);
    assert_eq!(m.resumes.get(), 1, "the retry must resume warm, not restart cold");
    assert!(
        m.checkpoints_written.get() >= 1,
        "a retry-armed job snapshots every boundary"
    );
    assert_eq!(m.jobs_ok.get(), 1);
}

/// Exhausted retry budget: when the retried attempt fails too (here
/// with an injected NaN gap), the job answers a structured error and
/// every faulted attempt stays accounted.
#[test]
fn exhausted_retry_budget_answers_a_structured_error() {
    let _g = serial();
    failpoint::reset();
    let buf = Buf::default();
    let opts =
        ServeOptions { workers: 1, retries: 1, retry_backoff_ms: 5, ..Default::default() };
    let core = ServeCore::start(&opts, Box::new(buf.clone()));
    failpoint::arm("serve-job", FpAction::Panic, 1);
    failpoint::arm("iaes-gap", FpAction::Nan, 1);
    core.submit_line(r#"{"id": "doomed", "workload": {"kind": "iwata", "p": 24}}"#);
    core.finish();
    failpoint::reset();

    let lines = buf.lines();
    assert_eq!(lines.len(), 1);
    let doomed = by_id(&lines, "doomed");
    assert_eq!(status(doomed), "error");
    assert_eq!(error_kind(doomed), "numeric", "the *final* attempt's fault classifies");
    let m = core.metrics();
    assert_eq!(m.jobs_retried.get(), 1, "one retry spent, budget exhausted");
    assert_eq!(m.jobs_panicked.get(), 1);
    assert_eq!(m.jobs_numeric_faulted.get(), 1);
    assert_eq!(m.jobs_error.get(), 1);
    assert_eq!(m.jobs_ok.get(), 0);
}

/// Regression (original-deadline preservation): a retry must never
/// extend the job's admission deadline. The first attempt panics
/// immediately; by the time the backoff elapses the original deadline
/// has passed, so the retried attempt must come back `partial` with
/// zero iterations — a re-armed (fresh) deadline would let this tiny
/// solve finish and answer `ok`.
#[test]
fn retry_never_extends_the_original_admission_deadline() {
    let _g = serial();
    failpoint::reset();
    let buf = Buf::default();
    let opts =
        ServeOptions { workers: 1, retries: 1, retry_backoff_ms: 100, ..Default::default() };
    let core = ServeCore::start(&opts, Box::new(buf.clone()));
    failpoint::arm("serve-job", FpAction::Panic, 1);
    let line =
        r#"{"id": "late", "deadline_ms": 40, "workload": {"kind": "iwata", "p": 24}}"#;
    core.submit_line(line);
    core.finish();
    failpoint::reset();

    let lines = buf.lines();
    assert_eq!(lines.len(), 1);
    let late = by_id(&lines, "late");
    assert_eq!(
        status(late),
        "partial",
        "a retry re-armed from re-admission would have answered ok"
    );
    let report = late.get("report").unwrap();
    assert_eq!(report.get("cancel_reason").unwrap().as_str(), Some("deadline"));
    assert_eq!(report.get("iters").unwrap().as_num(), Some(0.0));
    let m = core.metrics();
    assert_eq!(m.jobs_retried.get(), 1);
    assert_eq!(m.jobs_partial.get(), 1);
}

/// Deadlines are armed at admission, so time spent queued behind a slow
/// job counts: a short-deadline job stuck in the queue comes back as an
/// immediate partial report with zero iterations.
#[test]
fn deadline_covers_time_spent_in_the_queue() {
    let _g = serial();
    failpoint::reset();
    let buf = Buf::default();
    let opts = ServeOptions { workers: 1, ..Default::default() };
    let core = ServeCore::start(&opts, Box::new(buf.clone()));
    failpoint::arm("serve-job", FpAction::Delay(Duration::from_millis(150)), 1);
    core.submit_line(r#"{"id": "slow", "workload": {"kind": "iwata", "p": 24}}"#);
    let line =
        r#"{"id": "starved", "deadline_ms": 40, "workload": {"kind": "iwata", "p": 24}}"#;
    core.submit_line(line);
    core.finish();
    failpoint::reset();

    let lines = buf.lines();
    assert_eq!(lines.len(), 2);
    assert_eq!(status(by_id(&lines, "slow")), "ok");
    let starved = by_id(&lines, "starved");
    assert_eq!(status(starved), "partial");
    let report = starved.get("report").unwrap();
    assert_eq!(report.get("cancel_reason").unwrap().as_str(), Some("deadline"));
    assert_eq!(report.get("iters").unwrap().as_num(), Some(0.0));
}
