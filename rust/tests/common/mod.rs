//! Shared helpers for the integration-test binaries.
//!
//! (`tests/decompose.rs` keeps its own `thread_matrix()` — its knob
//! semantics genuinely differ: the block solver accepts `t = 1` as a
//! matrix entry, while a pooled-oracle count below 2 means "no pool".)

/// The `SFM_BENCH_THREADS` pooled-oracle thread count, when it names a
/// count a pool can serve (≥ 2; the monolithic convention is `t − 1`
/// parked workers plus the calling thread). This is CI's single knob:
/// the pooled monolithic leg sets it to an *unpinned* count (3) so the
/// default t ∈ {2, 4} matrices stay meaningful and the leg is never a
/// no-op.
pub fn env_pool_threads() -> Option<usize> {
    std::env::var("SFM_BENCH_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&t| t > 1)
}
