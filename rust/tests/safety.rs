//! Cross-family safety suite: the screening certificates must never
//! contradict the brute-force lattice of minimizers, for every function
//! family, rule subset, solver, and trigger schedule.
//!
//! This is the paper's central claim ("IAES is safe in the sense that it
//! would never sacrifice any accuracy") tested end to end.

use sfm_screen::brute::brute_force_sfm;
use sfm_screen::rng::Pcg64;
use sfm_screen::screening::iaes::{IaesEngine, IaesOptions, SolverChoice};
use sfm_screen::screening::RuleSet;
use sfm_screen::solvers::frankwolfe::FwOptions;
use sfm_screen::solvers::minnorm::MinNormOptions;
use sfm_screen::submodular::concave_card::ConcaveCardFn;
use sfm_screen::submodular::coverage::CoverageFn;
use sfm_screen::submodular::cut::CutFn;
use sfm_screen::submodular::iwata::IwataFn;
use sfm_screen::submodular::kernel_cut::KernelCutFn;
use sfm_screen::submodular::Submodular;

fn random_kernel_cut(p: usize, rng: &mut Pcg64) -> KernelCutFn {
    let mut k = vec![0.0; p * p];
    for i in 0..p {
        for j in (i + 1)..p {
            let w = rng.uniform(0.0, 1.0);
            k[i * p + j] = w;
            k[j * p + i] = w;
        }
    }
    let unary = rng.uniform_vec(p, -2.0, 2.0);
    KernelCutFn::new(p, k, unary)
}

fn random_sparse_cut(p: usize, rng: &mut Pcg64) -> CutFn {
    let mut edges = Vec::new();
    for i in 0..p {
        for j in (i + 1)..p {
            if rng.bernoulli(0.3) {
                edges.push((i, j, rng.uniform(0.0, 1.5)));
            }
        }
    }
    CutFn::from_edges(p, &edges, rng.uniform_vec(p, -1.5, 1.5))
}

/// Solve with screening and assert (a) the result is a true minimizer,
/// (b) every trigger's certificates respect the minimizer lattice.
fn assert_safe(f: &dyn Submodular, opts: &IaesOptions, label: &str) {
    let brute = brute_force_sfm(f, 1e-7);
    let report = IaesEngine::new(f, opts.clone()).run().unwrap();
    assert!(
        (report.minimum - brute.minimum).abs() < 1e-5 * (1.0 + brute.minimum.abs()),
        "{label}: IAES minimum {} vs brute {}",
        report.minimum,
        brute.minimum
    );
    // Certificates vs lattice: active ⊆ maximal minimizer is NOT enough;
    // active elements must appear in the *minimal* minimizer's closure —
    // precisely: active ⇒ in every minimizer ⇒ in the minimal one.
    let minimal: std::collections::HashSet<usize> =
        brute.minimal.iter().copied().collect();
    let maximal: std::collections::HashSet<usize> =
        brute.maximal.iter().copied().collect();
    for trig in &report.triggers {
        for &a in &trig.new_active_ids {
            assert!(
                minimal.contains(&a),
                "{label}: active certificate {a} not in minimal minimizer {:?}",
                brute.minimal
            );
        }
        for &n in &trig.new_inactive_ids {
            assert!(
                !maximal.contains(&n),
                "{label}: inactive certificate {n} inside maximal minimizer {:?}",
                brute.maximal
            );
        }
    }
}

#[test]
fn safety_across_function_families() {
    let mut rng = Pcg64::seeded(7001);
    let opts = IaesOptions { eps: 1e-9, ..Default::default() };
    for trial in 0..6 {
        let p = 8 + (trial % 5);
        assert_safe(&random_kernel_cut(p, &mut rng), &opts, "kernel-cut");
        assert_safe(&random_sparse_cut(p, &mut rng), &opts, "sparse-cut");
        let m = rng.uniform_vec(p, -2.0, 2.0);
        assert_safe(
            &ConcaveCardFn::sqrt(p, rng.uniform(0.5, 2.5), m),
            &opts,
            "concave-card",
        );
        assert_safe(&CoverageFn::random(p, 3 * p, 4, &mut rng), &opts, "coverage");
        assert_safe(&IwataFn::new(p), &opts, "iwata");
    }
}

#[test]
fn safety_under_all_rule_subsets() {
    let mut rng = Pcg64::seeded(7002);
    for rules in [
        RuleSet::all(),
        RuleSet::aes_only(),
        RuleSet::ies_only(),
        RuleSet::pair1_only(),
        RuleSet::pair2_only(),
    ] {
        let f = random_kernel_cut(10, &mut rng);
        let opts = IaesOptions { rules, eps: 1e-9, ..Default::default() };
        assert_safe(&f, &opts, &format!("{rules:?}"));
    }
}

#[test]
fn safety_under_aggressive_and_lazy_triggering() {
    let mut rng = Pcg64::seeded(7003);
    for rho in [0.05, 0.3, 0.9, 0.99] {
        let f = random_kernel_cut(9, &mut rng);
        let opts = IaesOptions { rho, eps: 1e-9, ..Default::default() };
        assert_safe(&f, &opts, &format!("rho={rho}"));
    }
}

#[test]
fn safety_with_frank_wolfe_solver() {
    let mut rng = Pcg64::seeded(7004);
    for _ in 0..3 {
        let f = random_kernel_cut(9, &mut rng);
        let opts = IaesOptions {
            solver: SolverChoice::FrankWolfe(FwOptions::default()),
            eps: 1e-8,
            max_iters: 50_000,
            ..Default::default()
        };
        assert_safe(&f, &opts, "fw-solver");
    }
}

#[test]
fn safety_with_loose_minnorm_tolerances() {
    // Sloppier inner solves produce looser gaps — screening must stay safe.
    let mut rng = Pcg64::seeded(7005);
    let f = random_kernel_cut(10, &mut rng);
    let opts = IaesOptions {
        solver: SolverChoice::MinNorm(MinNormOptions {
            wolfe_tol: 1e-6,
            ..Default::default()
        }),
        eps: 1e-7,
        ..Default::default()
    };
    assert_safe(&f, &opts, "loose-minnorm");
}

#[test]
fn safety_through_multi_contraction_warm_restarts() {
    // The projected-corral warm restart must preserve every safety
    // property across instances that force *several* ground-set
    // contractions (min_reduction_frac = 0 restarts on every
    // certificate). Both solvers take their reset_mapped path here.
    let mut rng = Pcg64::seeded(7007);
    for trial in 0..4 {
        let p = 9 + trial;
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 0.4);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let unary = rng.uniform_vec(p, -3.0, 3.0);
        let f = KernelCutFn::new(p, k, unary);
        let opts = IaesOptions {
            eps: 1e-10,
            min_reduction_frac: 0.0,
            ..Default::default()
        };
        let report = IaesEngine::new(&f, opts.clone()).run().unwrap();
        let contractions = report
            .history
            .windows(2)
            .filter(|w| w[1].p_remaining < w[0].p_remaining)
            .count();
        assert!(
            contractions >= 1,
            "trial {trial}: instance produced no contraction"
        );
        assert_safe(&f, &opts, &format!("warm-multi-contraction t{trial}"));
        let fw_opts = IaesOptions {
            solver: SolverChoice::FrankWolfe(FwOptions::default()),
            eps: 1e-8,
            max_iters: 50_000,
            min_reduction_frac: 0.0,
            ..Default::default()
        };
        assert_safe(&f, &fw_opts, &format!("warm-multi-contraction-fw t{trial}"));
    }
}

#[test]
fn ground_set_reaches_zero_on_separable_instances() {
    // The "no theoretical limit" property: with strong unaries everything
    // is eventually certified and the residual problem empties.
    let mut rng = Pcg64::seeded(7006);
    let p = 12;
    let mut k = vec![0.0; p * p];
    for i in 0..p {
        for j in (i + 1)..p {
            let w = rng.uniform(0.0, 0.05); // weak coupling
            k[i * p + j] = w;
            k[j * p + i] = w;
        }
    }
    let unary: Vec<f64> =
        (0..p).map(|i| if i % 2 == 0 { -3.0 } else { 3.0 }).collect();
    let f = KernelCutFn::new(p, k, unary);
    let opts = IaesOptions { eps: 1e-12, ..Default::default() };
    let report = IaesEngine::new(&f, opts).run().unwrap();
    assert!(report.emptied, "expected full screening, got {report:?}");
    let brute = brute_force_sfm(&f, 1e-9);
    assert!((report.minimum - brute.minimum).abs() < 1e-7);
}
