//! Fixture-tree and self-check tests for the `sfm_lint` invariant pass.
//!
//! Seeded-violation sources are written to a temp tree whose layout
//! mimics the crate (`src/runtime/…`, `src/coordinator/serve.rs`, …) so
//! the path-scoped rules trigger; diagnostics must come back with the
//! exact file and line. The fixtures live in raw strings here — string
//! literals are invisible to the lexer-driven rules, so this file stays
//! lint-clean itself (`repo_sources_are_lint_clean` checks that).

use sfm_screen::analysis::{lint_tree, Config, Diagnostic};
use std::path::{Path, PathBuf};

const BAD_LOCK: &str = r#"fn f(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
"#;

const BAD_UNSAFE: &str = r#"fn f(p: *const u32) -> u32 {
    unsafe { *p }
}
"#;

const BAD_HOT: &str = r#"pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    let scratch: Vec<f64> = Vec::new();
    let _ = scratch;
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}
"#;

const BAD_SERVE: &str = r#"pub fn run_job(xs: &[u8]) -> u8 {
    let first = xs[0];
    let parsed = std::str::from_utf8(xs).unwrap();
    let _ = parsed.len();
    first
}
"#;

const WAIVED: &str = r#"fn f(m: &std::sync::Mutex<u32>) -> u32 {
    // lint: allow(lock-poison) — fixture exercises the waiver path.
    *m.lock().unwrap()
}

fn g() {
    // lint: allow(lock-poison)
    let x = 1;
    let _ = x;
}
"#;

const CLEAN: &str = r#"// SAFETY: fixture — the pointer is valid by construction.
unsafe fn deref(p: *const u32) -> u32 {
    // SAFETY: see the function contract above.
    unsafe { *p }
}

fn helper(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap_or_else(|e| e.into_inner())
}
"#;

/// The temp fixture tree; removed on drop (best-effort).
struct FixtureTree {
    root: PathBuf,
}

impl FixtureTree {
    fn new(tag: &str) -> FixtureTree {
        let root =
            std::env::temp_dir().join(format!("sfm_lint_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let files: &[(&str, &str)] = &[
            ("src/runtime/bad_lock.rs", BAD_LOCK),
            ("src/runtime/bad_unsafe.rs", BAD_UNSAFE),
            ("src/linalg/vecops.rs", BAD_HOT),
            ("src/coordinator/serve.rs", BAD_SERVE),
            ("src/screening/waived.rs", WAIVED),
            ("src/clean.rs", CLEAN),
        ];
        for (rel, content) in files {
            let path = root.join(rel);
            std::fs::create_dir_all(path.parent().expect("fixture path has parent"))
                .expect("create fixture dir");
            std::fs::write(&path, content).expect("write fixture");
        }
        FixtureTree { root }
    }
}

impl Drop for FixtureTree {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.root);
    }
}

fn has(diags: &[Diagnostic], suffix: &str, line: u32, rule: &str) -> bool {
    diags.iter().any(|d| d.file.ends_with(suffix) && d.line == line && d.rule == rule)
}

#[test]
fn fixture_violations_reported_with_file_and_line() {
    let tree = FixtureTree::new("engine");
    let (nfiles, diags) =
        lint_tree(&tree.root, &Config::default_for_repo()).expect("lint fixture tree");
    assert_eq!(nfiles, 6);

    assert!(has(&diags, "src/runtime/bad_lock.rs", 2, "lock-poison"), "{diags:?}");
    assert!(has(&diags, "src/runtime/bad_unsafe.rs", 2, "safety-comment"), "{diags:?}");
    assert!(has(&diags, "src/linalg/vecops.rs", 2, "hot-path-alloc"), "{diags:?}");
    assert!(has(&diags, "src/coordinator/serve.rs", 2, "no-panic-paths"), "{diags:?}");
    assert!(has(&diags, "src/coordinator/serve.rs", 3, "no-panic-paths"), "{diags:?}");
    // The waived violation is suppressed; the reason-less waiver is not.
    assert!(!diags.iter().any(|d| d.file.ends_with("waived.rs") && d.rule == "lock-poison"));
    assert!(has(&diags, "src/screening/waived.rs", 7, "waiver-syntax"), "{diags:?}");
    // The clean fixture contributes nothing.
    assert!(!diags.iter().any(|d| d.file.ends_with("clean.rs")), "{diags:?}");
    // Every rule fired somewhere in the tree, and the rendered form is
    // the documented `file:line: [rule] message`.
    for rule in ["safety-comment", "lock-poison", "hot-path-alloc", "no-panic-paths", "waiver-syntax"]
    {
        let d = diags.iter().find(|d| d.rule == rule).expect(rule);
        let shown = d.to_string();
        assert!(shown.contains(&format!(":{}: [{}] ", d.line, d.rule)), "{shown}");
    }
}

#[test]
fn lint_binary_flags_fixtures_and_passes_repo() {
    let tree = FixtureTree::new("binary");
    let exe = env!("CARGO_BIN_EXE_sfm_lint");

    let bad = std::process::Command::new(exe)
        .args(["--root", tree.root.to_str().expect("utf8 tmp path")])
        .output()
        .expect("run sfm_lint on fixtures");
    assert_eq!(bad.status.code(), Some(1), "fixtures must fail the lint");
    let stdout = String::from_utf8_lossy(&bad.stdout);
    assert!(stdout.contains("bad_lock.rs:2: [lock-poison]"), "{stdout}");
    assert!(stdout.contains("bad_unsafe.rs:2: [safety-comment]"), "{stdout}");

    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut repo = std::process::Command::new(exe);
    for sub in ["src", "tests", "benches"] {
        repo.args(["--root", manifest.join(sub).to_str().expect("utf8 path")]);
    }
    let repo = repo.output().expect("run sfm_lint on repo");
    assert!(
        repo.status.success(),
        "repo must be lint-clean:\n{}",
        String::from_utf8_lossy(&repo.stdout),
    );
}

#[test]
fn repo_sources_are_lint_clean() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let cfg = Config::default_for_repo();
    let mut all = Vec::new();
    for sub in ["src", "tests", "benches"] {
        let (_, diags) = lint_tree(&manifest.join(sub), &cfg).expect("lint repo tree");
        all.extend(diags);
    }
    assert!(
        all.is_empty(),
        "repository sources must be lint-clean:\n{}",
        all.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n"),
    );
}
