//! Fixture-tree and self-check tests for the `sfm_lint` invariant pass.
//!
//! Seeded-violation sources are written to a temp tree whose layout
//! mimics the crate (`src/runtime/…`, `src/coordinator/serve.rs`, …) so
//! the path-scoped rules and root sets trigger; diagnostics must come
//! back with the exact file and line, and the transitive rules must
//! carry the cross-module call chain that produced them. The fixtures
//! live in raw strings here — string literals are invisible to the
//! lexer-driven rules, so this file stays lint-clean itself
//! (`repo_sources_are_lint_clean` checks that).

use sfm_screen::analysis::callgraph::CallGraph;
use sfm_screen::analysis::{collect_sources, hot_reach, lint_crate, lint_tree, Config, Diagnostic};
use sfm_screen::coordinator::json::Json;
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

const BAD_LOCK: &str = r#"fn f(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
"#;

const BAD_UNSAFE: &str = r#"fn f(p: *const u32) -> u32 {
    unsafe { *p }
}
"#;

/// Hot root whose own body is clean — the allocation sits two calls and
/// one module away, in `HOT_HELPERS`.
const HOT_ROOT: &str = r#"pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    stage(a);
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}
"#;

const HOT_HELPERS: &str = r#"pub fn stage(a: &[f64]) {
    scratch(a);
}

pub fn scratch(a: &[f64]) {
    let v: Vec<f64> = Vec::new();
    let _ = (v, a);
}
"#;

/// `serve_one` is a no-panic root (its unwrap sits in `WIRE`); `run_job`
/// is the panic-contained job body, checked directly (index + unwrap).
const BAD_SERVE: &str = r#"pub fn serve_one(xs: &[u8]) -> u8 {
    decode(xs)
}

pub fn run_job(xs: &[u8]) -> u8 {
    let first = xs[0];
    let parsed = std::str::from_utf8(xs).unwrap();
    let _ = parsed.len();
    first
}
"#;

const WIRE: &str = r#"pub fn decode(xs: &[u8]) -> u8 {
    let n = xs.first().unwrap();
    *n
}
"#;

/// A trace emission outside the designated boundary fns.
const BAD_BOUNDARY: &str = r#"pub fn probe(sink: &TraceSink, ev: &Event) {
    sink.record(ev);
}
"#;

const WAIVED: &str = r#"fn f(m: &std::sync::Mutex<u32>) -> u32 {
    // lint: allow(lock-poison) — fixture exercises the waiver path.
    *m.lock().unwrap()
}

fn g() {
    // lint: allow(lock-poison)
    let x = 1;
    let _ = x;
}

fn h() {
    // lint: allow(safety-comment) — nothing unsafe is left here.
    let y = 2;
    let _ = y;
}
"#;

const CLEAN: &str = r#"// SAFETY: fixture — the pointer is valid by construction.
unsafe fn deref(p: *const u32) -> u32 {
    // SAFETY: see the function contract above.
    unsafe { *p }
}

fn helper(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap_or_else(|e| e.into_inner())
}
"#;

/// The temp fixture tree; removed on drop (best-effort).
struct FixtureTree {
    root: PathBuf,
}

impl FixtureTree {
    fn new(tag: &str) -> FixtureTree {
        let root =
            std::env::temp_dir().join(format!("sfm_lint_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let files: &[(&str, &str)] = &[
            ("src/runtime/bad_lock.rs", BAD_LOCK),
            ("src/runtime/bad_unsafe.rs", BAD_UNSAFE),
            ("src/linalg/vecops.rs", HOT_ROOT),
            ("src/linalg/helpers.rs", HOT_HELPERS),
            ("src/coordinator/serve.rs", BAD_SERVE),
            ("src/coordinator/wire.rs", WIRE),
            ("src/screening/probe.rs", BAD_BOUNDARY),
            ("src/screening/waived.rs", WAIVED),
            ("src/clean.rs", CLEAN),
        ];
        for (rel, content) in files {
            let path = root.join(rel);
            std::fs::create_dir_all(path.parent().expect("fixture path has parent"))
                .expect("create fixture dir");
            std::fs::write(&path, content).expect("write fixture");
        }
        FixtureTree { root }
    }
}

impl Drop for FixtureTree {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.root);
    }
}

fn has(diags: &[Diagnostic], suffix: &str, line: u32, rule: &str) -> bool {
    diags.iter().any(|d| d.file.ends_with(suffix) && d.line == line && d.rule == rule)
}

#[test]
fn fixture_violations_cover_every_rule_with_file_and_line() {
    let tree = FixtureTree::new("engine");
    let (nfiles, diags) =
        lint_tree(&tree.root, &Config::default_for_repo()).expect("lint fixture tree");
    assert_eq!(nfiles, 9);

    assert!(has(&diags, "src/runtime/bad_lock.rs", 2, "lock-poison"), "{diags:?}");
    assert!(has(&diags, "src/runtime/bad_unsafe.rs", 2, "safety-comment"), "{diags:?}");
    // The transitive hot finding lands on the leaf, two hops from the
    // root, in a different module.
    assert!(has(&diags, "src/linalg/helpers.rs", 6, "hot-path-alloc"), "{diags:?}");
    // `run_job` is panic-contained: both its index and its unwrap are
    // direct-body findings. `decode` is reached from `serve_one`.
    assert!(has(&diags, "src/coordinator/serve.rs", 6, "no-panic-paths"), "{diags:?}");
    assert!(has(&diags, "src/coordinator/serve.rs", 7, "no-panic-paths"), "{diags:?}");
    assert!(has(&diags, "src/coordinator/wire.rs", 2, "no-panic-paths"), "{diags:?}");
    assert!(has(&diags, "src/screening/probe.rs", 2, "boundary-coupling"), "{diags:?}");
    assert!(has(&diags, "src/screening/waived.rs", 7, "waiver-syntax"), "{diags:?}");
    assert!(has(&diags, "src/screening/waived.rs", 13, "stale-waiver"), "{diags:?}");
    assert_eq!(diags.len(), 9, "{diags:?}");

    // The waived lock-poison violation is suppressed; the hot root's
    // own body and the clean fixture contribute nothing.
    assert!(!diags.iter().any(|d| d.file.ends_with("waived.rs") && d.rule == "lock-poison"));
    assert!(!diags.iter().any(|d| d.file.ends_with("vecops.rs")), "{diags:?}");
    assert!(!diags.iter().any(|d| d.file.ends_with("clean.rs")), "{diags:?}");

    // Every rule in the registry fired exactly here, and the rendered
    // form is the documented `file:line: [code rule] message`.
    let codes: BTreeSet<&str> = diags.iter().map(|d| d.code).collect();
    assert_eq!(codes.len(), 7, "{codes:?}");
    for d in &diags {
        let shown = d.to_string();
        assert!(shown.contains(&format!(":{}: [{} {}] ", d.line, d.code, d.rule)), "{shown}");
    }
}

#[test]
fn transitive_findings_carry_cross_module_chains() {
    let tree = FixtureTree::new("chains");
    let (_, diags) =
        lint_tree(&tree.root, &Config::default_for_repo()).expect("lint fixture tree");

    // Hot: dot (vecops.rs) -> stage (helpers.rs) -> scratch, which
    // allocates. PR 7 would have needed `stage` and `scratch` on a
    // manual allowlist; the graph derives them and names every hop.
    let hot = diags.iter().find(|d| d.rule == "hot-path-alloc").expect("hot finding");
    assert!(hot.file.ends_with("src/linalg/helpers.rs"), "{}", hot.file);
    assert_eq!(hot.line, 6);
    assert!(hot.msg.contains("`scratch`"), "{}", hot.msg);
    assert_eq!(hot.chain.len(), 3, "{:?}", hot.chain);
    assert!(hot.chain[0].contains("vecops.rs::dot (root @1)"), "{:?}", hot.chain);
    assert!(hot.chain[1].contains("helpers.rs::stage (called at"), "{:?}", hot.chain);
    assert!(hot.chain[1].contains("vecops.rs:2)"), "{:?}", hot.chain);
    assert!(hot.chain[2].contains("helpers.rs::scratch (called at"), "{:?}", hot.chain);
    assert!(hot.chain[2].contains("helpers.rs:2)"), "{:?}", hot.chain);

    // No-panic: serve_one (serve.rs) -> decode (wire.rs), which unwraps.
    let wire = diags.iter().find(|d| d.file.ends_with("wire.rs")).expect("wire finding");
    assert_eq!((wire.line, wire.rule), (2, "no-panic-paths"));
    assert!(wire.msg.contains("on a no-panic path"), "{}", wire.msg);
    assert_eq!(wire.chain.len(), 2, "{:?}", wire.chain);
    assert!(wire.chain[0].contains("serve.rs::serve_one (root @1)"), "{:?}", wire.chain);
    assert!(wire.chain[1].contains("wire.rs::decode (called at"), "{:?}", wire.chain);

    // Contained job body: direct findings, panic-contained chain tag.
    let contained: Vec<&Diagnostic> =
        diags.iter().filter(|d| d.file.ends_with("serve.rs")).collect();
    assert_eq!(contained.len(), 2, "{contained:?}");
    assert_eq!((contained[0].line, contained[1].line), (6, 7));
    for d in contained {
        assert!(d.msg.contains("panic-contained fn `run_job`"), "{}", d.msg);
        assert!(d.chain[0].contains("panic-contained"), "{:?}", d.chain);
    }
}

#[test]
fn lint_binary_flags_fixtures_and_passes_repo() {
    let tree = FixtureTree::new("binary");
    let exe = env!("CARGO_BIN_EXE_sfm_lint");

    let bad = std::process::Command::new(exe)
        .args(["--root", tree.root.to_str().expect("utf8 tmp path")])
        .output()
        .expect("run sfm_lint on fixtures");
    assert_eq!(bad.status.code(), Some(1), "fixtures must fail the lint");
    let stdout = String::from_utf8_lossy(&bad.stdout);
    assert!(stdout.contains("bad_lock.rs:2: [SFM002 lock-poison]"), "{stdout}");
    assert!(stdout.contains("bad_unsafe.rs:2: [SFM001 safety-comment]"), "{stdout}");
    assert!(stdout.contains("helpers.rs:6: [SFM003 hot-path-alloc]"), "{stdout}");
    assert!(stdout.contains("wire.rs:2: [SFM004 no-panic-paths]"), "{stdout}");
    assert!(stdout.contains("probe.rs:2: [SFM006 boundary-coupling]"), "{stdout}");
    assert!(stdout.contains("waived.rs:13: [SFM007 stale-waiver]"), "{stdout}");
    assert!(stdout.contains("chain:") && stdout.contains("->"), "{stdout}");

    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut repo = std::process::Command::new(exe);
    for sub in ["src", "tests", "benches"] {
        repo.args(["--root", manifest.join(sub).to_str().expect("utf8 path")]);
    }
    let repo = repo.output().expect("run sfm_lint on repo");
    assert!(
        repo.status.success(),
        "repo must be lint-clean:\n{}",
        String::from_utf8_lossy(&repo.stdout),
    );
}

#[test]
fn lint_binary_json_round_trips() {
    let tree = FixtureTree::new("json");
    let exe = env!("CARGO_BIN_EXE_sfm_lint");
    let out = std::process::Command::new(exe)
        .args(["--root", tree.root.to_str().expect("utf8 tmp path"), "--json"])
        .output()
        .expect("run sfm_lint --json");
    assert_eq!(out.status.code(), Some(1), "fixtures must still fail under --json");

    let stdout = String::from_utf8_lossy(&out.stdout);
    let parsed = Json::parse(stdout.trim()).expect("stdout parses as JSON");
    let arr = parsed.as_array().expect("top level is an array");
    let (_, diags) =
        lint_tree(&tree.root, &Config::default_for_repo()).expect("lint fixture tree");
    assert_eq!(arr.len(), diags.len());
    for (j, d) in arr.iter().zip(&diags) {
        assert_eq!(j.get("file").and_then(Json::as_str), Some(d.file.as_str()));
        assert_eq!(j.get("line").and_then(Json::as_num), Some(f64::from(d.line)));
        assert_eq!(j.get("rule").and_then(Json::as_str), Some(d.rule));
        assert_eq!(j.get("code").and_then(Json::as_str), Some(d.code));
        assert_eq!(j.get("msg").and_then(Json::as_str), Some(d.msg.as_str()));
        let chain = j.get("chain").and_then(Json::as_array).expect("chain is an array");
        assert_eq!(chain.len(), d.chain.len());
        for (hop, expect) in chain.iter().zip(&d.chain) {
            assert_eq!(hop.as_str(), Some(expect.as_str()));
        }
    }
}

#[test]
fn lint_binary_explains_hot_membership() {
    let tree = FixtureTree::new("explain");
    let exe = env!("CARGO_BIN_EXE_sfm_lint");
    let root = tree.root.to_str().expect("utf8 tmp path");

    let hot = std::process::Command::new(exe)
        .args(["--root", root, "--explain", "helpers.rs::scratch"])
        .output()
        .expect("run sfm_lint --explain");
    assert!(hot.status.success(), "{hot:?}");
    let stdout = String::from_utf8_lossy(&hot.stdout);
    assert!(stdout.contains("is hot"), "{stdout}");
    assert!(stdout.contains("(root @1)"), "{stdout}");
    assert!(stdout.contains("called at"), "{stdout}");

    let cold = std::process::Command::new(exe)
        .args(["--root", root, "--explain", "wire.rs::decode"])
        .output()
        .expect("run sfm_lint --explain on a cold fn");
    assert!(cold.status.success(), "{cold:?}");
    let stdout = String::from_utf8_lossy(&cold.stdout);
    assert!(stdout.contains("not reachable from the hot root set"), "{stdout}");

    let missing = std::process::Command::new(exe)
        .args(["--root", root, "--explain", "nope.rs::zzz"])
        .output()
        .expect("run sfm_lint --explain on a missing fn");
    assert_eq!(missing.status.code(), Some(2), "unknown fn is a usage error");
}

#[test]
fn lint_binary_lists_rules_with_codes() {
    let exe = env!("CARGO_BIN_EXE_sfm_lint");
    let out = std::process::Command::new(exe)
        .arg("--list-rules")
        .output()
        .expect("run sfm_lint --list-rules");
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    for code in ["SFM001", "SFM002", "SFM003", "SFM004", "SFM005", "SFM006", "SFM007"] {
        assert!(stdout.contains(code), "{stdout}");
    }
    assert!(stdout.contains("hot-path-alloc"), "{stdout}");
    assert!(stdout.contains("boundary-coupling"), "{stdout}");
}

/// PR 7's manual per-body allowlist for `hot-path-alloc`, retired by
/// the call-graph rewrite. The derived transitive hot set must cover
/// every function that used to be listed by hand — otherwise the
/// rewrite silently *narrowed* the rule.
const RETIRED_PR7_ALLOWLIST: &[(&str, &[&str])] = &[
    (
        "src/linalg/vecops.rs",
        &[
            "dot",
            "dot4",
            "dot_gather4",
            "norm2_sq",
            "axpy",
            "axpy4",
            "add_assign4",
            "sweep4",
            "cover_gain4",
            "relu_mac_col4",
            "max_update_col4",
            "argsort_desc_adaptive",
            "argsort_desc_into",
            "argsort_desc_remap",
            "insertion_repair",
            "project_indices",
        ],
    ),
    ("src/linalg/cholesky.rs", &["push", "remove", "retain", "solve_into"]),
    ("src/decompose/chain.rs", &["tv_prox_into"]),
    ("src/solvers/pav.rs", &["run"]),
    ("src/lovasz.rs", &["accumulate_pass"]),
    ("src/submodular/kernel_cut.rs", &["prefix_gains_scratch"]),
    (
        "src/submodular/cut.rs",
        &["prefix_gains_scratch", "chunked_adjacency_sum", "fold_partials"],
    ),
];

#[test]
fn derived_hot_set_covers_retired_pr7_allowlist() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let roots: Vec<PathBuf> = ["src", "tests", "benches"]
        .iter()
        .map(|s| manifest.join(s))
        .filter(|p| p.is_dir())
        .collect();
    let files = collect_sources(&roots).expect("read repo sources");
    let graph = CallGraph::build(&files);
    let reach = hot_reach(&graph, &Config::default_for_repo());
    for &(pat, fns) in RETIRED_PR7_ALLOWLIST {
        for &name in fns {
            let matches = graph.find(pat, name);
            assert!(!matches.is_empty(), "{pat}::{name} no longer exists in the crate");
            assert!(
                matches.iter().any(|&i| reach.seen[i]),
                "{pat}::{name} was on PR 7's manual allowlist but fell out of the \
                 derived hot set — the transitive rewrite narrowed the rule",
            );
        }
    }
}

#[test]
fn repo_sources_are_lint_clean() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let roots: Vec<PathBuf> = ["src", "tests", "benches"]
        .iter()
        .map(|s| manifest.join(s))
        .filter(|p| p.is_dir())
        .collect();
    let files = collect_sources(&roots).expect("read repo sources");
    let diags = lint_crate(&files, &Config::default_for_repo());
    assert!(
        diags.is_empty(),
        "repository sources must be lint-clean:\n{}",
        diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n"),
    );
}
