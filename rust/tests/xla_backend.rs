//! Integration tests for the XLA/PJRT screening backend.
//!
//! These require `make artifacts`; when artifacts are absent every test
//! SKIPs (prints and returns) so `cargo test` is green in a fresh clone.

use sfm_screen::rng::Pcg64;
use sfm_screen::runtime::{AffinityExec, XlaScreener};
use sfm_screen::screening::rules::RustScreener;
use sfm_screen::screening::{RuleSet, ScreenInputs, Screener};
use sfm_screen::workloads::two_moons::{TwoMoons, TwoMoonsParams};

fn xla() -> Option<XlaScreener> {
    match XlaScreener::at_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e:#}");
            None
        }
    }
}

fn random_inputs(p: usize, seed: u64) -> (Vec<f64>, f64, f64, f64) {
    let mut rng = Pcg64::seeded(seed);
    let w = rng.normal_vec(p);
    let gap = rng.uniform(1e-4, 1.0);
    // Plane near the iterate so both signs of certificates appear.
    let sum: f64 = w.iter().sum();
    let f_v = -sum + rng.uniform(-0.2, 0.2);
    let f_c = -rng.uniform(0.0, 1.0);
    (w, gap, f_v, f_c)
}

#[test]
fn masks_match_rust_backend_across_sizes() {
    let Some(xla) = xla() else { return };
    let rust = RustScreener::default();
    for &p in &[2usize, 3, 17, 64, 100, 256, 300, 1000, 1024, 2000] {
        for seed in 0..4u64 {
            let (w, gap, f_v, f_c) = random_inputs(p, 1000 + seed * 7 + p as u64);
            let inputs = ScreenInputs { w: &w, gap, f_v, f_c };
            let a = xla.screen(&inputs, RuleSet::all());
            let b = rust.screen(&inputs, RuleSet::all());
            // Masks must agree except within numerical distance of a
            // decision boundary (FMA contraction inside XLA).
            for j in 0..p {
                let near = b.wmin[j].abs().min(b.wmax[j].abs()) < 1e-6;
                if !near {
                    assert_eq!(
                        a.active[j], b.active[j],
                        "active mismatch p={p} seed={seed} j={j}"
                    );
                    assert_eq!(
                        a.inactive[j], b.inactive[j],
                        "inactive mismatch p={p} seed={seed} j={j}"
                    );
                }
                let scale = 1.0 + b.wmin[j].abs().max(b.wmax[j].abs());
                assert!(
                    (a.wmin[j] - b.wmin[j]).abs() < 1e-6 * scale,
                    "wmin p={p} j={j}: {} vs {}",
                    a.wmin[j],
                    b.wmin[j]
                );
                assert!(
                    (a.wmax[j] - b.wmax[j]).abs() < 1e-6 * scale,
                    "wmax p={p} j={j}: {} vs {}",
                    a.wmax[j],
                    b.wmax[j]
                );
            }
        }
    }
}

#[test]
fn rule_subsets_respected() {
    let Some(xla) = xla() else { return };
    let (w, gap, f_v, f_c) = random_inputs(128, 99);
    let inputs = ScreenInputs { w: &w, gap, f_v, f_c };
    let aes = xla.screen(&inputs, RuleSet::aes_only());
    assert!(aes.inactive.iter().all(|&b| !b));
    let ies = xla.screen(&inputs, RuleSet::ies_only());
    assert!(ies.active.iter().all(|&b| !b));
    let none = xla.screen(&inputs, RuleSet::none());
    assert_eq!(none.identified(), 0);
}

#[test]
fn oversize_inputs_fall_back_to_rust() {
    let Some(xla) = xla() else { return };
    let max_bucket = *xla.buckets().last().unwrap();
    let p = max_bucket + 1;
    let (w, gap, f_v, f_c) = random_inputs(p, 5);
    let inputs = ScreenInputs { w: &w, gap, f_v, f_c };
    let a = xla.screen(&inputs, RuleSet::all());
    let b = RustScreener::default().screen(&inputs, RuleSet::all());
    assert_eq!(a.active, b.active);
    assert_eq!(a.inactive, b.inactive);
}

#[test]
fn affinity_kernel_matches_rust() {
    let aff = match AffinityExec::at_default() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e:#}");
            return;
        }
    };
    for &p in &[10usize, 100, 256, 300] {
        let tm = TwoMoons::generate(TwoMoonsParams { p, seed: 42, ..Default::default() });
        let want = tm.affinity();
        let got = aff.affinity(&tm.points, tm.params.alpha).unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }
}

#[test]
fn iaes_with_xla_backend_is_lossless() {
    let Some(xla) = xla() else { return };
    use sfm_screen::screening::iaes::{solve_sfm_with_screening, IaesOptions};
    let tm = TwoMoons::generate(TwoMoonsParams { p: 60, seed: 11, ..Default::default() });
    let f = tm.kernel_cut();
    let rust_opts = IaesOptions::default();
    let xla_opts = IaesOptions {
        screener: Some(std::sync::Arc::new(xla)),
        ..Default::default()
    };
    let a = solve_sfm_with_screening(&f, &rust_opts).unwrap();
    let b = solve_sfm_with_screening(&f, &xla_opts).unwrap();
    assert!(
        (a.minimum - b.minimum).abs() < 1e-6,
        "backends disagree: {} vs {}",
        a.minimum,
        b.minimum
    );
}

#[test]
fn two_moons_built_from_xla_affinity_solves_identically() {
    let aff = match AffinityExec::at_default() {
        Ok(a) => a,
        Err(_) => {
            eprintln!("SKIP (no artifacts)");
            return;
        }
    };
    use sfm_screen::screening::iaes::{solve_sfm_with_screening, IaesOptions};
    let tm = TwoMoons::generate(TwoMoonsParams { p: 50, seed: 21, ..Default::default() });
    let k = aff.affinity(&tm.points, tm.params.alpha).unwrap();
    let f_xla = tm.kernel_cut_with_affinity(k);
    let f_rust = tm.kernel_cut();
    let a = solve_sfm_with_screening(&f_xla, &IaesOptions::default()).unwrap();
    let b = solve_sfm_with_screening(&f_rust, &IaesOptions::default()).unwrap();
    assert_eq!(a.minimizer, b.minimizer);
}
