//! Cross-validation: independent algorithms must agree with each other.
//!
//! Beyond brute force (capped at p ≤ 14 by 2^p), these tests pit the
//! pipeline's components against one another at *medium* scale, where an
//! implementation bug in any one of them would break the agreement:
//!
//! * min-norm vs pairwise-FW vs away-FW (unique min-norm point),
//! * IAES vs screening-free solves on every oracle family,
//! * Queyranne vs proximal SFM on symmetric instances,
//! * the regularization path vs direct solves of tilted functions.

use sfm_screen::prelude::*;
use sfm_screen::rng::Pcg64;
use sfm_screen::screening::parametric::RegularizationPath;
use sfm_screen::solvers::frankwolfe::FwVariant;
use sfm_screen::solvers::queyranne::queyranne;
use sfm_screen::submodular::facility::FacilityLocationFn;
use sfm_screen::submodular::modular::PlusModular;
use sfm_screen::workloads::two_moons::TwoMoonsParams;

fn solve_plain(f: &dyn Submodular, eps: f64) -> IaesReport {
    let opts = IaesOptions { rules: RuleSet::none(), eps, ..Default::default() };
    solve_sfm_with_screening(f, &opts).unwrap()
}

fn solve_iaes(f: &dyn Submodular, eps: f64) -> IaesReport {
    let opts = IaesOptions { eps, ..Default::default() };
    solve_sfm_with_screening(f, &opts).unwrap()
}

#[test]
fn three_solvers_agree_on_min_norm_point() {
    let tm = TwoMoons::generate(TwoMoonsParams { p: 60, seed: 88, ..Default::default() });
    let f = tm.knn_cut(10, 1.0);
    let run = |mut s: Box<dyn ProxSolver>, iters: usize| -> Vec<f64> {
        for _ in 0..iters {
            if s.step(&f).gap < 1e-10 {
                break;
            }
        }
        s.s().to_vec()
    };
    let mn = run(
        Box::new(MinNormPoint::new(&f, MinNormOptions::default(), None)),
        5_000,
    );
    let pw = run(
        Box::new(FrankWolfe::new(&f, FwOptions::default(), None)),
        60_000,
    );
    let away = run(
        Box::new(FrankWolfe::new(
            &f,
            FwOptions { variant: FwVariant::Away, ..Default::default() },
            None,
        )),
        60_000,
    );
    for j in 0..60 {
        assert!((mn[j] - pw[j]).abs() < 1e-3, "pairwise j={j}: {} vs {}", mn[j], pw[j]);
        assert!((mn[j] - away[j]).abs() < 1e-3, "away j={j}: {} vs {}", mn[j], away[j]);
    }
}

#[test]
fn iaes_lossless_on_every_oracle_family_medium_scale() {
    let mut rng = Pcg64::seeded(909);
    // Families at p ≈ 60–150 — way beyond brute force.
    let tm = TwoMoons::generate(TwoMoonsParams { p: 150, seed: 1, ..Default::default() });
    let knn = tm.knn_cut(10, 1.0);
    let dense = tm.kernel_cut();
    let cov = CoverageFn::random(80, 300, 6, &mut rng);
    let fac = FacilityLocationFn::random(120, 60, &mut rng);
    let iwata = IwataFn::new(120);
    let families: Vec<(&str, &dyn Submodular)> = vec![
        ("knn-cut", &knn),
        ("dense-cut", &dense),
        ("coverage", &cov),
        ("facility", &fac),
        ("iwata", &iwata),
    ];
    for (name, f) in families {
        let a = solve_plain(f, 1e-7);
        let b = solve_iaes(f, 1e-7);
        let tol = 1e-5 * (1.0 + a.minimum.abs());
        assert!(
            (a.minimum - b.minimum).abs() < tol,
            "{name}: {} vs {}",
            a.minimum,
            b.minimum
        );
    }
}

#[test]
fn queyranne_agrees_with_proximal_on_tilted_symmetric_cut() {
    // A symmetric cut has trivial SFM minimum (∅). Tilt it with a uniform
    // negative modular term γ so the global minimizer is non-trivial, then
    // compare IAES's answer against the best over Queyranne's candidate
    // plus the trivial sets — on an instance too big for brute force.
    let tm = TwoMoons::generate(TwoMoonsParams { p: 40, labeled: 0, seed: 3, ..Default::default() });
    let cut = tm.knn_cut(8, 1.0);
    let gamma = -0.35;
    let tilted = PlusModular::new(&cut, vec![gamma; 40]);
    let iaes = solve_iaes(&tilted, 1e-9);

    // The tilted function is no longer symmetric, but its minimizer over
    // each cardinality class relates to min cuts; we use Queyranne on the
    // *symmetric* part as a lower-bound witness:
    // F_tilted(A) = cut(A) + γ|A| ≥ q_min_cut_value… only for the sets
    // Queyranne saw. Instead verify first-order optimality directly:
    // no single-element flip improves the IAES minimizer.
    let p = 40;
    let mut set = vec![false; p];
    for &i in &iaes.minimizer {
        set[i] = true;
    }
    let v0 = tilted.eval(&set);
    assert!((v0 - iaes.minimum).abs() < 1e-9);
    for j in 0..p {
        let mut flip = set.clone();
        flip[j] = !flip[j];
        assert!(
            tilted.eval(&flip) >= v0 - 1e-9,
            "flip {j} improves the reported minimizer"
        );
    }

    // And Queyranne itself returns a valid nontrivial cut of the symmetric
    // part, which upper-bounds the symmetric min-cut at the IAES boundary.
    let q = queyranne(&cut);
    assert!(q.minimum >= 0.0);
    assert!(!q.minimizer.is_empty() && q.minimizer.len() < p);
}

#[test]
fn regularization_path_matches_direct_tilted_solves() {
    let tm = TwoMoons::generate(TwoMoonsParams { p: 80, seed: 12, ..Default::default() });
    let f = tm.knn_cut(10, 1.0);
    let path = RegularizationPath::compute(&f, 1e-10, 100_000).unwrap();
    for &alpha in &[-1.0, 0.0, 0.8] {
        let tilted = PlusModular::new(&f, vec![alpha; 80]);
        let direct = solve_iaes(&tilted, 1e-8);
        let from_path = path.minimizer_at(alpha);
        // Compare objective values (minimizers may differ on ties).
        let mut set = vec![false; 80];
        for &i in &from_path {
            set[i] = true;
        }
        let v_path = tilted.eval(&set);
        assert!(
            (v_path - direct.minimum).abs() < 1e-5 * (1.0 + direct.minimum.abs()),
            "alpha={alpha}: path {v_path} vs direct {}",
            direct.minimum
        );
    }
}

#[test]
fn json_export_of_medium_run_is_well_formed() {
    let tm = TwoMoons::generate(TwoMoonsParams { p: 60, seed: 7, ..Default::default() });
    let f = tm.knn_cut(10, 1.0);
    let report = solve_iaes(&f, 1e-6);
    let json = sfm_screen::coordinator::json::report_to_json(&report, true).to_string();
    assert!(json.contains("\"triggers\""));
    assert_eq!(json.matches('{').count(), json.matches('}').count());
    assert_eq!(json.matches('[').count(), json.matches(']').count());
}

#[test]
fn deferred_contraction_zero_matches_literal_algorithm2_result() {
    // frac = 0 (restart every certificate) and frac = 0.5 must agree on
    // the minimum — the schedule is a performance knob, not a semantic one.
    let tm = TwoMoons::generate(TwoMoonsParams { p: 100, seed: 23, ..Default::default() });
    let f = tm.knn_cut(10, 1.0);
    let a = IaesOptions { min_reduction_frac: 0.0, ..Default::default() };
    let b = IaesOptions { min_reduction_frac: 0.5, ..Default::default() };
    let ra = solve_sfm_with_screening(&f, &a).unwrap();
    let rb = solve_sfm_with_screening(&f, &b).unwrap();
    assert!((ra.minimum - rb.minimum).abs() < 1e-6);
}
