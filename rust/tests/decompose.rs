//! Decomposition equivalence, screening safety, and thread-count
//! determinism for the decomposable-SFM subsystem.
//!
//! * the decomposed image-grid prox solve must return the **same minimal
//!   minimizer** as the monolithic path (brute-force checked),
//! * screening masks fired from the aggregated dual `y = Σ y_i` must be
//!   safe across forced contractions (`min_reduction_frac = 0`),
//! * the block solver must be bitwise deterministic for any thread count
//!   (run this suite under `RUST_TEST_THREADS=1` *and* default
//!   parallelism — CI does both).

use sfm_screen::brute::brute_force_sfm;
use sfm_screen::decompose::builders::{grid_cut_components, star_components_from_edges};
use sfm_screen::decompose::{solve_decomposed, DecomposeOptions};
use sfm_screen::rng::Pcg64;
use sfm_screen::screening::iaes::{solve_sfm_with_screening, IaesOptions};
use sfm_screen::submodular::cut::CutFn;
use sfm_screen::workloads::grid::eight_neighbor_edges;
use sfm_screen::workloads::two_moons::{TwoMoons, TwoMoonsParams};

/// A small random 8-neighbor grid cut: `(h, w, edges, unary)`.
fn random_grid(
    h: usize,
    w: usize,
    seed: u64,
) -> (Vec<(usize, usize, f64)>, Vec<f64>) {
    let mut rng = Pcg64::seeded(seed);
    let edges: Vec<(usize, usize, f64)> = eight_neighbor_edges(h, w)
        .into_iter()
        .map(|(a, b)| (a, b, rng.uniform(0.0, 1.2)))
        .collect();
    let unary = rng.uniform_vec(h * w, -1.5, 1.5);
    (edges, unary)
}

#[test]
fn grid_decomposed_matches_monolithic_minimal_minimizer() {
    // Acceptance criterion: decomposed image-grid prox solve returns the
    // same minimal minimizer as the monolithic path, brute-force checked.
    let (h, w) = (3, 4);
    for seed in [11u64, 22, 33] {
        let (edges, unary) = random_grid(h, w, seed);
        let mono = CutFn::from_edges(h * w, &edges, unary.clone());
        let dec = grid_cut_components(h, w, &edges, unary).unwrap();
        let brute = brute_force_sfm(&mono, 1e-9);
        let opts = IaesOptions { eps: 1e-10, max_iters: 30_000, ..Default::default() };
        let mono_rep = solve_sfm_with_screening(&mono, &opts).unwrap();
        let dec_rep = solve_decomposed(
            &dec,
            &opts,
            DecomposeOptions { threads: 2, ..Default::default() },
        )
        .unwrap();
        assert!(
            (mono_rep.minimum - brute.minimum).abs() < 1e-7,
            "seed {seed}: monolithic minimum off"
        );
        assert!(
            (dec_rep.minimum - brute.minimum).abs() < 1e-7,
            "seed {seed}: decomposed minimum {} vs brute {}",
            dec_rep.minimum,
            brute.minimum
        );
        assert_eq!(
            dec_rep.minimizer, brute.minimal,
            "seed {seed}: decomposed minimizer is not the minimal minimizer"
        );
        assert_eq!(
            mono_rep.minimizer, dec_rep.minimizer,
            "seed {seed}: decomposed and monolithic minimizers differ"
        );
    }
}

#[test]
fn star_decomposed_two_moons_matches_monolithic() {
    let tm = TwoMoons::generate(TwoMoonsParams { p: 60, ..Default::default() });
    let mono = tm.knn_cut(10, 1.0);
    let dec = tm.knn_cut_decomposition(10, 1.0);
    let opts = IaesOptions::default();
    let mono_rep = solve_sfm_with_screening(&mono, &opts).unwrap();
    let dec_rep = solve_decomposed(
        &dec,
        &opts,
        DecomposeOptions { threads: 2, ..Default::default() },
    )
    .unwrap();
    assert!(
        (mono_rep.minimum - dec_rep.minimum).abs()
            < 1e-5 * (1.0 + mono_rep.minimum.abs()),
        "two-moons: decomposed {} vs monolithic {}",
        dec_rep.minimum,
        mono_rep.minimum
    );
    assert_eq!(mono_rep.minimizer, dec_rep.minimizer);
}

#[test]
fn screening_from_aggregated_dual_is_safe_across_forced_contractions() {
    // min_reduction_frac = 0 restarts the block solver on every
    // certificate — the literal Algorithm 2 — so every trigger exercises
    // per-component contraction threading. The certificates must stay
    // lossless on random stars and grids.
    let mut rng = Pcg64::seeded(404);
    for trial in 0..6 {
        let p = 8 + (trial % 3);
        let mut edges = Vec::new();
        for i in 0..p {
            for j in (i + 1)..p {
                if rng.bernoulli(0.5) {
                    edges.push((i, j, rng.uniform(0.0, 1.0)));
                }
            }
        }
        let unary = rng.uniform_vec(p, -2.0, 2.0);
        let mono = CutFn::from_edges(p, &edges, unary.clone());
        let dec = star_components_from_edges(p, &edges, unary);
        let brute = brute_force_sfm(&mono, 1e-9);
        let opts = IaesOptions {
            eps: 1e-9,
            min_reduction_frac: 0.0,
            max_iters: 30_000,
            ..Default::default()
        };
        let rep = solve_decomposed(
            &dec,
            &opts,
            DecomposeOptions { threads: 2, ..Default::default() },
        )
        .unwrap();
        assert!(
            (rep.minimum - brute.minimum).abs() < 1e-6,
            "trial {trial}: {} vs {}",
            rep.minimum,
            brute.minimum
        );
    }
    // Same drill on a grid decomposition.
    let (h, w) = (3, 3);
    let (edges, unary) = random_grid(h, w, 505);
    let mono = CutFn::from_edges(h * w, &edges, unary.clone());
    let dec = grid_cut_components(h, w, &edges, unary).unwrap();
    let brute = brute_force_sfm(&mono, 1e-9);
    let opts = IaesOptions {
        eps: 1e-9,
        min_reduction_frac: 0.0,
        max_iters: 30_000,
        ..Default::default()
    };
    let rep =
        solve_decomposed(&dec, &opts, DecomposeOptions { threads: 2, ..Default::default() })
            .unwrap();
    assert!((rep.minimum - brute.minimum).abs() < 1e-6);
}

/// Thread counts under test: the fixed 1/2 base matrix, plus an extra
/// count from `SFM_BENCH_THREADS` — CI's pooled matrix leg sets it to 4
/// under a single-threaded harness, genuinely extending the matrix (4 is
/// deliberately NOT in the base, so the leg is never a no-op) while the
/// serialized harness keeps test-runner interleaving out of the picture.
fn thread_matrix() -> Vec<usize> {
    let mut counts = vec![1usize, 2];
    if let Ok(tv) = std::env::var("SFM_BENCH_THREADS") {
        if let Ok(tv) = tv.trim().parse::<usize>() {
            if tv > 0 && !counts.contains(&tv) {
                counts.push(tv);
            }
        }
    }
    counts
}

#[test]
fn block_solver_is_deterministic_for_any_thread_count() {
    let (h, w) = (4, 4);
    let (edges, unary) = random_grid(h, w, 606);
    let dec = grid_cut_components(h, w, &edges, unary).unwrap();
    let opts = IaesOptions { eps: 1e-9, max_iters: 30_000, ..Default::default() };
    let reports: Vec<_> = thread_matrix()
        .iter()
        .map(|&t| {
            solve_decomposed(
                &dec,
                &opts,
                DecomposeOptions { threads: t, ..Default::default() },
            )
            .unwrap()
        })
        .collect();
    let base = &reports[0];
    for (i, rep) in reports.iter().enumerate().skip(1) {
        assert_eq!(rep.minimizer, base.minimizer, "minimizer differs (t index {i})");
        assert_eq!(rep.iters, base.iters, "iteration count differs (t index {i})");
        assert_eq!(
            rep.final_gap.to_bits(),
            base.final_gap.to_bits(),
            "final gap differs bitwise (t index {i})"
        );
        assert_eq!(rep.history.len(), base.history.len());
        for (a, b) in rep.history.iter().zip(&base.history) {
            assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "trajectory diverged");
            assert_eq!(a.p_remaining, b.p_remaining);
        }
        assert_eq!(rep.triggers.len(), base.triggers.len());
    }
}

#[test]
fn jacobi_schedule_is_deterministic_for_any_thread_count() {
    // Same drill with the Gauss–Seidel groups disabled: the damped-Jacobi
    // fallback must also be bitwise thread-count-deterministic.
    let (h, w) = (4, 4);
    let (edges, unary) = random_grid(h, w, 606);
    let dec = grid_cut_components(h, w, &edges, unary).unwrap();
    let opts = IaesOptions { eps: 1e-9, max_iters: 30_000, ..Default::default() };
    let reports: Vec<_> = thread_matrix()
        .iter()
        .map(|&t| {
            solve_decomposed(
                &dec,
                &opts,
                DecomposeOptions { threads: t, gauss_seidel: false, ..Default::default() },
            )
            .unwrap()
        })
        .collect();
    let base = &reports[0];
    for (i, rep) in reports.iter().enumerate().skip(1) {
        assert_eq!(rep.minimizer, base.minimizer, "minimizer differs (t index {i})");
        assert_eq!(rep.iters, base.iters, "iteration count differs (t index {i})");
        assert_eq!(
            rep.final_gap.to_bits(),
            base.final_gap.to_bits(),
            "final gap differs bitwise (t index {i})"
        );
    }
}

#[test]
fn generic_warm_dual_path_is_deterministic_for_any_thread_count() {
    // Star decompositions are all-Generic: this drill pins the
    // translated-warm-dual min-norm path (per-component solver state,
    // reset_translated each round, reset_mapped across contractions) as
    // schedule-independent — the grid drills above never touch it
    // (grids are pure Chain/Modular closed forms).
    let p = 12;
    let mut rng = Pcg64::seeded(909);
    let mut edges = Vec::new();
    for i in 0..p {
        for j in (i + 1)..p {
            if rng.bernoulli(0.4) {
                edges.push((i, j, rng.uniform(0.0, 1.0)));
            }
        }
    }
    let unary = rng.uniform_vec(p, -2.0, 2.0);
    let dec = star_components_from_edges(p, &edges, unary);
    let opts = IaesOptions {
        eps: 1e-9,
        min_reduction_frac: 0.0, // force contraction restarts too
        max_iters: 30_000,
        ..Default::default()
    };
    let reports: Vec<_> = thread_matrix()
        .iter()
        .map(|&t| {
            solve_decomposed(
                &dec,
                &opts,
                DecomposeOptions { threads: t, ..Default::default() },
            )
            .unwrap()
        })
        .collect();
    let base = &reports[0];
    for (i, rep) in reports.iter().enumerate().skip(1) {
        assert_eq!(rep.minimizer, base.minimizer, "minimizer differs (t index {i})");
        assert_eq!(rep.iters, base.iters, "iteration count differs (t index {i})");
        assert_eq!(
            rep.final_gap.to_bits(),
            base.final_gap.to_bits(),
            "final gap differs bitwise (t index {i})"
        );
    }
}

#[test]
fn gauss_seidel_and_jacobi_agree_on_minimal_minimizer_vs_brute() {
    // Both schedules — and both prox backends behind them (taut-string
    // chains for GS-grouped grids, the same chains under Jacobi damping)
    // — must land on the brute-force minimal minimizer, on 4- and
    // 8-neighbor grids.
    for (four_neighbor, seed) in [(true, 71u64), (true, 72), (false, 73), (false, 74)] {
        let (h, w) = (3, 4);
        let mut rng = Pcg64::seeded(seed);
        let raw = if four_neighbor {
            sfm_screen::workloads::grid::four_neighbor_edges(h, w)
        } else {
            eight_neighbor_edges(h, w)
        };
        let edges: Vec<(usize, usize, f64)> =
            raw.into_iter().map(|(a, b)| (a, b, rng.uniform(0.0, 1.2))).collect();
        let unary = rng.uniform_vec(h * w, -1.5, 1.5);
        let mono = CutFn::from_edges(h * w, &edges, unary.clone());
        let brute = brute_force_sfm(&mono, 1e-9);
        let dec = grid_cut_components(h, w, &edges, unary).unwrap();
        let opts = IaesOptions { eps: 1e-10, max_iters: 30_000, ..Default::default() };
        let gs = solve_decomposed(
            &dec,
            &opts,
            DecomposeOptions { threads: 2, ..Default::default() },
        )
        .unwrap();
        let ja = solve_decomposed(
            &dec,
            &opts,
            DecomposeOptions { threads: 2, gauss_seidel: false, ..Default::default() },
        )
        .unwrap();
        assert_eq!(
            gs.minimizer, brute.minimal,
            "seed {seed}: GS missed the minimal minimizer"
        );
        assert_eq!(
            ja.minimizer, brute.minimal,
            "seed {seed}: Jacobi missed the minimal minimizer"
        );
        assert!((gs.minimum - brute.minimum).abs() < 1e-7, "seed {seed}");
        assert!((ja.minimum - brute.minimum).abs() < 1e-7, "seed {seed}");
    }
}

#[test]
fn warm_and_cold_duals_agree_end_to_end() {
    // The translated-corral warm start (atoms shifted by Δz across
    // rounds, reset_mapped across contractions) changes trajectories,
    // never answers: the reached minimizer must agree with the cold
    // per-round regeneration, through full screened solves with forced
    // contractions.
    let mut rng = Pcg64::seeded(515);
    for trial in 0..4 {
        let p = 8 + trial;
        let mut edges = Vec::new();
        for i in 0..p {
            for j in (i + 1)..p {
                if rng.bernoulli(0.5) {
                    edges.push((i, j, rng.uniform(0.0, 1.0)));
                }
            }
        }
        let unary = rng.uniform_vec(p, -2.0, 2.0);
        let mono = CutFn::from_edges(p, &edges, unary.clone());
        let dec = star_components_from_edges(p, &edges, unary);
        let brute = brute_force_sfm(&mono, 1e-9);
        let opts = IaesOptions {
            eps: 1e-9,
            min_reduction_frac: 0.0,
            max_iters: 30_000,
            ..Default::default()
        };
        let warm = solve_decomposed(
            &dec,
            &opts,
            DecomposeOptions { threads: 2, ..Default::default() },
        )
        .unwrap();
        let cold = solve_decomposed(
            &dec,
            &opts,
            DecomposeOptions { threads: 2, warm_duals: false, ..Default::default() },
        )
        .unwrap();
        assert!((warm.minimum - brute.minimum).abs() < 1e-6, "trial {trial}: warm");
        assert!((cold.minimum - brute.minimum).abs() < 1e-6, "trial {trial}: cold");
        assert_eq!(
            warm.minimizer, cold.minimizer,
            "trial {trial}: warm and cold duals reached different minimizers"
        );
    }
}

#[test]
fn decomposed_jobspec_runs_and_matches_monolithic() {
    use sfm_screen::coordinator::jobs::{JobSpec, WorkloadSpec};
    let wl = WorkloadSpec::TwoMoons { p: 40, use_mi: false, seed: 3 };
    let mono = JobSpec {
        name: "tm-mono".into(),
        workload: wl.clone(),
        opts: IaesOptions::default(),
        decompose: None,
    }
    .run()
    .unwrap();
    let dec = JobSpec {
        name: "tm-dec".into(),
        workload: wl,
        opts: IaesOptions::default(),
        decompose: Some(DecomposeOptions { threads: 2, ..Default::default() }),
    }
    .run()
    .unwrap();
    assert!(
        (mono.report.minimum - dec.report.minimum).abs()
            < 1e-5 * (1.0 + mono.report.minimum.abs())
    );
    // Workloads without a decomposition fail loudly, not silently.
    let bad = JobSpec {
        name: "iwata-dec".into(),
        workload: WorkloadSpec::Iwata { p: 10 },
        opts: IaesOptions::default(),
        decompose: Some(DecomposeOptions::default()),
    };
    assert!(bad.run().is_err());
}
