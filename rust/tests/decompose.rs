//! Decomposition equivalence, screening safety, and thread-count
//! determinism for the decomposable-SFM subsystem.
//!
//! * the decomposed image-grid prox solve must return the **same minimal
//!   minimizer** as the monolithic path (brute-force checked),
//! * screening masks fired from the aggregated dual `y = Σ y_i` must be
//!   safe across forced contractions (`min_reduction_frac = 0`),
//! * the block solver must be bitwise deterministic for any thread count
//!   (run this suite under `RUST_TEST_THREADS=1` *and* default
//!   parallelism — CI does both).

use sfm_screen::brute::brute_force_sfm;
use sfm_screen::decompose::builders::{grid_cut_components, star_components_from_edges};
use sfm_screen::decompose::{solve_decomposed, DecomposeOptions};
use sfm_screen::rng::Pcg64;
use sfm_screen::screening::iaes::{solve_sfm_with_screening, IaesOptions};
use sfm_screen::submodular::cut::CutFn;
use sfm_screen::workloads::grid::eight_neighbor_edges;
use sfm_screen::workloads::two_moons::{TwoMoons, TwoMoonsParams};

/// A small random 8-neighbor grid cut: `(h, w, edges, unary)`.
fn random_grid(
    h: usize,
    w: usize,
    seed: u64,
) -> (Vec<(usize, usize, f64)>, Vec<f64>) {
    let mut rng = Pcg64::seeded(seed);
    let edges: Vec<(usize, usize, f64)> = eight_neighbor_edges(h, w)
        .into_iter()
        .map(|(a, b)| (a, b, rng.uniform(0.0, 1.2)))
        .collect();
    let unary = rng.uniform_vec(h * w, -1.5, 1.5);
    (edges, unary)
}

#[test]
fn grid_decomposed_matches_monolithic_minimal_minimizer() {
    // Acceptance criterion: decomposed image-grid prox solve returns the
    // same minimal minimizer as the monolithic path, brute-force checked.
    let (h, w) = (3, 4);
    for seed in [11u64, 22, 33] {
        let (edges, unary) = random_grid(h, w, seed);
        let mono = CutFn::from_edges(h * w, &edges, unary.clone());
        let dec = grid_cut_components(h, w, &edges, unary).unwrap();
        let brute = brute_force_sfm(&mono, 1e-9);
        let opts = IaesOptions { eps: 1e-10, max_iters: 30_000, ..Default::default() };
        let mono_rep = solve_sfm_with_screening(&mono, &opts).unwrap();
        let dec_rep = solve_decomposed(
            &dec,
            &opts,
            DecomposeOptions { threads: 2, ..Default::default() },
        )
        .unwrap();
        assert!(
            (mono_rep.minimum - brute.minimum).abs() < 1e-7,
            "seed {seed}: monolithic minimum off"
        );
        assert!(
            (dec_rep.minimum - brute.minimum).abs() < 1e-7,
            "seed {seed}: decomposed minimum {} vs brute {}",
            dec_rep.minimum,
            brute.minimum
        );
        assert_eq!(
            dec_rep.minimizer, brute.minimal,
            "seed {seed}: decomposed minimizer is not the minimal minimizer"
        );
        assert_eq!(
            mono_rep.minimizer, dec_rep.minimizer,
            "seed {seed}: decomposed and monolithic minimizers differ"
        );
    }
}

#[test]
fn star_decomposed_two_moons_matches_monolithic() {
    let tm = TwoMoons::generate(TwoMoonsParams { p: 60, ..Default::default() });
    let mono = tm.knn_cut(10, 1.0);
    let dec = tm.knn_cut_decomposition(10, 1.0);
    let opts = IaesOptions::default();
    let mono_rep = solve_sfm_with_screening(&mono, &opts).unwrap();
    let dec_rep = solve_decomposed(
        &dec,
        &opts,
        DecomposeOptions { threads: 2, ..Default::default() },
    )
    .unwrap();
    assert!(
        (mono_rep.minimum - dec_rep.minimum).abs()
            < 1e-5 * (1.0 + mono_rep.minimum.abs()),
        "two-moons: decomposed {} vs monolithic {}",
        dec_rep.minimum,
        mono_rep.minimum
    );
    assert_eq!(mono_rep.minimizer, dec_rep.minimizer);
}

#[test]
fn screening_from_aggregated_dual_is_safe_across_forced_contractions() {
    // min_reduction_frac = 0 restarts the block solver on every
    // certificate — the literal Algorithm 2 — so every trigger exercises
    // per-component contraction threading. The certificates must stay
    // lossless on random stars and grids.
    let mut rng = Pcg64::seeded(404);
    for trial in 0..6 {
        let p = 8 + (trial % 3);
        let mut edges = Vec::new();
        for i in 0..p {
            for j in (i + 1)..p {
                if rng.bernoulli(0.5) {
                    edges.push((i, j, rng.uniform(0.0, 1.0)));
                }
            }
        }
        let unary = rng.uniform_vec(p, -2.0, 2.0);
        let mono = CutFn::from_edges(p, &edges, unary.clone());
        let dec = star_components_from_edges(p, &edges, unary);
        let brute = brute_force_sfm(&mono, 1e-9);
        let opts = IaesOptions {
            eps: 1e-9,
            min_reduction_frac: 0.0,
            max_iters: 30_000,
            ..Default::default()
        };
        let rep = solve_decomposed(
            &dec,
            &opts,
            DecomposeOptions { threads: 2, ..Default::default() },
        )
        .unwrap();
        assert!(
            (rep.minimum - brute.minimum).abs() < 1e-6,
            "trial {trial}: {} vs {}",
            rep.minimum,
            brute.minimum
        );
    }
    // Same drill on a grid decomposition.
    let (h, w) = (3, 3);
    let (edges, unary) = random_grid(h, w, 505);
    let mono = CutFn::from_edges(h * w, &edges, unary.clone());
    let dec = grid_cut_components(h, w, &edges, unary).unwrap();
    let brute = brute_force_sfm(&mono, 1e-9);
    let opts = IaesOptions {
        eps: 1e-9,
        min_reduction_frac: 0.0,
        max_iters: 30_000,
        ..Default::default()
    };
    let rep =
        solve_decomposed(&dec, &opts, DecomposeOptions { threads: 2, ..Default::default() })
            .unwrap();
    assert!((rep.minimum - brute.minimum).abs() < 1e-6);
}

#[test]
fn block_solver_is_deterministic_for_any_thread_count() {
    let (h, w) = (4, 4);
    let (edges, unary) = random_grid(h, w, 606);
    let dec = grid_cut_components(h, w, &edges, unary).unwrap();
    let opts = IaesOptions { eps: 1e-9, max_iters: 30_000, ..Default::default() };
    let reports: Vec<_> = [1usize, 2, 4]
        .iter()
        .map(|&t| {
            solve_decomposed(
                &dec,
                &opts,
                DecomposeOptions { threads: t, ..Default::default() },
            )
            .unwrap()
        })
        .collect();
    let base = &reports[0];
    for (i, rep) in reports.iter().enumerate().skip(1) {
        assert_eq!(rep.minimizer, base.minimizer, "minimizer differs (t index {i})");
        assert_eq!(rep.iters, base.iters, "iteration count differs (t index {i})");
        assert_eq!(
            rep.final_gap.to_bits(),
            base.final_gap.to_bits(),
            "final gap differs bitwise (t index {i})"
        );
        assert_eq!(rep.history.len(), base.history.len());
        for (a, b) in rep.history.iter().zip(&base.history) {
            assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "trajectory diverged");
            assert_eq!(a.p_remaining, b.p_remaining);
        }
        assert_eq!(rep.triggers.len(), base.triggers.len());
    }
}

#[test]
fn decomposed_jobspec_runs_and_matches_monolithic() {
    use sfm_screen::coordinator::jobs::{JobSpec, WorkloadSpec};
    let wl = WorkloadSpec::TwoMoons { p: 40, use_mi: false, seed: 3 };
    let mono = JobSpec {
        name: "tm-mono".into(),
        workload: wl.clone(),
        opts: IaesOptions::default(),
        decompose: None,
    }
    .run()
    .unwrap();
    let dec = JobSpec {
        name: "tm-dec".into(),
        workload: wl,
        opts: IaesOptions::default(),
        decompose: Some(DecomposeOptions { threads: 2, ..Default::default() }),
    }
    .run()
    .unwrap();
    assert!(
        (mono.report.minimum - dec.report.minimum).abs()
            < 1e-5 * (1.0 + mono.report.minimum.abs())
    );
    // Workloads without a decomposition fail loudly, not silently.
    let bad = JobSpec {
        name: "iwata-dec".into(),
        workload: WorkloadSpec::Iwata { p: 10 },
        opts: IaesOptions::default(),
        decompose: Some(DecomposeOptions::default()),
    };
    assert!(bad.run().is_err());
}
