//! Trajectory determinism for the zero-allocation solver engine.
//!
//! The flat corral (`CorralMat`), the packed Gram factor, the adaptive
//! re-sort, and the oracle scratch are all *exact* accelerations: they
//! must not change a single bit of the iterate trajectory. These tests
//! pin that down by running solvers in lockstep — a fresh instance vs. a
//! warm-reset instance whose buffers are dirty from a different problem —
//! and by checking the final minimizer against brute force.

use sfm_screen::brute::brute_force_sfm;
use sfm_screen::lovasz::sup_level_set;
use sfm_screen::rng::Pcg64;
use sfm_screen::solvers::frankwolfe::{FrankWolfe, FwOptions};
use sfm_screen::solvers::minnorm::{MinNormOptions, MinNormPoint};
use sfm_screen::solvers::ProxSolver;
use sfm_screen::submodular::cut::CutFn;
use sfm_screen::submodular::iwata::IwataFn;
use sfm_screen::submodular::Submodular;

fn seeded_cut(p: usize, seed: u64) -> CutFn {
    let mut rng = Pcg64::seeded(seed);
    let mut edges = Vec::new();
    for i in 0..p {
        for j in (i + 1)..p {
            if rng.bernoulli(0.3) {
                edges.push((i, j, rng.uniform(0.0, 1.5)));
            }
        }
    }
    CutFn::from_edges(p, &edges, rng.uniform_vec(p, -1.5, 1.5))
}

/// Step `a` and `b` in lockstep on `f`; every event and iterate must be
/// bit-identical at every iteration.
fn assert_lockstep(
    a: &mut dyn ProxSolver,
    b: &mut dyn ProxSolver,
    f: &dyn Submodular,
    iters: usize,
    label: &str,
) {
    for t in 0..iters {
        let ea = a.step(f);
        let eb = b.step(f);
        assert_eq!(
            ea.gap.to_bits(),
            eb.gap.to_bits(),
            "{label}: gap diverged at iter {t}: {} vs {}",
            ea.gap,
            eb.gap
        );
        assert_eq!(
            ea.wolfe_gap.to_bits(),
            eb.wolfe_gap.to_bits(),
            "{label}: wolfe gap diverged at iter {t}"
        );
        assert_eq!(ea.fc.to_bits(), eb.fc.to_bits(), "{label}: fc diverged at {t}");
        for (j, (x, y)) in a.s().iter().zip(b.s()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{label}: dual iterate diverged at iter {t}, coord {j}"
            );
        }
        for (j, (x, y)) in a.w().iter().zip(b.w()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{label}: primal iterate diverged at iter {t}, coord {j}"
            );
        }
        if ea.gap < 1e-12 {
            break;
        }
    }
}

/// Fresh solver vs. warm-reset solver (dirty workspaces from a different
/// problem size): identical trajectories, correct minimizer.
fn check_minnorm_on(f: &dyn Submodular, label: &str) {
    let p = f.ground_size();
    let mut fresh = MinNormPoint::new(f, MinNormOptions::default(), None);
    // Dirty the second solver on an unrelated problem, then warm-reset.
    let other = IwataFn::new(9);
    let mut warm = MinNormPoint::new(&other, MinNormOptions::default(), None);
    for _ in 0..30 {
        warm.step(&other);
    }
    warm.reset(f, &vec![0.0; p]);
    assert_lockstep(&mut fresh, &mut warm, f, 600, label);
    // Final minimizer against brute force.
    let brute = brute_force_sfm(f, 1e-9);
    let a_min = sup_level_set(fresh.w(), 0.0);
    assert_eq!(a_min, brute.minimal, "{label}: minimizer mismatch");
}

#[test]
fn minnorm_trajectory_deterministic_on_iwata() {
    check_minnorm_on(&IwataFn::new(14), "min-norm/iwata");
}

#[test]
fn minnorm_trajectory_deterministic_on_seeded_cut() {
    let f = seeded_cut(14, 2024);
    let p = f.ground_size();
    let mut fresh = MinNormPoint::new(&f, MinNormOptions::default(), None);
    let other = seeded_cut(7, 11);
    let mut warm = MinNormPoint::new(&other, MinNormOptions::default(), None);
    for _ in 0..20 {
        warm.step(&other);
    }
    warm.reset(&f, &vec![0.0; p]);
    assert_lockstep(&mut fresh, &mut warm, &f, 600, "min-norm/cut");
    let brute = brute_force_sfm(&f, 1e-7);
    let mut set = vec![false; p];
    for &i in &sup_level_set(fresh.w(), 0.0) {
        set[i] = true;
    }
    assert!(
        (f.eval(&set) - brute.minimum).abs() < 1e-6,
        "min-norm/cut: recovered set is not a minimizer"
    );
}

#[test]
fn frankwolfe_trajectory_deterministic_after_reset() {
    let f = seeded_cut(12, 77);
    let p = f.ground_size();
    let mut fresh = FrankWolfe::new(&f, FwOptions::default(), None);
    let other = IwataFn::new(8);
    let mut warm = FrankWolfe::new(&other, FwOptions::default(), None);
    for _ in 0..50 {
        warm.step(&other);
    }
    warm.reset(&f, &vec![0.0; p]);
    assert_lockstep(&mut fresh, &mut warm, &f, 2000, "pairwise-fw/cut");
}

#[test]
fn repeated_runs_are_bitwise_identical() {
    // Same problem, two fresh solvers: byte-for-byte identical event
    // streams (no hidden global state, no allocation-address dependence).
    let f = IwataFn::new(16);
    let mut a = MinNormPoint::new(&f, MinNormOptions::default(), None);
    let mut b = MinNormPoint::new(&f, MinNormOptions::default(), None);
    assert_lockstep(&mut a, &mut b, &f, 400, "min-norm/repeat");
}
