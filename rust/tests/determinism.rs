//! Trajectory determinism for the zero-allocation solver engine.
//!
//! The flat corral (`CorralMat`), the packed Gram factor, the adaptive
//! re-sort, and the oracle scratch are all *exact* accelerations: they
//! must not change a single bit of the iterate trajectory. These tests
//! pin that down by running solvers in lockstep — a fresh instance vs. a
//! warm-reset instance whose buffers are dirty from a different problem —
//! and by checking the final minimizer against brute force.

use sfm_screen::brute::brute_force_sfm;
use sfm_screen::lovasz::{sup_level_set, ContractionMap};
use sfm_screen::obs::TraceSink;
use sfm_screen::rng::Pcg64;
use sfm_screen::screening::iaes::{solve_sfm_with_screening, IaesOptions, IaesReport};
use sfm_screen::solvers::frankwolfe::{FrankWolfe, FwOptions};
use sfm_screen::solvers::minnorm::{MinNormOptions, MinNormPoint};
use sfm_screen::solvers::ProxSolver;
use sfm_screen::submodular::cut::CutFn;
use sfm_screen::submodular::iwata::IwataFn;
use sfm_screen::submodular::kernel_cut::KernelCutFn;
use sfm_screen::submodular::scaled::ScaledFn;
use sfm_screen::submodular::Submodular;

fn seeded_cut(p: usize, seed: u64) -> CutFn {
    let mut rng = Pcg64::seeded(seed);
    let mut edges = Vec::new();
    for i in 0..p {
        for j in (i + 1)..p {
            if rng.bernoulli(0.3) {
                edges.push((i, j, rng.uniform(0.0, 1.5)));
            }
        }
    }
    CutFn::from_edges(p, &edges, rng.uniform_vec(p, -1.5, 1.5))
}

/// Step `a` and `b` in lockstep on `f`; every event and iterate must be
/// bit-identical at every iteration.
fn assert_lockstep(
    a: &mut dyn ProxSolver,
    b: &mut dyn ProxSolver,
    f: &dyn Submodular,
    iters: usize,
    label: &str,
) {
    for t in 0..iters {
        let ea = a.step(f);
        let eb = b.step(f);
        assert_eq!(
            ea.gap.to_bits(),
            eb.gap.to_bits(),
            "{label}: gap diverged at iter {t}: {} vs {}",
            ea.gap,
            eb.gap
        );
        assert_eq!(
            ea.wolfe_gap.to_bits(),
            eb.wolfe_gap.to_bits(),
            "{label}: wolfe gap diverged at iter {t}"
        );
        assert_eq!(ea.fc.to_bits(), eb.fc.to_bits(), "{label}: fc diverged at {t}");
        for (j, (x, y)) in a.s().iter().zip(b.s()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{label}: dual iterate diverged at iter {t}, coord {j}"
            );
        }
        for (j, (x, y)) in a.w().iter().zip(b.w()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{label}: primal iterate diverged at iter {t}, coord {j}"
            );
        }
        if ea.gap < 1e-12 {
            break;
        }
    }
}

/// Fresh solver vs. warm-reset solver (dirty workspaces from a different
/// problem size): identical trajectories, correct minimizer.
fn check_minnorm_on(f: &dyn Submodular, label: &str) {
    let p = f.ground_size();
    let mut fresh = MinNormPoint::new(f, MinNormOptions::default(), None);
    // Dirty the second solver on an unrelated problem, then warm-reset.
    let other = IwataFn::new(9);
    let mut warm = MinNormPoint::new(&other, MinNormOptions::default(), None);
    for _ in 0..30 {
        warm.step(&other);
    }
    warm.reset(f, &vec![0.0; p]);
    assert_lockstep(&mut fresh, &mut warm, f, 600, label);
    // Final minimizer against brute force.
    let brute = brute_force_sfm(f, 1e-9);
    let a_min = sup_level_set(fresh.w(), 0.0);
    assert_eq!(a_min, brute.minimal, "{label}: minimizer mismatch");
}

#[test]
fn minnorm_trajectory_deterministic_on_iwata() {
    check_minnorm_on(&IwataFn::new(14), "min-norm/iwata");
}

#[test]
fn minnorm_trajectory_deterministic_on_seeded_cut() {
    let f = seeded_cut(14, 2024);
    let p = f.ground_size();
    let mut fresh = MinNormPoint::new(&f, MinNormOptions::default(), None);
    let other = seeded_cut(7, 11);
    let mut warm = MinNormPoint::new(&other, MinNormOptions::default(), None);
    for _ in 0..20 {
        warm.step(&other);
    }
    warm.reset(&f, &vec![0.0; p]);
    assert_lockstep(&mut fresh, &mut warm, &f, 600, "min-norm/cut");
    let brute = brute_force_sfm(&f, 1e-7);
    let mut set = vec![false; p];
    for &i in &sup_level_set(fresh.w(), 0.0) {
        set[i] = true;
    }
    assert!(
        (f.eval(&set) - brute.minimum).abs() < 1e-6,
        "min-norm/cut: recovered set is not a minimizer"
    );
}

#[test]
fn frankwolfe_trajectory_deterministic_after_reset() {
    let f = seeded_cut(12, 77);
    let p = f.ground_size();
    let mut fresh = FrankWolfe::new(&f, FwOptions::default(), None);
    let other = IwataFn::new(8);
    let mut warm = FrankWolfe::new(&other, FwOptions::default(), None);
    for _ in 0..50 {
        warm.step(&other);
    }
    warm.reset(&f, &vec![0.0; p]);
    assert_lockstep(&mut fresh, &mut warm, &f, 2000, "pairwise-fw/cut");
}

/// Kernel cut with moderate coupling and strong unaries: separable
/// enough that screening certifies elements (so IAES actually contracts),
/// coupled enough that several triggers fire before convergence.
fn seeded_kernel_cut(p: usize, seed: u64) -> KernelCutFn {
    let mut rng = Pcg64::seeded(seed);
    let mut k = vec![0.0; p * p];
    for i in 0..p {
        for j in (i + 1)..p {
            let w = rng.uniform(0.0, 0.3);
            k[i * p + j] = w;
            k[j * p + i] = w;
        }
    }
    KernelCutFn::new(p, k, rng.uniform_vec(p, -3.0, 3.0))
}

fn iaes_with_remap(f: &dyn Submodular, argsort_remap: bool) -> IaesReport {
    let opts = IaesOptions {
        eps: 1e-10,
        min_reduction_frac: 0.0, // contract on every certificate
        argsort_remap,
        ..Default::default()
    };
    solve_sfm_with_screening(f, &opts).unwrap()
}

/// The warm-restart remap is an *exact* acceleration: running the full
/// IAES engine with the argsort-remap fast path force-enabled vs.
/// force-disabled (full re-sort at every contraction) must produce
/// bitwise-equal trajectories — every gap, every trigger, the minimizer.
#[test]
fn iaes_remap_fast_path_is_bitwise_equal_to_full_resort() {
    for seed in [2024u64, 555] {
        let f = seeded_kernel_cut(16, seed);
        let a = iaes_with_remap(&f, true);
        let b = iaes_with_remap(&f, false);
        // The instances must actually exercise the warm-restart path.
        assert!(
            a.history.iter().any(|h| h.p_remaining < 16),
            "seed {seed}: no contraction happened — test instance too easy"
        );
        assert_eq!(a.iters, b.iters, "seed {seed}: iteration counts differ");
        assert_eq!(a.history.len(), b.history.len(), "seed {seed}");
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(
                x.gap.to_bits(),
                y.gap.to_bits(),
                "seed {seed}: gap diverged at iter {}",
                x.iter
            );
            assert_eq!(x.p_remaining, y.p_remaining, "seed {seed}");
            assert_eq!(x.active, y.active, "seed {seed}");
            assert_eq!(x.inactive, y.inactive, "seed {seed}");
        }
        assert_eq!(a.triggers.len(), b.triggers.len(), "seed {seed}");
        for (x, y) in a.triggers.iter().zip(&b.triggers) {
            assert_eq!(x.iter, y.iter, "seed {seed}");
            assert_eq!(x.gap.to_bits(), y.gap.to_bits(), "seed {seed}");
            assert_eq!(x.new_active_ids, y.new_active_ids, "seed {seed}");
            assert_eq!(x.new_inactive_ids, y.new_inactive_ids, "seed {seed}");
        }
        assert_eq!(a.minimizer, b.minimizer, "seed {seed}");
        assert_eq!(a.minimum.to_bits(), b.minimum.to_bits(), "seed {seed}");
        assert_eq!(a.final_gap.to_bits(), b.final_gap.to_bits(), "seed {seed}");
    }
}

/// Solver-level lockstep across one contraction: two identically-warmed
/// min-norm solvers, one restarted with the remap fast path and one with
/// the forced full re-sort, must stay bit-identical forever after — and
/// the fast-path solver must not have paid a full sort for the restart.
#[test]
fn reset_mapped_remap_toggle_is_bitwise_unobservable() {
    let f = seeded_kernel_cut(18, 99);
    let kept: Vec<usize> = (0..18).collect();
    let mut scaled_a = ScaledFn::new(&f, &[], kept.clone());
    let mut scaled_b = ScaledFn::new(&f, &[], kept.clone());
    let mut a = MinNormPoint::new(&scaled_a, MinNormOptions::default(), None);
    let mut b = MinNormPoint::new(&scaled_b, MinNormOptions::default(), None);
    for _ in 0..15 {
        a.step(&scaled_a);
        b.step(&scaled_b);
    }
    // Contract both: remove four elements (2 certified active; 5, 11 and
    // 14 inactive).
    let new_kept: Vec<usize> =
        kept.iter().copied().filter(|&i| ![2, 5, 11, 14].contains(&i)).collect();
    let w_surv: Vec<f64> = new_kept.iter().map(|&i| a.w()[i]).collect();
    let mut map_a = ContractionMap::new();
    scaled_a.contract(&[2], &new_kept, &mut map_a);
    let mut map_b = ContractionMap::new();
    scaled_b.contract(&[2], &new_kept, &mut map_b);
    map_b.remap_argsort = false;
    let sorts_before = a.greedy_full_sorts();
    a.reset_mapped(&scaled_a, &w_surv, &map_a);
    b.reset_mapped(&scaled_b, &w_surv, &map_b);
    assert_eq!(
        a.greedy_full_sorts(),
        sorts_before,
        "remap-enabled restart must not full-sort"
    );
    assert!(
        b.greedy_full_sorts() > sorts_before,
        "remap-disabled restart must cold-sort"
    );
    assert_eq!(a.gap().to_bits(), b.gap().to_bits(), "restart gap diverged");
    assert_lockstep(&mut a, &mut b, &scaled_a, 400, "min-norm/remap-toggle");
}

#[test]
fn repeated_runs_are_bitwise_identical() {
    // Same problem, two fresh solvers: byte-for-byte identical event
    // streams (no hidden global state, no allocation-address dependence).
    let f = IwataFn::new(16);
    let mut a = MinNormPoint::new(&f, MinNormOptions::default(), None);
    let mut b = MinNormPoint::new(&f, MinNormOptions::default(), None);
    assert_lockstep(&mut a, &mut b, &f, 400, "min-norm/repeat");
}

// ---- Pooled monolithic greedy oracle (SIMD + worker-pool passes) ----

mod common;

use sfm_screen::lovasz::{greedy_base_vertex, GreedyWorkspace};
use sfm_screen::runtime::pool::WorkerPool;
use std::sync::Arc;

/// Thread counts for the pooled-oracle determinism matrix: the pinned
/// t ∈ {2, 4} legs plus `SFM_BENCH_THREADS` (CI's pooled monolithic leg
/// sets an unpinned count — 3 — so the env leg always adds coverage).
fn pool_thread_matrix() -> Vec<usize> {
    let mut counts = vec![2usize, 4];
    if let Some(t) = common::env_pool_threads() {
        if !counts.contains(&t) {
            counts.push(t);
        }
    }
    counts
}

/// A `t`-thread pooled workspace under the monolithic convention:
/// `t − 1` parked workers plus the calling thread.
fn pooled_workspace(p: usize, t: usize) -> GreedyWorkspace {
    let mut ws = GreedyWorkspace::new(p);
    ws.set_pool(Some(Arc::new(WorkerPool::new(t - 1))));
    ws
}

/// Run a drifting-direction greedy sequence on `f` with a sequential
/// workspace and one pooled workspace per thread count; every pass must
/// agree bit for bit — order, gains, vertex, and summary.
fn assert_greedy_thread_matrix(f: &dyn Submodular, label: &str) {
    let p = f.ground_size();
    let counts = pool_thread_matrix();
    let mut seq_ws = GreedyWorkspace::new(p);
    let mut pooled: Vec<GreedyWorkspace> =
        counts.iter().map(|&t| pooled_workspace(p, t)).collect();
    let mut rng = Pcg64::seeded(0xBEEF);
    let mut w = rng.normal_vec(p);
    let mut s_seq = vec![0.0; p];
    let mut s_pool = vec![0.0; p];
    for step in 0..6 {
        let info_seq = greedy_base_vertex(f, &w, &mut seq_ws, &mut s_seq);
        for (ws, &t) in pooled.iter_mut().zip(&counts) {
            s_pool.iter_mut().for_each(|x| *x = f64::NAN);
            let info = greedy_base_vertex(f, &w, ws, &mut s_pool);
            assert_eq!(ws.order, seq_ws.order, "{label}: order differs (t={t}, step {step})");
            for j in 0..p {
                assert_eq!(
                    s_pool[j].to_bits(),
                    s_seq[j].to_bits(),
                    "{label}: vertex differs at {j} (t={t}, step {step})"
                );
            }
            for (a, b) in ws.gains.iter().zip(&seq_ws.gains) {
                assert_eq!(a.to_bits(), b.to_bits(), "{label}: gains differ (t={t})");
            }
            assert_eq!(info.lovasz.to_bits(), info_seq.lovasz.to_bits(), "{label} (t={t})");
            assert_eq!(info.best_level_value.to_bits(), info_seq.best_level_value.to_bits());
            assert_eq!(info.best_level_k, info_seq.best_level_k);
        }
        // Drift, with a jump on the last step (cold re-sort path).
        if step == 4 {
            w = rng.normal_vec(p);
        } else {
            for x in w.iter_mut() {
                *x += 0.02 * rng.normal();
            }
        }
    }
}

/// The pooled kernel-cut superblock path (p above the pool gate) is
/// bitwise identical for every thread count.
#[test]
fn pooled_kernel_cut_pass_is_bitwise_thread_count_identical() {
    let f = seeded_kernel_cut(192, 31_337);
    assert_greedy_thread_matrix(&f, "pooled-greedy/kernel-cut");
}

/// The pooled sparse-cut adjacency walk: a hub of degree ≥ 4096 forces
/// the fixed-order chunk reduction onto the pool — same bits always.
#[test]
fn pooled_hub_cut_pass_is_bitwise_thread_count_identical() {
    let p = 4450;
    let mut rng = Pcg64::seeded(606);
    let mut edges: Vec<(usize, usize, f64)> = Vec::with_capacity(2 * p);
    for j in 1..p {
        edges.push((0, j, rng.uniform(0.0, 1.0)));
        // A sparse second layer so leaves have degree > 1 too.
        if j + 7 < p {
            edges.push((j, j + 7, rng.uniform(0.0, 0.5)));
        }
    }
    let f = CutFn::from_edges(p, &edges, rng.uniform_vec(p, -1.0, 1.0));
    assert_greedy_thread_matrix(&f, "pooled-greedy/hub-cut");
}

/// End-to-end acceptance: full IAES monolithic solves at t ∈ {1, 2, 4}
/// (plus the CI matrix extension) produce bitwise-equal reports —
/// every gap, every trigger, the minimizer. The pooled oracle is an
/// exact acceleration, so `--threads` can never change an answer.
#[test]
fn iaes_monolithic_solve_is_bitwise_identical_across_thread_counts() {
    let f = seeded_kernel_cut(150, 2025);
    let run = |threads: usize| {
        let opts = IaesOptions {
            eps: 1e-9,
            min_reduction_frac: 0.0, // contract on every certificate
            threads,
            ..Default::default()
        };
        solve_sfm_with_screening(&f, &opts).unwrap()
    };
    let base = run(1);
    assert_eq!(base.greedy_threads, None);
    assert!(
        base.emptied || base.history.iter().any(|h| h.p_remaining < 150),
        "no contraction happened — instance too easy to exercise restarts"
    );
    for t in pool_thread_matrix() {
        let r = run(t);
        assert_eq!(r.greedy_threads, Some(t), "t={t}: resolved count missing");
        assert_eq!(r.iters, base.iters, "t={t}");
        assert_eq!(r.history.len(), base.history.len(), "t={t}");
        for (x, y) in r.history.iter().zip(&base.history) {
            assert_eq!(x.gap.to_bits(), y.gap.to_bits(), "t={t}, iter {}", x.iter);
            assert_eq!(x.p_remaining, y.p_remaining, "t={t}");
        }
        assert_eq!(r.triggers.len(), base.triggers.len(), "t={t}");
        for (x, y) in r.triggers.iter().zip(&base.triggers) {
            assert_eq!(x.iter, y.iter, "t={t}");
            assert_eq!(x.gap.to_bits(), y.gap.to_bits(), "t={t}");
            assert_eq!(x.new_active_ids, y.new_active_ids, "t={t}");
            assert_eq!(x.new_inactive_ids, y.new_inactive_ids, "t={t}");
        }
        assert_eq!(r.minimizer, base.minimizer, "t={t}");
        assert_eq!(r.minimum.to_bits(), base.minimum.to_bits(), "t={t}");
        assert_eq!(r.final_gap.to_bits(), base.final_gap.to_bits(), "t={t}");
    }
}

/// Tracing is observation only: a traced monolithic solve must match
/// the untraced one bit for bit at every thread count — same history,
/// same triggers, same minimizer — and the recorded events must mirror
/// the per-iteration history exactly (clock fields aside).
#[test]
fn iaes_traced_solve_is_bitwise_identical_to_untraced_across_threads() {
    let f = seeded_kernel_cut(150, 2025);
    let run = |threads: usize, trace: Option<TraceSink>| {
        let opts = IaesOptions {
            eps: 1e-9,
            min_reduction_frac: 0.0, // contract on every certificate
            threads,
            trace,
            ..Default::default()
        };
        solve_sfm_with_screening(&f, &opts).unwrap()
    };
    for t in [1usize, 2, 4] {
        let plain = run(t, None);
        assert!(plain.trace.is_none(), "t={t}: untraced run carries no summary");
        let sink = TraceSink::new();
        let traced = run(t, Some(sink.clone()));
        assert_eq!(traced.iters, plain.iters, "t={t}");
        assert_eq!(traced.history.len(), plain.history.len(), "t={t}");
        for (x, y) in traced.history.iter().zip(&plain.history) {
            assert_eq!(x.gap.to_bits(), y.gap.to_bits(), "t={t}, iter {}", x.iter);
            assert_eq!(x.p_remaining, y.p_remaining, "t={t}");
            assert_eq!(x.active, y.active, "t={t}");
            assert_eq!(x.inactive, y.inactive, "t={t}");
        }
        assert_eq!(traced.triggers.len(), plain.triggers.len(), "t={t}");
        for (x, y) in traced.triggers.iter().zip(&plain.triggers) {
            assert_eq!(x.iter, y.iter, "t={t}");
            assert_eq!(x.gap.to_bits(), y.gap.to_bits(), "t={t}");
            assert_eq!(x.new_active_ids, y.new_active_ids, "t={t}");
            assert_eq!(x.new_inactive_ids, y.new_inactive_ids, "t={t}");
        }
        assert_eq!(traced.minimizer, plain.minimizer, "t={t}");
        assert_eq!(traced.minimum.to_bits(), plain.minimum.to_bits(), "t={t}");
        assert_eq!(traced.final_gap.to_bits(), plain.final_gap.to_bits(), "t={t}");
        // The trace saw exactly the iterations the history recorded, with
        // the same gaps — boundary sampling, nothing interpolated.
        let events = sink.snapshot();
        assert_eq!(events.len(), plain.history.len(), "t={t}");
        for (e, h) in events.iter().zip(&plain.history) {
            assert_eq!(e.iter as usize, h.iter, "t={t}");
            assert_eq!(e.gap.to_bits(), h.gap.to_bits(), "t={t}");
        }
        let s = traced.trace.expect("traced run must return a summary");
        assert_eq!(s.events, traced.iters as u64, "t={t}");
        assert_eq!(s.screens, traced.triggers.len() as u64, "t={t}");
        if t == 1 {
            assert_eq!(s.pool_dispatches, 0, "t=1 runs without a pool");
        } else {
            assert!(s.pool_dispatches > 0, "t={t}: pooled passes must be counted");
        }
    }
}
