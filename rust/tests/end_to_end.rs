//! End-to-end integration: the full pipeline on both paper workloads,
//! lossless-ness of every screening variant, and coordinator plumbing.

use sfm_screen::coordinator::experiments::{rejection_curve, run_variant, BenchConfig};
use sfm_screen::coordinator::jobs::{BackendChoice, WorkloadSpec};
use sfm_screen::screening::iaes::{solve_sfm_with_screening, IaesOptions};
use sfm_screen::screening::RuleSet;
use sfm_screen::workloads::images::{benchmark_suite, ImageInstance, ImageParams};
use sfm_screen::workloads::two_moons::{TwoMoons, TwoMoonsParams};

#[allow(clippy::field_reassign_with_default)]
fn cfg() -> BenchConfig {
    let mut c = BenchConfig::default();
    c.sizes = vec![50];
    c.eps = 1e-6;
    c.quiet = true;
    c.backend = BackendChoice::Rust;
    c.out_dir = std::env::temp_dir().join("sfm_e2e_out");
    c
}

#[test]
fn two_moons_variants_agree_and_screening_accelerates_iterations() {
    let c = cfg();
    let wl = WorkloadSpec::TwoMoons { p: 80, use_mi: false, seed: 2018 };
    let base = run_variant(&wl, RuleSet::none(), &c).unwrap();
    let iaes = run_variant(&wl, RuleSet::all(), &c).unwrap();
    assert!(
        (base.report.minimum - iaes.report.minimum).abs() < 1e-5,
        "screening changed the optimum"
    );
    // The reduced problems must shrink.
    assert!(iaes.report.screened_active + iaes.report.screened_inactive > 0);
}

#[test]
fn image_segmentation_pipeline() {
    let img = ImageInstance::generate(
        "e2e",
        ImageParams {
            h: 24,
            w: 20,
            fg_a: 0.3,
            fg_b: 0.25,
            fg_mean: 0.75,
            bg_mean: 0.3,
            noise: 0.05,
            texture: 0.06,
            beta: 0.35,
            seed: 77,
        },
    );
    let f = img.cut_fn();
    let base = solve_sfm_with_screening(
        &f,
        &IaesOptions { rules: RuleSet::none(), ..Default::default() },
    )
    .unwrap();
    let iaes = solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
    assert!((base.minimum - iaes.minimum).abs() < 1e-5);
    assert!(img.iou(&iaes.minimizer) > 0.5, "segmentation degraded");
    // The paper's observation: foreground (active side) is small.
    assert!(
        iaes.screened_inactive > iaes.screened_active,
        "IES should dominate on segmentation"
    );
}

#[test]
fn rejection_curves_hit_one_when_emptied() {
    let c = cfg();
    let wl = WorkloadSpec::TwoMoons { p: 60, use_mi: false, seed: 5 };
    let mut tight = c.clone();
    tight.eps = 1e-12;
    let run = run_variant(&wl, RuleSet::all(), &tight).unwrap();
    let curve = rejection_curve(&run.report, 60);
    let last = curve.last().unwrap().1;
    if run.report.emptied {
        assert!((last - 1.0).abs() < 1e-12);
    } else {
        assert!(last <= 1.0);
    }
}

#[test]
fn gaussian_mi_objective_end_to_end() {
    // The paper-exact objective on a small instance: lossless + aligned
    // with the kernel-cut substitute's clustering.
    let tm = TwoMoons::generate(TwoMoonsParams { p: 24, seed: 9, ..Default::default() });
    let f = tm.gaussian_mi(0.1);
    let base = solve_sfm_with_screening(
        &f,
        &IaesOptions { rules: RuleSet::none(), ..Default::default() },
    )
    .unwrap();
    let iaes = solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
    assert!(
        (base.minimum - iaes.minimum).abs() < 1e-5,
        "{} vs {}",
        base.minimum,
        iaes.minimum
    );
    let acc = tm.clustering_accuracy(&iaes.minimizer);
    let acc = acc.max(1.0 - acc);
    assert!(acc > 0.7, "MI clustering accuracy {acc}");
}

#[test]
fn benchmark_suite_solvable_at_tiny_scale() {
    let suite = benchmark_suite(0.35);
    for img in suite.iter().take(2) {
        let f = img.cut_fn();
        let rep = solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
        assert!(rep.final_gap < 1e-6 || rep.emptied, "{} did not converge", img.name);
    }
}

#[test]
fn speedup_in_iterations_on_moderate_instance() {
    // Wall-clock is noisy in CI; iteration-weighted work is the robust
    // proxy: Σ_iters p̂ per iteration must shrink with screening.
    let c = cfg();
    let wl = WorkloadSpec::TwoMoons { p: 120, use_mi: false, seed: 31 };
    let base = run_variant(&wl, RuleSet::none(), &c).unwrap();
    let iaes = run_variant(&wl, RuleSet::all(), &c).unwrap();
    let work = |r: &sfm_screen::screening::iaes::IaesReport| -> f64 {
        r.history.iter().map(|h| (h.p_remaining * h.p_remaining) as f64).sum()
    };
    let w_base = work(&base.report);
    let w_iaes = work(&iaes.report);
    assert!(
        w_iaes < w_base,
        "screening did not reduce solver work: {w_iaes} vs {w_base}"
    );
}
