//! Offline shim implementing the subset of the `anyhow` API this workspace
//! uses: [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The build environment has no crates.io access, so the real crate cannot
//! be fetched; this shim is API-compatible for every call site in the tree
//! (error construction from format strings, `?` on any
//! `std::error::Error + Send + Sync + 'static`, context chaining, `{:#}`
//! display). Swap the `[dependencies]` path entry for the real crate when
//! building online — no source changes needed.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error: a message plus an optional boxed source.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `Result<T, anyhow::Error>` with the same default-parameter shape as the
/// real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from anything displayable (the `anyhow!` macro calls this).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Construct from a concrete error value, preserving it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// Downcast to a concrete error type by reference, like the real
    /// crate. Context wrapping preserves the source, so a typed error
    /// stays downcastable through `.context(...)` chains — the serve
    /// layer uses this to classify `NumericFault` job failures.
    pub fn downcast_ref<E: StdError + Send + Sync + 'static>(&self) -> Option<&E> {
        self.source.as_deref().and_then(|s| s.downcast_ref::<E>())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error` — that
// is what allows the blanket `From` below to coexist with the reflexive
// `From<Error> for Error` (same design as the real crate).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(src) = &self.source {
            write!(f, "\n\nCaused by:\n    {src}")?;
        }
        Ok(())
    }
}

/// Extension trait adding `.context()` / `.with_context()` to `Result` and
/// `Option`, mirroring `anyhow::Context`.
pub trait Context<T, E>: Sized {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let n: i32 = s.parse().context("not an integer")?;
        ensure!(n >= 0, "negative: {n}");
        Ok(n)
    }

    #[test]
    fn question_mark_and_context() {
        assert_eq!(parse("4").unwrap(), 4);
        let e = parse("x").unwrap_err();
        assert!(e.to_string().starts_with("not an integer"));
        let e = parse("-2").unwrap_err();
        assert_eq!(e.to_string(), "negative: -2");
    }

    #[test]
    fn macros_and_display() {
        let e: Error = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
        assert_eq!(format!("{e:#}"), "code 7");
        let io = std::io::Error::new(std::io::ErrorKind::Other, "io boom");
        let dbg = format!("{:?}", Error::new(io));
        assert!(dbg.contains("io boom"));
    }

    #[test]
    fn downcast_ref_survives_context() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "io boom");
        let e: Result<()> = Err(Error::new(io));
        let e = e.context("outer").unwrap_err();
        let back = e.downcast_ref::<std::io::Error>().expect("downcast");
        assert_eq!(back.to_string(), "io boom");
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        // Message-only errors have no source to downcast.
        assert!(anyhow!("plain").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn option_context() {
        let none: Option<i32> = None;
        let e = none.with_context(|| "missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }
}
