//! Offline stub of the `xla-rs` PJRT surface consumed by
//! `sfm_screen::runtime`.
//!
//! The real crate links libxla/PJRT, which cannot be built in the offline
//! environment. This stub type-checks the runtime module unchanged and
//! reports the backend as unavailable: [`PjRtClient::cpu`] always errors,
//! so `Engine::new` fails, `XlaScreener`/`AffinityExec` construction fails,
//! and every caller takes its documented pure-rust fallback
//! (`best_screener()` → `RustScreener`, affinity → direct loop).
//!
//! Swap the `[dependencies]` path entry for the real `xla` crate to enable
//! the compiled-kernel path — no changes in `sfm_screen` are needed.

use std::borrow::Borrow;

/// Stub error carrying a human-readable reason.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

const STUB_MSG: &str =
    "xla backend not compiled in: offline stub; vendor the real xla-rs crate to enable PJRT";

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(STUB_MSG.to_string()))
}

/// Host literal (stub: never materialized — construction is only reachable
/// after a successful client, which the stub never produces).
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    /// Rank-1 f64 literal.
    pub fn vec1(_values: &[f64]) -> Literal {
        Literal
    }

    /// Scalar f64 literal.
    pub fn scalar(_value: f64) -> Literal {
        Literal
    }

    /// Flatten a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// XLA computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer to the host.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with host-literal arguments; per-device output buffers.
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client — unavailable in the offline stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    /// Compile a computation.
    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.0.contains("offline stub"));
        assert!(Literal::vec1(&[1.0]).to_vec::<f64>().is_err());
    }
}
