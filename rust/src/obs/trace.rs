//! Boundary-sampled solve traces: a preallocated ring of fixed-size
//! [`TraceEvent`]s recorded **only at major-iteration boundaries**.
//!
//! The sampling discipline mirrors `runtime::cancel`: the IAES engine
//! consults the sink exactly where the dual iterate is valid in B(F̂) —
//! after a completed prox step, after a screening pass, after a
//! contraction — never inside a solver inner loop or an oracle pass.
//! Consequences:
//!
//! * an unattached sink (`IaesOptions::trace = None`) is **bitwise
//!   inert**: the engine takes the same branches, performs the same
//!   arithmetic, and allocates nothing extra (pinned by
//!   `tests/determinism.rs`);
//! * an attached sink adds one clock read per phase span plus one
//!   mutex round-trip per major iteration — amortized to noise against
//!   an O(p log p) greedy pass (the `obs/trace-overhead` micro row
//!   budgets this at ≤ 2%);
//! * recording is allocation-free at steady state: the ring is
//!   pre-sized at attach time and overwrites its oldest slot when full
//!   (certified by `tests/zero_alloc.rs`).
//!
//! Events serialize through [`coordinator::json`](crate::coordinator::json)
//! as one JSON object per line (`solve --trace PATH`), and
//! [`TraceEvent::from_json`] parses that schema back — the CI trace
//! smoke leg round-trips every emitted line through it. Summaries are
//! exact even when the ring wraps: totals accumulate on push, not from
//! surviving slots.

use crate::coordinator::json::Json;
use std::sync::{Arc, Mutex, MutexGuard};

/// Default ring capacity when a sink is attached without an explicit
/// size (`TraceSink::new`, `solve --trace` without `--trace-cap`).
pub const DEFAULT_TRACE_CAPACITY: usize = 4096;

/// Slot of the decompose block solver's `Modular` components in
/// [`TraceEvent::kind_ns`] / [`TraceSummary::kind_ns`].
pub const KIND_MODULAR: usize = 0;
/// Slot of `Cardinality` components.
pub const KIND_CARDINALITY: usize = 1;
/// Slot of `Chain` components.
pub const KIND_CHAIN: usize = 2;
/// Slot of `Generic` (per-block min-norm) components.
pub const KIND_GENERIC: usize = 3;
/// JSON key of each `kind_ns` slot, indexed by the `KIND_*` constants.
pub const KIND_NAMES: [&str; 4] = ["modular", "cardinality", "chain", "generic"];

/// Bit flags marking what happened at a recorded boundary. An event
/// with `flags == 0` is a plain major iteration (step + gap check, no
/// screening trigger).
pub mod flags {
    /// A screening pass ran at this boundary (`screen_ns`,
    /// `new_active`, `new_inactive` are meaningful).
    pub const SCREEN: u32 = 1;
    /// The certificate cleared the contraction threshold and the ground
    /// set was rebuilt (`contract_ns` covers the rebuild + restart).
    pub const CONTRACTION: u32 = 1 << 1;
    /// The post-contraction restart projected the corral through the
    /// survivor map (warm restart).
    pub const WARM_RESTART: u32 = 1 << 2;
    /// The post-contraction restart discarded the corral (cold restart).
    pub const COLD_RESTART: u32 = 1 << 3;
    /// The run stopped at this boundary on a cooperative cancellation.
    pub const CANCELLED: u32 = 1 << 4;
    /// The cancellation was a deadline expiry (set alongside
    /// `CANCELLED`).
    pub const DEADLINE: u32 = 1 << 5;
    /// The contraction emptied the ground set (set alongside
    /// `CONTRACTION`).
    pub const EMPTIED: u32 = 1 << 6;
    /// The last event of the run (converged, iteration cap, cancelled,
    /// or emptied).
    pub const FINAL: u32 = 1 << 7;
    /// The run was resumed from a boundary checkpoint (set on every
    /// event of the resumed run, so spliced traces are attributable).
    pub const RESUMED: u32 = 1 << 8;
}

/// `(bit, tag)` pairs for JSON serialization of [`TraceEvent::flags`].
const FLAG_TAGS: [(u32, &str); 9] = [
    (flags::SCREEN, "screen"),
    (flags::CONTRACTION, "contraction"),
    (flags::WARM_RESTART, "warm-restart"),
    (flags::COLD_RESTART, "cold-restart"),
    (flags::CANCELLED, "cancelled"),
    (flags::DEADLINE, "deadline"),
    (flags::EMPTIED, "emptied"),
    (flags::FINAL, "final"),
    (flags::RESUMED, "resumed"),
];

/// One major-iteration boundary, fixed-size (`Copy`, no heap) so ring
/// slots can be overwritten in place without allocating.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TraceEvent {
    /// Global major-iteration index (1-based, monotone across restarts).
    pub iter: u64,
    /// Boundary markers (see [`flags`]).
    pub flags: u32,
    /// Primal objective at the boundary (best Lovász level value).
    pub primal: f64,
    /// Dual objective at the boundary.
    pub dual: f64,
    /// Duality gap used by the screening gate.
    pub gap: f64,
    /// Screening-ball radius `r = sqrt(2·gap)` (Theorem 7).
    pub radius: f64,
    /// Elements certified active so far (∈ every minimizer).
    pub active: u32,
    /// Elements certified inactive so far (∉ any minimizer).
    pub inactive: u32,
    /// Undecided elements still in the reduced problem.
    pub survivors: u32,
    /// Elements newly certified active by this boundary's screen.
    pub new_active: u32,
    /// Elements newly certified inactive by this boundary's screen.
    pub new_inactive: u32,
    /// Nanoseconds the step spent in greedy/certificate oracle passes.
    pub greedy_ns: u64,
    /// Nanoseconds the step spent in prox updates (step minus oracle).
    pub prox_ns: u64,
    /// Nanoseconds spent evaluating the screening rules.
    pub screen_ns: u64,
    /// Nanoseconds spent contracting the ground set and restarting
    /// (zero unless `CONTRACTION` is set).
    pub contract_ns: u64,
    /// Decompose only: per-component-kind nanoseconds inside the block
    /// sweeps, indexed by the `KIND_*` constants. All-zero for
    /// monolithic solves.
    pub kind_ns: [u64; 4],
}

impl TraceEvent {
    /// Human-readable tags for the set flag bits.
    pub fn tags(&self) -> Vec<&'static str> {
        FLAG_TAGS
            .iter()
            .filter(|(bit, _)| self.flags & bit != 0)
            .map(|&(_, tag)| tag)
            .collect()
    }

    /// Serialize as one JSON object (the `--trace` JSONL schema; see
    /// OBSERVABILITY.md).
    pub fn to_json(&self) -> Json {
        let ns = |n: u64| Json::Num(n as f64);
        let tags: Vec<Json> =
            self.tags().iter().map(|t| Json::Str(t.to_string())).collect();
        Json::obj(vec![
            ("iter", ns(self.iter)),
            ("tags", Json::Arr(tags)),
            ("primal", Json::Num(self.primal)),
            ("dual", Json::Num(self.dual)),
            ("gap", Json::Num(self.gap)),
            ("radius", Json::Num(self.radius)),
            ("active", ns(self.active as u64)),
            ("inactive", ns(self.inactive as u64)),
            ("survivors", ns(self.survivors as u64)),
            ("new_active", ns(self.new_active as u64)),
            ("new_inactive", ns(self.new_inactive as u64)),
            ("greedy_ns", ns(self.greedy_ns)),
            ("prox_ns", ns(self.prox_ns)),
            ("screen_ns", ns(self.screen_ns)),
            ("contract_ns", ns(self.contract_ns)),
            (
                "kind_ns",
                Json::Obj(
                    KIND_NAMES
                        .iter()
                        .zip(self.kind_ns)
                        .map(|(k, v)| (k.to_string(), ns(v)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a JSON trace event back, validating the full schema.
    /// Errors name the offending field — the CI trace smoke leg and
    /// `trace-check` rely on this to reject corrupt JSONL loudly.
    pub fn from_json(v: &Json) -> Result<TraceEvent, String> {
        if !matches!(v, Json::Obj(_)) {
            return Err("trace event must be a JSON object".to_string());
        }
        let known = [
            "iter",
            "tags",
            "primal",
            "dual",
            "gap",
            "radius",
            "active",
            "inactive",
            "survivors",
            "new_active",
            "new_inactive",
            "greedy_ns",
            "prox_ns",
            "screen_ns",
            "contract_ns",
            "kind_ns",
        ];
        if let Json::Obj(pairs) = v {
            for (k, _) in pairs {
                if !known.contains(&k.as_str()) {
                    return Err(format!("unknown trace event field `{k}`"));
                }
            }
        }
        let num = |key: &str| -> Result<f64, String> {
            v.get(key)
                .ok_or_else(|| format!("missing trace event field `{key}`"))?
                .as_num()
                .ok_or_else(|| format!("trace event field `{key}` must be a number"))
        };
        let uint = |key: &str| -> Result<u64, String> {
            let x = num(key)?;
            if !x.is_finite() || x < 0.0 || x != x.trunc() {
                return Err(format!(
                    "trace event field `{key}` must be a non-negative integer"
                ));
            }
            Ok(x as u64)
        };
        let mut ev = TraceEvent {
            iter: uint("iter")?,
            flags: 0,
            primal: num("primal")?,
            dual: num("dual")?,
            gap: num("gap")?,
            radius: num("radius")?,
            active: uint("active")? as u32,
            inactive: uint("inactive")? as u32,
            survivors: uint("survivors")? as u32,
            new_active: uint("new_active")? as u32,
            new_inactive: uint("new_inactive")? as u32,
            greedy_ns: uint("greedy_ns")?,
            prox_ns: uint("prox_ns")?,
            screen_ns: uint("screen_ns")?,
            contract_ns: uint("contract_ns")?,
            kind_ns: [0; 4],
        };
        let tags = v
            .get("tags")
            .ok_or_else(|| "missing trace event field `tags`".to_string())?
            .as_array()
            .ok_or_else(|| "trace event field `tags` must be an array".to_string())?;
        for tag in tags {
            let name = tag
                .as_str()
                .ok_or_else(|| "trace event field `tags` must hold strings".to_string())?;
            let bit = FLAG_TAGS
                .iter()
                .find(|(_, t)| *t == name)
                .map(|&(bit, _)| bit)
                .ok_or_else(|| format!("unknown trace event tag `{name}`"))?;
            ev.flags |= bit;
        }
        let kinds = v
            .get("kind_ns")
            .ok_or_else(|| "missing trace event field `kind_ns`".to_string())?;
        if let Json::Obj(pairs) = kinds {
            for (k, _) in pairs {
                if !KIND_NAMES.contains(&k.as_str()) {
                    return Err(format!("unknown trace event field `kind_ns.{k}`"));
                }
            }
        } else {
            return Err("trace event field `kind_ns` must be an object".to_string());
        }
        for (slot, name) in KIND_NAMES.iter().enumerate() {
            let x = kinds
                .get(name)
                .ok_or_else(|| format!("missing trace event field `kind_ns.{name}`"))?
                .as_num()
                .ok_or_else(|| format!("trace event field `kind_ns.{name}` must be a number"))?;
            if !x.is_finite() || x < 0.0 || x != x.trunc() {
                return Err(format!(
                    "trace event field `kind_ns.{name}` must be a non-negative integer"
                ));
            }
            ev.kind_ns[slot] = x as u64;
        }
        Ok(ev)
    }
}

/// Exact totals over every event ever pushed (ring wrap loses events,
/// never totals — they accumulate on push). Folded into
/// `IaesReport::trace` and serve response lines.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TraceSummary {
    /// Events recorded (including any later overwritten by wrap).
    pub events: u64,
    /// Events overwritten because the ring was full.
    pub dropped: u64,
    /// Boundaries at which a screening pass ran.
    pub screens: u64,
    /// Contractions (ground-set rebuilds).
    pub contractions: u64,
    /// Total nanoseconds in greedy/certificate oracle passes.
    pub greedy_ns: u64,
    /// Total nanoseconds in prox updates.
    pub prox_ns: u64,
    /// Total nanoseconds in screening-rule evaluation.
    pub screen_ns: u64,
    /// Total nanoseconds in contraction rebuilds + restarts.
    pub contract_ns: u64,
    /// Decompose only: per-component-kind totals (`KIND_*` slots).
    pub kind_ns: [u64; 4],
    /// Fork-join regions dispatched to the worker pool during the run
    /// (delta of `WorkerPool::dispatches`; zero for sequential solves).
    pub pool_dispatches: u64,
}

impl TraceSummary {
    fn absorb(&mut self, ev: &TraceEvent) {
        self.events += 1;
        if ev.flags & flags::SCREEN != 0 {
            self.screens += 1;
        }
        if ev.flags & flags::CONTRACTION != 0 {
            self.contractions += 1;
        }
        self.greedy_ns += ev.greedy_ns;
        self.prox_ns += ev.prox_ns;
        self.screen_ns += ev.screen_ns;
        self.contract_ns += ev.contract_ns;
        for (acc, &x) in self.kind_ns.iter_mut().zip(&ev.kind_ns) {
            *acc += x;
        }
    }
}

/// Preallocated overwrite-oldest event ring. All slots are materialized
/// at construction, so `push` never allocates.
#[derive(Debug)]
pub struct TraceRing {
    buf: Vec<TraceEvent>,
    /// Next write position.
    head: usize,
    /// Valid events currently held (≤ capacity).
    len: usize,
    totals: TraceSummary,
}

impl TraceRing {
    /// A ring holding up to `cap` events (`cap` is clamped to ≥ 1);
    /// every slot is allocated up front.
    pub fn with_capacity(cap: usize) -> TraceRing {
        TraceRing {
            buf: vec![TraceEvent::default(); cap.max(1)],
            head: 0,
            len: 0,
            totals: TraceSummary::default(),
        }
    }

    /// Slot count (fixed at construction).
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Events currently held.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no event has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Record one event, overwriting the oldest slot when full. Never
    /// allocates (the buffer is pre-sized and `TraceEvent` is `Copy`).
    pub fn push(&mut self, ev: &TraceEvent) {
        let cap = self.buf.len();
        if self.len == cap {
            self.totals.dropped += 1;
        } else {
            self.len += 1;
        }
        self.buf[self.head] = *ev;
        self.head = (self.head + 1) % cap;
        self.totals.absorb(ev);
    }

    /// Surviving events, oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &TraceEvent> {
        let cap = self.buf.len();
        let start = (self.head + cap - self.len) % cap;
        (0..self.len).map(move |i| &self.buf[(start + i) % cap])
    }

    /// Exact running totals (independent of ring wrap).
    pub fn summary(&self) -> TraceSummary {
        self.totals
    }

    /// Fold externally-counted pool fork-join regions into the totals
    /// (the engine records the `WorkerPool::dispatches` delta here).
    pub fn add_pool_dispatches(&mut self, n: u64) {
        self.totals.pool_dispatches += n;
    }
}

/// Cloneable handle to a shared [`TraceRing`]. The engine records
/// through it at major-iteration boundaries; the caller snapshots or
/// summarizes after (or during) the run. One mutex round-trip per
/// boundary — never inside a solver inner loop.
#[derive(Clone, Debug)]
pub struct TraceSink {
    ring: Arc<Mutex<TraceRing>>,
}

impl TraceSink {
    /// A sink with the default ring capacity.
    pub fn new() -> TraceSink {
        TraceSink::with_capacity(DEFAULT_TRACE_CAPACITY)
    }

    /// A sink holding up to `cap` events.
    pub fn with_capacity(cap: usize) -> TraceSink {
        TraceSink { ring: Arc::new(Mutex::new(TraceRing::with_capacity(cap))) }
    }

    /// Lock the ring, adopting a poisoned lock: the ring holds plain
    /// counters and `Copy` slots, so any interrupted write left it
    /// structurally intact.
    fn ring(&self) -> MutexGuard<'_, TraceRing> {
        self.ring.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Record one boundary event (allocation-free; see
    /// [`TraceRing::push`]).
    pub fn record(&self, ev: &TraceEvent) {
        self.ring().push(ev);
    }

    /// Fold pool fork-join region counts into the summary.
    pub fn add_pool_dispatches(&self, n: u64) {
        self.ring().add_pool_dispatches(n);
    }

    /// Copy out the surviving events, oldest → newest.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.ring().iter().copied().collect()
    }

    /// Exact totals over the whole run so far.
    pub fn summary(&self) -> TraceSummary {
        self.ring().summary()
    }

    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.ring().capacity()
    }

    /// Events currently held in the ring.
    pub fn len(&self) -> usize {
        self.ring().len()
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.ring().is_empty()
    }
}

impl Default for TraceSink {
    fn default() -> TraceSink {
        TraceSink::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(iter: u64, flags: u32, greedy_ns: u64) -> TraceEvent {
        TraceEvent {
            iter,
            flags,
            primal: 1.5,
            dual: -0.5,
            gap: 2.0,
            radius: 2.0,
            active: 1,
            inactive: 2,
            survivors: 7,
            new_active: 0,
            new_inactive: 1,
            greedy_ns,
            prox_ns: 10,
            screen_ns: 3,
            contract_ns: 0,
            kind_ns: [1, 2, 3, 4],
        }
    }

    #[test]
    fn ring_overwrites_oldest_but_totals_stay_exact() {
        let mut ring = TraceRing::with_capacity(3);
        for i in 0..5 {
            ring.push(&ev(i + 1, if i % 2 == 0 { flags::SCREEN } else { 0 }, 100));
        }
        assert_eq!(ring.len(), 3);
        assert_eq!(ring.capacity(), 3);
        let iters: Vec<u64> = ring.iter().map(|e| e.iter).collect();
        assert_eq!(iters, vec![3, 4, 5], "oldest events must be overwritten first");
        let s = ring.summary();
        assert_eq!(s.events, 5);
        assert_eq!(s.dropped, 2);
        assert_eq!(s.screens, 3);
        assert_eq!(s.greedy_ns, 500, "totals must include overwritten events");
        assert_eq!(s.kind_ns, [5, 10, 15, 20]);
    }

    #[test]
    fn zero_capacity_is_clamped_to_one_slot() {
        let mut ring = TraceRing::with_capacity(0);
        assert_eq!(ring.capacity(), 1);
        ring.push(&ev(1, 0, 1));
        ring.push(&ev(2, 0, 1));
        assert_eq!(ring.len(), 1);
        assert_eq!(ring.iter().next().unwrap().iter, 2);
        assert_eq!(ring.summary().events, 2);
        assert_eq!(ring.summary().dropped, 1);
    }

    #[test]
    fn event_json_roundtrip_is_lossless() {
        let original = ev(42, flags::SCREEN | flags::CONTRACTION | flags::WARM_RESTART, 7);
        let text = original.to_json().to_string();
        let back = TraceEvent::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, original);
        // The resumed marker survives the round trip by name.
        let resumed = ev(43, flags::RESUMED | flags::FINAL, 7);
        let text = resumed.to_json().to_string();
        assert!(text.contains("\"resumed\""), "{text}");
        let back = TraceEvent::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, resumed);
        // A flagless event round-trips too (empty tags array).
        let plain = ev(1, 0, 0);
        let back = TraceEvent::from_json(&Json::parse(&plain.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back, plain);
    }

    #[test]
    fn event_parser_names_the_offending_field() {
        let good = ev(3, flags::FINAL, 9).to_json().to_string();
        let cases: Vec<(Json, &str)> = vec![
            (Json::parse(&good.replace("\"gap\"", "\"gaap\"")).unwrap(), "gaap"),
            (Json::parse(&good.replace("\"iter\":3", "\"iter\":-1")).unwrap(), "iter"),
            (Json::parse(&good.replace("\"iter\":3", "\"iter\":3.5")).unwrap(), "iter"),
            (
                Json::parse(&good.replace("[\"final\"]", "[\"finale\"]")).unwrap(),
                "finale",
            ),
            (
                Json::parse(&good.replace("\"survivors\":7", "\"survivors\":\"x\""))
                    .unwrap(),
                "survivors",
            ),
            (
                Json::parse(&good.replace("\"chain\":3", "\"chain\":-3")).unwrap(),
                "chain",
            ),
            (Json::parse("[1,2]").unwrap(), "object"),
        ];
        for (doc, needle) in cases {
            let err = TraceEvent::from_json(&doc).unwrap_err();
            assert!(err.contains(needle), "wanted `{needle}` in `{err}`");
        }
        // Dropping a field names it as missing.
        let no_gap = Json::obj(vec![("iter", Json::Num(1.0))]);
        let err = TraceEvent::from_json(&no_gap).unwrap_err();
        assert!(err.contains("primal") || err.contains("missing"), "got `{err}`");
    }

    #[test]
    fn non_finite_floats_survive_the_jsonl_round_trip() {
        // The emitter writes NaN/inf as null; the parser reads null
        // back as NaN rather than erroring (same contract as
        // `Json::as_num`). A cancelled first boundary can carry a
        // pre-step NaN primal, so the trace pipeline must not choke.
        let mut e = ev(1, flags::CANCELLED | flags::FINAL, 0);
        e.primal = f64::NAN;
        let back = TraceEvent::from_json(&Json::parse(&e.to_json().to_string()).unwrap())
            .unwrap();
        assert!(back.primal.is_nan());
        assert_eq!(back.flags, e.flags);
    }

    #[test]
    fn sink_is_shared_across_clones() {
        let sink = TraceSink::with_capacity(8);
        let other = sink.clone();
        sink.record(&ev(1, 0, 5));
        other.record(&ev(2, flags::SCREEN, 5));
        other.add_pool_dispatches(3);
        assert_eq!(sink.len(), 2);
        let s = sink.summary();
        assert_eq!(s.events, 2);
        assert_eq!(s.screens, 1);
        assert_eq!(s.pool_dispatches, 3);
        let snap = sink.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].iter, 1);
        assert_eq!(snap[1].iter, 2);
    }
}
