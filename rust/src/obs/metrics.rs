//! Serve-mode metrics: lock-free atomic counters/gauges, fixed-bucket
//! latency histograms, and a Prometheus-style text exposition writer.
//!
//! The registry lives in the serve core's shared state (one `Arc` for
//! the whole service lifetime), so its counts are **reset-safe by
//! construction**: a worker panic tears down that worker's stack and
//! the pool is rebuilt, but the atomics live outside every worker and
//! keep counting across the rebuild. All updates are single `Relaxed`
//! atomic ops — the registry is written from worker threads and read
//! by the `{"op": "stats"}` control line without any lock.
//!
//! Exposition follows the Prometheus text format conventions
//! (`# HELP`/`# TYPE` headers, `_bucket{le="…"}`/`_sum`/`_count`
//! histogram series with cumulative buckets); [`validate_exposition`]
//! parses that grammar back and checks the histogram invariants — the
//! serve tests round-trip every emitted line through it.

use crate::coordinator::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// Monotone event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current count.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed level (queue depth, in-flight jobs).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    /// Add one.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Subtract one.
    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    /// Current level.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Upper bounds (seconds, inclusive) of the fixed latency buckets; the
/// implicit final bucket is `+Inf`. Fixed at compile time so histograms
/// never allocate and bucket counts are comparable across runs.
pub const LATENCY_BUCKETS_S: [f64; 8] = [0.001, 0.005, 0.025, 0.1, 0.5, 1.0, 5.0, 30.0];

const BUCKETS: usize = LATENCY_BUCKETS_S.len() + 1;

/// Fixed-bucket latency histogram over [`LATENCY_BUCKETS_S`]. Updates
/// are two relaxed atomic adds; no allocation, no lock.
#[derive(Debug, Default)]
pub struct Histogram {
    /// Per-bucket observation counts (NOT cumulative; the exposition
    /// writer accumulates). Slot `i < 8` covers
    /// `(bounds[i-1], bounds[i]]`; the last slot is the `+Inf` tail.
    counts: [AtomicU64; BUCKETS],
    /// Total observed time in nanoseconds.
    sum_ns: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    /// Record one observation of `seconds` (non-finite or negative
    /// values clamp to zero — wall clocks can't go backwards, but a
    /// histogram must never panic in a worker).
    pub fn observe(&self, seconds: f64) {
        let s = if seconds.is_finite() && seconds > 0.0 { seconds } else { 0.0 };
        let slot = LATENCY_BUCKETS_S
            .iter()
            .position(|&b| s <= b)
            .unwrap_or(LATENCY_BUCKETS_S.len());
        self.counts[slot].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add((s * 1e9) as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of observed values in seconds.
    pub fn sum_seconds(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Raw (non-cumulative) per-bucket counts; the last slot is the
    /// `+Inf` tail.
    pub fn bucket_counts(&self) -> [u64; BUCKETS] {
        let mut out = [0u64; BUCKETS];
        for (o, c) in out.iter_mut().zip(&self.counts) {
            *o = c.load(Ordering::Relaxed);
        }
        out
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("sum_s", Json::Num(self.sum_seconds())),
            ("le", Json::Arr(LATENCY_BUCKETS_S.iter().map(|&b| Json::Num(b)).collect())),
            (
                "counts",
                Json::Arr(
                    self.bucket_counts().iter().map(|&c| Json::Num(c as f64)).collect(),
                ),
            ),
        ])
    }
}

/// Every metric the resident solve service exports. Allocated once in
/// the service's shared state; see the module docs for the reset-safety
/// argument.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    /// Jobs admitted to the queue (parsed, validated, within capacity).
    pub jobs_accepted: Counter,
    /// Submissions rejected before admission: unparseable or invalid.
    pub jobs_invalid: Counter,
    /// Submissions rejected because the queue was full.
    pub jobs_rejected: Counter,
    /// Completed jobs by terminal status.
    pub jobs_ok: Counter,
    /// Jobs that returned a partial result (deadline/cancellation or
    /// iteration cap).
    pub jobs_partial: Counter,
    /// Jobs that ended in an error envelope (panic, numeric fault, or
    /// internal error).
    pub jobs_error: Counter,
    /// Error subset: jobs whose worker panicked (pool rebuilt).
    pub jobs_panicked: Counter,
    /// Error subset: jobs stopped by a non-finite gap/primal.
    pub jobs_numeric_faulted: Counter,
    /// Workload-instance cache hits.
    pub cache_hits: Counter,
    /// Worker-pool rebuilds after a contained panic.
    pub pool_rebuilds: Counter,
    /// `{"op": "stats"}` control lines answered.
    pub stats_requests: Counter,
    /// Job attempts re-admitted after a contained panic or numeric
    /// fault (serve `--retries`; one increment per extra attempt).
    pub jobs_retried: Counter,
    /// Retry attempts that resumed from a boundary checkpoint instead
    /// of restarting cold.
    pub resumes: Counter,
    /// Boundary checkpoints captured by solve engines on behalf of
    /// retry-armed jobs.
    pub checkpoints_written: Counter,
    /// Jobs admitted but not yet answered (queued + in flight).
    pub queue_depth: Gauge,
    /// Wall time of jobs that finished `ok`.
    pub wall_ok: Histogram,
    /// Wall time of jobs that finished `partial`.
    pub wall_partial: Histogram,
    /// Wall time of jobs that finished `error` (panics included).
    pub wall_error: Histogram,
    /// Admission → worker-pickup latency (the `queue_wait_s` field of
    /// response envelopes).
    pub queue_wait: Histogram,
}

impl MetricsRegistry {
    /// A zeroed registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// The `{"op": "stats"}` JSON body.
    pub fn to_json(&self) -> Json {
        let n = |c: &Counter| Json::Num(c.get() as f64);
        Json::obj(vec![
            (
                "jobs",
                Json::obj(vec![
                    ("accepted", n(&self.jobs_accepted)),
                    ("invalid", n(&self.jobs_invalid)),
                    ("rejected", n(&self.jobs_rejected)),
                    ("ok", n(&self.jobs_ok)),
                    ("partial", n(&self.jobs_partial)),
                    ("error", n(&self.jobs_error)),
                    ("panicked", n(&self.jobs_panicked)),
                    ("numeric_faulted", n(&self.jobs_numeric_faulted)),
                    ("retried", n(&self.jobs_retried)),
                ]),
            ),
            ("cache_hits", n(&self.cache_hits)),
            ("pool_rebuilds", n(&self.pool_rebuilds)),
            ("stats_requests", n(&self.stats_requests)),
            ("resumes", n(&self.resumes)),
            ("checkpoints_written", n(&self.checkpoints_written)),
            ("queue_depth", Json::Num(self.queue_depth.get() as f64)),
            (
                "wall_s",
                Json::obj(vec![
                    ("ok", self.wall_ok.to_json()),
                    ("partial", self.wall_partial.to_json()),
                    ("error", self.wall_error.to_json()),
                ]),
            ),
            ("queue_wait_s", self.queue_wait.to_json()),
        ])
    }

    /// Prometheus-style text exposition (`format: "text"` on the stats
    /// op). One self-contained document; every line passes
    /// [`validate_exposition`].
    pub fn render_text(&self) -> String {
        let mut out = String::with_capacity(4096);
        let counter = |out: &mut String, name: &str, help: &str, c: &Counter| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {}", c.get());
        };
        let _ = writeln!(out, "# HELP sfm_serve_jobs_total Completed jobs by status.");
        let _ = writeln!(out, "# TYPE sfm_serve_jobs_total counter");
        for (status, c) in [
            ("ok", &self.jobs_ok),
            ("partial", &self.jobs_partial),
            ("error", &self.jobs_error),
        ] {
            let _ = writeln!(out, "sfm_serve_jobs_total{{status=\"{status}\"}} {}", c.get());
        }
        let _ = writeln!(
            out,
            "# HELP sfm_serve_rejects_total Submissions rejected before running."
        );
        let _ = writeln!(out, "# TYPE sfm_serve_rejects_total counter");
        for (kind, c) in
            [("invalid", &self.jobs_invalid), ("queue_full", &self.jobs_rejected)]
        {
            let _ =
                writeln!(out, "sfm_serve_rejects_total{{kind=\"{kind}\"}} {}", c.get());
        }
        counter(
            &mut out,
            "sfm_serve_jobs_admitted_total",
            "Jobs admitted to the queue.",
            &self.jobs_accepted,
        );
        counter(
            &mut out,
            "sfm_serve_job_panics_total",
            "Jobs whose worker panicked (pool rebuilt).",
            &self.jobs_panicked,
        );
        counter(
            &mut out,
            "sfm_serve_numeric_faults_total",
            "Jobs stopped by a non-finite gap or primal.",
            &self.jobs_numeric_faulted,
        );
        counter(
            &mut out,
            "sfm_serve_cache_hits_total",
            "Workload-instance cache hits.",
            &self.cache_hits,
        );
        counter(
            &mut out,
            "sfm_serve_pool_rebuilds_total",
            "Worker-pool rebuilds after a contained panic.",
            &self.pool_rebuilds,
        );
        counter(
            &mut out,
            "sfm_serve_stats_requests_total",
            "Stats control lines answered.",
            &self.stats_requests,
        );
        counter(
            &mut out,
            "sfm_serve_jobs_retried_total",
            "Job attempts re-admitted after a contained fault.",
            &self.jobs_retried,
        );
        counter(
            &mut out,
            "sfm_serve_resumes_total",
            "Retry attempts resumed from a boundary checkpoint.",
            &self.resumes,
        );
        counter(
            &mut out,
            "sfm_serve_checkpoints_written_total",
            "Boundary checkpoints captured for retry-armed jobs.",
            &self.checkpoints_written,
        );
        let _ = writeln!(
            out,
            "# HELP sfm_serve_queue_depth Jobs admitted but not yet answered."
        );
        let _ = writeln!(out, "# TYPE sfm_serve_queue_depth gauge");
        let _ = writeln!(out, "sfm_serve_queue_depth {}", self.queue_depth.get());
        let _ = writeln!(
            out,
            "# HELP sfm_serve_job_wall_seconds Job wall time by terminal status."
        );
        let _ = writeln!(out, "# TYPE sfm_serve_job_wall_seconds histogram");
        for (status, h) in [
            ("ok", &self.wall_ok),
            ("partial", &self.wall_partial),
            ("error", &self.wall_error),
        ] {
            write_histogram(
                &mut out,
                "sfm_serve_job_wall_seconds",
                &format!("status=\"{status}\","),
                h,
            );
        }
        let _ = writeln!(
            out,
            "# HELP sfm_serve_queue_wait_seconds Admission-to-pickup latency."
        );
        let _ = writeln!(out, "# TYPE sfm_serve_queue_wait_seconds histogram");
        write_histogram(&mut out, "sfm_serve_queue_wait_seconds", "", &self.queue_wait);
        out
    }
}

/// One histogram series: cumulative `_bucket` lines (Prometheus
/// convention), then `_sum` and `_count`. `labels` is either empty or
/// `key="value",` pairs each ending in a comma (the `le` label is
/// appended after them).
fn write_histogram(out: &mut String, name: &str, labels: &str, h: &Histogram) {
    let counts = h.bucket_counts();
    let mut cum = 0u64;
    for (i, &b) in LATENCY_BUCKETS_S.iter().enumerate() {
        cum += counts[i];
        let _ = writeln!(out, "{name}_bucket{{{labels}le=\"{b}\"}} {cum}");
    }
    cum += counts[LATENCY_BUCKETS_S.len()];
    let _ = writeln!(out, "{name}_bucket{{{labels}le=\"+Inf\"}} {cum}");
    let trimmed = labels.trim_end_matches(',');
    if trimmed.is_empty() {
        let _ = writeln!(out, "{name}_sum {}", h.sum_seconds());
        let _ = writeln!(out, "{name}_count {}", h.count());
    } else {
        let _ = writeln!(out, "{name}_sum{{{trimmed}}} {}", h.sum_seconds());
        let _ = writeln!(out, "{name}_count{{{trimmed}}} {}", h.count());
    }
}

/// Parse a text exposition document back, checking the line grammar
/// (`# HELP`/`# TYPE` headers, `name{labels} value` samples) and the
/// histogram invariants (buckets cumulative and non-decreasing, `+Inf`
/// bucket equal to `_count`). Returns the number of sample lines.
/// Errors name the offending line. Test/CI support — never on a solve
/// path.
pub fn validate_exposition(text: &str) -> Result<usize, String> {
    let mut samples = 0usize;
    let mut typed: BTreeMap<String, String> = BTreeMap::new();
    // (family, labels-without-le) → cumulative bucket counts in order.
    let mut buckets: BTreeMap<(String, String), Vec<(String, f64)>> = BTreeMap::new();
    let mut counts: BTreeMap<(String, String), f64> = BTreeMap::new();
    let mut sums: BTreeMap<(String, String), bool> = BTreeMap::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            let mut parts = rest.splitn(3, ' ');
            let keyword = parts.next().unwrap_or("");
            let name = parts.next().unwrap_or("");
            let body = parts.next().unwrap_or("");
            match keyword {
                "HELP" if !name.is_empty() && !body.is_empty() => {}
                "TYPE" if !name.is_empty() => {
                    if !matches!(body, "counter" | "gauge" | "histogram") {
                        return Err(format!("bad TYPE `{body}` in line `{line}`"));
                    }
                    typed.insert(name.to_string(), body.to_string());
                }
                _ => return Err(format!("malformed comment line `{line}`")),
            }
            continue;
        }
        // Sample: name{labels} value | name value.
        let (name_labels, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("sample line `{line}` has no value"))?;
        let v: f64 = value
            .parse()
            .map_err(|_| format!("bad sample value `{value}` in line `{line}`"))?;
        let (name, labels) = match name_labels.split_once('{') {
            Some((n, rest)) => {
                let labels = rest
                    .strip_suffix('}')
                    .ok_or_else(|| format!("unclosed labels in line `{line}`"))?;
                (n, labels)
            }
            None => (name_labels, ""),
        };
        if name.is_empty()
            || !name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
            || name.starts_with(|c: char| c.is_ascii_digit())
        {
            return Err(format!("bad metric name `{name}` in line `{line}`"));
        }
        let mut le: Option<String> = None;
        let mut others: Vec<String> = Vec::new();
        if !labels.is_empty() {
            for pair in labels.split(',') {
                let (k, quoted) = pair
                    .split_once('=')
                    .ok_or_else(|| format!("bad label `{pair}` in line `{line}`"))?;
                let val = quoted
                    .strip_prefix('"')
                    .and_then(|q| q.strip_suffix('"'))
                    .ok_or_else(|| format!("unquoted label `{pair}` in line `{line}`"))?;
                if k == "le" {
                    le = Some(val.to_string());
                } else {
                    others.push(format!("{k}={val}"));
                }
            }
        }
        others.sort();
        let series = others.join(",");
        samples += 1;
        if let Some(family) = name.strip_suffix("_bucket") {
            let le = le
                .ok_or_else(|| format!("bucket line `{line}` is missing an le label"))?;
            if typed.get(family).map(String::as_str) != Some("histogram") {
                return Err(format!("`{name}` has no histogram TYPE declaration"));
            }
            buckets
                .entry((family.to_string(), series))
                .or_default()
                .push((le, v));
        } else if let Some(family) = name.strip_suffix("_count") {
            if typed.get(family).map(String::as_str) == Some("histogram") {
                counts.insert((family.to_string(), series), v);
            }
        } else if let Some(family) = name.strip_suffix("_sum") {
            if typed.get(family).map(String::as_str) == Some("histogram") {
                sums.insert((family.to_string(), series), true);
            }
        }
    }
    for ((family, series), series_buckets) in &buckets {
        let mut prev = -1.0;
        let mut inf: Option<f64> = None;
        for (le, v) in series_buckets {
            if *v < prev {
                return Err(format!(
                    "histogram `{family}{{{series}}}` buckets not cumulative at le={le}"
                ));
            }
            prev = *v;
            if le == "+Inf" {
                inf = Some(*v);
            } else {
                le.parse::<f64>().map_err(|_| {
                    format!("histogram `{family}` has a non-numeric le `{le}`")
                })?;
            }
        }
        let inf =
            inf.ok_or_else(|| format!("histogram `{family}` is missing +Inf bucket"))?;
        let total = counts.get(&(family.clone(), series.clone())).ok_or_else(|| {
            format!("histogram `{family}{{{series}}}` is missing a _count sample")
        })?;
        if inf != *total {
            return Err(format!(
                "histogram `{family}{{{series}}}`: +Inf bucket {inf} != count {total}"
            ));
        }
        if !sums.contains_key(&(family.clone(), series.clone())) {
            return Err(format!(
                "histogram `{family}{{{series}}}` is missing a _sum sample"
            ));
        }
    }
    Ok(samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_observations_into_fixed_bounds() {
        let h = Histogram::default();
        h.observe(0.0005); // ≤ 0.001 → slot 0
        h.observe(0.003); // ≤ 0.005 → slot 1
        h.observe(0.003);
        h.observe(2.0); // ≤ 5.0 → slot 6
        h.observe(100.0); // +Inf tail
        h.observe(f64::NAN); // clamps to 0 → slot 0
        h.observe(-3.0); // clamps to 0 → slot 0
        let counts = h.bucket_counts();
        assert_eq!(counts[0], 3);
        assert_eq!(counts[1], 2);
        assert_eq!(counts[6], 1);
        assert_eq!(counts[BUCKETS - 1], 1);
        assert_eq!(h.count(), 7);
        assert!((h.sum_seconds() - 102.0065).abs() < 1e-6);
    }

    #[test]
    fn exposition_round_trips_through_the_validator() {
        let reg = MetricsRegistry::new();
        reg.jobs_accepted.add(5);
        reg.jobs_ok.add(3);
        reg.jobs_partial.inc();
        reg.jobs_error.inc();
        reg.jobs_panicked.inc();
        reg.cache_hits.add(2);
        reg.jobs_retried.inc();
        reg.resumes.inc();
        reg.checkpoints_written.add(4);
        reg.queue_depth.inc();
        for s in [0.0004, 0.02, 0.3] {
            reg.wall_ok.observe(s);
        }
        reg.wall_partial.observe(0.9);
        reg.wall_error.observe(7.0);
        for s in [0.0001, 0.0001, 0.04] {
            reg.queue_wait.observe(s);
        }
        let text = reg.render_text();
        let samples = validate_exposition(&text).unwrap_or_else(|e| panic!("{e}"));
        // 3 status + 2 reject + 9 scalar counters + 1 gauge
        // + 4 histograms × (9 buckets + sum + count) = 59.
        assert_eq!(samples, 15 + 4 * (BUCKETS + 2));
        assert!(text.contains("sfm_serve_jobs_total{status=\"ok\"} 3"));
        assert!(text.contains("sfm_serve_jobs_retried_total 1"));
        assert!(text.contains("sfm_serve_resumes_total 1"));
        assert!(text.contains("sfm_serve_checkpoints_written_total 4"));
        assert!(text.contains("sfm_serve_queue_depth 1"));
        assert!(text.contains(
            "sfm_serve_job_wall_seconds_bucket{status=\"ok\",le=\"+Inf\"} 3"
        ));
        assert!(text.contains("sfm_serve_job_wall_seconds_count{status=\"ok\"} 3"));
    }

    #[test]
    fn validator_rejects_broken_documents() {
        for (doc, needle) in [
            ("# NOPE x y\n", "malformed comment"),
            ("# TYPE m widget\n", "bad TYPE"),
            ("m\n", "no value"),
            ("m abc\n", "bad sample value"),
            ("1up 3\n", "bad metric name"),
            ("m{le=\"0.1\" 3\n", "unclosed labels"),
            ("m{le=0.1} 3\n", "unquoted label"),
            (
                "# TYPE h histogram\nh_bucket{le=\"0.1\"} 3\nh_bucket{le=\"+Inf\"} 2\n\
                 h_sum 1\nh_count 2\n",
                "not cumulative",
            ),
            (
                "# TYPE h histogram\nh_bucket{le=\"0.1\"} 1\nh_sum 1\nh_count 1\n",
                "missing +Inf",
            ),
            (
                "# TYPE h histogram\nh_bucket{le=\"+Inf\"} 3\nh_sum 1\nh_count 2\n",
                "!= count",
            ),
            (
                "# TYPE h histogram\nh_bucket{le=\"+Inf\"} 2\nh_count 2\n",
                "missing a _sum",
            ),
            ("h_bucket{le=\"+Inf\"} 2\n", "no histogram TYPE"),
        ] {
            let err = validate_exposition(doc).unwrap_err();
            assert!(err.contains(needle), "doc `{doc}`: wanted `{needle}` in `{err}`");
        }
    }

    #[test]
    fn registry_json_carries_raw_bucket_counts() {
        let reg = MetricsRegistry::new();
        reg.jobs_ok.add(2);
        reg.wall_ok.observe(0.0005);
        reg.wall_ok.observe(0.3);
        let j = reg.to_json();
        assert_eq!(
            j.get("jobs").and_then(|o| o.get("ok")).and_then(Json::as_num),
            Some(2.0)
        );
        let wall = j.get("wall_s").and_then(|o| o.get("ok")).unwrap();
        assert_eq!(wall.get("count").and_then(Json::as_num), Some(2.0));
        let counts = wall.get("counts").and_then(Json::as_array).unwrap();
        assert_eq!(counts.len(), BUCKETS);
        assert_eq!(counts[0].as_num(), Some(1.0));
        // 0.3 lands in the (0.1, 0.5] bucket — slot 4.
        assert_eq!(counts[4].as_num(), Some(1.0));
        // The emitted JSON parses back (serve embeds it in a response
        // line).
        let text = j.to_string();
        assert!(Json::parse(&text).is_ok());
    }
}
