//! Observability for the solve engine and the resident service.
//!
//! Two independent layers, both built so that *not* observing costs
//! nothing and observing costs almost nothing:
//!
//! * [`trace`] — boundary-sampled solve traces: a preallocated ring of
//!   fixed-size [`TraceEvent`]s the IAES engine records **only at
//!   major-iteration boundaries** (the same points where cooperative
//!   cancellation is checked — the dual is valid in B(F̂) there and the
//!   solver inner loops stay untouched). `IaesOptions::trace = None` is
//!   bitwise inert; an attached sink never changes the numerics, only
//!   adds boundary clock reads.
//! * [`metrics`] — the serve-mode [`MetricsRegistry`]: atomic
//!   counters/gauges and fixed-bucket latency histograms, answered over
//!   the serve protocol by `{"op": "stats"}` as JSON or Prometheus-style
//!   text exposition.
//!
//! Schemas, the boundary-sampling argument, and the overhead budget are
//! documented in OBSERVABILITY.md at the repo root. The hot-path lint
//! (`sfm_lint`, see LINTS.md) bans any `TraceSink`/`MetricsRegistry`
//! call inside hot function bodies, pinning the boundary discipline
//! structurally.

pub mod metrics;
pub mod trace;

pub use metrics::{validate_exposition, Counter, Gauge, Histogram, MetricsRegistry};
pub use trace::{TraceEvent, TraceRing, TraceSink, TraceSummary};
