//! `sfm-screen` — the experiment launcher (L3 leader binary).
//!
//! See `sfm-screen help` for the command reference. Every paper table and
//! figure has a dedicated subcommand; `all` regenerates the full
//! evaluation into `--out-dir`.

use anyhow::{bail, Context, Result};
use sfm_screen::cli::{bench_config, parse_args, USAGE};
use sfm_screen::coordinator::experiments as exp;
use sfm_screen::coordinator::jobs::{rule_set, JobSpec, WorkloadSpec};
use sfm_screen::screening::RuleSet;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(err) = run(&args) {
        eprintln!("error: {err:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    // SFM_FAILPOINT=site=action@N[,site=action@N...] arms deterministic
    // fault injection before any solve starts (the CI crash-resume
    // smoke). Errors loudly — including on builds without
    // `--features failpoint`, where arming is impossible — so a
    // misconfigured crash test can never pass vacuously.
    if let Ok(specs) = std::env::var("SFM_FAILPOINT") {
        for spec in specs.split(',').filter(|s| !s.trim().is_empty()) {
            sfm_screen::runtime::failpoint::arm_from_spec(spec.trim())
                .map_err(|e| anyhow::anyhow!("{e}"))?;
        }
    }
    let cli = parse_args(args)?;
    if cli.flags.get("help").is_some() && cli.command != "help" {
        println!("{USAGE}");
        return Ok(());
    }
    match cli.command.as_str() {
        "help" => println!("{USAGE}"),
        "version" => println!("sfm-screen {}", sfm_screen::VERSION),
        "info" => info()?,
        "solve" => solve(&cli.flags)?,
        "serve" => serve(&cli.flags)?,
        "trace-check" => trace_check(&cli.flags)?,
        "checkpoint-check" => checkpoint_check(&cli.flags)?,
        "path" => path(&cli.flags)?,
        "table1" => {
            let cfg = bench_config(&cli.flags)?;
            println!("{}", exp::table1(&cfg)?.render());
        }
        "table3" => {
            let cfg = bench_config(&cli.flags)?;
            let (t2, t3) = exp::table3(&cfg)?;
            println!("Table 2 — instance statistics\n{}", t2.render());
            println!("Table 3 — running times\n{}", t3.render());
        }
        "fig2" => {
            let cfg = bench_config(&cli.flags)?;
            println!("{}", exp::fig2(&cfg)?.render());
        }
        "fig3" => {
            let cfg = bench_config(&cli.flags)?;
            let p = cli.flags.get_usize("p", 400)?;
            println!("{}", exp::fig3(&cfg, p)?.render());
        }
        "fig4" => {
            let cfg = bench_config(&cli.flags)?;
            println!("{}", exp::fig4(&cfg)?.render());
        }
        "decompose-bench" => {
            let cfg = bench_config(&cli.flags)?;
            let threads = cli.flags.get_usize_list("threads-list", &[1, 2, 4])?;
            println!("{}", exp::decompose_bench(&cfg, &threads)?.render());
        }
        "ablation-rho" => {
            let cfg = bench_config(&cli.flags)?;
            let p = cli.flags.get_usize("p", *cfg.sizes.last().unwrap_or(&400))?;
            let rhos = [0.1, 0.3, 0.5, 0.7, 0.9];
            println!("{}", exp::ablation_rho(&cfg, p, &rhos)?.render());
        }
        "ablation-rules" => {
            let cfg = bench_config(&cli.flags)?;
            let p = cli.flags.get_usize("p", *cfg.sizes.last().unwrap_or(&400))?;
            println!("{}", exp::ablation_rules(&cfg, p)?.render());
        }
        "ablation-solver" => {
            let cfg = bench_config(&cli.flags)?;
            let p = cli.flags.get_usize("p", *cfg.sizes.last().unwrap_or(&400))?;
            println!("{}", exp::ablation_solver(&cfg, p)?.render());
        }
        "all" => {
            let cfg = bench_config(&cli.flags)?;
            println!("== Table 1 ==\n{}", exp::table1(&cfg)?.render());
            let (t2, t3) = exp::table3(&cfg)?;
            println!("== Table 2 ==\n{}", t2.render());
            println!("== Table 3 ==\n{}", t3.render());
            println!("== Figure 2 ==\n{}", exp::fig2(&cfg)?.render());
            let p = *cfg.sizes.last().unwrap_or(&400);
            println!("== Figure 3 ==\n{}", exp::fig3(&cfg, p)?.render());
            println!("== Figure 4 ==\n{}", exp::fig4(&cfg)?.render());
            println!("== Ablation ρ ==\n{}", exp::ablation_rho(&cfg, p, &[0.1, 0.3, 0.5, 0.7, 0.9])?.render());
            println!("== Ablation rules ==\n{}", exp::ablation_rules(&cfg, p)?.render());
            println!("== Ablation solver ==\n{}", exp::ablation_solver(&cfg, p)?.render());
            println!("CSV outputs under {}", cfg.out_dir.display());
        }
        other => bail!("unknown command `{other}` — try `sfm-screen help`"),
    }
    Ok(())
}

/// Run the fault-isolated resident solve service: `JobSpec` JSON lines
/// in (stdin, plus `--socket PATH`), one response line per job out.
fn serve(flags: &sfm_screen::config::Config) -> Result<()> {
    let opts = sfm_screen::coordinator::serve::ServeOptions {
        workers: flags.get_usize("workers", 0)?,
        queue_cap: flags.get_usize("queue-cap", 64)?,
        default_deadline_ms: match flags.get("deadline-ms") {
            Some(_) => Some(flags.get_u64("deadline-ms", 0)?),
            None => None,
        },
        oracle_threads: flags.get_usize("oracle-threads", 1)?,
        retries: flags.get_usize("retries", 0)?,
        retry_backoff_ms: flags.get_u64("retry-backoff-ms", 100)?,
        socket: flags.get("socket").map(std::path::PathBuf::from),
    };
    sfm_screen::coordinator::serve::serve(&opts)
}

/// Compute the SFM′ regularization path (Theorem 2): one proximal solve
/// yields `argmin F + α|A|` for every α.
fn path(flags: &sfm_screen::config::Config) -> Result<()> {
    use sfm_screen::screening::parametric::RegularizationPath;
    let cfg = bench_config(flags)?;
    let p = flags.get_usize("p", 200)?;
    let tm = sfm_screen::workloads::two_moons::TwoMoons::generate(
        sfm_screen::workloads::two_moons::TwoMoonsParams {
            p,
            seed: cfg.seed,
            ..Default::default()
        },
    );
    let f = tm.knn_cut(10, 1.0);
    let rp = RegularizationPath::compute(&f, cfg.eps, cfg.max_iters)?;
    println!("regularization path on two-moons(p={p}):");
    println!("  gap            : {:.3e}", rp.gap);
    println!("  breakpoints    : {}", rp.breakpoints.len());
    let certs = rp.certificates();
    for alpha in [-2.0, -0.5, 0.0, 0.5, 2.0] {
        let a = rp.minimizer_at(alpha);
        println!(
            "  alpha = {alpha:>5}: |A*_a| = {:>4}, certified {:.0}%",
            a.len(),
            100.0 * certs.decided_fraction(alpha, 1e-10)
        );
    }
    Ok(())
}

fn info() -> Result<()> {
    println!("sfm-screen {}", sfm_screen::VERSION);
    let dir = sfm_screen::runtime::default_artifact_dir();
    println!("artifact dir: {}", dir.display());
    match sfm_screen::runtime::XlaScreener::new(&dir) {
        Ok(s) => {
            println!("screen backend: xla (buckets: {:?})", s.buckets());
        }
        Err(e) => {
            println!("screen backend: rust fallback ({e:#})");
        }
    }
    match sfm_screen::runtime::AffinityExec::new(&dir) {
        Ok(a) => println!("affinity kernel: available (buckets: {:?})", a.buckets()),
        Err(_) => println!("affinity kernel: unavailable (rust fallback)"),
    }
    Ok(())
}

fn solve(flags: &sfm_screen::config::Config) -> Result<()> {
    let cfg = bench_config(flags)?;
    let workload = flags.get_str("workload", "two-moons");
    let p = flags.get_usize("p", 400)?;
    let wl = match workload.as_str() {
        "two-moons" => WorkloadSpec::TwoMoons { p, use_mi: cfg.use_mi, seed: cfg.seed },
        "iwata" => WorkloadSpec::Iwata { p },
        img if img.starts_with("image") => {
            let idx: usize = img
                .trim_start_matches("image")
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad image name `{img}`"))?
                .saturating_sub(1);
            WorkloadSpec::Image { index: idx, scale: cfg.image_scale }
        }
        other => bail!("unknown workload `{other}`"),
    };
    let rules: RuleSet = rule_set(&flags.get_str("rules", "all"))?;
    let threads = flags.get_usize("threads", 0)?;
    let decompose = if flags.get_bool("decompose", false)? {
        Some(sfm_screen::decompose::DecomposeOptions { threads, ..Default::default() })
    } else {
        None
    };
    cfg.warmup(&[p]); // pre-compile PJRT executables outside the timed solve
    let mut opts = sfm_screen::screening::iaes::IaesOptions {
        eps: cfg.eps,
        rho: cfg.rho,
        rules,
        solver: sfm_screen::coordinator::jobs::solver_choice(&cfg.solver)?,
        max_iters: cfg.max_iters,
        screener: cfg.screener(),
        record_history: false,
        min_reduction_frac: cfg.min_reduction_frac,
        // Monolithic solves drive the pooled greedy oracle with the same
        // --threads flag the block solver uses (0 = all cores; pooled
        // passes are bit-identical to sequential, so this only changes
        // wall clock).
        threads,
        ..Default::default()
    };
    opts.record_history = false;
    // --trace PATH attaches a boundary-sampled trace ring to the solve
    // and dumps it as JSONL afterwards (one event object per line; the
    // schema `trace-check` validates). Keep a clone of the sink — the
    // ring is shared, so events recorded through the job's copy are
    // visible here after the run.
    let trace_path = flags.get("trace").map(std::path::PathBuf::from);
    let trace_sink = match &trace_path {
        Some(_) => {
            let cap = flags
                .get_usize("trace-cap", sfm_screen::obs::trace::DEFAULT_TRACE_CAPACITY)?;
            Some(sfm_screen::obs::TraceSink::with_capacity(cap))
        }
        None => None,
    };
    opts.trace = trace_sink.clone();
    // --checkpoint PATH attaches a boundary checkpoint sink: every
    // --checkpoint-every N major iterations (default 1) the engine
    // snapshots its screened sets + solver state, atomically replacing
    // PATH (see RELIABILITY.md; validate with checkpoint-check). Keep a
    // clone of the sink — it is shared, so the written() count is
    // visible here after the run.
    let ckpt_path = flags.get("checkpoint").map(std::path::PathBuf::from);
    let ckpt_sink = ckpt_path
        .as_ref()
        .map(|p| sfm_screen::screening::checkpoint::CheckpointSink::to_file(p.clone()));
    if let Some(sink) = &ckpt_sink {
        let every = flags.get_usize("checkpoint-every", 1)?;
        opts.checkpoint =
            Some(sfm_screen::screening::checkpoint::CheckpointConf::new(sink.clone(), every));
    }
    let job = JobSpec { name: wl.label(), workload: wl, opts, decompose };
    // --resume PATH restarts from a boundary snapshot instead of cold:
    // the checkpoint's screened sets are re-installed and its solver
    // atoms regenerated from their stored orders on the contracted
    // oracle (never coordinate-projected — see RELIABILITY.md).
    let resume_path = flags.get("resume").map(std::path::PathBuf::from);
    let res = match &resume_path {
        Some(p) => {
            let ck = sfm_screen::screening::checkpoint::load(p)?;
            let t0 = std::time::Instant::now();
            let report = match job.decompose {
                Some(dopts) => {
                    let f = job.workload.build_decomposed()?;
                    sfm_screen::decompose::solve_decomposed_resumed(&f, &job.opts, dopts, ck)?
                }
                None => {
                    let f = job.workload.build()?;
                    sfm_screen::screening::iaes::IaesEngine::new(f.as_ref(), job.opts.clone())
                        .resume_from(ck)?
                        .run()?
                }
            };
            sfm_screen::coordinator::jobs::JobResult {
                name: job.name.clone(),
                wall: t0.elapsed(),
                report,
            }
        }
        None => job.run()?,
    };
    if let (Some(path), Some(sink)) = (&trace_path, &trace_sink) {
        write_trace(path, sink)?;
    }
    if let (Some(path), Some(sink)) = (&ckpt_path, &ckpt_sink) {
        eprintln!("checkpoint: {} snapshots -> {}", sink.written(), path.display());
    }
    let allow_partial = flags.get_bool("allow-partial", false)?;
    if flags.get_bool("json", false)? {
        println!(
            "{}",
            sfm_screen::coordinator::json::report_to_json(&res.report, false).to_string()
        );
        return check_partial(&res.report, cfg.eps, allow_partial);
    }
    println!("workload     : {}", res.name);
    println!("minimum      : {:.6}", res.report.minimum);
    println!("|A*|         : {}", res.report.minimizer.len());
    println!("iterations   : {}", res.report.iters);
    println!("final gap    : {:.3e}", res.report.final_gap);
    println!(
        "screened     : {} active + {} inactive",
        res.report.screened_active, res.report.screened_inactive
    );
    println!("triggers     : {}", res.report.triggers.len());
    if let Some(t) = res.report.block_threads {
        println!("block workers: {t} (decomposable block solver)");
    }
    if let Some(t) = res.report.greedy_threads {
        println!("oracle threads: {t} (pooled monolithic greedy oracle)");
    }
    println!(
        "time         : {:.3}s total ({:.3}s solver, {:.3}s screening)",
        res.wall.as_secs_f64(),
        res.report.solver_time.as_secs_f64(),
        res.report.screen_time.as_secs_f64()
    );
    println!("emptied      : {}", res.report.emptied);
    println!("converged    : {}", res.report.converged);
    if let Some(r) = res.report.cancel_reason {
        println!("stopped early: {r}");
    }
    if !res.report.converged {
        let why = match res.report.cancel_reason {
            Some(r) => format!("stopped early ({r})"),
            None => format!("hit max_iters={}", res.report.iters),
        };
        eprintln!(
            "WARNING: {why} before reaching eps={:.1e}; the leftover elements \
             were sign-decided from an unconverged iterate and the reported \
             minimizer may be inaccurate (elements screened before the stop \
             remain safe)",
            cfg.eps
        );
    }
    check_partial(&res.report, cfg.eps, allow_partial)
}

/// Dump a solve's trace ring as JSON lines — one event object per
/// line, oldest first. `trace-check` (and the CI trace smoke leg)
/// re-parses every line with the crate's own parser.
fn write_trace(path: &std::path::Path, sink: &sfm_screen::obs::TraceSink) -> Result<()> {
    use std::io::Write;
    let events = sink.snapshot();
    let mut out = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?,
    );
    for ev in &events {
        writeln!(out, "{}", ev.to_json().to_string())?;
    }
    out.flush()?;
    let s = sink.summary();
    eprintln!(
        "trace: {} events ({} dropped) -> {}",
        s.events,
        s.dropped,
        path.display()
    );
    Ok(())
}

/// Validate a `solve --trace` JSONL file with the crate's own parser:
/// every non-empty line must round-trip through
/// [`TraceEvent::from_json`](sfm_screen::obs::TraceEvent::from_json).
/// Exits nonzero on the first malformed line (named by line number).
fn trace_check(flags: &sfm_screen::config::Config) -> Result<()> {
    let path = flags
        .get("file")
        .ok_or_else(|| anyhow::anyhow!("trace-check needs --file PATH"))?
        .to_string();
    let text =
        std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
    let mut events = 0usize;
    let mut finals = 0usize;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = sfm_screen::coordinator::json::Json::parse(line)
            .with_context(|| format!("{path}:{}: not valid JSON", i + 1))?;
        let ev = sfm_screen::obs::TraceEvent::from_json(&v)
            .map_err(|e| anyhow::anyhow!("{path}:{}: {e}", i + 1))?;
        events += 1;
        if ev.flags & sfm_screen::obs::trace::flags::FINAL != 0 {
            finals += 1;
        }
    }
    if events == 0 {
        bail!("{path}: no trace events");
    }
    println!("trace-check: {events} events ok ({finals} final) in {path}");
    Ok(())
}

/// Validate a `solve --checkpoint` JSONL file with the crate's own
/// strict parser: versioned header, no unknown fields, internal
/// consistency (partition, sortedness, finite duals), and byte-stable
/// re-emission. Exits nonzero on the first violation, naming the field.
fn checkpoint_check(flags: &sfm_screen::config::Config) -> Result<()> {
    let path = flags
        .get("file")
        .ok_or_else(|| anyhow::anyhow!("checkpoint-check needs --file PATH"))?
        .to_string();
    let p = std::path::PathBuf::from(&path);
    let ck = sfm_screen::screening::checkpoint::load(&p)?;
    let text =
        std::fs::read_to_string(&p).with_context(|| format!("reading {path}"))?;
    if ck.to_jsonl() != text {
        bail!("{path}: re-emission is not byte-identical (non-canonical checkpoint)");
    }
    println!(
        "checkpoint-check: iter {} of a {}-element solve ok \
         ({} active + {} inactive screened, {} kept) in {path}",
        ck.iter,
        ck.p_total,
        ck.active.len(),
        ck.inactive.len(),
        ck.kept.len()
    );
    Ok(())
}

/// A partial (unconverged or cancelled) solve exits nonzero unless the
/// caller opted in with `--allow-partial` — a script must not mistake a
/// deadline-truncated minimizer for a converged one.
fn check_partial(
    report: &sfm_screen::screening::iaes::IaesReport,
    eps: f64,
    allow_partial: bool,
) -> Result<()> {
    if report.converged || allow_partial {
        return Ok(());
    }
    let why = match report.cancel_reason {
        Some(r) => format!("stopped early ({r})"),
        None => format!("hit max_iters={}", report.iters),
    };
    bail!(
        "solve {why} before reaching eps={eps:.1e} (gap {:.3e}); \
         pass --allow-partial to accept the partial result",
        report.final_gap
    )
}
