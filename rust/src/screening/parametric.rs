//! The parametric family SFM′ and the regularization path (paper §2).
//!
//! Theorem 2: for `ψ_j(x) = ½x²`, solving the single proximal problem
//! (Q-P) once yields the minimizers of the *entire* α-parameterized
//! family
//!
//! ```text
//! min_{A⊆V} F(A) + α|A|        (SFM′ with ∇ψ_j(α) = α)
//! ```
//!
//! via the level sets of `w*`: `{w* > α} ⊆ A*_α ⊆ {w* ≥ α}`. The distinct
//! sets as α sweeps ℝ form a nested chain — the regularization path.
//!
//! This module adds the screening view of that statement: from a *single*
//! approximate solve (ŵ, ŝ, gap, F̂(C)), the Lemma-2 extrema `[w]_j^min`,
//! `[w]_j^max` certify, **for every α simultaneously**, the elements with
//! `[w]_j^min > α` (in `A*_α`) and `[w]_j^max < α` (out of `A*_α`) — a
//! continuum of safe screenings for the price of one.

use crate::linalg::vecops::sum;
use crate::lovasz::{sup_level_set, weak_sup_level_set};
use crate::screening::rules::ball_plane_extrema;
use crate::solvers::minnorm::{MinNormOptions, MinNormPoint};
use crate::solvers::ProxSolver;
use crate::submodular::{Submodular, SubmodularExt};

/// The regularization path extracted from a proximal solve.
#[derive(Clone, Debug)]
pub struct RegularizationPath {
    /// The (approximate) proximal optimum `w*`.
    pub w: Vec<f64>,
    /// Distinct breakpoints of the path (sorted descending): the values
    /// of `w*` at which the minimizer changes.
    pub breakpoints: Vec<f64>,
    /// Duality gap of the solve (drives the per-α certificates).
    pub gap: f64,
    /// `F(V)` (plane offset used by the certificates).
    pub f_v: f64,
    /// Best super-level-set value (Ω bound).
    pub f_c: f64,
}

/// Per-α certificate bands from one solve.
#[derive(Clone, Debug)]
pub struct AlphaCertificates {
    /// `[w]_j^min` per element — `j ∈ A*_α` certified for all `α < wmin_j`.
    pub wmin: Vec<f64>,
    /// `[w]_j^max` per element — `j ∉ A*_α` certified for all `α > wmax_j`.
    pub wmax: Vec<f64>,
}

impl RegularizationPath {
    /// Solve (Q-P) for `f` to duality gap `eps` and extract the path.
    pub fn compute<F: Submodular + ?Sized>(
        f: &F,
        eps: f64,
        max_iters: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(f.ground_size() > 0, "empty ground set");
        let fd: &dyn Submodular = &f; // `&F: Submodular` blanket impl
        let mut solver = MinNormPoint::new(fd, MinNormOptions::default(), None);
        let mut gap = f64::INFINITY;
        for _ in 0..max_iters {
            gap = solver.step(fd).gap;
            if gap < eps {
                break;
            }
        }
        let w = solver.w().to_vec();
        let mut breakpoints: Vec<f64> = w.clone();
        breakpoints.sort_by(|a, b| b.partial_cmp(a).unwrap());
        breakpoints.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
        Ok(RegularizationPath {
            w,
            breakpoints,
            gap,
            f_v: f.eval_full(),
            f_c: solver.best_level_value(),
        })
    }

    /// The minimal minimizer of `F + α|·|`: `{w* > α}` (Theorem 2).
    pub fn minimizer_at(&self, alpha: f64) -> Vec<usize> {
        sup_level_set(&self.w, alpha)
    }

    /// The maximal minimizer: `{w* ≥ α}`.
    pub fn maximal_minimizer_at(&self, alpha: f64) -> Vec<usize> {
        weak_sup_level_set(&self.w, alpha)
    }

    /// The nested chain of minimal minimizers across all breakpoints
    /// (largest first). Consecutive entries differ by the elements whose
    /// `w*` equals the crossed breakpoint.
    pub fn nested_minimizers(&self) -> Vec<Vec<usize>> {
        let mut out = Vec::with_capacity(self.breakpoints.len() + 1);
        out.push(self.minimizer_at(f64::INFINITY)); // ∅
        for &b in &self.breakpoints {
            out.push(self.maximal_minimizer_at(b));
        }
        out
    }

    /// Lemma-2 certificate bands: safe for *every* α simultaneously.
    pub fn certificates(&self) -> AlphaCertificates {
        let p = self.w.len();
        let sum_w = sum(&self.w);
        let mut wmin = vec![0.0; p];
        let mut wmax = vec![0.0; p];
        for j in 0..p {
            let (lo, hi) = ball_plane_extrema(&self.w, j, sum_w, self.gap, self.f_v);
            wmin[j] = lo;
            wmax[j] = hi;
        }
        AlphaCertificates { wmin, wmax }
    }
}

impl AlphaCertificates {
    /// Elements certified inside `A*_α`.
    pub fn certified_active(&self, alpha: f64, margin: f64) -> Vec<usize> {
        self.wmin
            .iter()
            .enumerate()
            .filter(|(_, &lo)| lo > alpha + margin)
            .map(|(j, _)| j)
            .collect()
    }

    /// Elements certified outside `A*_α`.
    pub fn certified_inactive(&self, alpha: f64, margin: f64) -> Vec<usize> {
        self.wmax
            .iter()
            .enumerate()
            .filter(|(_, &hi)| hi < alpha - margin)
            .map(|(j, _)| j)
            .collect()
    }

    /// Fraction of the ground set decided at `alpha`.
    pub fn decided_fraction(&self, alpha: f64, margin: f64) -> f64 {
        let p = self.wmin.len();
        (self.certified_active(alpha, margin).len()
            + self.certified_inactive(alpha, margin).len()) as f64
            / p as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brute::brute_force_sfm;
    use crate::rng::Pcg64;
    use crate::submodular::iwata::IwataFn;
    use crate::submodular::kernel_cut::KernelCutFn;
    use crate::submodular::modular::PlusModular;
    use crate::testutil::forall_rng;

    fn random_kernel_cut(p: usize, rng: &mut Pcg64) -> KernelCutFn {
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let unary = rng.uniform_vec(p, -2.0, 2.0);
        KernelCutFn::new(p, k, unary)
    }

    #[test]
    fn path_minimizers_match_brute_force_tilts() {
        forall_rng(6, |rng| {
            let p = 6 + rng.below(5);
            let f = random_kernel_cut(p, rng);
            let path = RegularizationPath::compute(&f, 1e-12, 50_000)
                .map_err(|e| e.to_string())?;
            for &alpha in &[-1.5, -0.3, 0.0, 0.4, 2.0] {
                // Brute-force the α-tilted function.
                let tilt = PlusModular::new(&f, vec![alpha; p]);
                let brute = brute_force_sfm(&tilt, 1e-7);
                let a_min = path.minimizer_at(alpha);
                // {w* > α} must BE the minimal minimizer (Theorem 2).
                if a_min != brute.minimal {
                    return Err(format!(
                        "alpha={alpha}: {a_min:?} vs brute minimal {:?}",
                        brute.minimal
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn nested_chain_is_nested() {
        let mut rng = Pcg64::seeded(404);
        let f = random_kernel_cut(10, &mut rng);
        let path = RegularizationPath::compute(&f, 1e-10, 50_000).unwrap();
        let chain = path.nested_minimizers();
        for w in chain.windows(2) {
            let small: std::collections::HashSet<_> = w[0].iter().collect();
            assert!(w[1].iter().filter(|i| small.contains(i)).count() == small.len());
            assert!(w[1].len() >= w[0].len());
        }
        // Ends at the full set.
        assert_eq!(chain.last().unwrap().len(), 10);
    }

    #[test]
    fn certificates_are_safe_for_every_alpha() {
        forall_rng(5, |rng| {
            let p = 6 + rng.below(5);
            let f = random_kernel_cut(p, rng);
            // Loose solve — certificates must still be safe.
            let path = RegularizationPath::compute(&f, 1e-3, 10_000)
                .map_err(|e| e.to_string())?;
            let certs = path.certificates();
            for &alpha in &[-1.0, 0.0, 0.7] {
                let tilt = PlusModular::new(&f, vec![alpha; p]);
                let brute = brute_force_sfm(&tilt, 1e-7);
                let minimal: std::collections::HashSet<_> =
                    brute.minimal.into_iter().collect();
                let maximal: std::collections::HashSet<_> =
                    brute.maximal.into_iter().collect();
                for j in certs.certified_active(alpha, 1e-10) {
                    if !minimal.contains(&j) {
                        return Err(format!("alpha={alpha}: {j} wrongly certified in"));
                    }
                }
                for j in certs.certified_inactive(alpha, 1e-10) {
                    if maximal.contains(&j) {
                        return Err(format!("alpha={alpha}: {j} wrongly certified out"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn decided_fraction_increases_with_tighter_solve() {
        let f = IwataFn::new(14);
        let loose = RegularizationPath::compute(&f, 1e-1, 50_000).unwrap();
        let tight = RegularizationPath::compute(&f, 1e-12, 50_000).unwrap();
        let a = loose.certificates().decided_fraction(0.0, 1e-10);
        let b = tight.certificates().decided_fraction(0.0, 1e-10);
        assert!(b >= a, "tighter solve decided less: {b} < {a}");
        assert!(b > 0.9, "tight solve should decide nearly everything ({b})");
    }

    #[test]
    fn breakpoints_sorted_distinct() {
        let f = IwataFn::new(12);
        let path = RegularizationPath::compute(&f, 1e-10, 50_000).unwrap();
        for w in path.breakpoints.windows(2) {
            assert!(w[0] > w[1]);
        }
        assert!(!path.breakpoints.is_empty());
    }
}
