//! Safe element screening for SFM — the paper's contribution.
//!
//! * [`estimate`] — the Theorem-3 optimum estimation `w* ∈ B ∩ Ω ∩ P`
//!   (duality-gap ball, ℓ1 annulus, base-polytope plane) and test
//!   utilities for sampling it.
//! * [`rules`] — the four safe rules: AES-1/IES-1 (closed-form extrema of
//!   `[w]_j` over `B ∩ P`, Lemma 2 / Theorem 4) and AES-2/IES-2
//!   (ℓ1-maximum emptiness tests over `B ∩ Ω`, Lemma 3 / Theorem 5).
//! * [`parametric`] — the SFM′ regularization path: one proximal solve
//!   yields the minimizers of `F + α|·|` for *every* α, plus per-α safe
//!   certificates (Theorem 2 + Lemma 2 combined).
//! * [`iaes`] — Algorithm 2: the alternating screening engine that fires
//!   the rules every time the duality gap decays by `ρ`, contracts the
//!   ground set via Lemma 1, and warm-restarts the solver.
//!
//! The rule evaluation is pure element-wise math, so it has two
//! interchangeable backends behind the [`Screener`] trait: the reference
//! rust implementation in [`rules`], and the AOT-compiled JAX/Pallas kernel
//! executed via PJRT ([`crate::runtime`]). Both are exercised against each
//! other in the test suite.

pub mod checkpoint;
pub mod estimate;
pub mod iaes;
pub mod parametric;
pub mod rules;

/// Which of the four rules to apply (ablations switch subsets off).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RuleSet {
    /// AES-1: ball∩plane active rule.
    pub aes1: bool,
    /// IES-1: ball∩plane inactive rule.
    pub ies1: bool,
    /// AES-2: ball∩annulus active rule.
    pub aes2: bool,
    /// IES-2: ball∩annulus inactive rule.
    pub ies2: bool,
}

impl RuleSet {
    /// All four rules — the full IAES configuration.
    pub const fn all() -> Self {
        RuleSet { aes1: true, ies1: true, aes2: true, ies2: true }
    }
    /// Active-only (AES-1 + AES-2) — the paper's "AES+MinNorm" column.
    pub const fn aes_only() -> Self {
        RuleSet { aes1: true, ies1: false, aes2: true, ies2: false }
    }
    /// Inactive-only (IES-1 + IES-2) — the paper's "IES+MinNorm" column.
    pub const fn ies_only() -> Self {
        RuleSet { aes1: false, ies1: true, aes2: false, ies2: true }
    }
    /// Only the ball∩plane pair (ablation A2).
    pub const fn pair1_only() -> Self {
        RuleSet { aes1: true, ies1: true, aes2: false, ies2: false }
    }
    /// Only the ball∩annulus pair (ablation A2).
    pub const fn pair2_only() -> Self {
        RuleSet { aes1: false, ies1: false, aes2: true, ies2: true }
    }
    /// No screening (pure solver baseline).
    pub const fn none() -> Self {
        RuleSet { aes1: false, ies1: false, aes2: false, ies2: false }
    }
    /// True if no rule is enabled.
    pub fn is_empty(&self) -> bool {
        !(self.aes1 || self.ies1 || self.aes2 || self.ies2)
    }
}

/// Inputs to one screening evaluation, in the *reduced* problem's indexing.
#[derive(Clone, Debug)]
pub struct ScreenInputs<'a> {
    /// Current primal iterate `ŵ` (PAV-refined), length `p̂`.
    pub w: &'a [f64],
    /// Duality gap `G(ŵ, ŝ) ≥ 0`.
    pub gap: f64,
    /// `F̂(V̂)`.
    pub f_v: f64,
    /// Best super-level-set value `F̂(C)` (Remark 1; ≤ 0).
    pub f_c: f64,
}

/// Result of one screening evaluation.
#[derive(Clone, Debug, Default)]
pub struct ScreenOutcome {
    /// Per-element "certified in the minimizer" flags.
    pub active: Vec<bool>,
    /// Per-element "certified outside the minimizer" flags.
    pub inactive: Vec<bool>,
    /// `min_{w ∈ B∩P} [w]_j` (diagnostics; drives AES-1).
    pub wmin: Vec<f64>,
    /// `max_{w ∈ B∩P} [w]_j` (diagnostics; drives IES-1).
    pub wmax: Vec<f64>,
}

impl ScreenOutcome {
    /// Number of newly certified elements.
    pub fn identified(&self) -> usize {
        self.active.iter().filter(|&&b| b).count()
            + self.inactive.iter().filter(|&&b| b).count()
    }
}

/// A screening backend: evaluates the four rules on a reduced problem.
pub trait Screener: Send + Sync {
    /// Evaluate the enabled rules.
    fn screen(&self, inputs: &ScreenInputs<'_>, rules: RuleSet) -> ScreenOutcome;
    /// Backend name for reports.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_set_constructors() {
        assert!(RuleSet::all().aes1 && RuleSet::all().ies2);
        assert!(RuleSet::aes_only().aes2 && !RuleSet::aes_only().ies1);
        assert!(RuleSet::ies_only().ies1 && !RuleSet::ies_only().aes2);
        assert!(RuleSet::none().is_empty());
        assert!(!RuleSet::pair1_only().is_empty());
    }
}
