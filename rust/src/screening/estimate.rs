//! Theorem-3 optimum estimation: `ŵ* ∈ W = B ∩ Ω ∩ P`.
//!
//! This module packages the three certificates and provides membership
//! checks used by the property tests ("the true optimum lies in W at every
//! trigger") and by diagnostics. The derivation:
//!
//! * `B` — `P̂` is 1-strongly convex, so
//!   `½‖ŵ − ŵ*‖² ≤ P̂(ŵ) − P̂(ŵ*) ≤ G(ŵ, ŝ)`;
//! * `P` — `−ŵ* = ŝ* ∈ B(F̂)` implies `⟨ŵ*, 1⟩ = −F̂(V̂)`;
//! * `Ω` — Lemma 4 (`min F̂ = ½(F̂(V̂) − min_{s∈B(F̂)} ‖s‖₁)`) sandwiches
//!   `‖ŵ*‖₁` between `F̂(V̂) − 2F̂(C)` and `‖ŝ‖₁` for any feasible `ŝ`.

use crate::linalg::vecops::{dist2_sq, norm1, sum};

/// The Theorem-3 region `W = B ∩ Ω ∩ P`.
#[derive(Clone, Debug)]
pub struct OptimumEstimate {
    /// Ball center `ŵ`.
    pub center: Vec<f64>,
    /// Ball radius `√(2 G(ŵ, ŝ))`.
    pub radius: f64,
    /// Plane offset: `⟨w, 1⟩ = plane_rhs` (`= −F̂(V̂)`).
    pub plane_rhs: f64,
    /// Ω lower bound `F̂(V̂) − 2 F̂(C) ≤ ‖w‖₁`.
    pub l1_lo: f64,
    /// Ω upper bound `‖w‖₁ ≤ ‖ŝ‖₁`.
    pub l1_hi: f64,
}

impl OptimumEstimate {
    /// Build the estimate from the solver state.
    pub fn from_iterates(w: &[f64], s: &[f64], gap: f64, f_v: f64, f_c: f64) -> Self {
        OptimumEstimate {
            center: w.to_vec(),
            radius: (2.0 * gap.max(0.0)).sqrt(),
            plane_rhs: -f_v,
            l1_lo: f_v - 2.0 * f_c,
            l1_hi: norm1(s),
        }
    }

    /// Membership test with tolerance.
    pub fn contains(&self, x: &[f64], tol: f64) -> bool {
        self.ball_contains(x, tol) && self.plane_contains(x, tol) && self.omega_contains(x, tol)
    }

    /// `x ∈ B`?
    pub fn ball_contains(&self, x: &[f64], tol: f64) -> bool {
        dist2_sq(x, &self.center).sqrt() <= self.radius + tol
    }

    /// `x ∈ P`?
    pub fn plane_contains(&self, x: &[f64], tol: f64) -> bool {
        (sum(x) - self.plane_rhs).abs() <= tol * (1.0 + self.plane_rhs.abs())
    }

    /// `x ∈ Ω`?
    pub fn omega_contains(&self, x: &[f64], tol: f64) -> bool {
        let l1 = norm1(x);
        l1 >= self.l1_lo - tol && l1 <= self.l1_hi + tol
    }

    /// Volume proxy: the ball radius (the dominant shrinking term; the
    /// event log records it so the benches can plot estimation tightness).
    pub fn tightness(&self) -> f64 {
        self.radius
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brute::brute_force_sfm;
    use crate::lovasz::sup_level_set;
    use crate::rng::Pcg64;
    use crate::solvers::minnorm::{MinNormOptions, MinNormPoint};
    use crate::solvers::ProxSolver;
    use crate::submodular::iwata::IwataFn;
    use crate::submodular::kernel_cut::KernelCutFn;
    use crate::submodular::{Submodular, SubmodularExt};

    /// Solve (Q-P) to near-exactness and return w*.
    fn near_exact_wstar(f: &dyn Submodular) -> Vec<f64> {
        let mut solver = MinNormPoint::new(f, MinNormOptions::default(), None);
        for _ in 0..5000 {
            let ev = solver.step(f);
            if ev.gap < 1e-13 {
                break;
            }
        }
        solver.w().to_vec()
    }

    #[test]
    fn theorem3_contains_optimum_along_the_solve() {
        // Track a fresh solve; at every iteration the estimate built from
        // the current iterates must contain the (pre-computed) optimum.
        let f = IwataFn::new(14);
        let w_star = near_exact_wstar(&f);
        let f_v = f.eval_full();
        let mut solver = MinNormPoint::new(&f, MinNormOptions::default(), None);
        for _ in 0..60 {
            let ev = solver.step(&f);
            let est = OptimumEstimate::from_iterates(
                solver.w(),
                solver.s(),
                ev.gap,
                f_v,
                solver.best_level_value(),
            );
            assert!(
                est.ball_contains(&w_star, 1e-7),
                "ball violated at iter {} (gap {})",
                ev.iter,
                ev.gap
            );
            assert!(est.plane_contains(&w_star, 1e-7), "plane violated");
            assert!(est.omega_contains(&w_star, 1e-7), "omega violated");
            if ev.gap < 1e-12 {
                break;
            }
        }
    }

    #[test]
    fn theorem3_on_random_kernel_cut() {
        let mut rng = Pcg64::seeded(29);
        let p = 12;
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let unary = rng.uniform_vec(p, -2.0, 2.0);
        let f = KernelCutFn::new(p, k, unary);
        let w_star = near_exact_wstar(&f);
        // Sanity: {w* > 0} is a minimizer.
        let brute = brute_force_sfm(&f, 1e-7);
        let mut set = vec![false; p];
        for i in sup_level_set(&w_star, 0.0) {
            set[i] = true;
        }
        assert!((f.eval(&set) - brute.minimum).abs() < 1e-6);

        let f_v = f.eval_full();
        let mut solver = MinNormPoint::new(&f, MinNormOptions::default(), None);
        for _ in 0..200 {
            let ev = solver.step(&f);
            let est = OptimumEstimate::from_iterates(
                solver.w(),
                solver.s(),
                ev.gap,
                f_v,
                solver.best_level_value(),
            );
            assert!(est.contains(&w_star, 1e-6), "W violated at iter {}", ev.iter);
            if ev.gap < 1e-12 {
                break;
            }
        }
    }

    #[test]
    fn radius_shrinks_with_gap() {
        let a = OptimumEstimate::from_iterates(&[0.0], &[0.0], 2.0, 0.0, 0.0);
        let b = OptimumEstimate::from_iterates(&[0.0], &[0.0], 0.5, 0.0, 0.0);
        assert!(b.tightness() < a.tightness());
        assert!((a.tightness() - 2.0).abs() < 1e-12);
    }
}
