//! The four safe screening rules — reference rust implementation.
//!
//! Given the Theorem-3 estimate `w* ∈ B ∩ Ω ∩ P` with
//!
//! * `B = {w : ‖w − ŵ‖ ≤ r}`, `r = √(2 G(ŵ, ŝ))`,
//! * `P = {w : ⟨w, 1⟩ = −F̂(V̂)}`,
//! * `Ω = {w : F̂(V̂) − 2F̂(C) ≤ ‖w‖₁ ≤ ‖ŝ‖₁}`,
//!
//! the rules certify elements of the reduced ground set:
//!
//! * **AES-1 / IES-1** (Lemma 2, Theorem 4): the extrema of `[w]_j` over
//!   `B ∩ P` solve a quadratic — `p̂ t² + b_j t + c_j ≤ 0` — whose roots
//!   give `[w]_j^min/max` in closed form. `[w]_j^min > 0 ⇒ j ∈ A*`;
//!   `[w]_j^max < 0 ⇒ j ∉ A*`.
//! * **AES-2 / IES-2** (Lemma 3, Theorem 5): for the elements rules 1
//!   cannot decide (`|ŵ_j| ≤ r`), test whether the half-ball
//!   `{w ∈ B : [w]_j ≤ 0}` (resp. `≥ 0`) misses the annulus Ω entirely —
//!   its maximal ℓ1 norm has a closed form; if that maximum is below the
//!   lower Ω bound `F̂(V̂) − 2F̂(C)`, the half-ball is infeasible and the
//!   sign of `[w*]_j` is certified.
//!
//! A configurable `margin` turns the paper's strict inequalities into
//! `> margin` comparisons so that f64 round-off cannot flip a certificate;
//! the safety property tests in `tests/` drive this against brute force.

use super::{RuleSet, ScreenInputs, ScreenOutcome, Screener};
use crate::linalg::vecops::{norm1, sum};

/// Reference (pure rust) screening backend.
#[derive(Clone, Copy, Debug)]
pub struct RustScreener {
    /// Strictness margin added to every certificate comparison.
    pub margin: f64,
}

impl Default for RustScreener {
    fn default() -> Self {
        RustScreener { margin: 1e-10 }
    }
}

/// Per-call constants shared by every Lemma-3 ℓ1-maximum evaluation:
/// hoisting the square roots out of the per-element loop is what keeps
/// the hot screening pass lean, and routing the reference helpers through
/// the *same* core keeps the two paths from silently diverging (they once
/// disagreed on the `(p̂ − 1) ≥ 0` guard — see the p̂ = 1 regression test).
#[derive(Clone, Copy, Debug)]
pub struct L1Consts {
    /// `2 · gap` (the squared ball radius).
    two_g: f64,
    /// `√(2 p̂ · gap)`.
    sq_2pg: f64,
    /// `√(max(p̂ − 1, 0))` — clamped so p̂ = 1 cannot produce NaN.
    sq_pm1: f64,
    /// `√(2 · gap / p̂)`.
    sq_2g_over_p: f64,
}

impl L1Consts {
    /// Hoist the constants for ground-set size `p` and duality gap `gap`.
    pub fn new(p: usize, gap: f64) -> Self {
        let pf = p as f64;
        let two_g = 2.0 * gap;
        L1Consts {
            two_g,
            sq_2pg: (pf * two_g).sqrt(),
            sq_pm1: (pf - 1.0).max(0.0).sqrt(),
            sq_2g_over_p: (two_g / pf).sqrt(),
        }
    }
}

/// Lemma-3 core: `max ‖w‖₁` over the half-ball `{w ∈ B : [w]_j ≤ 0}` for
/// a coordinate with `ŵ_j = wj > 0`. The `≥ 0` case is the mirror image
/// (`wj → −wj`), so both reference helpers and the fused hot loop call
/// this one function — the single source of truth for the closed form.
#[inline]
fn l1_halfball_max(wj: f64, l1_w: f64, c: &L1Consts) -> f64 {
    if wj - c.sq_2g_over_p < 0.0 {
        l1_w - 2.0 * wj + c.sq_2pg
    } else {
        l1_w - wj + c.sq_pm1 * (c.two_g - wj * wj).max(0.0).sqrt()
    }
}

/// Lemma-2 core: closed-form `[w]_j^min / [w]_j^max` over `B ∩ P` given
/// the hoisted `p̂` constants. Shared verbatim by the reference helper and
/// the fused `screen_rust` loop (same operations in the same order, so
/// the two stay bit-identical).
#[inline]
fn ball_plane_extrema_core(
    wj: f64,
    sum_w: f64,
    gap: f64,
    f_v: f64,
    pf: f64,
) -> (f64, f64) {
    let sum_except = sum_w - wj;
    let b = 2.0 * (sum_except + f_v - (pf - 1.0) * wj);
    let c = {
        let t = sum_except + f_v;
        t * t - (pf - 1.0) * (2.0 * gap - wj * wj)
    };
    // b² − 4 p̂ c ≥ 0 in exact arithmetic (the feasible w* satisfies the
    // quadratic); clamp against round-off.
    let disc = (b * b - 4.0 * pf * c).max(0.0);
    let sq = disc.sqrt();
    ((-b - sq) / (2.0 * pf), (-b + sq) / (2.0 * pf))
}

/// Closed-form `[w]_j^min / [w]_j^max` over `B ∩ P` (Lemma 2).
///
/// Returns `(wmin, wmax)`. Handles the degenerate `p̂ = 1` case where the
/// plane pins `w = −F̂(V̂)` exactly.
pub fn ball_plane_extrema(
    w: &[f64],
    j: usize,
    sum_w: f64,
    gap: f64,
    f_v: f64,
) -> (f64, f64) {
    if w.len() == 1 {
        return (-f_v, -f_v);
    }
    ball_plane_extrema_core(w[j], sum_w, gap, f_v, w.len() as f64)
}

/// `max_{w ∈ B, [w]_j ≤ 0} ‖w‖₁` for `0 < ŵ_j ≤ r` (Lemma 3(ii)).
pub fn l1_max_nonpos(w: &[f64], j: usize, l1_w: f64, gap: f64) -> f64 {
    let wj = w[j];
    debug_assert!(wj > 0.0);
    l1_halfball_max(wj, l1_w, &L1Consts::new(w.len(), gap))
}

/// `max_{w ∈ B, [w]_j ≥ 0} ‖w‖₁` for `−r ≤ ŵ_j < 0` (Lemma 3(iii)).
/// Mirror image of [`l1_max_nonpos`] under `w → −w`.
pub fn l1_max_nonneg(w: &[f64], j: usize, l1_w: f64, gap: f64) -> f64 {
    let wj = w[j];
    debug_assert!(wj < 0.0);
    l1_halfball_max(-wj, l1_w, &L1Consts::new(w.len(), gap))
}

/// Evaluate the enabled rules over the whole reduced ground set.
///
/// This is the hot screening path of the rust backend — one pass over the
/// vector after two O(p̂) reductions, mirroring the fused Pallas kernel.
pub fn screen_rust(inputs: &ScreenInputs<'_>, rules: RuleSet, margin: f64) -> ScreenOutcome {
    let w = inputs.w;
    let p = w.len();
    let gap = inputs.gap.max(0.0);
    let r = (2.0 * gap).sqrt();
    let sum_w = sum(w);
    let l1_w = norm1(w);
    // Lower Ω bound: ‖w*‖₁ ≥ F̂(V̂) − 2 F̂(C) (Lemma 4).
    let omega_lo = inputs.f_v - 2.0 * inputs.f_c;

    let mut out = ScreenOutcome {
        active: vec![false; p],
        inactive: vec![false; p],
        wmin: vec![0.0; p],
        wmax: vec![0.0; p],
    };

    // Hoisted per-call constants (the per-element loop below runs at every
    // trigger on the full residual vector — keep it lean). Both pairs of
    // rules share their closed forms with the reference helpers via
    // `ball_plane_extrema_core` / `l1_halfball_max`, so the hot loop and
    // the reference API cannot drift apart again.
    let pf = p as f64;
    let consts = L1Consts::new(p, gap);
    let f_v = inputs.f_v;
    let p1 = p == 1;

    for j in 0..p {
        let wj = w[j];
        // Lemma 2 closed forms (shared core, hoisted constants).
        let (wmin, wmax) = if p1 {
            (-f_v, -f_v)
        } else {
            ball_plane_extrema_core(wj, sum_w, gap, f_v, pf)
        };
        out.wmin[j] = wmin;
        out.wmax[j] = wmax;

        // Pair 1: ball ∩ plane.
        if rules.aes1 && wmin > margin {
            out.active[j] = true;
            continue;
        }
        if rules.ies1 && wmax < -margin {
            out.inactive[j] = true;
            continue;
        }

        // Pair 2: ball ∩ annulus — only for the undecided band |ŵ_j| ≤ r.
        if rules.aes2
            && wj > 0.0
            && wj <= r
            && l1_halfball_max(wj, l1_w, &consts) < omega_lo - margin
        {
            out.active[j] = true;
            continue;
        }
        if rules.ies2
            && wj < 0.0
            && -wj <= r
            && l1_halfball_max(-wj, l1_w, &consts) < omega_lo - margin
        {
            out.inactive[j] = true;
        }
    }
    out
}

impl Screener for RustScreener {
    fn screen(&self, inputs: &ScreenInputs<'_>, rules: RuleSet) -> ScreenOutcome {
        screen_rust(inputs, rules, self.margin)
    }
    fn name(&self) -> &'static str {
        "rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testutil::forall_rng;

    /// Sample a point of B ∩ P by projecting a random ball point onto the
    /// plane and rescaling to stay in the ball (rejection-free because we
    /// shrink toward the projected center).
    fn sample_ball_plane(rng: &mut Pcg64, w: &[f64], gap: f64, f_v: f64) -> Option<Vec<f64>> {
        let p = w.len();
        let r = (2.0 * gap).sqrt();
        // Project ŵ onto P: ŵ + ((−f_v − Σŵ)/p) 1.
        let shift = (-f_v - sum(w)) / p as f64;
        let center: Vec<f64> = w.iter().map(|x| x + shift).collect();
        let dist_cp = shift.abs() * (p as f64).sqrt();
        if dist_cp > r {
            return None; // plane misses ball (cannot happen for valid inputs)
        }
        let r_in_plane = (r * r - dist_cp * dist_cp).sqrt();
        // Random direction inside the plane (1ᵀd = 0):
        let mut d = rng.normal_vec(p);
        let mean = sum(&d) / p as f64;
        for x in d.iter_mut() {
            *x -= mean;
        }
        let n = crate::linalg::vecops::norm2(&d);
        if n < 1e-12 {
            return Some(center);
        }
        let scale = rng.next_f64().powf(1.0 / p as f64) * r_in_plane / n;
        Some(center.iter().zip(&d).map(|(c, x)| c + scale * x).collect())
    }

    #[test]
    fn lemma2_extrema_bound_sampled_points() {
        forall_rng(40, |rng| {
            let p = 2 + rng.below(8);
            let w = rng.normal_vec(p);
            let gap = rng.uniform(0.01, 2.0);
            // Choose f_v so the plane intersects the ball: the distance
            // from ŵ to P is |Σŵ + f_v|/√p ≤ r·0.8.
            let r = (2.0f64 * gap).sqrt();
            let slack = rng.uniform(-0.8, 0.8) * r * (p as f64).sqrt();
            let f_v = -sum(&w) + slack;
            for _ in 0..50 {
                let Some(pt) = sample_ball_plane(rng, &w, gap, f_v) else {
                    continue;
                };
                // Check membership of the sample first (tolerance).
                let dist = crate::linalg::vecops::dist2_sq(&pt, &w).sqrt();
                if dist > r + 1e-9 {
                    continue;
                }
                let sum_w = sum(&w);
                for j in 0..p {
                    let (lo, hi) = ball_plane_extrema(&w, j, sum_w, gap, f_v);
                    if pt[j] < lo - 1e-7 || pt[j] > hi + 1e-7 {
                        return Err(format!(
                            "sampled point violates Lemma 2 bounds at j={j}: {} not in [{lo}, {hi}]",
                            pt[j]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lemma2_extrema_attained_tightly() {
        // Maximize [w]_j over B∩P numerically (projected coordinate ascent
        // via the closed-form structure: optimum has all other coords
        // equal). Cross-check the closed form.
        forall_rng(30, |rng| {
            let p = 3 + rng.below(6);
            let w = rng.normal_vec(p);
            let gap = rng.uniform(0.05, 1.5);
            let r = (2.0f64 * gap).sqrt();
            let slack = rng.uniform(-0.5, 0.5) * r * (p as f64).sqrt();
            let f_v = -sum(&w) + slack;
            let sum_w = sum(&w);
            for j in 0..p {
                let (lo, hi) = ball_plane_extrema(&w, j, sum_w, gap, f_v);
                // Construct the argmax point explicitly: fix [w]_j = hi,
                // the rest at the constrained ball/plane tangency:
                // others = ŵ_i + t where Σ others = −f_v − hi.
                let t = (-f_v - hi - (sum_w - w[j])) / (p as f64 - 1.0);
                let mut pt: Vec<f64> = w
                    .iter()
                    .enumerate()
                    .map(|(i, &x)| if i == j { hi } else { x + t })
                    .collect();
                // Must lie on the ball boundary (that's where extrema live)
                let dist = crate::linalg::vecops::dist2_sq(&pt, &w).sqrt();
                if (dist - r).abs() > 1e-6 * (1.0 + r) {
                    return Err(format!("argmax not on ball boundary: {dist} vs {r}"));
                }
                // And on the plane.
                let on_plane = (sum(&pt) + f_v).abs() < 1e-7;
                if !on_plane {
                    return Err("argmax not on plane".into());
                }
                // Same for the min.
                let t = (-f_v - lo - (sum_w - w[j])) / (p as f64 - 1.0);
                pt = w
                    .iter()
                    .enumerate()
                    .map(|(i, &x)| if i == j { lo } else { x + t })
                    .collect();
                let dist = crate::linalg::vecops::dist2_sq(&pt, &w).sqrt();
                if (dist - r).abs() > 1e-6 * (1.0 + r) {
                    return Err("argmin not on ball boundary".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lemma3_l1max_bounds_sampled_halfball_points() {
        forall_rng(40, |rng| {
            let p = 2 + rng.below(8);
            let mut w = rng.normal_vec(p);
            let gap = rng.uniform(0.05, 1.0);
            let r = (2.0f64 * gap).sqrt();
            let l1_w = norm1(&w);
            // Pick a coordinate with 0 < w_j ≤ r (rig one if needed).
            let j = rng.below(p);
            w[j] = rng.uniform(1e-6, r * 0.99);
            let l1_w = {
                let _ = l1_w;
                norm1(&w)
            };
            let bound = l1_max_nonpos(&w, j, l1_w, gap);
            // Sample ball points with [w]_j ≤ 0 and check their ℓ1 norm.
            for _ in 0..200 {
                let mut d = rng.normal_vec(p);
                let n = crate::linalg::vecops::norm2(&d);
                let scale = rng.next_f64().powf(1.0 / p as f64) * r / n;
                for x in d.iter_mut() {
                    *x *= scale;
                }
                let pt: Vec<f64> = w.iter().zip(&d).map(|(a, b)| a + b).collect();
                if pt[j] > 0.0 {
                    continue;
                }
                if norm1(&pt) > bound + 1e-7 {
                    return Err(format!(
                        "ℓ1 of half-ball point {} exceeds Lemma 3 bound {bound}",
                        norm1(&pt)
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lemma3_symmetry() {
        // l1_max_nonneg(w, j) on w must equal l1_max_nonpos(−w, j) on −w.
        forall_rng(30, |rng| {
            let p = 2 + rng.below(8);
            let mut w = rng.normal_vec(p);
            let gap = rng.uniform(0.05, 1.0);
            let r = (2.0f64 * gap).sqrt();
            let j = rng.below(p);
            w[j] = -rng.uniform(1e-6, r * 0.99);
            let l1 = norm1(&w);
            let a = l1_max_nonneg(&w, j, l1, gap);
            let wneg: Vec<f64> = w.iter().map(|x| -x).collect();
            let b = l1_max_nonpos(&wneg, j, l1, gap);
            crate::testutil::assert_close(a, b, 1e-12, "lemma3 symmetry")
        });
    }

    #[test]
    fn p1_degenerate_case() {
        let w = [0.7];
        let (lo, hi) = ball_plane_extrema(&w, 0, 0.7, 0.5, -1.25);
        assert_eq!(lo, 1.25);
        assert_eq!(hi, 1.25);
    }

    #[test]
    fn lemma3_helpers_finite_at_tiny_ground_sets() {
        // Regression: the reference helpers and the fused hot loop must
        // agree on the (p̂ − 1) ≥ 0 guard — a p̂ = 1 residual problem has
        // to produce finite bounds, not NaN, on both paths.
        for gap in [1e-12, 0.01, 0.5] {
            let r = (2.0f64 * gap).sqrt();
            // p = 1, positive coordinate inside the undecided band.
            let w = [0.9 * r];
            let bound = l1_max_nonpos(&w, 0, norm1(&w), gap);
            assert!(bound.is_finite(), "p=1 nonpos bound NaN at gap {gap}");
            let wn = [-0.9 * r];
            let bound = l1_max_nonneg(&wn, 0, norm1(&wn), gap);
            assert!(bound.is_finite(), "p=1 nonneg bound NaN at gap {gap}");
            // p = 2: both branch arms of the closed form stay finite.
            for wj in [0.1 * r, 0.9 * r] {
                let w2 = [wj, -1.3];
                let bound = l1_max_nonpos(&w2, 0, norm1(&w2), gap);
                assert!(bound.is_finite(), "p=2 bound NaN at gap {gap}");
            }
        }
    }

    #[test]
    fn reference_helpers_bitwise_match_hot_loop() {
        // The inlined screen_rust pass and the public helpers share one
        // core; pin the bit-level agreement on both pair-2 branches.
        forall_rng(25, |rng| {
            let p = 1 + rng.below(12);
            let w = rng.normal_vec(p);
            let gap = rng.uniform(1e-6, 1.0);
            let consts = super::L1Consts::new(p, gap);
            for j in 0..p {
                let wj = w[j];
                if wj > 0.0 {
                    let a = l1_max_nonpos(&w, j, norm1(&w), gap);
                    let b = super::l1_halfball_max(wj, norm1(&w), &consts);
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("nonpos helper drifted at j={j}"));
                    }
                } else if wj < 0.0 {
                    let a = l1_max_nonneg(&w, j, norm1(&w), gap);
                    let b = super::l1_halfball_max(-wj, norm1(&w), &consts);
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("nonneg helper drifted at j={j}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn screen_rust_single_element_problems_are_decided_sanely() {
        // p̂ = 1 end-to-end: the last surviving element must be certified
        // by its pinned value −F̂(V̂), never NaN-skipped.
        for (f_v, expect_active) in [(-2.0, true), (2.0, false)] {
            let w = [if f_v < 0.0 { 1.0 } else { -1.0 }];
            let inputs = ScreenInputs { w: &w, gap: 1e-10, f_v, f_c: 0.0 };
            let out = screen_rust(&inputs, RuleSet::all(), 1e-10);
            assert!(out.wmin[0].is_finite() && out.wmax[0].is_finite());
            assert_eq!(out.active[0], expect_active, "f_v = {f_v}");
            assert_eq!(out.inactive[0], !expect_active, "f_v = {f_v}");
        }
    }

    #[test]
    fn screen_rust_shapes_and_disjoint() {
        forall_rng(20, |rng| {
            let p = 1 + rng.below(20);
            let w = rng.normal_vec(p);
            let gap = rng.uniform(0.0, 1.0);
            let f_v = -sum(&w) + rng.uniform(-0.3, 0.3);
            let f_c = -rng.uniform(0.0, 1.0);
            let inputs = ScreenInputs { w: &w, gap, f_v, f_c };
            let out = screen_rust(&inputs, RuleSet::all(), 1e-10);
            if out.active.len() != p || out.inactive.len() != p {
                return Err("wrong lengths".into());
            }
            for j in 0..p {
                if out.active[j] && out.inactive[j] {
                    return Err(format!("element {j} both active and inactive"));
                }
                if out.wmin[j] > out.wmax[j] + 1e-12 {
                    return Err("wmin > wmax".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tight_gap_screens_everything() {
        // With gap → 0 the ball collapses to ŵ; every element with
        // |ŵ_j| bounded away from 0 must be decided by rules 1.
        let w = [0.5, -0.3, 1.2, -2.0];
        let f_v = -sum(&w); // plane passes through ŵ
        let inputs = ScreenInputs { w: &w, gap: 1e-14, f_v, f_c: 0.0 };
        let out = screen_rust(&inputs, RuleSet::all(), 1e-10);
        assert_eq!(out.active, vec![true, false, true, false]);
        assert_eq!(out.inactive, vec![false, true, false, true]);
    }

    #[test]
    fn aes_only_never_marks_inactive() {
        let mut rng = Pcg64::seeded(3);
        let w = rng.normal_vec(12);
        let inputs = ScreenInputs { w: &w, gap: 0.01, f_v: -sum(&w), f_c: -0.2 };
        let out = screen_rust(&inputs, RuleSet::aes_only(), 1e-10);
        assert!(out.inactive.iter().all(|&b| !b));
        let out = screen_rust(&inputs, RuleSet::ies_only(), 1e-10);
        assert!(out.active.iter().all(|&b| !b));
    }
}
