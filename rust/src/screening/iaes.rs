//! Algorithm 2 — the IAES engine: Inactive and Active Element Screening.
//!
//! The engine drives a [`ProxSolver`] on the reduced pair (Q-P′)/(Q-D′)
//! and fires the enabled screening rules every time the duality gap drops
//! below `ρ ×` (gap at last trigger). Newly certified elements update the
//! global active/inactive sets; the ground set is contracted via the
//! Lemma-1 reduction ([`ScaledFn`]); the solver warm-restarts from the
//! restricted primal with `ŝ ← argmax_{s∈B(F̂)} ⟨ŵ, s⟩` (step 14).
//!
//! Termination: either the residual ground set empties (`A* = Ê` — the
//! paper's "no theoretical limit" property: screening can finish the whole
//! problem), or the gap reaches `ε` and the remaining signs of `ŵ` decide
//! the leftover elements (`A* = Ê ∪ {ŵ > 0}`).

use super::checkpoint::{CheckpointConf, SolveCheckpoint};
use super::rules::RustScreener;
use super::{RuleSet, ScreenInputs, Screener};
use crate::obs::trace::{flags as tflags, TraceEvent, TraceSink, TraceSummary};
use crate::runtime::cancel::{CancelReason, CancelToken};
use crate::runtime::failpoint;
use crate::runtime::pool::WorkerPool;
use crate::solvers::frankwolfe::{FrankWolfe, FwOptions};
use crate::solvers::minnorm::{MinNormOptions, MinNormPoint};
use crate::solvers::ProxSolver;
use crate::submodular::scaled::ScaledFn;
use crate::submodular::{Submodular, SubmodularExt};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A non-finite duality gap or primal iterate observed mid-solve.
///
/// A NaN/∞ gap means the screening radius of Theorem 3 is meaningless, so
/// continuing to screen would certify elements unsafely; the engine fails
/// the solve with this typed error instead. The serve layer downcasts it
/// (`anyhow::Error::downcast_ref`) to emit a structured `numeric` error
/// envelope rather than a generic failure.
#[derive(Clone, Debug)]
pub struct NumericFault {
    /// Which quantity went non-finite (`"duality gap"`, `"primal iterate"`).
    pub what: String,
    /// Global major-iteration index at which it was detected.
    pub iter: usize,
}

impl std::fmt::Display for NumericFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "non-finite {} at iteration {}: screening radius undefined, refusing to screen",
            self.what, self.iter
        )
    }
}

impl std::error::Error for NumericFault {}

/// Solver selection for the engine.
#[derive(Clone, Copy, Debug)]
pub enum SolverChoice {
    /// Fujishige–Wolfe minimum-norm point (the paper's choice).
    MinNorm(MinNormOptions),
    /// Conditional gradient (Remark 2 alternative).
    FrankWolfe(FwOptions),
}

impl Default for SolverChoice {
    fn default() -> Self {
        SolverChoice::MinNorm(MinNormOptions::default())
    }
}

impl SolverChoice {
    fn build(&self, f: &dyn Submodular) -> Box<dyn ProxSolver> {
        match self {
            SolverChoice::MinNorm(o) => Box::new(MinNormPoint::new(f, *o, None)),
            SolverChoice::FrankWolfe(o) => Box::new(FrankWolfe::new(f, *o, None)),
        }
    }
}

/// Engine configuration.
#[derive(Clone)]
pub struct IaesOptions {
    /// Duality-gap accuracy `ε` (paper: 1e−6).
    pub eps: f64,
    /// Trigger decay `ρ ∈ (0, 1)` (paper: 0.5; Remark 5).
    pub rho: f64,
    /// Which rules run (all / AES-only / IES-only / none).
    pub rules: RuleSet,
    /// Solver A.
    pub solver: SolverChoice,
    /// Hard cap on major iterations.
    pub max_iters: usize,
    /// Screening backend; `None` → reference rust backend.
    pub screener: Option<Arc<dyn Screener>>,
    /// Record per-iteration history (rejection-ratio curves).
    pub record_history: bool,
    /// Deferred-contraction threshold: certified elements are *removed*
    /// (ground set contracted + solver warm-restarted, Algorithm 2 steps
    /// 13–15) only once they make up at least this fraction of the
    /// residual problem. Certification itself is never deferred — only
    /// the restart. Remark 4 notes the restart "may increase the dual gap
    /// slightly"; batching keeps that cost amortized against a reduction
    /// that is actually worth it. `0.0` restarts on every certificate
    /// (the literal Algorithm 2).
    pub min_reduction_frac: f64,
    /// Contraction-aware warm restarts: project the solver's greedy
    /// order, corral, and atoms through each ground-set contraction
    /// ([`crate::solvers::ProxSolver::reset_mapped`]) instead of
    /// rebuilding them cold. `false` restores the discard-everything
    /// restart (cold-rebuild baseline for the `restart/*` bench rows).
    pub warm_restart: bool,
    /// Within a warm restart, re-derive the greedy argsort by remapping
    /// the surviving permutation (the fast path) rather than re-sorting
    /// from scratch. Both paths produce the identical deterministic
    /// order, so flipping this flag never changes a bit of the
    /// trajectory — the determinism suite certifies exactly that.
    pub argsort_remap: bool,
    /// Worker threads for the **pooled monolithic greedy oracle**
    /// (`0` = all available cores, `1` = sequential — the default). At
    /// `t > 1` the engine parks one persistent
    /// [`WorkerPool`](crate::runtime::pool::WorkerPool) of `t − 1`
    /// workers and installs it into the solver's greedy workspace; every
    /// oracle pass then fans its bandwidth-bound inner loops (dense
    /// kernel-cut accumulator sweeps, high-degree cut adjacency walks)
    /// across the pool plus the engine thread. Pooled passes are
    /// **bit-identical** to sequential ones for every thread count
    /// (fixed chunk grids + fixed-order chunk reductions — the same
    /// discipline as the block solver's rounds), so this knob never
    /// changes a trajectory. Ignored for caller-provided solvers
    /// ([`IaesEngine::with_solver`]) — the block solver owns its own
    /// pool and reports `block_threads` instead.
    pub threads: usize,
    /// Cooperative cancellation: when set, the engine polls the token
    /// **once per major iteration, at the iteration boundary** (before
    /// the greedy pass) and stops early with a *partial* report —
    /// `converged: false`, [`IaesReport::cancel_reason`] set, and every
    /// element screened so far still reported (certificates fired before
    /// the stop remain Lemma-2/3 safe). A token that never fires is
    /// bitwise inert: the trajectory is identical to `cancel: None`.
    pub cancel: Option<CancelToken>,
    /// Caller-owned worker pool for the pooled monolithic greedy oracle:
    /// when set (and `threads` would permit pooling, i.e. the solve is
    /// monolithic), the engine installs this pool instead of parking a
    /// fresh one, and reports `greedy_threads = size() + 1`. This is the
    /// serve-mode resident-pool path — one persistent pool per serve
    /// worker, reused across jobs, rebuilt only after a contained panic.
    pub oracle_pool: Option<Arc<WorkerPool>>,
    /// Boundary-sampled solve telemetry: when set, the engine records one
    /// fixed-size [`TraceEvent`] into this sink at every major-iteration
    /// boundary — the same boundary discipline as `cancel`, where the
    /// dual is a valid point of B(F̂) — with per-phase wall clocks
    /// drained from the solver. `None` is bitwise inert (not one extra
    /// clock read or branch happens), and an *attached* sink still never
    /// changes a trajectory bit: timing is read-only and the sink is
    /// consulted only between iterations. The determinism suite
    /// certifies both properties.
    pub trace: Option<TraceSink>,
    /// Boundary checkpointing: when set, the engine stores a
    /// [`SolveCheckpoint`] into the attached sink every
    /// `every` major-iteration boundaries — the same boundary
    /// discipline as `cancel`/`trace`, where the dual is a valid point
    /// of B(F̂) and the screened sets are Lemma-2/3 safe, so every
    /// snapshot is a provably safe resume point. `None` is bitwise
    /// inert; an attached-but-not-due sink costs two integer compares
    /// per boundary and allocates nothing (certified by the zero-alloc
    /// suite). Storage errors fail the solve — a run asked to
    /// checkpoint must not silently lose durability.
    pub checkpoint: Option<CheckpointConf>,
}

impl Default for IaesOptions {
    fn default() -> Self {
        IaesOptions {
            eps: 1e-6,
            rho: 0.5,
            rules: RuleSet::all(),
            solver: SolverChoice::default(),
            max_iters: 100_000,
            screener: None,
            record_history: true,
            min_reduction_frac: 0.2,
            warm_restart: true,
            argsort_remap: true,
            threads: 1,
            cancel: None,
            oracle_pool: None,
            trace: None,
            checkpoint: None,
        }
    }
}

impl std::fmt::Debug for IaesOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("IaesOptions")
            .field("eps", &self.eps)
            .field("rho", &self.rho)
            .field("rules", &self.rules)
            .field("solver", &self.solver)
            .field("max_iters", &self.max_iters)
            .field("record_history", &self.record_history)
            .field("min_reduction_frac", &self.min_reduction_frac)
            .field("warm_restart", &self.warm_restart)
            .field("argsort_remap", &self.argsort_remap)
            .field("threads", &self.threads)
            .field("cancel", &self.cancel.is_some())
            .field("oracle_pool", &self.oracle_pool.is_some())
            .field("trace", &self.trace.is_some())
            .field("checkpoint", &self.checkpoint.is_some())
            .finish()
    }
}

/// One screening trigger event.
#[derive(Clone, Debug)]
pub struct TriggerRecord {
    /// Global major-iteration index at which the trigger fired.
    pub iter: usize,
    /// Duality gap at the trigger.
    pub gap: f64,
    /// Residual ground-set size before screening.
    pub p_before: usize,
    /// Newly certified active elements.
    pub new_active: usize,
    /// Newly certified inactive elements.
    pub new_inactive: usize,
    /// Newly certified active elements (original ids) — drives the Figure-3
    /// visualization.
    pub new_active_ids: Vec<usize>,
    /// Newly certified inactive elements (original ids).
    pub new_inactive_ids: Vec<usize>,
    /// Time spent inside the screening rules (this trigger).
    pub screen_time: Duration,
}

/// Per-iteration history row (rejection-ratio curves).
#[derive(Clone, Copy, Debug)]
pub struct IterRecord {
    /// Global major-iteration index (1-based).
    pub iter: usize,
    /// Duality gap after the iteration.
    pub gap: f64,
    /// Cumulative certified-active count.
    pub active: usize,
    /// Cumulative certified-inactive count.
    pub inactive: usize,
    /// Residual problem size.
    pub p_remaining: usize,
}

/// Final report of a screened solve.
#[derive(Clone, Debug)]
pub struct IaesReport {
    /// The minimizer `A*` (original ids, sorted).
    pub minimizer: Vec<usize>,
    /// `F(A*)`.
    pub minimum: f64,
    /// Total major iterations across all restarts.
    pub iters: usize,
    /// Final duality gap on the residual problem (0 if emptied).
    pub final_gap: f64,
    /// Elements certified active by screening (excludes sign-decided ones).
    pub screened_active: usize,
    /// Elements certified inactive by screening.
    pub screened_inactive: usize,
    /// Trigger log.
    pub triggers: Vec<TriggerRecord>,
    /// Per-iteration history (empty unless `record_history`).
    pub history: Vec<IterRecord>,
    /// Wall time inside the solver (greedy + updates).
    pub solver_time: Duration,
    /// Wall time inside the screening rules.
    pub screen_time: Duration,
    /// True when screening emptied the ground set before the gap hit ε.
    pub emptied: bool,
    /// True when the run actually reached its stopping criterion (gap
    /// below ε, or the ground set emptied). False when the `max_iters`
    /// cap tripped first: the leftover elements were then sign-decided
    /// from an *unconverged* primal and the minimizer may be wrong —
    /// callers must surface this instead of reporting silently.
    pub converged: bool,
    /// Resolved worker-thread count of the decomposable block solver
    /// (`Some` for [`solve_decomposed`](crate::decompose::solve_decomposed)
    /// runs, `None` for monolithic solves). Surfaced in the JSON report
    /// so `--decompose` runs record the parallelism they actually used.
    pub block_threads: Option<usize>,
    /// Resolved thread count of the pooled monolithic greedy oracle:
    /// `Some(t)` when [`IaesOptions::threads`] resolved to `t ≥ 2` and a
    /// pool was parked for the run (oracles fan out over it once the
    /// problem is large enough to pay for a dispatch), `None` for
    /// sequential and decomposed solves. Surfaced in the JSON report
    /// exactly like `block_threads`, so `solve --threads N` runs record
    /// the parallelism they actually used.
    pub greedy_threads: Option<usize>,
    /// Why the solve stopped early, when it did: `Some` exactly when a
    /// [`CancelToken`] fired (deadline or explicit cancel) at a
    /// major-iteration boundary. Such a report is *partial* —
    /// `converged` is false and the minimizer is sign-decided from an
    /// unconverged primal — but `screened_active`/`screened_inactive`
    /// and the trigger log remain safe: every certificate fired before
    /// the stop is a valid Lemma-2/3 certificate.
    pub cancel_reason: Option<CancelReason>,
    /// Boundary-sampled telemetry totals: `Some` exactly when
    /// [`IaesOptions::trace`] was attached. Running sums over *every*
    /// recorded event (exact even after the ring wrapped) plus the
    /// pooled monolithic oracle's fork-join dispatch delta for this run.
    pub trace: Option<TraceSummary>,
}

impl IaesReport {
    /// Rejection ratio `(m_i + n_i)/p` at the final iteration.
    pub fn final_rejection_ratio(&self, p: usize) -> f64 {
        (self.screened_active + self.screened_inactive) as f64 / p as f64
    }
}

/// The Algorithm-2 engine.
pub struct IaesEngine<'a> {
    f: &'a dyn Submodular,
    opts: IaesOptions,
    /// Certified-active original ids.
    active: Vec<usize>,
    /// Certified-inactive original ids.
    inactive: Vec<usize>,
    /// Residual original ids (V̂).
    kept: Vec<usize>,
    /// Caller-provided solver (decomposed solves); `None` → built from
    /// `opts.solver`.
    solver_override: Option<Box<dyn ProxSolver + 'a>>,
    /// Boundary snapshot to resume from ([`resume_from`](Self::resume_from)).
    resume: Option<SolveCheckpoint>,
}

impl<'a> IaesEngine<'a> {
    /// Create an engine for `f`.
    pub fn new(f: &'a dyn Submodular, opts: IaesOptions) -> Self {
        let p = f.ground_size();
        IaesEngine {
            f,
            opts,
            active: Vec::new(),
            inactive: Vec::new(),
            kept: (0..p).collect(),
            solver_override: None,
            resume: None,
        }
    }

    /// Arm the engine to resume from a boundary snapshot instead of
    /// starting cold: the snapshot's fixed active/inactive sets, survivor
    /// map, pending certificates, restricted primal, and solver dual
    /// state are all re-installed, and `run()` continues the solve from
    /// iteration `ck.iter`. Solver atoms are regenerated by replaying
    /// their stored greedy orders on the reduced oracle (never
    /// coordinate-projected), then the gap is re-closed against the
    /// rebuilt corral — so the resumed screening radius is valid and
    /// every certificate in the snapshot stays Lemma-2/3 safe.
    pub fn resume_from(mut self, ck: SolveCheckpoint) -> anyhow::Result<Self> {
        ck.validate()?;
        anyhow::ensure!(
            ck.p_total == self.f.ground_size(),
            "checkpoint is for a {}-element problem, this one has {}",
            ck.p_total,
            self.f.ground_size()
        );
        self.active = ck.active.clone();
        self.inactive = ck.inactive.clone();
        self.kept = ck.kept.clone();
        self.resume = Some(ck);
        Ok(self)
    }

    /// Create an engine that drives a caller-provided solver instead of
    /// building one from `opts.solver` — the entry point for solvers that
    /// need structure beyond the `&dyn Submodular` the engine passes
    /// around (the decomposable block solver borrows the underlying
    /// [`DecomposableFn`](crate::decompose::DecomposableFn) directly).
    ///
    /// The solver must already be initialized on the full problem `f`
    /// (constructors of the [`ProxSolver`] implementations do this). If
    /// the solver has no cold reduced-problem rebuild path (the block
    /// solver does not), run with `warm_restart = true` so reductions
    /// arrive through `reset_mapped`.
    pub fn with_solver(
        f: &'a dyn Submodular,
        opts: IaesOptions,
        solver: Box<dyn ProxSolver + 'a>,
    ) -> Self {
        let mut engine = Self::new(f, opts);
        engine.solver_override = Some(solver);
        engine
    }

    /// Run Algorithm 2 to completion.
    pub fn run(mut self) -> anyhow::Result<IaesReport> {
        let p_total = self.f.ground_size();
        anyhow::ensure!(p_total > 0, "empty ground set");
        anyhow::ensure!(
            self.opts.rho > 0.0 && self.opts.rho < 1.0,
            "rho must lie in (0,1)"
        );
        let screener: Arc<dyn Screener> = self
            .opts
            .screener
            .clone()
            .unwrap_or_else(|| Arc::new(RustScreener::default()));

        let mut triggers = Vec::new();
        let mut history = Vec::new();
        let mut solver_time = Duration::ZERO;
        let mut screen_time = Duration::ZERO;
        let mut total_iters = 0usize;
        let mut final_gap = f64::INFINITY;
        let mut emptied = false;
        let mut converged = true;
        let mut cancel_reason = None;
        let cancel = self.opts.cancel.clone();
        let trace = self.opts.trace.clone();
        let ckpt = self.opts.checkpoint.clone();

        // Residual primal (kept alive across restarts for warm starts).
        let mut w_restricted: Vec<f64> = vec![0.0; self.kept.len()];
        // Certified-but-not-yet-removed flags, aligned with `kept`.
        let mut pending_a = vec![false; self.kept.len()];
        let mut pending_i = vec![false; self.kept.len()];
        let mut pending_a_count = 0usize;
        let mut pending_i_count = 0usize;
        let mut pending_total = 0usize;

        // Resume injection: `resume_from` already installed the
        // snapshot's element sets, so the reduction below is built at the
        // checkpoint's survivor map. Here the aligned runtime state comes
        // back: iteration count, restricted primal, and the certificates
        // that were pending (certified but not yet contracted) when the
        // snapshot was taken.
        let resume_state = self.resume.take();
        let resumed = resume_state.is_some();
        let resumed_flags = if resumed { tflags::RESUMED } else { 0 };
        let mut skip_restart = resumed;
        let mut last_ckpt_iter = 0usize;
        let mut resume_gate: Option<f64> = None;
        if let Some(ck) = &resume_state {
            total_iters = ck.iter;
            final_gap = ck.gap;
            last_ckpt_iter = ck.iter;
            resume_gate = Some(ck.q_gate);
            w_restricted.clear();
            w_restricted.extend_from_slice(&ck.w);
            for &orig in &ck.pending_active {
                if let Ok(j) = self.kept.binary_search(&orig) {
                    pending_a[j] = true;
                    pending_a_count += 1;
                    pending_total += 1;
                }
            }
            for &orig in &ck.pending_inactive {
                if let Ok(j) = self.kept.binary_search(&orig) {
                    pending_i[j] = true;
                    pending_i_count += 1;
                    pending_total += 1;
                }
            }
        }

        // One ScaledFn and one solver for the whole run: every restart
        // re-targets them in place (set_reduction + reset), so the
        // translation buffers, corral/atom storage, Gram factor, and
        // greedy/PAV/oracle scratch all persist across contractions
        // instead of being rebuilt from scratch.
        let monolithic = self.solver_override.is_none();
        // Survivor map of the most recent contraction (buffer reused for
        // the whole run); `warm_pending` says the map and the
        // already-contracted `scaled` describe the next restart.
        let mut map = crate::lovasz::ContractionMap::new();
        let mut scaled = if resumed && !monolithic {
            // Decomposed resume: rebuild the reduction through the same
            // contraction path a live run takes, so the survivor map is
            // available to bring the caller-provided solver (initialized
            // on the full problem) to the checkpoint's reduction via the
            // ordinary warm-restart machinery.
            let mut s = ScaledFn::new(self.f, &[], (0..p_total).collect());
            map.remap_argsort = self.opts.argsort_remap;
            s.contract(&self.active, &self.kept, &mut map);
            s
        } else {
            ScaledFn::new(self.f, &self.active, self.kept.clone())
        };
        let mut solver: Box<dyn ProxSolver + 'a> = match self.solver_override.take() {
            Some(s) => s,
            None => self.opts.solver.build(&scaled),
        };
        // Pooled monolithic greedy oracle: one persistent parked pool of
        // t − 1 workers for the whole run (the engine thread is the t-th
        // lane). Installed once — the workspace and its pool handle
        // survive every contraction restart. Caller-provided solvers
        // (the decomposable block solver) own their parallelism and are
        // left alone.
        let greedy_threads = if monolithic {
            match &self.opts.oracle_pool {
                // A caller-owned resident pool (serve mode) fixes the
                // lane count: pool workers plus the engine thread.
                Some(pool) => pool.size() + 1,
                None => {
                    let t = match self.opts.threads {
                        0 => std::thread::available_parallelism()
                            .map(|n| n.get())
                            .unwrap_or(1),
                        t => t,
                    };
                    t.max(1)
                }
            }
        } else {
            1
        };
        let oracle_pool = if monolithic && greedy_threads > 1 {
            let pool = match self.opts.oracle_pool.clone() {
                Some(pool) => pool,
                None => Arc::new(WorkerPool::new(greedy_threads - 1)),
            };
            solver.set_pool(Some(Arc::clone(&pool)));
            Some(pool)
        } else {
            None
        };
        // Telemetry arming: flipping the solver's phase clocks on is the
        // only per-run setup tracing needs. The clocks are read-only —
        // their values never feed back into an iterate — so an attached
        // sink cannot change a trajectory bit; with `trace: None` this
        // whole layer is dead code (not even the `Instant` reads happen).
        if trace.is_some() {
            solver.set_trace_timing(true);
        }
        let pool_dispatch_base = oracle_pool.as_ref().map_or(0, |p| p.dispatches());
        // Contract/restart wall-nanos accumulated since the last recorded
        // event: the solver restart runs *after* its contraction event was
        // recorded, so its cost carries into the next boundary's span.
        let mut carry_contract_ns: u64 = 0;
        // Persistent contraction buffers: `survivors`/`w_surv` double-
        // buffer against `kept`/`w_restricted` via swap, so a contraction
        // allocates nothing once the run's high-water capacity is reached.
        let mut survivors: Vec<usize> = Vec::with_capacity(self.kept.len());
        let mut w_surv: Vec<f64> = Vec::with_capacity(self.kept.len());
        let mut warm_pending = false;
        // Resume, final leg: re-install the solver's dual state at the
        // checkpoint's reduction. Atoms are regenerated by replaying
        // their stored greedy orders on the reduced oracle (`restore` —
        // the regeneration invariant, never a coordinate projection) and
        // the gap is re-closed against the rebuilt corral. A snapshot
        // with no solver state (plain FW) falls back to the cold step-14
        // reset, which is always safe.
        if let Some(ck) = &resume_state {
            if !monolithic {
                // Bring the caller-provided solver (initialized on the
                // full problem) to the checkpoint's reduction first.
                solver.reset_mapped(&scaled, &w_restricted, &map);
            }
            match &ck.solver {
                Some(state) => solver
                    .restore(&scaled, &w_restricted, state)
                    .map_err(|e| e.context("resuming solver state from checkpoint"))?,
                None => {
                    if monolithic {
                        solver.reset(&scaled, &w_restricted);
                    }
                }
            }
        }
        'outer: while !self.kept.is_empty() {
            if total_iters > 0 && !std::mem::take(&mut skip_restart) {
                // Restart from the restricted primal (step 14): warm —
                // solver state projected through the contraction — or the
                // cold rebuild when warm restarts are disabled.
                let t_r = trace.is_some().then(Instant::now);
                if warm_pending {
                    solver.reset_mapped(&scaled, &w_restricted, &map);
                } else {
                    scaled.set_reduction(&self.active, &self.kept);
                    solver.reset(&scaled, &w_restricted);
                }
                warm_pending = false;
                if let Some(t_r) = t_r {
                    // The restart's greedy pass is already inside this
                    // wall span; drain the solver's phase clocks so it
                    // cannot leak into the next step's greedy/prox split.
                    let _ = solver.take_phase_ns();
                    carry_contract_ns += t_r.elapsed().as_nanos() as u64;
                }
            }
            let f_v = scaled.eval_full();
            let mut q_gate = solver.gap(); // gap at last trigger (q in Alg. 2)
            if !q_gate.is_finite() {
                q_gate = f64::INFINITY;
            }
            if let Some(gate) = resume_gate.take() {
                // The checkpointed trigger gate survives the resume so
                // the screening cadence picks up where it left off; a
                // smaller gate only makes screening fire sooner, which
                // is always safe.
                if gate.is_finite() {
                    q_gate = q_gate.min(gate);
                }
            }

            loop {
                // Cancellation boundary: between major iterations the dual
                // is a valid point of B(F̂), so stopping here keeps every
                // certificate fired so far Lemma-2/3 safe. The leftovers
                // are sign-decided from the current (unconverged) primal
                // and the report is flagged partial via `cancel_reason`.
                if let Some(reason) = cancel.as_ref().and_then(|c| c.check()) {
                    converged = false;
                    cancel_reason = Some(reason);
                    w_restricted.clear();
                    w_restricted.extend_from_slice(solver.w());
                    if let Some(sink) = trace.as_ref() {
                        // No step ran this boundary: gap/radius are the
                        // last step's, primal/dual unknown (→ null).
                        let mut flags =
                            tflags::CANCELLED | tflags::FINAL | resumed_flags;
                        if reason == CancelReason::DeadlineExpired {
                            flags |= tflags::DEADLINE;
                        }
                        sink.record(&TraceEvent {
                            iter: total_iters as u64,
                            flags,
                            primal: f64::NAN,
                            dual: f64::NAN,
                            gap: final_gap,
                            radius: (2.0 * final_gap).sqrt(),
                            active: (self.active.len() + pending_a_count) as u32,
                            inactive: (self.inactive.len() + pending_i_count) as u32,
                            survivors: self.kept.len() as u32,
                            contract_ns: std::mem::take(&mut carry_contract_ns),
                            ..TraceEvent::default()
                        });
                    }
                    break 'outer;
                }
                // Checkpoint boundary: the dual is a valid point of
                // B(F̂), the gap is a valid screening radius, and every
                // certificate so far is Lemma-2/3 safe — exactly the
                // state a resume needs. Due-check first: an attached but
                // not-due sink costs two integer compares and allocates
                // nothing (the zero-alloc suite certifies this).
                if let Some(conf) = ckpt.as_ref() {
                    if total_iters > last_ckpt_iter
                        && total_iters % conf.every.max(1) == 0
                    {
                        last_ckpt_iter = total_iters;
                        let mut pending_active = Vec::new();
                        let mut pending_inactive = Vec::new();
                        for (j, &orig) in self.kept.iter().enumerate() {
                            if pending_a[j] {
                                pending_active.push(orig);
                            } else if pending_i[j] {
                                pending_inactive.push(orig);
                            }
                        }
                        conf.sink.store(SolveCheckpoint {
                            iter: total_iters,
                            p_total,
                            active: self.active.clone(),
                            inactive: self.inactive.clone(),
                            kept: self.kept.clone(),
                            pending_active,
                            pending_inactive,
                            w: solver.w().to_vec(),
                            gap: solver.gap(),
                            q_gate,
                            solver: solver.export_state(),
                        })?;
                    }
                }
                failpoint::hit("iaes-iter");
                let t0 = Instant::now();
                let ev = solver.step(&scaled);
                let step_dt = t0.elapsed();
                solver_time += step_dt;
                total_iters += 1;
                // Non-finite guard: a NaN/∞ gap makes the Theorem-3
                // screening radius meaningless, so screening from it would
                // be unsafe — fail the job with a typed error instead.
                let gap = failpoint::eval_f64("iaes-gap", ev.gap);
                if !gap.is_finite() {
                    return Err(NumericFault {
                        what: "duality gap".into(),
                        iter: total_iters,
                    }
                    .into());
                }
                final_gap = gap;
                // Boundary telemetry: one fixed-size stack event per
                // major iteration, phase clocks drained exactly once per
                // step so greedy/prox attribution stays per-boundary.
                // Nothing here escapes unless a sink is attached.
                let mut tev = TraceEvent::default();
                if trace.is_some() {
                    tev.flags = resumed_flags;
                    let ph = solver.take_phase_ns();
                    let step_ns = step_dt.as_nanos() as u64;
                    tev.iter = total_iters as u64;
                    tev.primal = ev.primal_value;
                    tev.dual = ev.dual_value;
                    tev.gap = gap;
                    tev.radius = (2.0 * gap).sqrt();
                    tev.greedy_ns = ph.oracle_ns.min(step_ns);
                    tev.prox_ns = step_ns.saturating_sub(ph.oracle_ns);
                    tev.kind_ns = ph.kind_ns;
                    tev.contract_ns = std::mem::take(&mut carry_contract_ns);
                }

                if self.opts.record_history {
                    history.push(IterRecord {
                        iter: total_iters,
                        gap,
                        active: self.active.len() + pending_a_count,
                        inactive: self.inactive.len() + pending_i_count,
                        p_remaining: self.kept.len(),
                    });
                }
                if gap < self.opts.eps || total_iters >= self.opts.max_iters {
                    // Capture the final restricted primal: the leftover
                    // elements are decided by its sign (Alg. 2, line 19),
                    // except the ones already certified. A max-iters trip
                    // decides them from an unconverged primal — flag it.
                    converged = gap < self.opts.eps;
                    w_restricted.clear();
                    w_restricted.extend_from_slice(solver.w());
                    if let Some(sink) = trace.as_ref() {
                        tev.flags |= tflags::FINAL;
                        tev.active = (self.active.len() + pending_a_count) as u32;
                        tev.inactive = (self.inactive.len() + pending_i_count) as u32;
                        tev.survivors = self.kept.len() as u32;
                        sink.record(&tev);
                    }
                    break 'outer;
                }

                let should_screen = !self.opts.rules.is_empty()
                    && gap < self.opts.rho * q_gate;
                if !should_screen {
                    if let Some(sink) = trace.as_ref() {
                        tev.active = (self.active.len() + pending_a_count) as u32;
                        tev.inactive = (self.inactive.len() + pending_i_count) as u32;
                        tev.survivors = self.kept.len() as u32;
                        sink.record(&tev);
                    }
                    continue;
                }

                // ---- Screening trigger (steps 6–15) ----
                if solver.w().iter().any(|v| !v.is_finite()) {
                    return Err(NumericFault {
                        what: "primal iterate".into(),
                        iter: total_iters,
                    }
                    .into());
                }
                let t1 = Instant::now();
                let inputs = ScreenInputs {
                    w: solver.w(),
                    gap,
                    f_v,
                    f_c: solver.best_level_value(),
                };
                let outcome = screener.screen(&inputs, self.opts.rules);
                let dt = t1.elapsed();
                screen_time += dt;

                // New certificates = fired rules minus already-pending.
                let mut new_active_ids = Vec::new();
                let mut new_inactive_ids = Vec::new();
                for (j, &orig) in self.kept.iter().enumerate() {
                    if pending_a[j] || pending_i[j] {
                        continue;
                    }
                    if outcome.active[j] {
                        pending_a[j] = true;
                        pending_a_count += 1;
                        pending_total += 1;
                        new_active_ids.push(orig);
                    } else if outcome.inactive[j] {
                        pending_i[j] = true;
                        pending_i_count += 1;
                        pending_total += 1;
                        new_inactive_ids.push(orig);
                    }
                }
                triggers.push(TriggerRecord {
                    iter: total_iters,
                    gap,
                    p_before: self.kept.len(),
                    new_active: new_active_ids.len(),
                    new_inactive: new_inactive_ids.len(),
                    new_active_ids,
                    new_inactive_ids,
                    screen_time: dt,
                });
                q_gate = gap;
                if trace.is_some() {
                    let last = triggers.last().expect("trigger just pushed");
                    tev.flags |= tflags::SCREEN;
                    tev.screen_ns = dt.as_nanos() as u64;
                    tev.new_active = last.new_active as u32;
                    tev.new_inactive = last.new_inactive as u32;
                }

                // Contract only when the batch is worth a solver restart
                // (Remark 4 cost/benefit; min_reduction_frac = 0 restarts
                // on every certificate, the literal Algorithm 2).
                let threshold = (self.opts.min_reduction_frac
                    * self.kept.len() as f64)
                    .ceil()
                    .max(1.0) as usize;
                if pending_total == 0
                    || (pending_total < threshold && pending_total < self.kept.len())
                {
                    if let Some(sink) = trace.as_ref() {
                        tev.active = (self.active.len() + pending_a_count) as u32;
                        tev.inactive = (self.inactive.len() + pending_i_count) as u32;
                        tev.survivors = self.kept.len() as u32;
                        sink.record(&tev);
                    }
                    continue;
                }
                let t_c = trace.is_some().then(Instant::now);

                // Contract the ground set: move pending certificates out.
                // All buffers are persistent: survivors/w_surv refill and
                // then swap with kept/w_restricted, the pending flags
                // shrink in place (resize-down never allocates).
                let n_active_before = self.active.len();
                let w_now = solver.w();
                survivors.clear();
                w_surv.clear();
                for (j, &orig) in self.kept.iter().enumerate() {
                    if pending_a[j] {
                        self.active.push(orig);
                    } else if pending_i[j] {
                        self.inactive.push(orig);
                    } else {
                        survivors.push(orig);
                        w_surv.push(w_now[j]);
                    }
                }
                if self.opts.warm_restart {
                    // Thread the survivor map through the reduction: the
                    // scaled oracle re-targets incrementally and the next
                    // solver restart projects its state through `map`.
                    map.remap_argsort = self.opts.argsort_remap;
                    scaled.contract(
                        &self.active[n_active_before..],
                        &survivors,
                        &mut map,
                    );
                    warm_pending = true;
                }
                std::mem::swap(&mut self.kept, &mut survivors);
                std::mem::swap(&mut w_restricted, &mut w_surv);
                pending_a.clear();
                pending_a.resize(self.kept.len(), false);
                pending_i.clear();
                pending_i.resize(self.kept.len(), false);
                pending_a_count = 0;
                pending_i_count = 0;
                pending_total = 0;

                if self.kept.is_empty() {
                    emptied = true;
                    final_gap = 0.0;
                }
                if let Some(sink) = trace.as_ref() {
                    if let Some(t_c) = t_c {
                        tev.contract_ns += t_c.elapsed().as_nanos() as u64;
                    }
                    tev.flags |= tflags::CONTRACTION;
                    if self.kept.is_empty() {
                        tev.flags |= tflags::EMPTIED | tflags::FINAL;
                    } else if warm_pending {
                        tev.flags |= tflags::WARM_RESTART;
                    } else {
                        tev.flags |= tflags::COLD_RESTART;
                    }
                    tev.active = self.active.len() as u32;
                    tev.inactive = self.inactive.len() as u32;
                    tev.survivors = self.kept.len() as u32;
                    sink.record(&tev);
                }
                // Re-target the scaled problem + solver (outer loop).
                continue 'outer;
            }
        }

        // Assemble A* = Ê ∪ {pending-active} ∪ {ŵ > 0 among undecided}:
        // certificates (removed or still pending) take precedence; the
        // leftover elements are decided by sign (Alg. 2, line 19).
        let mut minimizer = self.active.clone();
        let mut screened_active = self.active.len();
        let mut screened_inactive = self.inactive.len();
        if !self.kept.is_empty() {
            debug_assert_eq!(w_restricted.len(), self.kept.len());
            for (j, &orig) in self.kept.iter().enumerate() {
                if pending_a[j] {
                    minimizer.push(orig);
                    screened_active += 1;
                } else if pending_i[j] {
                    screened_inactive += 1;
                } else if w_restricted[j] > 0.0 {
                    minimizer.push(orig);
                }
            }
        }
        minimizer.sort_unstable();
        let minimum = self.f.eval_ids(&minimizer);

        // Fold the pooled oracle's fork-join dispatch delta into the
        // summary: how many greedy passes this run fanned over the pool.
        let trace_summary = trace.as_ref().map(|sink| {
            if let Some(pool) = oracle_pool.as_ref() {
                sink.add_pool_dispatches(
                    pool.dispatches().saturating_sub(pool_dispatch_base),
                );
            }
            sink.summary()
        });

        Ok(IaesReport {
            minimizer,
            minimum,
            iters: total_iters,
            final_gap,
            screened_active,
            screened_inactive,
            triggers,
            history,
            solver_time,
            screen_time,
            emptied,
            converged,
            block_threads: None,
            greedy_threads: (monolithic && greedy_threads > 1).then_some(greedy_threads),
            cancel_reason,
            trace: trace_summary,
        })
    }
}

/// Convenience: run Algorithm 2 on `f` with `opts`.
pub fn solve_sfm_with_screening(
    f: &dyn Submodular,
    opts: &IaesOptions,
) -> anyhow::Result<IaesReport> {
    IaesEngine::new(f, opts.clone()).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brute::brute_force_sfm;
    use crate::rng::Pcg64;
    use crate::submodular::concave_card::ConcaveCardFn;
    use crate::submodular::iwata::IwataFn;
    use crate::submodular::kernel_cut::KernelCutFn;
    use crate::testutil::forall_rng;

    fn random_kernel_cut(p: usize, rng: &mut Pcg64) -> KernelCutFn {
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let unary = rng.uniform_vec(p, -2.0, 2.0);
        KernelCutFn::new(p, k, unary)
    }

    #[test]
    fn iaes_finds_minimum_iwata() {
        let f = IwataFn::new(20);
        let report = solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
        let brute = brute_force_sfm(&f, 1e-9);
        assert!((report.minimum - brute.minimum).abs() < 1e-7,
            "IAES minimum {} vs brute {}", report.minimum, brute.minimum);
    }

    #[test]
    fn iaes_safe_on_random_kernel_cuts() {
        forall_rng(10, |rng| {
            let p = 6 + rng.below(8);
            let f = random_kernel_cut(p, rng);
            let brute = brute_force_sfm(&f, 1e-7);
            let report = solve_sfm_with_screening(&f, &IaesOptions::default())
                .map_err(|e| e.to_string())?;
            if (report.minimum - brute.minimum).abs() > 1e-6 {
                return Err(format!(
                    "not a minimizer: {} vs {}",
                    report.minimum, brute.minimum
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn screening_identifies_everything_eventually() {
        // The paper's headline property: the residual problem size can
        // reach zero. With a tight eps the engine should empty or decide
        // every element on a well-separated instance.
        let mut m = vec![3.0; 15];
        for (i, v) in m.iter_mut().enumerate() {
            if i % 3 == 0 {
                *v = -3.0;
            }
        }
        let f = ConcaveCardFn::sqrt(15, 1.0, m);
        let opts = IaesOptions { eps: 1e-12, ..Default::default() };
        let report = solve_sfm_with_screening(&f, &opts).unwrap();
        assert!(
            report.screened_active + report.screened_inactive > 0,
            "screening identified nothing"
        );
        let brute = brute_force_sfm(&f, 1e-9);
        assert!((report.minimum - brute.minimum).abs() < 1e-7);
    }

    #[test]
    fn aes_and_ies_subsets_are_safe() {
        forall_rng(6, |rng| {
            let p = 6 + rng.below(6);
            let f = random_kernel_cut(p, rng);
            let brute = brute_force_sfm(&f, 1e-7);
            for rules in [RuleSet::aes_only(), RuleSet::ies_only(), RuleSet::pair1_only(), RuleSet::pair2_only()] {
                let opts = IaesOptions { rules, ..Default::default() };
                let report =
                    solve_sfm_with_screening(&f, &opts).map_err(|e| e.to_string())?;
                if (report.minimum - brute.minimum).abs() > 1e-6 {
                    return Err(format!(
                        "rules {rules:?} broke correctness: {} vs {}",
                        report.minimum, brute.minimum
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn screened_elements_respect_lattice() {
        // Every screened-active element must be in the minimal minimizer's
        // closure (i.e. in EVERY minimizer ⊇ minimal); every screened-
        // inactive element must be outside the maximal minimizer.
        forall_rng(8, |rng| {
            let p = 6 + rng.below(7);
            let f = random_kernel_cut(p, rng);
            let brute = brute_force_sfm(&f, 1e-7);
            let opts = IaesOptions { eps: 1e-10, ..Default::default() };
            let report =
                solve_sfm_with_screening(&f, &opts).map_err(|e| e.to_string())?;
            // Reconstruct which ids were certified (need engine internals:
            // rerun manually to capture). Simpler: certified sets are
            // implied by the minimizer only when everything is certified;
            // here we check the final minimizer is sandwiched.
            for &a in &report.minimizer {
                if !brute.maximal.contains(&a) {
                    return Err(format!("element {a} outside maximal minimizer"));
                }
            }
            for &m in &brute.minimal {
                if !report.minimizer.contains(&m) {
                    return Err(format!("minimal-minimizer element {m} missing"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn no_screening_matches_plain_solver() {
        let f = IwataFn::new(16);
        let opts = IaesOptions { rules: RuleSet::none(), ..Default::default() };
        let report = solve_sfm_with_screening(&f, &opts).unwrap();
        let brute = brute_force_sfm(&f, 1e-9);
        assert!((report.minimum - brute.minimum).abs() < 1e-7);
        assert!(report.triggers.is_empty());
        assert_eq!(report.screened_active + report.screened_inactive, 0);
    }

    #[test]
    fn frank_wolfe_solver_choice_works() {
        let f = IwataFn::new(14);
        let opts = IaesOptions {
            solver: SolverChoice::FrankWolfe(FwOptions::default()),
            max_iters: 20_000,
            ..Default::default()
        };
        let report = solve_sfm_with_screening(&f, &opts).unwrap();
        let brute = brute_force_sfm(&f, 1e-9);
        assert!((report.minimum - brute.minimum).abs() < 1e-6);
    }

    #[test]
    fn history_is_recorded_and_monotone() {
        let f = IwataFn::new(18);
        let report = solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
        assert!(!report.history.is_empty());
        let mut last = 0usize;
        for rec in &report.history {
            let ident = rec.active + rec.inactive;
            assert!(ident >= last, "identified count decreased");
            last = ident;
        }
    }

    #[test]
    fn rho_validation() {
        let f = IwataFn::new(5);
        let opts = IaesOptions { rho: 1.5, ..Default::default() };
        assert!(solve_sfm_with_screening(&f, &opts).is_err());
    }

    #[test]
    fn pooled_threads_are_reported_and_never_change_the_answer() {
        // p = 140 is large enough for the pooled kernel-cut superblock
        // path to actually engage; the full reports must agree with the
        // sequential run bit for bit (pooled oracle passes are exact).
        // Weak coupling + strong unaries keep the solve fast and the
        // screening rules productive.
        let p = 140;
        let mut rng = Pcg64::seeded(4040);
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 0.15);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let f = KernelCutFn::new(p, k, rng.uniform_vec(p, -3.0, 3.0));
        let base = IaesOptions { eps: 1e-8, ..Default::default() };
        let seq = solve_sfm_with_screening(&f, &base).unwrap();
        assert_eq!(seq.greedy_threads, None, "sequential runs report no pool");
        let pooled =
            solve_sfm_with_screening(&f, &IaesOptions { threads: 3, ..base }).unwrap();
        assert_eq!(pooled.greedy_threads, Some(3), "resolved count must surface");
        assert_eq!(pooled.minimum.to_bits(), seq.minimum.to_bits());
        assert_eq!(pooled.minimizer, seq.minimizer);
        assert_eq!(pooled.iters, seq.iters);
        assert_eq!(pooled.final_gap.to_bits(), seq.final_gap.to_bits());
    }

    #[test]
    fn converged_flag_reflects_termination() {
        let f = IwataFn::new(16);
        let report = solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
        assert!(report.converged, "normal run must report convergence");
        // A starved iteration budget must be reported, not hidden.
        let opts = IaesOptions { max_iters: 2, eps: 1e-14, ..Default::default() };
        let report = solve_sfm_with_screening(&f, &opts).unwrap();
        assert!(!report.converged, "max-iters exit must clear `converged`");
        assert_eq!(report.iters, 2);
    }

    #[test]
    fn emptied_run_counts_as_converged() {
        let mut m = vec![3.0; 15];
        for (i, v) in m.iter_mut().enumerate() {
            if i % 3 == 0 {
                *v = -3.0;
            }
        }
        let f = ConcaveCardFn::sqrt(15, 1.0, m);
        let opts = IaesOptions { eps: 1e-12, ..Default::default() };
        let report = solve_sfm_with_screening(&f, &opts).unwrap();
        if report.emptied {
            assert!(report.converged);
        }
    }

    #[test]
    fn unfired_cancel_token_is_bitwise_inert() {
        // A token that never fires must not change a bit of the
        // trajectory: the boundary check reads the clock but never the
        // numerics.
        let f = IwataFn::new(18);
        let plain = solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
        let opts = IaesOptions {
            cancel: Some(CancelToken::with_deadline(Duration::from_secs(3600))),
            ..Default::default()
        };
        let tokened = solve_sfm_with_screening(&f, &opts).unwrap();
        assert_eq!(tokened.cancel_reason, None);
        assert!(tokened.converged);
        assert_eq!(tokened.minimum.to_bits(), plain.minimum.to_bits());
        assert_eq!(tokened.minimizer, plain.minimizer);
        assert_eq!(tokened.iters, plain.iters);
        assert_eq!(tokened.final_gap.to_bits(), plain.final_gap.to_bits());
    }

    #[test]
    fn attached_trace_sink_is_bitwise_inert_and_summarizes_the_run() {
        // Tracing is observation only: an attached sink must reproduce
        // the untraced trajectory bit for bit, and the summary must
        // account for every major iteration exactly once.
        let f = IwataFn::new(18);
        let plain = solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
        assert!(plain.trace.is_none(), "untraced runs carry no summary");
        let sink = TraceSink::new();
        let opts = IaesOptions { trace: Some(sink.clone()), ..Default::default() };
        let traced = solve_sfm_with_screening(&f, &opts).unwrap();
        assert_eq!(traced.minimum.to_bits(), plain.minimum.to_bits());
        assert_eq!(traced.minimizer, plain.minimizer);
        assert_eq!(traced.iters, plain.iters);
        assert_eq!(traced.final_gap.to_bits(), plain.final_gap.to_bits());
        let s = traced.trace.expect("traced run must return a summary");
        assert_eq!(s.events, traced.iters as u64, "one event per major iteration");
        assert_eq!(s.dropped, 0);
        assert_eq!(s.screens, traced.triggers.len() as u64);
        let events = sink.snapshot();
        assert_eq!(events.len() as u64, s.events);
        let last = events.last().expect("non-empty trace");
        assert_ne!(last.flags & tflags::FINAL, 0, "last event is terminal");
        assert!(events.iter().all(|e| e.gap.is_finite() && e.iter >= 1));
        // Phase spans accounted: per-event greedy+prox sums match the
        // summary totals (absorbed on push, exact even if wrapped).
        let greedy: u64 = events.iter().map(|e| e.greedy_ns).sum();
        assert_eq!(greedy, s.greedy_ns);
    }

    #[test]
    fn expired_deadline_yields_partial_report() {
        // Deadline already passed: the engine must stop at the very first
        // boundary — zero iterations, empty minimizer machinery intact,
        // partial flags set.
        let f = IwataFn::new(16);
        let opts = IaesOptions {
            cancel: Some(CancelToken::with_deadline(Duration::ZERO)),
            ..Default::default()
        };
        let report = solve_sfm_with_screening(&f, &opts).unwrap();
        assert_eq!(report.cancel_reason, Some(CancelReason::DeadlineExpired));
        assert!(!report.converged);
        assert_eq!(report.iters, 0);
    }

    #[test]
    fn explicit_cancel_yields_partial_report() {
        let f = IwataFn::new(16);
        let token = CancelToken::new();
        token.cancel();
        let opts = IaesOptions { cancel: Some(token), ..Default::default() };
        let report = solve_sfm_with_screening(&f, &opts).unwrap();
        assert_eq!(report.cancel_reason, Some(CancelReason::Cancelled));
        assert!(!report.converged);
        assert_eq!(report.iters, 0);
    }

    #[test]
    fn caller_owned_oracle_pool_is_used_and_reported() {
        // Serve-mode resident pool: same answer as the self-parked pool,
        // greedy_threads derived from the shared pool's size.
        let p = 140;
        let mut rng = Pcg64::seeded(4040);
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 0.15);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let f = KernelCutFn::new(p, k, rng.uniform_vec(p, -3.0, 3.0));
        let base = IaesOptions { eps: 1e-8, ..Default::default() };
        let seq = solve_sfm_with_screening(&f, &base).unwrap();
        let pool = Arc::new(WorkerPool::new(2));
        let shared = IaesOptions { oracle_pool: Some(Arc::clone(&pool)), ..base };
        let pooled = solve_sfm_with_screening(&f, &shared).unwrap();
        assert_eq!(pooled.greedy_threads, Some(3));
        assert_eq!(pooled.minimum.to_bits(), seq.minimum.to_bits());
        assert_eq!(pooled.minimizer, seq.minimizer);
        // The pool is caller-owned: still alive and serviceable after.
        pool.run(&|_| {});
    }

    #[test]
    fn warm_and_cold_restarts_agree_on_the_minimizer() {
        // The projected-corral warm restart changes the trajectory but
        // never the answer: both engines must land on the same minimum on
        // instances that force several contractions.
        forall_rng(6, |rng| {
            let p = 8 + rng.below(5);
            let f = random_kernel_cut(p, rng);
            let base = IaesOptions {
                eps: 1e-9,
                min_reduction_frac: 0.0, // restart on every certificate
                ..Default::default()
            };
            let brute = brute_force_sfm(&f, 1e-7);
            let warm = solve_sfm_with_screening(&f, &base).map_err(|e| e.to_string())?;
            let cold_opts = IaesOptions { warm_restart: false, ..base.clone() };
            let cold =
                solve_sfm_with_screening(&f, &cold_opts).map_err(|e| e.to_string())?;
            // Both must be true minimizers (the minimizer *sets* may
            // legitimately differ when the optimum is not unique).
            if (warm.minimum - brute.minimum).abs() > 1e-6 {
                return Err(format!("warm {} vs brute {}", warm.minimum, brute.minimum));
            }
            if (cold.minimum - brute.minimum).abs() > 1e-6 {
                return Err(format!("cold {} vs brute {}", cold.minimum, brute.minimum));
            }
            Ok(())
        });
    }

    #[test]
    fn attached_checkpoint_sink_is_bitwise_inert() {
        // Checkpoint capture is observation only: an attached sink must
        // reproduce the unchecked trajectory bit for bit, whether the
        // cadence fires every round or never.
        use crate::screening::checkpoint::{CheckpointConf, CheckpointSink};
        let f = IwataFn::new(18);
        let plain = solve_sfm_with_screening(&f, &IaesOptions::default()).unwrap();
        for every in [1usize, 1_000_000] {
            let sink = CheckpointSink::in_memory();
            let opts = IaesOptions {
                checkpoint: Some(CheckpointConf::new(sink.clone(), every)),
                ..Default::default()
            };
            let ckpted = solve_sfm_with_screening(&f, &opts).unwrap();
            assert_eq!(ckpted.minimum.to_bits(), plain.minimum.to_bits());
            assert_eq!(ckpted.minimizer, plain.minimizer);
            assert_eq!(ckpted.iters, plain.iters);
            assert_eq!(ckpted.final_gap.to_bits(), plain.final_gap.to_bits());
            if every == 1 {
                assert!(sink.written() >= 1, "every-round cadence must store");
                let ck = sink.latest().expect("stored checkpoint retrievable");
                ck.validate().expect("stored checkpoint is self-consistent");
                // Byte-stable through the strict JSONL codec.
                let line = ck.to_jsonl();
                let back = SolveCheckpoint::from_jsonl(&line).unwrap();
                assert_eq!(back.to_jsonl(), line);
            } else {
                assert_eq!(sink.written(), 0, "never-due cadence stores nothing");
            }
        }
    }

    #[test]
    fn resume_from_mid_solve_checkpoint_reaches_the_minimizer() {
        // Kill/resume safety at the engine level: truncate a solve at a
        // few major iterations, snapshot the boundary, resume in a fresh
        // engine, and land on the brute-force minimum. The checkpoint's
        // certified sets must be safe (⊆ minimal / ∩ maximal = ∅) and the
        // resumed run must never lose certified elements.
        forall_rng(8, |rng| {
            use crate::screening::checkpoint::{CheckpointConf, CheckpointSink};
            let p = 8 + rng.below(6);
            let f = random_kernel_cut(p, rng);
            let brute = brute_force_sfm(&f, 1e-7);
            let base = IaesOptions { eps: 1e-9, ..Default::default() };
            let cut = 2 + rng.below(4) as usize;
            let sink = CheckpointSink::in_memory();
            let truncated = IaesOptions {
                max_iters: cut,
                checkpoint: Some(CheckpointConf::new(sink.clone(), 1)),
                ..base.clone()
            };
            let partial =
                solve_sfm_with_screening(&f, &truncated).map_err(|e| e.to_string())?;
            let Some(ck) = sink.latest() else {
                // Converged inside the budget before any boundary was due;
                // nothing to resume.
                return Ok(());
            };
            ck.validate().map_err(|e| e.to_string())?;
            // Safety of the snapshotted certificates.
            for &a in &ck.active {
                if !brute.minimal.contains(&a) {
                    return Err(format!("ckpt active {a} outside minimal minimizer"));
                }
            }
            for &i in &ck.inactive {
                if brute.maximal.contains(&i) {
                    return Err(format!("ckpt inactive {i} inside maximal minimizer"));
                }
            }
            // Round-trip through the serialized form, as a real resume would.
            let ck = SolveCheckpoint::from_jsonl(&ck.to_jsonl())
                .map_err(|e| e.to_string())?;
            let resumed = IaesEngine::new(&f, base.clone())
                .resume_from(ck.clone())
                .map_err(|e| e.to_string())?
                .run()
                .map_err(|e| e.to_string())?;
            if (resumed.minimum - brute.minimum).abs() > 1e-6 {
                return Err(format!(
                    "resumed {} vs brute {} (cut at {cut}, partial iters {})",
                    resumed.minimum, brute.minimum, partial.iters
                ));
            }
            if resumed.screened_active < ck.active.len()
                || resumed.screened_inactive < ck.inactive.len()
            {
                return Err(format!(
                    "resumed run lost certified elements: {}/{} < {}/{}",
                    resumed.screened_active,
                    resumed.screened_inactive,
                    ck.active.len(),
                    ck.inactive.len()
                ));
            }
            if resumed.iters < ck.iter {
                return Err(format!(
                    "resumed iteration counter went backwards: {} < {}",
                    resumed.iters, ck.iter
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn resume_rejects_mismatched_problem_size() {
        use crate::screening::checkpoint::{CheckpointConf, CheckpointSink};
        let f = IwataFn::new(12);
        let sink = CheckpointSink::in_memory();
        let opts = IaesOptions {
            max_iters: 3,
            checkpoint: Some(CheckpointConf::new(sink.clone(), 1)),
            ..Default::default()
        };
        solve_sfm_with_screening(&f, &opts).unwrap();
        let ck = sink.latest().expect("boundary stored");
        let g = IwataFn::new(13);
        let err = IaesEngine::new(&g, IaesOptions::default())
            .resume_from(ck)
            .err()
            .expect("size mismatch must be rejected");
        assert!(err.to_string().contains("12-element"), "got: {err}");
    }
}
