//! Checkpoint/resume for IAES solves.
//!
//! A [`SolveCheckpoint`] is captured **only at major-iteration
//! boundaries** — the same points where the cancellation-boundary
//! invariant already makes partial deadline reports safe: the dual
//! iterate is a valid point of `B(F̂)`, the gap is a valid screening
//! radius, and the Lemma-2/3 screened sets are monotone. Snapshot state
//! between boundaries is never observed, so a resume can never see a
//! half-updated corral or an uncertified screening decision.
//!
//! Atoms are stored as their **generating greedy permutations** (the
//! [`SolverState`] convention), never as raw coordinate vectors: resume
//! replays each order on the reduced oracle and obtains vertices of the
//! current base polytope *by construction* — the regeneration invariant
//! that already underpins warm restarts (`reset_mapped`). After the
//! replay, the gap is re-closed against the rebuilt corral so the
//! screening radius stays valid.
//!
//! Serialization is strict JSONL through [`coordinator::json`]
//! (crate-local parser: unknown fields rejected by name, `NaN ↔ null`,
//! versioned header line). See RELIABILITY.md for the format and the
//! boundary-safety argument.
//!
//! [`coordinator::json`]: crate::coordinator::json

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::coordinator::json::Json;
use crate::solvers::{ComponentState, SolverState};

/// Format tag carried by the JSONL header line.
pub const FORMAT: &str = "sfm-checkpoint";
/// Current checkpoint format version; bumped on any schema change.
pub const VERSION: u64 = 1;

/// Boundary snapshot of an IAES solve: everything needed to rebuild a
/// feasible engine + solver state at the checkpoint's reduction. Element
/// ids are **original** (pre-reduction) indices throughout.
#[derive(Clone, Debug, PartialEq)]
pub struct SolveCheckpoint {
    /// Major iterations completed when the snapshot was taken (≥ 1).
    pub iter: usize,
    /// Ground-set size of the original (unreduced) problem.
    pub p_total: usize,
    /// Elements certified in every minimizer (fixed active set).
    pub active: Vec<usize>,
    /// Elements certified in no minimizer (fixed inactive set).
    pub inactive: Vec<usize>,
    /// Surviving (unscreened) elements, ascending — the survivor map.
    pub kept: Vec<usize>,
    /// Certified-active elements awaiting the next contraction batch
    /// (subset of `kept`; certification can precede contraction).
    pub pending_active: Vec<usize>,
    /// Certified-inactive elements awaiting the next contraction batch.
    pub pending_inactive: Vec<usize>,
    /// Restricted primal iterate `ŵ`, one entry per `kept` element.
    pub w: Vec<f64>,
    /// Duality gap at the boundary (the screening radius).
    pub gap: f64,
    /// Gap recorded at the last restart — the `ρ`-trigger gate.
    pub q_gate: f64,
    /// Solver dual state (atoms as generating orders), or `None` when
    /// the solver maintains no replayable decomposition (plain FW):
    /// resume then cold-resets at the checkpoint's reduction, which is
    /// always safe — the screening progress lives in the element sets.
    pub solver: Option<SolverState>,
}

impl SolveCheckpoint {
    /// Serialize to the two-line JSONL document (header + state).
    pub fn to_jsonl(&self) -> String {
        let header = Json::obj(vec![
            ("format", Json::Str(FORMAT.to_string())),
            ("version", Json::Num(VERSION as f64)),
        ]);
        let mut out = header.to_string();
        out.push('\n');
        out.push_str(&self.to_json().to_string());
        out.push('\n');
        out
    }

    /// The state line as a JSON object (no header).
    pub fn to_json(&self) -> Json {
        let solver = match &self.solver {
            None => Json::Null,
            Some(st) => Json::obj(vec![
                ("kind", Json::Str(st.kind.clone())),
                (
                    "orders",
                    Json::Arr(st.orders.iter().map(|o| ids(o)).collect()),
                ),
                ("weights", nums(&st.weights)),
                ("dual", nums(&st.dual)),
                (
                    "components",
                    Json::Arr(
                        st.components
                            .iter()
                            .map(|c| {
                                Json::obj(vec![
                                    ("y", nums(&c.y)),
                                    ("z_prev", nums(&c.z_prev)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        };
        Json::obj(vec![
            ("iter", Json::Num(self.iter as f64)),
            ("p_total", Json::Num(self.p_total as f64)),
            ("active", ids(&self.active)),
            ("inactive", ids(&self.inactive)),
            ("kept", ids(&self.kept)),
            ("pending_active", ids(&self.pending_active)),
            ("pending_inactive", ids(&self.pending_inactive)),
            ("w", nums(&self.w)),
            ("gap", Json::Num(self.gap)),
            ("q_gate", Json::Num(self.q_gate)),
            ("solver", solver),
        ])
    }

    /// Parse a two-line JSONL document. Strict: versioned header
    /// required, unknown fields rejected by name, truncation rejected.
    /// Structural validity only — call [`validate`](Self::validate)
    /// before resuming from the result.
    pub fn from_jsonl(text: &str) -> Result<SolveCheckpoint> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines
            .next()
            .context("empty checkpoint file (missing header line)")?;
        let header =
            Json::parse(header).context("checkpoint header is not valid JSON")?;
        known_fields(&header, &["format", "version"], "checkpoint header")?;
        let format = req(&header, "format", "checkpoint header")?
            .as_str()
            .context("field 'format' in checkpoint header is not a string")?;
        if format != FORMAT {
            bail!("field 'format' is '{format}', expected '{FORMAT}'");
        }
        let version = uint_field(&header, "version", "checkpoint header")?;
        if version as u64 != VERSION {
            bail!("unsupported checkpoint version {version} (expected {VERSION})");
        }
        let state = lines
            .next()
            .context("truncated checkpoint (missing state line)")?;
        if lines.next().is_some() {
            bail!("trailing content after the checkpoint state line");
        }
        let state = Json::parse(state).context("checkpoint state is not valid JSON")?;
        Self::from_json(&state)
    }

    /// Parse the state object (strict, unknown fields rejected by name).
    pub fn from_json(v: &Json) -> Result<SolveCheckpoint> {
        const KNOWN: &[&str] = &[
            "iter",
            "p_total",
            "active",
            "inactive",
            "kept",
            "pending_active",
            "pending_inactive",
            "w",
            "gap",
            "q_gate",
            "solver",
        ];
        known_fields(v, KNOWN, "checkpoint state")?;
        let solver = match req(v, "solver", "checkpoint state")? {
            Json::Null => None,
            sv => Some(parse_solver(sv)?),
        };
        Ok(SolveCheckpoint {
            iter: uint_field(v, "iter", "checkpoint state")?,
            p_total: uint_field(v, "p_total", "checkpoint state")?,
            active: id_array(v, "active")?,
            inactive: id_array(v, "inactive")?,
            kept: id_array(v, "kept")?,
            pending_active: id_array(v, "pending_active")?,
            pending_inactive: id_array(v, "pending_inactive")?,
            w: num_array(req(v, "w", "checkpoint state")?, "w")?,
            gap: num_field(v, "gap")?,
            q_gate: num_field(v, "q_gate")?,
            solver,
        })
    }

    /// Semantic validation: the snapshot must describe a coherent
    /// boundary state before anything resumes from it. Errors name the
    /// offending field.
    pub fn validate(&self) -> Result<()> {
        if self.iter == 0 {
            bail!("field 'iter' must be ≥ 1 (checkpoints exist only at boundaries)");
        }
        if self.p_total == 0 {
            bail!("field 'p_total' must be ≥ 1");
        }
        if self.kept.is_empty() {
            bail!("field 'kept' is empty (an exhausted solve has no boundary state)");
        }
        // active ∪ inactive ∪ kept must partition 0..p_total.
        let mut owner = vec![0u8; self.p_total];
        for (field, set, tag) in [
            ("active", &self.active, 1u8),
            ("inactive", &self.inactive, 2u8),
            ("kept", &self.kept, 3u8),
        ] {
            for &i in set {
                if i >= self.p_total {
                    bail!("field '{field}' holds id {i} ≥ p_total {}", self.p_total);
                }
                if owner[i] != 0 {
                    bail!("element {i} appears in more than one of active/inactive/kept (field '{field}')");
                }
                owner[i] = tag;
            }
        }
        if let Some(i) = owner.iter().position(|&t| t == 0) {
            bail!("element {i} is missing from active/inactive/kept (fields must partition the ground set)");
        }
        for i in 1..self.kept.len() {
            if self.kept[i - 1] >= self.kept[i] {
                bail!("field 'kept' is not strictly ascending");
            }
        }
        for (field, set, want) in [
            ("pending_active", &self.pending_active, 3u8),
            ("pending_inactive", &self.pending_inactive, 3u8),
        ] {
            for &i in set {
                if i >= self.p_total || owner[i] != want {
                    bail!("field '{field}' holds id {i} outside the kept set");
                }
            }
        }
        for i in &self.pending_active {
            if self.pending_inactive.contains(i) {
                bail!("element {i} is in both pending_active and pending_inactive");
            }
        }
        if self.w.len() != self.kept.len() {
            bail!(
                "field 'w' has {} entries for {} kept elements",
                self.w.len(),
                self.kept.len()
            );
        }
        if self.w.iter().any(|x| !x.is_finite()) {
            bail!("field 'w' holds a non-finite entry");
        }
        if !self.gap.is_finite() {
            bail!("field 'gap' is not finite");
        }
        if !self.q_gate.is_finite() {
            bail!("field 'q_gate' is not finite");
        }
        if let Some(st) = &self.solver {
            let p = self.kept.len();
            if st.dual.len() != p {
                bail!(
                    "field 'dual' has {} coordinates for {} kept elements",
                    st.dual.len(),
                    p
                );
            }
            if st.dual.iter().any(|x| !x.is_finite()) {
                bail!("field 'dual' holds a non-finite entry");
            }
            if st.weights.len() != st.orders.len() {
                bail!(
                    "field 'weights' has {} entries for {} orders",
                    st.weights.len(),
                    st.orders.len()
                );
            }
            if st.weights.iter().any(|x| !x.is_finite() || *x < 0.0) {
                bail!("field 'weights' holds a negative or non-finite entry");
            }
            // Orders are validated as permutations only when the solver
            // carries atoms at the engine reduction (components carry
            // their own local orders through best-response regeneration).
            if st.components.is_empty() {
                let mut seen = vec![false; p];
                for order in &st.orders {
                    if order.len() != p {
                        bail!(
                            "field 'orders' holds an order of {} entries for {} kept elements",
                            order.len(),
                            p
                        );
                    }
                    seen.iter_mut().for_each(|s| *s = false);
                    for &j in order {
                        if j >= p || seen[j] {
                            bail!("field 'orders' holds a non-permutation order");
                        }
                        seen[j] = true;
                    }
                }
            }
            for c in &st.components {
                if c.y.iter().any(|x| !x.is_finite()) {
                    bail!("field 'y' holds a non-finite entry");
                }
                if c.z_prev.iter().any(|x| !x.is_finite()) {
                    bail!("field 'z_prev' holds a non-finite entry");
                }
                if c.z_prev.len() != c.y.len() {
                    bail!(
                        "field 'z_prev' has {} entries for a component of {} elements",
                        c.z_prev.len(),
                        c.y.len()
                    );
                }
            }
        }
        Ok(())
    }
}

fn ids(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|&i| Json::Num(i as f64)).collect())
}

fn nums(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

fn known_fields(v: &Json, known: &[&str], what: &str) -> Result<()> {
    let Json::Obj(pairs) = v else {
        bail!("{what} is not a JSON object");
    };
    for (k, _) in pairs {
        if !known.contains(&k.as_str()) {
            bail!("unknown field '{k}' in {what}");
        }
    }
    Ok(())
}

fn req<'a>(v: &'a Json, key: &str, what: &str) -> Result<&'a Json> {
    v.get(key)
        .with_context(|| format!("missing field '{key}' in {what}"))
}

fn num_field(v: &Json, key: &str) -> Result<f64> {
    req(v, key, "checkpoint state")?
        .as_num()
        .with_context(|| format!("field '{key}' is not a number"))
}

fn uint_field(v: &Json, key: &str, what: &str) -> Result<usize> {
    let x = req(v, key, what)?
        .as_num()
        .with_context(|| format!("field '{key}' in {what} is not a number"))?;
    if !x.is_finite() || x < 0.0 || x.fract() != 0.0 {
        bail!("field '{key}' in {what} is not a non-negative integer");
    }
    Ok(x as usize)
}

fn id_array(v: &Json, key: &str) -> Result<Vec<usize>> {
    let arr = req(v, key, "checkpoint state")?
        .as_array()
        .with_context(|| format!("field '{key}' is not an array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for item in arr {
        let x = item
            .as_num()
            .with_context(|| format!("field '{key}' holds a non-numeric entry"))?;
        if !x.is_finite() || x < 0.0 || x.fract() != 0.0 {
            bail!("field '{key}' holds a non-integer entry");
        }
        out.push(x as usize);
    }
    Ok(out)
}

fn num_array(v: &Json, key: &str) -> Result<Vec<f64>> {
    let arr = v
        .as_array()
        .with_context(|| format!("field '{key}' is not an array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for item in arr {
        out.push(
            item.as_num()
                .with_context(|| format!("field '{key}' holds a non-numeric entry"))?,
        );
    }
    Ok(out)
}

fn parse_solver(v: &Json) -> Result<SolverState> {
    known_fields(
        v,
        &["kind", "orders", "weights", "dual", "components"],
        "solver state",
    )?;
    let kind = req(v, "kind", "solver state")?
        .as_str()
        .context("field 'kind' is not a string")?
        .to_string();
    let orders_v = req(v, "orders", "solver state")?
        .as_array()
        .context("field 'orders' is not an array")?;
    let mut orders = Vec::with_capacity(orders_v.len());
    for (i, o) in orders_v.iter().enumerate() {
        let o = o
            .as_array()
            .with_context(|| format!("field 'orders'[{i}] is not an array"))?;
        let mut order = Vec::with_capacity(o.len());
        for item in o {
            let x = item
                .as_num()
                .context("field 'orders' holds a non-numeric entry")?;
            if !x.is_finite() || x < 0.0 || x.fract() != 0.0 {
                bail!("field 'orders' holds a non-integer entry");
            }
            order.push(x as usize);
        }
        orders.push(order);
    }
    let comps_v = req(v, "components", "solver state")?
        .as_array()
        .context("field 'components' is not an array")?;
    let mut components = Vec::with_capacity(comps_v.len());
    for c in comps_v {
        known_fields(c, &["y", "z_prev"], "component state")?;
        components.push(ComponentState {
            y: num_array(req(c, "y", "component state")?, "y")?,
            z_prev: num_array(req(c, "z_prev", "component state")?, "z_prev")?,
        });
    }
    Ok(SolverState {
        kind,
        orders,
        weights: num_array(req(v, "weights", "solver state")?, "weights")?,
        dual: num_array(req(v, "dual", "solver state")?, "dual")?,
        components,
    })
}

/// Checkpoint cadence + destination attached to
/// [`IaesOptions::checkpoint`](crate::screening::iaes::IaesOptions):
/// a snapshot is stored every `every` major-iteration boundaries.
/// `None` on the option is bitwise inert (same discipline as
/// trace/cancel); an attached-but-not-due sink costs two integer
/// compares per boundary and allocates nothing.
#[derive(Clone, Debug)]
pub struct CheckpointConf {
    /// Where snapshots go (in-memory slot, optionally mirrored to disk).
    pub sink: CheckpointSink,
    /// Store every N boundaries (clamped to ≥ 1).
    pub every: usize,
}

impl CheckpointConf {
    /// Sink with the given cadence.
    pub fn new(sink: CheckpointSink, every: usize) -> Self {
        CheckpointConf { sink, every: every.max(1) }
    }
}

/// Destination for boundary snapshots: an in-memory latest-value slot
/// (what the serve-mode retry path resumes from), optionally mirrored to
/// a file via an atomic tmp-then-rename write (what `solve --checkpoint`
/// uses). Cloning shares the slot.
#[derive(Clone, Debug)]
pub struct CheckpointSink {
    inner: Arc<SinkInner>,
}

#[derive(Debug)]
struct SinkInner {
    slot: Mutex<Option<SolveCheckpoint>>,
    written: AtomicU64,
    path: Option<PathBuf>,
}

impl CheckpointSink {
    /// In-memory slot only (serve-mode retries).
    pub fn in_memory() -> Self {
        CheckpointSink {
            inner: Arc::new(SinkInner {
                slot: Mutex::new(None),
                written: AtomicU64::new(0),
                path: None,
            }),
        }
    }

    /// Slot mirrored to `path` on every store (atomic replace: the file
    /// is always a complete, parseable document — a crash mid-store
    /// leaves the previous snapshot intact).
    pub fn to_file(path: impl Into<PathBuf>) -> Self {
        CheckpointSink {
            inner: Arc::new(SinkInner {
                slot: Mutex::new(None),
                written: AtomicU64::new(0),
                path: Some(path.into()),
            }),
        }
    }

    /// Store a snapshot (replacing the previous one). File mirroring
    /// errors propagate — a solve asked to checkpoint must not silently
    /// run without durability.
    pub fn store(&self, ck: SolveCheckpoint) -> Result<()> {
        if let Some(path) = &self.inner.path {
            let mut tmp = path.as_os_str().to_owned();
            tmp.push(".tmp");
            let tmp = PathBuf::from(tmp);
            std::fs::write(&tmp, ck.to_jsonl())
                .with_context(|| format!("writing checkpoint to {}", tmp.display()))?;
            std::fs::rename(&tmp, path).with_context(|| {
                format!("replacing checkpoint at {}", path.display())
            })?;
        }
        let mut slot = match self.inner.slot.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        *slot = Some(ck);
        drop(slot);
        self.inner.written.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// The most recent snapshot, if any.
    pub fn latest(&self) -> Option<SolveCheckpoint> {
        let slot = match self.inner.slot.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        slot.clone()
    }

    /// Snapshots stored over this sink's lifetime.
    pub fn written(&self) -> u64 {
        self.inner.written.load(Ordering::Relaxed)
    }
}

/// Read and strictly parse a checkpoint file, then
/// [`validate`](SolveCheckpoint::validate) it. The `checkpoint-check`
/// subcommand and `solve --resume` both enter here.
pub fn load(path: &std::path::Path) -> Result<SolveCheckpoint> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    let ck = SolveCheckpoint::from_jsonl(&text)
        .with_context(|| format!("parsing checkpoint {}", path.display()))?;
    ck.validate()
        .with_context(|| format!("validating checkpoint {}", path.display()))?;
    Ok(ck)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testutil::forall_rng;

    fn sample(rng: &mut Pcg64, with_solver: bool, with_components: bool) -> SolveCheckpoint {
        let p = 6 + rng.below(10);
        let mut ids: Vec<usize> = (0..p).collect();
        // Random partition: first chunk active, second inactive, rest kept.
        for i in (1..p).rev() {
            let j = rng.below(i + 1);
            ids.swap(i, j);
        }
        let na = rng.below(p / 3 + 1);
        let ni = rng.below(p / 3 + 1);
        let active: Vec<usize> = ids[..na].to_vec();
        let inactive: Vec<usize> = ids[na..na + ni].to_vec();
        let mut kept: Vec<usize> = ids[na + ni..].to_vec();
        kept.sort_unstable();
        let k = kept.len();
        let solver = with_solver.then(|| {
            let m = 1 + rng.below(3);
            let orders: Vec<Vec<usize>> = (0..m)
                .map(|_| {
                    let mut o: Vec<usize> = (0..k).collect();
                    for i in (1..k).rev() {
                        let j = rng.below(i + 1);
                        o.swap(i, j);
                    }
                    o
                })
                .collect();
            let components = if with_components {
                (0..2)
                    .map(|_| ComponentState {
                        y: rng.uniform_vec(3, -1.0, 1.0),
                        z_prev: rng.uniform_vec(3, -1.0, 1.0),
                    })
                    .collect()
            } else {
                Vec::new()
            };
            SolverState {
                kind: "min-norm".into(),
                orders,
                weights: (0..m).map(|_| rng.uniform(0.0, 1.0)).collect(),
                dual: rng.uniform_vec(k, -2.0, 2.0),
                components,
            }
        });
        SolveCheckpoint {
            iter: 1 + rng.below(100),
            p_total: p,
            active,
            inactive,
            kept,
            pending_active: Vec::new(),
            pending_inactive: Vec::new(),
            w: rng.uniform_vec(k, -2.0, 2.0),
            gap: rng.uniform(0.0, 5.0),
            q_gate: rng.uniform(0.0, 5.0),
            solver,
        }
    }

    #[test]
    fn round_trip_is_byte_stable() {
        forall_rng(40, |rng| {
            let with_solver = rng.below(2) == 0;
            let with_components = rng.below(2) == 0;
            let ck = sample(rng, with_solver, with_components);
            ck.validate().map_err(|e| format!("sample invalid: {e}"))?;
            let text = ck.to_jsonl();
            let back = SolveCheckpoint::from_jsonl(&text)
                .map_err(|e| format!("parse failed: {e}"))?;
            if back != ck {
                return Err("value round trip mismatch".into());
            }
            if back.to_jsonl() != text {
                return Err("emit→parse→emit is not byte-stable".into());
            }
            Ok(())
        });
    }

    #[test]
    fn nan_gap_round_trips_through_null() {
        let mut rng = Pcg64::seeded(7);
        let mut ck = sample(&mut rng, false, false);
        ck.gap = f64::NAN;
        let text = ck.to_jsonl();
        assert!(text.contains("\"gap\":null"), "{text}");
        let back = SolveCheckpoint::from_jsonl(&text).expect("parse");
        assert!(back.gap.is_nan());
        assert_eq!(back.to_jsonl(), text, "null NaN emit not byte-stable");
        // ... and semantic validation rejects it by name.
        let err = back.validate().expect_err("NaN gap must not validate");
        assert!(err.to_string().contains("'gap'"), "{err}");
    }

    #[test]
    fn unknown_fields_are_rejected_by_name() {
        let mut rng = Pcg64::seeded(9);
        let ck = sample(&mut rng, true, false);
        let text = ck.to_jsonl();
        let tampered = text.replacen("\"iter\":", "\"itre\":", 1);
        let err = SolveCheckpoint::from_jsonl(&tampered).expect_err("must reject");
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown field 'itre'"), "{msg}");
        let tampered = text.replacen("\"gap\":", "\"gap2\":", 1);
        let err = SolveCheckpoint::from_jsonl(&tampered).expect_err("must reject");
        let msg = format!("{err:#}");
        assert!(msg.contains("'gap2'") || msg.contains("'gap'"), "{msg}");
    }

    #[test]
    fn truncated_and_corrupted_documents_are_rejected() {
        let mut rng = Pcg64::seeded(11);
        let ck = sample(&mut rng, true, true);
        let text = ck.to_jsonl();
        let header_only = text.lines().next().unwrap().to_string();
        let err = SolveCheckpoint::from_jsonl(&header_only).expect_err("truncated");
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
        let err = SolveCheckpoint::from_jsonl("").expect_err("empty");
        assert!(format!("{err:#}").contains("missing header"), "{err:#}");
        // Chop the state line mid-document: not valid JSON.
        let chopped = &text[..text.len() - 10];
        assert!(SolveCheckpoint::from_jsonl(chopped).is_err());
        // Wrong version.
        let wrong = text.replacen("\"version\":1", "\"version\":99", 1);
        let err = SolveCheckpoint::from_jsonl(&wrong).expect_err("version");
        assert!(format!("{err:#}").contains("version 99"), "{err:#}");
        // Wrong format tag.
        let wrong = text.replacen(FORMAT, "sfm-trace", 1);
        assert!(SolveCheckpoint::from_jsonl(&wrong).is_err());
    }

    #[test]
    fn validate_names_partition_violations() {
        let mut rng = Pcg64::seeded(13);
        let mut ck = sample(&mut rng, false, false);
        ck.validate().expect("sample valid");
        let moved = ck.kept[0];
        ck.active.push(moved);
        let err = ck.validate().expect_err("duplicate element");
        assert!(err.to_string().contains("more than one"), "{err}");
        ck.active.pop();
        ck.w.push(0.0);
        let err = ck.validate().expect_err("w length");
        assert!(err.to_string().contains("'w'"), "{err}");
    }

    #[test]
    fn sink_slot_and_file_mirroring() {
        let mut rng = Pcg64::seeded(17);
        let ck = sample(&mut rng, true, false);
        let mem = CheckpointSink::in_memory();
        assert!(mem.latest().is_none());
        assert_eq!(mem.written(), 0);
        mem.store(ck.clone()).expect("store");
        assert_eq!(mem.written(), 1);
        assert_eq!(mem.latest().as_ref(), Some(&ck));

        let dir = std::env::temp_dir().join("sfm_ckpt_test");
        std::fs::create_dir_all(&dir).expect("tmpdir");
        let path = dir.join(format!("ck_{}.jsonl", std::process::id()));
        let file = CheckpointSink::to_file(&path);
        file.store(ck.clone()).expect("store to file");
        let loaded = load(&path).expect("load");
        assert_eq!(loaded, ck);
        std::fs::remove_file(&path).ok();
    }
}
