//! Conditional-gradient solvers for (Q-D) — Remark 2's alternative to the
//! min-norm-point algorithm.
//!
//! Minimizing `½‖x‖²` over `B(F)` with the greedy linear oracle:
//!
//! * **Plain Frank–Wolfe** with exact line search
//!   (`γ* = ⟨x, x−q⟩ / ‖x−q‖²` clipped to `[0,1]`) — O(1/t) convergence.
//! * **Pairwise Frank–Wolfe**: moves mass directly from the worst active
//!   atom to the new greedy atom, which restores linear convergence over
//!   polytopes (Lacoste-Julien & Jaggi 2015) and in practice tracks the
//!   min-norm-point algorithm much more closely.
//!
//! Both variants share the greedy/PAV/gap bookkeeping of
//! [`super::PrimalState`], so the IAES engine can drive either
//! interchangeably (ablation A3 in DESIGN.md).

use super::{PrimalState, ProxSolver, SolverEvent};
use crate::linalg::vecops::{axpy, dot, norm2_sq};
use crate::linalg::{CorralMat, IndexMat};
use crate::lovasz::{vertex_from_order, ContractionMap};
use crate::submodular::Submodular;

/// Frank–Wolfe variant selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FwVariant {
    /// Classic FW with exact line search.
    Plain,
    /// Pairwise FW (atom-to-atom mass transfer).
    Pairwise,
    /// Away-step FW (Guélat–Marcotte; linear rate over polytopes).
    Away,
}

/// Options for [`FrankWolfe`].
#[derive(Clone, Copy, Debug)]
pub struct FwOptions {
    /// Variant to run.
    pub variant: FwVariant,
    /// Atom weights below this are dropped (pairwise only).
    pub weight_tol: f64,
}

impl Default for FwOptions {
    fn default() -> Self {
        FwOptions { variant: FwVariant::Pairwise, weight_tol: 1e-14 }
    }
}

/// FNV-1a over a key (an atom's generating greedy order). The lookup
/// hashes full permutations, so a simple multiplicative hash is plenty —
/// collisions fall back to a key compare within the equal-hash run.
#[inline]
fn hash_key(key: &[usize]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &v in key {
        h ^= v as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Conditional-gradient solver state.
///
/// Atoms live in parallel flat arrays — vertices in a [`CorralMat`],
/// generating orders in an [`IndexMat`] (the interned-key arena), weights
/// and key hashes in plain `Vec`s — so steady-state steps (no atom
/// birth, no eviction) allocate nothing. Atom identity is the generating
/// greedy order (vertices of `B(F)` correspond to permutations; equal
/// orders ⇒ equal vertices), resolved through `lookup`: atom ids sorted
/// by `(hash, id)`, searched by hash and confirmed by key compare. This
/// replaces the old owned-key `HashMap`, whose restart re-keying cloned
/// every surviving key per contraction (ROADMAP item) — the arena re-keys
/// with one in-place [`IndexMat::contract`] sweep and a sort of the id
/// vector, allocation-free at the high-water mark.
pub struct FrankWolfe {
    opts: FwOptions,
    /// Current dual iterate.
    x: Vec<f64>,
    /// Atom vertices (pairwise/away variants), flat row-major.
    atoms: CorralMat,
    /// Atom weights, parallel to `atoms`.
    weights: Vec<f64>,
    /// Generating greedy order of each atom, parallel to `atoms`.
    keys: IndexMat,
    /// FNV-1a hash of each key, parallel to `atoms`.
    hashes: Vec<u64>,
    /// Atom ids sorted by `(hash, id)` — the allocation-free key index.
    lookup: Vec<u32>,
    /// Scratch: surviving-atom indices during eviction compaction.
    keep_buf: Vec<usize>,
    shared: PrimalState,
    q: Vec<f64>,
    dir: Vec<f64>,
}

impl FrankWolfe {
    /// Initialize on `f` from the greedy vertex in direction `w_init`.
    pub fn new(f: &dyn Submodular, opts: FwOptions, w_init: Option<&[f64]>) -> Self {
        let p = f.ground_size();
        let mut solver = FrankWolfe {
            opts,
            x: vec![0.0; p],
            atoms: CorralMat::new(p),
            weights: Vec::new(),
            keys: IndexMat::new(p),
            hashes: Vec::new(),
            lookup: Vec::new(),
            keep_buf: Vec::new(),
            shared: PrimalState::new(p),
            q: vec![0.0; p],
            dir: vec![0.0; p],
        };
        let w0 = match w_init {
            Some(w) => w.to_vec(),
            None => vec![0.0; p],
        };
        solver.reset(f, &w0);
        solver
    }

    /// Number of active atoms (pairwise variant; 0 for plain).
    pub fn num_atoms(&self) -> usize {
        self.weights.len()
    }

    /// Index of the atom whose generating order equals `key`: binary
    /// search on the hash, key compare within the equal-hash run.
    /// Allocation-free.
    fn find_atom(&self, h: u64, key: &[usize]) -> Option<usize> {
        let start = self.lookup.partition_point(|&i| self.hashes[i as usize] < h);
        for &i in &self.lookup[start..] {
            let i = i as usize;
            if self.hashes[i] != h {
                break;
            }
            if self.keys.row(i) == key {
                return Some(i);
            }
        }
        None
    }

    /// Re-sort the atom-id index by `(hash, id)` — one in-place sort of a
    /// `u32` vector, reused across calls (the restart-time replacement
    /// for the old HashMap re-key).
    fn rebuild_lookup(&mut self) {
        self.lookup.clear();
        self.lookup.extend(0..self.weights.len() as u32);
        let hashes = &self.hashes;
        self.lookup.sort_unstable_by_key(|&i| (hashes[i as usize], i));
    }

    /// Add `weight` to the atom whose key is the current greedy order
    /// (which always generated the vertex sitting in `q`), creating the
    /// atom if it is new. Steady state — including atom birth at the
    /// high-water mark — allocates nothing: the key is interned into the
    /// flat [`IndexMat`], not cloned into an owned buffer.
    fn add_current_atom(&mut self, weight: f64) {
        let h = hash_key(&self.shared.greedy_ws.order);
        if let Some(i) = self.find_atom(h, &self.shared.greedy_ws.order) {
            self.weights[i] += weight;
            return;
        }
        let idx = self.weights.len();
        self.keys.push(&self.shared.greedy_ws.order);
        self.hashes.push(h);
        self.atoms.push(&self.q);
        self.weights.push(weight);
        let hashes = &self.hashes;
        let at = self
            .lookup
            .partition_point(|&i| (hashes[i as usize], i as usize) < (h, idx));
        self.lookup.insert(at, idx as u32);
    }

    /// Compact every parallel atom array (weights, hashes, vertices,
    /// keys) down to the atoms whose weight satisfies `keep_if`, then
    /// re-sort the id lookup. One sweep no matter how many atoms die at
    /// once; the survivor index buffer is reused (allocation-free at the
    /// high-water mark).
    fn compact_atoms(&mut self, keep_if: impl Fn(f64) -> bool) {
        let mut keep = std::mem::take(&mut self.keep_buf);
        keep.clear();
        keep.extend(
            self.weights
                .iter()
                .enumerate()
                .filter(|&(_, &w)| keep_if(w))
                .map(|(i, _)| i),
        );
        for (w, &r) in keep.iter().enumerate() {
            self.weights[w] = self.weights[r];
            self.hashes[w] = self.hashes[r];
        }
        self.weights.truncate(keep.len());
        self.hashes.truncate(keep.len());
        self.atoms.compact(&keep);
        self.keys.compact(&keep);
        self.keep_buf = keep;
        self.rebuild_lookup();
    }

    fn drop_tiny_atoms(&mut self) {
        let tol = self.opts.weight_tol;
        if self.weights.iter().all(|&w| w > tol) {
            return;
        }
        // Weights rescale together, so several can cross the tolerance in
        // the same step — one batched compaction handles them all.
        self.compact_atoms(|w| w > tol);
    }

    /// The away atom: argmax ⟨x, v⟩ among active atoms.
    fn away_atom(&self) -> Option<usize> {
        (0..self.weights.len())
            .map(|i| (i, dot(&self.x, self.atoms.row(i))))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(i, _)| i)
    }

    fn step_plain(&mut self) {
        // d = q − x; γ* = ⟨x, −d⟩/‖d‖² = ⟨x, x−q⟩/‖x−q‖².
        for ((d, &qi), &xi) in self.dir.iter_mut().zip(&self.q).zip(&self.x) {
            *d = qi - xi;
        }
        let denom = norm2_sq(&self.dir);
        if denom <= 0.0 {
            return;
        }
        let gamma = (-dot(&self.x, &self.dir) / denom).clamp(0.0, 1.0);
        axpy(gamma, &self.dir, &mut self.x);
    }

    fn step_away(&mut self) {
        // Choose between the FW direction (q − x) and the away direction
        // (x − v_away) by alignment with the negative gradient −x.
        let Some(ai) = self.away_atom() else { return };
        let fw_score = dot(&self.x, &self.x) - dot(&self.x, &self.q); // ⟨−∇, q−x⟩
        let away_score = dot(&self.x, self.atoms.row(ai)) - dot(&self.x, &self.x);
        if fw_score >= away_score {
            // FW step toward q with atom bookkeeping.
            for ((d, &qi), &xi) in self.dir.iter_mut().zip(&self.q).zip(&self.x) {
                *d = qi - xi;
            }
            let denom = norm2_sq(&self.dir);
            if denom <= 1e-300 {
                return;
            }
            let gamma = (-dot(&self.x, &self.dir) / denom).clamp(0.0, 1.0);
            if gamma == 0.0 {
                return;
            }
            axpy(gamma, &self.dir, &mut self.x);
            for wgt in self.weights.iter_mut() {
                *wgt *= 1.0 - gamma;
            }
            self.add_current_atom(gamma);
        } else {
            // Away step: move off v_away; max step keeps weights ≥ 0.
            let lam = self.weights[ai];
            if lam >= 1.0 - 1e-15 {
                return; // single-atom corral: away direction is null
            }
            let gamma_max = lam / (1.0 - lam);
            {
                let v = self.atoms.row(ai);
                for ((d, &xi), &vi) in self.dir.iter_mut().zip(&self.x).zip(v) {
                    *d = xi - vi;
                }
            }
            let denom = norm2_sq(&self.dir);
            if denom <= 1e-300 {
                return;
            }
            let gamma = (-dot(&self.x, &self.dir) / denom).clamp(0.0, gamma_max);
            if gamma == 0.0 {
                return;
            }
            axpy(gamma, &self.dir, &mut self.x);
            for wgt in self.weights.iter_mut() {
                *wgt *= 1.0 + gamma;
            }
            self.weights[ai] -= gamma;
        }
        self.drop_tiny_atoms();
    }

    fn step_pairwise(&mut self) {
        let Some(ai) = self.away_atom() else {
            return;
        };
        // Direction q − v_away with max step = λ_away.
        let gamma_max = self.weights[ai];
        {
            let v_away = self.atoms.row(ai);
            for ((d, &qi), &vi) in self.dir.iter_mut().zip(&self.q).zip(v_away) {
                *d = qi - vi;
            }
        }
        let denom = norm2_sq(&self.dir);
        if denom <= 1e-300 {
            return;
        }
        let gamma = (-dot(&self.x, &self.dir) / denom).clamp(0.0, gamma_max);
        if gamma == 0.0 {
            return;
        }
        axpy(gamma, &self.dir, &mut self.x);
        self.weights[ai] -= gamma;
        self.add_current_atom(gamma);
        self.drop_tiny_atoms();
    }
}

impl ProxSolver for FrankWolfe {
    fn step(&mut self, f: &dyn Submodular) -> SolverEvent {
        let (_info, f_w) = self.shared.greedy_and_refine(f, &self.x, &mut self.q);
        let wolfe_gap = norm2_sq(&self.x) - dot(&self.x, &self.q);
        if wolfe_gap > 0.0 {
            match self.opts.variant {
                FwVariant::Plain => self.step_plain(),
                FwVariant::Pairwise => self.step_pairwise(),
                FwVariant::Away => self.step_away(),
            }
        }
        crate::lovasz::debug_assert_dual_feasible(f, &self.x, "FrankWolfe::step");
        self.shared.finish_step(f_w, &self.x, wolfe_gap)
    }

    fn s(&self) -> &[f64] {
        &self.x
    }

    fn w(&self) -> &[f64] {
        &self.shared.w
    }

    fn gap(&self) -> f64 {
        self.shared.gap
    }

    fn best_level_value(&self) -> f64 {
        self.shared.fc
    }

    fn iters(&self) -> usize {
        self.shared.iters
    }

    fn reset(&mut self, f: &dyn Submodular, w_init: &[f64]) {
        let p = f.ground_size();
        self.x.resize(p, 0.0);
        self.q.resize(p, 0.0);
        self.dir.resize(p, 0.0);
        self.atoms.reset(p);
        self.keys.reset(p);
        self.weights.clear();
        self.hashes.clear();
        self.lookup.clear();
        // The initial greedy vertex lands in `q` (the next step overwrites
        // it anyway), so warm restarts reuse every buffer.
        self.shared.reset_from(f, w_init, &mut self.q);
        self.x.copy_from_slice(&self.q);
        self.add_current_atom(1.0);
    }

    fn reset_mapped(&mut self, f: &dyn Submodular, w_init: &[f64], map: &ContractionMap) {
        let p = f.ground_size();
        // Plain FW maintains no atom set (`step_plain` moves x directly),
        // so its only "atom" is the stale run-start vertex — projecting
        // that would be strictly worse than the cold restart's fresh
        // greedy vertex. Warm restarts only pay off for the atom-carrying
        // variants.
        if self.opts.variant == FwVariant::Plain
            || map.new_len() != p
            || self.x.len() != map.old_len()
            || self.weights.is_empty()
            || self.keys.stride() != map.old_len()
            || self.keys.len() != self.weights.len()
        {
            self.reset(f, w_init);
            return;
        }
        // (1) Warm-start the greedy argsort through the contraction.
        self.shared.greedy_ws.contract(map);
        self.x.resize(p, 0.0);
        self.q.resize(p, 0.0);
        self.dir.resize(p, 0.0);
        // (2) Project the atom keys through the survivor map: one
        // in-place IndexMat sweep (each key — a full permutation of the
        // old reduced ground set — contracts to its induced order on the
        // new one), then rehash and re-sort the id index. No key is
        // cloned: the interned arena *is* the index storage, which makes
        // the whole restart allocation-free at the high-water mark.
        self.keys.contract(map.new_of_old(), p);
        self.atoms.reshape_rows(p);
        for i in 0..self.keys.len() {
            self.hashes[i] = hash_key(self.keys.row(i));
        }
        self.rebuild_lookup();
        // (3) Merge atoms whose induced orders collapsed to the same
        // permutation (identical vertices): walk the (hash, id)-sorted
        // lookup; the lowest-id atom of each duplicate group absorbs the
        // weights of the rest. Dead atoms are marked with a negative
        // weight sentinel (convex weights are nonnegative) and compacted
        // in one sweep.
        let mut any_dead = false;
        let mut g0 = 0usize;
        while g0 < self.lookup.len() {
            let h = self.hashes[self.lookup[g0] as usize];
            let mut g1 = g0 + 1;
            while g1 < self.lookup.len() && self.hashes[self.lookup[g1] as usize] == h {
                g1 += 1;
            }
            for a in g0..g1 {
                let ia = self.lookup[a] as usize;
                if self.weights[ia] < 0.0 {
                    continue;
                }
                for b in (a + 1)..g1 {
                    let ib = self.lookup[b] as usize;
                    if self.weights[ib] >= 0.0 && self.keys.row(ia) == self.keys.row(ib)
                    {
                        self.weights[ia] += self.weights[ib];
                        self.weights[ib] = -1.0;
                        any_dead = true;
                    }
                }
            }
            g0 = g1;
        }
        if any_dead {
            self.compact_atoms(|w| w >= 0.0);
        }
        // Regenerate each surviving atom from its induced order: a valid
        // vertex of the contracted base polytope by construction.
        for i in 0..self.keys.len() {
            vertex_from_order(
                f,
                self.keys.row(i),
                &mut self.shared.greedy_ws,
                self.atoms.row_mut(i),
            );
        }
        // (3) Renormalize the convex weights (defensive — merging
        // preserves the total) and rebuild x = Σ λ_i v_i.
        let total: f64 = self.weights.iter().sum();
        if total > 0.0 {
            for wgt in self.weights.iter_mut() {
                *wgt /= total;
            }
        }
        self.x.iter_mut().for_each(|v| *v = 0.0);
        for (wgt, v) in self.weights.iter().zip(self.atoms.iter()) {
            axpy(*wgt, v, &mut self.x);
        }
        // (4) Step-14 bookkeeping: adopt the restricted primal and close
        // the gap against the projected dual point (weak duality holds
        // for any x in B(F̂), so the gap stays a valid screening radius).
        let mut s0 = std::mem::take(&mut self.q);
        let f_w = self.shared.reset_primal(f, w_init, &mut s0);
        self.q = s0;
        let primal = f_w + 0.5 * norm2_sq(w_init);
        let dual = -0.5 * norm2_sq(&self.x);
        self.shared.gap = primal - dual;
        crate::lovasz::debug_assert_dual_feasible(f, &self.x, "FrankWolfe::reset_mapped");
    }

    fn greedy_full_sorts(&self) -> u64 {
        self.shared.greedy_ws.full_sorts
    }

    fn set_pool(
        &mut self,
        pool: Option<std::sync::Arc<crate::runtime::pool::WorkerPool>>,
    ) {
        self.shared.greedy_ws.set_pool(pool);
    }

    fn set_trace_timing(&mut self, enabled: bool) {
        self.shared.trace_timing = enabled;
    }

    fn take_phase_ns(&mut self) -> super::PhaseNs {
        super::PhaseNs { oracle_ns: self.shared.take_oracle_ns(), kind_ns: [0; 4] }
    }

    fn export_state(&self) -> Option<super::SolverState> {
        // Plain FW maintains no atom decomposition (`step_plain` moves x
        // directly), so there is nothing replayable to snapshot — resume
        // falls back to the cold step-14 reset at the checkpoint's
        // reduction, same rationale as the `reset_mapped` guard above.
        if self.opts.variant == FwVariant::Plain {
            return None;
        }
        let m = self.weights.len();
        if m == 0 || self.keys.len() != m {
            return None;
        }
        Some(super::SolverState {
            kind: self.name().to_string(),
            orders: (0..m).map(|i| self.keys.row(i).to_vec()).collect(),
            weights: self.weights.clone(),
            dual: self.x.clone(),
            components: Vec::new(),
        })
    }

    fn restore(
        &mut self,
        f: &dyn Submodular,
        w_init: &[f64],
        state: &super::SolverState,
    ) -> anyhow::Result<()> {
        let p = f.ground_size();
        anyhow::ensure!(
            state.kind == self.name(),
            "snapshot kind '{}' does not match solver '{}'",
            state.kind,
            self.name()
        );
        anyhow::ensure!(
            state.components.is_empty(),
            "monolithic snapshot must not carry component state"
        );
        anyhow::ensure!(!state.orders.is_empty(), "snapshot has no atoms");
        anyhow::ensure!(
            state.weights.len() == state.orders.len(),
            "snapshot has {} weights for {} atoms",
            state.weights.len(),
            state.orders.len()
        );
        anyhow::ensure!(
            state.dual.len() == p && w_init.len() == p,
            "snapshot dual has {} coordinates, problem has {p}",
            state.dual.len()
        );
        let mut seen = vec![false; p];
        for order in &state.orders {
            anyhow::ensure!(
                order.len() == p,
                "atom order has {} entries, problem has {p}",
                order.len()
            );
            seen.iter_mut().for_each(|s| *s = false);
            for &j in order {
                anyhow::ensure!(
                    j < p && !seen[j],
                    "atom order is not a permutation of 0..{p}"
                );
                seen[j] = true;
            }
        }
        for &wgt in &state.weights {
            anyhow::ensure!(
                wgt.is_finite() && wgt >= 0.0,
                "atom weight {wgt} is not finite and non-negative"
            );
        }
        // Rebuild the atom set by replaying each generating order on the
        // oracle (regeneration invariant — never coordinate-projected),
        // merging any duplicate orders through the interned-key index.
        self.x.resize(p, 0.0);
        self.dir.resize(p, 0.0);
        self.atoms.reset(p);
        self.keys.reset(p);
        self.weights.clear();
        self.hashes.clear();
        self.lookup.clear();
        self.shared.resize(p);
        let mut buf = std::mem::take(&mut self.q);
        buf.clear();
        buf.resize(p, 0.0);
        for (order, &wgt) in state.orders.iter().zip(&state.weights) {
            let h = hash_key(order);
            if let Some(i) = self.find_atom(h, order) {
                self.weights[i] += wgt;
                continue;
            }
            vertex_from_order(f, order, &mut self.shared.greedy_ws, &mut buf);
            let idx = self.weights.len();
            self.keys.push(order);
            self.hashes.push(h);
            self.atoms.push(&buf);
            self.weights.push(wgt);
            let hashes = &self.hashes;
            let at = self
                .lookup
                .partition_point(|&i| (hashes[i as usize], i as usize) < (h, idx));
            self.lookup.insert(at, idx as u32);
        }
        self.q = buf;
        let total: f64 = self.weights.iter().sum();
        anyhow::ensure!(total > 0.0, "snapshot atom weights sum to zero");
        for wgt in self.weights.iter_mut() {
            *wgt /= total;
        }
        self.x.iter_mut().for_each(|v| *v = 0.0);
        for (wgt, v) in self.weights.iter().zip(self.atoms.iter()) {
            axpy(*wgt, v, &mut self.x);
        }
        // Integrity gate: the regenerated combination must reproduce the
        // stored dual — a deviation means the snapshot describes a
        // different problem.
        let mut err: f64 = 0.0;
        for (a, b) in self.x.iter().zip(&state.dual) {
            err = err.max((a - b).abs());
        }
        anyhow::ensure!(
            err <= 1e-6,
            "regenerated dual deviates from snapshot by {err:.3e} \
             (corrupted or mismatched checkpoint)"
        );
        // Step-14 bookkeeping: adopt the restricted primal and close the
        // gap against the restored dual point (weak duality holds for any
        // x in B(F̂), so the gap is a valid screening radius).
        let mut s0 = std::mem::take(&mut self.q);
        let f_w = self.shared.reset_primal(f, w_init, &mut s0);
        self.q = s0;
        let primal = f_w + 0.5 * norm2_sq(w_init);
        let dual = -0.5 * norm2_sq(&self.x);
        self.shared.gap = primal - dual;
        crate::lovasz::debug_assert_dual_feasible(f, &self.x, "FrankWolfe::restore");
        Ok(())
    }

    fn name(&self) -> &'static str {
        match self.opts.variant {
            FwVariant::Plain => "frank-wolfe",
            FwVariant::Pairwise => "pairwise-fw",
            FwVariant::Away => "away-fw",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brute::brute_force_sfm;
    use crate::lovasz::sup_level_set;
    use crate::rng::Pcg64;
    use crate::solvers::minnorm::{MinNormOptions, MinNormPoint};
    use crate::submodular::iwata::IwataFn;
    use crate::submodular::kernel_cut::KernelCutFn;

    fn run(solver: &mut dyn ProxSolver, f: &dyn Submodular, iters: usize, eps: f64) {
        for _ in 0..iters {
            let ev = solver.step(f);
            if ev.gap < eps {
                break;
            }
        }
    }

    #[test]
    fn pairwise_converges_on_iwata() {
        let f = IwataFn::new(12);
        let mut fw = FrankWolfe::new(&f, FwOptions::default(), None);
        run(&mut fw, &f, 3000, 1e-8);
        assert!(fw.gap() < 1e-8, "gap {}", fw.gap());
        let brute = brute_force_sfm(&f, 1e-9);
        assert_eq!(sup_level_set(fw.w(), 0.0), brute.minimal);
    }

    #[test]
    fn plain_fw_decreases_dual_objective() {
        let f = IwataFn::new(10);
        let mut fw = FrankWolfe::new(
            &f,
            FwOptions { variant: FwVariant::Plain, ..Default::default() },
            None,
        );
        let mut last_norm = f64::INFINITY;
        for _ in 0..200 {
            fw.step(&f);
            let n = norm2_sq(fw.s());
            assert!(n <= last_norm + 1e-9, "‖x‖² increased");
            last_norm = n;
        }
    }

    #[test]
    fn pairwise_matches_minnorm_solution() {
        let mut rng = Pcg64::seeded(23);
        let p = 10;
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let unary = rng.uniform_vec(p, -2.0, 2.0);
        let f = KernelCutFn::new(p, k, unary);

        let mut fw = FrankWolfe::new(&f, FwOptions::default(), None);
        run(&mut fw, &f, 5000, 1e-10);
        let mut mn = MinNormPoint::new(&f, MinNormOptions::default(), None);
        run(&mut mn, &f, 1000, 1e-10);

        // Min-norm point is unique: both solvers must agree.
        for (a, b) in fw.s().iter().zip(mn.s()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn away_variant_converges_and_weights_stay_convex() {
        let f = IwataFn::new(10);
        let mut fw = FrankWolfe::new(
            &f,
            FwOptions { variant: FwVariant::Away, ..Default::default() },
            None,
        );
        for _ in 0..4000 {
            let ev = fw.step(&f);
            let total: f64 = fw.weights.iter().sum();
            assert!((total - 1.0).abs() < 1e-6, "weights sum {total}");
            assert!(fw.weights.iter().all(|&w| w >= -1e-12));
            if ev.gap < 1e-8 {
                break;
            }
        }
        assert!(fw.gap() < 1e-6, "away-step FW gap {}", fw.gap());
        let brute = brute_force_sfm(&f, 1e-9);
        assert_eq!(sup_level_set(fw.w(), 0.0), brute.minimal);
    }

    #[test]
    fn export_restore_round_trip_pairwise() {
        let f = IwataFn::new(12);
        let mut fw = FrankWolfe::new(&f, FwOptions::default(), None);
        for _ in 0..30 {
            fw.step(&f);
        }
        let state = fw.export_state().expect("pairwise FW must export atoms");
        assert_eq!(state.kind, "pairwise-fw");
        let w_init = fw.w().to_vec();
        let mut fresh = FrankWolfe::new(&f, FwOptions::default(), None);
        fresh.restore(&f, &w_init, &state).expect("restore own export");
        // The restored combination reproduces the snapshot dual exactly
        // (same atoms regenerated on the same oracle).
        for (a, b) in fresh.s().iter().zip(&state.dual) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        assert!(fresh.gap() >= -1e-9);
        run(&mut fresh, &f, 3000, 1e-8);
        assert!(fresh.gap() < 1e-8, "restored FW stalled: {}", fresh.gap());
        let brute = brute_force_sfm(&f, 1e-9);
        assert_eq!(sup_level_set(fresh.w(), 0.0), brute.minimal);
    }

    #[test]
    fn plain_fw_exports_nothing() {
        let f = IwataFn::new(8);
        let mut fw = FrankWolfe::new(
            &f,
            FwOptions { variant: FwVariant::Plain, ..Default::default() },
            None,
        );
        for _ in 0..5 {
            fw.step(&f);
        }
        assert!(fw.export_state().is_none());
    }

    #[test]
    fn atom_weights_stay_convex() {
        let f = IwataFn::new(9);
        let mut fw = FrankWolfe::new(&f, FwOptions::default(), None);
        for _ in 0..100 {
            fw.step(&f);
            let total: f64 = fw.weights.iter().sum();
            assert!((total - 1.0).abs() < 1e-9, "weights sum {total}");
            assert!(fw.weights.iter().all(|&w| w >= 0.0));
            // Parallel-array + sorted-lookup invariants.
            assert_eq!(fw.weights.len(), fw.num_atoms());
            assert_eq!(fw.keys.len(), fw.num_atoms());
            assert_eq!(fw.hashes.len(), fw.num_atoms());
            assert_eq!(fw.lookup.len(), fw.num_atoms());
            for pos in 1..fw.lookup.len() {
                let (a, b) = (fw.lookup[pos - 1], fw.lookup[pos]);
                assert!(
                    (fw.hashes[a as usize], a) < (fw.hashes[b as usize], b),
                    "lookup unsorted"
                );
            }
            for i in 0..fw.num_atoms() {
                assert_eq!(
                    fw.find_atom(fw.hashes[i], fw.keys.row(i)),
                    Some(i),
                    "index lookup skewed"
                );
            }
        }
    }
}
