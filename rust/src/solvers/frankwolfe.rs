//! Conditional-gradient solvers for (Q-D) — Remark 2's alternative to the
//! min-norm-point algorithm.
//!
//! Minimizing `½‖x‖²` over `B(F)` with the greedy linear oracle:
//!
//! * **Plain Frank–Wolfe** with exact line search
//!   (`γ* = ⟨x, x−q⟩ / ‖x−q‖²` clipped to `[0,1]`) — O(1/t) convergence.
//! * **Pairwise Frank–Wolfe**: moves mass directly from the worst active
//!   atom to the new greedy atom, which restores linear convergence over
//!   polytopes (Lacoste-Julien & Jaggi 2015) and in practice tracks the
//!   min-norm-point algorithm much more closely.
//!
//! Both variants share the greedy/PAV/gap bookkeeping of
//! [`super::PrimalState`], so the IAES engine can drive either
//! interchangeably (ablation A3 in DESIGN.md).

use super::{PrimalState, ProxSolver, SolverEvent};
use crate::linalg::vecops::{axpy, dot, norm2_sq};
use crate::linalg::CorralMat;
use crate::lovasz::{vertex_from_order, ContractionMap};
use crate::submodular::Submodular;
use std::collections::HashMap;

/// Frank–Wolfe variant selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FwVariant {
    /// Classic FW with exact line search.
    Plain,
    /// Pairwise FW (atom-to-atom mass transfer).
    Pairwise,
    /// Away-step FW (Guélat–Marcotte; linear rate over polytopes).
    Away,
}

/// Options for [`FrankWolfe`].
#[derive(Clone, Copy, Debug)]
pub struct FwOptions {
    /// Variant to run.
    pub variant: FwVariant,
    /// Atom weights below this are dropped (pairwise only).
    pub weight_tol: f64,
}

impl Default for FwOptions {
    fn default() -> Self {
        FwOptions { variant: FwVariant::Pairwise, weight_tol: 1e-14 }
    }
}

/// Atom key: the greedy order that generated the vertex (vertices of B(F)
/// correspond to permutations; equal orders ⇒ equal vertices).
type AtomKey = Vec<u32>;

/// Conditional-gradient solver state.
///
/// Atoms live in parallel flat arrays — vertices in a [`CorralMat`], keys
/// and weights in plain `Vec`s — so steady-state steps (no atom birth, no
/// eviction) allocate nothing: the key of the current greedy order is
/// materialized into a reused buffer and looked up by slice, and a
/// repeat-atom step only bumps a weight.
pub struct FrankWolfe {
    opts: FwOptions,
    /// Current dual iterate.
    x: Vec<f64>,
    /// Atom vertices (pairwise/away variants), flat row-major.
    atoms: CorralMat,
    /// Atom weights, parallel to `atoms`.
    weights: Vec<f64>,
    /// Atom keys, parallel to `atoms`.
    keys: Vec<AtomKey>,
    /// Key → atom index (owned keys duplicate `keys` only at atom birth).
    atom_index: HashMap<AtomKey, usize>,
    /// Scratch: the current greedy order as a key, reused every step.
    key_buf: AtomKey,
    /// Scratch: surviving-atom indices during eviction compaction.
    keep_buf: Vec<usize>,
    /// Scratch: a key widened to usize ids (atom regeneration passes).
    order_buf: Vec<usize>,
    shared: PrimalState,
    q: Vec<f64>,
    dir: Vec<f64>,
}

impl FrankWolfe {
    /// Initialize on `f` from the greedy vertex in direction `w_init`.
    pub fn new(f: &dyn Submodular, opts: FwOptions, w_init: Option<&[f64]>) -> Self {
        let p = f.ground_size();
        let mut solver = FrankWolfe {
            opts,
            x: vec![0.0; p],
            atoms: CorralMat::new(p),
            weights: Vec::new(),
            keys: Vec::new(),
            atom_index: HashMap::new(),
            key_buf: Vec::new(),
            keep_buf: Vec::new(),
            order_buf: Vec::new(),
            shared: PrimalState::new(p),
            q: vec![0.0; p],
            dir: vec![0.0; p],
        };
        let w0 = match w_init {
            Some(w) => w.to_vec(),
            None => vec![0.0; p],
        };
        solver.reset(f, &w0);
        solver
    }

    /// Number of active atoms (pairwise variant; 0 for plain).
    pub fn num_atoms(&self) -> usize {
        self.weights.len()
    }

    /// Materialize the current greedy order into the reused key buffer.
    fn fill_key_buf(&mut self) {
        self.key_buf.clear();
        self.key_buf
            .extend(self.shared.greedy_ws.order.iter().map(|&i| i as u32));
    }

    /// Add `weight` to the atom whose key is in `key_buf` and whose vertex
    /// is in `q`, creating the atom if it is new (the only place a key is
    /// cloned — atom birth, not steady state).
    fn add_current_atom(&mut self, weight: f64) {
        if let Some(&i) = self.atom_index.get(self.key_buf.as_slice()) {
            self.weights[i] += weight;
        } else {
            let key = self.key_buf.clone();
            self.atom_index.insert(key.clone(), self.weights.len());
            self.keys.push(key);
            self.atoms.push(&self.q);
            self.weights.push(weight);
        }
    }

    fn drop_tiny_atoms(&mut self) {
        let tol = self.opts.weight_tol;
        if self.weights.iter().all(|&w| w > tol) {
            return;
        }
        // Single-pass compaction of the parallel arrays: one sweep no
        // matter how many atoms die at once (weights rescale together, so
        // they can cross the tolerance in batches). Dead positions are
        // only ever read — swaps target the current (surviving) read
        // position — so `keys[read]` is the original key when removed
        // from the index. The survivor index buffer is reused.
        let mut keep = std::mem::take(&mut self.keep_buf);
        keep.clear();
        let mut write = 0usize;
        for read in 0..self.weights.len() {
            if self.weights[read] > tol {
                keep.push(read);
                if write != read {
                    self.weights[write] = self.weights[read];
                    self.keys.swap(write, read);
                }
                write += 1;
            } else {
                self.atom_index.remove(self.keys[read].as_slice());
            }
        }
        self.weights.truncate(write);
        self.keys.truncate(write);
        self.atoms.compact(&keep);
        for (i, k) in self.keys.iter().enumerate() {
            *self
                .atom_index
                .get_mut(k.as_slice())
                .expect("surviving atom key must stay indexed") = i;
        }
        self.keep_buf = keep;
    }

    /// The away atom: argmax ⟨x, v⟩ among active atoms.
    fn away_atom(&self) -> Option<usize> {
        (0..self.weights.len())
            .map(|i| (i, dot(&self.x, self.atoms.row(i))))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(i, _)| i)
    }

    fn step_plain(&mut self) {
        // d = q − x; γ* = ⟨x, −d⟩/‖d‖² = ⟨x, x−q⟩/‖x−q‖².
        for ((d, &qi), &xi) in self.dir.iter_mut().zip(&self.q).zip(&self.x) {
            *d = qi - xi;
        }
        let denom = norm2_sq(&self.dir);
        if denom <= 0.0 {
            return;
        }
        let gamma = (-dot(&self.x, &self.dir) / denom).clamp(0.0, 1.0);
        axpy(gamma, &self.dir, &mut self.x);
    }

    fn step_away(&mut self) {
        // Choose between the FW direction (q − x) and the away direction
        // (x − v_away) by alignment with the negative gradient −x.
        let Some(ai) = self.away_atom() else { return };
        let fw_score = dot(&self.x, &self.x) - dot(&self.x, &self.q); // ⟨−∇, q−x⟩
        let away_score = dot(&self.x, self.atoms.row(ai)) - dot(&self.x, &self.x);
        if fw_score >= away_score {
            // FW step toward q with atom bookkeeping.
            for ((d, &qi), &xi) in self.dir.iter_mut().zip(&self.q).zip(&self.x) {
                *d = qi - xi;
            }
            let denom = norm2_sq(&self.dir);
            if denom <= 1e-300 {
                return;
            }
            let gamma = (-dot(&self.x, &self.dir) / denom).clamp(0.0, 1.0);
            if gamma == 0.0 {
                return;
            }
            axpy(gamma, &self.dir, &mut self.x);
            for wgt in self.weights.iter_mut() {
                *wgt *= 1.0 - gamma;
            }
            self.fill_key_buf();
            self.add_current_atom(gamma);
        } else {
            // Away step: move off v_away; max step keeps weights ≥ 0.
            let lam = self.weights[ai];
            if lam >= 1.0 - 1e-15 {
                return; // single-atom corral: away direction is null
            }
            let gamma_max = lam / (1.0 - lam);
            {
                let v = self.atoms.row(ai);
                for ((d, &xi), &vi) in self.dir.iter_mut().zip(&self.x).zip(v) {
                    *d = xi - vi;
                }
            }
            let denom = norm2_sq(&self.dir);
            if denom <= 1e-300 {
                return;
            }
            let gamma = (-dot(&self.x, &self.dir) / denom).clamp(0.0, gamma_max);
            if gamma == 0.0 {
                return;
            }
            axpy(gamma, &self.dir, &mut self.x);
            for wgt in self.weights.iter_mut() {
                *wgt *= 1.0 + gamma;
            }
            self.weights[ai] -= gamma;
        }
        self.drop_tiny_atoms();
    }

    fn step_pairwise(&mut self) {
        let Some(ai) = self.away_atom() else {
            return;
        };
        // Direction q − v_away with max step = λ_away.
        let gamma_max = self.weights[ai];
        {
            let v_away = self.atoms.row(ai);
            for ((d, &qi), &vi) in self.dir.iter_mut().zip(&self.q).zip(v_away) {
                *d = qi - vi;
            }
        }
        let denom = norm2_sq(&self.dir);
        if denom <= 1e-300 {
            return;
        }
        let gamma = (-dot(&self.x, &self.dir) / denom).clamp(0.0, gamma_max);
        if gamma == 0.0 {
            return;
        }
        axpy(gamma, &self.dir, &mut self.x);
        self.weights[ai] -= gamma;
        self.fill_key_buf();
        self.add_current_atom(gamma);
        self.drop_tiny_atoms();
    }
}

impl ProxSolver for FrankWolfe {
    fn step(&mut self, f: &dyn Submodular) -> SolverEvent {
        let (_info, f_w) = self.shared.greedy_and_refine(f, &self.x, &mut self.q);
        let wolfe_gap = norm2_sq(&self.x) - dot(&self.x, &self.q);
        if wolfe_gap > 0.0 {
            match self.opts.variant {
                FwVariant::Plain => self.step_plain(),
                FwVariant::Pairwise => self.step_pairwise(),
                FwVariant::Away => self.step_away(),
            }
        }
        self.shared.finish_step(f_w, &self.x, wolfe_gap)
    }

    fn s(&self) -> &[f64] {
        &self.x
    }

    fn w(&self) -> &[f64] {
        &self.shared.w
    }

    fn gap(&self) -> f64 {
        self.shared.gap
    }

    fn best_level_value(&self) -> f64 {
        self.shared.fc
    }

    fn iters(&self) -> usize {
        self.shared.iters
    }

    fn reset(&mut self, f: &dyn Submodular, w_init: &[f64]) {
        let p = f.ground_size();
        self.x.resize(p, 0.0);
        self.q.resize(p, 0.0);
        self.dir.resize(p, 0.0);
        self.atoms.reset(p);
        self.weights.clear();
        self.keys.clear();
        self.atom_index.clear();
        // The initial greedy vertex lands in `q` (the next step overwrites
        // it anyway), so warm restarts reuse every buffer.
        self.shared.reset_from(f, w_init, &mut self.q);
        self.x.copy_from_slice(&self.q);
        self.fill_key_buf();
        self.add_current_atom(1.0);
    }

    fn reset_mapped(&mut self, f: &dyn Submodular, w_init: &[f64], map: &ContractionMap) {
        let p = f.ground_size();
        // Plain FW maintains no atom set (`step_plain` moves x directly),
        // so its only "atom" is the stale run-start vertex — projecting
        // that would be strictly worse than the cold restart's fresh
        // greedy vertex. Warm restarts only pay off for the atom-carrying
        // variants.
        if self.opts.variant == FwVariant::Plain
            || map.new_len() != p
            || self.x.len() != map.old_len()
            || self.weights.is_empty()
            || self.keys.iter().any(|k| k.len() != map.old_len())
        {
            self.reset(f, w_init);
            return;
        }
        // (1) Warm-start the greedy argsort through the contraction.
        self.shared.greedy_ws.contract(map);
        self.x.resize(p, 0.0);
        self.q.resize(p, 0.0);
        self.dir.resize(p, 0.0);
        // (2) Project the atoms: filter each key (a full permutation of
        // the old reduced ground set) through the survivor map — the
        // induced order on the contracted problem — merging atoms whose
        // induced orders collapse to the same permutation. Unlike the
        // min-norm corral this re-keys the index map, which clones the
        // surviving keys (atom-count-bounded, restart-only allocations).
        self.atom_index.clear();
        let new_of_old = map.new_of_old();
        let mut keep = std::mem::take(&mut self.keep_buf);
        keep.clear();
        let mut write = 0usize;
        for read in 0..self.keys.len() {
            let key = &mut self.keys[read];
            let mut w = 0usize;
            for r in 0..key.len() {
                let mapped = new_of_old[key[r] as usize];
                if mapped != usize::MAX {
                    key[w] = mapped as u32;
                    w += 1;
                }
            }
            key.truncate(w);
            debug_assert_eq!(w, p, "atom key was not a permutation");
            if let Some(&first) = self.atom_index.get(key.as_slice()) {
                // Duplicate induced order ⇒ identical vertex: merge mass.
                self.weights[first] += self.weights[read];
            } else {
                let owned = self.keys[read].clone();
                self.atom_index.insert(owned, write);
                if write != read {
                    self.keys.swap(write, read);
                    self.weights[write] = self.weights[read];
                }
                keep.push(read);
                write += 1;
            }
        }
        self.keys.truncate(write);
        self.weights.truncate(write);
        self.atoms.reshape_rows(p);
        self.atoms.compact(&keep);
        self.keep_buf = keep;
        // Regenerate each surviving atom from its induced order: a valid
        // vertex of the contracted base polytope by construction.
        for i in 0..self.keys.len() {
            self.order_buf.clear();
            self.order_buf.extend(self.keys[i].iter().map(|&e| e as usize));
            vertex_from_order(
                f,
                &self.order_buf,
                &mut self.shared.greedy_ws,
                self.atoms.row_mut(i),
            );
        }
        // (3) Renormalize the convex weights (defensive — merging
        // preserves the total) and rebuild x = Σ λ_i v_i.
        let total: f64 = self.weights.iter().sum();
        if total > 0.0 {
            for wgt in self.weights.iter_mut() {
                *wgt /= total;
            }
        }
        self.x.iter_mut().for_each(|v| *v = 0.0);
        for (wgt, v) in self.weights.iter().zip(self.atoms.iter()) {
            axpy(*wgt, v, &mut self.x);
        }
        // (4) Step-14 bookkeeping: adopt the restricted primal and close
        // the gap against the projected dual point (weak duality holds
        // for any x in B(F̂), so the gap stays a valid screening radius).
        let mut s0 = std::mem::take(&mut self.q);
        let f_w = self.shared.reset_primal(f, w_init, &mut s0);
        self.q = s0;
        let primal = f_w + 0.5 * norm2_sq(w_init);
        let dual = -0.5 * norm2_sq(&self.x);
        self.shared.gap = primal - dual;
    }

    fn greedy_full_sorts(&self) -> u64 {
        self.shared.greedy_ws.full_sorts
    }

    fn name(&self) -> &'static str {
        match self.opts.variant {
            FwVariant::Plain => "frank-wolfe",
            FwVariant::Pairwise => "pairwise-fw",
            FwVariant::Away => "away-fw",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brute::brute_force_sfm;
    use crate::lovasz::sup_level_set;
    use crate::rng::Pcg64;
    use crate::solvers::minnorm::{MinNormOptions, MinNormPoint};
    use crate::submodular::iwata::IwataFn;
    use crate::submodular::kernel_cut::KernelCutFn;

    fn run(solver: &mut dyn ProxSolver, f: &dyn Submodular, iters: usize, eps: f64) {
        for _ in 0..iters {
            let ev = solver.step(f);
            if ev.gap < eps {
                break;
            }
        }
    }

    #[test]
    fn pairwise_converges_on_iwata() {
        let f = IwataFn::new(12);
        let mut fw = FrankWolfe::new(&f, FwOptions::default(), None);
        run(&mut fw, &f, 3000, 1e-8);
        assert!(fw.gap() < 1e-8, "gap {}", fw.gap());
        let brute = brute_force_sfm(&f, 1e-9);
        assert_eq!(sup_level_set(fw.w(), 0.0), brute.minimal);
    }

    #[test]
    fn plain_fw_decreases_dual_objective() {
        let f = IwataFn::new(10);
        let mut fw = FrankWolfe::new(
            &f,
            FwOptions { variant: FwVariant::Plain, ..Default::default() },
            None,
        );
        let mut last_norm = f64::INFINITY;
        for _ in 0..200 {
            fw.step(&f);
            let n = norm2_sq(fw.s());
            assert!(n <= last_norm + 1e-9, "‖x‖² increased");
            last_norm = n;
        }
    }

    #[test]
    fn pairwise_matches_minnorm_solution() {
        let mut rng = Pcg64::seeded(23);
        let p = 10;
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let unary = rng.uniform_vec(p, -2.0, 2.0);
        let f = KernelCutFn::new(p, k, unary);

        let mut fw = FrankWolfe::new(&f, FwOptions::default(), None);
        run(&mut fw, &f, 5000, 1e-10);
        let mut mn = MinNormPoint::new(&f, MinNormOptions::default(), None);
        run(&mut mn, &f, 1000, 1e-10);

        // Min-norm point is unique: both solvers must agree.
        for (a, b) in fw.s().iter().zip(mn.s()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn away_variant_converges_and_weights_stay_convex() {
        let f = IwataFn::new(10);
        let mut fw = FrankWolfe::new(
            &f,
            FwOptions { variant: FwVariant::Away, ..Default::default() },
            None,
        );
        for _ in 0..4000 {
            let ev = fw.step(&f);
            let total: f64 = fw.weights.iter().sum();
            assert!((total - 1.0).abs() < 1e-6, "weights sum {total}");
            assert!(fw.weights.iter().all(|&w| w >= -1e-12));
            if ev.gap < 1e-8 {
                break;
            }
        }
        assert!(fw.gap() < 1e-6, "away-step FW gap {}", fw.gap());
        let brute = brute_force_sfm(&f, 1e-9);
        assert_eq!(sup_level_set(fw.w(), 0.0), brute.minimal);
    }

    #[test]
    fn atom_weights_stay_convex() {
        let f = IwataFn::new(9);
        let mut fw = FrankWolfe::new(&f, FwOptions::default(), None);
        for _ in 0..100 {
            fw.step(&f);
            let total: f64 = fw.weights.iter().sum();
            assert!((total - 1.0).abs() < 1e-9, "weights sum {total}");
            assert!(fw.weights.iter().all(|&w| w >= 0.0));
            // Parallel-array + index-map invariants.
            assert_eq!(fw.weights.len(), fw.num_atoms());
            assert_eq!(fw.keys.len(), fw.num_atoms());
            assert_eq!(fw.atom_index.len(), fw.num_atoms());
            for (i, k) in fw.keys.iter().enumerate() {
                assert_eq!(fw.atom_index[k.as_slice()], i, "index map skewed");
            }
        }
    }
}
