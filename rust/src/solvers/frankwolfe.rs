//! Conditional-gradient solvers for (Q-D) — Remark 2's alternative to the
//! min-norm-point algorithm.
//!
//! Minimizing `½‖x‖²` over `B(F)` with the greedy linear oracle:
//!
//! * **Plain Frank–Wolfe** with exact line search
//!   (`γ* = ⟨x, x−q⟩ / ‖x−q‖²` clipped to `[0,1]`) — O(1/t) convergence.
//! * **Pairwise Frank–Wolfe**: moves mass directly from the worst active
//!   atom to the new greedy atom, which restores linear convergence over
//!   polytopes (Lacoste-Julien & Jaggi 2015) and in practice tracks the
//!   min-norm-point algorithm much more closely.
//!
//! Both variants share the greedy/PAV/gap bookkeeping of
//! [`super::PrimalState`], so the IAES engine can drive either
//! interchangeably (ablation A3 in DESIGN.md).

use super::{PrimalState, ProxSolver, SolverEvent};
use crate::linalg::vecops::{axpy, dot, norm2_sq};
use crate::submodular::Submodular;
use std::collections::HashMap;

/// Frank–Wolfe variant selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FwVariant {
    /// Classic FW with exact line search.
    Plain,
    /// Pairwise FW (atom-to-atom mass transfer).
    Pairwise,
    /// Away-step FW (Guélat–Marcotte; linear rate over polytopes).
    Away,
}

/// Options for [`FrankWolfe`].
#[derive(Clone, Copy, Debug)]
pub struct FwOptions {
    /// Variant to run.
    pub variant: FwVariant,
    /// Atom weights below this are dropped (pairwise only).
    pub weight_tol: f64,
}

impl Default for FwOptions {
    fn default() -> Self {
        FwOptions { variant: FwVariant::Pairwise, weight_tol: 1e-14 }
    }
}

/// Atom key: the greedy order that generated the vertex (vertices of B(F)
/// correspond to permutations; equal orders ⇒ equal vertices).
type AtomKey = Vec<u32>;

/// Conditional-gradient solver state.
pub struct FrankWolfe {
    opts: FwOptions,
    /// Current dual iterate.
    x: Vec<f64>,
    /// Active atoms (pairwise variant): key → (vertex, weight).
    atoms: Vec<(AtomKey, Vec<f64>, f64)>,
    atom_index: HashMap<AtomKey, usize>,
    shared: PrimalState,
    q: Vec<f64>,
    dir: Vec<f64>,
}

impl FrankWolfe {
    /// Initialize on `f` from the greedy vertex in direction `w_init`.
    pub fn new(f: &dyn Submodular, opts: FwOptions, w_init: Option<&[f64]>) -> Self {
        let p = f.ground_size();
        let mut solver = FrankWolfe {
            opts,
            x: vec![0.0; p],
            atoms: Vec::new(),
            atom_index: HashMap::new(),
            shared: PrimalState::new(p),
            q: vec![0.0; p],
            dir: vec![0.0; p],
        };
        let w0 = match w_init {
            Some(w) => w.to_vec(),
            None => vec![0.0; p],
        };
        solver.reset(f, &w0);
        solver
    }

    /// Number of active atoms (pairwise variant; 0 for plain).
    pub fn num_atoms(&self) -> usize {
        self.atoms.len()
    }

    fn current_order_key(&self) -> AtomKey {
        self.shared.greedy_ws.order.iter().map(|&i| i as u32).collect()
    }

    fn add_atom(&mut self, key: AtomKey, vertex: Vec<f64>, weight: f64) {
        if let Some(&i) = self.atom_index.get(&key) {
            self.atoms[i].2 += weight;
        } else {
            self.atom_index.insert(key.clone(), self.atoms.len());
            self.atoms.push((key, vertex, weight));
        }
    }

    fn drop_tiny_atoms(&mut self) {
        let tol = self.opts.weight_tol;
        if self.atoms.iter().all(|(_, _, w)| *w > tol) {
            return;
        }
        self.atoms.retain(|(_, _, w)| *w > tol);
        self.atom_index.clear();
        for (i, (k, _, _)) in self.atoms.iter().enumerate() {
            self.atom_index.insert(k.clone(), i);
        }
    }

    fn step_plain(&mut self) {
        // d = q − x; γ* = ⟨x, −d⟩/‖d‖² = ⟨x, x−q⟩/‖x−q‖².
        for ((d, &qi), &xi) in self.dir.iter_mut().zip(&self.q).zip(&self.x) {
            *d = qi - xi;
        }
        let denom = norm2_sq(&self.dir);
        if denom <= 0.0 {
            return;
        }
        let gamma = (-dot(&self.x, &self.dir) / denom).clamp(0.0, 1.0);
        axpy(gamma, &self.dir, &mut self.x);
    }

    fn step_away(&mut self) {
        // Choose between the FW direction (q − x) and the away direction
        // (x − v_away) by alignment with the negative gradient −x.
        let away = self
            .atoms
            .iter()
            .enumerate()
            .map(|(i, (_, v, _))| (i, dot(&self.x, v)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(i, _)| i);
        let Some(ai) = away else { return };
        let fw_score = dot(&self.x, &self.x) - dot(&self.x, &self.q); // ⟨−∇, q−x⟩
        let away_score = dot(&self.x, &self.atoms[ai].1) - dot(&self.x, &self.x);
        if fw_score >= away_score {
            // FW step toward q with atom bookkeeping.
            for ((d, &qi), &xi) in self.dir.iter_mut().zip(&self.q).zip(&self.x) {
                *d = qi - xi;
            }
            let denom = norm2_sq(&self.dir);
            if denom <= 1e-300 {
                return;
            }
            let gamma = (-dot(&self.x, &self.dir) / denom).clamp(0.0, 1.0);
            if gamma == 0.0 {
                return;
            }
            axpy(gamma, &self.dir, &mut self.x);
            for (_, _, wgt) in self.atoms.iter_mut() {
                *wgt *= 1.0 - gamma;
            }
            let key = self.current_order_key();
            let q = self.q.clone();
            self.add_atom(key, q, gamma);
        } else {
            // Away step: move off v_away; max step keeps weights ≥ 0.
            let lam = self.atoms[ai].2;
            if lam >= 1.0 - 1e-15 {
                return; // single-atom corral: away direction is null
            }
            let gamma_max = lam / (1.0 - lam);
            {
                let v = &self.atoms[ai].1;
                for ((d, &xi), &vi) in self.dir.iter_mut().zip(&self.x).zip(v) {
                    *d = xi - vi;
                }
            }
            let denom = norm2_sq(&self.dir);
            if denom <= 1e-300 {
                return;
            }
            let gamma = (-dot(&self.x, &self.dir) / denom).clamp(0.0, gamma_max);
            if gamma == 0.0 {
                return;
            }
            axpy(gamma, &self.dir, &mut self.x);
            for (_, _, wgt) in self.atoms.iter_mut() {
                *wgt *= 1.0 + gamma;
            }
            self.atoms[ai].2 -= gamma;
        }
        self.drop_tiny_atoms();
    }

    fn step_pairwise(&mut self) {
        // Away atom: argmax ⟨x, v⟩ among active atoms.
        let away = self
            .atoms
            .iter()
            .enumerate()
            .map(|(i, (_, v, _))| (i, dot(&self.x, v)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(i, _)| i);
        let Some(ai) = away else {
            return;
        };
        // Direction q − v_away with max step = λ_away.
        let gamma_max = self.atoms[ai].2;
        {
            let v_away = &self.atoms[ai].1;
            for ((d, &qi), &vi) in self.dir.iter_mut().zip(&self.q).zip(v_away) {
                *d = qi - vi;
            }
        }
        let denom = norm2_sq(&self.dir);
        if denom <= 1e-300 {
            return;
        }
        let gamma = (-dot(&self.x, &self.dir) / denom).clamp(0.0, gamma_max);
        if gamma == 0.0 {
            return;
        }
        axpy(gamma, &self.dir, &mut self.x);
        self.atoms[ai].2 -= gamma;
        let key = self.current_order_key();
        let q = self.q.clone();
        self.add_atom(key, q, gamma);
        self.drop_tiny_atoms();
    }
}

impl ProxSolver for FrankWolfe {
    fn step(&mut self, f: &dyn Submodular) -> SolverEvent {
        let mut q = std::mem::take(&mut self.q);
        let (_info, f_w) = self.shared.greedy_and_refine(f, &self.x, &mut q);
        self.q = q;
        let wolfe_gap = norm2_sq(&self.x) - dot(&self.x, &self.q);
        if wolfe_gap > 0.0 {
            match self.opts.variant {
                FwVariant::Plain => self.step_plain(),
                FwVariant::Pairwise => self.step_pairwise(),
                FwVariant::Away => self.step_away(),
            }
        }
        self.shared.finish_step(f_w, &self.x, wolfe_gap)
    }

    fn s(&self) -> &[f64] {
        &self.x
    }

    fn w(&self) -> &[f64] {
        &self.shared.w
    }

    fn gap(&self) -> f64 {
        self.shared.gap
    }

    fn best_level_value(&self) -> f64 {
        self.shared.fc
    }

    fn iters(&self) -> usize {
        self.shared.iters
    }

    fn reset(&mut self, f: &dyn Submodular, w_init: &[f64]) {
        let p = f.ground_size();
        self.x.resize(p, 0.0);
        self.q.resize(p, 0.0);
        self.dir.resize(p, 0.0);
        self.atoms.clear();
        self.atom_index.clear();
        let mut s0 = vec![0.0; p];
        self.shared.reset_from(f, w_init, &mut s0);
        self.x.copy_from_slice(&s0);
        let key = self.current_order_key();
        self.add_atom(key, s0, 1.0);
    }

    fn name(&self) -> &'static str {
        match self.opts.variant {
            FwVariant::Plain => "frank-wolfe",
            FwVariant::Pairwise => "pairwise-fw",
            FwVariant::Away => "away-fw",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brute::brute_force_sfm;
    use crate::lovasz::sup_level_set;
    use crate::rng::Pcg64;
    use crate::solvers::minnorm::{MinNormOptions, MinNormPoint};
    use crate::submodular::iwata::IwataFn;
    use crate::submodular::kernel_cut::KernelCutFn;

    fn run(solver: &mut dyn ProxSolver, f: &dyn Submodular, iters: usize, eps: f64) {
        for _ in 0..iters {
            let ev = solver.step(f);
            if ev.gap < eps {
                break;
            }
        }
    }

    #[test]
    fn pairwise_converges_on_iwata() {
        let f = IwataFn::new(12);
        let mut fw = FrankWolfe::new(&f, FwOptions::default(), None);
        run(&mut fw, &f, 3000, 1e-8);
        assert!(fw.gap() < 1e-8, "gap {}", fw.gap());
        let brute = brute_force_sfm(&f, 1e-9);
        assert_eq!(sup_level_set(fw.w(), 0.0), brute.minimal);
    }

    #[test]
    fn plain_fw_decreases_dual_objective() {
        let f = IwataFn::new(10);
        let mut fw = FrankWolfe::new(
            &f,
            FwOptions { variant: FwVariant::Plain, ..Default::default() },
            None,
        );
        let mut last_norm = f64::INFINITY;
        for _ in 0..200 {
            fw.step(&f);
            let n = norm2_sq(fw.s());
            assert!(n <= last_norm + 1e-9, "‖x‖² increased");
            last_norm = n;
        }
    }

    #[test]
    fn pairwise_matches_minnorm_solution() {
        let mut rng = Pcg64::seeded(23);
        let p = 10;
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let unary = rng.uniform_vec(p, -2.0, 2.0);
        let f = KernelCutFn::new(p, k, unary);

        let mut fw = FrankWolfe::new(&f, FwOptions::default(), None);
        run(&mut fw, &f, 5000, 1e-10);
        let mut mn = MinNormPoint::new(&f, MinNormOptions::default(), None);
        run(&mut mn, &f, 1000, 1e-10);

        // Min-norm point is unique: both solvers must agree.
        for (a, b) in fw.s().iter().zip(mn.s()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn away_variant_converges_and_weights_stay_convex() {
        let f = IwataFn::new(10);
        let mut fw = FrankWolfe::new(
            &f,
            FwOptions { variant: FwVariant::Away, ..Default::default() },
            None,
        );
        for _ in 0..4000 {
            let ev = fw.step(&f);
            let total: f64 = fw.atoms.iter().map(|(_, _, w)| w).sum();
            assert!((total - 1.0).abs() < 1e-6, "weights sum {total}");
            assert!(fw.atoms.iter().all(|(_, _, w)| *w >= -1e-12));
            if ev.gap < 1e-8 {
                break;
            }
        }
        assert!(fw.gap() < 1e-6, "away-step FW gap {}", fw.gap());
        let brute = brute_force_sfm(&f, 1e-9);
        assert_eq!(sup_level_set(fw.w(), 0.0), brute.minimal);
    }

    #[test]
    fn atom_weights_stay_convex() {
        let f = IwataFn::new(9);
        let mut fw = FrankWolfe::new(&f, FwOptions::default(), None);
        for _ in 0..100 {
            fw.step(&f);
            let total: f64 = fw.atoms.iter().map(|(_, _, w)| w).sum();
            assert!((total - 1.0).abs() < 1e-9, "weights sum {total}");
            assert!(fw.atoms.iter().all(|(_, _, w)| *w >= 0.0));
        }
    }
}
