//! Pool-adjacent-violators isotonic regression.
//!
//! Remark 2 of the paper: solvers that only maintain the dual iterate
//! `s ∈ B(F)` obtain a primal iterate by setting `w = −s` and *refining* it
//! with PAV. The refinement solves
//!
//! ```text
//! min_w  f(w) + ½‖w‖²   s.t.  w is measurable w.r.t. the greedy order
//! ```
//!
//! i.e. `min Σ_k (g_k w_k + ½ w_k²)` subject to `w_{k}` non-increasing in
//! the order positions, where `g_k` are the greedy marginal gains. The
//! unconstrained optimum is `w_k = −g_k`; the order constraint makes it the
//! **non-increasing isotonic regression of `−g`**, solved exactly by PAV in
//! O(n). This never increases the primal objective relative to `w = −s`,
//! so the duality gap — and therefore every screening ball — only tightens.

/// Non-increasing isotonic regression: returns `w` minimizing
/// `Σ (w_k − t_k)²` subject to `w_0 ≥ w_1 ≥ … ≥ w_{n−1}`.
pub fn pav_nonincreasing(t: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; t.len()];
    pav_nonincreasing_into(t, &mut out);
    out
}

/// In-place variant of [`pav_nonincreasing`] (no allocation beyond the
/// block stack, which is reused by callers via [`PavWorkspace`]).
pub fn pav_nonincreasing_into(t: &[f64], out: &mut [f64]) {
    let mut ws = PavWorkspace::default();
    ws.run(t, out);
}

/// Reusable block stack for PAV.
#[derive(Clone, Debug, Default)]
pub struct PavWorkspace {
    /// (sum, count) per merged block.
    blocks: Vec<(f64, usize)>,
}

impl PavWorkspace {
    /// Pre-size the block stack for inputs up to length `n`.
    pub fn reserve(&mut self, n: usize) {
        self.blocks.reserve(n);
    }

    /// Run non-increasing PAV on `t`, writing the fit into `out`.
    pub fn run(&mut self, t: &[f64], out: &mut [f64]) {
        assert_eq!(t.len(), out.len());
        self.blocks.clear();
        for &x in t {
            let mut sum = x;
            let mut count = 1usize;
            // Non-increasing fit: a later block's mean must not exceed an
            // earlier block's mean; merge while violated.
            while let Some(&(psum, pcount)) = self.blocks.last() {
                if sum / count as f64 > psum / pcount as f64 - 0.0 {
                    self.blocks.pop();
                    sum += psum;
                    count += pcount;
                } else {
                    break;
                }
            }
            self.blocks.push((sum, count));
        }
        let mut k = 0;
        for &(sum, count) in &self.blocks {
            let mean = sum / count as f64;
            for _ in 0..count {
                out[k] = mean;
                k += 1;
            }
        }
        debug_assert_eq!(k, t.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testutil::forall_rng;

    fn is_nonincreasing(w: &[f64]) -> bool {
        w.windows(2).all(|p| p[0] >= p[1] - 1e-12)
    }

    fn sse(w: &[f64], t: &[f64]) -> f64 {
        w.iter().zip(t).map(|(a, b)| (a - b) * (a - b)).sum()
    }

    #[test]
    fn already_sorted_is_identity() {
        let t = [5.0, 3.0, 1.0, -2.0];
        assert_eq!(pav_nonincreasing(&t), t.to_vec());
    }

    #[test]
    fn single_violator_pools() {
        let t = [1.0, 3.0];
        assert_eq!(pav_nonincreasing(&t), vec![2.0, 2.0]);
    }

    #[test]
    fn constant_input() {
        let t = [2.0; 5];
        assert_eq!(pav_nonincreasing(&t), t.to_vec());
    }

    #[test]
    fn fit_is_feasible_and_not_worse_than_constant() {
        forall_rng(50, |rng| {
            let n = 1 + rng.below(40);
            let t = rng.normal_vec(n);
            let w = pav_nonincreasing(&t);
            if !is_nonincreasing(&w) {
                return Err("fit not non-increasing".into());
            }
            // PAV is optimal; at minimum it beats the best constant fit.
            let mean = t.iter().sum::<f64>() / n as f64;
            let const_fit = vec![mean; n];
            if sse(&w, &t) > sse(&const_fit, &t) + 1e-9 {
                return Err("worse than constant fit".into());
            }
            Ok(())
        });
    }

    #[test]
    fn fit_is_optimal_vs_random_feasible_points() {
        forall_rng(30, |rng| {
            let n = 2 + rng.below(10);
            let t = rng.normal_vec(n);
            let w = pav_nonincreasing(&t);
            let base = sse(&w, &t);
            // Random non-increasing candidates must not beat PAV.
            for _ in 0..20 {
                let mut c = rng.normal_vec(n);
                c.sort_by(|a, b| b.partial_cmp(a).unwrap());
                if sse(&c, &t) < base - 1e-9 {
                    return Err(format!("candidate beats PAV: {} < {base}", sse(&c, &t)));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn block_means_preserve_total() {
        let mut rng = Pcg64::seeded(7);
        let t = rng.normal_vec(100);
        let w = pav_nonincreasing(&t);
        let st: f64 = t.iter().sum();
        let sw: f64 = w.iter().sum();
        assert!((st - sw).abs() < 1e-9, "PAV preserves block sums");
    }
}
