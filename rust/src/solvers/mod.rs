//! Solvers for the proximal pair (Q-P)/(Q-D).
//!
//! Both solvers optimize the dual `max_{s∈B(F)} −½‖s‖²` (equivalently: find
//! the minimum-norm point of the base polytope) using only greedy
//! linear-maximization oracles, and maintain a primal iterate `ŵ` via the
//! pool-adjacent-violators refinement of Remark 2. Each major iteration
//! performs exactly **one** greedy pass, from which it extracts, for free:
//!
//! * the Frank–Wolfe/Wolfe vertex `q = argmax_{s∈B} ⟨−x, s⟩`,
//! * the best super-level-set value `F̂(C)` (Remark 1 — feeds the Ω
//!   estimate of Theorem 3),
//! * the PAV-refined primal `ŵ` and the duality gap
//!   `G(ŵ, x) = f(ŵ) + ½‖ŵ‖² + ½‖x‖²`.
//!
//! The IAES engine drives solvers through the [`ProxSolver`] trait and
//! rebuilds them on the reduced problem after every successful screening
//! round (Algorithm 2, step 14).

pub mod frankwolfe;
pub mod minnorm;
pub mod pav;
pub mod queyranne;

use crate::linalg::vecops::{dot, norm2_sq};
use crate::lovasz::{greedy_base_vertex, ContractionMap, GreedyInfo, GreedyWorkspace};
use crate::solvers::pav::PavWorkspace;
use crate::submodular::Submodular;

/// Per-iteration summary emitted by [`ProxSolver::step`].
#[derive(Clone, Copy, Debug)]
pub struct SolverEvent {
    /// Major-iteration counter (1-based after the first step).
    pub iter: usize,
    /// Duality gap `G(ŵ, ŝ) = P(ŵ) − D(ŝ)`.
    pub gap: f64,
    /// Wolfe gap `⟨x, x − q⟩` (exactness certificate for the min-norm
    /// point; ≤ 0 means `x` is optimal up to numerics).
    pub wolfe_gap: f64,
    /// Best super-level-set value `F̂(C)` observed so far (≤ 0).
    pub fc: f64,
    /// Dual objective `−½‖ŝ‖²`.
    pub dual_value: f64,
    /// Primal objective `f(ŵ) + ½‖ŵ‖²`.
    pub primal_value: f64,
}

/// Per-phase nanosecond accumulators drained by the IAES engine at
/// major-iteration boundaries (trace plumbing; see
/// [`obs::trace`](crate::obs::trace)). All-zero unless trace timing is
/// enabled on the solver.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseNs {
    /// Nanoseconds inside greedy/certificate oracle passes.
    pub oracle_ns: u64,
    /// Decompose only: nanoseconds inside the block best-response
    /// sweeps, split by component kind (slots follow
    /// `obs::trace::KIND_*`). All-zero for monolithic solvers.
    pub kind_ns: [u64; 4],
}

/// Portable snapshot of a solver's dual state at a major-iteration
/// boundary, exported for checkpointing (see
/// [`screening::checkpoint`](crate::screening::checkpoint)). Atoms are
/// stored as their **generating greedy permutations** — the same
/// combinatorial state the warm-restart machinery persists across
/// contractions — never as raw coordinates: a restore replays each order
/// on the (possibly contracted) oracle and obtains vertices of the
/// *current* base polytope by construction, exactly the regeneration
/// invariant of [`reset_mapped`](ProxSolver::reset_mapped).
#[derive(Clone, Debug, PartialEq)]
pub struct SolverState {
    /// [`ProxSolver::name`] of the exporting solver; a restore rejects
    /// snapshots of a different kind.
    pub kind: String,
    /// Generating greedy permutation per atom (corral rows / FW atoms),
    /// in reduced coordinates of the checkpointed problem.
    pub orders: Vec<Vec<usize>>,
    /// Convex weight per atom, parallel to `orders`.
    pub weights: Vec<f64>,
    /// Dual iterate `ŝ = Σ λᵢ vᵢ` at export time. Restore validates the
    /// regenerated convex combination against this vector — a mismatch
    /// means the snapshot does not describe the given problem.
    pub dual: Vec<f64>,
    /// Decomposed runs only: per-component dual state, in component
    /// order. Empty for monolithic solvers.
    pub components: Vec<ComponentState>,
}

/// Per-component dual state of the block-prox solver (decomposed runs).
#[derive(Clone, Debug, PartialEq)]
pub struct ComponentState {
    /// Component dual `y_i ∈ B(F̂_i)`, in the component's local reduced
    /// coordinates at the checkpointed reduction.
    pub y: Vec<f64>,
    /// Prox center the component's inner solver last warm-started from
    /// (`z_prev`); restored for faithfulness, consumed only once the
    /// inner solver warms back up.
    pub z_prev: Vec<f64>,
}

/// A dual solver for (Q-D) that also maintains the PAV-refined primal.
pub trait ProxSolver {
    /// One major iteration (exactly one greedy oracle pass).
    fn step(&mut self, f: &dyn Submodular) -> SolverEvent;

    /// Current dual iterate `ŝ ∈ B(F̂)`.
    fn s(&self) -> &[f64];

    /// Current primal iterate `ŵ` (PAV refinement of `−ŝ`).
    fn w(&self) -> &[f64];

    /// Current duality gap (`+∞` before the first step).
    fn gap(&self) -> f64;

    /// Best super-level-set value `F̂(C)` seen so far (0 before any step).
    fn best_level_value(&self) -> f64;

    /// Major iterations performed.
    fn iters(&self) -> usize;

    /// Re-initialize on a (typically reduced) problem: `ŝ ← argmax_{s ∈
    /// B(F̂)} ⟨w_init, s⟩` (one greedy pass), primal `ŵ ← w_init`
    /// (Algorithm 2, step 14). This is the *cold* restart: all corral /
    /// atom state is discarded.
    fn reset(&mut self, f: &dyn Submodular, w_init: &[f64]);

    /// Contraction-aware warm restart: like [`reset`](Self::reset), but
    /// `f` is the Lemma-1 contraction of the problem the solver was just
    /// running, described by `map` (old reduced index → new reduced
    /// index). Implementations project their combinatorial state — the
    /// persisted greedy order, the corral / atom set — onto the surviving
    /// coordinates and revalidate it instead of discarding it, so the
    /// restart is an incremental solver event rather than a cold rebuild.
    ///
    /// The default implementation falls back to the cold [`reset`], which
    /// is always correct; solvers that can do better override it. The
    /// map's `remap_argsort` flag only switches *how* the greedy order is
    /// re-derived (remap + repair vs full re-sort) and never changes a
    /// bit of the result.
    fn reset_mapped(&mut self, f: &dyn Submodular, w_init: &[f64], map: &ContractionMap) {
        let _ = map;
        self.reset(f, w_init);
    }

    /// Cumulative full (non-incremental) greedy argsorts performed by
    /// this solver's workspace — cold starts, resizes, and repair-budget
    /// bailouts. The warm-restart tests assert this does not move across
    /// a contraction.
    fn greedy_full_sorts(&self) -> u64;

    /// Install (or clear) a shared worker pool for pooled greedy oracle
    /// passes ([`GreedyWorkspace::set_pool`]): the IAES engine calls
    /// this once per monolithic `--threads N` run so every greedy pass —
    /// major iterations, restarts, atom regeneration — fans its oracle
    /// inner loops across the pool. Pooled passes are bit-identical to
    /// sequential ones, so this never changes a trajectory. The default
    /// is a no-op for solvers that own their parallelism (the block
    /// solver) or do no greedy passes.
    fn set_pool(&mut self, pool: Option<std::sync::Arc<crate::runtime::pool::WorkerPool>>) {
        let _ = pool;
    }

    /// Enable (or disable) boundary phase timing. When on, the solver
    /// accumulates per-phase nanoseconds for
    /// [`take_phase_ns`](Self::take_phase_ns); the IAES engine flips
    /// this once per run when a trace sink is attached. Timing only
    /// reads clocks around existing spans — it never changes a
    /// trajectory bit (pinned by the traced-vs-untraced determinism
    /// tests). The default is a no-op for solvers with no phases to
    /// report.
    fn set_trace_timing(&mut self, enabled: bool) {
        let _ = enabled;
    }

    /// Drain the per-phase nanoseconds accumulated since the last call
    /// (zeroing the accumulators). Always default when trace timing is
    /// off.
    fn take_phase_ns(&mut self) -> PhaseNs {
        PhaseNs::default()
    }

    /// Export a portable snapshot of the dual state for checkpointing,
    /// or `None` when the solver maintains no replayable atom
    /// decomposition (plain Frank–Wolfe): a resume then falls back to
    /// the cold step-14 reset at the checkpoint's reduction, which is
    /// always safe — the screening progress lives in the element sets,
    /// not the solver.
    fn export_state(&self) -> Option<SolverState> {
        None
    }

    /// Rebuild dual state from a checkpoint snapshot on `f` (the problem
    /// at the checkpoint's reduction): replay each stored order on the
    /// oracle, revalidate, land on the stored convex combination, then
    /// run the step-14 bookkeeping against `w_init` so the gap is a
    /// valid screening radius again. Errors mean the snapshot does not
    /// describe a valid state of `f` (corrupted or mismatched
    /// checkpoint); the solver must be reset before further use.
    fn restore(
        &mut self,
        f: &dyn Submodular,
        w_init: &[f64],
        state: &SolverState,
    ) -> anyhow::Result<()> {
        let _ = (f, w_init);
        anyhow::bail!(
            "solver '{}' cannot restore snapshots of kind '{}'",
            self.name(),
            state.kind
        )
    }

    /// Human-readable solver name (reports/benches).
    fn name(&self) -> &'static str;
}

/// Shared primal/dual bookkeeping used by both solver implementations.
///
/// Owns the greedy + PAV workspaces and the `ŵ`/gap state; solvers keep
/// their own dual representation (`x`, corral / atom weights).
#[derive(Clone, Debug)]
pub(crate) struct PrimalState {
    pub w: Vec<f64>,
    pub gap: f64,
    pub fc: f64,
    pub iters: usize,
    pub greedy_ws: GreedyWorkspace,
    pub pav_ws: PavWorkspace,
    pav_buf: Vec<f64>,
    neg_gain_buf: Vec<f64>,
    /// Trace-timing gate: when set, every greedy pass is clocked into
    /// `oracle_ns`. Off by default — an untraced solve reads no clocks
    /// here.
    pub trace_timing: bool,
    /// Nanoseconds spent in greedy passes since the last drain.
    pub oracle_ns: u64,
}

impl PrimalState {
    pub fn new(p: usize) -> Self {
        PrimalState {
            w: vec![0.0; p],
            gap: f64::INFINITY,
            fc: 0.0,
            iters: 0,
            greedy_ws: GreedyWorkspace::new(p),
            pav_ws: PavWorkspace::default(),
            pav_buf: vec![0.0; p],
            neg_gain_buf: vec![0.0; p],
            trace_timing: false,
            oracle_ns: 0,
        }
    }

    /// Drain the greedy-span accumulator (zero unless
    /// [`trace_timing`](Self::trace_timing) is set).
    pub fn take_oracle_ns(&mut self) -> u64 {
        std::mem::take(&mut self.oracle_ns)
    }

    pub fn resize(&mut self, p: usize) {
        self.w.resize(p, 0.0);
        self.pav_buf.resize(p, 0.0);
        self.neg_gain_buf.resize(p, 0.0);
        self.gap = f64::INFINITY;
        self.fc = 0.0;
        self.iters = 0;
    }

    /// One greedy pass in direction `−x`; writes the maximizing vertex into
    /// `q`, updates `fc`, recomputes the PAV primal `ŵ` and its Lovász
    /// value. Returns `(info, f(ŵ))`.
    pub fn greedy_and_refine(
        &mut self,
        f: &dyn Submodular,
        x: &[f64],
        q: &mut [f64],
    ) -> (GreedyInfo, f64) {
        let p = x.len();
        debug_assert_eq!(self.w.len(), p);
        // Direction −x (no allocation: reuse pav_buf temporarily).
        for (d, &xi) in self.pav_buf.iter_mut().zip(x) {
            *d = -xi;
        }
        let dir = std::mem::take(&mut self.pav_buf);
        // Boundary-discipline clock: read only around the whole oracle
        // pass, and only when a trace sink armed the gate.
        let t0 = self.trace_timing.then(std::time::Instant::now);
        let info = greedy_base_vertex(f, &dir, &mut self.greedy_ws, q);
        if let Some(t0) = t0 {
            self.oracle_ns += t0.elapsed().as_nanos() as u64;
        }
        self.pav_buf = dir;
        self.fc = self.fc.min(info.best_level_value);

        // PAV refinement along the greedy order: targets are −gains.
        for (t, &g) in self.neg_gain_buf.iter_mut().zip(&self.greedy_ws.gains) {
            *t = -g;
        }
        self.pav_ws.run(&self.neg_gain_buf[..p], &mut self.pav_buf[..p]);
        // f(ŵ) = Σ_k ŵ_sorted[k] · gains[k] (order-consistent by PAV).
        let mut f_w = 0.0;
        for (k, &j) in self.greedy_ws.order.iter().enumerate() {
            let v = self.pav_buf[k];
            self.w[j] = v;
            f_w += v * self.greedy_ws.gains[k];
        }
        (info, f_w)
    }

    /// Finalize the iteration: compute the gap against the (updated) dual
    /// point and emit the event.
    pub fn finish_step(&mut self, f_w: f64, x: &[f64], wolfe_gap: f64) -> SolverEvent {
        self.iters += 1;
        let primal = f_w + 0.5 * norm2_sq(&self.w);
        let dual = -0.5 * norm2_sq(x);
        self.gap = primal - dual;
        SolverEvent {
            iter: self.iters,
            gap: self.gap,
            wolfe_gap,
            fc: self.fc,
            dual_value: dual,
            primal_value: primal,
        }
    }

    /// Algorithm-2 step 14 bookkeeping shared by cold and warm restarts:
    /// adopt `w_init` as the primal and run one greedy pass to obtain the
    /// matching dual vertex `ŝ` (written into `s_out`). Returns
    /// `f(w_init) = ⟨w_init, ŝ⟩` so the caller can close the gap against
    /// whatever dual point it adopts (the vertex itself for a cold reset,
    /// the projected corral's min-norm point for a warm one). Leaves
    /// `self.gap` untouched.
    pub fn reset_primal(
        &mut self,
        f: &dyn Submodular,
        w_init: &[f64],
        s_out: &mut [f64],
    ) -> f64 {
        let p = f.ground_size();
        self.resize(p);
        self.w.copy_from_slice(w_init);
        let t0 = self.trace_timing.then(std::time::Instant::now);
        let info = greedy_base_vertex(f, w_init, &mut self.greedy_ws, s_out);
        if let Some(t0) = t0 {
            self.oracle_ns += t0.elapsed().as_nanos() as u64;
        }
        self.fc = self.fc.min(info.best_level_value);
        dot(w_init, s_out)
    }

    /// Algorithm 2 step 14: adopt `w_init` as the primal and run one greedy
    /// pass to obtain the matching dual vertex (returned in `s_out`).
    pub fn reset_from(
        &mut self,
        f: &dyn Submodular,
        w_init: &[f64],
        s_out: &mut [f64],
    ) {
        // Gap for the fresh pair (w_init, s): f(w_init) = ⟨w_init, s⟩.
        let f_w = self.reset_primal(f, w_init, s_out);
        let primal = f_w + 0.5 * norm2_sq(w_init);
        let dual = -0.5 * norm2_sq(s_out);
        self.gap = primal - dual;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::iwata::IwataFn;

    #[test]
    fn primal_state_reset_gap_nonnegative() {
        let f = IwataFn::new(12);
        let mut st = PrimalState::new(12);
        let w0 = vec![0.0; 12];
        let mut s = vec![0.0; 12];
        st.reset_from(&f, &w0, &mut s);
        assert!(st.gap >= -1e-9, "gap {}", st.gap);
        assert!(st.gap.is_finite());
    }

    #[test]
    fn greedy_and_refine_gap_monotone_vs_unrefined() {
        // PAV primal must be at least as good as w = −x.
        let f = IwataFn::new(10);
        let mut st = PrimalState::new(10);
        let x: Vec<f64> = (0..10).map(|i| (i as f64) - 4.5).collect();
        let mut q = vec![0.0; 10];
        let (_, f_w) = st.greedy_and_refine(&f, &x, &mut q);
        let primal_refined = f_w + 0.5 * norm2_sq(&st.w);
        // Unrefined primal at w = −x:
        let neg_x: Vec<f64> = x.iter().map(|v| -v).collect();
        let f_negx = crate::lovasz::lovasz_value(&f, &neg_x);
        let primal_unrefined = f_negx + 0.5 * norm2_sq(&neg_x);
        assert!(primal_refined <= primal_unrefined + 1e-9);
    }
}
