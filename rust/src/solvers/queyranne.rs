//! Queyranne's algorithm — the combinatorial baseline for *symmetric*
//! submodular function minimization.
//!
//! For symmetric `F` (`F(A) = F(V∖A)`, e.g. pure graph cuts), Queyranne
//! (1998) finds `min_{∅ ≠ A ⊊ V} F(A)` with O(p³) oracle calls via
//! pendant pairs — no convex optimization at all. It serves two roles
//! here:
//!
//! 1. an independent correctness oracle for the proximal/IAES pipeline on
//!    symmetric instances (mid-sized instances where brute force is
//!    impossible but O(p³) is fine), and
//! 2. the baseline a reviewer would ask for: "how does screening-
//!    accelerated min-norm compare to a purpose-built combinatorial
//!    algorithm?" (micro bench `queyranne` rows).
//!
//! Note the problem differs from general SFM by excluding ∅ and V (for
//! symmetric F both have value 0 and are always minimizers).

use crate::submodular::Submodular;

/// Result of a Queyranne run.
#[derive(Clone, Debug)]
pub struct QueyranneResult {
    /// The best non-trivial set found.
    pub minimizer: Vec<usize>,
    /// Its value.
    pub minimum: f64,
    /// Oracle (eval) calls performed.
    pub oracle_calls: usize,
}

/// Minimize a symmetric submodular function over `∅ ≠ A ⊊ V`.
///
/// The function is *not* checked for symmetry (callers assert it in
/// tests); on non-symmetric input the result is a heuristic upper bound.
pub fn queyranne<F: Submodular + ?Sized>(f: &F) -> QueyranneResult {
    let p = f.ground_size();
    assert!(p >= 2, "need at least two elements");
    let mut calls = 0usize;

    // Work on "merged" super-elements: groups[i] = original ids.
    let mut groups: Vec<Vec<usize>> = (0..p).map(|i| vec![i]).collect();
    let mut best_value = f64::INFINITY;
    let mut best_set: Vec<usize> = Vec::new();

    let mut set_buf = vec![false; p];
    let eval_groups = |gs: &[usize], groups: &Vec<Vec<usize>>,
                           set_buf: &mut Vec<bool>, calls: &mut usize|
     -> f64 {
        set_buf.iter_mut().for_each(|b| *b = false);
        for &g in gs {
            for &i in &groups[g] {
                set_buf[i] = true;
            }
        }
        *calls += 1;
        f.eval(set_buf)
    };

    while groups.len() > 1 {
        // Find a pendant pair (t, u) by the maximum-adjacency order:
        // W starts from group 0; repeatedly add the group maximizing
        // F(W ∪ {x}) − F({x})  (the "key"), minimized... Queyranne's key:
        // choose next x minimizing F(W ∪ {x}) − F({x}).
        let n = groups.len();
        let mut order = Vec::with_capacity(n);
        let mut in_w = vec![false; n];
        order.push(0);
        in_w[0] = true;
        let mut w_members: Vec<usize> = vec![0];
        for _ in 1..n {
            let mut best_key = f64::INFINITY;
            let mut best_x = usize::MAX;
            for x in 0..n {
                if in_w[x] {
                    continue;
                }
                let mut with_x = w_members.clone();
                with_x.push(x);
                let fw = eval_groups(&with_x, &groups, &mut set_buf, &mut calls);
                let fx = eval_groups(&[x], &groups, &mut set_buf, &mut calls);
                let key = fw - fx;
                if key < best_key {
                    best_key = key;
                    best_x = x;
                }
            }
            order.push(best_x);
            in_w[best_x] = true;
            w_members.push(best_x);
        }
        // The last element u of the order forms a pendant pair with the
        // second-to-last t: {u} (as a merged group) is a candidate cut.
        let u = order[n - 1];
        let t = order[n - 2];
        let cut_value = eval_groups(&[u], &groups, &mut set_buf, &mut calls);
        if cut_value < best_value {
            best_value = cut_value;
            best_set = groups[u].clone();
        }
        // Merge the pendant pair (t, u) into one super-element.
        let (keep, drop) = (t.min(u), t.max(u));
        let dropped = groups.remove(drop);
        groups[keep].extend(dropped);
    }

    best_set.sort_unstable();
    QueyranneResult { minimizer: best_set, minimum: best_value, oracle_calls: calls }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::submodular::cut::CutFn;
    use crate::submodular::SubmodularExt;

    fn random_symmetric_cut(p: usize, density: f64, rng: &mut Pcg64) -> CutFn {
        let mut edges = Vec::new();
        for i in 0..p {
            for j in (i + 1)..p {
                if rng.bernoulli(density) {
                    edges.push((i, j, rng.uniform(0.1, 2.0)));
                }
            }
        }
        // Ensure connectivity-ish with a cycle.
        for i in 0..p {
            edges.push((i, (i + 1) % p, rng.uniform(0.1, 0.5)));
        }
        CutFn::from_edges(p, &edges, vec![0.0; p])
    }

    fn brute_nontrivial_min(f: &dyn Submodular) -> f64 {
        let p = f.ground_size();
        let mut best = f64::INFINITY;
        for mask in 1u64..((1 << p) - 1) {
            let set: Vec<bool> = (0..p).map(|i| mask >> i & 1 == 1).collect();
            best = best.min(f.eval(&set));
        }
        best
    }

    #[test]
    fn matches_brute_force_on_random_cuts() {
        let mut rng = Pcg64::seeded(5150);
        for trial in 0..8 {
            let p = 4 + trial % 6;
            let f = random_symmetric_cut(p, 0.4, &mut rng);
            let q = queyranne(&f);
            let brute = brute_nontrivial_min(&f);
            assert!(
                (q.minimum - brute).abs() < 1e-9,
                "trial {trial}: queyranne {} vs brute {brute}",
                q.minimum
            );
            // Returned set must attain the value and be non-trivial.
            assert!(!q.minimizer.is_empty() && q.minimizer.len() < p);
            assert!((f.eval_ids(&q.minimizer) - q.minimum).abs() < 1e-9);
        }
    }

    #[test]
    fn barbell_graph_cuts_the_bridge() {
        // Two triangles joined by one weak edge: the min cut is the bridge.
        let mut edges = vec![
            (0, 1, 5.0),
            (1, 2, 5.0),
            (0, 2, 5.0),
            (3, 4, 5.0),
            (4, 5, 5.0),
            (3, 5, 5.0),
            (2, 3, 0.1),
        ];
        edges.dedup();
        let f = CutFn::from_edges(6, &edges, vec![0.0; 6]);
        let q = queyranne(&f);
        assert!((q.minimum - 0.1).abs() < 1e-12);
        let side: Vec<usize> = q.minimizer.clone();
        assert!(side == vec![0, 1, 2] || side == vec![3, 4, 5]);
    }

    #[test]
    fn oracle_call_count_is_cubic_ish() {
        let mut rng = Pcg64::seeded(5151);
        let f = random_symmetric_cut(12, 0.3, &mut rng);
        let q = queyranne(&f);
        // 2·Σ_{n=2..p} (n−1)·n ≈ O(p³); loose upper bound 2p³.
        assert!(q.oracle_calls < 2 * 12 * 12 * 12, "calls {}", q.oracle_calls);
    }
}
