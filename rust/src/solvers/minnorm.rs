//! Fujishige–Wolfe minimum-norm-point algorithm (the paper's solver A).
//!
//! Wolfe (1976) computes the nearest point to the origin of a polytope
//! given only a linear-maximization oracle — here Edmonds' greedy over the
//! base polytope `B(F)`. Fujishige's theorem then reads the SFM minimizers
//! off the sign pattern of the min-norm point: `A*_min = {−x* > 0}`,
//! `A*_max = {−x* ≥ 0}` — i.e. `w* = −x*` solves (Q-P).
//!
//! Implementation notes:
//!
//! * The corral Gram system is maintained as an incremental Cholesky
//!   factor of `M = 11ᵀ + SᵀS` (positive definite while the corral is
//!   affinely independent — Wolfe's classic trick). Adding a vertex is a
//!   rank-one `push`, evicting one is a Givens `remove`; both O(|corral|²)
//!   instead of the O(|corral|³) re-factorization a naive implementation
//!   pays per minor cycle.
//! * Affine weights solve `M ᾱ = 1`, normalized to `Σα = 1`.
//! * Numerical breakdowns (affine dependence, cancellation) trigger a
//!   from-scratch re-factorization with jitter; vertices whose pivot
//!   vanishes are dropped. This is the standard robustness recipe
//!   (Fujishige–Isotani 2011).

use super::{PrimalState, ProxSolver, SolverEvent};
use crate::linalg::vecops::{dot, norm2_sq};
use crate::linalg::{CorralMat, IncrementalCholesky, IndexMat};
use crate::lovasz::{vertex_from_order, ContractionMap};
use crate::submodular::Submodular;

/// Options for [`MinNormPoint`].
#[derive(Clone, Copy, Debug)]
pub struct MinNormOptions {
    /// Wolfe-gap tolerance: a major cycle that improves `⟨x, x⟩ − ⟨x, q⟩`
    /// by less than this declares `x` optimal.
    pub wolfe_tol: f64,
    /// Coefficients below this are treated as zero in minor cycles.
    pub lambda_tol: f64,
    /// Cholesky jitter used on rebuilds.
    pub jitter: f64,
    /// Safety cap on minor cycles per major cycle.
    pub max_minor: usize,
}

impl Default for MinNormOptions {
    fn default() -> Self {
        MinNormOptions {
            wolfe_tol: 1e-12,
            lambda_tol: 1e-12,
            jitter: 1e-12,
            max_minor: 1000,
        }
    }
}

/// Fujishige–Wolfe solver state.
///
/// Steady-state `step` calls perform **zero heap allocations**: the corral
/// is a flat [`CorralMat`], the Gram factor is packed-flat, and every
/// transient (cross row, ones RHS, affine weights, oracle scratch) lives
/// in a reused buffer. Only genuine state growth (corral high-water mark,
/// first pass at a new problem size) touches the allocator.
pub struct MinNormPoint {
    opts: MinNormOptions,
    /// Current point `x = Σ λ_i v_i` (the dual iterate `ŝ`).
    x: Vec<f64>,
    /// Corral vertices, flat row-major (stride = p).
    corral: CorralMat,
    /// Generating greedy permutation of each corral vertex, parallel to
    /// `corral` — the combinatorial state that survives an IAES
    /// contraction: replaying an atom's induced order on the contracted
    /// oracle regenerates a valid vertex of the new base polytope.
    orders: IndexMat,
    /// Convex weights over the corral.
    lambda: Vec<f64>,
    /// Cholesky factor of `11ᵀ + SᵀS`.
    chol: IncrementalCholesky,
    shared: PrimalState,
    /// Scratch vertex buffer.
    q: Vec<f64>,
    /// Scratch: cross-products row for Gram pushes.
    cross: Vec<f64>,
    /// Scratch: all-ones RHS for the affine system.
    ones: Vec<f64>,
    /// Scratch: affine minimizer weights.
    alpha: Vec<f64>,
    /// Scratch: surviving-atom indices for batch evictions/rebuilds.
    keep_buf: Vec<usize>,
}

impl MinNormPoint {
    /// Initialize on `f`, starting from the greedy vertex in direction
    /// `w_init` (zeros → index order, the paper's "choose ŝ ∈ B(F)").
    pub fn new(f: &dyn Submodular, opts: MinNormOptions, w_init: Option<&[f64]>) -> Self {
        let p = f.ground_size();
        let mut solver = MinNormPoint {
            opts,
            x: vec![0.0; p],
            corral: CorralMat::new(p),
            orders: IndexMat::new(p),
            lambda: Vec::new(),
            chol: IncrementalCholesky::new(),
            shared: PrimalState::new(p),
            q: vec![0.0; p],
            cross: Vec::new(),
            ones: Vec::new(),
            alpha: Vec::new(),
            keep_buf: Vec::new(),
        };
        let w0 = match w_init {
            Some(w) => w.to_vec(),
            None => vec![0.0; p],
        };
        solver.reset(f, &w0);
        solver
    }

    /// Current corral size (diagnostics / benches).
    pub fn corral_size(&self) -> usize {
        self.corral.len()
    }

    /// Push `v` into the corral (copied into flat storage — the caller
    /// keeps its buffer; nothing is cloned on the hot path). The vertex's
    /// generating greedy order is recorded from the shared workspace,
    /// which always holds it right after the pass that produced `v`.
    fn push_vertex(&mut self, v: &[f64]) -> bool {
        self.cross.clear();
        self.cross.extend(self.corral.iter().map(|u| 1.0 + dot(u, v)));
        let diag = 1.0 + norm2_sq(v);
        match self.chol.push(&self.cross, diag, self.opts.jitter) {
            Some(_) => {
                self.corral.push(v);
                self.orders.push(&self.shared.greedy_ws.order);
                self.lambda.push(0.0);
                true
            }
            None => false, // affinely dependent — skip
        }
    }

    /// Drop every corral atom whose index is *not* in `keep` (ascending):
    /// one compaction sweep over the parallel arrays and one batched
    /// Cholesky downdate, instead of an O(m²) restructuring per eviction.
    fn evict_except(&mut self, keep: &[usize]) {
        debug_assert!(keep.len() < self.corral.len());
        for (w, &r) in keep.iter().enumerate() {
            self.lambda[w] = self.lambda[r];
        }
        self.lambda.truncate(keep.len());
        self.corral.compact(keep);
        self.orders.compact(keep);
        self.chol.retain(keep);
    }

    /// Rebuild the Cholesky factor from the current corral, dropping
    /// atoms whose pivot vanishes (affine dependence). Used both by the
    /// numerical recovery path and by the projected-corral restart;
    /// allocation-free at the high-water mark (the survivor buffer is
    /// reused).
    fn rebuild_chol(&mut self) {
        self.chol.reset();
        let mut keep = std::mem::take(&mut self.keep_buf);
        keep.clear();
        for i in 0..self.corral.len() {
            self.cross.clear();
            for &r in &keep {
                self.cross.push(1.0 + dot(self.corral.row(r), self.corral.row(i)));
            }
            let diag = 1.0 + norm2_sq(self.corral.row(i));
            if self.chol.push(&self.cross, diag, self.opts.jitter).is_some() {
                keep.push(i);
            }
        }
        if keep.len() != self.corral.len() {
            for (w, &r) in keep.iter().enumerate() {
                self.lambda[w] = self.lambda[r];
            }
            self.lambda.truncate(keep.len());
            self.corral.compact(&keep);
            self.orders.compact(&keep);
            let total: f64 = self.lambda.iter().sum();
            if total > 0.0 {
                for l in self.lambda.iter_mut() {
                    *l /= total;
                }
            } else if !self.lambda.is_empty() {
                let u = 1.0 / self.lambda.len() as f64;
                self.lambda.iter_mut().for_each(|l| *l = u);
            }
        }
        self.keep_buf = keep;
    }

    /// Affine minimizer weights over the current corral: solve
    /// `(11ᵀ + SᵀS) ᾱ = 1` into `self.alpha`, normalize. Returns `false`
    /// on breakdown. Allocation-free once the buffers reached size.
    fn affine_weights(&mut self) -> bool {
        let m = self.corral.len();
        if m == 0 {
            return false;
        }
        self.ones.clear();
        self.ones.resize(m, 1.0);
        self.chol.solve_into(&self.ones, &mut self.alpha);
        let total: f64 = self.alpha.iter().sum();
        if !total.is_finite() || total.abs() < 1e-300 {
            return false;
        }
        for a in self.alpha.iter_mut() {
            *a /= total;
        }
        true
    }

    fn recompute_x(&mut self) {
        self.x.iter_mut().for_each(|v| *v = 0.0);
        for (l, v) in self.lambda.iter().zip(self.corral.iter()) {
            if *l != 0.0 {
                for (xi, vi) in self.x.iter_mut().zip(v) {
                    *xi += l * vi;
                }
            }
        }
    }

    /// Translation-aware warm reset for block-prox reuse: the new
    /// problem's base polytope is the previous one translated
    /// coordinate-wise by `delta` (`B(F + m_{z'}) = B(F + m_z) +
    /// (z' − z)` — a modular shift moves the polytope, it never reshapes
    /// it). Every corral atom is a greedy vertex of the old polytope
    /// generated by its stored order; translating it by `delta` yields
    /// exactly the vertex the same order generates on the new polytope
    /// (gains shift coordinate-wise, independent of the order), so the
    /// corral — and the dual progress it encodes — survives the shift
    /// without one oracle pass per atom. The Gram factor is revalidated
    /// via [`rebuild_chol`](Self::rebuild_chol) (translations change
    /// inner products and can create affine dependence), then the usual
    /// step-14 bookkeeping runs: adopt `w_init`, push the fresh greedy
    /// vertex, land the dual on the min-norm point of the carried corral.
    ///
    /// Falls back to the cold [`reset`](ProxSolver::reset) when the
    /// solver holds no state at this problem size (fresh solver, post-
    /// contraction size change). Allocation-free at the high-water mark —
    /// the decomposable block solver calls this once per generic
    /// component per best-response round.
    pub fn reset_translated(&mut self, f: &dyn Submodular, delta: &[f64], w_init: &[f64]) {
        let p = f.ground_size();
        assert_eq!(delta.len(), p);
        if self.x.len() != p
            || self.corral.is_empty()
            || self.corral.len() != self.orders.len()
            || self.orders.stride() != p
        {
            self.reset(f, w_init);
            return;
        }
        for i in 0..self.corral.len() {
            for (v, &d) in self.corral.row_mut(i).iter_mut().zip(delta) {
                *v += d;
            }
        }
        self.rebuild_chol();
        let total: f64 = self.lambda.iter().sum();
        if total > 0.0 {
            for l in self.lambda.iter_mut() {
                *l /= total;
            }
        }
        let mut s0 = std::mem::take(&mut self.q);
        s0.clear();
        s0.resize(p, 0.0);
        let f_w = self.shared.reset_primal(f, w_init, &mut s0);
        self.push_vertex(&s0);
        self.q = s0;
        if self.corral.len() > 1 {
            self.minor_cycles();
        } else {
            if !self.lambda.is_empty() {
                self.lambda[0] = 1.0;
            }
            self.recompute_x();
        }
        // Weak duality holds for any x in B(F̂ + m_z), so the gap stays a
        // valid screening radius after the translation.
        let primal = f_w + 0.5 * norm2_sq(w_init);
        let dual = -0.5 * norm2_sq(&self.x);
        self.shared.gap = primal - dual;
        crate::lovasz::debug_assert_dual_feasible(
            f,
            &self.x,
            "MinNormPoint::reset_translated",
        );
    }

    /// Wolfe minor cycles: move `x` to the min-norm point of the corral's
    /// convex hull, evicting vertices whose weight hits zero.
    fn minor_cycles(&mut self) {
        for _ in 0..self.opts.max_minor {
            if !self.affine_weights() {
                self.rebuild_chol();
                if !self.affine_weights() {
                    break;
                }
            }
            let min_alpha =
                self.alpha.iter().cloned().fold(f64::INFINITY, f64::min);
            if min_alpha >= -self.opts.lambda_tol {
                // Affine minimizer is feasible — adopt it.
                self.lambda.clear();
                self.lambda.extend(self.alpha.iter().map(|a| a.max(0.0)));
                let total: f64 = self.lambda.iter().sum();
                for l in self.lambda.iter_mut() {
                    *l /= total;
                }
                break;
            }
            // Line search toward the affine minimizer, stopping at the
            // first coefficient that hits zero.
            let mut theta = f64::INFINITY;
            for (&l, &a) in self.lambda.iter().zip(&self.alpha) {
                if a < l {
                    let t = l / (l - a);
                    if t < theta {
                        theta = t;
                    }
                }
            }
            let theta = theta.clamp(0.0, 1.0);
            for (l, &a) in self.lambda.iter_mut().zip(&self.alpha) {
                *l = (1.0 - theta) * *l + theta * a;
            }
            // Evict zeros — all of them in one batched compaction sweep
            // (weights rescale together, so several can cross the
            // tolerance in the same minor cycle).
            let mut keep = std::mem::take(&mut self.keep_buf);
            keep.clear();
            let tol = self.opts.lambda_tol;
            keep.extend(
                self.lambda
                    .iter()
                    .enumerate()
                    .filter(|&(_, &l)| l > tol)
                    .map(|(i, _)| i),
            );
            let evicted = keep.len() != self.lambda.len();
            if evicted {
                self.evict_except(&keep);
            }
            self.keep_buf = keep;
            if !evicted {
                // θ hit 1 without eviction (numerical): we're at the affine
                // minimizer already.
                break;
            }
            if self.corral.len() <= 1 {
                break;
            }
        }
        // Renormalize for safety.
        let total: f64 = self.lambda.iter().sum();
        if total > 0.0 && (total - 1.0).abs() > 1e-12 {
            for l in self.lambda.iter_mut() {
                *l /= total;
            }
        }
        self.recompute_x();
    }
}

impl ProxSolver for MinNormPoint {
    fn step(&mut self, f: &dyn Submodular) -> SolverEvent {
        let p = f.ground_size();
        debug_assert_eq!(self.x.len(), p);
        // One greedy pass in direction −x: vertex q + PAV primal + fc.
        // `q` is moved out so `push_vertex` can borrow it — the corral
        // copies it into flat storage, no clone.
        let mut q = std::mem::take(&mut self.q);
        let (_info, f_w) = self.shared.greedy_and_refine(f, &self.x, &mut q);
        let wolfe_gap = norm2_sq(&self.x) - dot(&self.x, &q);
        if wolfe_gap > self.opts.wolfe_tol && self.push_vertex(&q) {
            self.minor_cycles();
        }
        self.q = q;
        crate::lovasz::debug_assert_dual_feasible(f, &self.x, "MinNormPoint::step");
        self.shared.finish_step(f_w, &self.x, wolfe_gap)
    }

    fn s(&self) -> &[f64] {
        &self.x
    }

    fn w(&self) -> &[f64] {
        &self.shared.w
    }

    fn gap(&self) -> f64 {
        self.shared.gap
    }

    fn best_level_value(&self) -> f64 {
        self.shared.fc
    }

    fn iters(&self) -> usize {
        self.shared.iters
    }

    fn reset(&mut self, f: &dyn Submodular, w_init: &[f64]) {
        let p = f.ground_size();
        self.x.resize(p, 0.0);
        self.corral.reset(p);
        self.orders.reset(p);
        self.lambda.clear();
        self.chol.reset();
        // Reuse `q` as the initial-vertex buffer (scratch that the next
        // step overwrites anyway) — warm restarts allocate nothing once
        // the buffers exist.
        let mut s0 = std::mem::take(&mut self.q);
        s0.clear();
        s0.resize(p, 0.0);
        self.shared.reset_from(f, w_init, &mut s0);
        self.x.copy_from_slice(&s0);
        self.push_vertex(&s0);
        self.q = s0;
        if !self.lambda.is_empty() {
            self.lambda[0] = 1.0;
        }
    }

    fn reset_mapped(&mut self, f: &dyn Submodular, w_init: &[f64], map: &ContractionMap) {
        let p = f.ground_size();
        // The map must describe a contraction of this solver's current
        // state; anything else (fresh solver, unrelated problem) gets the
        // always-correct cold rebuild.
        if map.new_len() != p
            || self.orders.stride() != map.old_len()
            || self.corral.len() != self.orders.len()
            || self.corral.is_empty()
        {
            self.reset(f, w_init);
            return;
        }
        // (1) Warm-start the greedy argsort: the surviving order, mapped
        // to new indices, is already sorted up to tie drift.
        self.shared.greedy_ws.contract(map);
        // (2) Project the corral: replay each atom's induced greedy order
        // on the contracted oracle. Any permutation yields a valid vertex
        // of the new base polytope, so every regenerated atom is feasible
        // by construction (the coordinate-wise projection of the old
        // vertex generally is not).
        self.x.resize(p, 0.0);
        self.orders.contract(map.new_of_old(), p);
        self.corral.reshape_rows(p);
        for i in 0..self.corral.len() {
            vertex_from_order(
                f,
                self.orders.row(i),
                &mut self.shared.greedy_ws,
                self.corral.row_mut(i),
            );
        }
        // (3) Revalidate the Gram factor, dropping atoms that became
        // affinely dependent (e.g. two orders that collapsed to the same
        // induced permutation), and renormalize the carried weights.
        self.rebuild_chol();
        let total: f64 = self.lambda.iter().sum();
        if total > 0.0 {
            for l in self.lambda.iter_mut() {
                *l /= total;
            }
        }
        // (4) Step-14 bookkeeping: adopt the restricted primal, push the
        // fresh greedy vertex ŝ, then land the dual iterate on the
        // min-norm point of the projected corral — the restart inherits
        // the dual progress instead of falling back to a single vertex.
        let mut s0 = std::mem::take(&mut self.q);
        s0.clear();
        s0.resize(p, 0.0);
        let f_w = self.shared.reset_primal(f, w_init, &mut s0);
        self.push_vertex(&s0);
        self.q = s0;
        if self.corral.len() > 1 {
            self.minor_cycles();
        } else {
            if !self.lambda.is_empty() {
                self.lambda[0] = 1.0;
            }
            self.recompute_x();
        }
        // Weak duality holds for any x in B(F̂), so this gap is a valid
        // (non-negative) screening radius.
        let primal = f_w + 0.5 * norm2_sq(w_init);
        let dual = -0.5 * norm2_sq(&self.x);
        self.shared.gap = primal - dual;
        crate::lovasz::debug_assert_dual_feasible(f, &self.x, "MinNormPoint::reset_mapped");
    }

    fn greedy_full_sorts(&self) -> u64 {
        self.shared.greedy_ws.full_sorts
    }

    fn set_pool(
        &mut self,
        pool: Option<std::sync::Arc<crate::runtime::pool::WorkerPool>>,
    ) {
        self.shared.greedy_ws.set_pool(pool);
    }

    fn set_trace_timing(&mut self, enabled: bool) {
        self.shared.trace_timing = enabled;
    }

    fn take_phase_ns(&mut self) -> super::PhaseNs {
        super::PhaseNs { oracle_ns: self.shared.take_oracle_ns(), kind_ns: [0; 4] }
    }

    fn export_state(&self) -> Option<super::SolverState> {
        let m = self.corral.len();
        if m == 0 || self.orders.len() != m || self.lambda.len() != m {
            return None;
        }
        Some(super::SolverState {
            kind: self.name().to_string(),
            orders: (0..m).map(|i| self.orders.row(i).to_vec()).collect(),
            weights: self.lambda.clone(),
            dual: self.x.clone(),
            components: Vec::new(),
        })
    }

    fn restore(
        &mut self,
        f: &dyn Submodular,
        w_init: &[f64],
        state: &super::SolverState,
    ) -> anyhow::Result<()> {
        let p = f.ground_size();
        anyhow::ensure!(
            state.kind == self.name(),
            "snapshot kind '{}' does not match solver '{}'",
            state.kind,
            self.name()
        );
        anyhow::ensure!(
            state.components.is_empty(),
            "monolithic snapshot must not carry component state"
        );
        anyhow::ensure!(!state.orders.is_empty(), "snapshot has no atoms");
        anyhow::ensure!(
            state.weights.len() == state.orders.len(),
            "snapshot has {} weights for {} atoms",
            state.weights.len(),
            state.orders.len()
        );
        anyhow::ensure!(
            state.dual.len() == p && w_init.len() == p,
            "snapshot dual has {} coordinates, problem has {p}",
            state.dual.len()
        );
        let mut seen = vec![false; p];
        for order in &state.orders {
            anyhow::ensure!(
                order.len() == p,
                "atom order has {} entries, problem has {p}",
                order.len()
            );
            seen.iter_mut().for_each(|s| *s = false);
            for &j in order {
                anyhow::ensure!(
                    j < p && !seen[j],
                    "atom order is not a permutation of 0..{p}"
                );
                seen[j] = true;
            }
        }
        for &l in &state.weights {
            anyhow::ensure!(
                l.is_finite() && l >= 0.0,
                "atom weight {l} is not finite and non-negative"
            );
        }
        // Rebuild the corral by replaying each atom's generating order on
        // the oracle — the regeneration invariant: any permutation yields
        // a vertex of *this* base polytope, so every atom is feasible by
        // construction (a stored coordinate vector would not be).
        self.x.resize(p, 0.0);
        self.corral.reset(p);
        self.orders.reset(p);
        self.lambda.clear();
        self.chol.reset();
        self.shared.resize(p);
        let mut buf = std::mem::take(&mut self.q);
        buf.clear();
        buf.resize(p, 0.0);
        for (order, &l) in state.orders.iter().zip(&state.weights) {
            vertex_from_order(f, order, &mut self.shared.greedy_ws, &mut buf);
            self.orders.push(order);
            self.corral.push(&buf);
            self.lambda.push(l);
        }
        self.q = buf;
        // Revalidate the Gram factor (drops affinely dependent atoms)
        // and renormalize the carried weights.
        self.rebuild_chol();
        let total: f64 = self.lambda.iter().sum();
        anyhow::ensure!(total > 0.0, "snapshot atom weights sum to zero");
        for l in self.lambda.iter_mut() {
            *l /= total;
        }
        self.recompute_x();
        // Integrity gate: the regenerated combination must reproduce the
        // stored dual — same reduction, same atoms, same weights. A
        // deviation means the snapshot describes a different problem.
        let mut err: f64 = 0.0;
        for (a, b) in self.x.iter().zip(&state.dual) {
            err = err.max((a - b).abs());
        }
        anyhow::ensure!(
            err <= 1e-6,
            "regenerated dual deviates from snapshot by {err:.3e} \
             (corrupted or mismatched checkpoint)"
        );
        // Step-14 bookkeeping: adopt the restricted primal, push the
        // fresh greedy vertex, land on the min-norm point of the rebuilt
        // corral, and close the gap so the screening radius is valid.
        let mut s0 = std::mem::take(&mut self.q);
        s0.clear();
        s0.resize(p, 0.0);
        let f_w = self.shared.reset_primal(f, w_init, &mut s0);
        self.push_vertex(&s0);
        self.q = s0;
        if self.corral.len() > 1 {
            self.minor_cycles();
        } else {
            if !self.lambda.is_empty() {
                self.lambda[0] = 1.0;
            }
            self.recompute_x();
        }
        let primal = f_w + 0.5 * norm2_sq(w_init);
        let dual = -0.5 * norm2_sq(&self.x);
        self.shared.gap = primal - dual;
        crate::lovasz::debug_assert_dual_feasible(f, &self.x, "MinNormPoint::restore");
        Ok(())
    }

    fn name(&self) -> &'static str {
        "min-norm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brute::brute_force_sfm;
    use crate::lovasz::sup_level_set;
    use crate::rng::Pcg64;
    use crate::submodular::concave_card::ConcaveCardFn;
    use crate::submodular::iwata::IwataFn;
    use crate::submodular::kernel_cut::KernelCutFn;
    use crate::submodular::modular::ModularFn;
    use crate::testutil::forall_rng;

    fn solve(f: &dyn Submodular, max_iter: usize, eps: f64) -> MinNormPoint {
        let mut solver = MinNormPoint::new(f, MinNormOptions::default(), None);
        for _ in 0..max_iter {
            let ev = solver.step(f);
            if ev.gap < eps {
                break;
            }
        }
        solver
    }

    #[test]
    fn modular_min_norm_is_clipped_weights() {
        // For modular F, B(F) = {w} is a point: x* = w.
        let w = vec![1.0, -2.0, 0.5];
        let f = ModularFn::new(w.clone());
        let solver = solve(&f, 50, 1e-12);
        for (a, b) in solver.s().iter().zip(&w) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn iwata_minimizer_matches_brute_force() {
        let f = IwataFn::new(12);
        let brute = brute_force_sfm(&f, 1e-9);
        let solver = solve(&f, 400, 1e-10);
        assert!(solver.gap() < 1e-10, "gap {}", solver.gap());
        let a_min = sup_level_set(solver.w(), 0.0);
        assert_eq!(a_min, brute.minimal, "minimal minimizer mismatch");
    }

    #[test]
    fn gap_reaches_tolerance_on_random_kernel_cuts() {
        forall_rng(10, |rng| {
            let p = 5 + rng.below(10);
            let mut k = vec![0.0; p * p];
            for i in 0..p {
                for j in (i + 1)..p {
                    let w = rng.uniform(0.0, 1.0);
                    k[i * p + j] = w;
                    k[j * p + i] = w;
                }
            }
            let unary = rng.uniform_vec(p, -2.0, 2.0);
            let f = KernelCutFn::new(p, k, unary);
            let solver = solve(&f, 2000, 1e-9);
            if solver.gap() >= 1e-9 {
                return Err(format!("gap did not converge: {}", solver.gap()));
            }
            // w* must recover a true minimizer.
            let brute = brute_force_sfm(&f, 1e-7);
            let a = sup_level_set(solver.w(), 0.0);
            let mut set = vec![false; p];
            for &i in &a {
                set[i] = true;
            }
            let val = f.eval(&set);
            if (val - brute.minimum).abs() > 1e-6 {
                return Err(format!("recovered set not minimal: {val} vs {}", brute.minimum));
            }
            Ok(())
        });
    }

    #[test]
    fn dual_value_monotone_nondecreasing() {
        // −½‖x‖² must not decrease across iterations (Wolfe is monotone).
        let f = IwataFn::new(15);
        let mut solver = MinNormPoint::new(&f, MinNormOptions::default(), None);
        let mut last = f64::NEG_INFINITY;
        for _ in 0..100 {
            let ev = solver.step(&f);
            assert!(
                ev.dual_value >= last - 1e-9,
                "dual decreased: {last} -> {}",
                ev.dual_value
            );
            last = ev.dual_value;
            if ev.gap < 1e-11 {
                break;
            }
        }
    }

    #[test]
    fn concave_card_converges() {
        let mut rng = Pcg64::seeded(17);
        let p = 14;
        let m = rng.uniform_vec(p, -1.5, 1.5);
        let f = ConcaveCardFn::sqrt(p, 2.0, m);
        let solver = solve(&f, 1000, 1e-10);
        assert!(solver.gap() < 1e-10);
        let brute = brute_force_sfm(&f, 1e-9);
        let a = sup_level_set(solver.w(), 0.0);
        assert_eq!(a, brute.minimal);
    }

    #[test]
    fn reset_on_reduced_problem() {
        let f = IwataFn::new(10);
        let mut solver = solve(&f, 50, 1e-6);
        // Pretend screening reduced to 6 elements: reset with a small init.
        let g = IwataFn::new(6);
        let w0 = vec![0.0; 6];
        solver.reset(&g, &w0);
        assert_eq!(solver.s().len(), 6);
        let ev = solver.step(&g);
        assert!(ev.gap.is_finite());
    }

    #[test]
    fn reset_mapped_projects_corral_and_stays_feasible() {
        use crate::lovasz::{in_base_polytope, ContractionMap};
        use crate::submodular::scaled::ScaledFn;
        let mut rng = Pcg64::seeded(808);
        let p = 12;
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let f = KernelCutFn::new(p, k, rng.uniform_vec(p, -2.0, 2.0));
        let kept: Vec<usize> = (0..p).collect();
        let mut scaled = ScaledFn::new(&f, &[], kept.clone());
        let mut solver = MinNormPoint::new(&scaled, MinNormOptions::default(), None);
        for _ in 0..12 {
            solver.step(&scaled);
        }
        let corral_before = solver.corral_size();
        // Contract: certify element 1 active, elements 4 and 9 inactive.
        let new_kept: Vec<usize> =
            kept.iter().copied().filter(|&i| ![1, 4, 9].contains(&i)).collect();
        let w_surv: Vec<f64> = new_kept.iter().map(|&i| solver.w()[i]).collect();
        let mut map = ContractionMap::new();
        scaled.contract(&[1], &new_kept, &mut map);
        let sorts_before = solver.greedy_full_sorts();
        solver.reset_mapped(&scaled, &w_surv, &map);
        assert_eq!(
            solver.greedy_full_sorts(),
            sorts_before,
            "warm restart fell back to a full re-sort"
        );
        assert_eq!(solver.s().len(), new_kept.len());
        assert!(solver.corral_size() > 1, "projected corral was discarded");
        assert!(solver.corral_size() <= corral_before + 1);
        // The restarted dual iterate must lie in the contracted base
        // polytope (safety: the gap certificate depends on it) and the
        // gap must respect weak duality.
        assert!(in_base_polytope(&scaled, solver.s(), 1e-7));
        assert!(solver.gap() >= -1e-9, "negative gap: {}", solver.gap());
        // And the solver still converges to the true reduced minimum.
        let mut gap = f64::INFINITY;
        for _ in 0..2000 {
            gap = solver.step(&scaled).gap;
            if gap < 1e-9 {
                break;
            }
        }
        assert!(gap < 1e-9, "warm-restarted solver stalled: gap {gap}");
        let brute = brute_force_sfm(&scaled, 1e-9);
        let a = sup_level_set(solver.w(), 0.0);
        let mut set = vec![false; new_kept.len()];
        for &i in &a {
            set[i] = true;
        }
        assert!(
            (scaled.eval(&set) - brute.minimum).abs() < 1e-6,
            "warm-restarted minimizer is wrong"
        );
    }

    #[test]
    fn reset_translated_carries_corral_and_stays_feasible() {
        use crate::decompose::prox::OffsetFn;
        use crate::lovasz::in_base_polytope;
        let mut rng = Pcg64::seeded(909);
        let p = 10;
        let f = {
            let mut k = vec![0.0; p * p];
            for i in 0..p {
                for j in (i + 1)..p {
                    let w = rng.uniform(0.0, 1.0);
                    k[i * p + j] = w;
                    k[j * p + i] = w;
                }
            }
            KernelCutFn::new(p, k, rng.uniform_vec(p, -1.5, 1.5))
        };
        let z1 = rng.uniform_vec(p, -1.0, 1.0);
        let z2 = rng.uniform_vec(p, -1.0, 1.0);
        let delta: Vec<f64> = z2.iter().zip(&z1).map(|(a, b)| a - b).collect();
        let sh1 = OffsetFn::new(&f, &z1);
        let mut solver = MinNormPoint::new(&sh1, MinNormOptions::default(), None);
        for _ in 0..12 {
            solver.step(&sh1);
        }
        let corral_before = solver.corral_size();
        assert!(corral_before > 1, "need real corral state to carry");
        // Shift the polytope: B(F + z2) = B(F + z1) + (z2 − z1).
        let sh2 = OffsetFn::new(&f, &z2);
        let w0 = vec![0.0; p];
        solver.reset_translated(&sh2, &delta, &w0);
        assert!(
            solver.corral_size() > 1,
            "translation must carry the corral, not discard it"
        );
        assert!(in_base_polytope(&sh2, solver.s(), 1e-7), "translated dual left B");
        assert!(solver.gap() >= -1e-9, "negative gap {}", solver.gap());
        // Still converges to the same optimum as a cold solver. (The
        // min-norm point is unique; gap ≤ 1e−10 bounds ‖x − x*‖ by
        // strong convexity to ≈ 1.4e−5, hence the 1e−4 agreement bar.)
        let mut gap = f64::INFINITY;
        for _ in 0..2000 {
            gap = solver.step(&sh2).gap;
            if gap < 1e-10 {
                break;
            }
        }
        assert!(gap < 1e-10, "translated warm start stalled: {gap}");
        let mut cold = MinNormPoint::new(&sh2, MinNormOptions::default(), None);
        for _ in 0..2000 {
            if cold.step(&sh2).gap < 1e-10 {
                break;
            }
        }
        for (a, b) in solver.s().iter().zip(cold.s()) {
            assert!(
                (a - b).abs() < 1e-4,
                "warm and cold min-norm points disagree: {a} vs {b}"
            );
        }
    }

    #[test]
    fn reset_translated_without_state_falls_back_to_cold() {
        let f = IwataFn::new(8);
        let mut solver = MinNormPoint::new(&f, MinNormOptions::default(), None);
        // Fresh solver at a different size: must cold-reset, not panic.
        let g = IwataFn::new(5);
        let delta = vec![0.0; 5];
        solver.reset_translated(&g, &delta, &[0.0; 5]);
        assert_eq!(solver.s().len(), 5);
        assert!(solver.step(&g).gap.is_finite());
    }

    #[test]
    fn export_restore_lands_on_snapshot_dual_and_converges() {
        let mut rng = Pcg64::seeded(4242);
        let p = 12;
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let f = KernelCutFn::new(p, k, rng.uniform_vec(p, -2.0, 2.0));
        let mut solver = MinNormPoint::new(&f, MinNormOptions::default(), None);
        for _ in 0..8 {
            solver.step(&f);
        }
        let state = solver.export_state().expect("corral state to export");
        assert_eq!(state.kind, "min-norm");
        assert!(state.orders.len() > 1, "need a real corral to snapshot");
        let w_init = solver.w().to_vec();
        let mut fresh = MinNormPoint::new(&f, MinNormOptions::default(), None);
        fresh.restore(&f, &w_init, &state).expect("restore must accept its own export");
        assert!(crate::lovasz::in_base_polytope(&f, fresh.s(), 1e-7));
        assert!(fresh.gap() >= -1e-9, "negative gap {}", fresh.gap());
        let mut gap = f64::INFINITY;
        for _ in 0..2000 {
            gap = fresh.step(&f).gap;
            if gap < 1e-9 {
                break;
            }
        }
        assert!(gap < 1e-9, "restored solver stalled: gap {gap}");
        let brute = brute_force_sfm(&f, 1e-9);
        let a = sup_level_set(fresh.w(), 0.0);
        assert_eq!(a, brute.minimal, "restored solver found the wrong minimizer");
    }

    #[test]
    fn restore_rejects_tampered_snapshot() {
        let f = IwataFn::new(10);
        let mut solver = solve(&f, 20, 1e-8);
        let mut state = solver.export_state().expect("export");
        state.dual[0] += 1.0;
        let w_init = solver.w().to_vec();
        let err = solver
            .restore(&f, &w_init, &state)
            .expect_err("tampered dual must be rejected");
        assert!(
            err.to_string().contains("deviates from snapshot"),
            "unexpected error: {err}"
        );
        // And a snapshot of the wrong kind is rejected up front.
        let mut wrong = solver.export_state().unwrap_or_else(|| {
            solver.reset(&f, &w_init);
            solver.export_state().expect("export after reset")
        });
        wrong.kind = "pairwise-fw".into();
        let err = solver
            .restore(&f, &w_init, &wrong)
            .expect_err("kind mismatch must be rejected");
        assert!(err.to_string().contains("does not match solver"), "{err}");
    }

    #[test]
    fn reset_mapped_with_stale_map_falls_back_to_cold() {
        use crate::lovasz::ContractionMap;
        let f = IwataFn::new(10);
        let mut solver = solve(&f, 30, 1e-6);
        // A map whose old length does not match the solver state.
        let mut map = ContractionMap::new();
        map.rebuild(&[0, 1, 2, 3], &[0, 2]);
        let g = IwataFn::new(2);
        solver.reset_mapped(&g, &[0.0, 0.0], &map);
        assert_eq!(solver.s().len(), 2);
        assert_eq!(solver.corral_size(), 1, "fallback must be the cold reset");
        let ev = solver.step(&g);
        assert!(ev.gap.is_finite());
    }
}
