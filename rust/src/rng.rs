//! Deterministic pseudo-random number generation.
//!
//! The build environment has no `rand` crate available offline, so we carry
//! a small, well-tested PCG64 (XSL-RR 128/64) implementation plus the
//! distribution helpers the workload generators need (uniform, normal,
//! permutation). Everything in the repository that consumes randomness is
//! seeded explicitly, so every experiment, test, and bench is reproducible
//! bit-for-bit.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation" (2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different stream
    /// ids give statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64) | 0xda3e_39cb_94b9_5bdb) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Create a generator from a seed with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0x5851_f42d_4c95_7f2d)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's nearly-divisionless method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (polar-free, uses two uniforms).
    pub fn normal(&mut self) -> f64 {
        // Guard against log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Sample `k` distinct indices from `0..n` (reservoir-free, shuffle
    /// prefix; fine for the sizes we use).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut v = self.permutation(n);
        v.truncate(k);
        v.sort_unstable();
        v
    }

    /// A vector of i.i.d. standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// A vector of i.i.d. uniforms in `[lo, hi)`.
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_uniform_ish() {
        let mut rng = Pcg64::seeded(4);
        let n = 10usize;
        let mut counts = vec![0usize; n];
        let trials = 100_000;
        for _ in 0..trials {
            counts[rng.below(n)] += 1;
        }
        let expected = trials as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expected).abs() < 5.0 * expected.sqrt());
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut rng = Pcg64::seeded(6);
        let p = rng.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut rng = Pcg64::seeded(7);
        let s = rng.sample_indices(50, 16);
        assert_eq!(s.len(), 16);
        for w in s.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Pcg64::seeded(8);
        let hits = (0..100_000).filter(|_| rng.bernoulli(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }
}
