//! Experiment configuration: a minimal `key = value` format plus typed
//! accessors and CLI-override merging.
//!
//! No TOML/serde crates are available offline, so the launcher accepts a
//! flat config file:
//!
//! ```text
//! # two_moons.cfg
//! workload = two-moons
//! sizes    = 100,200,300,400
//! eps      = 1e-6
//! rho      = 0.5
//! solver   = minnorm
//! backend  = auto
//! out_dir  = bench_out
//! ```
//!
//! CLI flags (`--key value`) override file entries; the merged map feeds
//! [`crate::coordinator`] job builders.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A flat, ordered key→value configuration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    entries: BTreeMap<String, String>,
}

impl Config {
    /// Empty config.
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse from file contents.
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected `key = value`", lineno + 1))?;
            let key = k.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            entries.insert(key.to_string(), v.trim().to_string());
        }
        Ok(Config { entries })
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Set (or override) a key.
    pub fn set(&mut self, key: &str, value: impl Into<String>) {
        self.entries.insert(key.to_string(), value.into());
    }

    /// Merge `other` over `self` (other wins).
    pub fn merge(&mut self, other: &Config) {
        for (k, v) in &other.entries {
            self.entries.insert(k.clone(), v.clone());
        }
    }

    /// Raw string lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    /// String with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed f64 lookup.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("config key `{key}` = `{s}`")),
        }
    }

    /// Typed usize lookup.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("config key `{key}` = `{s}`")),
        }
    }

    /// Typed u64 lookup.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("config key `{key}` = `{s}`")),
        }
    }

    /// Typed bool lookup (`true/false/1/0/yes/no`).
    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => match s.to_ascii_lowercase().as_str() {
                "true" | "1" | "yes" | "on" => Ok(true),
                "false" | "0" | "no" | "off" => Ok(false),
                other => bail!("config key `{key}`: bad bool `{other}`"),
            },
        }
    }

    /// Comma-separated usize list.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .with_context(|| format!("config key `{key}` item `{t}`"))
                })
                .collect(),
        }
    }

    /// All keys (for `--help`-style dumps).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

impl std::fmt::Display for Config {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (k, v) in &self.entries {
            writeln!(f, "{k} = {v}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let cfg = Config::parse("a = 1\n# comment\nb = two-moons # tail\n\n").unwrap();
        assert_eq!(cfg.get("a"), Some("1"));
        assert_eq!(cfg.get("b"), Some("two-moons"));
        assert_eq!(cfg.get("c"), None);
    }

    #[test]
    fn typed_getters() {
        let cfg = Config::parse("eps = 1e-6\nsizes = 100, 200,300\nfull = yes\n").unwrap();
        assert_eq!(cfg.get_f64("eps", 0.0).unwrap(), 1e-6);
        assert_eq!(cfg.get_usize_list("sizes", &[]).unwrap(), vec![100, 200, 300]);
        assert!(cfg.get_bool("full", false).unwrap());
        assert_eq!(cfg.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_values_error() {
        let cfg = Config::parse("eps = banana\n").unwrap();
        assert!(cfg.get_f64("eps", 0.0).is_err());
        assert!(Config::parse("just a line\n").is_err());
    }

    #[test]
    fn merge_overrides() {
        let mut a = Config::parse("x = 1\ny = 2\n").unwrap();
        let b = Config::parse("y = 3\nz = 4\n").unwrap();
        a.merge(&b);
        assert_eq!(a.get("y"), Some("3"));
        assert_eq!(a.get("z"), Some("4"));
        assert_eq!(a.get("x"), Some("1"));
    }

    #[test]
    fn display_is_parseable() {
        let cfg = Config::parse("a = 1\nb = 2\n").unwrap();
        let re = Config::parse(&cfg.to_string()).unwrap();
        assert_eq!(cfg, re);
    }
}
