//! Cooperative cancellation for long-running solves.
//!
//! A [`CancelToken`] combines a caller-settable cancel flag with an
//! optional wall-clock deadline. The IAES engine polls the token **only
//! at major-iteration boundaries** (one check per greedy oracle pass /
//! block round), which is the coarsest granularity at which stopping is
//! *safe*: at an iteration boundary the dual iterate is a valid point of
//! `B(F̂)`, so every screening certificate fired so far remains a
//! Lemma-2/3 safe certificate and the partial report a cancelled solve
//! returns is still trustworthy — `converged: false`, the cancel reason,
//! and the elements screened so far (see `IaesReport::cancel_reason`).
//! Because the check sits *between* iterations and never alters any
//! numeric path, a token that never fires is bitwise inert: the
//! trajectory with `cancel: Some(token)` is identical to the trajectory
//! without it, preserving all determinism invariants.
//!
//! Tokens are cheap to clone (one `Arc`); the serve layer mints one per
//! job (`runtime::cancel` + deadline from the request) and keeps a clone
//! so an admission-control or shutdown path can cancel in flight work.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Why a solve stopped early.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelReason {
    /// [`CancelToken::cancel`] was called.
    Cancelled,
    /// The token's deadline passed.
    DeadlineExpired,
}

impl CancelReason {
    /// Stable machine-readable id (the JSON `cancel_reason` value).
    pub fn as_str(&self) -> &'static str {
        match self {
            CancelReason::Cancelled => "cancelled",
            CancelReason::DeadlineExpired => "deadline",
        }
    }
}

impl std::fmt::Display for CancelReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[derive(Debug)]
struct Inner {
    flag: AtomicBool,
    deadline: Option<Instant>,
}

/// A cloneable cancel flag plus optional deadline (see the module docs).
#[derive(Clone, Debug)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

impl CancelToken {
    /// A token with no deadline; fires only via [`cancel`](Self::cancel).
    pub fn new() -> Self {
        CancelToken {
            inner: Arc::new(Inner { flag: AtomicBool::new(false), deadline: None }),
        }
    }

    /// A token that expires `timeout` from now (and can also be cancelled
    /// explicitly). A zero timeout is already expired — useful for
    /// "validate + screen nothing" probe jobs and deadline tests.
    pub fn with_deadline(timeout: Duration) -> Self {
        Self::with_deadline_at(Instant::now() + timeout)
    }

    /// A token that expires at `at`.
    pub fn with_deadline_at(at: Instant) -> Self {
        CancelToken {
            inner: Arc::new(Inner {
                flag: AtomicBool::new(false),
                deadline: Some(at),
            }),
        }
    }

    /// Request cooperative cancellation (idempotent, thread-safe).
    pub fn cancel(&self) {
        self.inner.flag.store(true, Ordering::Release);
    }

    /// Whether [`cancel`](Self::cancel) has been called (ignores the
    /// deadline — use [`check`](Self::check) for the full verdict).
    pub fn is_cancelled(&self) -> bool {
        self.inner.flag.load(Ordering::Acquire)
    }

    /// The configured deadline, if any.
    pub fn deadline(&self) -> Option<Instant> {
        self.inner.deadline
    }

    /// Poll the token: `Some(reason)` once the flag is set or the
    /// deadline has passed, `None` while the solve may continue. An
    /// explicit cancel wins over a simultaneously-expired deadline.
    pub fn check(&self) -> Option<CancelReason> {
        if self.inner.flag.load(Ordering::Acquire) {
            return Some(CancelReason::Cancelled);
        }
        match self.inner.deadline {
            Some(at) if Instant::now() >= at => Some(CancelReason::DeadlineExpired),
            _ => None,
        }
    }
}

impl Default for CancelToken {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_token_is_live() {
        let t = CancelToken::new();
        assert_eq!(t.check(), None);
        assert!(!t.is_cancelled());
        assert!(t.deadline().is_none());
    }

    #[test]
    fn explicit_cancel_fires_and_is_idempotent() {
        let t = CancelToken::new();
        t.cancel();
        t.cancel();
        assert_eq!(t.check(), Some(CancelReason::Cancelled));
        assert!(t.is_cancelled());
    }

    #[test]
    fn clones_share_the_flag() {
        let t = CancelToken::new();
        let c = t.clone();
        c.cancel();
        assert_eq!(t.check(), Some(CancelReason::Cancelled));
    }

    #[test]
    fn zero_deadline_is_already_expired() {
        let t = CancelToken::with_deadline(Duration::ZERO);
        assert_eq!(t.check(), Some(CancelReason::DeadlineExpired));
    }

    #[test]
    fn future_deadline_is_live_until_it_passes() {
        let t = CancelToken::with_deadline(Duration::from_secs(3600));
        assert_eq!(t.check(), None);
        // An explicit cancel overrides a pending deadline.
        t.cancel();
        assert_eq!(t.check(), Some(CancelReason::Cancelled));
    }

    #[test]
    fn reason_ids_are_stable() {
        assert_eq!(CancelReason::Cancelled.as_str(), "cancelled");
        assert_eq!(CancelReason::DeadlineExpired.as_str(), "deadline");
        assert_eq!(CancelReason::DeadlineExpired.to_string(), "deadline");
    }
}
