//! XLA/PJRT runtime — executes the AOT-compiled JAX/Pallas artifacts from
//! the rust hot path.
//!
//! `make artifacts` (build time, python) lowers the L2 model to **HLO
//! text** at a ladder of padded bucket sizes; this module loads
//! `artifacts/*.hlo.txt`, compiles each once on the PJRT CPU client, and
//! caches the executables. Python never runs at request time.
//!
//! Two executors are exposed:
//!
//! * [`XlaScreener`] — the fused screening kernel (AES-1/IES-1/AES-2/IES-2
//!   masks + Lemma-2 extrema) behind the [`Screener`] trait, bucket-padded.
//! * [`AffinityExec`] — the tiled Gaussian-affinity kernel used by the
//!   two-moons workload builder.
//!
//! When artifacts are missing the callers fall back to the pure-rust
//! implementations ([`crate::screening::rules`] and the direct affinity
//! loop); the integration tests cross-check both paths in f64.
//!
//! The [`pool`], [`cancel`], and [`failpoint`] submodules are unrelated
//! to XLA: [`pool`] hosts the persistent condvar-parked
//! [`WorkerPool`](pool::WorkerPool) used by the decomposable block solver
//! and the pooled greedy oracle; [`cancel`] provides the cooperative
//! [`CancelToken`](cancel::CancelToken) the IAES engine polls at
//! major-iteration boundaries; [`failpoint`] is the compile-feature fault
//! injection harness behind the `failpoint` cargo feature.

pub mod cancel;
pub mod failpoint;
pub mod pool;

use crate::screening::{RuleSet, ScreenInputs, ScreenOutcome, Screener};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Resolve the artifacts directory: `$SFM_SCREEN_ARTIFACTS`, else
/// `./artifacts`, else `<manifest dir>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("SFM_SCREEN_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.is_dir() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

struct EngineInner {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

// SAFETY: the PJRT CPU client and its executables are thread-compatible
// (the underlying C++ objects are internally synchronized for compilation
// and execution); all rust-side access is additionally serialized through
// the `Mutex` in `Engine`.
unsafe impl Send for EngineInner {}

/// A lazy, caching PJRT engine: one CPU client, one compiled executable
/// per artifact file.
pub struct Engine {
    dir: PathBuf,
    inner: Mutex<EngineInner>,
}

impl Engine {
    /// Create an engine rooted at `dir` (must contain `*.hlo.txt`).
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Engine {
            dir,
            inner: Mutex::new(EngineInner { client, cache: HashMap::new() }),
        })
    }

    /// Engine at the default artifact location.
    pub fn at_default() -> Result<Self> {
        Self::new(default_artifact_dir())
    }

    /// The artifact directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Whether `name.hlo.txt` exists.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).is_file()
    }

    /// List available artifact stems.
    pub fn list_artifacts(&self) -> Vec<String> {
        let mut out = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for entry in rd.flatten() {
                let name = entry.file_name().to_string_lossy().to_string();
                if let Some(stem) = name.strip_suffix(".hlo.txt") {
                    out.push(stem.to_string());
                }
            }
        }
        out.sort();
        out
    }

    /// Execute artifact `name` with the given input literals; returns the
    /// flattened output tuple. Compiles (and caches) on first use.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        // Poison recovery: the cache map stays structurally valid even if a
        // panic unwound mid-compile (worst case: one executable re-compiles).
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if !inner.cache.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let text_path = path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?
                .to_string();
            let proto = xla::HloModuleProto::from_text_file(&text_path)
                .map_err(|e| anyhow!("parse {name}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            inner.cache.insert(name.to_string(), exe);
        }
        let exe = inner.cache.get(name).expect("just inserted");
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("no output buffers from {name}"))?
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name} output: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }
}

/// Screening-kernel artifact naming: `screen_p{bucket}`.
fn screen_artifact(bucket: usize) -> String {
    format!("screen_p{bucket}")
}

/// Affinity-kernel artifact naming: `affinity_n{bucket}`.
fn affinity_artifact(bucket: usize) -> String {
    format!("affinity_n{bucket}")
}

/// The XLA screening backend.
pub struct XlaScreener {
    engine: Engine,
    /// Available padded sizes, ascending.
    buckets: Vec<usize>,
    /// Strictness margin (mirrors [`crate::screening::rules::RustScreener`]).
    pub margin: f64,
}

impl XlaScreener {
    /// Load from `dir`; errors if no screening artifacts are present.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let engine = Engine::new(dir)?;
        let mut buckets: Vec<usize> = engine
            .list_artifacts()
            .iter()
            .filter_map(|s| s.strip_prefix("screen_p").and_then(|n| n.parse().ok()))
            .collect();
        buckets.sort_unstable();
        if buckets.is_empty() {
            bail!(
                "no screen_p*.hlo.txt artifacts under {} — run `make artifacts`",
                engine.dir().display()
            );
        }
        Ok(XlaScreener { engine, buckets, margin: 1e-10 })
    }

    /// Load from the default artifact location.
    pub fn at_default() -> Result<Self> {
        Self::new(default_artifact_dir())
    }

    /// The bucket ladder.
    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn bucket_for(&self, p: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= p)
    }

    /// Raw kernel evaluation: returns the four rule masks + extrema, all
    /// truncated to `p̂`. Public for the backend-equivalence tests.
    #[allow(clippy::type_complexity)]
    pub fn run_kernel(
        &self,
        inputs: &ScreenInputs<'_>,
    ) -> Result<(Vec<bool>, Vec<bool>, Vec<bool>, Vec<bool>, Vec<f64>, Vec<f64>)> {
        let p = inputs.w.len();
        let bucket = self
            .bucket_for(p)
            .ok_or_else(|| anyhow!("p-hat = {p} exceeds largest bucket"))?;
        let mut w_pad = vec![0.0f64; bucket];
        w_pad[..p].copy_from_slice(inputs.w);
        let mut valid = vec![0.0f64; bucket];
        valid[..p].iter_mut().for_each(|v| *v = 1.0);

        let lits = [
            xla::Literal::vec1(&w_pad),
            xla::Literal::vec1(&valid),
            xla::Literal::scalar(inputs.gap.max(0.0)),
            xla::Literal::scalar(inputs.f_v),
            xla::Literal::scalar(inputs.f_c),
            xla::Literal::scalar(p as f64),
            xla::Literal::scalar(self.margin),
        ];
        let outs = self
            .engine
            .execute(&screen_artifact(bucket), &lits)
            .context("screen kernel")?;
        anyhow::ensure!(outs.len() == 6, "expected 6 outputs, got {}", outs.len());
        let as_mask = |l: &xla::Literal| -> Result<Vec<bool>> {
            Ok(l.to_vec::<f64>()
                .map_err(|e| anyhow!("{e:?}"))?[..p]
                .iter()
                .map(|&x| x > 0.5)
                .collect())
        };
        let as_vec = |l: &xla::Literal| -> Result<Vec<f64>> {
            Ok(l.to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?[..p].to_vec())
        };
        Ok((
            as_mask(&outs[0])?,
            as_mask(&outs[1])?,
            as_mask(&outs[2])?,
            as_mask(&outs[3])?,
            as_vec(&outs[4])?,
            as_vec(&outs[5])?,
        ))
    }
}

impl Screener for XlaScreener {
    fn screen(&self, inputs: &ScreenInputs<'_>, rules: RuleSet) -> ScreenOutcome {
        let p = inputs.w.len();
        // Degenerate / out-of-ladder sizes: reference backend.
        if p < 2 || self.bucket_for(p).is_none() {
            return crate::screening::rules::screen_rust(inputs, rules, self.margin);
        }
        match self.run_kernel(inputs) {
            Ok((aes1, ies1, aes2, ies2, wmin, wmax)) => {
                let mut active = vec![false; p];
                let mut inactive = vec![false; p];
                for j in 0..p {
                    // Mirror the rust backend's precedence: pair-1 rules
                    // decide first, pair-2 fills in the undecided band.
                    if rules.aes1 && aes1[j] {
                        active[j] = true;
                    } else if rules.ies1 && ies1[j] {
                        inactive[j] = true;
                    } else if rules.aes2 && aes2[j] {
                        active[j] = true;
                    } else if rules.ies2 && ies2[j] {
                        inactive[j] = true;
                    }
                }
                ScreenOutcome { active, inactive, wmin, wmax }
            }
            Err(err) => {
                // Never fail the solve because of the accelerator path.
                eprintln!(
                    "[sfm-screen] XLA backend error ({err:#}); falling back to rust rules"
                );
                crate::screening::rules::screen_rust(inputs, rules, self.margin)
            }
        }
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// The AOT affinity-matrix executor (two-moons workload builder).
pub struct AffinityExec {
    engine: Engine,
    buckets: Vec<usize>,
}

impl AffinityExec {
    /// Load from `dir`; errors if no affinity artifacts are present.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let engine = Engine::new(dir)?;
        let mut buckets: Vec<usize> = engine
            .list_artifacts()
            .iter()
            .filter_map(|s| s.strip_prefix("affinity_n").and_then(|n| n.parse().ok()))
            .collect();
        buckets.sort_unstable();
        if buckets.is_empty() {
            bail!(
                "no affinity_n*.hlo.txt artifacts under {} — run `make artifacts`",
                engine.dir().display()
            );
        }
        Ok(AffinityExec { engine, buckets })
    }

    /// Load from the default artifact location.
    pub fn at_default() -> Result<Self> {
        Self::new(default_artifact_dir())
    }

    /// Available padded sizes.
    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Compute the `n x n` Gaussian affinity `exp(-a * |xi-xj|^2)` with zero
    /// diagonal for 2-D points, via the compiled Pallas kernel.
    pub fn affinity(&self, points: &[[f64; 2]], alpha: f64) -> Result<Vec<f64>> {
        let n = points.len();
        let bucket = self
            .buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| anyhow!("n = {n} exceeds largest affinity bucket"))?;
        let mut xs = vec![0.0f64; bucket];
        let mut ys = vec![0.0f64; bucket];
        for (i, pt) in points.iter().enumerate() {
            xs[i] = pt[0];
            ys[i] = pt[1];
        }
        let lits = [
            xla::Literal::vec1(&xs),
            xla::Literal::vec1(&ys),
            xla::Literal::scalar(alpha),
        ];
        let outs = self.engine.execute(&affinity_artifact(bucket), &lits)?;
        anyhow::ensure!(outs.len() == 1, "expected 1 output");
        let full = outs[0].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
        anyhow::ensure!(full.len() == bucket * bucket, "bad affinity shape");
        // Crop the padded bucket x bucket matrix to n x n; zero the diagonal
        // (padded lanes produce exp(0)=1 there).
        let mut out = vec![0.0f64; n * n];
        for i in 0..n {
            out[i * n..(i + 1) * n]
                .copy_from_slice(&full[i * bucket..i * bucket + n]);
            out[i * n + i] = 0.0;
        }
        Ok(out)
    }
}

/// Convenience: build the best available screener (XLA if artifacts exist,
/// reference rust backend otherwise).
pub fn best_screener() -> std::sync::Arc<dyn Screener> {
    match XlaScreener::at_default() {
        Ok(s) => std::sync::Arc::new(s),
        Err(_) => std::sync::Arc::new(crate::screening::rules::RustScreener::default()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that require compiled artifacts live in
    // rust/tests/xla_backend.rs (integration), so unit `cargo test` stays
    // green before `make artifacts`. Here: pure logic only.

    #[test]
    fn artifact_names() {
        assert_eq!(screen_artifact(1024), "screen_p1024");
        assert_eq!(affinity_artifact(256), "affinity_n256");
    }

    #[test]
    fn default_dir_resolves() {
        let d = default_artifact_dir();
        assert!(d.to_string_lossy().contains("artifacts"));
    }

    #[test]
    fn missing_artifacts_error_is_friendly() {
        let err = match XlaScreener::new("/nonexistent-dir-xyz") {
            Ok(_) => panic!("expected error"),
            Err(e) => e,
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts") || msg.contains("PJRT"), "{msg}");
    }
}
