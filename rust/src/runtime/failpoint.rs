//! Compile-feature fail-point harness for deterministic fault injection.
//!
//! Built with `--features failpoint`, named sites throughout the solve
//! path (`"oracle"` at the top of every greedy pass, `"iaes-iter"` at
//! each IAES major-iteration boundary, `"iaes-gap"` on the freshly
//! computed duality gap, `"serve-job"` around each serve-mode job) can
//! be armed to panic, inject a NaN, or sleep — exactly once, at the
//! N-th hit — so every containment boundary (catch_unwind, pool
//! rebuild, non-finite guard, deadline expiry) has a deterministic
//! test. Without the feature every hook compiles to an inlined no-op,
//! so release builds pay nothing.
//!
//! Semantics of [`arm`]`(site, action, at)`:
//!
//! * the site's hit counter restarts from zero,
//! * [`FpAction::Panic`] and [`FpAction::Nan`] fire exactly at hit
//!   `at` (later hits pass through untouched, so subsequent jobs on
//!   the same process proceed normally),
//! * [`FpAction::Delay`] fires at every hit `>= at` until disarmed.
//!
//! Panics and sleeps happen *outside* the registry lock, so an
//! injected panic can never poison the harness itself.

/// What an armed fail-point does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FpAction {
    /// Panic with a message naming the site and hit count.
    Panic,
    /// Replace the guarded value with `f64::NAN` ([`eval_f64`] sites).
    Nan,
    /// Sleep for the given duration ([`hit`] sites).
    Delay(std::time::Duration),
}

/// Parse a textual arming spec `site=action@N` (action: `panic`, `nan`,
/// or `delay:MS`; `N` is the 1-based hit index). Shared by both feature
/// arms so a misspelled spec is rejected loudly even in builds where
/// arming itself is impossible.
fn parse_spec(spec: &str) -> Result<(String, FpAction, u64), String> {
    let (site, rest) = spec
        .split_once('=')
        .ok_or_else(|| format!("failpoint spec `{spec}` missing `=` (want site=action@N)"))?;
    if site.is_empty() {
        return Err(format!("failpoint spec `{spec}` has an empty site name"));
    }
    let (action, at) = rest
        .split_once('@')
        .ok_or_else(|| format!("failpoint spec `{spec}` missing `@` (want site=action@N)"))?;
    let at: u64 = at
        .parse()
        .map_err(|_| format!("failpoint spec `{spec}`: hit index `{at}` is not a number"))?;
    if at == 0 {
        return Err(format!("failpoint spec `{spec}`: hit index is 1-based"));
    }
    let action = if action == "panic" {
        FpAction::Panic
    } else if action == "nan" {
        FpAction::Nan
    } else if let Some(ms) = action.strip_prefix("delay:") {
        let ms: u64 = ms.parse().map_err(|_| {
            format!("failpoint spec `{spec}`: delay `{ms}` is not a millisecond count")
        })?;
        FpAction::Delay(std::time::Duration::from_millis(ms))
    } else {
        return Err(format!(
            "failpoint spec `{spec}`: unknown action `{action}` (panic | nan | delay:MS)"
        ));
    };
    Ok((site.to_string(), action, at))
}

/// Arm a site from a `site=action@N` spec (the `SFM_FAILPOINT`
/// environment hook used by the CI crash-resume smoke). Errors on a
/// malformed spec — and, in builds without `--features failpoint`, on
/// every spec: silently ignoring an armed fault would let a
/// misconfigured crash test pass vacuously.
#[cfg(feature = "failpoint")]
pub fn arm_from_spec(spec: &str) -> Result<(), String> {
    let (site, action, at) = parse_spec(spec)?;
    arm(&site, action, at);
    Ok(())
}

/// Refusal stub (feature `failpoint` disabled): validates the spec, then
/// reports that this build cannot arm it.
#[cfg(not(feature = "failpoint"))]
pub fn arm_from_spec(spec: &str) -> Result<(), String> {
    let _ = parse_spec(spec)?;
    Err(format!(
        "failpoint spec `{spec}` requires a build with --features failpoint"
    ))
}

#[cfg(feature = "failpoint")]
mod imp {
    use super::FpAction;
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};

    struct Armed {
        action: FpAction,
        at: u64,
        hits: u64,
    }

    fn registry() -> &'static Mutex<HashMap<String, Armed>> {
        static REG: OnceLock<Mutex<HashMap<String, Armed>>> = OnceLock::new();
        REG.get_or_init(|| Mutex::new(HashMap::new()))
    }

    fn with_reg<R>(f: impl FnOnce(&mut HashMap<String, Armed>) -> R) -> R {
        let mut g = registry().lock().unwrap_or_else(|e| e.into_inner());
        f(&mut g)
    }

    /// Arm `site` to perform `action` at its `at`-th hit (1-based). The
    /// site's hit counter restarts from zero.
    pub fn arm(site: &str, action: FpAction, at: u64) {
        with_reg(|reg| {
            reg.insert(site.to_string(), Armed { action, at, hits: 0 });
        });
    }

    /// Disarm a single site (no-op if it was never armed).
    pub fn disarm(site: &str) {
        with_reg(|reg| {
            reg.remove(site);
        });
    }

    /// Disarm everything (test teardown).
    pub fn reset() {
        with_reg(HashMap::clear);
    }

    /// What a hit at `site` should do right now, if anything. Counts the
    /// hit; the caller performs the action outside the registry lock.
    fn fire(site: &str) -> Option<(FpAction, u64)> {
        with_reg(|reg| {
            let armed = reg.get_mut(site)?;
            armed.hits += 1;
            let due = match armed.action {
                FpAction::Delay(_) => armed.hits >= armed.at,
                _ => armed.hits == armed.at,
            };
            due.then_some((armed.action, armed.hits))
        })
    }

    /// Execution hook: panics or sleeps when `site` is armed and due.
    pub fn hit(site: &str) {
        match fire(site) {
            Some((FpAction::Panic, n)) => {
                panic!("failpoint `{site}` injected panic at hit {n}")
            }
            Some((FpAction::Delay(d), _)) => std::thread::sleep(d),
            Some((FpAction::Nan, _)) | None => {}
        }
    }

    /// Value hook: returns `value`, or `NaN` when `site` is armed with
    /// [`FpAction::Nan`] and due. `Panic`/`Delay` also fire here so a
    /// single site name can guard either kind of hook.
    pub fn eval_f64(site: &str, value: f64) -> f64 {
        match fire(site) {
            Some((FpAction::Nan, _)) => f64::NAN,
            Some((FpAction::Panic, n)) => {
                panic!("failpoint `{site}` injected panic at hit {n}")
            }
            Some((FpAction::Delay(d), _)) => {
                std::thread::sleep(d);
                value
            }
            None => value,
        }
    }
}

#[cfg(not(feature = "failpoint"))]
mod imp {
    use super::FpAction;

    /// No-op stub (feature `failpoint` disabled).
    #[inline(always)]
    pub fn arm(_site: &str, _action: FpAction, _at: u64) {}

    /// No-op stub (feature `failpoint` disabled).
    #[inline(always)]
    pub fn disarm(_site: &str) {}

    /// No-op stub (feature `failpoint` disabled).
    #[inline(always)]
    pub fn reset() {}

    /// No-op stub (feature `failpoint` disabled).
    #[inline(always)]
    pub fn hit(_site: &str) {}

    /// Identity stub (feature `failpoint` disabled).
    #[inline(always)]
    pub fn eval_f64(_site: &str, value: f64) -> f64 {
        value
    }
}

pub use imp::{arm, disarm, eval_f64, hit, reset};

#[cfg(all(test, feature = "failpoint"))]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    // The registry is process-global; serialize these tests against each
    // other (cargo runs #[test] fns on parallel threads by default).
    fn serial() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn spec_arming_round_trips_and_rejects_garbage() {
        let _g = serial();
        reset();
        arm_from_spec("t-spec=panic@2").unwrap();
        hit("t-spec"); // hit 1: pass
        let err = std::panic::catch_unwind(|| hit("t-spec")).unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("t-spec"), "panic message: {msg}");
        arm_from_spec("t-spec2=delay:5@1").unwrap();
        arm_from_spec("t-spec3=nan@1").unwrap();
        assert!(eval_f64("t-spec3", 1.0).is_nan());
        for bad in [
            "no-equals",
            "site=panic",
            "=panic@1",
            "site=panic@0",
            "site=panic@x",
            "site=explode@1",
            "site=delay:abc@1",
        ] {
            let err = arm_from_spec(bad).unwrap_err();
            assert!(err.contains("failpoint spec"), "spec `{bad}`: {err}");
        }
        reset();
    }

    #[test]
    fn unarmed_sites_are_inert() {
        let _g = serial();
        reset();
        hit("nope");
        assert_eq!(eval_f64("nope", 2.5), 2.5);
    }

    #[test]
    fn panic_fires_exactly_at_nth_hit() {
        let _g = serial();
        reset();
        arm("t-panic", FpAction::Panic, 2);
        hit("t-panic"); // hit 1: pass
        let err = std::panic::catch_unwind(|| hit("t-panic")).unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("t-panic"), "panic message: {msg}");
        assert!(msg.contains("hit 2"), "panic message: {msg}");
        hit("t-panic"); // hit 3: pass again (exactly-once)
        reset();
    }

    #[test]
    fn nan_injection_and_rearm_resets_counter() {
        let _g = serial();
        reset();
        arm("t-nan", FpAction::Nan, 1);
        assert!(eval_f64("t-nan", 1.0).is_nan());
        assert_eq!(eval_f64("t-nan", 1.0), 1.0);
        arm("t-nan", FpAction::Nan, 1); // re-arm restarts the count
        assert!(eval_f64("t-nan", 7.0).is_nan());
        reset();
    }

    #[test]
    fn delay_fires_from_nth_hit_onward_until_disarmed() {
        let _g = serial();
        reset();
        arm("t-delay", FpAction::Delay(Duration::from_millis(30)), 2);
        let t0 = Instant::now();
        hit("t-delay"); // hit 1: no sleep
        assert!(t0.elapsed() < Duration::from_millis(25));
        let t1 = Instant::now();
        hit("t-delay"); // hit 2: sleeps
        assert!(t1.elapsed() >= Duration::from_millis(30));
        disarm("t-delay");
        let t2 = Instant::now();
        hit("t-delay");
        assert!(t2.elapsed() < Duration::from_millis(25));
        reset();
    }
}
