//! A persistent, condvar-parked worker pool — the crate's fork-join
//! primitive for solver hot loops.
//!
//! Originally built for the decomposable block solver's parallel
//! best-response phases, the pool is now shared by every hot path that
//! fans work across cores — including the pooled monolithic greedy
//! oracle passes (`submodular::kernel_cut` / `submodular::cut`).
//! Spawning scoped threads per phase costs O(threads) heap allocations
//! and two thread create/join syscalls; [`WorkerPool`] replaces that
//! with threads spawned **once** and parked on a condvar between jobs:
//! dispatching a job is one mutex round-trip plus a `notify_all`,
//! completely allocation-free, which is what lets the `threads > 1`
//! steady state certify zero-allocation in `tests/zero_alloc.rs`
//! exactly like `threads = 1` does.
//!
//! Job model: [`run`](WorkerPool::run) takes a borrowed `Fn(usize)`
//! (the argument is the worker index — callers distribute work items via
//! an atomic cursor and index per-worker arenas by it), wakes every
//! worker, and **blocks until all of them finished the job**. That
//! barrier is what makes the internal borrow-extension sound: the job
//! pointer handed to the workers never outlives the `run` call. A panic
//! inside a job is caught on the worker, the barrier still completes,
//! and `run` re-raises it on the caller thread — a poisoned job can
//! never deadlock the pool.
//!
//! Two fork-join conveniences sit on top:
//!
//! * [`run_with_caller`](WorkerPool::run_with_caller) — the caller
//!   thread participates as one extra lane instead of idling on the
//!   barrier, so a "t-way" parallel region needs only `t − 1` parked
//!   workers (the convention of the pooled monolithic oracle).
//! * [`run_chunks`](WorkerPool::run_chunks) — fixed-size chunk grid over
//!   an index range, distributed by an atomic cursor. The chunk
//!   *boundaries* depend only on the range and the chunk size — never on
//!   the worker count — which is the determinism discipline that keeps
//!   pooled numeric sweeps bitwise identical for every thread count.
//!
//! [`DisjointSlice`] is the companion for writing into one output slice
//! from many workers when the written ranges are provably disjoint.

use std::marker::PhantomData;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased job pointer. The fat pointer is only dereferenced between
/// the epoch hand-off and the barrier release inside one `run` call.
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared &-access from many threads is its
// contract) and the pointer is only dereferenced while the issuing `run`
// call is blocked on the completion barrier, so the borrow it came from
// is alive for every dereference.
unsafe impl Send for JobPtr {}

struct Ctrl {
    /// Bumped once per dispatched job; workers run a job exactly when
    /// they observe an epoch they have not served yet.
    epoch: u64,
    /// The current job (valid while `remaining > 0`).
    job: Option<JobPtr>,
    /// Workers still running the current job.
    remaining: usize,
    /// A worker caught a panic in the current job.
    panicked: bool,
    /// Pool is shutting down (Drop).
    shutdown: bool,
}

struct Shared {
    ctrl: Mutex<Ctrl>,
    /// Workers park here between jobs.
    go: Condvar,
    /// The dispatcher parks here until `remaining == 0`.
    done: Condvar,
    /// Monotone count of dispatched fork-join jobs — mirrors `epoch`
    /// but readable without the control lock, for telemetry snapshots
    /// ([`WorkerPool::dispatches`]). Never consulted by workers.
    dispatches: AtomicU64,
}

/// A fixed-size pool of parked worker threads (see the module docs).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

/// Lock the control block, adopting the state if the mutex is poisoned.
///
/// Poison recovery is sound here because `Ctrl` is a scalar epoch
/// protocol: every transition (epoch bump, `remaining` decrement, flag
/// stores) is a single field write performed *after* any code that can
/// panic — job panics are caught on the worker before the decrement, so
/// an unwinding thread can never leave `Ctrl` mid-transition. Adopting
/// the state therefore never observes a torn protocol; refusing to (the
/// old `expect("pool poisoned")`) turned one already-contained job panic
/// into a process-wide wedge the moment any *other* thread holding the
/// lock unwound.
fn lock_ctrl(m: &Mutex<Ctrl>) -> std::sync::MutexGuard<'_, Ctrl> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl WorkerPool {
    /// Spawn `workers ≥ 1` parked threads.
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1, "a pool needs at least one worker");
        Self::try_new(workers).expect("spawning pool worker")
    }

    /// Fallible [`new`](Self::new): `Err` on zero workers or a
    /// thread-spawn failure instead of panicking, with any
    /// already-spawned workers shut down and joined first. The serve
    /// loop builds its oracle pool through this so resource exhaustion
    /// degrades to sequential evaluation rather than killing the worker
    /// thread (SERVING.md).
    pub fn try_new(workers: usize) -> std::io::Result<Self> {
        if workers < 1 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "a pool needs at least one worker",
            ));
        }
        let shared = Arc::new(Shared {
            ctrl: Mutex::new(Ctrl {
                epoch: 0,
                job: None,
                remaining: 0,
                panicked: false,
                shutdown: false,
            }),
            go: Condvar::new(),
            done: Condvar::new(),
            dispatches: AtomicU64::new(0),
        });
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let sh = Arc::clone(&shared);
            match std::thread::Builder::new()
                .name(format!("sfm-pool-{w}"))
                .spawn(move || worker_loop(&sh, w))
            {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // Unwind the partial spawn the way Drop would: wake
                    // the parked workers so none of them leaks.
                    {
                        let mut c = lock_ctrl(&shared.ctrl);
                        c.shutdown = true;
                    }
                    shared.go.notify_all();
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(e);
                }
            }
        }
        Ok(WorkerPool { shared, handles })
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Monotone count of fork-join jobs dispatched over the pool's
    /// lifetime. Telemetry readers snapshot this before and after a
    /// solve and report the delta; the counter itself never feeds back
    /// into scheduling, so reading it cannot perturb a trajectory.
    pub fn dispatches(&self) -> u64 {
        self.shared.dispatches.load(Ordering::Relaxed)
    }

    /// Run `job(worker_index)` once on **every** worker and block until
    /// all of them return. Allocation-free. Panics (on this thread) if
    /// any worker's job panicked.
    pub fn run(&self, job: &(dyn Fn(usize) + Sync)) {
        self.dispatch(job);
        if self.barrier() {
            panic!("worker pool job panicked");
        }
    }

    /// Like [`run`](Self::run), but the **caller participates**: after
    /// waking the workers this thread runs `job(self.size())` itself
    /// (lane index = worker count, so arenas sized `size() + 1` can be
    /// indexed by lane), then blocks on the completion barrier. A
    /// `t`-way parallel region therefore needs a pool of only `t − 1`
    /// workers — the convention used by the pooled monolithic greedy
    /// oracle, where the dispatching solver thread would otherwise idle.
    ///
    /// Panic safety: a panic in the caller's own lane is caught, the
    /// barrier is still honored (the job pointer stays valid until every
    /// worker is done), and the payload is re-raised afterwards.
    pub fn run_with_caller(&self, job: &(dyn Fn(usize) + Sync)) {
        self.dispatch(job);
        let caller = catch_unwind(AssertUnwindSafe(|| job(self.handles.len())));
        let worker_panicked = self.barrier();
        match caller {
            Err(payload) => resume_unwind(payload),
            Ok(()) if worker_panicked => panic!("worker pool job panicked"),
            Ok(()) => {}
        }
    }

    /// Fork-join over the index range `0..n` in fixed `chunk`-sized
    /// pieces: `body` is called with each sub-range exactly once, work
    /// distributed over the workers **and the calling thread** by an
    /// atomic cursor. The chunk boundaries are `[0, chunk, 2·chunk, …]`
    /// regardless of the worker count, so any `body` whose writes are
    /// per-chunk-disjoint (and whose per-chunk arithmetic is fixed)
    /// produces bitwise thread-count-independent results — the
    /// determinism discipline of the pooled oracle sweeps.
    ///
    /// Allocation-free.
    pub fn run_chunks(&self, n: usize, chunk: usize, body: &(dyn Fn(Range<usize>) + Sync)) {
        assert!(chunk > 0, "chunk size must be positive");
        let nchunks = n.div_ceil(chunk);
        let next = AtomicUsize::new(0);
        #[cfg(feature = "debug-invariants")]
        let executed = AtomicUsize::new(0);
        #[cfg(feature = "debug-invariants")]
        let executed_ref = &executed;
        let job = move |_lane: usize| loop {
            let c = next.fetch_add(1, Ordering::Relaxed);
            if c >= nchunks {
                break;
            }
            #[cfg(feature = "debug-invariants")]
            executed_ref.fetch_add(1, Ordering::Relaxed);
            let lo = c * chunk;
            body(lo..n.min(lo + chunk));
        };
        self.run_with_caller(&job);
        // Chunk-grid coverage: every chunk was dispatched to exactly one
        // lane (the cursor can neither skip nor repeat a chunk index).
        #[cfg(feature = "debug-invariants")]
        assert_eq!(
            executed.load(Ordering::Relaxed),
            nchunks,
            "run_chunks chunk-grid coverage",
        );
    }

    /// Publish `job` to the workers and wake them. Must be paired with
    /// exactly one [`barrier`](Self::barrier) call before this method is
    /// entered again — the barrier is what keeps the lifetime-erased job
    /// pointer sound.
    fn dispatch(&self, job: &(dyn Fn(usize) + Sync)) {
        // SAFETY: the lifetime is erased only for the duration of one
        // dispatch/barrier pair — the completion barrier outlives every
        // dereference.
        let job = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(
                job,
            )
        };
        let mut c = lock_ctrl(&self.shared.ctrl);
        // Unconditional: a second dispatcher mid-job would overwrite the
        // in-flight job pointer and corrupt the barrier count — in a
        // release build that is a hang or a use-after-return, not a
        // recoverable error, so the invariant must hold everywhere.
        assert_eq!(c.remaining, 0, "WorkerPool::run re-entered mid-job");
        c.job = Some(JobPtr(job as *const _));
        c.epoch += 1;
        c.remaining = self.handles.len();
        drop(c);
        self.shared.dispatches.fetch_add(1, Ordering::Relaxed);
        self.shared.go.notify_all();
    }

    /// Block until every worker finished the dispatched job; returns
    /// whether any worker panicked (the job slot is cleared either way).
    fn barrier(&self) -> bool {
        let mut c = lock_ctrl(&self.shared.ctrl);
        while c.remaining > 0 {
            c = self.shared.done.wait(c).unwrap_or_else(|e| e.into_inner());
        }
        c.job = None;
        std::mem::take(&mut c.panicked)
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("workers", &self.handles.len()).finish()
    }
}

/// A shared view of a mutable slice for provably **disjoint** parallel
/// writes — the output side of [`WorkerPool::run_chunks`] sweeps, where
/// each chunk owns a distinct index range of one output buffer.
///
/// The borrow checker cannot see per-range disjointness through a
/// `Fn(Range) + Sync` closure, so the split is expressed with one
/// narrowly-scoped unsafe accessor instead of sprinkling raw pointers
/// through the oracle kernels.
pub struct DisjointSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    /// Ranges handed out so far, for the `debug-invariants` overlap
    /// check. Claims are never released: the crate creates one wrapper
    /// per fork-join sweep, so claiming an index twice is a bug even
    /// after the first borrow ended.
    #[cfg(feature = "debug-invariants")]
    claims: Mutex<Vec<(usize, usize)>>,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: the only way to touch the data is the `unsafe` range accessor,
// whose contract (disjoint ranges across concurrent users) is exactly
// what makes shared cross-thread use sound. `T: Send` because elements
// are written from other threads; `Sync` on the wrapper because workers
// access it by `&` reference.
unsafe impl<T: Send> Sync for DisjointSlice<'_, T> {}
// SAFETY: moving the wrapper to another thread moves only the raw
// pointer and length; the elements it can reach are `T: Send`, and every
// access still goes through the `slice_mut` disjointness contract.
unsafe impl<T: Send> Send for DisjointSlice<'_, T> {}

impl<'a, T> DisjointSlice<'a, T> {
    /// Wrap a mutable slice for disjoint parallel writes.
    pub fn new(slice: &'a mut [T]) -> Self {
        DisjointSlice {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            #[cfg(feature = "debug-invariants")]
            claims: Mutex::new(Vec::new()),
            _marker: PhantomData,
        }
    }

    /// Length of the underlying slice.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the underlying slice is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable access to `range`.
    ///
    /// # Safety
    ///
    /// No two concurrently live ranges obtained from the same
    /// `DisjointSlice` may overlap, and `range` must lie within bounds.
    /// (`run_chunks` hands out non-overlapping chunk ranges, so passing
    /// the chunk range straight through satisfies this.)
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, range: Range<usize>) -> &mut [T] {
        debug_assert!(range.start <= range.end && range.end <= self.len);
        #[cfg(feature = "debug-invariants")]
        self.check_disjoint(&range);
        // SAFETY: per the `# Safety` contract above, `range` is in
        // bounds and disjoint from every other live range, so the
        // pointer arithmetic stays inside the wrapped slice and the
        // produced `&mut` aliases nothing.
        unsafe {
            std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.end - range.start)
        }
    }

    /// Record `range` and panic if it overlaps any range previously
    /// claimed from this wrapper — the `debug-invariants` teeth behind
    /// the `slice_mut` contract.
    #[cfg(feature = "debug-invariants")]
    fn check_disjoint(&self, range: &Range<usize>) {
        let mut claims = self.claims.lock().unwrap_or_else(|e| e.into_inner());
        for &(s, e) in claims.iter() {
            assert!(
                range.end <= s || e <= range.start,
                "DisjointSlice overlap: {}..{} intersects claimed {s}..{e}",
                range.start,
                range.end,
            );
        }
        claims.push((range.start, range.end));
    }
}

fn worker_loop(sh: &Shared, w: usize) {
    let mut served = 0u64;
    loop {
        let job = {
            let mut c = lock_ctrl(&sh.ctrl);
            loop {
                if c.shutdown {
                    return;
                }
                if c.epoch != served {
                    served = c.epoch;
                    break c.job.as_ref().map(|j| j.0);
                }
                c = sh.go.wait(c).unwrap_or_else(|e| e.into_inner());
            }
        };
        if let Some(ptr) = job {
            // SAFETY: see `JobPtr` — the dispatcher is blocked on the
            // barrier until we decrement `remaining` below.
            let f = unsafe { &*ptr };
            let ok = catch_unwind(AssertUnwindSafe(|| f(w))).is_ok();
            let mut c = lock_ctrl(&sh.ctrl);
            if !ok {
                c.panicked = true;
            }
            c.remaining -= 1;
            if c.remaining == 0 {
                sh.done.notify_one();
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut c = lock_ctrl(&self.shared.ctrl);
            c.shutdown = true;
        }
        self.shared.go.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn every_worker_runs_each_job() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..50 {
            pool.run(&|w| {
                hits[w].fetch_add(1, Ordering::Relaxed);
            });
        }
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 50);
        }
    }

    #[test]
    fn work_stealing_covers_all_items() {
        let pool = WorkerPool::new(3);
        let n = 1000;
        let done: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..5 {
            let next = AtomicUsize::new(0);
            pool.run(&|_w| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                done[i].fetch_add(1, Ordering::Relaxed);
            });
        }
        for d in &done {
            assert_eq!(d.load(Ordering::Relaxed), 5, "item missed or doubled");
        }
    }

    #[test]
    fn panicking_job_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(&|w| {
                if w == 0 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err(), "worker panic must re-raise on the caller");
        // The pool is still serviceable afterwards.
        let count = AtomicUsize::new(0);
        pool.run(&|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn consecutive_panicking_jobs_do_not_wedge_the_pool() {
        // Poison-recovery regression: repeated job panics (including
        // panics on every worker at once) must leave the pool fully
        // serviceable for the next `run` — no poisoned-mutex abort, no
        // stuck barrier.
        let pool = WorkerPool::new(3);
        for round in 0..10 {
            let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
                pool.run(&|w| {
                    if round % 2 == 0 || w == 1 {
                        panic!("boom round {round} lane {w}");
                    }
                });
            }));
            assert!(caught.is_err(), "round {round} must re-raise");
        }
        let count = AtomicUsize::new(0);
        pool.run_with_caller(&|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = WorkerPool::new(2);
        pool.run(&|_| {});
        drop(pool); // must not hang
    }

    #[test]
    fn run_with_caller_adds_the_caller_lane() {
        let pool = WorkerPool::new(3);
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..25 {
            pool.run_with_caller(&|lane| {
                hits[lane].fetch_add(1, Ordering::Relaxed);
            });
        }
        for (lane, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 25, "lane {lane} missed jobs");
        }
    }

    #[test]
    fn run_with_caller_propagates_caller_panic_after_barrier() {
        let pool = WorkerPool::new(2);
        let worker_done = AtomicUsize::new(0);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_with_caller(&|lane| {
                if lane == pool.size() {
                    panic!("caller lane boom");
                }
                worker_done.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(caught.is_err(), "caller-lane panic must re-raise");
        // The barrier completed before the unwind: both workers ran.
        assert_eq!(worker_done.load(Ordering::Relaxed), 2);
        // And the pool is still serviceable.
        pool.run(&|_| {});
    }

    #[test]
    fn run_chunks_covers_every_index_once() {
        let pool = WorkerPool::new(3);
        for (n, chunk) in [(1000usize, 64usize), (64, 64), (63, 64), (1, 7), (0, 8)] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run_chunks(n, chunk, &|r| {
                // Chunk boundaries are multiples of `chunk` (grid is
                // thread-count-independent by construction).
                assert_eq!(r.start % chunk, 0);
                assert!(r.len() <= chunk);
                for i in r {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} (n={n})");
            }
        }
    }

    #[test]
    fn disjoint_slice_parallel_writes_land() {
        let pool = WorkerPool::new(2);
        let mut out = vec![0.0f64; 500];
        let view = DisjointSlice::new(&mut out);
        assert_eq!(view.len(), 500);
        assert!(!view.is_empty());
        pool.run_chunks(500, 32, &|r| {
            // SAFETY: run_chunks ranges are disjoint.
            let dst = unsafe { view.slice_mut(r.clone()) };
            for (k, x) in r.zip(dst.iter_mut()) {
                *x = k as f64;
            }
        });
        for (k, x) in out.iter().enumerate() {
            assert_eq!(*x, k as f64);
        }
    }
}
