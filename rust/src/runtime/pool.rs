//! A persistent, condvar-parked worker pool for the solver hot loop.
//!
//! The decomposable block solver runs several parallel best-response
//! phases *per round*; spawning scoped threads for each phase costs
//! O(threads) heap allocations and two thread create/join syscalls per
//! phase. [`WorkerPool`] replaces that with threads spawned **once** and
//! parked on a condvar between jobs: dispatching a job is one mutex
//! round-trip plus a `notify_all`, completely allocation-free, which is
//! what lets the `threads > 1` steady state certify zero-allocation in
//! `tests/zero_alloc.rs` exactly like `threads = 1` does.
//!
//! Job model: [`run`](WorkerPool::run) takes a borrowed `Fn(usize)`
//! (the argument is the worker index — callers distribute work items via
//! an atomic cursor and index per-worker arenas by it), wakes every
//! worker, and **blocks until all of them finished the job**. That
//! barrier is what makes the internal borrow-extension sound: the job
//! pointer handed to the workers never outlives the `run` call. A panic
//! inside a job is caught on the worker, the barrier still completes,
//! and `run` re-raises it on the caller thread — a poisoned job can
//! never deadlock the pool.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased job pointer. The fat pointer is only dereferenced between
/// the epoch hand-off and the barrier release inside one `run` call.
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared &-access from many threads is its
// contract) and the pointer is only dereferenced while the issuing `run`
// call is blocked on the completion barrier, so the borrow it came from
// is alive for every dereference.
unsafe impl Send for JobPtr {}

struct Ctrl {
    /// Bumped once per dispatched job; workers run a job exactly when
    /// they observe an epoch they have not served yet.
    epoch: u64,
    /// The current job (valid while `remaining > 0`).
    job: Option<JobPtr>,
    /// Workers still running the current job.
    remaining: usize,
    /// A worker caught a panic in the current job.
    panicked: bool,
    /// Pool is shutting down (Drop).
    shutdown: bool,
}

struct Shared {
    ctrl: Mutex<Ctrl>,
    /// Workers park here between jobs.
    go: Condvar,
    /// The dispatcher parks here until `remaining == 0`.
    done: Condvar,
}

/// A fixed-size pool of parked worker threads (see the module docs).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers ≥ 1` parked threads.
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1, "a pool needs at least one worker");
        let shared = Arc::new(Shared {
            ctrl: Mutex::new(Ctrl {
                epoch: 0,
                job: None,
                remaining: 0,
                panicked: false,
                shutdown: false,
            }),
            go: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|w| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("sfm-pool-{w}"))
                    .spawn(move || worker_loop(&sh, w))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Run `job(worker_index)` once on **every** worker and block until
    /// all of them return. Allocation-free. Panics (on this thread) if
    /// any worker's job panicked.
    pub fn run(&self, job: &(dyn Fn(usize) + Sync)) {
        // SAFETY: the lifetime is erased only for the duration of this
        // call — the completion barrier below outlives every dereference.
        let job = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(
                job,
            )
        };
        let mut c = self.shared.ctrl.lock().expect("pool poisoned");
        // Unconditional: a second dispatcher mid-job would overwrite the
        // in-flight job pointer and corrupt the barrier count — in a
        // release build that is a hang or a use-after-return, not a
        // recoverable error, so the invariant must hold everywhere.
        assert_eq!(c.remaining, 0, "WorkerPool::run re-entered mid-job");
        c.job = Some(JobPtr(job as *const _));
        c.epoch += 1;
        c.remaining = self.handles.len();
        drop(c);
        self.shared.go.notify_all();
        let mut c = self.shared.ctrl.lock().expect("pool poisoned");
        while c.remaining > 0 {
            c = self.shared.done.wait(c).expect("pool poisoned");
        }
        c.job = None;
        let panicked = std::mem::take(&mut c.panicked);
        drop(c);
        if panicked {
            panic!("worker pool job panicked");
        }
    }
}

fn worker_loop(sh: &Shared, w: usize) {
    let mut served = 0u64;
    loop {
        let job = {
            let mut c = sh.ctrl.lock().expect("pool poisoned");
            loop {
                if c.shutdown {
                    return;
                }
                if c.epoch != served {
                    served = c.epoch;
                    break c.job.as_ref().map(|j| j.0);
                }
                c = sh.go.wait(c).expect("pool poisoned");
            }
        };
        if let Some(ptr) = job {
            // SAFETY: see `JobPtr` — the dispatcher is blocked on the
            // barrier until we decrement `remaining` below.
            let f = unsafe { &*ptr };
            let ok = catch_unwind(AssertUnwindSafe(|| f(w))).is_ok();
            let mut c = sh.ctrl.lock().expect("pool poisoned");
            if !ok {
                c.panicked = true;
            }
            c.remaining -= 1;
            if c.remaining == 0 {
                sh.done.notify_one();
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut c = self.shared.ctrl.lock().expect("pool poisoned");
            c.shutdown = true;
        }
        self.shared.go.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn every_worker_runs_each_job() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..50 {
            pool.run(&|w| {
                hits[w].fetch_add(1, Ordering::Relaxed);
            });
        }
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 50);
        }
    }

    #[test]
    fn work_stealing_covers_all_items() {
        let pool = WorkerPool::new(3);
        let n = 1000;
        let done: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..5 {
            let next = AtomicUsize::new(0);
            pool.run(&|_w| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                done[i].fetch_add(1, Ordering::Relaxed);
            });
        }
        for d in &done {
            assert_eq!(d.load(Ordering::Relaxed), 5, "item missed or doubled");
        }
    }

    #[test]
    fn panicking_job_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(&|w| {
                if w == 0 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err(), "worker panic must re-raise on the caller");
        // The pool is still serviceable afterwards.
        let count = AtomicUsize::new(0);
        pool.run(&|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = WorkerPool::new(2);
        pool.run(&|_| {});
        drop(pool); // must not hang
    }
}
