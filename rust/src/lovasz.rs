//! The Lovász extension and Edmonds' greedy algorithm — the bridge between
//! SFM and the proximal pair (Q-P)/(Q-D).
//!
//! For `w ∈ ℝ^p` sorted decreasingly along an order `j₁,…,j_p`, the greedy
//! vertex `s` with `s_{j_k} = F({j₁..j_k}) − F({j₁..j_{k−1}})` maximizes
//! `⟨w, s⟩` over the base polytope `B(F)`, and `f(w) = ⟨w, s⟩` is the
//! Lovász extension (Definition 3). One greedy pass also yields, for free,
//! the value of `F` at every super-level set of `w` (prefix sums of the
//! gains) — which is exactly what Remark 1 of the paper exploits to obtain
//! the set `C` used by the Ω estimate.

use crate::linalg::vecops::{argsort_desc, argsort_desc_adaptive, dot};
use crate::submodular::{OracleScratch, Submodular};

/// Reusable buffers for greedy passes — the solver hot loop calls greedy
/// every iteration and must not allocate.
///
/// The workspace also persists the *previous* greedy order in `order`,
/// which [`greedy_base_vertex`] reuses as the warm start for the adaptive
/// argsort (consecutive solver directions are nearly co-sorted), and owns
/// the [`OracleScratch`] threaded into every oracle pass.
#[derive(Clone, Debug, Default)]
pub struct GreedyWorkspace {
    /// Descending argsort of the direction vector.
    pub order: Vec<usize>,
    /// Marginal gains along `order`.
    pub gains: Vec<f64>,
    /// All-false membership vector (greedy passes start from ∅).
    empty_base: Vec<bool>,
    /// Reusable oracle pass state.
    pub scratch: OracleScratch,
}

impl GreedyWorkspace {
    /// Workspace for ground-set size `p`.
    pub fn new(p: usize) -> Self {
        GreedyWorkspace {
            order: Vec::with_capacity(p),
            gains: vec![0.0; p],
            empty_base: vec![false; p],
            scratch: OracleScratch::new(),
        }
    }
}

/// Summary of one greedy pass.
#[derive(Clone, Copy, Debug)]
pub struct GreedyInfo {
    /// `f(w) = ⟨w, s⟩` — the Lovász extension at `w`.
    pub lovasz: f64,
    /// `min_k F(prefix_k)` over `k = 0..=p` (the best super-level set seen;
    /// `k = 0` gives `F(∅) = 0`, so this is always ≤ 0).
    pub best_level_value: f64,
    /// The `k` attaining `best_level_value` (`prefix_k` = first `k`
    /// elements of the order).
    pub best_level_k: usize,
}

/// One greedy pass: writes the base-polytope vertex maximizing `⟨w, s⟩`
/// into `s_out` and returns the pass summary.
///
/// Ties in `w` are broken by index, so the result is deterministic — and
/// independent of the workspace history: the adaptive argsort and the
/// oracle scratch are exact (bit-identical) accelerations of the cold
/// path, which [`greedy_base_vertex_ref`] preserves for the tests.
///
/// Steady state (workspace and scratch at working size) performs **zero
/// heap allocations**.
pub fn greedy_base_vertex<F: Submodular + ?Sized>(
    f: &F,
    w: &[f64],
    ws: &mut GreedyWorkspace,
    s_out: &mut [f64],
) -> GreedyInfo {
    let p = f.ground_size();
    assert_eq!(w.len(), p);
    assert_eq!(s_out.len(), p);
    ws.gains.resize(p, 0.0);
    ws.empty_base.clear();
    ws.empty_base.resize(p, false);
    argsort_desc_adaptive(w, &mut ws.order);
    f.prefix_gains_scratch(&ws.empty_base, &ws.order, &mut ws.gains, &mut ws.scratch);
    accumulate_pass(w, &ws.order, &ws.gains, s_out)
}

/// Allocating reference implementation of [`greedy_base_vertex`]: fresh
/// buffers, full sort, allocating oracle path. Kept as the comparison
/// baseline for the determinism tests and the `greedy/*-alloc` bench rows;
/// bit-identical to the fast path by construction (same accumulation, same
/// total sort order).
pub fn greedy_base_vertex_ref<F: Submodular + ?Sized>(
    f: &F,
    w: &[f64],
    s_out: &mut [f64],
) -> GreedyInfo {
    let p = f.ground_size();
    assert_eq!(w.len(), p);
    assert_eq!(s_out.len(), p);
    let order = argsort_desc(w);
    let mut gains = vec![0.0; p];
    f.prefix_gains(&order, &mut gains);
    accumulate_pass(w, &order, &gains, s_out)
}

/// Shared pass accumulation: scatter gains into the vertex, accumulate the
/// Lovász value and the best prefix (super-level-set) value.
fn accumulate_pass(
    w: &[f64],
    order: &[usize],
    gains: &[f64],
    s_out: &mut [f64],
) -> GreedyInfo {
    let mut lovasz = 0.0;
    let mut prefix = 0.0;
    let mut best = 0.0; // k = 0 → F(∅) = 0
    let mut best_k = 0;
    for (k, (&j, &g)) in order.iter().zip(gains.iter()).enumerate() {
        s_out[j] = g;
        lovasz += w[j] * g;
        prefix += g;
        if prefix < best {
            best = prefix;
            best_k = k + 1;
        }
    }
    GreedyInfo { lovasz, best_level_value: best, best_level_k: best_k }
}

/// The Lovász extension `f(w)` (allocating convenience wrapper).
pub fn lovasz_value<F: Submodular + ?Sized>(f: &F, w: &[f64]) -> f64 {
    let p = f.ground_size();
    let mut ws = GreedyWorkspace::new(p);
    let mut s = vec![0.0; p];
    greedy_base_vertex(f, w, &mut ws, &mut s).lovasz
}

/// The strict sup-level set `{w > α}` as ids.
pub fn sup_level_set(w: &[f64], alpha: f64) -> Vec<usize> {
    w.iter().enumerate().filter(|(_, &x)| x > alpha).map(|(i, _)| i).collect()
}

/// The weak sup-level set `{w ≥ α}` as ids.
pub fn weak_sup_level_set(w: &[f64], alpha: f64) -> Vec<usize> {
    w.iter().enumerate().filter(|(_, &x)| x >= alpha).map(|(i, _)| i).collect()
}

/// Verify `s ∈ B(F)` by checking `s(V) = F(V)` and `s(A) ≤ F(A)` for all
/// subsets — O(2^p), test helper only.
pub fn in_base_polytope<F: Submodular + ?Sized>(f: &F, s: &[f64], tol: f64) -> bool {
    let p = f.ground_size();
    assert!(p <= 22, "exponential check");
    let total: f64 = s.iter().sum();
    let full = f.eval(&vec![true; p]);
    if (total - full).abs() > tol {
        return false;
    }
    for mask in 0u64..(1 << p) {
        let set: Vec<bool> = (0..p).map(|i| mask >> i & 1 == 1).collect();
        let s_a: f64 = (0..p).filter(|&i| set[i]).map(|i| s[i]).sum();
        if s_a > f.eval(&set) + tol {
            return false;
        }
    }
    true
}

/// `⟨w, s⟩` helper re-exported for solver code readability.
#[inline]
pub fn inner(w: &[f64], s: &[f64]) -> f64 {
    dot(w, s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::submodular::concave_card::ConcaveCardFn;
    use crate::submodular::iwata::IwataFn;
    use crate::submodular::modular::ModularFn;
    use crate::testutil::forall_rng;

    #[test]
    fn greedy_vertex_in_base_polytope() {
        forall_rng(20, |rng| {
            let p = 2 + rng.below(7);
            let m = rng.uniform_vec(p, -1.0, 1.0);
            let f = ConcaveCardFn::sqrt(p, rng.uniform(0.5, 2.0), m);
            let w = rng.normal_vec(p);
            let mut ws = GreedyWorkspace::new(p);
            let mut s = vec![0.0; p];
            greedy_base_vertex(&f, &w, &mut ws, &mut s);
            if in_base_polytope(&f, &s, 1e-9) {
                Ok(())
            } else {
                Err("greedy vertex outside B(F)".into())
            }
        });
    }

    #[test]
    fn lovasz_of_indicator_is_f() {
        // f(1_A) = F(A) for any A (fundamental property).
        let f = IwataFn::new(10);
        let mut rng = Pcg64::seeded(91);
        for _ in 0..30 {
            let set: Vec<bool> = (0..10).map(|_| rng.bernoulli(0.5)).collect();
            let w: Vec<f64> = set.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
            let expect = f.eval(&set);
            assert!((lovasz_value(&f, &w) - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn lovasz_positive_homogeneous_and_convex_1d_slices() {
        let f = IwataFn::new(8);
        let mut rng = Pcg64::seeded(92);
        for _ in 0..20 {
            let w = rng.normal_vec(8);
            let t = rng.uniform(0.1, 3.0);
            let tw: Vec<f64> = w.iter().map(|x| t * x).collect();
            assert!(
                (lovasz_value(&f, &tw) - t * lovasz_value(&f, &w)).abs() < 1e-8
            );
            // Midpoint convexity along a random segment.
            let v = rng.normal_vec(8);
            let mid: Vec<f64> = w.iter().zip(&v).map(|(a, b)| 0.5 * (a + b)).collect();
            let lhs = lovasz_value(&f, &mid);
            let rhs = 0.5 * lovasz_value(&f, &w) + 0.5 * lovasz_value(&f, &v);
            assert!(lhs <= rhs + 1e-9);
        }
    }

    #[test]
    fn greedy_maximizes_over_vertices() {
        // ⟨w, s_greedy(w)⟩ ≥ ⟨w, s_greedy(u)⟩ for any direction u.
        let f = IwataFn::new(7);
        let mut rng = Pcg64::seeded(93);
        let mut ws = GreedyWorkspace::new(7);
        for _ in 0..25 {
            let w = rng.normal_vec(7);
            let u = rng.normal_vec(7);
            let mut sw = vec![0.0; 7];
            let mut su = vec![0.0; 7];
            let info = greedy_base_vertex(&f, &w, &mut ws, &mut sw);
            greedy_base_vertex(&f, &u, &mut ws, &mut su);
            assert!(info.lovasz >= inner(&w, &su) - 1e-9);
        }
    }

    #[test]
    fn best_level_value_matches_scan() {
        let f = IwataFn::new(9);
        let mut rng = Pcg64::seeded(94);
        let mut ws = GreedyWorkspace::new(9);
        let mut s = vec![0.0; 9];
        for _ in 0..10 {
            let w = rng.normal_vec(9);
            let info = greedy_base_vertex(&f, &w, &mut ws, &mut s);
            // Recompute F at all prefixes directly.
            let mut best = 0.0f64;
            for k in 0..=9 {
                let ids: Vec<usize> = ws.order[..k].to_vec();
                let v = crate::submodular::SubmodularExt::eval_ids(&f, &ids);
                best = best.min(v);
            }
            assert!((info.best_level_value - best).abs() < 1e-9);
        }
    }

    #[test]
    fn warm_workspace_is_bit_identical_to_reference() {
        // Simulate the solver's direction evolution: a slowly drifting
        // vector with occasional jumps, one *reused* workspace. Every pass
        // must match the allocating/full-sort reference bit for bit —
        // order, gains, vertex, and summary.
        use crate::submodular::cut::CutFn;
        let mut rng = Pcg64::seeded(421);
        let p = 60;
        let mut edges = Vec::new();
        for i in 0..p {
            for j in (i + 1)..p {
                if rng.bernoulli(0.15) {
                    edges.push((i, j, rng.uniform(0.0, 1.5)));
                }
            }
        }
        let f = CutFn::from_edges(p, &edges, rng.uniform_vec(p, -1.0, 1.0));
        let mut ws = GreedyWorkspace::new(p);
        let mut w = rng.normal_vec(p);
        let mut s_fast = vec![0.0; p];
        let mut s_ref = vec![0.0; p];
        for step in 0..60 {
            let fast = greedy_base_vertex(&f, &w, &mut ws, &mut s_fast);
            let refr = greedy_base_vertex_ref(&f, &w, &mut s_ref);
            assert_eq!(ws.order, crate::linalg::vecops::argsort_desc(&w));
            for j in 0..p {
                assert_eq!(
                    s_fast[j].to_bits(),
                    s_ref[j].to_bits(),
                    "vertex differs at {j} step {step}"
                );
            }
            assert_eq!(fast.lovasz.to_bits(), refr.lovasz.to_bits());
            assert_eq!(fast.best_level_k, refr.best_level_k);
            // Drift (typical between major iterations), jump every 13th.
            if step % 13 == 12 {
                w = rng.normal_vec(p);
            } else {
                for x in w.iter_mut() {
                    *x += 0.02 * rng.normal();
                }
            }
        }
    }

    #[test]
    fn modular_greedy_is_weights() {
        let f = ModularFn::new(vec![2.0, -1.0, 0.5]);
        let mut ws = GreedyWorkspace::new(3);
        let mut s = vec![0.0; 3];
        greedy_base_vertex(&f, &[0.3, 0.2, 0.9], &mut ws, &mut s);
        assert_eq!(s, vec![2.0, -1.0, 0.5]);
    }

    #[test]
    fn level_sets() {
        let w = [0.5, -0.1, 0.0, 2.0];
        assert_eq!(sup_level_set(&w, 0.0), vec![0, 3]);
        assert_eq!(weak_sup_level_set(&w, 0.0), vec![0, 2, 3]);
    }
}
