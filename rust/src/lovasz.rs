//! The Lovász extension and Edmonds' greedy algorithm — the bridge between
//! SFM and the proximal pair (Q-P)/(Q-D).
//!
//! For `w ∈ ℝ^p` sorted decreasingly along an order `j₁,…,j_p`, the greedy
//! vertex `s` with `s_{j_k} = F({j₁..j_k}) − F({j₁..j_{k−1}})` maximizes
//! `⟨w, s⟩` over the base polytope `B(F)`, and `f(w) = ⟨w, s⟩` is the
//! Lovász extension (Definition 3). One greedy pass also yields, for free,
//! the value of `F` at every super-level set of `w` (prefix sums of the
//! gains) — which is exactly what Remark 1 of the paper exploits to obtain
//! the set `C` used by the Ω estimate.

use crate::linalg::vecops::{argsort_desc, argsort_desc_adaptive, dot, project_indices};
use crate::submodular::{OracleScratch, Submodular};

/// The survivor map of one IAES ground-set contraction, in *reduced*
/// indices: `new_of_old[i]` is element `i`'s index in the contracted
/// problem, or `usize::MAX` when the element was certified and removed.
///
/// Built by [`ScaledFn::contract`](crate::submodular::scaled::ScaledFn)
/// from the old/new kept-id lists and handed to
/// [`ProxSolver::reset_mapped`](crate::solvers::ProxSolver::reset_mapped),
/// which uses it to project the greedy order, the min-norm corral, and
/// the Frank–Wolfe atoms onto the surviving coordinates instead of
/// rebuilding them cold. The buffer is reused across contractions, so a
/// long IAES run allocates map storage once.
#[derive(Clone, Debug)]
pub struct ContractionMap {
    new_of_old: Vec<usize>,
    new_len: usize,
    /// For each *removed* old index: `true` when the element was certified
    /// active (it moved into the reduction base `Ê`), `false` when it was
    /// certified inactive (it left the problem entirely). Meaningless for
    /// survivors. The decomposable block solver needs this distinction to
    /// thread one global contraction through every component's own
    /// base/kept split; the monolithic solvers ignore it.
    went_active: Vec<bool>,
    /// When false, [`GreedyWorkspace::contract`] discards the stale order
    /// instead of remapping it, forcing the next argsort onto the full
    /// cold re-sort. Both paths produce the unique deterministic greedy
    /// order, so flipping this flag is unobservable bit for bit — the
    /// determinism tests exploit exactly that to certify the remap.
    pub remap_argsort: bool,
}

impl Default for ContractionMap {
    fn default() -> Self {
        ContractionMap {
            new_of_old: Vec::new(),
            new_len: 0,
            went_active: Vec::new(),
            remap_argsort: true,
        }
    }
}

impl ContractionMap {
    /// Marker for a removed element.
    pub const REMOVED: usize = usize::MAX;

    /// Empty map (remap enabled).
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild from the old and new kept-id lists (both sorted ascending,
    /// `new_kept` a subsequence of `old_kept`). O(p̂) merge walk; the map
    /// buffer is reused.
    pub fn rebuild(&mut self, old_kept: &[usize], new_kept: &[usize]) {
        self.new_of_old.clear();
        self.new_of_old.resize(old_kept.len(), Self::REMOVED);
        self.went_active.clear();
        self.went_active.resize(old_kept.len(), false);
        let mut j = 0usize;
        for (i, &orig) in old_kept.iter().enumerate() {
            if j < new_kept.len() && new_kept[j] == orig {
                self.new_of_old[i] = j;
                j += 1;
            }
        }
        assert_eq!(
            j,
            new_kept.len(),
            "new kept ids must be a subsequence of the old kept ids"
        );
        self.new_len = new_kept.len();
    }

    /// Record that the *removed* old reduced element `old` was certified
    /// active (moved into the base `Ê`) rather than inactive. Filled by
    /// [`ScaledFn::contract`](crate::submodular::scaled::ScaledFn) after
    /// [`rebuild`](Self::rebuild).
    #[inline]
    pub fn mark_active(&mut self, old: usize) {
        debug_assert_eq!(
            self.new_of_old[old],
            Self::REMOVED,
            "only removed elements can go active"
        );
        self.went_active[old] = true;
    }

    /// True when removed old element `old` was certified active (entered
    /// the base) rather than inactive (left the problem). Only meaningful
    /// when [`new_index`](Self::new_index) returns `None`.
    #[inline]
    pub fn went_active(&self, old: usize) -> bool {
        self.went_active[old]
    }

    /// Pre-contraction reduced ground-set size.
    #[inline]
    pub fn old_len(&self) -> usize {
        self.new_of_old.len()
    }

    /// Post-contraction reduced ground-set size.
    #[inline]
    pub fn new_len(&self) -> usize {
        self.new_len
    }

    /// The raw old→new index map (`usize::MAX` = removed).
    #[inline]
    pub fn new_of_old(&self) -> &[usize] {
        &self.new_of_old
    }

    /// New index of old reduced element `old`, if it survived.
    #[inline]
    pub fn new_index(&self, old: usize) -> Option<usize> {
        match self.new_of_old[old] {
            Self::REMOVED => None,
            k => Some(k),
        }
    }
}

/// Reusable buffers for greedy passes — the solver hot loop calls greedy
/// every iteration and must not allocate.
///
/// The workspace also persists the *previous* greedy order in `order`,
/// which [`greedy_base_vertex`] reuses as the warm start for the adaptive
/// argsort (consecutive solver directions are nearly co-sorted), and owns
/// the [`OracleScratch`] threaded into every oracle pass. Across an IAES
/// contraction, [`contract`](Self::contract) maps the surviving order
/// through the survivor map so the next pass repairs instead of
/// re-sorting.
#[derive(Clone, Debug, Default)]
pub struct GreedyWorkspace {
    /// Descending argsort of the direction vector.
    pub order: Vec<usize>,
    /// Marginal gains along `order`.
    pub gains: Vec<f64>,
    /// All-false membership vector (greedy passes start from ∅).
    empty_base: Vec<bool>,
    /// Reusable oracle pass state.
    pub scratch: OracleScratch,
    /// Cumulative count of full (non-incremental) argsorts: cold starts,
    /// resizes, and repair-budget bailouts. The warm-restart tests pin
    /// this down to certify that a contraction does *not* cost a re-sort.
    pub full_sorts: u64,
}

impl GreedyWorkspace {
    /// Workspace for ground-set size `p`.
    pub fn new(p: usize) -> Self {
        GreedyWorkspace {
            order: Vec::with_capacity(p),
            gains: vec![0.0; p],
            empty_base: vec![false; p],
            scratch: OracleScratch::new(),
            full_sorts: 0,
        }
    }

    /// Install (or clear) a shared worker pool for pooled oracle passes:
    /// greedy passes driven through this workspace fan the dense
    /// kernel-cut accumulator sweep and high-degree sparse-cut adjacency
    /// walks across the pool plus the calling thread. The pooled passes
    /// are **bit-identical** to the sequential ones (fixed chunk grids,
    /// fixed-order chunk reductions), so installing a pool is purely a
    /// wall-clock decision — trajectories never change.
    pub fn set_pool(
        &mut self,
        pool: Option<std::sync::Arc<crate::runtime::pool::WorkerPool>>,
    ) {
        self.scratch.set_pool(pool);
    }

    /// Project the persisted greedy order through an IAES contraction:
    /// survivors keep their relative ranks, so the mapped order is the
    /// warm start the next [`greedy_base_vertex`] repairs in O(p) instead
    /// of re-sorting. A stale order (wrong length) — or a map with
    /// `remap_argsort` disabled — clears the buffer, which sends the next
    /// pass down the full-sort cold path instead.
    pub fn contract(&mut self, map: &ContractionMap) {
        if map.remap_argsort && self.order.len() == map.old_len() {
            project_indices(&mut self.order, map.new_of_old());
            if self.order.len() != map.new_len() {
                // Defensive: the buffer wasn't a permutation of the old
                // ground set. Fall back to the cold path.
                self.order.clear();
            }
        } else {
            self.order.clear();
        }
    }
}

/// Summary of one greedy pass.
#[derive(Clone, Copy, Debug)]
pub struct GreedyInfo {
    /// `f(w) = ⟨w, s⟩` — the Lovász extension at `w`.
    pub lovasz: f64,
    /// `min_k F(prefix_k)` over `k = 0..=p` (the best super-level set seen;
    /// `k = 0` gives `F(∅) = 0`, so this is always ≤ 0).
    pub best_level_value: f64,
    /// The `k` attaining `best_level_value` (`prefix_k` = first `k`
    /// elements of the order).
    pub best_level_k: usize,
}

/// One greedy pass: writes the base-polytope vertex maximizing `⟨w, s⟩`
/// into `s_out` and returns the pass summary.
///
/// Ties in `w` are broken by index, so the result is deterministic — and
/// independent of the workspace history: the adaptive argsort and the
/// oracle scratch are exact (bit-identical) accelerations of the cold
/// path, which [`greedy_base_vertex_ref`] preserves for the tests.
///
/// Steady state (workspace and scratch at working size) performs **zero
/// heap allocations**.
pub fn greedy_base_vertex<F: Submodular + ?Sized>(
    f: &F,
    w: &[f64],
    ws: &mut GreedyWorkspace,
    s_out: &mut [f64],
) -> GreedyInfo {
    crate::runtime::failpoint::hit("oracle");
    let p = f.ground_size();
    assert_eq!(w.len(), p);
    assert_eq!(s_out.len(), p);
    ws.gains.resize(p, 0.0);
    ws.empty_base.clear();
    ws.empty_base.resize(p, false);
    if !argsort_desc_adaptive(w, &mut ws.order) {
        ws.full_sorts += 1;
    }
    f.prefix_gains_scratch(&ws.empty_base, &ws.order, &mut ws.gains, &mut ws.scratch);
    accumulate_pass(w, &ws.order, &ws.gains, s_out)
}

/// Regenerate the base-polytope vertex of a *given* permutation: one
/// oracle pass along `order`, scattered into `s_out`. Any permutation of
/// the ground set yields a valid vertex of `B(F)`, which is what makes
/// the projected-corral IAES restart safe — each surviving atom's induced
/// order is replayed on the contracted function, so the regenerated atom
/// is exactly a base vertex of the *new* polytope (the coordinate-wise
/// projection of the old atom generally is not; see ROADMAP.md).
///
/// Uses the workspace's gains/base/oracle buffers but leaves `ws.order`
/// untouched. Allocation-free at steady state.
pub fn vertex_from_order<F: Submodular + ?Sized>(
    f: &F,
    order: &[usize],
    ws: &mut GreedyWorkspace,
    s_out: &mut [f64],
) {
    let p = f.ground_size();
    assert_eq!(order.len(), p);
    assert_eq!(s_out.len(), p);
    ws.gains.resize(p, 0.0);
    ws.empty_base.clear();
    ws.empty_base.resize(p, false);
    f.prefix_gains_scratch(&ws.empty_base, order, &mut ws.gains, &mut ws.scratch);
    for (&j, &g) in order.iter().zip(ws.gains.iter()) {
        s_out[j] = g;
    }
}

/// Allocating reference implementation of [`greedy_base_vertex`]: fresh
/// buffers, full sort, allocating oracle path. Kept as the comparison
/// baseline for the determinism tests and the `greedy/*-alloc` bench rows;
/// bit-identical to the fast path by construction (same accumulation, same
/// total sort order).
pub fn greedy_base_vertex_ref<F: Submodular + ?Sized>(
    f: &F,
    w: &[f64],
    s_out: &mut [f64],
) -> GreedyInfo {
    let p = f.ground_size();
    assert_eq!(w.len(), p);
    assert_eq!(s_out.len(), p);
    let order = argsort_desc(w);
    let mut gains = vec![0.0; p];
    f.prefix_gains(&order, &mut gains);
    accumulate_pass(w, &order, &gains, s_out)
}

/// Shared pass accumulation: scatter gains into the vertex, accumulate the
/// Lovász value and the best prefix (super-level-set) value.
fn accumulate_pass(
    w: &[f64],
    order: &[usize],
    gains: &[f64],
    s_out: &mut [f64],
) -> GreedyInfo {
    let mut lovasz = 0.0;
    let mut prefix = 0.0;
    let mut best = 0.0; // k = 0 → F(∅) = 0
    let mut best_k = 0;
    for (k, (&j, &g)) in order.iter().zip(gains.iter()).enumerate() {
        s_out[j] = g;
        lovasz += w[j] * g;
        prefix += g;
        if prefix < best {
            best = prefix;
            best_k = k + 1;
        }
    }
    GreedyInfo { lovasz, best_level_value: best, best_level_k: best_k }
}

/// The Lovász extension `f(w)` (allocating convenience wrapper).
pub fn lovasz_value<F: Submodular + ?Sized>(f: &F, w: &[f64]) -> f64 {
    let p = f.ground_size();
    let mut ws = GreedyWorkspace::new(p);
    let mut s = vec![0.0; p];
    greedy_base_vertex(f, w, &mut ws, &mut s).lovasz
}

/// The strict sup-level set `{w > α}` as ids.
pub fn sup_level_set(w: &[f64], alpha: f64) -> Vec<usize> {
    w.iter().enumerate().filter(|(_, &x)| x > alpha).map(|(i, _)| i).collect()
}

/// The weak sup-level set `{w ≥ α}` as ids.
pub fn weak_sup_level_set(w: &[f64], alpha: f64) -> Vec<usize> {
    w.iter().enumerate().filter(|(_, &x)| x >= alpha).map(|(i, _)| i).collect()
}

/// Verify `s ∈ B(F)` by checking `s(V) = F(V)` and `s(A) ≤ F(A)` for all
/// subsets — O(2^p), test helper only.
pub fn in_base_polytope<F: Submodular + ?Sized>(f: &F, s: &[f64], tol: f64) -> bool {
    let p = f.ground_size();
    assert!(p <= 22, "exponential check");
    let total: f64 = s.iter().sum();
    let full = f.eval(&vec![true; p]);
    if (total - full).abs() > tol {
        return false;
    }
    for mask in 0u64..(1 << p) {
        let set: Vec<bool> = (0..p).map(|i| mask >> i & 1 == 1).collect();
        let s_a: f64 = (0..p).filter(|&i| set[i]).map(|i| s[i]).sum();
        if s_a > f.eval(&set) + tol {
            return false;
        }
    }
    true
}

/// `⟨w, s⟩` helper re-exported for solver code readability.
#[inline]
pub fn inner(w: &[f64], s: &[f64]) -> f64 {
    dot(w, s)
}

/// Polynomial dual-feasibility check: the largest violation of a
/// *necessary* family of `s ∈ B(F)` constraints, checkable at solver
/// scale (unlike [`in_base_polytope`], which is `O(2^p)`):
///
/// * `|s(V) − F(V)|` — the base-polytope hyperplane, and
/// * `s(A_k) − F(A_k)` for the chain of prefixes `A_k` of the ground
///   set ordered by `s` **descending** — among all cardinality-`k`
///   sets, `A_k` maximizes `s(A)`, so this is the most violated
///   cardinality-`k` constraint in that chain.
///
/// For `p ≤ 12` the exhaustive subset family is checked too, making the
/// result exact on the sizes unit tests use. Nonpositive (up to
/// roundoff) means no violation found. Allocates — diagnostic/assertion
/// use, not hot-path.
pub fn dual_feasibility_violation<F: Submodular + ?Sized>(f: &F, s: &[f64]) -> f64 {
    let p = f.ground_size();
    assert_eq!(s.len(), p);
    if p == 0 {
        return 0.0;
    }
    let order = argsort_desc(s);
    let mut gains = vec![0.0; p];
    f.prefix_gains(&order, &mut gains);
    let mut viol: f64 = 0.0;
    let mut s_pref = 0.0;
    let mut f_pref = 0.0;
    for (&j, &g) in order.iter().zip(gains.iter()) {
        s_pref += s[j];
        f_pref += g;
        viol = viol.max(s_pref - f_pref);
    }
    // After the full chain, `s_pref = s(V)` and `f_pref = F(V)`: the
    // hyperplane constraint is an equality.
    viol = viol.max((s_pref - f_pref).abs());
    if p <= 12 {
        for mask in 1u64..(1 << p) {
            let set: Vec<bool> = (0..p).map(|i| mask >> i & 1 == 1).collect();
            let s_a: f64 = (0..p).filter(|&i| set[i]).map(|i| s[i]).sum();
            viol = viol.max(s_a - f.eval(&set));
        }
    }
    viol
}

/// `debug-invariants` teeth for the ROADMAP invariant "the dual iterate
/// stays in `B(F̂)` across major-iteration boundaries": panics when
/// [`dual_feasibility_violation`] exceeds a roundoff-scaled tolerance.
/// Uses only fresh buffers and the allocating oracle path, so it never
/// perturbs a solver's persisted workspace (argsort order, scratch).
#[cfg(feature = "debug-invariants")]
pub fn debug_assert_dual_feasible<F: Submodular + ?Sized>(f: &F, s: &[f64], site: &str) {
    let viol = dual_feasibility_violation(f, s);
    let scale = 1.0 + s.iter().map(|x| x.abs()).sum::<f64>();
    assert!(
        viol <= 1e-7 * scale,
        "dual iterate left B(F) at {site}: violation {viol:.3e} (scale {scale:.3e})",
    );
}

/// No-op without `debug-invariants` (checks allocate and cost an oracle
/// pass; release hot loops must not pay for them).
#[cfg(not(feature = "debug-invariants"))]
#[inline(always)]
pub fn debug_assert_dual_feasible<F: Submodular + ?Sized>(_f: &F, _s: &[f64], _site: &str) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::submodular::concave_card::ConcaveCardFn;
    use crate::submodular::iwata::IwataFn;
    use crate::submodular::modular::ModularFn;
    use crate::testutil::forall_rng;

    #[test]
    fn greedy_vertex_in_base_polytope() {
        forall_rng(20, |rng| {
            let p = 2 + rng.below(7);
            let m = rng.uniform_vec(p, -1.0, 1.0);
            let f = ConcaveCardFn::sqrt(p, rng.uniform(0.5, 2.0), m);
            let w = rng.normal_vec(p);
            let mut ws = GreedyWorkspace::new(p);
            let mut s = vec![0.0; p];
            greedy_base_vertex(&f, &w, &mut ws, &mut s);
            if in_base_polytope(&f, &s, 1e-9) {
                Ok(())
            } else {
                Err("greedy vertex outside B(F)".into())
            }
        });
    }

    #[test]
    fn feasibility_violation_zero_on_vertices_positive_off() {
        forall_rng(20, |rng| {
            let p = 2 + rng.below(9);
            let m = rng.uniform_vec(p, -1.0, 1.0);
            let f = ConcaveCardFn::sqrt(p, rng.uniform(0.5, 2.0), m);
            let w = rng.normal_vec(p);
            let mut ws = GreedyWorkspace::new(p);
            let mut s = vec![0.0; p];
            greedy_base_vertex(&f, &w, &mut ws, &mut s);
            let v = dual_feasibility_violation(&f, &s);
            if v > 1e-9 {
                return Err(format!("vertex flagged infeasible: {v:.3e}"));
            }
            // Move mass onto the greedy-first element while keeping s(V)
            // fixed: its singleton constraint is tight at a vertex
            // (`s[hi] = F({hi})`), so the move violates it by exactly 1.
            let hi = ws.order[0];
            let lo = ws.order[p - 1];
            s[hi] += 1.0;
            s[lo] -= 1.0;
            let perturbed = dual_feasibility_violation(&f, &s);
            if perturbed <= 1e-9 {
                return Err(format!("perturbed iterate not flagged: {perturbed:.3e}"));
            }
            Ok(())
        });
    }

    #[test]
    fn lovasz_of_indicator_is_f() {
        // f(1_A) = F(A) for any A (fundamental property).
        let f = IwataFn::new(10);
        let mut rng = Pcg64::seeded(91);
        for _ in 0..30 {
            let set: Vec<bool> = (0..10).map(|_| rng.bernoulli(0.5)).collect();
            let w: Vec<f64> = set.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
            let expect = f.eval(&set);
            assert!((lovasz_value(&f, &w) - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn lovasz_positive_homogeneous_and_convex_1d_slices() {
        let f = IwataFn::new(8);
        let mut rng = Pcg64::seeded(92);
        for _ in 0..20 {
            let w = rng.normal_vec(8);
            let t = rng.uniform(0.1, 3.0);
            let tw: Vec<f64> = w.iter().map(|x| t * x).collect();
            assert!(
                (lovasz_value(&f, &tw) - t * lovasz_value(&f, &w)).abs() < 1e-8
            );
            // Midpoint convexity along a random segment.
            let v = rng.normal_vec(8);
            let mid: Vec<f64> = w.iter().zip(&v).map(|(a, b)| 0.5 * (a + b)).collect();
            let lhs = lovasz_value(&f, &mid);
            let rhs = 0.5 * lovasz_value(&f, &w) + 0.5 * lovasz_value(&f, &v);
            assert!(lhs <= rhs + 1e-9);
        }
    }

    #[test]
    fn greedy_maximizes_over_vertices() {
        // ⟨w, s_greedy(w)⟩ ≥ ⟨w, s_greedy(u)⟩ for any direction u.
        let f = IwataFn::new(7);
        let mut rng = Pcg64::seeded(93);
        let mut ws = GreedyWorkspace::new(7);
        for _ in 0..25 {
            let w = rng.normal_vec(7);
            let u = rng.normal_vec(7);
            let mut sw = vec![0.0; 7];
            let mut su = vec![0.0; 7];
            let info = greedy_base_vertex(&f, &w, &mut ws, &mut sw);
            greedy_base_vertex(&f, &u, &mut ws, &mut su);
            assert!(info.lovasz >= inner(&w, &su) - 1e-9);
        }
    }

    #[test]
    fn best_level_value_matches_scan() {
        let f = IwataFn::new(9);
        let mut rng = Pcg64::seeded(94);
        let mut ws = GreedyWorkspace::new(9);
        let mut s = vec![0.0; 9];
        for _ in 0..10 {
            let w = rng.normal_vec(9);
            let info = greedy_base_vertex(&f, &w, &mut ws, &mut s);
            // Recompute F at all prefixes directly.
            let mut best = 0.0f64;
            for k in 0..=9 {
                let ids: Vec<usize> = ws.order[..k].to_vec();
                let v = crate::submodular::SubmodularExt::eval_ids(&f, &ids);
                best = best.min(v);
            }
            assert!((info.best_level_value - best).abs() < 1e-9);
        }
    }

    #[test]
    fn warm_workspace_is_bit_identical_to_reference() {
        // Simulate the solver's direction evolution: a slowly drifting
        // vector with occasional jumps, one *reused* workspace. Every pass
        // must match the allocating/full-sort reference bit for bit —
        // order, gains, vertex, and summary.
        use crate::submodular::cut::CutFn;
        let mut rng = Pcg64::seeded(421);
        let p = 60;
        let mut edges = Vec::new();
        for i in 0..p {
            for j in (i + 1)..p {
                if rng.bernoulli(0.15) {
                    edges.push((i, j, rng.uniform(0.0, 1.5)));
                }
            }
        }
        let f = CutFn::from_edges(p, &edges, rng.uniform_vec(p, -1.0, 1.0));
        let mut ws = GreedyWorkspace::new(p);
        let mut w = rng.normal_vec(p);
        let mut s_fast = vec![0.0; p];
        let mut s_ref = vec![0.0; p];
        for step in 0..60 {
            let fast = greedy_base_vertex(&f, &w, &mut ws, &mut s_fast);
            let refr = greedy_base_vertex_ref(&f, &w, &mut s_ref);
            assert_eq!(ws.order, crate::linalg::vecops::argsort_desc(&w));
            for j in 0..p {
                assert_eq!(
                    s_fast[j].to_bits(),
                    s_ref[j].to_bits(),
                    "vertex differs at {j} step {step}"
                );
            }
            assert_eq!(fast.lovasz.to_bits(), refr.lovasz.to_bits());
            assert_eq!(fast.best_level_k, refr.best_level_k);
            // Drift (typical between major iterations), jump every 13th.
            if step % 13 == 12 {
                w = rng.normal_vec(p);
            } else {
                for x in w.iter_mut() {
                    *x += 0.02 * rng.normal();
                }
            }
        }
    }

    #[test]
    fn contraction_map_rebuild_and_lookup() {
        let mut map = ContractionMap::new();
        map.rebuild(&[2, 5, 7, 9, 11], &[2, 7, 11]);
        assert_eq!(map.old_len(), 5);
        assert_eq!(map.new_len(), 3);
        const GONE: usize = ContractionMap::REMOVED;
        assert_eq!(map.new_of_old(), &[0, GONE, 1, GONE, 2]);
        assert_eq!(map.new_index(0), Some(0));
        assert_eq!(map.new_index(1), None);
        assert_eq!(map.new_index(4), Some(2));
        // Removed-to-active annotations: off by default, sticky per
        // rebuild, and reset by the next rebuild.
        assert!(!map.went_active(1));
        map.mark_active(1);
        assert!(map.went_active(1));
        assert!(!map.went_active(3));
        // Reuse: rebuild with a different shape.
        map.rebuild(&[0, 1, 2], &[1]);
        assert_eq!(map.old_len(), 3);
        assert_eq!(map.new_len(), 1);
        assert_eq!(map.new_index(1), Some(0));
        assert!(!map.went_active(0), "rebuild must clear active marks");
    }

    #[test]
    #[should_panic(expected = "subsequence")]
    fn contraction_map_rejects_non_subsequence() {
        let mut map = ContractionMap::new();
        map.rebuild(&[2, 5, 7], &[3]);
    }

    #[test]
    fn workspace_contract_feeds_repair_not_resort() {
        // Greedy pass on the full problem, contract, greedy pass on the
        // reduced problem: the full-sort counter must not move, and the
        // order must equal the reference sort.
        let f = IwataFn::new(12);
        let mut ws = GreedyWorkspace::new(12);
        let mut rng = Pcg64::seeded(515);
        let w = rng.normal_vec(12);
        let mut s = vec![0.0; 12];
        greedy_base_vertex(&f, &w, &mut ws, &mut s);
        assert_eq!(ws.full_sorts, 1, "first pass is the cold sort");
        // Contract: keep reduced elements {0,2,3,5,6,8,9,11}.
        let old_kept: Vec<usize> = (0..12).collect();
        let new_kept = vec![0, 2, 3, 5, 6, 8, 9, 11];
        let mut map = ContractionMap::new();
        map.rebuild(&old_kept, &new_kept);
        ws.contract(&map);
        let g = IwataFn::new(8);
        let w_red: Vec<f64> = new_kept.iter().map(|&i| w[i]).collect();
        let mut s_red = vec![0.0; 8];
        greedy_base_vertex(&g, &w_red, &mut ws, &mut s_red);
        assert_eq!(ws.full_sorts, 1, "contraction must not cost a re-sort");
        assert_eq!(ws.order, crate::linalg::vecops::argsort_desc(&w_red));
        // With the remap disabled the same contraction cold-sorts — and
        // still lands on the identical order.
        let mut ws2 = GreedyWorkspace::new(12);
        greedy_base_vertex(&f, &w, &mut ws2, &mut s);
        map.remap_argsort = false;
        ws2.contract(&map);
        greedy_base_vertex(&g, &w_red, &mut ws2, &mut s_red);
        assert_eq!(ws2.full_sorts, 2, "disabled remap must cold-sort");
        assert_eq!(ws2.order, ws.order);
    }

    #[test]
    fn vertex_from_order_matches_greedy_on_its_own_order() {
        let f = IwataFn::new(10);
        let mut rng = Pcg64::seeded(616);
        let w = rng.normal_vec(10);
        let mut ws = GreedyWorkspace::new(10);
        let mut s = vec![0.0; 10];
        greedy_base_vertex(&f, &w, &mut ws, &mut s);
        let order = ws.order.clone();
        let mut s2 = vec![f64::NAN; 10];
        vertex_from_order(&f, &order, &mut ws, &mut s2);
        for (a, b) in s.iter().zip(&s2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(ws.order, order, "vertex_from_order must not touch the order");
    }

    #[test]
    fn vertex_from_order_any_permutation_is_in_base_polytope() {
        forall_rng(15, |rng| {
            let p = 3 + rng.below(6);
            let m = rng.uniform_vec(p, -1.0, 1.0);
            let f = ConcaveCardFn::sqrt(p, rng.uniform(0.5, 2.0), m);
            let mut order: Vec<usize> = (0..p).collect();
            rng.shuffle(&mut order);
            let mut ws = GreedyWorkspace::new(p);
            let mut s = vec![0.0; p];
            vertex_from_order(&f, &order, &mut ws, &mut s);
            if in_base_polytope(&f, &s, 1e-9) {
                Ok(())
            } else {
                Err("regenerated vertex outside B(F)".into())
            }
        });
    }

    #[test]
    fn modular_greedy_is_weights() {
        let f = ModularFn::new(vec![2.0, -1.0, 0.5]);
        let mut ws = GreedyWorkspace::new(3);
        let mut s = vec![0.0; 3];
        greedy_base_vertex(&f, &[0.3, 0.2, 0.9], &mut ws, &mut s);
        assert_eq!(s, vec![2.0, -1.0, 0.5]);
    }

    #[test]
    fn level_sets() {
        let w = [0.5, -0.1, 0.0, 2.0];
        assert_eq!(sup_level_set(&w, 0.0), vec![0, 3]);
        assert_eq!(weak_sup_level_set(&w, 0.0), vec![0, 2, 3]);
    }
}
