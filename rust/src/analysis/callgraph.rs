//! Whole-crate call graph over the [`super::lexer`] token stream.
//!
//! This is the interprocedural layer under the transitive lint rules
//! (`hot-path-alloc`, `no-panic-paths`, `boundary-coupling`): function
//! items are extracted per file (module path from the file layout,
//! impl-block self-type attribution), call sites are classified as free
//! (`foo(…)`), associated (`Type::foo(…)`), or method (`.foo(…)`)
//! calls, and name-based conservative resolution wires them into a
//! graph with deterministic iteration order (files sorted, functions in
//! source order, edges in call order). Reachability from a root set —
//! with parent pointers, so every reached function carries its
//! *shortest* call chain back to a root — is what turns the PR-7
//! per-body allowlists into computed properties.
//!
//! Three deliberate analysis decisions, all visible in the tests:
//!
//! * **The production build is the subject.** Items and statements
//!   gated behind `#[cfg(test)]` or a diagnostic feature
//!   (`debug-invariants`, `failpoint`) are stripped from the token
//!   stream before anything looks at it — the armed failpoint registry
//!   and the dual-feasibility assert allocate *by design* and only
//!   exist under their features (LINTS.md).
//! * **Method calls resolve conservatively but not promiscuously.**
//!   A `.foo(…)` call resolves to every in-crate *method* named `foo`
//!   (same-file candidates preferred), except for names on
//!   [`METHOD_STOP`] — `push`, `load`, `sqrt`, … — whose receivers are
//!   overwhelmingly std types; resolving those would wire every
//!   `Vec::push` to the crate's own `push` methods and drown the graph
//!   in false edges.
//! * **`catch_unwind` contains panics, not allocations.** Call edges
//!   whose call site sits syntactically inside a `catch_unwind(…)`
//!   argument list are marked `contained`; the no-panic reachability
//!   pass skips them (the panic cannot escape), the hot-path pass does
//!   not (the allocation still happens).

use super::lexer::{Token, TokenKind};
use std::collections::BTreeMap;
use std::collections::VecDeque;

/// Features whose gated code is invisible to the analysis: both are
/// diagnostic-only builds (runtime invariant asserts, fault injection)
/// that allocate and panic by design and are off in production.
pub const CFG_OFF_FEATURES: &[&str] = &["debug-invariants", "failpoint"];

/// Method-call names that never resolve to in-crate methods: receivers
/// with these names are overwhelmingly std types (`Vec`, slices,
/// atomics, floats, iterators), so name-based resolution would produce
/// a false edge for nearly every call site. In-crate hot methods with
/// colliding names (`IncrementalCholesky::push`/`remove`/`retain`) are
/// covered by being hot *roots* themselves; scratch-state names
/// (`reset`, `clear`, `resize`) fall under the amortized-reuse
/// carve-out documented in LINTS.md.
pub const METHOD_STOP: &[&str] = &[
    "abs",
    "and_then",
    "as_mut",
    "as_ref",
    "borrow",
    "ceil",
    "clear",
    "clone",
    "cmp",
    "contains",
    "count",
    "default",
    "drain",
    "drop",
    "eq",
    "exp",
    "extend",
    "fill",
    "filter",
    "finish",
    "first",
    "floor",
    "flush",
    "fmt",
    "fold",
    "from",
    "get",
    "get_mut",
    "hash",
    "insert",
    "into",
    "is_empty",
    "iter",
    "iter_mut",
    "join",
    "last",
    "len",
    "ln",
    "load",
    "lock",
    "map",
    "max",
    "min",
    "new",
    "next",
    "pop",
    "position",
    "powf",
    "powi",
    "product",
    "push",
    "read",
    "recv",
    "remove",
    "replace",
    "reserve",
    "reset",
    "resize",
    "retain",
    "round",
    "send",
    "sort",
    "sort_by",
    "sort_unstable",
    "sort_unstable_by",
    "split_off",
    "sqrt",
    "store",
    "sum",
    "swap",
    "take",
    "to_bits",
    "truncate",
    "wait",
    "write",
];

// ---------------------------------------------------------------------
// cfg stripping
// ---------------------------------------------------------------------

/// Whether the attribute body `inner` (the tokens between `[` and `]`)
/// is a `cfg(…)` predicate that is **off** in the production build.
/// `cfg(not(…))` is conservatively kept (the negated form is exactly
/// how the no-op stubs are gated in).
fn cfg_is_off(inner: &[&Token]) -> bool {
    if !inner.first().is_some_and(|t| t.is_ident("cfg")) {
        return false;
    }
    if !inner.get(1).is_some_and(|t| t.is_punct('(')) {
        return false;
    }
    let args = &inner[2..];
    let first_ident = args.iter().find(|t| t.kind == TokenKind::Ident);
    if first_ident.is_some_and(|t| t.is_ident("not")) {
        return false;
    }
    if args.iter().any(|t| t.is_ident("test")) {
        return true;
    }
    args.iter().any(|t| {
        t.kind == TokenKind::StrLit && CFG_OFF_FEATURES.contains(&t.text.trim_matches('"'))
    })
}

/// With `code[i]` a `#`: return the index just past the attribute's
/// closing `]` and the inner tokens, or `None` if no `[` follows.
fn attr_span<'a>(code: &'a [Token], i: usize) -> Option<(usize, Vec<&'a Token>)> {
    let mut j = i + 1;
    if code.get(j).is_some_and(|t| t.is_punct('!')) {
        j += 1;
    }
    if !code.get(j).is_some_and(|t| t.is_punct('[')) {
        return None;
    }
    let mut depth = 1usize;
    j += 1;
    let start = j;
    while j < code.len() && depth > 0 {
        if code[j].is_punct('[') {
            depth += 1;
        } else if code[j].is_punct(']') {
            depth -= 1;
        }
        j += 1;
    }
    let inner = code[start..j.saturating_sub(1)].iter().collect();
    Some((j, inner))
}

/// With `j` just past a stripped attribute's `]`: consume any further
/// attributes plus one item / statement / struct field, returning the
/// index just past it. An item body (`{ … }`) ends the node; so does a
/// `;` or `,` at bracket depth zero (angle brackets tracked shallowly,
/// enough for `field: Mutex<Vec<(usize, usize)>>,`); so does the close
/// of the enclosing group.
fn skip_node(code: &[Token], mut j: usize) -> usize {
    let n = code.len();
    while j < n && code[j].is_punct('#') {
        match attr_span(code, j) {
            Some((end, _)) => j = end,
            None => break,
        }
    }
    let mut depth = 0i32;
    let mut angle = 0i32;
    while j < n {
        let t = &code[j];
        match t.kind {
            TokenKind::Punct('(') | TokenKind::Punct('[') => depth += 1,
            TokenKind::Punct(')') | TokenKind::Punct(']') => {
                if depth == 0 {
                    return j;
                }
                depth -= 1;
            }
            TokenKind::Punct('<') => angle += 1,
            TokenKind::Punct('>') => angle = (angle - 1).max(0),
            TokenKind::Punct('{') if depth == 0 => {
                let mut braces = 1i32;
                j += 1;
                while j < n && braces > 0 {
                    if code[j].is_punct('{') {
                        braces += 1;
                    } else if code[j].is_punct('}') {
                        braces -= 1;
                    }
                    j += 1;
                }
                return j;
            }
            TokenKind::Punct('}') if depth == 0 => return j,
            TokenKind::Punct(';') | TokenKind::Punct(',') if depth == 0 && angle == 0 => {
                return j + 1;
            }
            _ => {}
        }
        j += 1;
    }
    j
}

/// Drop every node gated behind an off `cfg(…)` attribute (see
/// [`cfg_is_off`]) from a comment-free token stream.
pub fn strip_cfg_off(code: Vec<Token>) -> Vec<Token> {
    let mut out = Vec::with_capacity(code.len());
    let mut i = 0;
    while i < code.len() {
        if code[i].is_punct('#') {
            if let Some((end, inner)) = attr_span(&code, i) {
                if cfg_is_off(&inner) {
                    i = skip_node(&code, end);
                    continue;
                }
                out.extend(code[i..end].iter().cloned());
                i = end;
                continue;
            }
        }
        out.push(code[i].clone());
        i += 1;
    }
    out
}

// ---------------------------------------------------------------------
// fn-item extraction
// ---------------------------------------------------------------------

/// One extracted function item. `body` holds the token indices of the
/// opening and closing braces in the owning file's code-token stream.
#[derive(Debug, Clone)]
pub struct FnItem {
    /// `/`-normalized file label.
    pub file: String,
    /// Function name (raw-ident prefix stripped: `fn r#loop` → `loop`).
    pub name: String,
    /// Self type when defined inside an `impl` block.
    pub self_type: Option<String>,
    /// 1-based line of the `fn` keyword.
    pub line: u32,
    /// Token indices of the body's `{` and `}` in the file's stream.
    pub body: (usize, usize),
    /// First parameter is (some form of) `self`.
    pub has_self: bool,
    /// Defined in test code: a `mod tests`, a test/bench source file.
    pub is_test: bool,
    /// Body ranges of functions nested inside this one (token scans of
    /// this body must skip them — they are items of their own).
    pub nested: Vec<(usize, usize)>,
}

enum FrameKind {
    Impl,
    Mod,
    Fn,
    Brace,
}

struct Frame {
    kind: FrameKind,
    self_type: Option<String>,
    test: bool,
}

fn is_test_file(file: &str) -> bool {
    file.contains("/tests/") || file.contains("/benches/") || file.ends_with("build.rs")
}

/// Scan an `impl` header starting just past the `impl` keyword: returns
/// `(self type, index of the opening '{' or terminating ';')`. The self
/// type is the last path segment at angle depth 0 before the brace; an
/// `impl Trait for Type` header takes the segment after `for`.
fn scan_impl_header(code: &[Token], mut j: usize) -> (Option<String>, usize) {
    let mut angle = 0i32;
    let mut last_ident: Option<String> = None;
    let mut after_for = false;
    let mut for_ident: Option<String> = None;
    let mut in_where = false;
    while j < code.len() {
        let t = &code[j];
        match t.kind {
            TokenKind::Punct('<') => angle += 1,
            TokenKind::Punct('>') => angle = (angle - 1).max(0),
            TokenKind::Ident if angle == 0 => {
                if t.is_ident("for") {
                    after_for = true;
                    for_ident = None;
                } else if t.is_ident("where") {
                    in_where = true;
                } else if !in_where {
                    if after_for && for_ident.is_none() {
                        for_ident = Some(t.ident_name().to_string());
                    }
                    last_ident = Some(t.ident_name().to_string());
                }
            }
            TokenKind::Punct('{') | TokenKind::Punct(';') if angle == 0 => break,
            _ => {}
        }
        j += 1;
    }
    let self_type = if after_for && for_ident.is_some() {
        for_ident
    } else {
        last_ident
    };
    (self_type, j)
}

/// Extract every fn item from one file's (comment-free, cfg-stripped)
/// token stream, in source order.
pub fn extract_fns(file: &str, code: &[Token]) -> Vec<FnItem> {
    let mut fns: Vec<FnItem> = Vec::new();
    let test_file = is_test_file(file);
    let mut stack: Vec<Frame> = Vec::new();
    let mut i = 0;
    let n = code.len();
    while i < n {
        let t = &code[i];
        if t.is_punct('#') {
            if let Some((end, _)) = attr_span(code, i) {
                i = end;
                continue;
            }
        }
        if t.is_ident("impl") {
            let (self_type, j) = scan_impl_header(code, i + 1);
            if code.get(j).is_some_and(|t| t.is_punct('{')) {
                stack.push(Frame { kind: FrameKind::Impl, self_type, test: false });
                i = j + 1;
            } else {
                i = j.max(i + 1);
            }
            continue;
        }
        if t.is_ident("mod") {
            let mut j = i + 1;
            let mut name = String::new();
            if let Some(id) = code.get(j).filter(|t| t.kind == TokenKind::Ident) {
                name = id.ident_name().to_string();
                j += 1;
            }
            if code.get(j).is_some_and(|t| t.is_punct('{')) {
                stack.push(Frame {
                    kind: FrameKind::Mod,
                    self_type: None,
                    test: name == "tests",
                });
                i = j + 1;
            } else {
                i = j;
            }
            continue;
        }
        if t.is_ident("fn") {
            if let Some(name_tok) = code.get(i + 1).filter(|t| t.kind == TokenKind::Ident) {
                let name = name_tok.ident_name().to_string();
                let mut j = i + 2;
                let mut depth = 0i32;
                let mut open_idx = None;
                let mut first_paren = None;
                while j < n {
                    match code[j].kind {
                        TokenKind::Punct('(') | TokenKind::Punct('[') => {
                            if first_paren.is_none() && code[j].is_punct('(') {
                                first_paren = Some(j);
                            }
                            depth += 1;
                        }
                        TokenKind::Punct(')') | TokenKind::Punct(']') => depth -= 1,
                        TokenKind::Punct(';') if depth == 0 => break,
                        TokenKind::Punct('{') if depth == 0 => {
                            open_idx = Some(j);
                            break;
                        }
                        _ => {}
                    }
                    j += 1;
                }
                let has_self = first_paren.is_some_and(|p| {
                    let mut m = p + 1;
                    while code.get(m).is_some_and(|t| {
                        t.is_punct('&') || t.kind == TokenKind::Lifetime || t.is_ident("mut")
                    }) {
                        m += 1;
                    }
                    code.get(m).is_some_and(|t| t.is_ident("self"))
                });
                if let Some(open) = open_idx {
                    let mut braces = 1i32;
                    let mut k = open + 1;
                    while k < n && braces > 0 {
                        if code[k].is_punct('{') {
                            braces += 1;
                        } else if code[k].is_punct('}') {
                            braces -= 1;
                        }
                        k += 1;
                    }
                    let in_test = test_file || stack.iter().any(|f| f.test);
                    let self_type = stack
                        .iter()
                        .rev()
                        .find(|f| matches!(f.kind, FrameKind::Impl))
                        .and_then(|f| f.self_type.clone());
                    fns.push(FnItem {
                        file: file.to_string(),
                        name,
                        self_type,
                        line: t.line,
                        body: (open, k.saturating_sub(1)),
                        has_self,
                        is_test: in_test,
                        nested: Vec::new(),
                    });
                    // Keep scanning *inside* the body for nested items;
                    // the frame keeps test-ness and brace depth right.
                    stack.push(Frame {
                        kind: FrameKind::Fn,
                        self_type: None,
                        test: in_test,
                    });
                    i = open + 1;
                    continue;
                }
                i = j;
                continue;
            }
        }
        if t.is_punct('{') {
            stack.push(Frame { kind: FrameKind::Brace, self_type: None, test: false });
            i += 1;
            continue;
        }
        if t.is_punct('}') {
            stack.pop();
            i += 1;
            continue;
        }
        i += 1;
    }
    // Record nested-fn body ranges so body scans can skip them.
    let ranges: Vec<(usize, usize)> = fns.iter().map(|f| f.body).collect();
    for f in &mut fns {
        for &(lo, hi) in &ranges {
            if lo > f.body.0 && hi < f.body.1 {
                f.nested.push((lo, hi));
            }
        }
    }
    fns
}

// ---------------------------------------------------------------------
// call-site extraction
// ---------------------------------------------------------------------

/// How a call site is spelled, which determines resolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CallKind {
    /// `foo(…)`.
    Free,
    /// `Type::foo(…)` (the qualifier is the path segment before `::`).
    Assoc,
    /// `recv.foo(…)`.
    Method,
}

/// One syntactic call site inside a function body.
#[derive(Debug, Clone)]
pub struct CallSite {
    pub kind: CallKind,
    /// Path segment before `::` for associated calls.
    pub qual: Option<String>,
    pub name: String,
    pub line: u32,
    /// Sits inside a `catch_unwind(…)` argument list.
    pub contained: bool,
}

/// Keywords that can precede `(` without forming a call (`if (…)`,
/// `match (…)`, `return (…)`, …).
const KEYWORDS_NONCALL: &[&str] = &[
    "Self", "as", "async", "await", "box", "break", "const", "continue", "crate", "dyn",
    "else", "enum", "extern", "fn", "for", "if", "impl", "in", "let", "loop", "match",
    "mod", "move", "mut", "pub", "ref", "return", "self", "static", "struct", "super",
    "trait", "type", "union", "unsafe", "use", "where", "while", "yield",
];

/// Ranges of `catch_unwind(…)` argument tokens within `lo..=hi`.
fn contained_ranges(code: &[Token], lo: usize, hi: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut k = lo;
    while k < hi {
        if code[k].is_ident("catch_unwind") && code.get(k + 1).is_some_and(|t| t.is_punct('(')) {
            let mut d = 1i32;
            let mut j = k + 2;
            while j <= hi && d > 0 {
                if code[j].is_punct('(') {
                    d += 1;
                } else if code[j].is_punct(')') {
                    d -= 1;
                }
                j += 1;
            }
            out.push((k + 1, j.saturating_sub(1)));
            k = j;
        } else {
            k += 1;
        }
    }
    out
}

/// Extract the call sites in `item`'s body, skipping nested fn items.
pub fn extract_calls(code: &[Token], item: &FnItem) -> Vec<CallSite> {
    let (lo, hi) = item.body;
    let contained = contained_ranges(code, lo, hi);
    let in_contained = |k: usize| contained.iter().any(|&(a, b)| a <= k && k <= b);
    let in_nested = |k: usize| item.nested.iter().any(|&(a, b)| a <= k && k <= b);
    let mut out = Vec::new();
    let mut k = lo + 1;
    while k < hi {
        if in_nested(k) {
            k += 1;
            continue;
        }
        let t = &code[k];
        if t.kind == TokenKind::Ident && code.get(k + 1).is_some_and(|n| n.is_punct('(')) {
            let name = t.ident_name().to_string();
            let prev = &code[k - 1];
            if prev.is_punct('.') {
                out.push(CallSite {
                    kind: CallKind::Method,
                    qual: None,
                    name,
                    line: t.line,
                    contained: in_contained(k),
                });
            } else if prev.is_punct(':') && k >= 2 && code[k - 2].is_punct(':') {
                let qual = (k >= 3)
                    .then(|| &code[k - 3])
                    .filter(|q| q.kind == TokenKind::Ident)
                    .map(|q| q.ident_name().to_string());
                out.push(CallSite {
                    kind: CallKind::Assoc,
                    qual,
                    name,
                    line: t.line,
                    contained: in_contained(k),
                });
            } else if !prev.is_ident("fn") && !KEYWORDS_NONCALL.contains(&name.as_str()) {
                out.push(CallSite {
                    kind: CallKind::Free,
                    qual: None,
                    name,
                    line: t.line,
                    contained: in_contained(k),
                });
            }
        }
        k += 1;
    }
    out
}

// ---------------------------------------------------------------------
// the graph
// ---------------------------------------------------------------------

/// One resolved call edge.
#[derive(Debug, Clone, Copy)]
pub struct Edge {
    /// Callee's index into [`CallGraph::fns`].
    pub callee: usize,
    /// Line of the call site (in the caller's file).
    pub line: u32,
    /// Call site sits inside a `catch_unwind(…)` argument list.
    pub contained: bool,
}

/// The whole-crate call graph. Iteration order is deterministic: files
/// in sorted order, functions in source order, edges in call order.
pub struct CallGraph {
    pub fns: Vec<FnItem>,
    edges: Vec<Vec<Edge>>,
    code: BTreeMap<String, Vec<Token>>,
}

impl CallGraph {
    /// Build the graph from `label → source` pairs. Labels should be
    /// `/`-normalized paths; sources are lexed, comment-stripped, and
    /// cfg-stripped before extraction.
    pub fn build(files: &BTreeMap<String, String>) -> CallGraph {
        let mut code_map: BTreeMap<String, Vec<Token>> = BTreeMap::new();
        for (label, src) in files {
            let toks: Vec<Token> =
                super::lexer::lex(src).into_iter().filter(|t| !t.is_comment()).collect();
            code_map.insert(label.clone(), strip_cfg_off(toks));
        }
        let mut fns: Vec<FnItem> = Vec::new();
        for (label, code) in &code_map {
            fns.extend(extract_fns(label, code));
        }
        // Name index over non-test fns, in deterministic order.
        let mut by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for (idx, f) in fns.iter().enumerate() {
            if !f.is_test {
                by_name.entry(f.name.as_str()).or_default().push(idx);
            }
        }
        let mut edges: Vec<Vec<Edge>> = vec![Vec::new(); fns.len()];
        for (caller, f) in fns.iter().enumerate() {
            let code = &code_map[&f.file];
            for call in extract_calls(code, f) {
                let Some(cands) = by_name.get(call.name.as_str()) else {
                    continue;
                };
                let resolved: Vec<usize> = match call.kind {
                    CallKind::Free => {
                        let same: Vec<usize> = cands
                            .iter()
                            .copied()
                            .filter(|&c| fns[c].file == f.file)
                            .collect();
                        if same.is_empty() { cands.clone() } else { same }
                    }
                    CallKind::Assoc => match call.qual.as_deref() {
                        None => cands.clone(),
                        Some("Self") => {
                            let same_impl: Vec<usize> = cands
                                .iter()
                                .copied()
                                .filter(|&c| {
                                    fns[c].file == f.file && fns[c].self_type == f.self_type
                                })
                                .collect();
                            if same_impl.is_empty() {
                                cands
                                    .iter()
                                    .copied()
                                    .filter(|&c| fns[c].self_type == f.self_type)
                                    .collect()
                            } else {
                                same_impl
                            }
                        }
                        Some(q) => {
                            let by_type: Vec<usize> = cands
                                .iter()
                                .copied()
                                .filter(|&c| fns[c].self_type.as_deref() == Some(q))
                                .collect();
                            if by_type.is_empty() {
                                // Module-qualified free fn (`pav::run`);
                                // no fallback — an unmatched qualifier
                                // is a std/extern type.
                                let file_name = format!("{q}.rs");
                                cands
                                    .iter()
                                    .copied()
                                    .filter(|&c| {
                                        fns[c].file.rsplit('/').next() == Some(&file_name)
                                    })
                                    .collect()
                            } else {
                                by_type
                            }
                        }
                    },
                    CallKind::Method => {
                        if METHOD_STOP.contains(&call.name.as_str()) {
                            Vec::new()
                        } else {
                            let methods: Vec<usize> = cands
                                .iter()
                                .copied()
                                .filter(|&c| fns[c].self_type.is_some() && fns[c].has_self)
                                .collect();
                            let same: Vec<usize> = methods
                                .iter()
                                .copied()
                                .filter(|&c| fns[c].file == f.file)
                                .collect();
                            if same.is_empty() { methods } else { same }
                        }
                    }
                };
                for callee in resolved {
                    edges[caller].push(Edge {
                        callee,
                        line: call.line,
                        contained: call.contained,
                    });
                }
            }
        }
        CallGraph { fns, edges, code: code_map }
    }

    /// The (comment-free, cfg-stripped) token stream of `file`, which
    /// [`FnItem::body`] indices refer into.
    pub fn file_code(&self, file: &str) -> &[Token] {
        self.code.get(file).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Outgoing edges of fn `idx`.
    pub fn edges_of(&self, idx: usize) -> &[Edge] {
        &self.edges[idx]
    }

    /// Indices of non-test fns named `name` in files whose label
    /// contains `pattern`.
    pub fn find(&self, pattern: &str, name: &str) -> Vec<usize> {
        self.fns
            .iter()
            .enumerate()
            .filter(|(_, f)| !f.is_test && f.name == name && f.file.contains(pattern))
            .map(|(i, _)| i)
            .collect()
    }

    /// BFS reachability from `roots`. Parent pointers record, for each
    /// reached fn, the caller and call line it was first discovered
    /// through — BFS order makes the resulting chain a *shortest* one.
    /// `skip_contained` drops edges whose call site is inside a
    /// `catch_unwind(…)` argument list (panic propagation stops there;
    /// allocation does not).
    pub fn reach(&self, roots: &[usize], skip_contained: bool) -> Reach {
        let mut seen = vec![false; self.fns.len()];
        let mut parent: Vec<Option<(usize, u32)>> = vec![None; self.fns.len()];
        let mut queue = VecDeque::new();
        for &r in roots {
            if !seen[r] {
                seen[r] = true;
                queue.push_back(r);
            }
        }
        let mut order = Vec::new();
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for e in &self.edges[u] {
                if skip_contained && e.contained {
                    continue;
                }
                if !seen[e.callee] {
                    seen[e.callee] = true;
                    parent[e.callee] = Some((u, e.line));
                    queue.push_back(e.callee);
                }
            }
        }
        Reach { seen, parent, order }
    }

    /// The call chain from a root to fn `idx` under `reach`, one
    /// rendered hop per element: `file::name (root @line)` for the
    /// root, `file::name (called at caller_file:line)` for each step.
    pub fn chain(&self, reach: &Reach, idx: usize) -> Vec<String> {
        let mut hops = Vec::new();
        let mut cur = idx;
        loop {
            let f = &self.fns[cur];
            match reach.parent[cur] {
                None => {
                    hops.push(format!("{}::{} (root @{})", f.file, f.name, f.line));
                    break;
                }
                Some((caller, line)) => {
                    hops.push(format!(
                        "{}::{} (called at {}:{})",
                        f.file, f.name, self.fns[caller].file, line
                    ));
                    cur = caller;
                }
            }
        }
        hops.reverse();
        hops
    }
}

/// Reachability result: `seen[i]` / `order` (BFS discovery order) /
/// parent pointers for chain reconstruction.
pub struct Reach {
    pub seen: Vec<bool>,
    parent: Vec<Option<(usize, u32)>>,
    pub order: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph(files: &[(&str, &str)]) -> CallGraph {
        let map: BTreeMap<String, String> =
            files.iter().map(|&(l, s)| (l.to_string(), s.to_string())).collect();
        CallGraph::build(&map)
    }

    fn idx(g: &CallGraph, file: &str, name: &str) -> usize {
        let found = g.find(file, name);
        assert_eq!(found.len(), 1, "{file}::{name}: {found:?}");
        found[0]
    }

    #[test]
    fn extracts_fns_with_impl_self_types() {
        let g = graph(&[(
            "src/a.rs",
            "struct S;\nimpl S {\n    fn m(&self) {}\n    fn assoc() {}\n}\n\
             impl Clone for S {\n    fn clone(&self) -> S { S }\n}\nfn free() {}\n",
        )]);
        let m = &g.fns[idx(&g, "a.rs", "m")];
        assert_eq!(m.self_type.as_deref(), Some("S"));
        assert!(m.has_self);
        let assoc = &g.fns[idx(&g, "a.rs", "assoc")];
        assert_eq!(assoc.self_type.as_deref(), Some("S"));
        assert!(!assoc.has_self);
        let clone = &g.fns[idx(&g, "a.rs", "clone")];
        assert_eq!(clone.self_type.as_deref(), Some("S"), "impl Trait for Type");
        assert!(g.fns[idx(&g, "a.rs", "free")].self_type.is_none());
    }

    #[test]
    fn raw_ident_fn_names_are_stripped() {
        let g = graph(&[("src/a.rs", "fn r#loop() {}\nfn caller() { r#loop(); }\n")]);
        let target = idx(&g, "a.rs", "loop");
        let caller = idx(&g, "a.rs", "caller");
        assert!(g.edges_of(caller).iter().any(|e| e.callee == target));
    }

    #[test]
    fn cfg_test_and_diag_features_are_stripped() {
        let g = graph(&[(
            "src/a.rs",
            "fn live() {}\n\
             #[cfg(test)]\nmod tests {\n    fn helper() {}\n}\n\
             #[cfg(feature = \"debug-invariants\")]\nfn armed() {}\n\
             #[cfg(not(feature = \"debug-invariants\"))]\nfn stub() {}\n\
             #[cfg(feature = \"failpoint\")]\nmod imp {\n    pub fn hit() {}\n}\n",
        )]);
        let names: Vec<&str> = g.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["live", "stub"]);
    }

    #[test]
    fn cfg_stripped_statements_and_fields() {
        // A gated statement and a gated struct field (generic type with
        // commas) disappear; the surrounding tokens stay intact.
        let g = graph(&[(
            "src/a.rs",
            "struct D {\n    ptr: usize,\n    #[cfg(feature = \"debug-invariants\")]\n    \
             claims: Mutex<Vec<(usize, usize)>>,\n    len: usize,\n}\n\
             fn f() {\n    #[cfg(feature = \"debug-invariants\")]\n    \
             assert_eq!(1, 1);\n    g();\n}\nfn g() {}\n",
        )]);
        let f = idx(&g, "a.rs", "f");
        let code = g.file_code("src/a.rs");
        let (lo, hi) = g.fns[f].body;
        assert!(!code[lo..=hi].iter().any(|t| t.is_ident("assert_eq")));
        assert!(g.edges_of(f).iter().any(|e| e.callee == idx(&g, "a.rs", "g")));
        assert!(!code.iter().any(|t| t.is_ident("claims")));
        assert!(code.iter().any(|t| t.is_ident("len")));
    }

    #[test]
    fn free_assoc_and_method_calls_resolve() {
        let g = graph(&[
            (
                "src/a.rs",
                "pub fn entry(s: &S) {\n    helper();\n    S::assoc();\n    s.work();\n}\n\
                 fn helper() {}\n",
            ),
            (
                "src/b.rs",
                "pub struct S;\nimpl S {\n    pub fn assoc() {}\n    \
                 pub fn work(&self) {}\n}\n",
            ),
        ]);
        let entry = idx(&g, "a.rs", "entry");
        let callees: Vec<usize> = g.edges_of(entry).iter().map(|e| e.callee).collect();
        assert!(callees.contains(&idx(&g, "a.rs", "helper")));
        assert!(callees.contains(&idx(&g, "b.rs", "assoc")));
        assert!(callees.contains(&idx(&g, "b.rs", "work")));
    }

    #[test]
    fn method_stop_list_blocks_std_colliding_names() {
        let g = graph(&[
            ("src/a.rs", "pub fn entry(v: &mut Vec<u32>, c: &mut C) { v.push(1); c.step(); }\n"),
            (
                "src/b.rs",
                "pub struct C;\npub struct K;\nimpl C {\n    pub fn push(&mut self) {}\n    \
                 pub fn step(&mut self) {}\n}\nimpl K {\n    pub fn step(&mut self) {}\n}\n",
            ),
        ]);
        let entry = idx(&g, "a.rs", "entry");
        let callees: Vec<usize> = g.edges_of(entry).iter().map(|e| e.callee).collect();
        // `.push(` never resolves (std-colliding); `.step(` resolves to
        // every in-crate method of that name.
        assert!(!callees.contains(&idx(&g, "b.rs", "push")));
        let steps = g.find("b.rs", "step");
        assert_eq!(steps.len(), 2);
        for s in steps {
            assert!(callees.contains(&s), "conservative fan-out to all `step` methods");
        }
    }

    #[test]
    fn method_resolution_requires_a_self_param() {
        // `Config::load` takes no self — a `.load(…)` method call (an
        // atomic, in practice) must not resolve to it even off the
        // stop list (`load` is on it; use a distinctive name here).
        let g = graph(&[
            ("src/a.rs", "pub fn entry(x: &X) { x.ingest(); }\n"),
            ("src/b.rs", "pub struct B;\nimpl B {\n    pub fn ingest(path: &str) {}\n}\n"),
        ]);
        let entry = idx(&g, "a.rs", "entry");
        assert!(g.edges_of(entry).is_empty());
    }

    #[test]
    fn catch_unwind_marks_contained_edges() {
        let g = graph(&[(
            "src/a.rs",
            "fn outer() {\n    let r = catch_unwind(AssertUnwindSafe(|| inner()));\n    \
             after();\n}\nfn inner() {}\nfn after() {}\n",
        )]);
        let outer = idx(&g, "a.rs", "outer");
        let inner = idx(&g, "a.rs", "inner");
        let after = idx(&g, "a.rs", "after");
        let contained = g.reach(&[outer], true);
        assert!(!contained.seen[inner], "contained edge skipped");
        assert!(contained.seen[after]);
        let full = g.reach(&[outer], false);
        assert!(full.seen[inner], "hot reachability keeps contained edges");
    }

    #[test]
    fn test_mod_fns_are_excluded_from_resolution() {
        let g = graph(&[(
            "src/a.rs",
            "fn entry() { helper(); }\n\
             mod tests {\n    fn helper() { panic!(\"test-only\"); }\n}\n\
             fn helper() {}\n",
        )]);
        let entry = idx(&g, "a.rs", "entry");
        // idx() asserts exactly one non-test `helper` matched; the edge
        // goes to it.
        assert_eq!(g.edges_of(entry).len(), 1);
    }

    #[test]
    fn reach_chain_is_shortest_and_renders_hops() {
        let g = graph(&[(
            "src/a.rs",
            "fn root() {\n    mid();\n    leaf();\n}\n\
             fn mid() {\n    leaf();\n}\nfn leaf() {}\n",
        )]);
        let root = idx(&g, "a.rs", "root");
        let leaf = idx(&g, "a.rs", "leaf");
        let r = g.reach(&[root], false);
        let chain = g.chain(&r, leaf);
        // BFS finds the direct root→leaf edge, not the root→mid→leaf one.
        assert_eq!(chain.len(), 2, "{chain:?}");
        assert!(chain[0].contains("::root (root @1)"), "{chain:?}");
        assert!(chain[1].contains("::leaf (called at src/a.rs:3)"), "{chain:?}");
    }

    #[test]
    fn nested_fn_bodies_are_not_scanned_as_the_parent() {
        let g = graph(&[(
            "src/a.rs",
            "fn outer() {\n    fn inner() {\n        target();\n    }\n    other();\n}\n\
             fn target() {}\nfn other() {}\n",
        )]);
        let outer = idx(&g, "a.rs", "outer");
        let callees: Vec<usize> = g.edges_of(outer).iter().map(|e| e.callee).collect();
        assert!(callees.contains(&idx(&g, "a.rs", "other")));
        assert!(!callees.contains(&idx(&g, "a.rs", "target")));
        let inner = idx(&g, "a.rs", "inner");
        assert!(g.edges_of(inner).iter().any(|e| e.callee == idx(&g, "a.rs", "target")));
    }
}
