//! Self-contained static analysis for the crate's own sources.
//!
//! The `sfm_lint` binary (and the `tests/lint.rs` self-check) drive
//! this module: [`lexer`] turns Rust source into a line-annotated token
//! stream, [`callgraph`] builds a whole-crate call graph over it
//! (fn items, impl self-type attribution, conservatively resolved
//! call sites, reachability with shortest-chain parents), and [`rules`]
//! runs the project-specific invariant checks — the hot-path and
//! no-panic rules are *transitive* over the graph, so only root sets
//! are configured and everything they reach is derived. No external
//! dependencies — the same hand-rolled discipline as
//! `coordinator::json`.

pub mod callgraph;
pub mod lexer;
pub mod rules;

pub use rules::{
    collect_sources, hot_reach, lint_crate, lint_source, lint_tree, Config, Diagnostic, RULES,
};
