//! Self-contained static analysis for the crate's own sources.
//!
//! The `sfm_lint` binary (and the `tests/lint.rs` self-check) drive
//! this module: [`lexer`] turns Rust source into a line-annotated token
//! stream, [`rules`] runs the project-specific invariant checks over
//! it. No external dependencies — the same hand-rolled discipline as
//! `coordinator::json`.

pub mod lexer;
pub mod rules;

pub use rules::{lint_source, lint_tree, Config, Diagnostic, RULES};
