//! Rule engine for the `sfm_lint` static-analysis pass.
//!
//! Consumes the token stream from [`super::lexer`] and the whole-crate
//! call graph from [`super::callgraph`] and checks the project-specific
//! invariants that the runtime test suite cannot see statically. Every
//! rule carries a stable code (`SFM001`…) so findings can be tracked
//! across renames:
//!
//! * **SFM001 safety-comment** — every `unsafe` keyword (block, fn,
//!   impl) is immediately preceded by a `// SAFETY:` comment or a
//!   `# Safety` doc section (attribute lines between comment and item
//!   are skipped).
//! * **SFM002 lock-poison** — every `.lock()` in `src/runtime/`,
//!   `src/coordinator/`, `src/screening/`, `src/decompose/`, and
//!   `src/obs/` adopts poison via `.unwrap_or_else(…into_inner…)`: a
//!   sibling worker panic must surface as the original panic, never as
//!   a masking `PoisonError` unwrap.
//! * **SFM003 hot-path-alloc** — *transitive*: no allocation-capable,
//!   wall-clock, RNG, or observability calls in any function reachable
//!   from the hot **root set** (the documented zero-alloc kernels).
//!   PR 7's per-body allowlist is gone: helpers a kernel calls are hot
//!   because the graph says so, and each finding carries the shortest
//!   call chain that makes its function hot.
//! * **SFM004 no-panic-paths** — *transitive*: no bare `unwrap()` /
//!   `expect()` or panicking macro in any function reachable from the
//!   serve job roots, where reachability stops at `catch_unwind(…)`
//!   call sites (the panic cannot escape). Panicking *index*
//!   expressions are a direct-body check on the roots and on the
//!   configured panic-contained functions only — interior parsers
//!   index with proven bounds and return typed errors for the rest.
//! * **SFM005 waiver-syntax** — waiver comments are well-formed and
//!   name known rules.
//! * **SFM006 boundary-coupling** — cancellation polls (`.check()`),
//!   trace emission (`.record(…)`), and checkpoint stores
//!   (`sink.store(…)`) appear only in the designated boundary
//!   functions (engine `run`/`resume_from`, block-solver round sites),
//!   and no function consulting them is reachable from the hot root
//!   set. Tracing is boundary-sampled by design (OBSERVABILITY.md);
//!   this rule is the static proof that the discipline holds.
//! * **SFM007 stale-waiver** — a waiver that suppresses zero findings
//!   must be deleted, so the waiver inventory never outlives the code
//!   it excused.
//!
//! The graph rules analyze the **production build**: tokens under
//! `#[cfg(test)]` or a diagnostic feature are stripped first (see
//! [`super::callgraph::CFG_OFF_FEATURES`]). The per-file rules
//! (SFM001/SFM002/SFM005) stay cfg-blind — stricter, and currently
//! clean.
//!
//! A finding can be waived at its site with a comment of the form
//! `lint: allow(<rule>[, <rule>]) — <reason>` (after `//`); the reason
//! is mandatory. The waiver covers its own line and the first code line
//! below its comment block.

use super::callgraph::{CallGraph, Reach};
use super::lexer::{lex, Token, TokenKind};
use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

/// `(code, name, summary)` for every rule the engine knows. Codes are
/// stable across renames; names are what waivers cite.
pub const RULES: &[(&str, &str, &str)] = &[
    (
        "SFM001",
        "safety-comment",
        "every `unsafe` block/fn/impl is immediately preceded by a SAFETY comment",
    ),
    (
        "SFM002",
        "lock-poison",
        "`.lock()` in runtime/coordinator/screening/decompose/obs adopts poison via unwrap_or_else(..into_inner..)",
    ),
    (
        "SFM003",
        "hot-path-alloc",
        "no allocation, wall-clock, RNG, or observability calls reachable from the hot root set",
    ),
    (
        "SFM004",
        "no-panic-paths",
        "no bare unwrap/expect or panicking macro reachable from the serve roots (catch_unwind contains); no panicking index in root bodies",
    ),
    (
        "SFM005",
        "waiver-syntax",
        "waiver comments are well-formed and name known rules",
    ),
    (
        "SFM006",
        "boundary-coupling",
        "cancel polls, trace records, and checkpoint stores appear only in designated boundary fns, unreachable from hot roots",
    ),
    (
        "SFM007",
        "stale-waiver",
        "a waiver that suppresses zero findings must be removed",
    ),
];

fn known_rule(name: &str) -> Option<&'static str> {
    RULES.iter().map(|&(_, n, _)| n).find(|&n| n == name)
}

fn code_of(rule: &str) -> &'static str {
    RULES
        .iter()
        .find(|&&(_, n, _)| n == rule)
        .map(|&(c, _, _)| c)
        .unwrap_or("SFM000")
}

/// One lint finding, printed as `file:line: [code rule] message`, with
/// the offending call chain (when the finding is transitive) on
/// indented follow-up lines.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub code: &'static str,
    pub msg: String,
    /// Root-first call chain for transitive findings; empty for
    /// per-file findings.
    pub chain: Vec<String>,
}

impl Diagnostic {
    fn new(file: &str, line: u32, rule: &'static str, msg: String) -> Diagnostic {
        Diagnostic {
            file: file.to_string(),
            line,
            rule,
            code: code_of(rule),
            msg,
            chain: Vec::new(),
        }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{} {}] {}", self.file, self.line, self.code, self.rule, self.msg)?;
        for (i, hop) in self.chain.iter().enumerate() {
            let head = if i == 0 { "chain:" } else { "   ->" };
            write!(f, "\n      {head} {hop}")?;
        }
        Ok(())
    }
}

/// Where each scoped rule applies. `lock_paths` and the root-set
/// patterns match by substring against the `/`-normalized file label;
/// boundary designations and definition files match by path suffix.
#[derive(Debug, Clone, Default)]
pub struct Config {
    /// `(path substring, fn name)` — the **hot root set**: functions
    /// whose entire call closure is subject to SFM003.
    pub hot_roots: Vec<(String, String)>,
    /// Path substrings subject to SFM002.
    pub lock_paths: Vec<String>,
    /// `(path substring, fn name)` — the **no-panic root set** for
    /// SFM004 (transitive, `catch_unwind` edges excluded).
    pub no_panic_roots: Vec<(String, String)>,
    /// `(path substring, fn name)` — functions whose *callers* wrap
    /// them in `catch_unwind`: their own body gets the full direct
    /// SFM004 check (a panic there is an outcome, not a crash, but
    /// must still be deliberate), and nothing propagates through them.
    pub contained_fns: Vec<(String, String)>,
    /// `(path suffix, fn name)` — the designated boundary functions
    /// for SFM006.
    pub boundary_fns: Vec<(String, String)>,
    /// Path suffixes of the files *defining* the boundary machinery
    /// (cancel tokens, trace sinks, checkpoint sinks) — exempt from
    /// SFM006.
    pub boundary_def_files: Vec<String>,
}

impl Config {
    /// The root sets for this repository. These replace PR 7's manual
    /// per-body allowlists: only the *entry points* are named, and the
    /// call graph derives the rest (`tests/lint.rs` pins that the
    /// derived hot set is a superset of the retired allowlist).
    /// `argsort_desc` and `CholeskyFactor::solve` are deliberately
    /// absent — they are the documented allocating conveniences; the
    /// `_into` variants are the hot ones.
    pub fn default_for_repo() -> Config {
        let hot: &[(&str, &[&str])] = &[
            (
                "src/linalg/vecops.rs",
                &[
                    "dot",
                    "dot4",
                    "dot_gather4",
                    "norm2_sq",
                    "axpy",
                    "axpy4",
                    "add_assign4",
                    "sweep4",
                    "cover_gain4",
                    "relu_mac_col4",
                    "max_update_col4",
                    "argsort_desc_adaptive",
                    "argsort_desc_remap",
                    "project_indices",
                ],
            ),
            ("src/linalg/cholesky.rs", &["push", "remove", "retain", "solve_into"]),
            ("src/decompose/chain.rs", &["tv_prox_into"]),
            ("src/solvers/pav.rs", &["run"]),
            ("src/lovasz.rs", &["accumulate_pass"]),
            // Both the kernelized and the graph-cut oracle keep their
            // scratch prefix-gain pass hot; the directory pattern
            // covers both files.
            ("src/submodular/", &["prefix_gains_scratch"]),
        ];
        let mut hot_roots = Vec::new();
        for &(file, fns) in hot {
            for &f in fns {
                hot_roots.push((file.to_string(), f.to_string()));
            }
        }
        let no_panic = [
            "worker_loop",
            "serve_one",
            "submit_line_with",
            "handle_op",
            "split_envelope",
            "envelope",
            "reject",
            "write_line",
            "make_pool",
            "retry_backoff",
        ];
        Config {
            hot_roots,
            lock_paths: [
                "src/runtime/",
                "src/coordinator/",
                "src/screening/",
                "src/decompose/",
                "src/obs/",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect(),
            no_panic_roots: no_panic
                .iter()
                .map(|f| ("src/coordinator/serve.rs".to_string(), f.to_string()))
                .collect(),
            // `serve_one` wraps `run_job` in `catch_unwind`: a panic in
            // the job body is a contained outcome, so it is checked
            // directly but does not propagate to its callees.
            contained_fns: vec![("src/coordinator/serve.rs".to_string(), "run_job".to_string())],
            boundary_fns: [
                ("src/screening/iaes.rs", "run"),
                ("src/screening/iaes.rs", "resume_from"),
                ("src/decompose/solver.rs", "step"),
                ("src/decompose/solver.rs", "close_gap"),
            ]
            .iter()
            .map(|&(p, n)| (p.to_string(), n.to_string()))
            .collect(),
            boundary_def_files: [
                "src/runtime/cancel.rs",
                "src/obs/trace.rs",
                "src/obs/metrics.rs",
                "src/screening/checkpoint.rs",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        }
    }
}

// ---------------------------------------------------------------------
// Per-line source classification
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Default)]
struct LineInfo {
    /// A non-comment token covers this line.
    has_code: bool,
    /// A comment token covers this line.
    has_comment: bool,
    /// The first non-comment token starting on this line is `#`
    /// (attribute line).
    starts_attr: bool,
    /// Comment texts starting on this line.
    comments: Vec<String>,
}

/// 1-indexed line table (`lines[0]` unused).
fn classify_lines(tokens: &[Token]) -> Vec<LineInfo> {
    let max = tokens.iter().map(|t| t.end_line).max().unwrap_or(0) as usize;
    let mut lines: Vec<LineInfo> = vec![LineInfo::default(); max + 1];
    for t in tokens {
        let span = t.line as usize..=t.end_line as usize;
        if t.is_comment() {
            for l in span {
                lines[l].has_comment = true;
            }
            lines[t.line as usize].comments.push(t.text.clone());
        } else {
            for l in span {
                lines[l].has_code = true;
            }
        }
    }
    // Second pass: mark attribute lines (first code token on the line is
    // `#`). Token order is source order, so the first non-comment token
    // whose start line is `l` decides.
    let mut seen = vec![false; max + 1];
    for t in tokens {
        if t.is_comment() {
            continue;
        }
        let l = t.line as usize;
        if !seen[l] {
            seen[l] = true;
            lines[l].starts_attr = t.is_punct('#');
        }
    }
    lines
}

impl LineInfo {
    fn comment_only(&self) -> bool {
        self.has_comment && !self.has_code
    }
    fn attr_only(&self) -> bool {
        self.has_code && self.starts_attr
    }
}

/// Does the comment context of code line `line` satisfy `pred`? Checks
/// trailing comments on the line itself, then walks upward through the
/// contiguous block of comment-only lines, skipping attribute lines
/// (`#[inline]` between a SAFETY comment and its fn is fine). Stops at
/// the first blank or code line.
fn context_has(lines: &[LineInfo], line: usize, pred: impl Fn(&str) -> bool) -> bool {
    if lines.get(line).is_some_and(|l| l.comments.iter().any(|c| pred(c))) {
        return true;
    }
    let mut l = line.saturating_sub(1);
    while l >= 1 {
        let info = &lines[l];
        if info.attr_only() {
            l -= 1;
            continue;
        }
        if info.comment_only() {
            if info.comments.iter().any(|c| pred(c)) {
                return true;
            }
            l -= 1;
            continue;
        }
        break;
    }
    false
}

/// The code line a comment block at `line` annotates: the first
/// non-blank, non-comment, non-attribute line at or below it.
fn annotated_code_line(lines: &[LineInfo], line: usize) -> Option<usize> {
    let mut l = line;
    while l < lines.len() {
        let info = &lines[l];
        if info.has_code && !info.starts_attr {
            return Some(l);
        }
        if !info.has_code && !info.has_comment && l != line {
            return None; // blank line ends the block
        }
        l += 1;
    }
    None
}

// ---------------------------------------------------------------------
// Waivers
// ---------------------------------------------------------------------

#[derive(Debug)]
struct Waiver {
    rules: Vec<&'static str>,
    /// Line of the waiver comment itself (for stale-waiver reporting).
    line: usize,
    /// Lines this waiver covers (its own line + the annotated code line).
    covers: Vec<usize>,
}

fn strip_comment_markers(text: &str) -> &str {
    let t = text.trim_start();
    let t = t
        .strip_prefix("//!")
        .or_else(|| t.strip_prefix("///"))
        .or_else(|| t.strip_prefix("//"))
        .unwrap_or(t);
    let t = match t.trim_start().strip_prefix("/*") {
        Some(inner) => inner.strip_suffix("*/").unwrap_or(inner),
        None => t,
    };
    t.trim()
}

/// Parse `lint: allow(rule[, rule]) — reason` from a stripped comment
/// body known to start with `lint:`. Returns the named rules or an
/// error message for the waiver-syntax diagnostic.
fn parse_waiver(body: &str) -> Result<Vec<&'static str>, String> {
    let rest = body.strip_prefix("lint:").expect("caller checked").trim_start();
    let rest = rest
        .strip_prefix("allow")
        .ok_or_else(|| "expected `allow(<rule>)` after `lint:`".to_string())?
        .trim_start();
    let rest = rest
        .strip_prefix('(')
        .ok_or_else(|| "expected `(` after `allow`".to_string())?;
    let close = rest
        .find(')')
        .ok_or_else(|| "unclosed `(` in waiver".to_string())?;
    let mut rules = Vec::new();
    for name in rest[..close].split(',') {
        let name = name.trim();
        if name.is_empty() {
            return Err("empty rule name in waiver".to_string());
        }
        match known_rule(name) {
            Some(r) => rules.push(r),
            None => return Err(format!("unknown rule `{name}` in waiver")),
        }
    }
    let tail = rest[close + 1..].trim_start();
    let reason = tail
        .strip_prefix('\u{2014}') // em dash
        .or_else(|| tail.strip_prefix('-'))
        .or_else(|| tail.strip_prefix(':'))
        .ok_or_else(|| "expected `— <reason>` after the rule list".to_string())?;
    if reason.trim().is_empty() {
        return Err("waiver reason must not be empty".to_string());
    }
    Ok(rules)
}

fn collect_waivers(
    file: &str,
    lines: &[LineInfo],
    diags: &mut Vec<Diagnostic>,
) -> Vec<Waiver> {
    let mut waivers = Vec::new();
    for (lno, info) in lines.iter().enumerate().skip(1) {
        for c in &info.comments {
            let body = strip_comment_markers(c);
            if !body.starts_with("lint:") {
                continue;
            }
            match parse_waiver(body) {
                Ok(rules) => {
                    let mut covers = vec![lno];
                    if let Some(code) = annotated_code_line(lines, lno) {
                        covers.push(code);
                    }
                    waivers.push(Waiver { rules, line: lno, covers });
                }
                Err(msg) => {
                    diags.push(Diagnostic::new(file, lno as u32, "waiver-syntax", msg));
                }
            }
        }
    }
    waivers
}

// ---------------------------------------------------------------------
// Per-file rule passes (over the comment-free, cfg-blind code view)
// ---------------------------------------------------------------------

/// Rust keywords that can legally precede `[` without forming an index
/// expression (`for x in [..]`, `return [..]`, …).
const NON_INDEX_KEYWORDS: &[&str] = &[
    "as", "async", "await", "box", "break", "const", "continue", "dyn", "else", "enum",
    "fn", "if", "impl", "in", "let", "loop", "match", "mod", "move", "mut", "pub", "ref",
    "return", "static", "struct", "trait", "type", "unsafe", "use", "where", "while",
    "yield",
];

fn rule_safety_comment(
    file: &str,
    code: &[Token],
    lines: &[LineInfo],
    diags: &mut Vec<Diagnostic>,
) {
    for t in code {
        if t.is_ident("unsafe") {
            let has = context_has(lines, t.line as usize, |c| {
                c.contains("SAFETY") || c.contains("# Safety")
            });
            if !has {
                diags.push(Diagnostic::new(
                    file,
                    t.line,
                    "safety-comment",
                    "`unsafe` without an immediately preceding `// SAFETY:` comment".to_string(),
                ));
            }
        }
    }
}

fn rule_lock_poison(file: &str, code: &[Token], diags: &mut Vec<Diagnostic>) {
    for i in 0..code.len() {
        // `.lock()` …
        if !(code[i].is_punct('.')
            && code.get(i + 1).is_some_and(|t| t.is_ident("lock"))
            && code.get(i + 2).is_some_and(|t| t.is_punct('('))
            && code.get(i + 3).is_some_and(|t| t.is_punct(')')))
        {
            continue;
        }
        // … must continue `.unwrap_or_else(` with `into_inner` nearby.
        let ok = code.get(i + 4).is_some_and(|t| t.is_punct('.'))
            && code.get(i + 5).is_some_and(|t| t.is_ident("unwrap_or_else"))
            && code.get(i + 6).is_some_and(|t| t.is_punct('('))
            && code[i + 7..code.len().min(i + 24)]
                .iter()
                .any(|t| t.is_ident("into_inner"));
        if !ok {
            diags.push(Diagnostic::new(
                file,
                code[i + 1].line,
                "lock-poison",
                "`.lock()` must adopt poison via `.unwrap_or_else(..into_inner..)` \
                 so sibling-panic shutdown re-raises the original panic"
                    .to_string(),
            ));
        }
    }
}

// ---------------------------------------------------------------------
// Token-level violation predicates (shared by the graph passes)
// ---------------------------------------------------------------------

/// Forbidden calls for **hot-path-alloc**. `.clone()` and
/// `push`/`extend`/`resize` are deliberately not listed: amortized
/// reuse of pre-sized buffers is the crate's sanctioned zero-alloc
/// pattern, stack clones (`Range`, `Arc` refcounts) are free, and a
/// token-level pass cannot see types — the counting allocator covers
/// the dynamic side.
const HOT_MACROS: &[&str] = &["vec", "format", "println", "eprintln", "print", "eprint"];
const HOT_METHODS: &[&str] = &["to_vec", "to_string", "to_owned", "collect"];
/// Observability entry points (`TraceSink::record`,
/// `Histogram::observe`) — banned in hot bodies outright: tracing is
/// boundary-sampled by design, so a hot kernel touching the sink means
/// the sampling discipline leaked into an inner loop (OBSERVABILITY.md).
const OBS_METHODS: &[&str] = &["record", "observe", "add_pool_dispatches"];
const HOT_TYPES: &[&str] = &[
    "Vec", "String", "Box", "Rc", "Arc", "VecDeque", "HashMap", "HashSet", "BTreeMap",
    "Instant", "SystemTime", "Pcg64", "TraceSink", "MetricsRegistry", "CheckpointSink",
];

fn hot_path_violation(code: &[Token], k: usize) -> Option<String> {
    let t = &code[k];
    if t.kind != TokenKind::Ident {
        return None;
    }
    let name = t.ident_name();
    if HOT_MACROS.contains(&name) && code.get(k + 1).is_some_and(|n| n.is_punct('!')) {
        return Some(format!("`{name}!` allocates"));
    }
    if HOT_METHODS.contains(&name)
        && k > 0
        && code[k - 1].is_punct('.')
        && code.get(k + 1).is_some_and(|n| n.is_punct('('))
    {
        return Some(format!("`.{name}()` allocates"));
    }
    if OBS_METHODS.contains(&name)
        && k > 0
        && code[k - 1].is_punct('.')
        && code.get(k + 1).is_some_and(|n| n.is_punct('('))
    {
        return Some(format!(
            "`.{name}()` is an observability call — tracing is boundary-sampled, \
             never from a hot kernel"
        ));
    }
    if HOT_TYPES.contains(&name)
        && code.get(k + 1).is_some_and(|n| n.is_punct(':'))
        && code.get(k + 2).is_some_and(|n| n.is_punct(':'))
    {
        if let Some(m) = code.get(k + 3).filter(|m| m.kind == TokenKind::Ident) {
            let assoc = m.ident_name();
            let bad = match name {
                "Instant" | "SystemTime" => assoc == "now",
                "Pcg64" => true, // any RNG construction/use is nondeterministic state
                // Observability/checkpoint handles must never be
                // constructed or touched inside a hot kernel — any
                // associated call (snapshots are boundary-sampled,
                // RELIABILITY.md).
                "TraceSink" | "MetricsRegistry" | "CheckpointSink" => true,
                _ => matches!(assoc, "new" | "with_capacity" | "from"),
            };
            if bad {
                return Some(format!("`{name}::{assoc}` is not allowed on the hot path"));
            }
        }
    }
    None
}

const PANIC_MACROS: &[&str] = &[
    "panic", "unreachable", "todo", "unimplemented", "assert", "assert_eq", "assert_ne",
];

/// Unwrap/expect and panicking macros — the *transitive* half of
/// SFM004.
fn panic_call_violation(code: &[Token], k: usize) -> Option<String> {
    let t = &code[k];
    if t.kind != TokenKind::Ident {
        return None;
    }
    let name = t.ident_name();
    if (name == "unwrap" || name == "expect")
        && k > 0
        && code[k - 1].is_punct('.')
        && code.get(k + 1).is_some_and(|n| n.is_punct('('))
    {
        return Some(format!("bare `.{name}()` can panic"));
    }
    if PANIC_MACROS.contains(&name) && code.get(k + 1).is_some_and(|n| n.is_punct('!')) {
        return Some(format!("`{name}!` panics"));
    }
    None
}

/// Panicking index expressions — the *direct-body* half of SFM004,
/// applied only to root and contained bodies (interior parsers index
/// with proven bounds).
fn panic_index_violation(code: &[Token], k: usize) -> Option<String> {
    if !code[k].is_punct('[') || k == 0 {
        return None;
    }
    let prev = &code[k - 1];
    let indexes = match &prev.kind {
        TokenKind::Ident => !NON_INDEX_KEYWORDS.contains(&prev.ident_name()),
        TokenKind::Punct(')') | TokenKind::Punct(']') => true,
        _ => false,
    };
    if indexes {
        Some("panicking index expression (use `get`/typed errors)".to_string())
    } else {
        None
    }
}

/// Boundary tokens for SFM006: cancellation polls, trace emission,
/// checkpoint stores. `sink.store(…)` is matched through its receiver
/// name so plain atomic `.store(…)` calls stay out of scope.
fn boundary_token_violation(code: &[Token], k: usize) -> Option<String> {
    let t = &code[k];
    if t.kind != TokenKind::Ident {
        return None;
    }
    let name = t.ident_name();
    if k > 0 && code[k - 1].is_punct('.') && code.get(k + 1).is_some_and(|n| n.is_punct('(')) {
        if name == "check" && code.get(k + 2).is_some_and(|n| n.is_punct(')')) {
            return Some("cancellation poll `.check()`".to_string());
        }
        if name == "record" {
            return Some("trace emission `.record(…)`".to_string());
        }
    }
    if name == "sink"
        && code.get(k + 1).is_some_and(|n| n.is_punct('.'))
        && code.get(k + 2).is_some_and(|n| n.is_ident("store"))
        && code.get(k + 3).is_some_and(|n| n.is_punct('('))
    {
        return Some("checkpoint store `sink.store(…)`".to_string());
    }
    None
}

// ---------------------------------------------------------------------
// Graph passes
// ---------------------------------------------------------------------

fn match_roots(graph: &CallGraph, specs: &[(String, String)]) -> Vec<usize> {
    let mut out = Vec::new();
    for (pat, name) in specs {
        for idx in graph.find(pat, name) {
            if !out.contains(&idx) {
                out.push(idx);
            }
        }
    }
    out
}

/// Hot-closure reachability (all edges; `catch_unwind` contains panics,
/// not allocations). Shared by SFM003, SFM006, and `sfm_lint --explain`.
pub fn hot_reach(graph: &CallGraph, cfg: &Config) -> Reach {
    graph.reach(&match_roots(graph, &cfg.hot_roots), false)
}

/// Run `check` over every body token of fn `idx` (nested fn items
/// skipped — they are scanned as their own items).
fn body_violations(
    graph: &CallGraph,
    idx: usize,
    check: fn(&[Token], usize) -> Option<String>,
) -> Vec<(u32, String)> {
    let item = &graph.fns[idx];
    let code = graph.file_code(&item.file);
    let (lo, hi) = item.body;
    let mut out = Vec::new();
    let mut k = lo + 1;
    while k < hi {
        if item.nested.iter().any(|&(a, b)| a <= k && k <= b) {
            k += 1;
            continue;
        }
        if let Some(what) = check(code, k) {
            out.push((code[k].line, what));
        }
        k += 1;
    }
    out
}

fn rule_hot_transitive(graph: &CallGraph, hot: &Reach, diags: &mut Vec<Diagnostic>) {
    for &idx in &hot.order {
        let item = &graph.fns[idx];
        if item.is_test {
            continue;
        }
        for (line, what) in body_violations(graph, idx, hot_path_violation) {
            let mut d = Diagnostic::new(
                &item.file,
                line,
                "hot-path-alloc",
                format!("{what} (in `{}`, reachable from the hot root set)", item.name),
            );
            d.chain = graph.chain(hot, idx);
            diags.push(d);
        }
    }
}

fn rule_no_panic_transitive(graph: &CallGraph, cfg: &Config, diags: &mut Vec<Diagnostic>) {
    let roots = match_roots(graph, &cfg.no_panic_roots);
    let reach = graph.reach(&roots, true);
    for &idx in &reach.order {
        let item = &graph.fns[idx];
        if item.is_test {
            continue;
        }
        for (line, what) in body_violations(graph, idx, panic_call_violation) {
            let mut d = Diagnostic::new(
                &item.file,
                line,
                "no-panic-paths",
                format!("{what} (in `{}`, on a no-panic path)", item.name),
            );
            d.chain = graph.chain(&reach, idx);
            diags.push(d);
        }
    }
    // The index ban is a direct-body check on the roots themselves.
    for &idx in &roots {
        let item = &graph.fns[idx];
        for (line, what) in body_violations(graph, idx, panic_index_violation) {
            let mut d = Diagnostic::new(
                &item.file,
                line,
                "no-panic-paths",
                format!("{what} (in job root `{}`)", item.name),
            );
            d.chain = graph.chain(&reach, idx);
            diags.push(d);
        }
    }
    // Contained fns: a panic there is caught by the caller's
    // `catch_unwind`, but the body must still be deliberate — full
    // direct check, no propagation through its callees.
    for &idx in &match_roots(graph, &cfg.contained_fns) {
        let item = &graph.fns[idx];
        let mut found = body_violations(graph, idx, panic_call_violation);
        found.extend(body_violations(graph, idx, panic_index_violation));
        for (line, what) in found {
            let mut d = Diagnostic::new(
                &item.file,
                line,
                "no-panic-paths",
                format!("{what} (in panic-contained fn `{}`)", item.name),
            );
            d.chain =
                vec![format!("{}::{} (panic-contained @{})", item.file, item.name, item.line)];
            diags.push(d);
        }
    }
}

fn rule_boundary(graph: &CallGraph, cfg: &Config, hot: &Reach, diags: &mut Vec<Diagnostic>) {
    for (idx, item) in graph.fns.iter().enumerate() {
        if item.is_test || cfg.boundary_def_files.iter().any(|d| item.file.ends_with(d)) {
            continue;
        }
        let toks = body_violations(graph, idx, boundary_token_violation);
        if toks.is_empty() {
            continue;
        }
        let designated = cfg
            .boundary_fns
            .iter()
            .any(|(p, n)| item.file.ends_with(p.as_str()) && item.name == *n);
        if !designated {
            for (line, what) in &toks {
                diags.push(Diagnostic::new(
                    &item.file,
                    *line,
                    "boundary-coupling",
                    format!("{what} outside a designated boundary fn (in `{}`)", item.name),
                ));
            }
        }
        if hot.seen[idx] {
            let mut d = Diagnostic::new(
                &item.file,
                item.line,
                "boundary-coupling",
                format!(
                    "`{}` consults boundary tokens and is reachable from the hot root set",
                    item.name
                ),
            );
            d.chain = graph.chain(hot, idx);
            diags.push(d);
        }
    }
}

// ---------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------

/// Lint a whole crate given `label → source` pairs: per-file rules on
/// each file, graph rules on the crate-wide call graph, then waiver
/// application and stale-waiver detection.
pub fn lint_crate(files: &BTreeMap<String, String>, cfg: &Config) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let mut waivers: Vec<(String, Waiver)> = Vec::new();
    for (label, src) in files {
        let tokens = lex(src);
        let lines = classify_lines(&tokens);
        let code: Vec<Token> = tokens.iter().filter(|t| !t.is_comment()).cloned().collect();
        for w in collect_waivers(label, &lines, &mut diags) {
            waivers.push((label.clone(), w));
        }
        rule_safety_comment(label, &code, &lines, &mut diags);
        if cfg.lock_paths.iter().any(|p| label.contains(p.as_str())) {
            rule_lock_poison(label, &code, &mut diags);
        }
    }

    let graph = CallGraph::build(files);
    let hot = hot_reach(&graph, cfg);
    rule_hot_transitive(&graph, &hot, &mut diags);
    rule_no_panic_transitive(&graph, cfg, &mut diags);
    rule_boundary(&graph, cfg, &hot, &mut diags);

    let mut used = vec![false; waivers.len()];
    diags.retain(|d| {
        if d.rule == "waiver-syntax" {
            return true;
        }
        let mut waived = false;
        for (wi, (wfile, w)) in waivers.iter().enumerate() {
            if wfile == &d.file
                && w.rules.contains(&d.rule)
                && w.covers.contains(&(d.line as usize))
            {
                used[wi] = true;
                waived = true;
            }
        }
        !waived
    });
    for (wi, (wfile, w)) in waivers.iter().enumerate() {
        if !used[wi] {
            diags.push(Diagnostic::new(
                wfile,
                w.line as u32,
                "stale-waiver",
                format!(
                    "waiver for [{}] suppresses no findings — remove it",
                    w.rules.join(", ")
                ),
            ));
        }
    }

    diags.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    diags.dedup_by(|a, b| {
        a.file == b.file && a.line == b.line && a.rule == b.rule && a.msg == b.msg
    });
    diags
}

/// Lint one source file (a single-file crate as far as the graph rules
/// are concerned). `file_label` is used for both path-scoped rule
/// matching (normalized to `/` separators) and diagnostics.
pub fn lint_source(file_label: &str, src: &str, cfg: &Config) -> Vec<Diagnostic> {
    let file = file_label.replace('\\', "/");
    let mut files = BTreeMap::new();
    files.insert(file, src.to_string());
    lint_crate(&files, cfg)
}

/// Read every `*.rs` file under each root into a `label → source` map
/// (labels `/`-normalized; `target`, `vendor`, and VCS dirs skipped).
pub fn collect_sources(roots: &[PathBuf]) -> std::io::Result<BTreeMap<String, String>> {
    let mut files = Vec::new();
    for root in roots {
        collect_rs_files(root, &mut files)?;
    }
    let mut map = BTreeMap::new();
    for f in files {
        let src = std::fs::read_to_string(&f)?;
        map.insert(f.to_string_lossy().replace('\\', "/"), src);
    }
    Ok(map)
}

/// Recursively lint every `*.rs` file under `root` as one crate.
/// Diagnostics come back sorted by `(file, line, rule)`.
pub fn lint_tree(root: &Path, cfg: &Config) -> std::io::Result<(usize, Vec<Diagnostic>)> {
    let files = collect_sources(std::slice::from_ref(&root.to_path_buf()))?;
    let diags = lint_crate(&files, cfg);
    Ok((files.len(), diags))
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if name == "target" || name == "vendor" || name.starts_with('.') {
                continue;
            }
            collect_rs_files(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_hot(file: &str, f: &str) -> Config {
        Config { hot_roots: vec![(file.to_string(), f.to_string())], ..Config::default() }
    }

    fn rules_of(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.rule).collect()
    }

    #[test]
    fn unsafe_without_safety_flagged_with_line() {
        let src = "fn f() {\n    let x = unsafe { g() };\n}\n";
        let d = lint_source("src/a.rs", src, &Config::default());
        assert_eq!(rules_of(&d), vec!["safety-comment"]);
        assert_eq!(d[0].line, 2);
        assert_eq!(d[0].code, "SFM001");
    }

    #[test]
    fn safety_comment_above_or_trailing_accepted() {
        let above = "fn f() {\n    // SAFETY: g is fine here.\n    let x = unsafe { g() };\n}\n";
        assert!(lint_source("src/a.rs", above, &Config::default()).is_empty());
        let trailing = "fn f() {\n    let x = unsafe { g() }; // SAFETY: fine\n}\n";
        assert!(lint_source("src/a.rs", trailing, &Config::default()).is_empty());
    }

    #[test]
    fn safety_walk_skips_attributes_and_doc_sections_count() {
        let src = "/// Does things.\n///\n/// # Safety\n///\n/// Caller checks bounds.\n#[inline]\npub unsafe fn f() {}\n";
        assert!(lint_source("src/a.rs", src, &Config::default()).is_empty());
    }

    #[test]
    fn safety_blocked_by_blank_line() {
        let src = "// SAFETY: stale comment.\n\nunsafe fn f() {}\n";
        let d = lint_source("src/a.rs", src, &Config::default());
        assert_eq!(rules_of(&d), vec!["safety-comment"]);
    }

    #[test]
    fn unsafe_in_strings_and_comments_ignored() {
        let src = "fn f() {\n    let s = \"unsafe { }\";\n    // unsafe in prose is fine\n}\n";
        assert!(lint_source("src/a.rs", src, &Config::default()).is_empty());
    }

    #[test]
    fn lock_without_poison_adoption_flagged_in_scope_only() {
        let src = "fn f() {\n    let g = m.lock().unwrap();\n}\n";
        let d = lint_source("src/runtime/x.rs", src, &Config::default_for_repo());
        assert_eq!(rules_of(&d), vec!["lock-poison"]);
        assert_eq!(d[0].line, 2);
        assert_eq!(d[0].code, "SFM002");
        // Same source outside the scoped dirs: clean.
        assert!(lint_source("tests/x.rs", src, &Config::default_for_repo()).is_empty());
    }

    #[test]
    fn lock_adopting_poison_passes() {
        let closure = "fn f() {\n    let g = m.lock().unwrap_or_else(|e| e.into_inner());\n}\n";
        assert!(lint_source("src/runtime/x.rs", closure, &Config::default_for_repo()).is_empty());
        let path_form = "fn f() {\n    let g = m.lock().unwrap_or_else(PoisonError::into_inner);\n}\n";
        assert!(lint_source("src/screening/x.rs", path_form, &Config::default_for_repo())
            .is_empty());
    }

    #[test]
    fn hot_path_flags_alloc_clock_and_rng() {
        let src = "fn hot(xs: &[f64]) -> f64 {\n    let v = Vec::new();\n    let t = Instant::now();\n    let s: Vec<f64> = xs.iter().collect();\n    let r = Pcg64::seeded(1);\n    0.0\n}\n";
        let d = lint_source("src/linalg/vecops.rs", src, &cfg_hot("src/linalg/vecops.rs", "hot"));
        assert_eq!(
            rules_of(&d),
            vec!["hot-path-alloc", "hot-path-alloc", "hot-path-alloc", "hot-path-alloc"]
        );
        assert_eq!(d[0].line, 2);
        assert_eq!(d[1].line, 3);
        assert_eq!(d[0].code, "SFM003");
        // The root itself carries a one-hop chain.
        assert_eq!(d[0].chain.len(), 1);
        assert!(d[0].chain[0].contains("::hot (root @1)"), "{:?}", d[0].chain);
    }

    #[test]
    fn hot_path_propagates_through_call_chain() {
        // The root is clean; the allocation sits two hops away. PR 7
        // would have needed `helper` and `leaf` on the allowlist — the
        // graph derives them.
        let src = "fn hot() {\n    helper();\n}\nfn helper() {\n    leaf();\n}\n\
                   fn leaf() {\n    let v = Vec::new();\n}\n";
        let d = lint_source("src/k.rs", src, &cfg_hot("src/k.rs", "hot"));
        assert_eq!(rules_of(&d), vec!["hot-path-alloc"]);
        assert_eq!(d[0].line, 8);
        assert!(d[0].msg.contains("`leaf`"), "{}", d[0].msg);
        let chain = &d[0].chain;
        assert_eq!(chain.len(), 3, "{chain:?}");
        assert!(chain[0].contains("::hot (root @1)"), "{chain:?}");
        assert!(chain[1].contains("::helper (called at src/k.rs:2)"), "{chain:?}");
        assert!(chain[2].contains("::leaf (called at src/k.rs:5)"), "{chain:?}");
    }

    #[test]
    fn hot_path_ignores_other_fns_and_reuse_pattern() {
        let src = "fn cold() { let v = Vec::new(); }\nfn hot(out: &mut Vec<f64>) {\n    out.clear();\n    out.resize(4, 0.0);\n    out.push(1.0);\n}\n";
        assert!(lint_source("src/x.rs", src, &cfg_hot("src/x.rs", "hot")).is_empty());
    }

    #[test]
    fn hot_path_vec_in_signature_is_fine() {
        let src = "fn hot(x: &mut Vec<f64>) -> Option<Vec<f64>> {\n    x.truncate(0);\n    None\n}\n";
        assert!(lint_source("src/x.rs", src, &cfg_hot("src/x.rs", "hot")).is_empty());
    }

    #[test]
    fn no_panic_flags_unwrap_expect_macros_and_indexing() {
        let cfg = Config {
            no_panic_roots: vec![("src/coordinator/serve.rs".into(), "run_job".into())],
            ..Config::default()
        };
        let src = "fn run_job(xs: &[u8]) {\n    let a = xs.first().unwrap();\n    let b = xs.iter().next().expect(\"x\");\n    let c = xs[0];\n    panic!(\"no\");\n}\n";
        let d = lint_source("src/coordinator/serve.rs", src, &cfg);
        assert_eq!(rules_of(&d).len(), 4);
        assert_eq!(d[0].line, 2);
        assert_eq!(d[2].line, 4);
        assert_eq!(d[0].code, "SFM004");
    }

    #[test]
    fn no_panic_allows_typed_fallbacks() {
        let cfg = Config {
            no_panic_roots: vec![("serve.rs".into(), "run_job".into())],
            ..Config::default()
        };
        let src = "fn run_job(xs: &[u8]) {\n    let a = xs.first().unwrap_or(&0);\n    let b = xs.get(0).unwrap_or_else(|| &0);\n    for x in [1, 2] { let _ = x; }\n    let v = vec![0u8; 3];\n    let _ = (a, b, v);\n}\n";
        assert!(lint_source("src/coordinator/serve.rs", src, &cfg).is_empty());
    }

    #[test]
    fn no_panic_propagates_but_index_stays_at_roots() {
        // `helper` is two files of chain away in spirit: its unwrap is
        // flagged transitively, its indexing is not (interior fns index
        // with proven bounds); the root's own indexing *is* flagged.
        let cfg = Config {
            no_panic_roots: vec![("src/s.rs".into(), "root".into())],
            ..Config::default()
        };
        let src = "fn root(xs: &[u8]) {\n    let a = xs[0];\n    helper();\n}\n\
                   fn helper() {\n    let v: Option<u8> = None;\n    v.unwrap();\n    \
                   let ys = [1u8];\n    let b = ys[0];\n}\n";
        let d = lint_source("src/s.rs", src, &cfg);
        assert_eq!(rules_of(&d), vec!["no-panic-paths", "no-panic-paths"]);
        assert_eq!(d[0].line, 2, "root index flagged");
        assert_eq!(d[1].line, 7, "helper unwrap flagged, helper index not");
        assert_eq!(d[1].chain.len(), 2, "{:?}", d[1].chain);
        assert!(d[1].chain[1].contains("::helper (called at src/s.rs:3)"), "{:?}", d[1].chain);
    }

    #[test]
    fn catch_unwind_stops_propagation_and_contained_fns_check_directly() {
        let cfg = Config {
            no_panic_roots: vec![("src/s.rs".into(), "serve_one".into())],
            contained_fns: vec![("src/s.rs".into(), "run_job".into())],
            ..Config::default()
        };
        // `deep` is only reachable through the contained edge: clean.
        // `run_job`'s own body is still checked directly.
        let src = "fn serve_one() {\n    let r = catch_unwind(AssertUnwindSafe(|| run_job()));\n}\n\
                   fn run_job() {\n    deep();\n    unreachable!(\"boom\");\n}\n\
                   fn deep() {\n    let v: Option<u8> = None;\n    v.unwrap();\n}\n";
        let d = lint_source("src/s.rs", src, &cfg);
        assert_eq!(rules_of(&d), vec!["no-panic-paths"]);
        assert_eq!(d[0].line, 6);
        assert!(d[0].msg.contains("panic-contained fn `run_job`"), "{}", d[0].msg);
        assert!(d[0].chain[0].contains("panic-contained"), "{:?}", d[0].chain);
    }

    #[test]
    fn boundary_tokens_flagged_outside_designated_fns() {
        let cfg = Config {
            boundary_fns: vec![("src/engine.rs".into(), "run".into())],
            ..Config::default()
        };
        let src = "fn run(sink: &TraceSink, c: &CancelToken, conf: &Ck) {\n    \
                   if let Some(r) = c.check() { return; }\n    sink.record(&ev);\n    \
                   conf.sink.store(ck);\n}\n\
                   fn rogue(sink: &TraceSink) {\n    sink.record(&ev);\n}\n";
        let d = lint_source("src/engine.rs", src, &cfg);
        assert_eq!(rules_of(&d), vec!["boundary-coupling"]);
        assert_eq!(d[0].line, 7);
        assert_eq!(d[0].code, "SFM006");
        assert!(d[0].msg.contains("`rogue`"), "{}", d[0].msg);
    }

    #[test]
    fn boundary_fn_reachable_from_hot_roots_is_flagged() {
        let cfg = Config {
            hot_roots: vec![("src/engine.rs".into(), "kernel".into())],
            boundary_fns: vec![("src/engine.rs".into(), "round".into())],
            ..Config::default()
        };
        let src = "fn kernel() {\n    round();\n}\n\
                   fn round(sink: &TraceSink) {\n    sink.record(&ev);\n}\n";
        let d = lint_source("src/engine.rs", src, &cfg);
        // `.record(` in a hot-reachable body also trips SFM003; the
        // designated-but-hot conflict is the SFM006 finding.
        let boundary: Vec<_> = d.iter().filter(|x| x.rule == "boundary-coupling").collect();
        assert_eq!(boundary.len(), 1, "{d:?}");
        assert!(boundary[0].msg.contains("reachable from the hot root set"));
        assert_eq!(boundary[0].chain.len(), 2, "{:?}", boundary[0].chain);
        assert!(d.iter().any(|x| x.rule == "hot-path-alloc"));
    }

    #[test]
    fn boundary_definition_files_are_exempt() {
        let src = "impl CancelToken {\n    pub fn poll(&self) -> bool {\n        \
                   self.inner.check().is_some()\n    }\n}\n";
        let d = lint_source("src/runtime/cancel.rs", src, &Config::default_for_repo());
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn waiver_suppresses_named_rule_on_next_code_line() {
        let src = "fn f() {\n    // lint: allow(safety-comment) — audited in PR 7.\n    let x = unsafe { g() };\n}\n";
        assert!(lint_source("src/a.rs", src, &Config::default()).is_empty());
    }

    #[test]
    fn waiver_covering_nothing_is_stale() {
        let src = "fn f() {\n    // lint: allow(lock-poison) - wrong rule.\n    let x = unsafe { g() };\n}\n";
        let d = lint_source("src/a.rs", src, &Config::default());
        assert_eq!(rules_of(&d), vec!["stale-waiver", "safety-comment"]);
        assert_eq!(d[0].line, 2);
        assert_eq!(d[0].code, "SFM007");
        assert!(d[0].msg.contains("lock-poison"), "{}", d[0].msg);
        assert_eq!(d[1].line, 3);
    }

    #[test]
    fn malformed_waivers_reported() {
        for bad in [
            "// lint: allow(safety-comment)",         // missing reason
            "// lint: allow safety-comment — x",      // missing parens
            "// lint: allow(not-a-rule) — x",         // unknown rule
            "// lint: allow() — x",                   // empty list
        ] {
            let src = format!("fn f() {{\n    {bad}\n    let y = 1;\n}}\n");
            let d = lint_source("src/a.rs", &src, &Config::default());
            assert_eq!(rules_of(&d), vec!["waiver-syntax"], "case: {bad}");
            assert_eq!(d[0].line, 2);
            assert_eq!(d[0].code, "SFM005");
        }
    }

    #[test]
    fn waiver_separators_and_multi_rule() {
        for sep in ["—", "-", ":"] {
            let src = format!(
                "fn f() {{\n    // lint: allow(safety-comment, lock-poison) {sep} reason here\n    let x = unsafe {{ m.lock().unwrap() }};\n}}\n"
            );
            let d = lint_source("src/runtime/x.rs", &src, &Config::default_for_repo());
            assert!(d.is_empty(), "sep {sep}: {d:?}");
        }
    }

    #[test]
    fn trait_declarations_have_no_bodies_to_scan() {
        let src = "trait T {\n    fn hot(&self);\n}\nimpl T for S {\n    fn hot(&self) { let v = Vec::new(); let _ = v; }\n}\n";
        let d = lint_source("src/x.rs", src, &cfg_hot("src/x.rs", "hot"));
        assert_eq!(rules_of(&d), vec!["hot-path-alloc"]);
        assert_eq!(d[0].line, 5);
    }

    #[test]
    fn display_renders_code_rule_and_chain() {
        let src = "fn hot() {\n    helper();\n}\nfn helper() {\n    let v = Vec::new();\n}\n";
        let d = lint_source("src/k.rs", src, &cfg_hot("src/k.rs", "hot"));
        assert_eq!(d.len(), 1);
        let text = d[0].to_string();
        assert!(text.starts_with("src/k.rs:5: [SFM003 hot-path-alloc]"), "{text}");
        assert!(text.contains("chain: src/k.rs::hot (root @1)"), "{text}");
        assert!(text.contains("-> src/k.rs::helper (called at src/k.rs:2)"), "{text}");
    }

    #[test]
    fn default_repo_config_is_well_formed() {
        let cfg = Config::default_for_repo();
        assert!(!cfg.hot_roots.is_empty());
        assert!(!cfg.lock_paths.is_empty());
        assert!(!cfg.no_panic_roots.is_empty());
        assert!(!cfg.contained_fns.is_empty());
        assert!(!cfg.boundary_fns.is_empty());
        assert!(!cfg.boundary_def_files.is_empty());
        for (code, name, _) in RULES {
            assert!(known_rule(name).is_some());
            assert!(code.starts_with("SFM"), "{code}");
        }
        // Codes are unique.
        let mut codes: Vec<&str> = RULES.iter().map(|&(c, _, _)| c).collect();
        codes.sort_unstable();
        codes.dedup();
        assert_eq!(codes.len(), RULES.len());
    }
}
