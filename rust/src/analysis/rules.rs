//! Rule engine for the `sfm_lint` static-analysis pass.
//!
//! Consumes the token stream from [`super::lexer`] and checks the
//! project-specific invariants that the runtime test suite cannot see
//! statically:
//!
//! * **safety-comment** — every `unsafe` keyword (block, fn, impl) is
//!   immediately preceded by a `// SAFETY:` comment or a `# Safety` doc
//!   section (attribute lines between comment and item are skipped).
//! * **lock-poison** — every `.lock()` in `src/runtime/`,
//!   `src/coordinator/`, `src/screening/`, and `src/decompose/` adopts
//!   poison via `.unwrap_or_else(…into_inner…)`: a sibling worker panic
//!   must surface as the original panic, never as a masking
//!   `PoisonError` unwrap.
//! * **hot-path-alloc** — no allocation-capable, wall-clock, or RNG
//!   calls inside a configured allowlist of hot functions (the static
//!   complement of the counting-allocator tests in
//!   `tests/zero_alloc.rs`, which only see executed paths).
//! * **no-panic-paths** — no bare `unwrap()` / `expect()`, panicking
//!   macro, or panicking index expression inside the
//!   `coordinator/serve.rs` job-handling functions: panic containment
//!   there must stay typed (`Outcome`/`ServeError`), not implicit.
//! * **waiver-syntax** — waiver comments are well-formed and name known
//!   rules.
//!
//! A finding can be waived at its site with a comment of the form
//! `lint: allow(<rule>[, <rule>]) — <reason>` (after `//`); the reason
//! is mandatory. The waiver covers its own line and the first code line
//! below its comment block.

use super::lexer::{lex, Token, TokenKind};
use std::fmt;
use std::path::Path;

/// `(name, summary)` for every rule the engine knows.
pub const RULES: &[(&str, &str)] = &[
    (
        "safety-comment",
        "every `unsafe` block/fn/impl is immediately preceded by a SAFETY comment",
    ),
    (
        "lock-poison",
        "`.lock()` in runtime/coordinator/screening/decompose adopts poison via unwrap_or_else(..into_inner..)",
    ),
    (
        "hot-path-alloc",
        "no allocation, wall-clock, or RNG calls inside the hot-path fn allowlist",
    ),
    (
        "no-panic-paths",
        "no bare unwrap/expect, panicking macro, or panicking index in serve job paths",
    ),
    (
        "waiver-syntax",
        "waiver comments are well-formed and name known rules",
    ),
];

fn known_rule(name: &str) -> Option<&'static str> {
    RULES.iter().map(|&(n, _)| n).find(|&n| n == name)
}

/// One lint finding, printed as `file:line: [rule] message`.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Where each scoped rule applies. Paths are matched against the
/// `/`-normalized file label: `lock_paths` by substring, the fn lists by
/// path suffix.
#[derive(Debug, Clone, Default)]
pub struct Config {
    /// `(path suffix, fn name)` — bodies subject to **hot-path-alloc**.
    pub hot_fns: Vec<(String, String)>,
    /// Path substrings subject to **lock-poison**.
    pub lock_paths: Vec<String>,
    /// `(path suffix, fn name)` — bodies subject to **no-panic-paths**.
    pub no_panic_fns: Vec<(String, String)>,
}

impl Config {
    /// The allowlists for this repository: the verified-allocation-free
    /// kernels (greedy pass, prox inner loops, pooled reducers) and the
    /// serve job path. `argsort_desc` and `CholeskyFactor::solve` are
    /// deliberately absent — they are the documented allocating
    /// conveniences; the `_into` variants are the hot ones.
    pub fn default_for_repo() -> Config {
        let hot: &[(&str, &[&str])] = &[
            (
                "src/linalg/vecops.rs",
                &[
                    "dot",
                    "dot4",
                    "dot_gather4",
                    "norm2_sq",
                    "axpy",
                    "axpy4",
                    "add_assign4",
                    "sweep4",
                    "cover_gain4",
                    "relu_mac_col4",
                    "max_update_col4",
                    "insertion_repair",
                    "argsort_desc_into",
                    "argsort_desc_adaptive",
                    "argsort_desc_remap",
                    "project_indices",
                ],
            ),
            ("src/linalg/cholesky.rs", &["push", "remove", "retain", "solve_into"]),
            ("src/decompose/chain.rs", &["tv_prox_into"]),
            ("src/solvers/pav.rs", &["run"]),
            ("src/lovasz.rs", &["accumulate_pass"]),
            ("src/submodular/kernel_cut.rs", &["prefix_gains_scratch"]),
            (
                "src/submodular/cut.rs",
                &["prefix_gains_scratch", "chunked_adjacency_sum", "fold_partials"],
            ),
        ];
        let mut hot_fns = Vec::new();
        for &(file, fns) in hot {
            for &f in fns {
                hot_fns.push((file.to_string(), f.to_string()));
            }
        }
        let no_panic = [
            "worker_loop",
            "serve_one",
            "run_job",
            "retry_backoff",
            "submit_line_with",
            "split_envelope",
            "envelope",
            "reject",
            "write_line",
            "make_pool",
        ];
        Config {
            hot_fns,
            lock_paths: [
                "src/runtime/",
                "src/coordinator/",
                "src/screening/",
                "src/decompose/",
                "src/obs/",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect(),
            no_panic_fns: no_panic
                .iter()
                .map(|f| ("src/coordinator/serve.rs".to_string(), f.to_string()))
                .collect(),
        }
    }
}

// ---------------------------------------------------------------------
// Per-line source classification
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Default)]
struct LineInfo {
    /// A non-comment token covers this line.
    has_code: bool,
    /// A comment token covers this line.
    has_comment: bool,
    /// The first non-comment token starting on this line is `#`
    /// (attribute line).
    starts_attr: bool,
    /// Comment texts starting on this line.
    comments: Vec<String>,
}

/// 1-indexed line table (`lines[0]` unused).
fn classify_lines(tokens: &[Token]) -> Vec<LineInfo> {
    let max = tokens.iter().map(|t| t.end_line).max().unwrap_or(0) as usize;
    let mut lines: Vec<LineInfo> = vec![LineInfo::default(); max + 1];
    for t in tokens {
        let span = t.line as usize..=t.end_line as usize;
        if t.is_comment() {
            for l in span {
                lines[l].has_comment = true;
            }
            lines[t.line as usize].comments.push(t.text.clone());
        } else {
            for l in span {
                lines[l].has_code = true;
            }
        }
    }
    // Second pass: mark attribute lines (first code token on the line is
    // `#`). Token order is source order, so the first non-comment token
    // whose start line is `l` decides.
    let mut seen = vec![false; max + 1];
    for t in tokens {
        if t.is_comment() {
            continue;
        }
        let l = t.line as usize;
        if !seen[l] {
            seen[l] = true;
            lines[l].starts_attr = t.is_punct('#');
        }
    }
    lines
}

impl LineInfo {
    fn comment_only(&self) -> bool {
        self.has_comment && !self.has_code
    }
    fn attr_only(&self) -> bool {
        self.has_code && self.starts_attr
    }
}

/// Does the comment context of code line `line` satisfy `pred`? Checks
/// trailing comments on the line itself, then walks upward through the
/// contiguous block of comment-only lines, skipping attribute lines
/// (`#[inline]` between a SAFETY comment and its fn is fine). Stops at
/// the first blank or code line.
fn context_has(lines: &[LineInfo], line: usize, pred: impl Fn(&str) -> bool) -> bool {
    if lines.get(line).is_some_and(|l| l.comments.iter().any(|c| pred(c))) {
        return true;
    }
    let mut l = line.saturating_sub(1);
    while l >= 1 {
        let info = &lines[l];
        if info.attr_only() {
            l -= 1;
            continue;
        }
        if info.comment_only() {
            if info.comments.iter().any(|c| pred(c)) {
                return true;
            }
            l -= 1;
            continue;
        }
        break;
    }
    false
}

/// The code line a comment block at `line` annotates: the first
/// non-blank, non-comment, non-attribute line at or below it.
fn annotated_code_line(lines: &[LineInfo], line: usize) -> Option<usize> {
    let mut l = line;
    while l < lines.len() {
        let info = &lines[l];
        if info.has_code && !info.starts_attr {
            return Some(l);
        }
        if !info.has_code && !info.has_comment && l != line {
            return None; // blank line ends the block
        }
        l += 1;
    }
    None
}

// ---------------------------------------------------------------------
// Waivers
// ---------------------------------------------------------------------

#[derive(Debug)]
struct Waiver {
    rules: Vec<&'static str>,
    /// Lines this waiver covers (its own line + the annotated code line).
    covers: Vec<usize>,
}

fn strip_comment_markers(text: &str) -> &str {
    let t = text.trim_start();
    let t = t
        .strip_prefix("//!")
        .or_else(|| t.strip_prefix("///"))
        .or_else(|| t.strip_prefix("//"))
        .unwrap_or(t);
    let t = match t.trim_start().strip_prefix("/*") {
        Some(inner) => inner.strip_suffix("*/").unwrap_or(inner),
        None => t,
    };
    t.trim()
}

/// Parse `lint: allow(rule[, rule]) — reason` from a stripped comment
/// body known to start with `lint:`. Returns the named rules or an
/// error message for the waiver-syntax diagnostic.
fn parse_waiver(body: &str) -> Result<Vec<&'static str>, String> {
    let rest = body.strip_prefix("lint:").expect("caller checked").trim_start();
    let rest = rest
        .strip_prefix("allow")
        .ok_or_else(|| "expected `allow(<rule>)` after `lint:`".to_string())?
        .trim_start();
    let rest = rest
        .strip_prefix('(')
        .ok_or_else(|| "expected `(` after `allow`".to_string())?;
    let close = rest
        .find(')')
        .ok_or_else(|| "unclosed `(` in waiver".to_string())?;
    let mut rules = Vec::new();
    for name in rest[..close].split(',') {
        let name = name.trim();
        if name.is_empty() {
            return Err("empty rule name in waiver".to_string());
        }
        match known_rule(name) {
            Some(r) => rules.push(r),
            None => return Err(format!("unknown rule `{name}` in waiver")),
        }
    }
    let tail = rest[close + 1..].trim_start();
    let reason = tail
        .strip_prefix('\u{2014}') // em dash
        .or_else(|| tail.strip_prefix('-'))
        .or_else(|| tail.strip_prefix(':'))
        .ok_or_else(|| "expected `— <reason>` after the rule list".to_string())?;
    if reason.trim().is_empty() {
        return Err("waiver reason must not be empty".to_string());
    }
    Ok(rules)
}

fn collect_waivers(
    file: &str,
    lines: &[LineInfo],
    diags: &mut Vec<Diagnostic>,
) -> Vec<Waiver> {
    let mut waivers = Vec::new();
    for (lno, info) in lines.iter().enumerate().skip(1) {
        for c in &info.comments {
            let body = strip_comment_markers(c);
            if !body.starts_with("lint:") {
                continue;
            }
            match parse_waiver(body) {
                Ok(rules) => {
                    let mut covers = vec![lno];
                    if let Some(code) = annotated_code_line(lines, lno) {
                        covers.push(code);
                    }
                    waivers.push(Waiver { rules, covers });
                }
                Err(msg) => diags.push(Diagnostic {
                    file: file.to_string(),
                    line: lno as u32,
                    rule: "waiver-syntax",
                    msg,
                }),
            }
        }
    }
    waivers
}

// ---------------------------------------------------------------------
// Rule passes (over the comment-free code view)
// ---------------------------------------------------------------------

/// Rust keywords that can legally precede `[` without forming an index
/// expression (`for x in [..]`, `return [..]`, …).
const NON_INDEX_KEYWORDS: &[&str] = &[
    "as", "async", "await", "box", "break", "const", "continue", "dyn", "else", "enum",
    "fn", "if", "impl", "in", "let", "loop", "match", "mod", "move", "mut", "pub", "ref",
    "return", "static", "struct", "trait", "type", "unsafe", "use", "where", "while",
    "yield",
];

fn rule_safety_comment(
    file: &str,
    code: &[&Token],
    lines: &[LineInfo],
    diags: &mut Vec<Diagnostic>,
) {
    for t in code {
        if t.is_ident("unsafe") {
            let has = context_has(lines, t.line as usize, |c| {
                c.contains("SAFETY") || c.contains("# Safety")
            });
            if !has {
                diags.push(Diagnostic {
                    file: file.to_string(),
                    line: t.line,
                    rule: "safety-comment",
                    msg: "`unsafe` without an immediately preceding `// SAFETY:` comment"
                        .to_string(),
                });
            }
        }
    }
}

fn rule_lock_poison(file: &str, code: &[&Token], diags: &mut Vec<Diagnostic>) {
    for i in 0..code.len() {
        // `.lock()` …
        if !(code[i].is_punct('.')
            && code.get(i + 1).is_some_and(|t| t.is_ident("lock"))
            && code.get(i + 2).is_some_and(|t| t.is_punct('('))
            && code.get(i + 3).is_some_and(|t| t.is_punct(')')))
        {
            continue;
        }
        // … must continue `.unwrap_or_else(` with `into_inner` nearby.
        let ok = code.get(i + 4).is_some_and(|t| t.is_punct('.'))
            && code.get(i + 5).is_some_and(|t| t.is_ident("unwrap_or_else"))
            && code.get(i + 6).is_some_and(|t| t.is_punct('('))
            && code[i + 7..code.len().min(i + 24)]
                .iter()
                .any(|t| t.is_ident("into_inner"));
        if !ok {
            diags.push(Diagnostic {
                file: file.to_string(),
                line: code[i + 1].line,
                rule: "lock-poison",
                msg: "`.lock()` must adopt poison via `.unwrap_or_else(..into_inner..)` \
                      so sibling-panic shutdown re-raises the original panic"
                    .to_string(),
            });
        }
    }
}

/// Find the token range `(start, end)` of the body of `fn name`, i.e.
/// the indices of its opening and closing braces in `code`. Returns all
/// bodies when the file defines the name more than once.
fn fn_bodies(code: &[&Token], name: &str) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i + 1 < code.len() {
        if code[i].is_ident("fn") && code[i + 1].is_ident(name) {
            let mut depth = 0i32; // parens + brackets (generics carry no braces here)
            let mut j = i + 2;
            let mut open = None;
            while j < code.len() {
                match code[j].kind {
                    TokenKind::Punct('(') | TokenKind::Punct('[') => depth += 1,
                    TokenKind::Punct(')') | TokenKind::Punct(']') => depth -= 1,
                    TokenKind::Punct(';') if depth == 0 => break, // bodyless decl
                    TokenKind::Punct('{') if depth == 0 => {
                        open = Some(j);
                        break;
                    }
                    _ => {}
                }
                j += 1;
            }
            if let Some(open) = open {
                let mut braces = 1i32;
                let mut k = open + 1;
                while k < code.len() && braces > 0 {
                    match code[k].kind {
                        TokenKind::Punct('{') => braces += 1,
                        TokenKind::Punct('}') => braces -= 1,
                        _ => {}
                    }
                    k += 1;
                }
                out.push((open, k.saturating_sub(1)));
                i = k;
                continue;
            }
        }
        i += 1;
    }
    out
}

/// Forbidden calls for **hot-path-alloc**. `.clone()` and
/// `push`/`extend`/`resize` are deliberately not listed: amortized
/// reuse of pre-sized buffers is the crate's sanctioned zero-alloc
/// pattern, stack clones (`Range`, `Arc` refcounts) are free, and a
/// token-level pass cannot see types — the counting allocator covers
/// the dynamic side.
const HOT_MACROS: &[&str] = &["vec", "format", "println", "eprintln", "print", "eprint"];
const HOT_METHODS: &[&str] = &["to_vec", "to_string", "to_owned", "collect"];
/// Observability entry points (`TraceSink::record`,
/// `Histogram::observe`) — banned in hot bodies outright: tracing is
/// boundary-sampled by design, so a hot kernel touching the sink means
/// the sampling discipline leaked into an inner loop (OBSERVABILITY.md).
const OBS_METHODS: &[&str] = &["record", "observe", "add_pool_dispatches"];
const HOT_TYPES: &[&str] = &[
    "Vec", "String", "Box", "Rc", "Arc", "VecDeque", "HashMap", "HashSet", "BTreeMap",
    "Instant", "SystemTime", "Pcg64", "TraceSink", "MetricsRegistry", "CheckpointSink",
];

fn hot_path_violation(code: &[&Token], k: usize) -> Option<String> {
    let t = code[k];
    if t.kind != TokenKind::Ident {
        return None;
    }
    let name = t.text.as_str();
    if HOT_MACROS.contains(&name) && code.get(k + 1).is_some_and(|n| n.is_punct('!')) {
        return Some(format!("`{name}!` allocates"));
    }
    if HOT_METHODS.contains(&name)
        && k > 0
        && code[k - 1].is_punct('.')
        && code.get(k + 1).is_some_and(|n| n.is_punct('('))
    {
        return Some(format!("`.{name}()` allocates"));
    }
    if OBS_METHODS.contains(&name)
        && k > 0
        && code[k - 1].is_punct('.')
        && code.get(k + 1).is_some_and(|n| n.is_punct('('))
    {
        return Some(format!(
            "`.{name}()` is an observability call — tracing is boundary-sampled, \
             never from a hot kernel"
        ));
    }
    if HOT_TYPES.contains(&name)
        && code.get(k + 1).is_some_and(|n| n.is_punct(':'))
        && code.get(k + 2).is_some_and(|n| n.is_punct(':'))
    {
        if let Some(m) = code.get(k + 3).filter(|m| m.kind == TokenKind::Ident) {
            let assoc = m.text.as_str();
            let bad = match name {
                "Instant" | "SystemTime" => assoc == "now",
                "Pcg64" => true, // any RNG construction/use is nondeterministic state
                // Observability/checkpoint handles must never be
                // constructed or touched inside a hot kernel — any
                // associated call (snapshots are boundary-sampled,
                // RELIABILITY.md).
                "TraceSink" | "MetricsRegistry" | "CheckpointSink" => true,
                _ => matches!(assoc, "new" | "with_capacity" | "from"),
            };
            if bad {
                return Some(format!("`{name}::{assoc}` is not allowed on the hot path"));
            }
        }
    }
    None
}

fn rule_hot_path(
    file: &str,
    code: &[&Token],
    cfg: &Config,
    diags: &mut Vec<Diagnostic>,
) {
    for (suffix, fname) in &cfg.hot_fns {
        if !file.ends_with(suffix.as_str()) {
            continue;
        }
        for (open, close) in fn_bodies(code, fname) {
            for k in open + 1..close {
                if let Some(what) = hot_path_violation(code, k) {
                    diags.push(Diagnostic {
                        file: file.to_string(),
                        line: code[k].line,
                        rule: "hot-path-alloc",
                        msg: format!("{what} (hot fn `{fname}`)"),
                    });
                }
            }
        }
    }
}

const PANIC_MACROS: &[&str] = &[
    "panic", "unreachable", "todo", "unimplemented", "assert", "assert_eq", "assert_ne",
];

fn no_panic_violation(code: &[&Token], k: usize) -> Option<String> {
    let t = code[k];
    match &t.kind {
        TokenKind::Ident => {
            let name = t.text.as_str();
            if (name == "unwrap" || name == "expect")
                && k > 0
                && code[k - 1].is_punct('.')
                && code.get(k + 1).is_some_and(|n| n.is_punct('('))
            {
                return Some(format!("bare `.{name}()` can panic"));
            }
            if PANIC_MACROS.contains(&name) && code.get(k + 1).is_some_and(|n| n.is_punct('!'))
            {
                return Some(format!("`{name}!` panics"));
            }
            None
        }
        TokenKind::Punct('[') if k > 0 => {
            let prev = code[k - 1];
            let indexes = match &prev.kind {
                TokenKind::Ident => !NON_INDEX_KEYWORDS.contains(&prev.text.as_str()),
                TokenKind::Punct(')') | TokenKind::Punct(']') => true,
                _ => false,
            };
            if indexes {
                return Some("panicking index expression (use `get`/typed errors)".to_string());
            }
            None
        }
        _ => None,
    }
}

fn rule_no_panic(
    file: &str,
    code: &[&Token],
    cfg: &Config,
    diags: &mut Vec<Diagnostic>,
) {
    for (suffix, fname) in &cfg.no_panic_fns {
        if !file.ends_with(suffix.as_str()) {
            continue;
        }
        for (open, close) in fn_bodies(code, fname) {
            for k in open + 1..close {
                if let Some(what) = no_panic_violation(code, k) {
                    diags.push(Diagnostic {
                        file: file.to_string(),
                        line: code[k].line,
                        rule: "no-panic-paths",
                        msg: format!("{what} (job path `{fname}`)"),
                    });
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------

/// Lint one source file. `file_label` is used for both path-scoped rule
/// matching (normalized to `/` separators) and diagnostics.
pub fn lint_source(file_label: &str, src: &str, cfg: &Config) -> Vec<Diagnostic> {
    let file = file_label.replace('\\', "/");
    let tokens = lex(src);
    let lines = classify_lines(&tokens);
    let code: Vec<&Token> = tokens.iter().filter(|t| !t.is_comment()).collect();

    let mut diags = Vec::new();
    let waivers = collect_waivers(&file, &lines, &mut diags);
    rule_safety_comment(&file, &code, &lines, &mut diags);
    rule_lock_poison_scoped(&file, &code, cfg, &mut diags);
    rule_hot_path(&file, &code, cfg, &mut diags);
    rule_no_panic(&file, &code, cfg, &mut diags);

    diags.retain(|d| {
        d.rule == "waiver-syntax"
            || !waivers
                .iter()
                .any(|w| w.rules.contains(&d.rule) && w.covers.contains(&(d.line as usize)))
    });
    diags.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    diags
}

fn rule_lock_poison_scoped(
    file: &str,
    code: &[&Token],
    cfg: &Config,
    diags: &mut Vec<Diagnostic>,
) {
    if cfg.lock_paths.iter().any(|p| file.contains(p.as_str())) {
        rule_lock_poison(file, code, diags);
    }
}

/// Recursively lint every `*.rs` file under `root`, skipping `target`,
/// `vendor`, and VCS directories. Diagnostics come back sorted by
/// `(file, line, rule)`.
pub fn lint_tree(root: &Path, cfg: &Config) -> std::io::Result<(usize, Vec<Diagnostic>)> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut diags = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f)?;
        let label = f.to_string_lossy().replace('\\', "/");
        diags.extend(lint_source(&label, &src, cfg));
    }
    diags.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok((files.len(), diags))
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if name == "target" || name == "vendor" || name.starts_with('.') {
                continue;
            }
            collect_rs_files(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_hot(file: &str, f: &str) -> Config {
        Config { hot_fns: vec![(file.to_string(), f.to_string())], ..Config::default() }
    }

    fn rules_of(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.rule).collect()
    }

    #[test]
    fn unsafe_without_safety_flagged_with_line() {
        let src = "fn f() {\n    let x = unsafe { g() };\n}\n";
        let d = lint_source("src/a.rs", src, &Config::default());
        assert_eq!(rules_of(&d), vec!["safety-comment"]);
        assert_eq!(d[0].line, 2);
    }

    #[test]
    fn safety_comment_above_or_trailing_accepted() {
        let above = "fn f() {\n    // SAFETY: g is fine here.\n    let x = unsafe { g() };\n}\n";
        assert!(lint_source("src/a.rs", above, &Config::default()).is_empty());
        let trailing = "fn f() {\n    let x = unsafe { g() }; // SAFETY: fine\n}\n";
        assert!(lint_source("src/a.rs", trailing, &Config::default()).is_empty());
    }

    #[test]
    fn safety_walk_skips_attributes_and_doc_sections_count() {
        let src = "/// Does things.\n///\n/// # Safety\n///\n/// Caller checks bounds.\n#[inline]\npub unsafe fn f() {}\n";
        assert!(lint_source("src/a.rs", src, &Config::default()).is_empty());
    }

    #[test]
    fn safety_blocked_by_blank_line() {
        let src = "// SAFETY: stale comment.\n\nunsafe fn f() {}\n";
        let d = lint_source("src/a.rs", src, &Config::default());
        assert_eq!(rules_of(&d), vec!["safety-comment"]);
    }

    #[test]
    fn unsafe_in_strings_and_comments_ignored() {
        let src = "fn f() {\n    let s = \"unsafe { }\";\n    // unsafe in prose is fine\n}\n";
        assert!(lint_source("src/a.rs", src, &Config::default()).is_empty());
    }

    #[test]
    fn lock_without_poison_adoption_flagged_in_scope_only() {
        let src = "fn f() {\n    let g = m.lock().unwrap();\n}\n";
        let d = lint_source("src/runtime/x.rs", src, &Config::default_for_repo());
        assert_eq!(rules_of(&d), vec!["lock-poison"]);
        assert_eq!(d[0].line, 2);
        // Same source outside the scoped dirs: clean.
        assert!(lint_source("tests/x.rs", src, &Config::default_for_repo()).is_empty());
    }

    #[test]
    fn lock_adopting_poison_passes() {
        let closure = "fn f() {\n    let g = m.lock().unwrap_or_else(|e| e.into_inner());\n}\n";
        assert!(lint_source("src/runtime/x.rs", closure, &Config::default_for_repo()).is_empty());
        let path_form = "fn f() {\n    let g = m.lock().unwrap_or_else(PoisonError::into_inner);\n}\n";
        assert!(lint_source("src/screening/x.rs", path_form, &Config::default_for_repo())
            .is_empty());
    }

    #[test]
    fn hot_path_flags_alloc_clock_and_rng() {
        let src = "fn hot(xs: &[f64]) -> f64 {\n    let v = Vec::new();\n    let t = Instant::now();\n    let s: Vec<f64> = xs.iter().collect();\n    let r = Pcg64::seeded(1);\n    0.0\n}\n";
        let d = lint_source("src/linalg/vecops.rs", src, &cfg_hot("src/linalg/vecops.rs", "hot"));
        assert_eq!(
            rules_of(&d),
            vec!["hot-path-alloc", "hot-path-alloc", "hot-path-alloc", "hot-path-alloc"]
        );
        assert_eq!(d[0].line, 2);
        assert_eq!(d[1].line, 3);
    }

    #[test]
    fn hot_path_flags_observability_calls() {
        // Any obs token in a hot body trips the rule: sink construction,
        // `.record()`, and `.observe()` — tracing is boundary-sampled.
        let src = "fn hot(xs: &[f64], sink: &TraceSink, h: &Histogram) -> f64 {\n    let s = TraceSink::clone(sink);\n    sink.record(&ev);\n    h.observe(0.1);\n    0.0\n}\n";
        let d = lint_source("src/x.rs", src, &cfg_hot("src/x.rs", "hot"));
        assert_eq!(rules_of(&d), vec!["hot-path-alloc", "hot-path-alloc", "hot-path-alloc"]);
        assert!(d[1].msg.contains("observability"), "{}", d[1].msg);
        // The same calls outside a hot body stay clean.
        let cold = "fn cold(sink: &TraceSink) { sink.record(&ev); }\n";
        assert!(lint_source("src/x.rs", cold, &cfg_hot("src/x.rs", "hot")).is_empty());
    }

    #[test]
    fn hot_path_ignores_other_fns_and_reuse_pattern() {
        let src = "fn cold() { let v = Vec::new(); }\nfn hot(out: &mut Vec<f64>) {\n    out.clear();\n    out.resize(4, 0.0);\n    out.push(1.0);\n}\n";
        assert!(lint_source("src/x.rs", src, &cfg_hot("src/x.rs", "hot")).is_empty());
    }

    #[test]
    fn hot_path_vec_in_signature_is_fine() {
        let src = "fn hot(x: &mut Vec<f64>) -> Option<Vec<f64>> {\n    x.truncate(0);\n    None\n}\n";
        assert!(lint_source("src/x.rs", src, &cfg_hot("src/x.rs", "hot")).is_empty());
    }

    #[test]
    fn no_panic_flags_unwrap_expect_macros_and_indexing() {
        let cfg = Config {
            no_panic_fns: vec![("src/coordinator/serve.rs".into(), "run_job".into())],
            ..Config::default()
        };
        let src = "fn run_job(xs: &[u8]) {\n    let a = xs.first().unwrap();\n    let b = xs.iter().next().expect(\"x\");\n    let c = xs[0];\n    panic!(\"no\");\n}\n";
        let d = lint_source("src/coordinator/serve.rs", src, &cfg);
        assert_eq!(rules_of(&d).len(), 4);
        assert_eq!(d[0].line, 2);
        assert_eq!(d[2].line, 4);
    }

    #[test]
    fn no_panic_allows_typed_fallbacks() {
        let cfg = Config {
            no_panic_fns: vec![("serve.rs".into(), "run_job".into())],
            ..Config::default()
        };
        let src = "fn run_job(xs: &[u8]) {\n    let a = xs.first().unwrap_or(&0);\n    let b = xs.get(0).unwrap_or_else(|| &0);\n    for x in [1, 2] { let _ = x; }\n    let v = vec![0u8; 3];\n    let _ = (a, b, v);\n}\n";
        assert!(lint_source("src/coordinator/serve.rs", src, &cfg).is_empty());
    }

    #[test]
    fn waiver_suppresses_named_rule_on_next_code_line() {
        let src = "fn f() {\n    // lint: allow(safety-comment) — audited in PR 7.\n    let x = unsafe { g() };\n}\n";
        assert!(lint_source("src/a.rs", src, &Config::default()).is_empty());
    }

    #[test]
    fn waiver_only_covers_named_rules() {
        let src = "fn f() {\n    // lint: allow(lock-poison) - wrong rule.\n    let x = unsafe { g() };\n}\n";
        let d = lint_source("src/a.rs", src, &Config::default());
        assert_eq!(rules_of(&d), vec!["safety-comment"]);
    }

    #[test]
    fn malformed_waivers_reported() {
        for bad in [
            "// lint: allow(safety-comment)",         // missing reason
            "// lint: allow safety-comment — x",      // missing parens
            "// lint: allow(not-a-rule) — x",         // unknown rule
            "// lint: allow() — x",                   // empty list
        ] {
            let src = format!("fn f() {{\n    {bad}\n    let y = 1;\n}}\n");
            let d = lint_source("src/a.rs", &src, &Config::default());
            assert_eq!(rules_of(&d), vec!["waiver-syntax"], "case: {bad}");
            assert_eq!(d[0].line, 2);
        }
    }

    #[test]
    fn waiver_separators_and_multi_rule() {
        for sep in ["—", "-", ":"] {
            let src = format!(
                "fn f() {{\n    // lint: allow(safety-comment, lock-poison) {sep} reason here\n    let x = unsafe {{ m.lock().unwrap() }};\n}}\n"
            );
            let d = lint_source("src/runtime/x.rs", &src, &Config::default_for_repo());
            assert!(d.is_empty(), "sep {sep}: {d:?}");
        }
    }

    #[test]
    fn fn_bodies_skip_trait_declarations() {
        let src = "trait T {\n    fn hot(&self);\n}\nimpl T for S {\n    fn hot(&self) { let v = Vec::new(); let _ = v; }\n}\n";
        let d = lint_source("src/x.rs", src, &cfg_hot("src/x.rs", "hot"));
        assert_eq!(rules_of(&d), vec!["hot-path-alloc"]);
        assert_eq!(d[0].line, 5);
    }

    #[test]
    fn default_repo_config_names_known_rules_only() {
        let cfg = Config::default_for_repo();
        assert!(!cfg.hot_fns.is_empty());
        assert!(!cfg.lock_paths.is_empty());
        assert!(!cfg.no_panic_fns.is_empty());
        for (name, _) in RULES {
            assert!(known_rule(name).is_some());
        }
    }
}
