//! A minimal, dependency-free Rust lexer for the `sfm_lint` pass.
//!
//! This is not a full grammar — it is exactly the token-level slice the
//! lint rules need: identifiers (including `r#raw` idents), lifetimes
//! vs. char literals, string literals in all their spellings (`"…"`,
//! `r"…"`, `r##"…"##`, `b"…"`, `br#"…"#`), numbers, line comments,
//! nested block comments, and single-character punctuation. The same
//! hand-rolled discipline as `coordinator::json`: no external crates,
//! error-tolerant (an unterminated literal lexes to end of input rather
//! than aborting), and every token carries 1-based start/end lines so
//! rules can report `file:line`.

/// Token classification. `Punct` carries the single character verbatim;
/// multi-character operators arrive as consecutive `Punct` tokens, which
/// is all the rule engine needs (`::` is `Punct(':') Punct(':')`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokenKind {
    /// Identifier or keyword; raw idents keep their `r#` prefix.
    Ident,
    /// `'a`, `'_`, `'static` — a tick followed by an identifier with no
    /// closing tick.
    Lifetime,
    /// `'x'`, `'\n'`, `'\u{1F980}'`, `b'x'`.
    CharLit,
    /// Any string literal: plain, raw, byte, raw-byte.
    StrLit,
    /// Integer or float literal, including suffixes (`1_000u64`, `1e-3`).
    NumLit,
    /// `// …` to end of line (includes `///` and `//!`).
    LineComment,
    /// `/* … */`, nesting-aware; may span lines.
    BlockComment,
    /// Any other single character.
    Punct(char),
}

/// One lexed token with its source text and 1-based line span.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokenKind,
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: u32,
    /// 1-based line of the token's last character (differs from `line`
    /// only for block comments and multi-line string literals).
    pub end_line: u32,
}

impl Token {
    /// Whether this token is a comment of either flavor.
    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokenKind::LineComment | TokenKind::BlockComment)
    }

    /// Whether this token is an identifier with exactly this text.
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokenKind::Ident && self.text == s
    }

    /// Whether this token is this exact punctuation character.
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokenKind::Punct(c)
    }

    /// The identifier's *name*: raw identifiers (`r#fn`, `r#match`)
    /// drop their `r#` prefix, everything else is the text verbatim.
    ///
    /// Name-driven analyses (fn-item extraction, call resolution) must
    /// match on this — `fn r#loop()` defines a function named `loop`.
    /// Keyword-driven rules must keep matching on [`Token::is_ident`]
    /// (exact text): `r#unsafe` is a plain identifier, *not* the
    /// `unsafe` keyword, and must not trip the safety-comment rule.
    pub fn ident_name(&self) -> &str {
        if self.kind == TokenKind::Ident {
            self.text.strip_prefix("r#").unwrap_or(&self.text)
        } else {
            &self.text
        }
    }
}

struct Cursor<'a> {
    src: &'a [u8],
    pos: usize,
    line: u32,
}

impl<'a> Cursor<'a> {
    fn peek(&self, ahead: usize) -> Option<u8> {
        self.src.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek(0)?;
        self.pos += 1;
        if b == b'\n' {
            self.line += 1;
        }
        Some(b)
    }
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_' || b >= 0x80
}

fn is_ident_continue(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_' || b >= 0x80
}

/// Lex `src` into a token stream. Never fails: malformed input produces
/// best-effort tokens (an unterminated string or block comment simply
/// extends to end of input).
pub fn lex(src: &str) -> Vec<Token> {
    let mut cur = Cursor { src: src.as_bytes(), pos: 0, line: 1 };
    let mut out = Vec::new();
    while let Some(b) = cur.peek(0) {
        let start = cur.pos;
        let start_line = cur.line;
        match b {
            b' ' | b'\t' | b'\r' | b'\n' => {
                cur.bump();
            }
            b'/' if cur.peek(1) == Some(b'/') => {
                while let Some(c) = cur.peek(0) {
                    if c == b'\n' {
                        break;
                    }
                    cur.bump();
                }
                push(&mut out, TokenKind::LineComment, src, start, &cur, start_line);
            }
            b'/' if cur.peek(1) == Some(b'*') => {
                cur.bump();
                cur.bump();
                let mut depth = 1usize;
                while depth > 0 {
                    match (cur.peek(0), cur.peek(1)) {
                        (Some(b'/'), Some(b'*')) => {
                            depth += 1;
                            cur.bump();
                            cur.bump();
                        }
                        (Some(b'*'), Some(b'/')) => {
                            depth -= 1;
                            cur.bump();
                            cur.bump();
                        }
                        (Some(_), _) => {
                            cur.bump();
                        }
                        (None, _) => break,
                    }
                }
                push(&mut out, TokenKind::BlockComment, src, start, &cur, start_line);
            }
            b'"' => {
                lex_plain_string(&mut cur);
                push(&mut out, TokenKind::StrLit, src, start, &cur, start_line);
            }
            b'r' | b'b' if starts_string_prefix(&cur) => {
                let kind = lex_prefixed_literal(&mut cur);
                push(&mut out, kind, src, start, &cur, start_line);
            }
            b'\'' => {
                let kind = lex_tick(&mut cur);
                push(&mut out, kind, src, start, &cur, start_line);
            }
            _ if b.is_ascii_digit() => {
                lex_number(&mut cur);
                push(&mut out, TokenKind::NumLit, src, start, &cur, start_line);
            }
            _ if is_ident_start(b) => {
                lex_ident(&mut cur);
                push(&mut out, TokenKind::Ident, src, start, &cur, start_line);
            }
            _ => {
                cur.bump();
                push(&mut out, TokenKind::Punct(b as char), src, start, &cur, start_line);
            }
        }
    }
    out
}

fn push(
    out: &mut Vec<Token>,
    kind: TokenKind,
    src: &str,
    start: usize,
    cur: &Cursor<'_>,
    start_line: u32,
) {
    out.push(Token {
        kind,
        text: src[start..cur.pos].to_string(),
        line: start_line,
        end_line: cur.line,
    });
}

/// After seeing `r` or `b` at the cursor: does a string/char literal
/// prefix follow, as opposed to a plain identifier like `range` or a raw
/// ident like `r#fn`? Accepted literal shapes: `r"`, `r#…#"`, `b"`,
/// `b'`, `br"`, `br#…#"`.
fn starts_string_prefix(cur: &Cursor<'_>) -> bool {
    let mut i = 1;
    if cur.peek(0) == Some(b'b') {
        if cur.peek(1) == Some(b'\'') || cur.peek(1) == Some(b'"') {
            return true;
        }
        if cur.peek(1) != Some(b'r') {
            return false;
        }
        i = 2;
    }
    // `r` at offset i-1; count hashes.
    let mut hashes = 0usize;
    while cur.peek(i + hashes) == Some(b'#') {
        hashes += 1;
    }
    match cur.peek(i + hashes) {
        Some(b'"') => true,
        // `r#ident` raw identifier (or bare `r` ident): not a literal.
        _ => false,
    }
}

/// Lex `r"…"`, `r#"…"#`, `b"…"`, `b'x'`, `br#"…"#` after
/// `starts_string_prefix` returned true.
fn lex_prefixed_literal(cur: &mut Cursor<'_>) -> TokenKind {
    let mut raw = false;
    if cur.peek(0) == Some(b'b') {
        cur.bump();
        if cur.peek(0) == Some(b'\'') {
            cur.bump(); // opening tick
            lex_char_body(cur);
            return TokenKind::CharLit;
        }
    }
    if cur.peek(0) == Some(b'r') {
        raw = true;
        cur.bump();
    }
    let mut hashes = 0usize;
    while cur.peek(0) == Some(b'#') {
        hashes += 1;
        cur.bump();
    }
    debug_assert_eq!(cur.peek(0), Some(b'"'));
    cur.bump(); // opening quote
    if raw {
        // Raw: no escapes; terminated by `"` + `hashes` hashes.
        'outer: while let Some(c) = cur.bump() {
            if c == b'"' {
                for k in 0..hashes {
                    if cur.peek(k) != Some(b'#') {
                        continue 'outer;
                    }
                }
                for _ in 0..hashes {
                    cur.bump();
                }
                break;
            }
        }
    } else {
        lex_plain_string_body(cur);
    }
    TokenKind::StrLit
}

fn lex_plain_string(cur: &mut Cursor<'_>) {
    cur.bump(); // opening quote
    lex_plain_string_body(cur);
}

fn lex_plain_string_body(cur: &mut Cursor<'_>) {
    while let Some(c) = cur.bump() {
        match c {
            b'\\' => {
                cur.bump();
            }
            b'"' => break,
            _ => {}
        }
    }
}

/// Everything after a `'`: decide char literal vs lifetime.
///
/// - `'\…` is always a char literal (escape).
/// - `'<ident-chars>'` is a char literal (`'a'`); `'<ident-chars>` with
///   no closing tick is a lifetime (`'a`, `'static`, `'_`).
/// - `'<other>` is a char literal (`'('`, `' '`).
fn lex_tick(cur: &mut Cursor<'_>) -> TokenKind {
    cur.bump(); // the tick
    match cur.peek(0) {
        Some(b'\\') => {
            // Leave the backslash for `lex_char_body`, whose escape
            // handling consumes the pair — bumping it here would make
            // the escaped char in `'\''` look like the terminator.
            lex_char_body(cur);
            TokenKind::CharLit
        }
        Some(c) if is_ident_continue(c) => {
            let mut n = 0usize;
            while cur.peek(n).is_some_and(is_ident_continue) {
                n += 1;
            }
            if cur.peek(n) == Some(b'\'') {
                for _ in 0..=n {
                    cur.bump();
                }
                TokenKind::CharLit
            } else {
                for _ in 0..n {
                    cur.bump();
                }
                TokenKind::Lifetime
            }
        }
        Some(_) => {
            cur.bump();
            lex_char_body(cur);
            TokenKind::CharLit
        }
        None => TokenKind::Lifetime,
    }
}

/// Consume the remainder of a char literal up to and including the
/// closing tick (escapes like `'\u{1F980}'` already consumed their
/// backslash; this just scans for the terminator).
fn lex_char_body(cur: &mut Cursor<'_>) {
    while let Some(c) = cur.bump() {
        match c {
            b'\\' => {
                cur.bump();
            }
            b'\'' => break,
            b'\n' => break, // malformed; don't swallow the file
            _ => {}
        }
    }
}

/// Numbers: `10`, `0x3f`, `1_000u64`, `1.5e-3`. Consumes `.` only when a
/// digit follows, so `0..p` and `1.max(2)` stop at the dot.
fn lex_number(cur: &mut Cursor<'_>) {
    let mut prev = 0u8;
    while let Some(c) = cur.peek(0) {
        if is_ident_continue(c) {
            prev = c;
            cur.bump();
        } else if c == b'.' && cur.peek(1).is_some_and(|d| d.is_ascii_digit()) {
            prev = c;
            cur.bump();
        } else if (c == b'+' || c == b'-') && (prev == b'e' || prev == b'E') {
            prev = c;
            cur.bump();
        } else {
            break;
        }
    }
}

fn lex_ident(cur: &mut Cursor<'_>) {
    // Raw-ident prefix: `r#fn`.
    if cur.peek(0) == Some(b'r')
        && cur.peek(1) == Some(b'#')
        && cur.peek(2).is_some_and(is_ident_start)
    {
        cur.bump();
        cur.bump();
    }
    while cur.peek(0).is_some_and(is_ident_continue) {
        cur.bump();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokenKind> {
        lex(src).into_iter().map(|t| t.kind).collect()
    }

    fn texts(src: &str) -> Vec<String> {
        lex(src).into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn idents_and_puncts() {
        let toks = lex("fn main() {}");
        assert!(toks[0].is_ident("fn"));
        assert!(toks[1].is_ident("main"));
        assert!(toks[2].is_punct('('));
        assert!(toks[3].is_punct(')'));
        assert!(toks[4].is_punct('{'));
        assert!(toks[5].is_punct('}'));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let toks = lex(r####"let s = r##"quote " and "# inside"##;"####);
        let lit = toks.iter().find(|t| t.kind == TokenKind::StrLit).unwrap();
        assert_eq!(lit.text, r####"r##"quote " and "# inside"##"####);
        // Nothing inside the raw string leaked out as separate tokens.
        assert!(!toks.iter().any(|t| t.is_ident("quote")));
        assert!(toks.last().unwrap().is_punct(';'));
    }

    #[test]
    fn raw_idents_lex_as_plain_identifiers() {
        // `r#fn` / `r#unsafe` are identifiers, not keywords: keyword
        // checks (exact text) must miss them, name checks must strip
        // the prefix.
        let toks = lex("fn r#fn() { r#unsafe(); let r#match = 1; }");
        assert!(toks[0].is_ident("fn"));
        assert_eq!(toks[1].kind, TokenKind::Ident);
        assert_eq!(toks[1].text, "r#fn");
        assert!(!toks[1].is_ident("fn"));
        assert_eq!(toks[1].ident_name(), "fn");
        let raw_unsafe = toks.iter().find(|t| t.text == "r#unsafe").unwrap();
        assert!(!raw_unsafe.is_ident("unsafe"));
        assert_eq!(raw_unsafe.ident_name(), "unsafe");
        let raw_match = toks.iter().find(|t| t.text == "r#match").unwrap();
        assert_eq!(raw_match.ident_name(), "match");
    }

    #[test]
    fn ident_name_leaves_normal_idents_alone() {
        let toks = lex("range r#range rx");
        assert_eq!(toks[0].ident_name(), "range");
        assert_eq!(toks[1].ident_name(), "range");
        assert_eq!(toks[1].text, "r#range");
        assert_eq!(toks[2].ident_name(), "rx");
    }

    #[test]
    fn raw_string_is_not_raw_ident() {
        let toks = lex("r#fn r\"x\" r#\"y\"# range");
        assert_eq!(toks[0].kind, TokenKind::Ident);
        assert_eq!(toks[0].text, "r#fn");
        assert_eq!(toks[1].kind, TokenKind::StrLit);
        assert_eq!(toks[2].kind, TokenKind::StrLit);
        assert!(toks[3].is_ident("range"));
    }

    #[test]
    fn byte_literals() {
        let toks = lex("b\"bytes\" br#\"raw\"# b'\\n' b'x'");
        assert_eq!(toks[0].kind, TokenKind::StrLit);
        assert_eq!(toks[1].kind, TokenKind::StrLit);
        assert_eq!(toks[2].kind, TokenKind::CharLit);
        assert_eq!(toks[3].kind, TokenKind::CharLit);
    }

    #[test]
    fn nested_block_comments() {
        let toks = lex("a /* outer /* inner */ still outer */ b");
        assert_eq!(toks.len(), 3);
        assert!(toks[0].is_ident("a"));
        assert_eq!(toks[1].kind, TokenKind::BlockComment);
        assert!(toks[1].text.contains("inner"));
        assert!(toks[2].is_ident("b"));
    }

    #[test]
    fn char_vs_lifetime() {
        let toks = lex("'a' 'a 'static '_ '\\u{1F980}' ' ' &'x str");
        assert_eq!(toks[0].kind, TokenKind::CharLit);
        assert_eq!(toks[1].kind, TokenKind::Lifetime);
        assert_eq!(toks[1].text, "'a");
        assert_eq!(toks[2].kind, TokenKind::Lifetime);
        assert_eq!(toks[2].text, "'static");
        assert_eq!(toks[3].kind, TokenKind::Lifetime);
        assert_eq!(toks[4].kind, TokenKind::CharLit);
        assert_eq!(toks[5].kind, TokenKind::CharLit);
        assert!(toks[6].is_punct('&'));
        assert_eq!(toks[7].kind, TokenKind::Lifetime);
        assert!(toks[8].is_ident("str"));
    }

    #[test]
    fn escaped_tick_char_literal() {
        let toks = lex(r"'\'' x '\\' y");
        assert_eq!(toks[0].kind, TokenKind::CharLit);
        assert_eq!(toks[0].text, r"'\''");
        assert!(toks[1].is_ident("x"));
        assert_eq!(toks[2].kind, TokenKind::CharLit);
        assert!(toks[3].is_ident("y"));
    }

    #[test]
    fn lifetime_in_generics() {
        // `<'a>` must not eat the `>` as part of a char literal.
        let toks = lex("impl<'a, T> Foo<'a> for Bar<T> {}");
        let lifetimes: Vec<_> =
            lex("impl<'a, T> Foo<'a> for Bar<T> {}")
                .into_iter()
                .filter(|t| t.kind == TokenKind::Lifetime)
                .collect();
        assert_eq!(lifetimes.len(), 2);
        assert!(toks.iter().any(|t| t.is_punct('>')));
    }

    #[test]
    fn numbers_do_not_eat_ranges_or_methods() {
        assert_eq!(
            texts("0..p 1.5 1.max(2) 1_000u64 1e-3 0x3f"),
            vec!["0", ".", ".", "p", "1.5", "1", ".", "max", "(", "2", ")", "1_000u64", "1e-3", "0x3f"]
        );
    }

    #[test]
    fn strings_hide_code() {
        let toks = lex(r#"let s = "unsafe { lock() }"; x"#);
        assert!(!toks.iter().any(|t| t.is_ident("unsafe")));
        assert!(!toks.iter().any(|t| t.is_ident("lock")));
        assert!(toks.iter().any(|t| t.is_ident("x")));
    }

    #[test]
    fn escaped_quotes_in_strings() {
        let toks = lex(r#""a\"b" c"#);
        assert_eq!(toks[0].kind, TokenKind::StrLit);
        assert_eq!(toks[0].text, r#""a\"b""#);
        assert!(toks[1].is_ident("c"));
    }

    #[test]
    fn line_tracking_spans() {
        let src = "a\n/* two\nlines */\nb \"multi\nline\"\nc";
        let toks = lex(src);
        assert_eq!((toks[0].line, toks[0].end_line), (1, 1)); // a
        assert_eq!((toks[1].line, toks[1].end_line), (2, 3)); // block comment
        assert_eq!(toks[2].line, 4); // b
        assert_eq!((toks[3].line, toks[3].end_line), (4, 5)); // string
        assert_eq!(toks[4].line, 6); // c
    }

    #[test]
    fn line_comment_stops_at_newline() {
        let toks = lex("x // SAFETY: fine\ny");
        assert_eq!(toks[1].kind, TokenKind::LineComment);
        assert_eq!(toks[1].text, "// SAFETY: fine");
        assert_eq!(toks[2].line, 2);
    }

    #[test]
    fn unterminated_literals_reach_eof() {
        assert_eq!(kinds("\"never closed"), vec![TokenKind::StrLit]);
        assert_eq!(kinds("/* never closed"), vec![TokenKind::BlockComment]);
        assert_eq!(kinds("r#\"never closed"), vec![TokenKind::StrLit]);
    }
}
