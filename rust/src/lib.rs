//! # sfm-screen
//!
//! A production-quality reproduction of **"Safe Element Screening for
//! Submodular Function Minimization"** (Zhang, Hong, Ma, Liu, Zhang —
//! ICML 2018) as a three-layer rust + JAX + Pallas stack.
//!
//! The library provides:
//!
//! * a family of submodular function oracles with a fast prefix-gain
//!   (greedy) path ([`submodular`]),
//! * the Lovász-extension bridge between SFM and the proximal problem
//!   pair (Q-P)/(Q-D) ([`lovasz`]),
//! * exact solvers for the min-norm-point problem on the base polytope:
//!   Fujishige–Wolfe and conditional gradient ([`solvers`]),
//! * the paper's contribution — the **IAES** safe element screening
//!   engine (rules AES-1/IES-1/AES-2/IES-2 and Algorithm 2) in
//!   [`screening`],
//! * a decomposable-function subsystem — `F = Σ_i F_i` with parallel
//!   per-component block prox solves feeding the same screening rules
//!   through the aggregated dual `y = Σ_i y_i ∈ B(F)` ([`decompose`]),
//! * an XLA/PJRT runtime that executes the AOT-compiled JAX/Pallas
//!   screening kernel from the rust hot path ([`runtime`]),
//! * workload generators reproducing the paper's experiments
//!   ([`workloads`]) and an experiment [`coordinator`], including a
//!   fault-isolated resident solve service with deadlines, cooperative
//!   cancellation, and panic containment ([`coordinator::serve`]).
//!
//! ## Quickstart
//!
//! ```
//! use sfm_screen::prelude::*;
//!
//! // Iwata's test function on |V| = 50.
//! let f = IwataFn::new(50);
//! let opts = IaesOptions::default();
//! let report = solve_sfm_with_screening(&f, &opts).unwrap();
//! let minimum = f.eval_ids(&report.minimizer);
//! assert!((minimum - report.minimum).abs() < 1e-6);
//! ```
//!
//! Python (JAX + Pallas) appears only at build time: `make artifacts`
//! lowers the screening kernel to HLO text once; the rust binary is
//! self-contained afterwards and falls back to a pure-rust screening
//! backend when artifacts are absent.

// Every unsafe operation must sit in an explicit `unsafe` block with its
// own `// SAFETY:` comment, even inside `unsafe fn` — enforced here and
// by the `safety-comment` rule of `sfm_lint` (see LINTS.md).
#![warn(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod brute;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod decompose;
pub mod linalg;
pub mod lovasz;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod screening;
pub mod solvers;
pub mod submodular;
pub mod testutil;
pub mod workloads;

/// Convenient re-exports of the most commonly used types.
pub mod prelude {
    pub use crate::decompose::{
        solve_decomposed, BlockProxSolver, Component, DecomposableFn, DecomposeOptions,
    };
    pub use crate::lovasz::{
        greedy_base_vertex, lovasz_value, vertex_from_order, ContractionMap,
        GreedyWorkspace,
    };
    pub use crate::coordinator::serve::{ServeCore, ServeHandle, ServeOptions};
    pub use crate::obs::{MetricsRegistry, TraceEvent, TraceSink, TraceSummary};
    pub use crate::runtime::cancel::{CancelReason, CancelToken};
    pub use crate::screening::iaes::{
        solve_sfm_with_screening, IaesEngine, IaesOptions, IaesReport, NumericFault,
    };
    pub use crate::screening::RuleSet;
    pub use crate::screening::parametric::RegularizationPath;
    pub use crate::solvers::frankwolfe::{FrankWolfe, FwOptions};
    pub use crate::solvers::minnorm::{MinNormOptions, MinNormPoint};
    pub use crate::solvers::queyranne::queyranne;
    pub use crate::solvers::{ProxSolver, SolverEvent};
    pub use crate::submodular::{
        concave_card::ConcaveCardFn,
        coverage::CoverageFn,
        cut::CutFn,
        facility::FacilityLocationFn,
        gaussian_mi::GaussianMiFn,
        iwata::IwataFn,
        kernel_cut::KernelCutFn,
        modular::ModularFn,
        scaled::ScaledFn,
        Submodular, SubmodularExt,
    };
    pub use crate::workloads::two_moons::TwoMoons;
}

/// Library version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
