//! Hand-rolled CLI (no `clap` in the offline environment).
//!
//! Grammar: `sfm-screen <command> [--key value | --flag]...`. Flags merge
//! over an optional `--config <file>` into a [`Config`], from which the
//! typed [`BenchConfig`] is built.

use crate::config::Config;
use crate::coordinator::jobs::BackendChoice;
use crate::coordinator::BenchConfig;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// Parsed command line.
#[derive(Clone, Debug)]
pub struct Cli {
    /// Subcommand (e.g. `table1`).
    pub command: String,
    /// Flag map (`--eps 1e-6` → `eps = 1e-6`; bare `--full` → `full = true`).
    pub flags: Config,
}

/// Boolean-valued flags that take no argument.
const BARE_FLAGS: &[&str] =
    &["full", "mi", "quiet", "help", "version", "json", "decompose", "allow-partial"];

/// Parse an argument vector (without argv[0]).
pub fn parse_args(args: &[String]) -> Result<Cli> {
    let mut command = String::new();
    let mut flags = Config::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if BARE_FLAGS.contains(&key) {
                flags.set(key, "true");
                i += 1;
            } else {
                let val = args
                    .get(i + 1)
                    .with_context(|| format!("flag --{key} needs a value"))?;
                flags.set(key, val.clone());
                i += 2;
            }
        } else if command.is_empty() {
            command = a.clone();
            i += 1;
        } else {
            bail!("unexpected positional argument `{a}`");
        }
    }
    if command.is_empty() {
        command = "help".into();
    }
    // Merge config file under explicit flags.
    if let Some(path) = flags.get("config").map(PathBuf::from) {
        let mut merged = Config::load(&path)?;
        merged.merge(&flags);
        flags = merged;
    }
    Ok(Cli { command, flags })
}

/// Build the typed bench configuration from parsed flags.
pub fn bench_config(flags: &Config) -> Result<BenchConfig> {
    let mut cfg = BenchConfig::default();
    if flags.get_bool("full", false)? {
        cfg = cfg.full();
    }
    cfg.sizes = flags.get_usize_list("sizes", &cfg.sizes)?;
    cfg.image_scale = flags.get_f64("image-scale", cfg.image_scale)?;
    cfg.eps = flags.get_f64("eps", cfg.eps)?;
    cfg.rho = flags.get_f64("rho", cfg.rho)?;
    cfg.seed = flags.get_u64("seed", cfg.seed)?;
    cfg.out_dir = PathBuf::from(flags.get_str("out-dir", &cfg.out_dir.to_string_lossy()));
    cfg.backend = BackendChoice::parse(&flags.get_str("backend", "auto"))?;
    cfg.use_mi = flags.get_bool("mi", cfg.use_mi)?;
    cfg.max_iters = flags.get_usize("max-iters", cfg.max_iters)?;
    cfg.solver = flags.get_str("solver", &cfg.solver);
    cfg.quiet = flags.get_bool("quiet", cfg.quiet)?;
    Ok(cfg)
}

/// Usage text.
pub const USAGE: &str = "\
sfm-screen — safe element screening for submodular function minimization
             (ICML 2018 reproduction; rust + JAX + Pallas via XLA/PJRT)

USAGE:
  sfm-screen <command> [flags]

COMMANDS:
  solve            solve one instance        (--workload two-moons|image1..5|iwata, --p, --rules, --json)
  serve            resident solve service: JobSpec JSON lines on stdin (and
                   --socket PATH), one response line per job on stdout;
                   answers {\"op\": \"stats\"} lines with the metrics registry
  trace-check      validate a solve --trace JSONL file (--file PATH)
  checkpoint-check validate a solve --checkpoint JSONL file (--file PATH)
  path             SFM' regularization path from one solve (--p)
  table1           Table 1: two-moons running times & speedups
  table3           Tables 2+3: image segmentation statistics & times
  fig2             Figure 2: rejection ratios on two-moons
  fig3             Figure 3: screening visualization (--p, default 400)
  fig4             Figure 4: rejection ratios on images
  decompose-bench  monolithic vs block-parallel decomposed solves (--threads-list 1,2,4)
  ablation-rho     ρ trigger-frequency sweep (Remark 5)
  ablation-rules   rule-pair contributions
  ablation-solver  min-norm vs conditional gradient (Remark 2)
  all              everything above, in order
  info             artifact/backend status
  help             this text

COMMON FLAGS:
  --config FILE    key = value config file (flags override)
  --sizes LIST     two-moons sizes, e.g. 100,200,400
  --image-scale X  image size multiplier (paper scale ≈ 4)
  --eps X          duality-gap accuracy (default 1e-6)
  --rho X          trigger decay (default 0.5)
  --seed N         workload seed
  --solver NAME    minnorm | fw | plain-fw
  --backend NAME   auto | rust | xla
  --out-dir DIR    CSV output directory (default bench_out)
  --full           paper-scale sizes
  --mi             exact GP mutual-information objective (slow)
  --decompose      solve via the decomposable block solver (solve command)
  --threads N      worker threads; default 0 = all available cores. With
                   --decompose: block-solver workers, capped by the
                   component count (reported as block_threads in --json).
                   Without: the pooled monolithic greedy oracle — passes
                   are bit-identical at every thread count (reported as
                   greedy_threads in --json)
  --threads-list L thread counts for decompose-bench, e.g. 1,2,4
  --quiet          suppress progress logs
  --allow-partial  solve: exit 0 even when the run stops before eps
                   (deadline/cancel/max_iters); default is a nonzero exit
  --trace PATH     solve: record boundary-sampled trace events and dump
                   them as JSON lines to PATH after the run (see
                   OBSERVABILITY.md; validate with trace-check)
  --trace-cap N    solve: trace ring capacity (default 4096); when full
                   the oldest events are overwritten, summaries stay exact
  --checkpoint PATH  solve: snapshot the solve at major-iteration
                   boundaries, atomically replacing PATH each time (see
                   RELIABILITY.md; validate with checkpoint-check)
  --checkpoint-every N  solve: snapshot cadence in boundaries (default 1)
  --resume PATH    solve: restart from a checkpoint instead of cold —
                   screened sets are re-installed and solver atoms
                   regenerated from their stored orders

SERVE FLAGS:
  --workers N      concurrent solve workers (default 0 = all cores)
  --queue-cap N    admission-queue capacity (default 64); overflow is
                   rejected with a structured queue_full response
  --deadline-ms N  default per-job deadline, overridable per request
                   via a `deadline_ms` field (cooperative: checked at
                   major-iteration boundaries; partial results stay safe)
  --oracle-threads N  greedy-oracle lanes per worker (default 1;
                   bit-identical at every lane count)
  --retries N      re-admit a panicked or numeric-faulted job up to N
                   times from its last in-memory boundary checkpoint
                   (default 0 = answer on the first failure)
  --retry-backoff-ms B  base backoff before a retry, doubled per attempt
                   and clamped to the job's original admission deadline
                   (default 100)
  --socket PATH    additional unix-socket ingress (responses per
                   connection)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let cli = parse_args(&v(&["table1", "--eps", "1e-4", "--full"])).unwrap();
        assert_eq!(cli.command, "table1");
        assert_eq!(cli.flags.get("eps"), Some("1e-4"));
        assert_eq!(cli.flags.get("full"), Some("true"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse_args(&v(&["solve", "--eps"])).is_err());
    }

    #[test]
    fn double_positional_errors() {
        assert!(parse_args(&v(&["a", "b"])).is_err());
    }

    #[test]
    fn empty_is_help() {
        assert_eq!(parse_args(&[]).unwrap().command, "help");
    }

    #[test]
    fn bench_config_from_flags() {
        let cli =
            parse_args(&v(&["table1", "--sizes", "10,20", "--rho", "0.3", "--quiet"])).unwrap();
        let cfg = bench_config(&cli.flags).unwrap();
        assert_eq!(cfg.sizes, vec![10, 20]);
        assert_eq!(cfg.rho, 0.3);
        assert!(cfg.quiet);
    }

    #[test]
    fn full_flag_rescales() {
        let cli = parse_args(&v(&["table1", "--full"])).unwrap();
        let cfg = bench_config(&cli.flags).unwrap();
        assert_eq!(cfg.sizes, vec![200, 400, 600, 800, 1000]);
        assert_eq!(cfg.image_scale, 4.0);
    }
}
