//! Per-component block prox subproblems.
//!
//! One best-response step of the block solver fixes every other
//! component and solves, for component `i` with offset `z = Σ_{j≠i} y_j`
//! restricted to `S_i`,
//!
//! ```text
//! y_i ← argmin_{y ∈ B(F̂_i)} ½‖y + z‖².
//! ```
//!
//! Substituting `u = y + z` and using `B(F̂_i) + z = B(F̂_i + m_z)` (a
//! modular shift translates the base polytope), this is the plain
//! min-norm-point problem on the shifted polytope — [`OffsetFn`] is that
//! shift as a zero-cost oracle wrapper, solved by the existing
//! Fujishige–Wolfe solver. For concave-of-cardinality components the
//! problem has a closed form via isotonic regression
//! ([`card_prox_into`]), for chain (path-cut) components via the O(s)
//! taut-string total-variation prox ([`super::chain::tv_prox_into`] —
//! grid workloads never touch the min-norm solver), and for modular
//! components `B` is a single point, so no solve happens at all.

use crate::linalg::vecops::argsort_desc_into;
use crate::solvers::pav::PavWorkspace;
use crate::submodular::{OracleScratch, Submodular};

/// `G = F + m` for a modular `m`: the oracle whose base polytope is
/// `B(F) + m`. Zero-cost wrapper — gains are the inner gains plus the
/// per-element offset, so the greedy pass stays allocation-free.
pub struct OffsetFn<'a> {
    inner: &'a dyn Submodular,
    offset: &'a [f64],
}

impl<'a> OffsetFn<'a> {
    /// Wrap `inner` with the modular shift `offset` (one weight per
    /// element of `inner`'s ground set).
    pub fn new(inner: &'a dyn Submodular, offset: &'a [f64]) -> Self {
        assert_eq!(inner.ground_size(), offset.len());
        OffsetFn { inner, offset }
    }
}

impl Submodular for OffsetFn<'_> {
    fn ground_size(&self) -> usize {
        self.inner.ground_size()
    }

    fn eval(&self, set: &[bool]) -> f64 {
        let shift: f64 = set
            .iter()
            .zip(self.offset)
            .filter(|(&b, _)| b)
            .map(|(_, &m)| m)
            .sum();
        self.inner.eval(set) + shift
    }

    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        let mut scratch = OracleScratch::new();
        self.prefix_gains_scratch(base, order, out, &mut scratch);
    }

    fn prefix_gains_scratch(
        &self,
        base: &[bool],
        order: &[usize],
        out: &mut [f64],
        scratch: &mut OracleScratch,
    ) {
        self.inner.prefix_gains_scratch(base, order, out, scratch);
        for (o, &j) in out.iter_mut().zip(order) {
            *o += self.offset[j];
        }
    }
}

/// Reusable buffers for [`card_prox_into`] (one per worker arena).
#[derive(Clone, Debug, Default)]
pub struct CardProxWorkspace {
    /// Projection target `t = −(z + m̂)`.
    t: Vec<f64>,
    /// Ladder-shifted targets `t_σ − ĉ` (PAV input).
    shifted: Vec<f64>,
    /// PAV fit.
    fit: Vec<f64>,
    /// Descending argsort of `t`.
    order: Vec<usize>,
    /// PAV block stack.
    pav: PavWorkspace,
}

impl CardProxWorkspace {
    /// Pre-size for components up to support size `n` (see
    /// [`TautStringWorkspace::reserve`](super::chain::TautStringWorkspace::reserve)
    /// for why the block solver sizes worker arenas up front).
    pub fn reserve(&mut self, n: usize) {
        self.t.reserve(n);
        self.shifted.reserve(n);
        self.fit.reserve(n);
        self.order.reserve(n);
        self.pav.reserve(n);
    }
}

/// Closed-form block prox of a cardinality component:
///
/// ```text
/// y* = argmin ½‖y + z‖²  over  y ∈ B(ĝ∘card + m̂)
/// ```
///
/// where `ĝ(k) = g(b + k) − g(b)` is the Lemma-1 contraction of the
/// tabulated concave `g` by the component's `b = |Ê ∩ S_i|` certified
/// elements — the ladder `ĉ_k = g[b+k] − g[b+k−1]` is just a window of
/// the full ladder, so the closed form survives IAES contractions.
///
/// Derivation (Bach 2013, §9.1): `B(ĝ∘card)` is the permutohedron of the
/// non-increasing ladder `ĉ`, and `B(ĝ∘card + m̂) = B(ĝ∘card) + m̂`.
/// Substituting `y = y° + m̂`, `t = −(z + m̂)` leaves the Euclidean
/// projection of `t` onto the permutohedron. The projection shares `t`'s
/// descending order `σ` (rearrangement), and writing `x_k = w_{σ_k}` for
/// the prox primal, the problem separates into
/// `min Σ_k ½(x_k − (t_{σ_k} − ĉ_k))²` subject to `x` non-increasing —
/// exactly the non-increasing isotonic regression solved by PAV. The
/// dual point is then `y°_{σ_k} = t_{σ_k} − x_k` (block sums telescope to
/// prefix sums of `ĉ`, so feasibility holds with equality on pooled
/// blocks).
///
/// Writes `y*` into `y_out` (length `n = z.len()`), allocation-free once
/// `ws` reached working size. Ties in `t` break by index (the shared
/// deterministic argsort), so the result is identical for any caller
/// schedule.
pub fn card_prox_into(
    g: &[f64],
    base_count: usize,
    mhat: &[f64],
    z: &[f64],
    ws: &mut CardProxWorkspace,
    y_out: &mut [f64],
) {
    let n = z.len();
    assert_eq!(mhat.len(), n);
    assert_eq!(y_out.len(), n);
    assert!(base_count + n < g.len(), "ladder window out of range");
    ws.t.clear();
    ws.t.extend(z.iter().zip(mhat).map(|(&zk, &mk)| -(zk + mk)));
    argsort_desc_into(&ws.t, &mut ws.order);
    ws.shifted.clear();
    ws.shifted.extend(ws.order.iter().enumerate().map(|(k, &j)| {
        let c_k = g[base_count + k + 1] - g[base_count + k];
        ws.t[j] - c_k
    }));
    ws.fit.clear();
    ws.fit.resize(n, 0.0);
    ws.pav.run(&ws.shifted, &mut ws.fit);
    for (k, &j) in ws.order.iter().enumerate() {
        y_out[j] = ws.t[j] - ws.fit[k] + mhat[j];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lovasz::in_base_polytope;
    use crate::rng::Pcg64;
    use crate::solvers::minnorm::{MinNormOptions, MinNormPoint};
    use crate::solvers::ProxSolver;
    use crate::submodular::concave_card::ConcaveCardFn;
    use crate::submodular::iwata::IwataFn;
    use crate::submodular::scaled::ScaledFn;
    use crate::testutil::forall_rng;

    /// Reference block prox via the min-norm solver on the shifted
    /// polytope: `u* = argmin ½‖u‖² over B(F + m_z)`, `y* = u* − z`.
    fn minnorm_block_prox(f: &dyn Submodular, z: &[f64]) -> Vec<f64> {
        let shifted = OffsetFn::new(f, z);
        let mut solver = MinNormPoint::new(&shifted, MinNormOptions::default(), None);
        for _ in 0..5000 {
            let ev = solver.step(&shifted);
            if ev.wolfe_gap <= 1e-13 {
                break;
            }
        }
        solver.s().iter().zip(z).map(|(&u, &zk)| u - zk).collect()
    }

    #[test]
    fn offset_fn_shifts_base_polytope() {
        let f = IwataFn::new(7);
        let mut rng = Pcg64::seeded(71);
        let z = rng.uniform_vec(7, -1.0, 1.0);
        let shifted = OffsetFn::new(&f, &z);
        // B(F + m_z) = B(F) + z: greedy vertices shift coordinate-wise.
        let w = rng.normal_vec(7);
        let mut ws = crate::lovasz::GreedyWorkspace::new(7);
        let mut s0 = vec![0.0; 7];
        let mut s1 = vec![0.0; 7];
        crate::lovasz::greedy_base_vertex(&f, &w, &mut ws, &mut s0);
        crate::lovasz::greedy_base_vertex(&shifted, &w, &mut ws, &mut s1);
        for j in 0..7 {
            assert!((s1[j] - (s0[j] + z[j])).abs() < 1e-12);
        }
    }

    #[test]
    fn card_prox_matches_minnorm() {
        forall_rng(20, |rng| {
            let n = 2 + rng.below(8);
            let scale = rng.uniform(0.3, 2.0);
            let g: Vec<f64> = (0..=n).map(|k| scale * (k as f64).sqrt()).collect();
            let m = rng.uniform_vec(n, -1.0, 1.0);
            let z = rng.uniform_vec(n, -1.5, 1.5);
            let f = ConcaveCardFn::new(g.clone(), m.clone());
            let mut ws = CardProxWorkspace::default();
            let mut y = vec![0.0; n];
            card_prox_into(&g, 0, &m, &z, &mut ws, &mut y);
            // Feasible in B(F)…
            if !in_base_polytope(&f, &y, 1e-8) {
                return Err("card prox left the base polytope".into());
            }
            // …and equal to the min-norm reference on the shifted polytope.
            let y_ref = minnorm_block_prox(&f, &z);
            for k in 0..n {
                if (y[k] - y_ref[k]).abs() > 1e-6 {
                    return Err(format!(
                        "coord {k}: pav {} vs minnorm {}",
                        y[k], y_ref[k]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn card_prox_reduced_window_matches_scaled_minnorm() {
        // The Lemma-1 contraction of g∘card + m is ĝ∘card + m̂ with the
        // ladder window shifted by the base count: the closed form on the
        // window must match the min-norm solve of the ScaledFn.
        forall_rng(12, |rng| {
            let s = 6 + rng.below(5);
            let scale = rng.uniform(0.3, 1.5);
            let g: Vec<f64> = (0..=s).map(|k| scale * (k as f64).sqrt()).collect();
            let m = rng.uniform_vec(s, -1.0, 1.0);
            let f = ConcaveCardFn::new(g.clone(), m.clone());
            // Split: element 0 active, last element inactive, rest kept.
            let active = vec![0usize];
            let kept: Vec<usize> = (1..s - 1).collect();
            let scaled = ScaledFn::new(&f, &active, kept.clone());
            let n = kept.len();
            let z = rng.uniform_vec(n, -1.0, 1.0);
            let mhat: Vec<f64> = kept.iter().map(|&l| m[l]).collect();
            let mut ws = CardProxWorkspace::default();
            let mut y = vec![0.0; n];
            card_prox_into(&g, active.len(), &mhat, &z, &mut ws, &mut y);
            if !in_base_polytope(&scaled, &y, 1e-8) {
                return Err("reduced card prox infeasible".into());
            }
            let y_ref = minnorm_block_prox(&scaled, &z);
            for k in 0..n {
                if (y[k] - y_ref[k]).abs() > 1e-6 {
                    return Err(format!(
                        "reduced coord {k}: pav {} vs minnorm {}",
                        y[k], y_ref[k]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn modular_offset_prox_is_constant() {
        // For a modular component the pav path degenerates to y = m̂
        // (zero ladder): sanity-check the formula's modular limit.
        let n = 6;
        let g = vec![0.0; n + 1];
        let mut rng = Pcg64::seeded(99);
        let m = rng.uniform_vec(n, -1.0, 1.0);
        let z = rng.uniform_vec(n, -2.0, 2.0);
        let mut ws = CardProxWorkspace::default();
        let mut y = vec![0.0; n];
        card_prox_into(&g, 0, &m, &z, &mut ws, &mut y);
        for k in 0..n {
            assert!((y[k] - m[k]).abs() < 1e-12, "modular limit broken at {k}");
        }
    }
}
