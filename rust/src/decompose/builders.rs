//! Workload decompositions: turning the repo's objectives into
//! `F = Σ_i F_i`.
//!
//! * **Grid cuts** (§4.2 images): an `h × w` pixel grid's pairwise term
//!   splits by edge direction into vertex-disjoint *chains* — one per
//!   row, column, diagonal, and anti-diagonal — plus one modular unary
//!   component ([`grid_cut_components`]). Every chain is emitted as a
//!   [`ComponentKind::Chain`](super::ComponentKind::Chain) (taut-string
//!   closed-form block prox, no min-norm solver), and the chains of one
//!   direction are support-disjoint, so the builder annotates one
//!   scheduling *group* per family (plus the unary term): the block
//!   solver sweeps the groups with exact simultaneous Gauss–Seidel
//!   instead of damping everything through one Jacobi line search.
//! * **Kernel cuts** (§4.1 two-moons, dense or kNN-sparsified): the
//!   pairwise sum groups into per-point *stars* — component `i` carries
//!   every edge `{i, j}` with `j > i` ([`star_components`],
//!   [`star_components_from_edges`]) — plus the modular label term.
//!
//! Every builder reproduces the original objective exactly
//! (`Σ_i F_i = F` term by term), which the equivalence tests enforce
//! against the monolithic oracles.

use super::{Component, DecomposableFn};
use crate::submodular::cut::CutFn;
use anyhow::{bail, Result};

/// Build one chain/star component from a global edge list: the support is
/// the sorted set of endpoint ids, the oracle a zero-unary [`CutFn`] on
/// the local ground set.
fn cut_component(edges: &[(usize, usize, f64)]) -> Component {
    let mut support: Vec<usize> = Vec::with_capacity(2 * edges.len());
    for &(a, b, _) in edges {
        support.push(a);
        support.push(b);
    }
    support.sort_unstable();
    support.dedup();
    let local_id = |v: usize| {
        support.binary_search(&v).expect("endpoint must be in the support")
    };
    let local: Vec<(usize, usize, f64)> = edges
        .iter()
        .map(|&(a, b, w)| (local_id(a), local_id(b), w))
        .collect();
    let f = CutFn::from_edges(support.len(), &local, vec![0.0; support.len()]);
    Component::generic(Box::new(f), support)
}

/// Build one *chain* component from a bucket of path edges (all steps of
/// one grid chain, `a < b` each). Sorting the endpoints puts them in path
/// order — every grid family walks the chain in ascending vertex id, so
/// each edge joins consecutive support entries; gaps (missing grid edges)
/// become zero-weight chain edges, which decouple exactly. Duplicate
/// edges accumulate, matching the parallel-edge semantics of [`CutFn`].
fn chain_component(edges: &[(usize, usize, f64)]) -> Component {
    let mut support: Vec<usize> = Vec::with_capacity(2 * edges.len());
    for &(a, b, _) in edges {
        debug_assert!(a < b);
        support.push(a);
        support.push(b);
    }
    support.sort_unstable();
    support.dedup();
    let mut w = vec![0.0; support.len() - 1];
    for &(a, b, wt) in edges {
        let k = support.binary_search(&a).expect("endpoint in support");
        assert_eq!(
            support[k + 1],
            b,
            "edge ({a},{b}) is not a step of this chain"
        );
        w[k] += wt;
    }
    Component::chain(w, support)
}

/// Decompose an `h × w` grid cut `u(A) + Σ d(i,j)` into direction-grouped
/// chain components plus one modular unary component.
///
/// Accepted edge directions (vertices row-major, `id = r·w + c`):
/// horizontal `(0,1)` → row chains, vertical `(1,0)` → column chains,
/// down-right `(1,1)` → diagonal chains, down-left `(1,−1)` →
/// anti-diagonal chains — i.e. exactly the repo's 4- and 8-neighbor
/// grids. Any other edge is an error.
pub fn grid_cut_components(
    h: usize,
    w: usize,
    edges: &[(usize, usize, f64)],
    unary: Vec<f64>,
) -> Result<DecomposableFn> {
    let p = h * w;
    assert_eq!(unary.len(), p);
    // Chain buckets per family, indexed by chain key.
    let mut rows: Vec<Vec<(usize, usize, f64)>> = vec![Vec::new(); h];
    let mut cols: Vec<Vec<(usize, usize, f64)>> = vec![Vec::new(); w];
    let diag_keys = (h + w).saturating_sub(1);
    let mut diags: Vec<Vec<(usize, usize, f64)>> = vec![Vec::new(); diag_keys];
    let mut antis: Vec<Vec<(usize, usize, f64)>> = vec![Vec::new(); diag_keys];
    for &(a, b, wt) in edges {
        anyhow::ensure!(a < p && b < p, "edge ({a},{b}) out of the {h}x{w} grid");
        let (i, j) = (a.min(b), a.max(b));
        let (ri, ci) = (i / w, i % w);
        let (rj, cj) = (j / w, j % w);
        let e = (i, j, wt);
        if ri == rj && cj == ci + 1 {
            rows[ri].push(e);
        } else if ci == cj && rj == ri + 1 {
            cols[ci].push(e);
        } else if rj == ri + 1 && cj == ci + 1 {
            diags[ci + (h - 1) - ri].push(e); // constant c − r, offset to ≥ 0
        } else if rj == ri + 1 && cj + 1 == ci {
            antis[ri + ci].push(e); // constant r + c
        } else {
            bail!("edge ({a},{b}) is not a grid-neighbor edge");
        }
    }
    // One chain component per non-empty bucket; one scheduling group per
    // non-empty family (chains of one direction are vertex-disjoint), and
    // the unary term is its own group — together the groups cover every
    // component, so grid rounds are pure Gauss–Seidel.
    let mut comps = Vec::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for family in [&rows, &cols, &diags, &antis] {
        let mut members = Vec::new();
        for chain in family {
            if !chain.is_empty() {
                members.push(comps.len());
                comps.push(chain_component(chain));
            }
        }
        if !members.is_empty() {
            groups.push(members);
        }
    }
    groups.push(vec![comps.len()]);
    comps.push(Component::modular(unary, (0..p).collect()));
    Ok(DecomposableFn::with_groups(p, comps, groups))
}

/// Decompose an arbitrary symmetric cut from an edge list into per-point
/// star components (edge `{i, j}` with `i < j` lands in star `i`) plus
/// one modular unary component. Works for the kNN two-moons objective
/// and any other sparse cut.
pub fn star_components_from_edges(
    p: usize,
    edges: &[(usize, usize, f64)],
    unary: Vec<f64>,
) -> DecomposableFn {
    assert_eq!(unary.len(), p);
    let mut stars: Vec<Vec<(usize, usize, f64)>> = vec![Vec::new(); p];
    for &(a, b, w) in edges {
        assert!(a < p && b < p && a != b, "bad edge ({a},{b})");
        let (i, j) = (a.min(b), a.max(b));
        stars[i].push((i, j, w));
    }
    let mut comps = Vec::new();
    for star in &stars {
        if !star.is_empty() {
            comps.push(cut_component(star));
        }
    }
    comps.push(Component::modular(unary, (0..p).collect()));
    DecomposableFn::new(p, comps)
}

/// Star decomposition of a *dense* symmetric kernel cut given as a weight
/// closure (`weight(i, j)` with `i < j`; zero weights are skipped).
pub fn star_components(
    p: usize,
    weight: impl Fn(usize, usize) -> f64,
    unary: Vec<f64>,
) -> DecomposableFn {
    let mut edges = Vec::new();
    for i in 0..p {
        for j in (i + 1)..p {
            let w = weight(i, j);
            if w > 0.0 {
                edges.push((i, j, w));
            }
        }
    }
    star_components_from_edges(p, &edges, unary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::submodular::kernel_cut::KernelCutFn;
    use crate::submodular::Submodular;
    use crate::workloads::grid::{eight_neighbor_edges, four_neighbor_edges};

    fn compare_on_random_sets(
        dec: &DecomposableFn,
        mono: &dyn Submodular,
        seed: u64,
        trials: usize,
    ) {
        let p = mono.ground_size();
        assert_eq!(dec.ground_size(), p);
        let mut rng = Pcg64::seeded(seed);
        for _ in 0..trials {
            let set: Vec<bool> = (0..p).map(|_| rng.bernoulli(0.5)).collect();
            let a = dec.eval(&set);
            let b = mono.eval(&set);
            assert!(
                (a - b).abs() < 1e-9 * (1.0 + b.abs()),
                "decomposed {a} vs monolithic {b}"
            );
        }
    }

    #[test]
    fn grid_decomposition_matches_monolithic_cut() {
        let (h, w) = (5, 6);
        let mut rng = Pcg64::seeded(11);
        for edges_raw in [eight_neighbor_edges(h, w), four_neighbor_edges(h, w)] {
            let edges: Vec<(usize, usize, f64)> = edges_raw
                .iter()
                .map(|&(a, b)| (a, b, rng.uniform(0.0, 1.5)))
                .collect();
            let unary = rng.uniform_vec(h * w, -1.0, 1.0);
            let mono = CutFn::from_edges(h * w, &edges, unary.clone());
            let dec = grid_cut_components(h, w, &edges, unary).unwrap();
            compare_on_random_sets(&dec, &mono, 12, 30);
        }
    }

    #[test]
    fn grid_rejects_non_grid_edges() {
        let edges = vec![(0usize, 5usize, 1.0)]; // (0,0) → (1,2) on a 3x3
        assert!(grid_cut_components(3, 3, &edges, vec![0.0; 9]).is_err());
    }

    #[test]
    fn grid_chains_are_closed_form_and_fully_grouped() {
        // Acceptance criterion: no grid component goes down the generic
        // (min-norm) block-prox path, and the builder's groups cover every
        // component so grid rounds are pure Gauss–Seidel.
        use crate::decompose::ComponentKind;
        let (h, w) = (4, 5);
        let mut rng = Pcg64::seeded(77);
        let edges: Vec<(usize, usize, f64)> = eight_neighbor_edges(h, w)
            .iter()
            .map(|&(a, b)| (a, b, rng.uniform(0.0, 1.0)))
            .collect();
        let dec =
            grid_cut_components(h, w, &edges, rng.uniform_vec(h * w, -1.0, 1.0)).unwrap();
        for c in dec.components() {
            assert!(
                matches!(c.kind(), ComponentKind::Chain { .. } | ComponentKind::Modular { .. }),
                "grid component is not closed-form"
            );
        }
        // 4 families (rows, cols, diags, antis) + the unary group.
        assert_eq!(dec.num_groups(), 5);
        assert!(dec.ungrouped().is_empty(), "grid must be fully grouped");
        let grouped: usize = (0..dec.num_groups()).map(|g| dec.group(g).len()).sum();
        assert_eq!(grouped, dec.num_components());
    }

    #[test]
    fn grid_chain_with_missing_edges_still_matches() {
        // A sparse subset of the grid edges leaves gaps inside chains
        // (zero-weight chain links): the decomposition must still match
        // the monolithic cut exactly.
        let (h, w) = (4, 4);
        let mut rng = Pcg64::seeded(31);
        let edges: Vec<(usize, usize, f64)> = eight_neighbor_edges(h, w)
            .into_iter()
            .filter(|_| rng.bernoulli(0.6))
            .map(|(a, b)| (a, b, rng.uniform(0.0, 1.5)))
            .collect();
        let unary = rng.uniform_vec(h * w, -1.0, 1.0);
        let mono = CutFn::from_edges(h * w, &edges, unary.clone());
        let dec = grid_cut_components(h, w, &edges, unary).unwrap();
        compare_on_random_sets(&dec, &mono, 32, 40);
    }

    #[test]
    fn star_decomposition_matches_dense_kernel_cut() {
        let p = 9;
        let mut rng = Pcg64::seeded(13);
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let unary = rng.uniform_vec(p, -2.0, 2.0);
        let mono = KernelCutFn::new(p, k.clone(), unary.clone());
        let dec = star_components(p, |i, j| k[i * p + j], unary);
        compare_on_random_sets(&dec, &mono, 14, 30);
        // p stars (all rows have at least one positive weight) + unary.
        assert_eq!(dec.num_components(), p);
    }

    #[test]
    fn sparse_star_decomposition_matches_cut() {
        let p = 12;
        let mut rng = Pcg64::seeded(15);
        let mut edges = Vec::new();
        for i in 0..p {
            for j in (i + 1)..p {
                if rng.bernoulli(0.3) {
                    edges.push((i, j, rng.uniform(0.0, 2.0)));
                }
            }
        }
        let unary = rng.uniform_vec(p, -1.0, 1.0);
        let mono = CutFn::from_edges(p, &edges, unary.clone());
        let dec = star_components_from_edges(p, &edges, unary);
        compare_on_random_sets(&dec, &mono, 16, 30);
    }
}
