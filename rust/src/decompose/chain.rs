//! Direct O(s) taut-string prox for chain (path-cut / total-variation)
//! components, with exact base-polytope dual recovery.
//!
//! A chain component is a path cut `F(A) = Σ_k λ_k · 1[{k, k+1} cut]`
//! whose Lovász extension is the weighted total variation
//! `f(x) = Σ_k λ_k |x_{k+1} − x_k|`. The block best response of such a
//! component,
//!
//! ```text
//! y* = argmin_{y ∈ B(F)} ½‖y − t‖²  (the projection of t onto B(F)),
//! ```
//!
//! has a closed form via the Moreau decomposition: `t = prox_f(t) + Π_B(t)`
//! because `f` is the support function of `B(F)`, so
//!
//! ```text
//! y* = t − x*,   x* = argmin_x ½‖x − t‖² + Σ_k λ_k |x_{k+1} − x_k|.
//! ```
//!
//! `x*` is the weighted 1-D total-variation denoising (fused-lasso signal)
//! problem, solved exactly in O(s) amortized by the taut-string dynamic
//! program below ([`tv_prox_into`]): the derivative of the forward value
//! function is a monotone piecewise-linear map clipped to `±λ_k` at every
//! edge (Bach 2013 §8; Johnson 2013; Condat 2013). The dual `y* = t − x*`
//! is read off the bending points for free — where the string is taut the
//! flow sits at `±λ_k`, between bends it follows the clipped derivative.
//! Feasibility (`y* ∈ B(F)`) is exact by the flow representation of the
//! path-cut base polytope: `y*_k = u_{k−1} − u_k` with `|u_k| ≤ λ_k`.
//!
//! Because a modular shift only *translates* the base polytope
//! (`B(F + m) = B(F) + m`) and the Lemma-1 contraction of a path cut is
//! again a path cut on the surviving subsequence plus a boundary modular
//! term (fixed-active neighbor ⇒ `−λ`, fixed-inactive neighbor ⇒ `+λ`,
//! gap between surviving non-adjacent nodes ⇒ a zero-weight edge), the
//! closed form survives `ScaledFn` reductions the same way
//! [`card_prox_into`](super::prox::card_prox_into)'s ladder-window form
//! does — the block solver rebuilds the reduced `(λ̂, m̂_b)` pair once per
//! contraction and every subsequent best response is a single
//! [`tv_prox_into`] call.

/// Reusable buffers for [`tv_prox_into`] (one per worker arena).
///
/// The knot deque (`xs`/`ss`) is the piecewise-linear derivative of the
/// forward value function; `tm`/`tp` are the per-edge clamp back-pointers.
#[derive(Clone, Debug, Default)]
pub struct TautStringWorkspace {
    /// Knot positions (deque storage, capacity `2n + 2`).
    xs: Vec<f64>,
    /// Slope deltas at the knots, parallel to `xs`.
    ss: Vec<f64>,
    /// Lower clamp per edge (`d = −λ_k` crossing).
    tm: Vec<f64>,
    /// Upper clamp per edge (`d = +λ_k` crossing).
    tp: Vec<f64>,
}

impl TautStringWorkspace {
    /// Pre-size for chains up to length `n`. The block solver reserves
    /// every worker arena for the *largest* component up front, so
    /// work-stealing schedules can never trigger a first-touch resize on
    /// a worker thread mid-run (the t = 4 zero-allocation certification
    /// depends on this being deterministic, not schedule-dependent).
    pub fn reserve(&mut self, n: usize) {
        self.xs.reserve(2 * n + 2);
        self.ss.reserve(2 * n + 2);
        self.tm.reserve(n);
        self.tp.reserve(n);
    }
}

/// Weighted 1-D total-variation prox (taut string / clipped-derivative
/// dynamic program):
///
/// ```text
/// x_out = argmin_x  Σ_k ½(x_k − t_k)² + Σ_k lam_k |x_{k+1} − x_k|
/// ```
///
/// `lam` has one nonnegative weight per consecutive pair (`lam.len() ==
/// t.len() − 1`); a zero weight decouples the chain at that edge exactly.
/// O(n) amortized — each forward step inserts two knots and every knot is
/// removed at most once — and allocation-free once `ws` reached working
/// size. Deterministic: no tolerances, ties resolved by the clamp order.
///
/// The block-prox dual is recovered as `y_k = t_k − x_out_k` (see the
/// module docs); callers that need it apply the subtraction in place.
pub fn tv_prox_into(t: &[f64], lam: &[f64], ws: &mut TautStringWorkspace, x_out: &mut [f64]) {
    let n = t.len();
    assert_eq!(x_out.len(), n);
    if n == 0 {
        return;
    }
    assert_eq!(lam.len(), n - 1, "one weight per consecutive pair");
    if n == 1 {
        x_out[0] = t[0];
        return;
    }
    let cap = 2 * n + 2;
    ws.xs.clear();
    ws.xs.resize(cap, 0.0);
    ws.ss.clear();
    ws.ss.resize(cap, 0.0);
    ws.tm.clear();
    ws.tm.resize(n - 1, 0.0);
    ws.tp.clear();
    ws.tp.resize(n - 1, 0.0);
    let (xs, ss) = (&mut ws.xs[..], &mut ws.ss[..]);
    // Empty deque convention: head > tail. Knots inserted from the middle
    // out — each forward step front-pushes one lower clamp knot and
    // back-pushes one upper clamp knot, so `n` front slots suffice.
    let mut head = n;
    let mut tail = n - 1;
    // Leftmost / rightmost affine pieces of the derivative d(x); every
    // interior piece slope is ≥ 1 (each step adds a unit-slope quadratic
    // term to a nondecreasing clipped function), so the clamp-root
    // divisions below are always well-posed.
    let (mut a0, mut b0) = (1.0, -t[0]);
    let (mut an, mut bn) = (1.0, -t[0]);
    for k in 0..n - 1 {
        let lm = lam[k];
        debug_assert!(lm >= 0.0, "negative TV weight");
        // Lower clamp: first crossing of d(x) = −λ, scanning pieces from
        // the left and absorbing knots the clip swallows.
        let (mut a, mut b) = (a0, b0);
        while head <= tail && a * xs[head] + b < -lm {
            a += ss[head];
            b -= ss[head] * xs[head];
            head += 1;
        }
        let tm = (-lm - b) / a;
        // Upper clamp: first crossing of d(x) = +λ from the right.
        let (mut ar, mut br) = (an, bn);
        while head <= tail && ar * xs[tail] + br > lm {
            ar -= ss[tail];
            br += ss[tail] * xs[tail];
            tail -= 1;
        }
        let tp = (lm - br) / ar;
        // The clipped derivative is −λ left of `tm`, d between, +λ right
        // of `tp`: push the two bend knots, then add the next data term
        // (slope-1 quadratic) to both boundary pieces.
        head -= 1;
        xs[head] = tm;
        ss[head] = a;
        tail += 1;
        xs[tail] = tp;
        ss[tail] = -ar;
        a0 = 1.0;
        b0 = -lm - t[k + 1];
        an = 1.0;
        bn = lm - t[k + 1];
        ws.tm[k] = tm;
        ws.tp[k] = tp;
    }
    // Root of the final derivative, then clamp back through the bends.
    let (mut a, mut b) = (a0, b0);
    while head <= tail && a * xs[head] + b < 0.0 {
        a += ss[head];
        b -= ss[head] * xs[head];
        head += 1;
    }
    x_out[n - 1] = -b / a;
    for k in (0..n - 1).rev() {
        // min-then-max instead of `clamp`: a zero-weight edge can leave
        // `tm` a hair above `tp` in floating point, which `f64::clamp`
        // would panic on; this order resolves the tie deterministically.
        x_out[k] = x_out[k + 1].min(ws.tp[k]).max(ws.tm[k]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::prox::OffsetFn;
    use crate::lovasz::in_base_polytope;
    use crate::rng::Pcg64;
    use crate::solvers::minnorm::{MinNormOptions, MinNormPoint};
    use crate::solvers::ProxSolver;
    use crate::submodular::cut::CutFn;
    use crate::submodular::Submodular;
    use crate::testutil::forall_rng;

    fn chain_cut(lam: &[f64]) -> CutFn {
        let n = lam.len() + 1;
        let edges: Vec<(usize, usize, f64)> =
            lam.iter().enumerate().map(|(k, &w)| (k, k + 1, w)).collect();
        CutFn::from_edges(n, &edges, vec![0.0; n])
    }

    fn tv_objective(x: &[f64], t: &[f64], lam: &[f64]) -> f64 {
        let mut v = 0.0;
        for (xi, ti) in x.iter().zip(t) {
            v += 0.5 * (xi - ti) * (xi - ti);
        }
        for (k, &l) in lam.iter().enumerate() {
            v += l * (x[k + 1] - x[k]).abs();
        }
        v
    }

    /// Exact optimality certificate: the edge flows
    /// `u_k = u_{k−1} + (x_k − t_k)` must satisfy `|u_k| ≤ λ_k`, hit the
    /// bound with the matching sign wherever `x` jumps, and telescope to
    /// zero at the last element.
    fn kkt_holds(x: &[f64], t: &[f64], lam: &[f64], tol: f64) -> Result<(), String> {
        let n = t.len();
        let mut u = 0.0;
        for k in 0..n {
            u += x[k] - t[k];
            if k < n - 1 {
                if u.abs() > lam[k] + tol {
                    return Err(format!("edge {k}: |u| = {} > λ = {}", u.abs(), lam[k]));
                }
                let d = x[k + 1] - x[k];
                if d > tol && u < lam[k] - tol {
                    return Err(format!("edge {k}: up-jump but u = {u} ≠ λ"));
                }
                if d < -tol && u > -lam[k] + tol {
                    return Err(format!("edge {k}: down-jump but u = {u} ≠ −λ"));
                }
            } else if u.abs() > tol {
                return Err(format!("terminal flow {u} ≠ 0"));
            }
        }
        Ok(())
    }

    #[test]
    fn taut_string_satisfies_kkt_on_random_chains() {
        forall_rng(60, |rng| {
            let n = 1 + rng.below(40);
            let t = rng.uniform_vec(n, -3.0, 3.0);
            let lam: Vec<f64> = (0..n.saturating_sub(1))
                .map(|_| if rng.bernoulli(0.2) { 0.0 } else { rng.uniform(0.0, 2.0) })
                .collect();
            let mut ws = TautStringWorkspace::default();
            let mut x = vec![0.0; n];
            tv_prox_into(&t, &lam, &mut ws, &mut x);
            kkt_holds(&x, &t, &lam, 1e-8)?;
            // No nearby point beats it (convexity makes this a real check).
            let base = tv_objective(&x, &t, &lam);
            for _ in 0..10 {
                let xp: Vec<f64> =
                    x.iter().map(|&v| v + rng.uniform(-0.05, 0.05)).collect();
                if tv_objective(&xp, &t, &lam) < base - 1e-9 {
                    return Err("perturbation beat the taut string".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn recovered_dual_is_projection_onto_chain_base_polytope() {
        forall_rng(30, |rng| {
            let n = 2 + rng.below(7);
            let t = rng.uniform_vec(n, -2.5, 2.5);
            let lam: Vec<f64> = (0..n - 1).map(|_| rng.uniform(0.0, 2.0)).collect();
            let f = chain_cut(&lam);
            let mut ws = TautStringWorkspace::default();
            let mut x = vec![0.0; n];
            tv_prox_into(&t, &lam, &mut ws, &mut x);
            let y: Vec<f64> = t.iter().zip(&x).map(|(&ti, &xi)| ti - xi).collect();
            if !in_base_polytope(&f, &y, 1e-8) {
                return Err("recovered dual left B(F)".into());
            }
            // Projection optimality vs the min-norm reference on the
            // shifted polytope: y = argmin ½‖y − t‖² over B(F) is the
            // block prox with offset z = −t.
            let z: Vec<f64> = t.iter().map(|&ti| -ti).collect();
            let shifted = OffsetFn::new(&f, &z);
            let mut solver = MinNormPoint::new(&shifted, MinNormOptions::default(), None);
            for _ in 0..5000 {
                if solver.step(&shifted).wolfe_gap <= 1e-13 {
                    break;
                }
            }
            for k in 0..n {
                let y_ref = solver.s()[k] - z[k];
                if (y[k] - y_ref).abs() > 1e-6 {
                    return Err(format!(
                        "coord {k}: taut-string {} vs min-norm {}",
                        y[k], y_ref
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_weight_edges_decouple_exactly() {
        let t = [3.0, -1.0, 2.0, 2.5];
        let lam = [0.0, 1.0, 0.0];
        let mut ws = TautStringWorkspace::default();
        let mut x = vec![0.0; 4];
        tv_prox_into(&t, &lam, &mut ws, &mut x);
        // Edge 0 and 2 decouple: x0 = t0 and x3 = t3; the middle pair is
        // the 2-point TV prox of (−1, 2) with λ = 1 → (0, 1).
        assert!((x[0] - 3.0).abs() < 1e-12);
        assert!((x[1] - 0.0).abs() < 1e-12);
        assert!((x[2] - 1.0).abs() < 1e-12);
        assert!((x[3] - 2.5).abs() < 1e-12);
    }

    #[test]
    fn huge_weight_fuses_to_the_mean() {
        let t = [4.0, -2.0, 1.0];
        let lam = [1e6, 1e6];
        let mut ws = TautStringWorkspace::default();
        let mut x = vec![0.0; 3];
        tv_prox_into(&t, &lam, &mut ws, &mut x);
        let mean = 1.0;
        for &v in &x {
            assert!((v - mean).abs() < 1e-9, "fused fit should be the mean");
        }
    }

    #[test]
    fn degenerate_sizes() {
        let mut ws = TautStringWorkspace::default();
        let mut x0: Vec<f64> = vec![];
        tv_prox_into(&[], &[], &mut ws, &mut x0);
        let mut x1 = vec![0.0];
        tv_prox_into(&[2.5], &[], &mut ws, &mut x1);
        assert_eq!(x1, vec![2.5]);
    }

    #[test]
    fn workspace_reuse_is_bit_identical() {
        let mut rng = Pcg64::seeded(4242);
        let mut shared = TautStringWorkspace::default();
        for _ in 0..25 {
            let n = 2 + rng.below(30);
            let t = rng.uniform_vec(n, -2.0, 2.0);
            let lam: Vec<f64> = (0..n - 1).map(|_| rng.uniform(0.0, 1.5)).collect();
            let mut fresh = TautStringWorkspace::default();
            let mut xa = vec![0.0; n];
            let mut xb = vec![0.0; n];
            tv_prox_into(&t, &lam, &mut shared, &mut xa);
            tv_prox_into(&t, &lam, &mut fresh, &mut xb);
            for (a, b) in xa.iter().zip(&xb) {
                assert_eq!(a.to_bits(), b.to_bits(), "workspace reuse changed bits");
            }
        }
    }

    #[test]
    fn matches_minnorm_on_long_chain_values_and_dual() {
        // One denser cross-check at a size where the taut string has to
        // exercise both deque ends repeatedly.
        let mut rng = Pcg64::seeded(99);
        let n = 60;
        let t = rng.uniform_vec(n, -2.0, 2.0);
        let lam: Vec<f64> = (0..n - 1).map(|_| rng.uniform(0.0, 1.2)).collect();
        let mut ws = TautStringWorkspace::default();
        let mut x = vec![0.0; n];
        tv_prox_into(&t, &lam, &mut ws, &mut x);
        kkt_holds(&x, &t, &lam, 1e-7).expect("KKT certificate");
        let f = chain_cut(&lam);
        let z: Vec<f64> = t.iter().map(|&ti| -ti).collect();
        let shifted = OffsetFn::new(&f, &z);
        let mut solver = MinNormPoint::new(&shifted, MinNormOptions::default(), None);
        for _ in 0..20000 {
            if solver.step(&shifted).wolfe_gap <= 1e-13 {
                break;
            }
        }
        for k in 0..n {
            let y_ref = solver.s()[k] - z[k];
            let y = t[k] - x[k];
            assert!(
                (y - y_ref).abs() < 1e-6,
                "coord {k}: taut-string {y} vs min-norm {y_ref}"
            );
        }
        let _ = f.ground_size();
    }
}
