//! Decomposable submodular functions `F = Σ_i F_i` and their block solver.
//!
//! Both experiment families are sums of *simple* submodular terms: the
//! §4.2 grid cuts split into row/column/diagonal chains plus a modular
//! unary term, and the §4.1 kernel-cut is a sum of per-point star cuts.
//! This module exploits that structure:
//!
//! * [`DecomposableFn`] represents `F = Σ_i F_i` over (possibly
//!   overlapping) supports `S_i ⊆ V` and implements [`Submodular`], so
//!   every existing consumer — the monolithic solvers, the IAES engine,
//!   the Lemma-1 [`ScaledFn`] reduction — works on it unchanged. Its
//!   greedy pass runs each component on the *induced* sub-order and
//!   scatter-adds the gains (marginals of a sum are sums of marginals),
//!   allocation-free at steady state.
//! * [`BlockProxSolver`](solver::BlockProxSolver) solves the proximal
//!   dual by parallel per-component best responses, exploiting the base
//!   polytope identity
//!
//!   ```text
//!   B(F) = B(F_1) + … + B(F_r)          (Minkowski sum)
//!   ```
//!
//!   which holds because the Lovász extension — the support function of
//!   `B(F)` — is additive in `F`. Maintaining `y_i ∈ B(F_i)` therefore
//!   keeps the aggregate `y = Σ_i y_i` inside `B(F)` **at every
//!   iteration**, so the duality gap `P(ŵ) − D(y)` is a valid screening
//!   radius and every Lemma-2/3 certificate fired from a decomposed
//!   solve is exactly as safe as from a monolithic one (weak duality
//!   needs nothing beyond `y ∈ B(F)`).
//! * [`builders`] turns the repo's workloads into decompositions
//!   (grid chains + unary, per-point stars, cardinality sums).
//!
//! References: Bach, *Learning with Submodular Functions* (2013), §9;
//! Kumar & Bach, *Active-set methods for submodular minimization
//! problems* (2015); Jegelka, Bach & Sra (2013) for the projection view.
//!
//! [`ScaledFn`]: crate::submodular::scaled::ScaledFn

pub mod builders;
pub mod chain;
pub mod prox;
pub mod solver;

pub use solver::{
    solve_decomposed, solve_decomposed_resumed, BlockProxSolver, DecomposeOptions,
};

use crate::submodular::concave_card::ConcaveCardFn;
use crate::submodular::cut::CutFn;
use crate::submodular::modular::ModularFn;
use crate::submodular::{OracleScratch, Submodular};

/// Structural class of one component — decides which block-prox backend
/// the [`BlockProxSolver`](solver::BlockProxSolver) uses.
pub enum ComponentKind {
    /// Arbitrary submodular term: block prox via the min-norm solver on
    /// the modular-shifted polytope.
    Generic,
    /// `F_i(A) = g(|A|) + m(A)` with concave `g` tabulated at `0..=s_i`:
    /// block prox in closed form via PAV (isotonic regression) — see
    /// [`prox::card_prox_into`]. The reduction `F̂_i(C) = ĝ(|C|) + m̂(C)`
    /// with `ĝ(k) = g(b+k) − g(b)` keeps the closed form across IAES
    /// contractions.
    Cardinality {
        /// `g` tabulated at `0..=s_i` (`g[0] = 0`, concave).
        g: Vec<f64>,
        /// Modular tilt, one weight per support element.
        m: Vec<f64>,
    },
    /// Pure modular term: `B(F_i)` is the single point `m`, so the block
    /// prox is the constant `m̂` (no solve at all).
    Modular {
        /// Weights, one per support element.
        m: Vec<f64>,
    },
    /// Path cut `F_i(A) = Σ_k w_k · 1[{k, k+1} cut]` over the support
    /// (local elements are chain-consecutive): block prox in closed form
    /// via the O(s) taut-string total-variation prox with exact dual
    /// recovery — see [`chain::tv_prox_into`]. The Lemma-1 contraction of
    /// a path cut is a path cut on the surviving subsequence plus a
    /// boundary modular term, so the closed form survives IAES
    /// contractions (the solver rebuilds the reduced `(λ̂, m̂_b)` pair per
    /// contraction, never per round).
    Chain {
        /// Edge weights: `w[k]` joins local elements `k` and `k + 1`
        /// (`w.len() = s_i − 1`, all nonnegative).
        w: Vec<f64>,
    },
}

/// One term `F_i` of a decomposable function, over support `S_i`.
pub struct Component {
    /// The oracle over the component's *local* ground set (`|S_i|`).
    f: Box<dyn Submodular>,
    /// `support[l]` = global id of local element `l` (sorted ascending).
    support: Vec<usize>,
    /// Structural class (block-prox backend selection).
    kind: ComponentKind,
}

impl Component {
    /// A generic component: any submodular oracle over `support`.
    pub fn generic(f: Box<dyn Submodular>, support: Vec<usize>) -> Self {
        assert_eq!(f.ground_size(), support.len(), "oracle/support size mismatch");
        Component { f, support, kind: ComponentKind::Generic }
    }

    /// A concave-of-cardinality component `g(|A|) + m(A)` (PAV block prox).
    pub fn cardinality(g: Vec<f64>, m: Vec<f64>, support: Vec<usize>) -> Self {
        assert_eq!(g.len(), support.len() + 1, "g must be tabulated at 0..=s");
        assert_eq!(m.len(), support.len());
        let f = Box::new(ConcaveCardFn::new(g.clone(), m.clone()));
        Component { f, support, kind: ComponentKind::Cardinality { g, m } }
    }

    /// A modular component (closed-form block prox).
    pub fn modular(m: Vec<f64>, support: Vec<usize>) -> Self {
        assert_eq!(m.len(), support.len());
        let f = Box::new(ModularFn::new(m.clone()));
        Component { f, support, kind: ComponentKind::Modular { m } }
    }

    /// A chain (path-cut) component: local element `k` joins `k + 1` with
    /// weight `w[k]` (taut-string block prox). Zero weights are legal and
    /// decouple the chain at that edge exactly.
    pub fn chain(w: Vec<f64>, support: Vec<usize>) -> Self {
        assert_eq!(w.len() + 1, support.len(), "chain needs s − 1 edge weights");
        assert!(w.iter().all(|&x| x >= 0.0), "negative chain weight");
        let s = support.len();
        let edges: Vec<(usize, usize, f64)> = w
            .iter()
            .enumerate()
            .filter(|&(_, &x)| x > 0.0)
            .map(|(k, &x)| (k, k + 1, x))
            .collect();
        let f = Box::new(CutFn::from_edges(s, &edges, vec![0.0; s]));
        Component { f, support, kind: ComponentKind::Chain { w } }
    }

    /// The component oracle (local ground set).
    pub fn inner(&self) -> &dyn Submodular {
        self.f.as_ref()
    }

    /// Global ids of the support, sorted ascending.
    pub fn support(&self) -> &[usize] {
        &self.support
    }

    /// Structural class.
    pub fn kind(&self) -> &ComponentKind {
        &self.kind
    }
}

/// `F = Σ_i F_i` over ground set `V = {0..p}`, components on (possibly
/// overlapping) supports.
///
/// Implements [`Submodular`] by summing component marginals: one greedy
/// pass runs every component on its induced sub-order (cost
/// `Σ_i pass(F_i)`) and scatter-adds the gains back into global order
/// positions. A per-element membership CSR built at construction makes
/// the induced-order extraction a single walk over the global order, and
/// all transient pass state lives in the caller's [`OracleScratch`], so
/// the pass is allocation-free once the scratch reached working size.
pub struct DecomposableFn {
    p: usize,
    comps: Vec<Component>,
    /// CSR offsets into `mem_entries`, length `p + 1`.
    mem_offsets: Vec<usize>,
    /// `(component, local id)` pairs per global element, components
    /// ascending within each element.
    mem_entries: Vec<(u32, u32)>,
    /// Cumulative support sizes, length `r + 1` (concatenated local
    /// buffers are laid out by these offsets).
    support_offsets: Vec<usize>,
    /// Support-disjoint scheduling groups (CSR): components within one
    /// group have pairwise-disjoint supports, so their best responses are
    /// *jointly exact* — the block solver runs simultaneous Gauss–Seidel
    /// over groups instead of damped Jacobi. Empty when the builder did
    /// not annotate any groups.
    group_offsets: Vec<usize>,
    group_members: Vec<u32>,
    /// Components in no group (solved by the damped-Jacobi fallback).
    ungrouped: Vec<u32>,
}

impl DecomposableFn {
    /// Build `F = Σ_i F_i` over ground size `p`. Supports must be sorted,
    /// unique, in range, and match each component oracle's ground size.
    /// No scheduling groups — the block solver uses the Jacobi round for
    /// every component.
    pub fn new(p: usize, comps: Vec<Component>) -> Self {
        Self::with_groups(p, comps, Vec::new())
    }

    /// Like [`new`](Self::new), but with support-disjoint scheduling
    /// groups: `groups[g]` lists component indices whose supports are
    /// pairwise disjoint (validated here), enabling exact simultaneous
    /// Gauss–Seidel sweeps in the block solver. A component may appear in
    /// at most one group; components in no group fall back to the damped
    /// Jacobi round.
    pub fn with_groups(p: usize, comps: Vec<Component>, groups: Vec<Vec<usize>>) -> Self {
        let r = comps.len();
        assert!(r > 0, "decomposition needs at least one component");
        assert!(r < u32::MAX as usize && p < u32::MAX as usize);
        let mut support_offsets = vec![0usize; r + 1];
        for (i, c) in comps.iter().enumerate() {
            assert!(
                c.support.windows(2).all(|w| w[0] < w[1]),
                "component {i}: support must be sorted and unique"
            );
            if let Some(&last) = c.support.last() {
                assert!(last < p, "component {i}: support id {last} out of range");
            }
            support_offsets[i + 1] = support_offsets[i] + c.support.len();
        }
        // Membership CSR: element → [(component, local id)], components
        // ascending within each element (comps iterated in index order).
        let mut mem_offsets = vec![0usize; p + 1];
        for c in &comps {
            for &g in &c.support {
                mem_offsets[g + 1] += 1;
            }
        }
        for v in 0..p {
            mem_offsets[v + 1] += mem_offsets[v];
        }
        let mut mem_entries = vec![(0u32, 0u32); mem_offsets[p]];
        let mut cursor = mem_offsets.clone();
        for (ci, c) in comps.iter().enumerate() {
            for (l, &g) in c.support.iter().enumerate() {
                mem_entries[cursor[g]] = (ci as u32, l as u32);
                cursor[g] += 1;
            }
        }
        // Validate + flatten the scheduling groups: each component in at
        // most one group, supports pairwise disjoint within a group.
        let mut in_group = vec![false; r];
        let mut group_offsets = vec![0usize; groups.len() + 1];
        let mut group_members: Vec<u32> = Vec::new();
        let mut touched = vec![false; p];
        for (g, members) in groups.iter().enumerate() {
            for &ci in members {
                assert!(ci < r, "group {g}: component index {ci} out of range");
                assert!(!in_group[ci], "component {ci} appears in two groups");
                in_group[ci] = true;
                for &s in &comps[ci].support {
                    assert!(
                        !touched[s],
                        "group {g}: supports overlap at element {s}"
                    );
                    touched[s] = true;
                }
                group_members.push(ci as u32);
            }
            group_offsets[g + 1] = group_members.len();
            for &ci in members {
                for &s in &comps[ci].support {
                    touched[s] = false;
                }
            }
        }
        let ungrouped: Vec<u32> =
            (0..r).filter(|&i| !in_group[i]).map(|i| i as u32).collect();
        DecomposableFn {
            p,
            comps,
            mem_offsets,
            mem_entries,
            support_offsets,
            group_offsets,
            group_members,
            ungrouped,
        }
    }

    /// The components.
    pub fn components(&self) -> &[Component] {
        &self.comps
    }

    /// Number of components `r`.
    pub fn num_components(&self) -> usize {
        self.comps.len()
    }

    /// Total support size `Σ_i |S_i|` (the per-pass oracle work).
    pub fn total_support(&self) -> usize {
        *self.support_offsets.last().unwrap()
    }

    /// Number of support-disjoint scheduling groups (0 = Jacobi only).
    pub fn num_groups(&self) -> usize {
        self.group_offsets.len() - 1
    }

    /// Component indices of scheduling group `g` (supports pairwise
    /// disjoint — validated at construction).
    pub fn group(&self, g: usize) -> &[u32] {
        &self.group_members[self.group_offsets[g]..self.group_offsets[g + 1]]
    }

    /// Component indices belonging to no group (Jacobi fallback).
    pub fn ungrouped(&self) -> &[u32] {
        &self.ungrouped
    }

    /// `(component, local id)` memberships of global element `v`.
    #[inline]
    fn memberships(&self, v: usize) -> &[(u32, u32)] {
        &self.mem_entries[self.mem_offsets[v]..self.mem_offsets[v + 1]]
    }
}

impl Submodular for DecomposableFn {
    fn ground_size(&self) -> usize {
        self.p
    }

    fn eval(&self, set: &[bool]) -> f64 {
        assert_eq!(set.len(), self.p);
        let mut local: Vec<bool> = Vec::new();
        let mut total = 0.0;
        for c in &self.comps {
            local.clear();
            local.extend(c.support.iter().map(|&g| set[g]));
            total += c.f.eval(&local);
        }
        total
    }

    fn prefix_gains_from(&self, base: &[bool], order: &[usize], out: &mut [f64]) {
        let mut scratch = OracleScratch::new();
        self.prefix_gains_scratch(base, order, out, &mut scratch);
    }

    fn prefix_gains_scratch(
        &self,
        base: &[bool],
        order: &[usize],
        out: &mut [f64],
        scratch: &mut OracleScratch,
    ) {
        // Marginals of a sum are sums of marginals: the gain of `v` given
        // prefix `A` is Σ_c [F_c((A∪v)∩S_c) − F_c(A∩S_c)], and the local
        // pass of component `c` along the induced sub-order computes
        // exactly those terms. Layout (all in the caller's scratch):
        //   ids2 = [offsets (r+1) | cursors (r)] per-component entry counts,
        //   ids  = concatenated induced local orders,
        //   mem_bool = concatenated local base flags (support_offsets),
        //   acc  = concatenated local gains.
        // The final walk re-traverses `order` with reset cursors to
        // scatter-add local gains into global positions, component order
        // ascending per element — deterministic, no position array needed.
        // The parallel-oracle pool handle is deliberately NOT propagated
        // into the nested component scratch: block-solver component
        // passes already run on pool worker threads, and a nested
        // dispatch from a worker would re-enter the pool mid-job.
        // Component supports are small; the sequential kernels are the
        // right tool here.
        assert_eq!(base.len(), self.p);
        assert_eq!(order.len(), out.len());
        let r = self.comps.len();
        let OracleScratch { ids, ids2, mem_bool, acc, inner, .. } = scratch;

        // Per-component counts → offsets.
        ids2.clear();
        ids2.resize(2 * r + 1, 0);
        for &v in order {
            for &(c, _) in self.memberships(v) {
                ids2[c as usize + 1] += 1;
            }
        }
        for c in 0..r {
            let prev = ids2[c];
            ids2[c + 1] += prev;
        }
        let total = ids2[r];
        for c in 0..r {
            ids2[r + 1 + c] = ids2[c];
        }
        // Induced local orders, grouped by component.
        ids.clear();
        ids.resize(total, 0);
        for &v in order {
            for &(c, l) in self.memberships(v) {
                let cur = ids2[r + 1 + c as usize];
                ids[cur] = l as usize;
                ids2[r + 1 + c as usize] = cur + 1;
            }
        }
        // Concatenated local base flags.
        mem_bool.clear();
        mem_bool.resize(self.support_offsets[r], false);
        for (v, &b) in base.iter().enumerate() {
            if b {
                for &(c, l) in self.memberships(v) {
                    mem_bool[self.support_offsets[c as usize] + l as usize] = true;
                }
            }
        }
        // Component passes into the concatenated gain buffer. One nested
        // scratch serves every component sequentially (oracles resize on
        // entry and carry no state between passes).
        acc.clear();
        acc.resize(total, 0.0);
        let nested = inner.get_or_insert_with(Default::default);
        for (c, comp) in self.comps.iter().enumerate() {
            let (lo, hi) = (ids2[c], ids2[c + 1]);
            if lo == hi {
                continue;
            }
            let (blo, bhi) = (self.support_offsets[c], self.support_offsets[c + 1]);
            comp.f.prefix_gains_scratch(
                &mem_bool[blo..bhi],
                &ids[lo..hi],
                &mut acc[lo..hi],
                nested,
            );
        }
        // Scatter-add: re-walk the order with cursors reset to offsets.
        for c in 0..r {
            ids2[r + 1 + c] = ids2[c];
        }
        for (o, &v) in out.iter_mut().zip(order) {
            *o = 0.0;
            for &(c, _) in self.memberships(v) {
                let cur = ids2[r + 1 + c as usize];
                *o += acc[cur];
                ids2[r + 1 + c as usize] = cur + 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::submodular::cut::CutFn;
    use crate::submodular::test_support::{check_axioms, check_gains_match_eval};
    use crate::submodular::SubmodularExt;

    /// Overlapping mixed decomposition: two concave-card terms on
    /// overlapping windows, one generic cut, one modular tilt.
    fn mixed(p: usize, seed: u64) -> DecomposableFn {
        let mut rng = Pcg64::seeded(seed);
        let h = p / 2 + 2;
        let s1: Vec<usize> = (0..h).collect();
        let s2: Vec<usize> = (p - h..p).collect();
        let g1: Vec<f64> = (0..=h).map(|k| 1.3 * (k as f64).sqrt()).collect();
        let g2: Vec<f64> = (0..=h).map(|k| 0.7 * (k as f64).sqrt()).collect();
        let m1 = rng.uniform_vec(h, -0.5, 0.5);
        let m2 = rng.uniform_vec(h, -0.5, 0.5);
        let mut edges = Vec::new();
        for i in 0..p - 1 {
            edges.push((i, i + 1, rng.uniform(0.0, 1.0)));
        }
        let chain = CutFn::from_edges(p, &edges, vec![0.0; p]);
        let tilt = rng.uniform_vec(p, -1.0, 1.0);
        DecomposableFn::new(
            p,
            vec![
                Component::cardinality(g1, m1, s1),
                Component::cardinality(g2, m2, s2),
                Component::generic(Box::new(chain), (0..p).collect()),
                Component::modular(tilt, (0..p).collect()),
            ],
        )
    }

    #[test]
    fn axioms_and_gains() {
        let f = mixed(11, 7);
        check_axioms(&f, 8, 1e-9);
        check_gains_match_eval(&f, 9, 1e-9);
    }

    #[test]
    fn eval_matches_component_sum() {
        let f = mixed(10, 17);
        let mut rng = Pcg64::seeded(18);
        for _ in 0..25 {
            let set: Vec<bool> = (0..10).map(|_| rng.bernoulli(0.5)).collect();
            let mut expect = 0.0;
            for c in f.components() {
                let local: Vec<bool> = c.support().iter().map(|&g| set[g]).collect();
                expect += c.inner().eval(&local);
            }
            assert!((f.eval(&set) - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn membership_csr_covers_supports() {
        let f = mixed(9, 3);
        let mut per_elem = vec![0usize; 9];
        for c in f.components() {
            for &g in c.support() {
                per_elem[g] += 1;
            }
        }
        for v in 0..9 {
            assert_eq!(f.memberships(v).len(), per_elem[v]);
        }
        assert_eq!(f.total_support(), per_elem.iter().sum::<usize>());
    }

    #[test]
    fn works_under_scaled_reduction() {
        // The Lemma-1 reduction must distribute over the sum: ScaledFn
        // over a DecomposableFn stays consistent with ScaledFn over an
        // equivalent monolithic oracle.
        use crate::submodular::scaled::ScaledFn;
        let f = mixed(10, 5);
        let scaled = ScaledFn::new(&f, &[1, 7], vec![0, 2, 4, 5, 8]);
        check_axioms(&scaled, 6, 1e-9);
        check_gains_match_eval(&scaled, 7, 1e-9);
        // Definition check: F̂(C) = F(Ê ∪ C) − F(Ê).
        let lhs = scaled.eval_ids(&[0, 3]);
        let rhs = f.eval_ids(&[0, 1, 5, 7]) - f.eval_ids(&[1, 7]);
        assert!((lhs - rhs).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "sorted")]
    fn rejects_unsorted_support() {
        let m = vec![0.0, 0.0];
        DecomposableFn::new(5, vec![Component::modular(m, vec![3, 1])]);
    }

    #[test]
    fn chain_component_matches_path_cut() {
        // Component::chain's oracle must equal the path cut it declares.
        let w = vec![0.7, 0.0, 1.3];
        let c = Component::chain(w.clone(), vec![1, 3, 4, 8]);
        let mut rng = Pcg64::seeded(23);
        for _ in 0..20 {
            let set: Vec<bool> = (0..4).map(|_| rng.bernoulli(0.5)).collect();
            let mut expect = 0.0;
            for (k, &wk) in w.iter().enumerate() {
                if set[k] != set[k + 1] {
                    expect += wk;
                }
            }
            assert!((c.inner().eval(&set) - expect).abs() < 1e-12);
        }
        assert!(matches!(c.kind(), ComponentKind::Chain { .. }));
    }

    #[test]
    fn groups_flatten_and_partition() {
        let m = |ids: Vec<usize>| {
            Component::modular(vec![0.0; ids.len()], ids)
        };
        let dec = DecomposableFn::with_groups(
            8,
            vec![m(vec![0, 1]), m(vec![2, 3]), m(vec![0, 2]), m(vec![4])],
            vec![vec![0, 1], vec![3]],
        );
        assert_eq!(dec.num_groups(), 2);
        assert_eq!(dec.group(0), &[0, 1]);
        assert_eq!(dec.group(1), &[3]);
        assert_eq!(dec.ungrouped(), &[2]);
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn groups_reject_overlapping_supports() {
        let m = |ids: Vec<usize>| {
            Component::modular(vec![0.0; ids.len()], ids)
        };
        DecomposableFn::with_groups(
            6,
            vec![m(vec![0, 1]), m(vec![1, 2])],
            vec![vec![0, 1]],
        );
    }

    #[test]
    #[should_panic(expected = "two groups")]
    fn groups_reject_duplicate_membership() {
        let m = |ids: Vec<usize>| {
            Component::modular(vec![0.0; ids.len()], ids)
        };
        DecomposableFn::with_groups(
            6,
            vec![m(vec![0]), m(vec![1])],
            vec![vec![0], vec![0]],
        );
    }
}
