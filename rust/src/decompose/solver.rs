//! The block best-response solver for decomposable prox problems.
//!
//! Minimizes `½‖Σ_i y_i‖²` over the product `Π_i B(F̂_i)` — equivalent to
//! the (Q-D) dual over `B(F̂) = Σ_i B(F̂_i)` — by damped Jacobi
//! best-response rounds:
//!
//! 1. **Best responses** (parallel): with the aggregate `y = Σ_j y_j`
//!    frozen, every component solves `ŷ_i = argmin_{v ∈ B(F̂_i)}
//!    ½‖v + (y − y_i)‖²` — PAV closed form for cardinality/modular
//!    components, the min-norm solver on the modular-shifted polytope for
//!    generic ones ([`super::prox`]). All responses read the *same*
//!    snapshot, so the round is deterministic for any thread count.
//! 2. **Exact line search** on the aggregated direction
//!    `d = Σ_i (ŷ_i − y_i)`: `θ* = clamp(−⟨y, d⟩/‖d‖², 0, 1)`, then
//!    `y_i ← y_i + θ*(ŷ_i − y_i)` (a convex combination, so `y_i` never
//!    leaves `B(F̂_i)`). Block optimality gives `⟨y, d⟩ ≤ Σ_i (best-
//!    response improvement) ≤ 0`, so `d` is a strict descent direction
//!    until every block is optimal — and for a smooth convex objective
//!    over a Cartesian product, blockwise optimality *is* global
//!    optimality, i.e. the fixed points are exactly the min-norm points
//!    of `B(F̂)`.
//! 3. **Global certificate pass** (the one sequential oracle pass): one
//!    greedy pass on the reduced function in direction `−y` yields the
//!    PAV-refined primal `ŵ`, the best level value `F̂(C)`, and the gap
//!    `P(ŵ) − D(y)` — identical bookkeeping to the monolithic solvers,
//!    so the IAES engine and the screening rules consume decomposed
//!    solves through the unchanged [`ProxSolver`] interface. Safety
//!    needs nothing more: `y ∈ B(F̂)` holds at every round by
//!    construction, so the gap is always a valid screening radius.
//!
//! IAES ground-set contractions arrive through
//! [`ProxSolver::reset_mapped`] and are threaded through every component:
//! the [`ContractionMap`] (with its removed-to-active annotations)
//! splits each component's surviving support into its own base/kept
//! pair, the per-component [`ScaledFn`] re-targets in place, and the
//! component duals are regenerated as greedy vertices of the contracted
//! polytopes — valid members of the new `B(F̂_i)` by construction, which
//! preserves the ROADMAP's warm-restart projection invariants (a
//! coordinate-projected dual point would *not* be feasible in general).
//!
//! Work is distributed over scoped threads with an atomic work index
//! (the [`coordinator::runner`](crate::coordinator::runner) pattern) and
//! **persistent per-worker arenas** (a min-norm solver + PAV workspace
//! each), so steady-state rounds at `threads = 1` are allocation-free;
//! the parallel path additionally pays only the `O(threads)` scope-spawn
//! cost per round.

use super::prox::{card_prox_into, CardProxWorkspace, OffsetFn};
use super::{ComponentKind, DecomposableFn};
use crate::linalg::vecops::{dot, norm2_sq};
use crate::lovasz::{greedy_base_vertex, ContractionMap, GreedyWorkspace};
use crate::screening::iaes::{IaesEngine, IaesOptions, IaesReport};
use crate::solvers::minnorm::{MinNormOptions, MinNormPoint};
use crate::solvers::{PrimalState, ProxSolver, SolverEvent};
use crate::submodular::scaled::ScaledFn;
use crate::submodular::Submodular;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Options for [`BlockProxSolver`].
#[derive(Clone, Copy, Debug)]
pub struct DecomposeOptions {
    /// Worker threads for the best-response round (`0` = all available
    /// cores). The trajectory is bit-identical for every value — the
    /// round is a Jacobi sweep off one frozen snapshot and the
    /// aggregation is sequential in component order.
    pub threads: usize,
    /// Wolfe-gap tolerance for generic (min-norm) block solves.
    pub inner_tol: f64,
    /// Iteration cap per generic block solve.
    pub max_inner: usize,
    /// Options of the per-worker min-norm solvers.
    pub minnorm: MinNormOptions,
}

impl Default for DecomposeOptions {
    fn default() -> Self {
        DecomposeOptions {
            threads: 0,
            inner_tol: 1e-11,
            max_inner: 256,
            minnorm: MinNormOptions::default(),
        }
    }
}

/// Per-component mutable state (one [`Mutex`] slot per component; locks
/// are uncontended — the atomic work index hands each slot to exactly
/// one worker per round).
struct CompState<'a> {
    /// Lemma-1 view of the component at the current reduction.
    scaled: ScaledFn<'a>,
    /// Structural class (borrowed from the decomposition).
    kind: &'a ComponentKind,
    /// Local ids (component ground set) still in play, ascending.
    local_kept: Vec<usize>,
    /// Local ids certified active — the component's share of `Ê`.
    local_base: Vec<usize>,
    /// Reduced-problem index of each kept element (parallel to
    /// `local_kept`).
    reduced_pos: Vec<usize>,
    /// Component dual `y_i` (local reduced coords).
    y: Vec<f64>,
    /// Best response `ŷ_i`.
    y_hat: Vec<f64>,
    /// Offset `z_i = y − y_i` restricted to the support.
    z: Vec<f64>,
    /// Scratch: restart direction / reduced modular gather.
    w0: Vec<f64>,
}

/// Persistent per-worker solve state: buffers grow to the largest
/// component each worker touches and are reused every round.
#[derive(Default)]
struct BlockArena {
    /// Lazily created min-norm solver for generic block solves.
    solver: Option<MinNormPoint>,
    /// Cardinality closed-form buffers.
    card: CardProxWorkspace,
}

/// One component best response off the frozen aggregate `y_global`.
fn best_response(
    st: &mut CompState<'_>,
    arena: &mut BlockArena,
    y_global: &[f64],
    opts: &DecomposeOptions,
) {
    let n = st.local_kept.len();
    if n == 0 {
        return;
    }
    for k in 0..n {
        st.z[k] = y_global[st.reduced_pos[k]] - st.y[k];
    }
    match st.kind {
        ComponentKind::Modular { m } => {
            // B(F̂_i) is the single point m̂ — the response is constant.
            for (k, &l) in st.local_kept.iter().enumerate() {
                st.y_hat[k] = m[l];
            }
        }
        ComponentKind::Cardinality { g, m } => {
            for (k, &l) in st.local_kept.iter().enumerate() {
                st.w0[k] = m[l];
            }
            card_prox_into(
                g,
                st.local_base.len(),
                &st.w0,
                &st.z,
                &mut arena.card,
                &mut st.y_hat,
            );
        }
        ComponentKind::Generic => {
            // min ½‖v + z‖² over B(F̂_i)  ⇔  min ½‖u‖² over B(F̂_i + m_z),
            // v = u − z. Warm direction: the current block iterate −(y+z).
            for k in 0..n {
                st.w0[k] = -(st.y[k] + st.z[k]);
            }
            let shifted = OffsetFn::new(&st.scaled, &st.z);
            match arena.solver.as_mut() {
                Some(solver) => solver.reset(&shifted, &st.w0),
                None => {
                    arena.solver =
                        Some(MinNormPoint::new(&shifted, opts.minnorm, Some(&st.w0)));
                }
            }
            let solver = arena.solver.as_mut().expect("solver just installed");
            for _ in 0..opts.max_inner {
                let ev = solver.step(&shifted);
                if ev.wolfe_gap <= opts.inner_tol {
                    break;
                }
            }
            for (k, (&u, &zk)) in solver.s().iter().zip(&st.z).enumerate() {
                st.y_hat[k] = u - zk;
            }
            // Accept the response only if it improves the block objective
            // ½‖y + z‖²: an inner solve cut off by `max_inner` before
            // overtaking the incumbent would otherwise break the
            // line-search descent property (⟨y, d⟩ ≤ 0). The closed-form
            // arms are exact and need no guard.
            let mut cur = 0.0;
            let mut new = 0.0;
            for k in 0..n {
                let zk = st.z[k];
                cur += (st.y[k] + zk) * (st.y[k] + zk);
                new += (st.y_hat[k] + zk) * (st.y_hat[k] + zk);
            }
            if new > cur {
                let (y_hat, y) = (&mut st.y_hat, &st.y);
                y_hat[..n].copy_from_slice(&y[..n]);
            }
        }
    }
}

/// The decomposable-dual solver behind the [`ProxSolver`] interface.
pub struct BlockProxSolver<'a> {
    dec: &'a DecomposableFn,
    opts: DecomposeOptions,
    /// Resolved worker count.
    threads: usize,
    comps: Vec<Mutex<CompState<'a>>>,
    arenas: Vec<BlockArena>,
    /// Aggregated dual `y = Σ_i y_i` (reduced coords) — always in `B(F̂)`.
    y: Vec<f64>,
    /// Aggregated best-response direction.
    d: Vec<f64>,
    shared: PrimalState,
    /// Scratch vertex buffer for the global certificate pass.
    q: Vec<f64>,
    /// Greedy workspace for per-component restart passes (kept separate
    /// from the shared one so component passes never clobber the global
    /// adaptive argsort warm start).
    comp_ws: GreedyWorkspace,
    /// Restart scratch: restricted direction / regenerated vertex.
    dirbuf: Vec<f64>,
    vbuf: Vec<f64>,
}

impl<'a> BlockProxSolver<'a> {
    /// Build on the full problem and initialize like the monolithic
    /// solvers: every `y_i` is the greedy vertex of `B(F_i)` along
    /// `w_init` (zeros → index order).
    pub fn new(dec: &'a DecomposableFn, opts: DecomposeOptions) -> Self {
        let p = dec.ground_size();
        let threads = if opts.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            opts.threads
        };
        let comps = dec
            .components()
            .iter()
            .map(|c| {
                let s = c.support().len();
                Mutex::new(CompState {
                    scaled: ScaledFn::new(c.inner(), &[], (0..s).collect()),
                    kind: c.kind(),
                    local_kept: (0..s).collect(),
                    local_base: Vec::new(),
                    reduced_pos: c.support().to_vec(),
                    y: vec![0.0; s],
                    y_hat: vec![0.0; s],
                    z: vec![0.0; s],
                    w0: vec![0.0; s],
                })
            })
            .collect();
        let mut solver = BlockProxSolver {
            dec,
            opts,
            threads,
            comps,
            arenas: (0..threads.max(1)).map(|_| BlockArena::default()).collect(),
            y: vec![0.0; p],
            d: vec![0.0; p],
            shared: PrimalState::new(p),
            q: vec![0.0; p],
            comp_ws: GreedyWorkspace::new(0),
            dirbuf: Vec::new(),
            vbuf: Vec::new(),
        };
        let w0 = vec![0.0; p];
        solver.reset(dec, &w0);
        solver
    }

    /// Resolved worker-thread count (diagnostics / benches).
    pub fn num_threads(&self) -> usize {
        self.threads
    }

    /// Number of components (diagnostics).
    pub fn num_components(&self) -> usize {
        self.comps.len()
    }

    /// Regenerate every component dual as the greedy vertex of its
    /// (possibly contracted) polytope along the restricted `w_init`, then
    /// rebuild the aggregate. Valid for `B(F̂_i)` by construction — this
    /// is what keeps restarts feasible where a coordinate projection of
    /// the old `y_i` would not be.
    fn regenerate_duals(&mut self, w_init: &[f64]) {
        for slot in self.comps.iter_mut() {
            let st = slot.get_mut().expect("component poisoned");
            let n = st.local_kept.len();
            st.y.clear();
            st.y.resize(n, 0.0);
            if n == 0 {
                continue;
            }
            self.dirbuf.clear();
            self.dirbuf.extend(st.reduced_pos.iter().map(|&pos| w_init[pos]));
            self.vbuf.clear();
            self.vbuf.resize(n, 0.0);
            greedy_base_vertex(&st.scaled, &self.dirbuf, &mut self.comp_ws, &mut self.vbuf);
            st.y.copy_from_slice(&self.vbuf);
        }
        self.aggregate();
    }

    /// `y = Σ_i y_i`, scattered in fixed component order (deterministic).
    fn aggregate(&mut self) {
        self.y.iter_mut().for_each(|v| *v = 0.0);
        for slot in self.comps.iter_mut() {
            let st = slot.get_mut().expect("component poisoned");
            for (k, &pos) in st.reduced_pos.iter().enumerate() {
                self.y[pos] += st.y[k];
            }
        }
    }

    /// Algorithm-2 step-14 bookkeeping against the *aggregated* dual
    /// point: adopt `w_init`, one global greedy pass, gap by weak duality
    /// (valid for any `y ∈ B(F̂)`).
    fn close_gap(&mut self, f: &dyn Submodular, w_init: &[f64]) {
        let p = f.ground_size();
        let mut q = std::mem::take(&mut self.q);
        q.clear();
        q.resize(p, 0.0);
        let f_w = self.shared.reset_primal(f, w_init, &mut q);
        self.q = q;
        self.shared.gap =
            f_w + 0.5 * norm2_sq(w_init) + 0.5 * norm2_sq(&self.y);
    }
}

impl ProxSolver for BlockProxSolver<'_> {
    fn step(&mut self, f: &dyn Submodular) -> SolverEvent {
        let p = f.ground_size();
        assert_eq!(p, self.y.len(), "solver/problem size mismatch");
        // (1) Jacobi best responses off the frozen aggregate.
        let workers = self.threads.min(self.comps.len()).max(1);
        if workers <= 1 {
            let arena = &mut self.arenas[0];
            for slot in &self.comps {
                let mut st = slot.lock().expect("component poisoned");
                best_response(&mut st, arena, &self.y, &self.opts);
            }
        } else {
            let next = AtomicUsize::new(0);
            let next = &next;
            let comps = &self.comps;
            let y = &self.y[..];
            let opts = &self.opts;
            std::thread::scope(|scope| {
                for arena in self.arenas.iter_mut().take(workers) {
                    scope.spawn(move || loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= comps.len() {
                            break;
                        }
                        let mut st = comps[i].lock().expect("component poisoned");
                        best_response(&mut st, arena, y, opts);
                    });
                }
            });
        }
        // (2) Exact line search on the aggregated direction.
        self.d.iter_mut().for_each(|v| *v = 0.0);
        for slot in self.comps.iter_mut() {
            let st = slot.get_mut().expect("component poisoned");
            for (k, &pos) in st.reduced_pos.iter().enumerate() {
                self.d[pos] += st.y_hat[k] - st.y[k];
            }
        }
        let denom = norm2_sq(&self.d);
        if denom > 0.0 {
            let theta = (-dot(&self.y, &self.d) / denom).clamp(0.0, 1.0);
            if theta > 0.0 {
                for slot in self.comps.iter_mut() {
                    let st = slot.get_mut().expect("component poisoned");
                    for k in 0..st.y.len() {
                        st.y[k] += theta * (st.y_hat[k] - st.y[k]);
                    }
                }
            }
        }
        self.aggregate();
        // (3) Global certificate pass: primal refinement + gap.
        let mut q = std::mem::take(&mut self.q);
        let (_info, f_w) = self.shared.greedy_and_refine(f, &self.y, &mut q);
        let wolfe_gap = norm2_sq(&self.y) - dot(&self.y, &q);
        self.q = q;
        self.shared.finish_step(f_w, &self.y, wolfe_gap)
    }

    fn s(&self) -> &[f64] {
        &self.y
    }

    fn w(&self) -> &[f64] {
        &self.shared.w
    }

    fn gap(&self) -> f64 {
        self.shared.gap
    }

    fn best_level_value(&self) -> f64 {
        self.shared.fc
    }

    fn iters(&self) -> usize {
        self.shared.iters
    }

    fn reset(&mut self, f: &dyn Submodular, w_init: &[f64]) {
        let p = f.ground_size();
        assert_eq!(
            p,
            self.dec.ground_size(),
            "BlockProxSolver::reset only supports the full problem; IAES \
             reductions must arrive via reset_mapped (run the engine with \
             warm_restart = true — solve_decomposed does)"
        );
        for (slot, comp) in self.comps.iter_mut().zip(self.dec.components()) {
            let st = slot.get_mut().expect("component poisoned");
            let s = comp.support().len();
            st.local_base.clear();
            st.local_kept.clear();
            st.local_kept.extend(0..s);
            st.reduced_pos.clear();
            st.reduced_pos.extend_from_slice(comp.support());
            st.y_hat.clear();
            st.y_hat.resize(s, 0.0);
            st.z.clear();
            st.z.resize(s, 0.0);
            st.w0.clear();
            st.w0.resize(s, 0.0);
            st.scaled.set_reduction(&[], &st.local_kept);
        }
        self.y.clear();
        self.y.resize(p, 0.0);
        self.d.clear();
        self.d.resize(p, 0.0);
        self.regenerate_duals(w_init);
        self.close_gap(f, w_init);
    }

    fn reset_mapped(&mut self, f: &dyn Submodular, w_init: &[f64], map: &ContractionMap) {
        let p = f.ground_size();
        if map.new_len() != p || self.y.len() != map.old_len() {
            // Stale map (fresh solver / unrelated problem): only the
            // full-problem reset is valid.
            self.reset(f, w_init);
            return;
        }
        // Thread the contraction through every component: survivors keep
        // their (renumbered) reduced position, removed-to-active elements
        // join the component's base, removed-to-inactive elements leave.
        for slot in self.comps.iter_mut() {
            let st = slot.get_mut().expect("component poisoned");
            let mut w = 0usize;
            for k in 0..st.local_kept.len() {
                let r = st.reduced_pos[k];
                match map.new_index(r) {
                    Some(nr) => {
                        st.local_kept[w] = st.local_kept[k];
                        st.reduced_pos[w] = nr;
                        w += 1;
                    }
                    None => {
                        if map.went_active(r) {
                            st.local_base.push(st.local_kept[k]);
                        }
                    }
                }
            }
            st.local_kept.truncate(w);
            st.reduced_pos.truncate(w);
            st.y_hat.truncate(w);
            st.z.truncate(w);
            st.w0.truncate(w);
            st.scaled.set_reduction(&st.local_base, &st.local_kept);
        }
        // Warm-start the global argsort through the survivor map, then
        // regenerate the component duals on the contracted polytopes and
        // close the gap against the new aggregate.
        self.shared.greedy_ws.contract(map);
        self.y.truncate(p);
        self.d.truncate(p);
        self.regenerate_duals(w_init);
        self.close_gap(f, w_init);
    }

    fn greedy_full_sorts(&self) -> u64 {
        self.shared.greedy_ws.full_sorts
    }

    fn name(&self) -> &'static str {
        "block-prox"
    }
}

/// Run Algorithm 2 on a decomposable function with the block solver.
/// Forces contraction-aware warm restarts (the block solver threads
/// reductions through per-component [`ContractionMap`]s and has no cold
/// reduced-rebuild path).
pub fn solve_decomposed(
    f: &DecomposableFn,
    opts: &IaesOptions,
    dopts: DecomposeOptions,
) -> anyhow::Result<IaesReport> {
    let mut opts = opts.clone();
    opts.warm_restart = true;
    let solver = BlockProxSolver::new(f, dopts);
    IaesEngine::with_solver(f, opts, Box::new(solver)).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brute::brute_force_sfm;
    use crate::decompose::builders::star_components;
    use crate::decompose::Component;
    use crate::lovasz::{in_base_polytope, sup_level_set};
    use crate::rng::Pcg64;

    fn random_star_decomposition(p: usize, rng: &mut Pcg64) -> DecomposableFn {
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let unary = rng.uniform_vec(p, -2.0, 2.0);
        star_components(p, |i, j| k[i * p + j], unary)
    }

    fn run(solver: &mut BlockProxSolver<'_>, f: &dyn Submodular, iters: usize, eps: f64) {
        for _ in 0..iters {
            let ev = solver.step(f);
            if ev.gap < eps {
                break;
            }
        }
    }

    #[test]
    fn block_solver_converges_on_star_decomposition() {
        let mut rng = Pcg64::seeded(41);
        let p = 9;
        let dec = random_star_decomposition(p, &mut rng);
        let mut solver = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 1,
            ..Default::default()
        });
        run(&mut solver, &dec, 500, 1e-10);
        assert!(solver.gap() < 1e-10, "gap {}", solver.gap());
        // The aggregate stays feasible and recovers the minimal minimizer.
        assert!(in_base_polytope(&dec, solver.s(), 1e-7));
        let brute = brute_force_sfm(&dec, 1e-9);
        assert_eq!(sup_level_set(solver.w(), 0.0), brute.minimal);
    }

    #[test]
    fn aggregate_dual_feasible_every_round() {
        let mut rng = Pcg64::seeded(43);
        let p = 8;
        let dec = random_star_decomposition(p, &mut rng);
        let mut solver = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 1,
            ..Default::default()
        });
        for _ in 0..20 {
            let ev = solver.step(&dec);
            assert!(in_base_polytope(&dec, solver.s(), 1e-7), "y left B(F)");
            assert!(ev.gap >= -1e-9, "negative gap {}", ev.gap);
        }
    }

    #[test]
    fn thread_counts_are_bitwise_identical() {
        let mut rng = Pcg64::seeded(47);
        let p = 10;
        let dec = random_star_decomposition(p, &mut rng);
        let mut one = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 1,
            ..Default::default()
        });
        let mut four = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 4,
            ..Default::default()
        });
        for it in 0..40 {
            let a = one.step(&dec);
            let b = four.step(&dec);
            assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "gap differs at {it}");
            for (x, y) in one.s().iter().zip(four.s()) {
                assert_eq!(x.to_bits(), y.to_bits(), "dual differs at {it}");
            }
            for (x, y) in one.w().iter().zip(four.w()) {
                assert_eq!(x.to_bits(), y.to_bits(), "primal differs at {it}");
            }
        }
    }

    #[test]
    fn reset_mapped_threads_contraction_through_components() {
        let mut rng = Pcg64::seeded(53);
        let p = 10;
        let dec = random_star_decomposition(p, &mut rng);
        let kept: Vec<usize> = (0..p).collect();
        let mut scaled = ScaledFn::new(&dec, &[], kept.clone());
        let mut solver = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 1,
            ..Default::default()
        });
        for _ in 0..8 {
            solver.step(&scaled);
        }
        // Certify element 2 active, elements 5 and 8 inactive.
        let new_kept: Vec<usize> =
            kept.iter().copied().filter(|&i| ![2, 5, 8].contains(&i)).collect();
        let w_surv: Vec<f64> = new_kept.iter().map(|&i| solver.w()[i]).collect();
        let mut map = ContractionMap::new();
        scaled.contract(&[2], &new_kept, &mut map);
        solver.reset_mapped(&scaled, &w_surv, &map);
        assert_eq!(solver.s().len(), new_kept.len());
        // Feasible in the contracted polytope, valid gap, and the solver
        // still converges to the reduced optimum.
        assert!(in_base_polytope(&scaled, solver.s(), 1e-7));
        assert!(solver.gap() >= -1e-9);
        let mut gap = f64::INFINITY;
        for _ in 0..500 {
            gap = solver.step(&scaled).gap;
            if gap < 1e-9 {
                break;
            }
        }
        assert!(gap < 1e-9, "stalled after contraction: gap {gap}");
        let brute = brute_force_sfm(&scaled, 1e-9);
        let a = sup_level_set(solver.w(), 0.0);
        let mut set = vec![false; new_kept.len()];
        for &i in &a {
            set[i] = true;
        }
        assert!((scaled.eval(&set) - brute.minimum).abs() < 1e-6);
    }

    #[test]
    fn solve_decomposed_matches_brute_force() {
        let mut rng = Pcg64::seeded(59);
        for p in [7usize, 9, 11] {
            let dec = random_star_decomposition(p, &mut rng);
            let brute = brute_force_sfm(&dec, 1e-9);
            let report = solve_decomposed(
                &dec,
                &IaesOptions { eps: 1e-9, ..Default::default() },
                DecomposeOptions { threads: 2, ..Default::default() },
            )
            .unwrap();
            assert!(
                (report.minimum - brute.minimum).abs() < 1e-6,
                "p={p}: decomposed {} vs brute {}",
                report.minimum,
                brute.minimum
            );
        }
    }

    #[test]
    fn cardinality_components_use_pav_path() {
        // A sum of overlapping cardinality terms + modular tilt solved by
        // the closed-form path only (no generic component at all).
        let mut rng = Pcg64::seeded(61);
        let p = 10;
        let h = 7;
        let g1: Vec<f64> = (0..=h).map(|k| 1.1 * (k as f64).sqrt()).collect();
        let g2: Vec<f64> = (0..=h).map(|k| 0.6 * (k as f64).sqrt()).collect();
        let dec = DecomposableFn::new(
            p,
            vec![
                Component::cardinality(g1, rng.uniform_vec(h, -0.8, 0.8), (0..h).collect()),
                Component::cardinality(
                    g2,
                    rng.uniform_vec(h, -0.8, 0.8),
                    (p - h..p).collect(),
                ),
                Component::modular(rng.uniform_vec(p, -1.0, 1.0), (0..p).collect()),
            ],
        );
        let brute = brute_force_sfm(&dec, 1e-9);
        let report = solve_decomposed(
            &dec,
            &IaesOptions { eps: 1e-9, ..Default::default() },
            DecomposeOptions { threads: 1, ..Default::default() },
        )
        .unwrap();
        assert!((report.minimum - brute.minimum).abs() < 1e-6);
    }
}
