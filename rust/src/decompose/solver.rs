//! The block best-response solver for decomposable prox problems.
//!
//! Minimizes `½‖Σ_i y_i‖²` over the product `Π_i B(F̂_i)` — equivalent to
//! the (Q-D) dual over `B(F̂) = Σ_i B(F̂_i)` — one *round* at a time:
//!
//! 1. **Gauss–Seidel group sweeps** (when the builder annotated
//!    support-disjoint groups, e.g. all row chains of a grid): for each
//!    group in fixed order, every member solves its block prox off the
//!    current aggregate and the responses are applied **undamped**
//!    (`θ = 1`). Within a group the supports are disjoint, so the
//!    simultaneous responses *are* sequential Gauss–Seidel — jointly
//!    exact, and `θ = 1` is exactly the minimizer of `½‖y + θd‖²` along
//!    the group direction (the group-optimal point satisfies the
//!    variational inequality `⟨y + d, −d⟩ ≤ 0`). No damping, no line
//!    search, and later groups see earlier groups' updates — which is
//!    what cuts grid round counts versus one damped Jacobi sweep.
//! 2. **Jacobi fallback** for ungrouped (overlapping) components: best
//!    responses off one frozen aggregate, then the exact line search on
//!    the summed direction `d = Σ_i (ŷ_i − y_i)`:
//!    `θ* = clamp(−⟨y, d⟩/‖d‖², 0, 1)` — block optimality gives
//!    `⟨y, d⟩ ≤ 0`, so `d` descends until every block is optimal, and
//!    blockwise optimality over a Cartesian product is global optimality.
//! 3. **Global certificate pass** (the one sequential oracle pass): one
//!    greedy pass on the reduced function in direction `−y` yields the
//!    PAV-refined primal `ŵ`, the best level value `F̂(C)`, and the gap
//!    `P(ŵ) − D(y)` — identical bookkeeping to the monolithic solvers,
//!    so the IAES engine and the screening rules consume decomposed
//!    solves through the unchanged [`ProxSolver`] interface. Safety needs
//!    nothing more: every `y_i` only ever moves to (a convex combination
//!    with) a point of `B(F̂_i)`, so `y = Σ y_i ∈ B(F̂)` at every round
//!    and the gap is always a valid screening radius.
//!
//! Block backends: the O(s) taut-string prox for chain components
//! ([`super::chain`]), the PAV closed form for cardinality components,
//! the constant for modular ones, and a **per-component** min-norm solver
//! for generic components. The generic solver's corral is *carried across
//! rounds by translation*: between rounds only the modular offset `z_i`
//! changes, and `B(F̂_i + m_z)` moves by the translation `Δz`, so
//! [`MinNormPoint::reset_translated`] shifts the atoms instead of
//! regenerating the corral from one vertex. Across IAES contractions the
//! carried corral goes through the usual [`ProxSolver::reset_mapped`]
//! projection machinery (atoms regenerated from their induced orders —
//! never coordinate-projected, per the ROADMAP invariants) on a
//! per-component survivor map.
//!
//! Every round is **bitwise deterministic for any thread count**: all
//! responses in a phase read one frozen aggregate (disjoint-support
//! groups make even the in-place Gauss–Seidel applies coordinate-unique),
//! per-component state travels with the component rather than the worker,
//! and aggregation is sequential in fixed component order. Work is
//! distributed over a persistent condvar-parked [`WorkerPool`] with an
//! atomic work index and per-worker closed-form arenas, so the
//! `threads > 1` steady state is as allocation-free as `threads = 1`
//! (certified in `tests/zero_alloc.rs`).

use super::chain::{tv_prox_into, TautStringWorkspace};
use super::prox::{card_prox_into, CardProxWorkspace, OffsetFn};
use super::{ComponentKind, DecomposableFn};
use crate::linalg::vecops::{dot, norm2_sq};
use crate::lovasz::{greedy_base_vertex, ContractionMap, GreedyWorkspace};
use crate::runtime::pool::WorkerPool;
use crate::screening::iaes::{IaesEngine, IaesOptions, IaesReport};
use crate::solvers::minnorm::{MinNormOptions, MinNormPoint};
use crate::obs::trace::{KIND_CARDINALITY, KIND_CHAIN, KIND_GENERIC, KIND_MODULAR};
use crate::screening::checkpoint::SolveCheckpoint;
use crate::solvers::{ComponentState, PhaseNs, PrimalState, ProxSolver, SolverEvent, SolverState};
use crate::submodular::scaled::ScaledFn;
use crate::submodular::Submodular;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Options for [`BlockProxSolver`].
#[derive(Clone, Copy, Debug)]
pub struct DecomposeOptions {
    /// Worker threads (`0` = all available cores; always capped by the
    /// component count). The trajectory is bit-identical for every value.
    pub threads: usize,
    /// Wolfe-gap tolerance for generic (min-norm) block solves.
    pub inner_tol: f64,
    /// Iteration cap per generic block solve.
    pub max_inner: usize,
    /// Options of the per-component min-norm solvers.
    pub minnorm: MinNormOptions,
    /// Run exact simultaneous Gauss–Seidel over the decomposition's
    /// support-disjoint groups (`true`, default). `false` ignores the
    /// groups and runs the damped-Jacobi round for every component — the
    /// PR-3 baseline, kept for A/B tests and the `decompose/*` benches.
    /// Both schedules land on the same minimal minimizer.
    pub gauss_seidel: bool,
    /// Carry each generic component's min-norm corral across rounds by
    /// translating its atoms with the modular-shift delta
    /// ([`MinNormPoint::reset_translated`]) and across contractions via
    /// `reset_mapped` (`true`, default). `false` cold-resets every block
    /// solve from one vertex — the PR-3 baseline.
    pub warm_duals: bool,
}

impl Default for DecomposeOptions {
    fn default() -> Self {
        DecomposeOptions {
            threads: 0,
            inner_tol: 1e-11,
            max_inner: 256,
            minnorm: MinNormOptions::default(),
            gauss_seidel: true,
            warm_duals: true,
        }
    }
}

/// Per-component mutable state (one [`Mutex`] slot per component; locks
/// are uncontended — the atomic work index hands each slot to exactly
/// one worker per phase).
struct CompState<'a> {
    /// Lemma-1 view of the component at the current reduction.
    scaled: ScaledFn<'a>,
    /// Structural class (borrowed from the decomposition).
    kind: &'a ComponentKind,
    /// Local ids (component ground set) still in play, ascending.
    local_kept: Vec<usize>,
    /// Local ids certified active — the component's share of `Ê`
    /// (kept sorted; the chain reduction binary-searches it).
    local_base: Vec<usize>,
    /// Reduced-problem index of each kept element (parallel to
    /// `local_kept`).
    reduced_pos: Vec<usize>,
    /// Component dual `y_i` (local reduced coords).
    y: Vec<f64>,
    /// Best response `ŷ_i`.
    y_hat: Vec<f64>,
    /// Offset `z_i = y − y_i` restricted to the support.
    z: Vec<f64>,
    /// Scratch: warm direction / taut-string target / modular gather.
    w0: Vec<f64>,
    /// Offset at which `solver`'s corral currently lives (translation
    /// reference for the next round's `reset_translated`).
    z_prev: Vec<f64>,
    /// Per-component min-norm solver (generic components only, created on
    /// first use; the corral travels with the component, not the worker,
    /// which keeps warm starts schedule-independent).
    solver: Option<MinNormPoint>,
    /// `solver` holds valid state for the current reduction (cleared by
    /// cold resets and by contraction fallbacks).
    warm: bool,
    /// Contracted chain data (chain components): TV weight between
    /// consecutive kept locals (`n − 1` entries; 0 where the chain is
    /// severed)…
    chain_w: Vec<f64>,
    /// …and the boundary modular term (fixed-active neighbor ⇒ `−λ`,
    /// fixed-inactive ⇒ `+λ`), one entry per kept local.
    chain_m: Vec<f64>,
}

/// Persistent per-worker closed-form scratch: buffers grow to the largest
/// component each worker touches and are reused every round. (The
/// *stateful* generic solver lives in [`CompState`] instead — its warm
/// corral must follow the component, not the worker schedule.)
#[derive(Default)]
struct BlockArena {
    /// Cardinality closed-form buffers.
    card: CardProxWorkspace,
    /// Chain taut-string buffers.
    chain: TautStringWorkspace,
    /// Trace-timing gate (set via [`ProxSolver::set_trace_timing`]):
    /// when on, each best response is clocked into `kind_ns`.
    timing: bool,
    /// Nanoseconds inside `best_response`, split by component kind
    /// (`obs::trace::KIND_*` slots); drained by `take_phase_ns`.
    kind_ns: [u64; 4],
}

/// `kind_ns` slot of a component kind (`obs::trace::KIND_*` order).
fn kind_slot(kind: &ComponentKind) -> usize {
    match kind {
        ComponentKind::Modular { .. } => KIND_MODULAR,
        ComponentKind::Cardinality { .. } => KIND_CARDINALITY,
        ComponentKind::Chain { .. } => KIND_CHAIN,
        ComponentKind::Generic => KIND_GENERIC,
    }
}

/// Rebuild the contracted chain data for a chain component: the Lemma-1
/// reduction of a path cut is the path cut over consecutive kept pairs
/// (severed — weight 0 — across gaps) plus the boundary modular term.
fn rebuild_chain_reduction(st: &mut CompState<'_>) {
    let ComponentKind::Chain { w } = st.kind else {
        return;
    };
    let s = w.len() + 1;
    let n = st.local_kept.len();
    st.chain_m.clear();
    st.chain_m.resize(n, 0.0);
    st.chain_w.clear();
    for k in 0..n {
        let l = st.local_kept[k];
        if l > 0 && !(k > 0 && st.local_kept[k - 1] == l - 1) {
            let active = st.local_base.binary_search(&(l - 1)).is_ok();
            st.chain_m[k] += if active { -w[l - 1] } else { w[l - 1] };
        }
        if l + 1 < s && !(k + 1 < n && st.local_kept[k + 1] == l + 1) {
            let active = st.local_base.binary_search(&(l + 1)).is_ok();
            st.chain_m[k] += if active { -w[l] } else { w[l] };
        }
    }
    for k in 0..n.saturating_sub(1) {
        let l = st.local_kept[k];
        st.chain_w.push(if st.local_kept[k + 1] == l + 1 { w[l] } else { 0.0 });
    }
}

/// Cold dual (re)generation shared by `reset` and the non-carry arm of
/// `reset_mapped`: `y_i` ← greedy vertex of the (possibly contracted)
/// `B(F̂_i)` along the restricted `w_init` — feasible by construction —
/// and the component's warm-solver state is invalidated. `dirbuf`/`vbuf`
/// and the greedy workspace are caller-owned scratch (reused across
/// components so restarts stay allocation-free at the high-water mark).
fn regenerate_dual(
    st: &mut CompState<'_>,
    w_init: &[f64],
    dirbuf: &mut Vec<f64>,
    vbuf: &mut Vec<f64>,
    ws: &mut GreedyWorkspace,
) {
    let n = st.local_kept.len();
    st.warm = false;
    st.y.clear();
    st.y.resize(n, 0.0);
    if n == 0 {
        return;
    }
    dirbuf.clear();
    dirbuf.extend(st.reduced_pos.iter().map(|&pos| w_init[pos]));
    vbuf.clear();
    vbuf.resize(n, 0.0);
    greedy_base_vertex(&st.scaled, dirbuf, ws, vbuf);
    st.y.copy_from_slice(vbuf);
}

/// One component best response off the frozen aggregate `y_global`.
fn best_response(
    st: &mut CompState<'_>,
    arena: &mut BlockArena,
    y_global: &[f64],
    opts: &DecomposeOptions,
) {
    let n = st.local_kept.len();
    if n == 0 {
        return;
    }
    // Boundary-discipline clock: one read around the whole block solve,
    // only when tracing armed the gate (per-kind nanos for the trace).
    let t0 = arena.timing.then(std::time::Instant::now);
    for k in 0..n {
        st.z[k] = y_global[st.reduced_pos[k]] - st.y[k];
    }
    match st.kind {
        ComponentKind::Modular { m } => {
            // B(F̂_i) is the single point m̂ — the response is constant.
            for (k, &l) in st.local_kept.iter().enumerate() {
                st.y_hat[k] = m[l];
            }
        }
        ComponentKind::Cardinality { g, m } => {
            for (k, &l) in st.local_kept.iter().enumerate() {
                st.w0[k] = m[l];
            }
            card_prox_into(
                g,
                st.local_base.len(),
                &st.w0,
                &st.z,
                &mut arena.card,
                &mut st.y_hat,
            );
        }
        ComponentKind::Chain { .. } => {
            // min ½‖y + z‖² over B(ĉhain + m̂_b): substitute y = m̂_b + y°
            // (the modular part translates the polytope), project
            // t = −(z + m̂_b) onto the TV base polytope via the taut
            // string, and read the dual off the bends: y = m̂_b + t − x.
            for k in 0..n {
                st.w0[k] = -(st.z[k] + st.chain_m[k]);
            }
            {
                let CompState { w0, y_hat, chain_w, .. } = st;
                tv_prox_into(&w0[..n], &chain_w[..], &mut arena.chain, &mut y_hat[..n]);
            }
            for k in 0..n {
                st.y_hat[k] = st.chain_m[k] + st.w0[k] - st.y_hat[k];
            }
        }
        ComponentKind::Generic => {
            // min ½‖v + z‖² over B(F̂_i)  ⇔  min ½‖u‖² over B(F̂_i + m_z),
            // v = u − z. Warm direction: the current block iterate −(y+z).
            for k in 0..n {
                st.w0[k] = -(st.y[k] + st.z[k]);
            }
            {
                let CompState { scaled, z, w0, z_prev, solver, warm, .. } = st;
                let shifted = OffsetFn::new(&*scaled, &z[..n]);
                match solver {
                    Some(s) if *warm && opts.warm_duals => {
                        // The polytope moved by Δz = z − z_prev since the
                        // corral was valid: translate the atoms instead
                        // of regenerating from one vertex.
                        for k in 0..n {
                            z_prev[k] = z[k] - z_prev[k];
                        }
                        s.reset_translated(&shifted, &z_prev[..n], &w0[..n]);
                    }
                    Some(s) => s.reset(&shifted, &w0[..n]),
                    None => {
                        *solver =
                            Some(MinNormPoint::new(&shifted, opts.minnorm, Some(&w0[..n])));
                    }
                }
                *warm = true;
                z_prev[..n].copy_from_slice(&z[..n]);
                let s = solver.as_mut().expect("solver just installed");
                for _ in 0..opts.max_inner {
                    let ev = s.step(&shifted);
                    if ev.wolfe_gap <= opts.inner_tol {
                        break;
                    }
                }
            }
            let s = st.solver.as_ref().expect("solver just installed");
            for (k, (&u, &zk)) in s.s().iter().zip(&st.z).enumerate() {
                st.y_hat[k] = u - zk;
            }
            // Accept the response only if it improves the block objective
            // ½‖y + z‖²: an inner solve cut off by `max_inner` before
            // overtaking the incumbent would otherwise break the descent
            // property of both schedules (line-search ⟨y, d⟩ ≤ 0 for
            // Jacobi, monotone θ=1 applies for Gauss–Seidel). The
            // closed-form arms are exact and need no guard.
            let mut cur = 0.0;
            let mut new = 0.0;
            for k in 0..n {
                let zk = st.z[k];
                cur += (st.y[k] + zk) * (st.y[k] + zk);
                new += (st.y_hat[k] + zk) * (st.y_hat[k] + zk);
            }
            if new > cur {
                let (y_hat, y) = (&mut st.y_hat, &st.y);
                y_hat[..n].copy_from_slice(&y[..n]);
            }
        }
    }
    if let Some(t0) = t0 {
        arena.kind_ns[kind_slot(st.kind)] += t0.elapsed().as_nanos() as u64;
    }
}

/// The decomposable-dual solver behind the [`ProxSolver`] interface.
pub struct BlockProxSolver<'a> {
    dec: &'a DecomposableFn,
    opts: DecomposeOptions,
    /// Resolved worker count (≥ 1, capped by the component count).
    threads: usize,
    comps: Vec<Mutex<CompState<'a>>>,
    arenas: Vec<Mutex<BlockArena>>,
    /// Parked worker threads (`None` at `threads = 1`).
    pool: Option<WorkerPool>,
    /// All component indices (Jacobi-over-everything schedule).
    all_members: Vec<u32>,
    /// Aggregated dual `y = Σ_i y_i` (reduced coords) — always in `B(F̂)`.
    y: Vec<f64>,
    /// Aggregated best-response direction (Jacobi phase).
    d: Vec<f64>,
    shared: PrimalState,
    /// Scratch vertex buffer for the global certificate pass.
    q: Vec<f64>,
    /// Greedy workspace for per-component restart passes (kept separate
    /// from the shared one so component passes never clobber the global
    /// adaptive argsort warm start).
    comp_ws: GreedyWorkspace,
    /// Restart scratch: restricted direction / regenerated vertex.
    dirbuf: Vec<f64>,
    vbuf: Vec<f64>,
    /// Contraction scratch: a component's pre-contraction kept locals and
    /// its survivor map (buffers reused across components and events).
    oldkept: Vec<usize>,
    comp_map: ContractionMap,
}

impl<'a> BlockProxSolver<'a> {
    /// Build on the full problem and initialize like the monolithic
    /// solvers: every `y_i` is the greedy vertex of `B(F_i)` along
    /// `w_init` (zeros → index order).
    pub fn new(dec: &'a DecomposableFn, opts: DecomposeOptions) -> Self {
        let p = dec.ground_size();
        let threads = if opts.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            opts.threads
        };
        let threads = threads.min(dec.num_components()).max(1);
        let comps: Vec<Mutex<CompState<'a>>> = dec
            .components()
            .iter()
            .map(|c| {
                let s = c.support().len();
                Mutex::new(CompState {
                    scaled: ScaledFn::new(c.inner(), &[], (0..s).collect()),
                    kind: c.kind(),
                    local_kept: (0..s).collect(),
                    local_base: Vec::new(),
                    reduced_pos: c.support().to_vec(),
                    y: vec![0.0; s],
                    y_hat: vec![0.0; s],
                    z: vec![0.0; s],
                    w0: vec![0.0; s],
                    z_prev: vec![0.0; s],
                    solver: None,
                    warm: false,
                    chain_w: Vec::new(),
                    chain_m: Vec::new(),
                })
            })
            .collect();
        // Size every worker arena for the largest component up front:
        // work-stealing hands components to arbitrary workers, and a
        // first-touch grow on a worker thread would make the t > 1
        // allocation profile schedule-dependent.
        let max_support =
            dec.components().iter().map(|c| c.support().len()).max().unwrap_or(0);
        let arenas: Vec<Mutex<BlockArena>> = (0..threads)
            .map(|_| {
                let mut a = BlockArena::default();
                a.card.reserve(max_support);
                a.chain.reserve(max_support);
                Mutex::new(a)
            })
            .collect();
        let mut solver = BlockProxSolver {
            dec,
            opts,
            threads,
            comps,
            arenas,
            pool: (threads > 1).then(|| WorkerPool::new(threads)),
            all_members: (0..dec.num_components() as u32).collect(),
            y: vec![0.0; p],
            d: vec![0.0; p],
            shared: PrimalState::new(p),
            q: vec![0.0; p],
            comp_ws: GreedyWorkspace::new(0),
            dirbuf: Vec::new(),
            vbuf: Vec::new(),
            oldkept: Vec::new(),
            comp_map: ContractionMap::new(),
        };
        let w0 = vec![0.0; p];
        solver.reset(dec, &w0);
        solver
    }

    /// Resolved worker-thread count (diagnostics / reports).
    pub fn num_threads(&self) -> usize {
        self.threads
    }

    /// Number of components (diagnostics).
    pub fn num_components(&self) -> usize {
        self.comps.len()
    }

    /// The parked worker pool, when `threads > 1` (diagnostics — the
    /// zero-allocation certification samples per-worker counters here).
    pub fn pool(&self) -> Option<&WorkerPool> {
        self.pool.as_ref()
    }

    /// True when this solver schedules Gauss–Seidel group sweeps.
    pub fn uses_gauss_seidel(&self) -> bool {
        self.opts.gauss_seidel && self.dec.num_groups() > 0
    }

    /// Run the best responses of `members` off the frozen aggregate
    /// `self.y` — via the parked pool with an atomic work index when it
    /// pays, inline otherwise. Either way each component's result depends
    /// only on the frozen aggregate and its own state, so the outcome is
    /// identical for every thread count and schedule.
    fn sweep(&self, members: &[u32]) {
        if members.is_empty() {
            return;
        }
        match &self.pool {
            Some(pool) if members.len() > 1 => {
                let next = AtomicUsize::new(0);
                let comps = &self.comps;
                let arenas = &self.arenas;
                let y = &self.y[..];
                let opts = &self.opts;
                // Poison adoption is sound here: a `best_response` panic
                // always re-raises through `WorkerPool::run` before any
                // later phase re-locks these mutexes, so adopting never
                // launders torn state — it only keeps the sibling lanes'
                // unwinds from masking the original panic with a
                // secondary `PoisonError` one (PR-6 lock discipline).
                pool.run(&|w: usize| {
                    let mut arena = arenas[w].lock().unwrap_or_else(|e| e.into_inner());
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= members.len() {
                            break;
                        }
                        let mut st = comps[members[i] as usize]
                            .lock()
                            .unwrap_or_else(|e| e.into_inner());
                        best_response(&mut st, &mut arena, y, opts);
                    }
                });
            }
            _ => {
                let mut arena = self.arenas[0].lock().unwrap_or_else(|e| e.into_inner());
                for &ci in members {
                    let mut st =
                        self.comps[ci as usize].lock().unwrap_or_else(|e| e.into_inner());
                    best_response(&mut st, &mut arena, &self.y, &self.opts);
                }
            }
        }
    }

    /// `y = Σ_i y_i`, scattered in fixed component order (deterministic).
    fn aggregate(&mut self) {
        self.y.iter_mut().for_each(|v| *v = 0.0);
        for slot in self.comps.iter_mut() {
            let st = slot.get_mut().expect("component poisoned");
            for (k, &pos) in st.reduced_pos.iter().enumerate() {
                self.y[pos] += st.y[k];
            }
        }
    }

    /// Algorithm-2 step-14 bookkeeping against the *aggregated* dual
    /// point: adopt `w_init`, one global greedy pass, gap by weak duality
    /// (valid for any `y ∈ B(F̂)`).
    fn close_gap(&mut self, f: &dyn Submodular, w_init: &[f64]) {
        let p = f.ground_size();
        let mut q = std::mem::take(&mut self.q);
        q.clear();
        q.resize(p, 0.0);
        let f_w = self.shared.reset_primal(f, w_init, &mut q);
        self.q = q;
        self.shared.gap = f_w + 0.5 * norm2_sq(w_init) + 0.5 * norm2_sq(&self.y);
    }
}

impl ProxSolver for BlockProxSolver<'_> {
    fn step(&mut self, f: &dyn Submodular) -> SolverEvent {
        let p = f.ground_size();
        assert_eq!(p, self.y.len(), "solver/problem size mismatch");
        // (1) Exact simultaneous Gauss–Seidel over support-disjoint
        // groups: responses off the current aggregate, applied undamped.
        // Disjoint supports make every coordinate update unique, so the
        // in-place aggregate refresh is deterministic for any schedule.
        if self.opts.gauss_seidel {
            for g in 0..self.dec.num_groups() {
                let members = self.dec.group(g);
                self.sweep(members);
                for &ci in members {
                    let st = self.comps[ci as usize].get_mut().expect("component poisoned");
                    for (k, &pos) in st.reduced_pos.iter().enumerate() {
                        let d = st.y_hat[k] - st.y[k];
                        if d != 0.0 {
                            self.y[pos] += d;
                        }
                        st.y[k] = st.y_hat[k];
                    }
                }
            }
        }
        // (2) Damped Jacobi for the overlapping remainder (all components
        // when Gauss–Seidel is off): frozen aggregate, exact line search.
        let jacobi: &[u32] = if self.opts.gauss_seidel {
            self.dec.ungrouped()
        } else {
            &self.all_members
        };
        if !jacobi.is_empty() {
            self.sweep(jacobi);
            self.d.iter_mut().for_each(|v| *v = 0.0);
            for &ci in jacobi {
                let st = self.comps[ci as usize].get_mut().expect("component poisoned");
                for (k, &pos) in st.reduced_pos.iter().enumerate() {
                    self.d[pos] += st.y_hat[k] - st.y[k];
                }
            }
            let denom = norm2_sq(&self.d);
            if denom > 0.0 {
                let theta = (-dot(&self.y, &self.d) / denom).clamp(0.0, 1.0);
                if theta > 0.0 {
                    for &ci in jacobi {
                        let st =
                            self.comps[ci as usize].get_mut().expect("component poisoned");
                        for k in 0..st.y.len() {
                            st.y[k] += theta * (st.y_hat[k] - st.y[k]);
                        }
                    }
                }
            }
        }
        self.aggregate();
        // (3) Global certificate pass: primal refinement + gap.
        let mut q = std::mem::take(&mut self.q);
        let (_info, f_w) = self.shared.greedy_and_refine(f, &self.y, &mut q);
        let wolfe_gap = norm2_sq(&self.y) - dot(&self.y, &q);
        self.q = q;
        crate::lovasz::debug_assert_dual_feasible(f, &self.y, "BlockProxSolver::step");
        self.shared.finish_step(f_w, &self.y, wolfe_gap)
    }

    fn s(&self) -> &[f64] {
        &self.y
    }

    fn w(&self) -> &[f64] {
        &self.shared.w
    }

    fn gap(&self) -> f64 {
        self.shared.gap
    }

    fn best_level_value(&self) -> f64 {
        self.shared.fc
    }

    fn iters(&self) -> usize {
        self.shared.iters
    }

    fn reset(&mut self, f: &dyn Submodular, w_init: &[f64]) {
        let p = f.ground_size();
        assert_eq!(
            p,
            self.dec.ground_size(),
            "BlockProxSolver::reset only supports the full problem; IAES \
             reductions must arrive via reset_mapped (run the engine with \
             warm_restart = true — solve_decomposed does)"
        );
        for (slot, comp) in self.comps.iter_mut().zip(self.dec.components()) {
            let st = slot.get_mut().expect("component poisoned");
            let s = comp.support().len();
            st.local_base.clear();
            st.local_kept.clear();
            st.local_kept.extend(0..s);
            st.reduced_pos.clear();
            st.reduced_pos.extend_from_slice(comp.support());
            st.y_hat.clear();
            st.y_hat.resize(s, 0.0);
            st.z.clear();
            st.z.resize(s, 0.0);
            st.w0.clear();
            st.w0.resize(s, 0.0);
            st.z_prev.clear();
            st.z_prev.resize(s, 0.0);
            st.scaled.set_reduction(&[], &st.local_kept);
            rebuild_chain_reduction(st);
            // Cold restarts carry no dual state: y_i is the greedy vertex
            // along the restricted w_init.
            regenerate_dual(st, w_init, &mut self.dirbuf, &mut self.vbuf, &mut self.comp_ws);
        }
        self.y.clear();
        self.y.resize(p, 0.0);
        self.d.clear();
        self.d.resize(p, 0.0);
        self.aggregate();
        self.close_gap(f, w_init);
        crate::lovasz::debug_assert_dual_feasible(f, &self.y, "BlockProxSolver::reset");
    }

    fn reset_mapped(&mut self, f: &dyn Submodular, w_init: &[f64], map: &ContractionMap) {
        let p = f.ground_size();
        if map.new_len() != p || self.y.len() != map.old_len() {
            // Stale map (fresh solver / unrelated problem): only the
            // full-problem reset is valid.
            self.reset(f, w_init);
            return;
        }
        // Thread the contraction through every component: survivors keep
        // their (renumbered) reduced position, removed-to-active elements
        // join the component's base, removed-to-inactive elements leave.
        // Generic components with a warm corral go through the standard
        // reset_mapped projection on their own survivor map (atoms
        // regenerated from induced orders — never coordinate-projected);
        // everything else regenerates its dual as a greedy vertex of the
        // contracted polytope. Both give `y_i ∈ B(F̂_i)` by construction.
        self.comp_map.remap_argsort = map.remap_argsort;
        for slot in self.comps.iter_mut() {
            let st = slot.get_mut().expect("component poisoned");
            self.oldkept.clear();
            self.oldkept.extend_from_slice(&st.local_kept);
            let mut w = 0usize;
            for k in 0..st.local_kept.len() {
                let r = st.reduced_pos[k];
                match map.new_index(r) {
                    Some(nr) => {
                        st.local_kept[w] = st.local_kept[k];
                        st.reduced_pos[w] = nr;
                        w += 1;
                    }
                    None => {
                        if map.went_active(r) {
                            st.local_base.push(st.local_kept[k]);
                        }
                    }
                }
            }
            st.local_kept.truncate(w);
            st.reduced_pos.truncate(w);
            st.local_base.sort_unstable();
            st.y_hat.truncate(w);
            st.z.truncate(w);
            st.w0.truncate(w);
            st.z_prev.truncate(w);
            st.scaled.set_reduction(&st.local_base, &st.local_kept);
            rebuild_chain_reduction(st);
            let n = w;
            let carry = n > 0
                && self.opts.warm_duals
                && st.warm
                && matches!(st.kind, ComponentKind::Generic)
                && st.solver.is_some();
            if carry {
                self.comp_map.rebuild(&self.oldkept, &st.local_kept);
                self.dirbuf.clear();
                self.dirbuf.extend(st.reduced_pos.iter().map(|&pos| w_init[pos]));
                let CompState { scaled, solver, y, z_prev, .. } = st;
                let s = solver.as_mut().expect("carried solver");
                s.reset_mapped(&*scaled, &self.dirbuf, &self.comp_map);
                y.clear();
                y.resize(n, 0.0);
                y.copy_from_slice(s.s());
                // The carried corral now lives on the *unshifted*
                // contracted polytope; the next round's translation
                // starts from z = 0.
                z_prev.iter_mut().for_each(|v| *v = 0.0);
            } else {
                regenerate_dual(st, w_init, &mut self.dirbuf, &mut self.vbuf, &mut self.comp_ws);
            }
        }
        // Warm-start the global argsort through the survivor map, rebuild
        // the aggregate, and close the gap against it.
        self.shared.greedy_ws.contract(map);
        self.y.truncate(p);
        self.d.truncate(p);
        self.aggregate();
        self.close_gap(f, w_init);
        crate::lovasz::debug_assert_dual_feasible(f, &self.y, "BlockProxSolver::reset");
    }

    fn export_state(&self) -> Option<SolverState> {
        // Decomposed snapshots carry no corral: the per-component inner
        // solvers rebuild their corrals on the first best response. What
        // a safe resume needs is each feasible block dual `y_i` (the
        // aggregate `y = Σ y_i ∈ B(F̂)` is then feasible by construction)
        // plus the translation reference `z_prev` for format fidelity.
        let mut components = Vec::with_capacity(self.comps.len());
        for slot in &self.comps {
            let st = slot.lock().unwrap_or_else(|e| e.into_inner());
            components.push(ComponentState {
                y: st.y.clone(),
                z_prev: st.z_prev.clone(),
            });
        }
        Some(SolverState {
            kind: self.name().to_string(),
            orders: Vec::new(),
            weights: Vec::new(),
            dual: self.y.clone(),
            components,
        })
    }

    fn restore(
        &mut self,
        f: &dyn Submodular,
        w_init: &[f64],
        state: &SolverState,
    ) -> anyhow::Result<()> {
        // Called after `reset_mapped` rebuilt every component's reduction
        // for the checkpointed active/kept partition: the restored `y_i`
        // were feasible in exactly these contracted `B(F̂_i)` when the
        // boundary was snapshotted, so copying them back re-enters the
        // product polytope without touching any oracle.
        if state.kind != self.name() {
            anyhow::bail!(
                "snapshot kind '{}' does not match solver '{}'",
                state.kind,
                self.name()
            );
        }
        if !state.orders.is_empty() || !state.weights.is_empty() {
            anyhow::bail!(
                "decomposed snapshot must not carry a corral \
                 ({} orders, {} weights)",
                state.orders.len(),
                state.weights.len()
            );
        }
        if state.components.len() != self.comps.len() {
            anyhow::bail!(
                "snapshot has {} components, decomposition has {}",
                state.components.len(),
                self.comps.len()
            );
        }
        let p = f.ground_size();
        if state.dual.len() != p || w_init.len() != p || self.y.len() != p {
            anyhow::bail!(
                "snapshot dual has {} entries, reduced problem has {}",
                state.dual.len(),
                p
            );
        }
        for (ci, (slot, cs)) in self.comps.iter_mut().zip(&state.components).enumerate() {
            let st = slot.get_mut().unwrap_or_else(|e| e.into_inner());
            let n = st.local_kept.len();
            if cs.y.len() != n || cs.z_prev.len() != n {
                anyhow::bail!(
                    "component {ci}: snapshot carries {} duals, reduction \
                     keeps {n} elements (corrupted or mismatched checkpoint)",
                    cs.y.len()
                );
            }
            st.y.clear();
            st.y.extend_from_slice(&cs.y);
            st.z_prev.clear();
            st.z_prev.extend_from_slice(&cs.z_prev);
            // The inner corral was not snapshotted: the next best response
            // cold-resets the block solver from the restored iterate.
            st.warm = false;
        }
        self.aggregate();
        let mut err = 0.0f64;
        for (a, b) in self.y.iter().zip(&state.dual) {
            let d = (a - b).abs();
            if d > err {
                err = d;
            }
        }
        if !(err <= 1e-6) {
            anyhow::bail!(
                "regenerated aggregate dual deviates from snapshot by \
                 {err:.3e} (corrupted or mismatched checkpoint)"
            );
        }
        self.close_gap(f, w_init);
        crate::lovasz::debug_assert_dual_feasible(f, &self.y, "BlockProxSolver::restore");
        Ok(())
    }

    fn greedy_full_sorts(&self) -> u64 {
        self.shared.greedy_ws.full_sorts
    }

    fn set_trace_timing(&mut self, enabled: bool) {
        self.shared.trace_timing = enabled;
        for slot in &mut self.arenas {
            slot.get_mut().unwrap_or_else(|e| e.into_inner()).timing = enabled;
        }
    }

    fn take_phase_ns(&mut self) -> PhaseNs {
        let mut out = PhaseNs { oracle_ns: self.shared.take_oracle_ns(), kind_ns: [0; 4] };
        for slot in &mut self.arenas {
            let arena = slot.get_mut().unwrap_or_else(|e| e.into_inner());
            for (acc, x) in out.kind_ns.iter_mut().zip(&mut arena.kind_ns) {
                *acc += std::mem::take(x);
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "block-prox"
    }
}

/// Run Algorithm 2 on a decomposable function with the block solver.
/// Forces contraction-aware warm restarts (the block solver threads
/// reductions through per-component [`ContractionMap`]s and has no cold
/// reduced-rebuild path) and records the resolved worker count in the
/// report (`block_threads`).
pub fn solve_decomposed(
    f: &DecomposableFn,
    opts: &IaesOptions,
    dopts: DecomposeOptions,
) -> anyhow::Result<IaesReport> {
    let mut opts = opts.clone();
    opts.warm_restart = true;
    let solver = BlockProxSolver::new(f, dopts);
    let workers = solver.num_threads();
    let mut report = IaesEngine::with_solver(f, opts, Box::new(solver)).run()?;
    report.block_threads = Some(workers);
    Ok(report)
}

/// [`solve_decomposed`], resumed from a boundary snapshot: the engine
/// replays the checkpointed reduction through the per-component
/// contraction machinery, the block solver re-enters the product polytope
/// from the stored `y_i`, and the solve continues from the snapshotted
/// major iteration.
pub fn solve_decomposed_resumed(
    f: &DecomposableFn,
    opts: &IaesOptions,
    dopts: DecomposeOptions,
    ck: SolveCheckpoint,
) -> anyhow::Result<IaesReport> {
    let mut opts = opts.clone();
    opts.warm_restart = true;
    let solver = BlockProxSolver::new(f, dopts);
    let workers = solver.num_threads();
    let mut report = IaesEngine::with_solver(f, opts, Box::new(solver))
        .resume_from(ck)?
        .run()?;
    report.block_threads = Some(workers);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brute::brute_force_sfm;
    use crate::decompose::builders::{grid_cut_components, star_components};
    use crate::decompose::Component;
    use crate::lovasz::{in_base_polytope, sup_level_set};
    use crate::rng::Pcg64;
    use crate::workloads::grid::eight_neighbor_edges;

    fn random_star_decomposition(p: usize, rng: &mut Pcg64) -> DecomposableFn {
        let mut k = vec![0.0; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let w = rng.uniform(0.0, 1.0);
                k[i * p + j] = w;
                k[j * p + i] = w;
            }
        }
        let unary = rng.uniform_vec(p, -2.0, 2.0);
        star_components(p, |i, j| k[i * p + j], unary)
    }

    fn random_grid_decomposition(h: usize, w: usize, seed: u64) -> DecomposableFn {
        let mut rng = Pcg64::seeded(seed);
        let edges: Vec<(usize, usize, f64)> = eight_neighbor_edges(h, w)
            .into_iter()
            .map(|(a, b)| (a, b, rng.uniform(0.0, 1.2)))
            .collect();
        let unary = rng.uniform_vec(h * w, -1.5, 1.5);
        grid_cut_components(h, w, &edges, unary).unwrap()
    }

    fn run(solver: &mut BlockProxSolver<'_>, f: &dyn Submodular, iters: usize, eps: f64) {
        for _ in 0..iters {
            let ev = solver.step(f);
            if ev.gap < eps {
                break;
            }
        }
    }

    #[test]
    fn block_solver_converges_on_star_decomposition() {
        let mut rng = Pcg64::seeded(41);
        let p = 9;
        let dec = random_star_decomposition(p, &mut rng);
        let mut solver = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 1,
            ..Default::default()
        });
        run(&mut solver, &dec, 500, 1e-10);
        assert!(solver.gap() < 1e-10, "gap {}", solver.gap());
        // The aggregate stays feasible and recovers the minimal minimizer.
        assert!(in_base_polytope(&dec, solver.s(), 1e-7));
        let brute = brute_force_sfm(&dec, 1e-9);
        assert_eq!(sup_level_set(solver.w(), 0.0), brute.minimal);
    }

    #[test]
    fn gauss_seidel_converges_on_grid_decomposition() {
        // Grid decompositions are fully grouped: the whole round is the
        // exact Gauss–Seidel path (chain taut-string + modular constant).
        let (h, w) = (3, 4);
        let dec = random_grid_decomposition(h, w, 97);
        let mut solver = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 1,
            ..Default::default()
        });
        assert!(solver.uses_gauss_seidel());
        run(&mut solver, &dec, 500, 1e-10);
        assert!(solver.gap() < 1e-10, "gap {}", solver.gap());
        assert!(in_base_polytope(&dec, solver.s(), 1e-7));
        let brute = brute_force_sfm(&dec, 1e-9);
        assert_eq!(sup_level_set(solver.w(), 0.0), brute.minimal);
    }

    #[test]
    fn gauss_seidel_rounds_are_monotone_descent() {
        // θ=1 group applies are exact block-coordinate steps: ½‖y‖² must
        // never increase, and the schedule must converge within the cap.
        // (Round-count *advantage* over Jacobi is typical but not a
        // theorem — the benches measure it; the tests only pin descent
        // and agreement.)
        let (h, w) = (4, 4);
        let dec = random_grid_decomposition(h, w, 202);
        let mut gs = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 1,
            ..Default::default()
        });
        let mut last = f64::INFINITY;
        let mut converged = false;
        for _ in 0..400 {
            let ev = gs.step(&dec);
            let norm = norm2_sq(gs.s());
            assert!(norm <= last + 1e-9, "GS round increased ‖y‖²");
            last = norm;
            if ev.gap < 1e-9 {
                converged = true;
                break;
            }
        }
        assert!(converged, "GS schedule did not converge in 400 rounds");
    }

    #[test]
    fn aggregate_dual_feasible_every_round() {
        let mut rng = Pcg64::seeded(43);
        let p = 8;
        let dec = random_star_decomposition(p, &mut rng);
        let mut solver = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 1,
            ..Default::default()
        });
        for _ in 0..20 {
            let ev = solver.step(&dec);
            assert!(in_base_polytope(&dec, solver.s(), 1e-7), "y left B(F)");
            assert!(ev.gap >= -1e-9, "negative gap {}", ev.gap);
        }
        // Same invariant on the Gauss–Seidel grid path.
        let dec = random_grid_decomposition(3, 3, 44);
        let mut solver = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 1,
            ..Default::default()
        });
        for _ in 0..20 {
            let ev = solver.step(&dec);
            assert!(in_base_polytope(&dec, solver.s(), 1e-7), "GS y left B(F)");
            assert!(ev.gap >= -1e-9, "negative gap {}", ev.gap);
        }
    }

    #[test]
    fn thread_counts_are_bitwise_identical() {
        let mut rng = Pcg64::seeded(47);
        let p = 10;
        let dec = random_star_decomposition(p, &mut rng);
        let mut one = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 1,
            ..Default::default()
        });
        let mut four = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 4,
            ..Default::default()
        });
        for it in 0..40 {
            let a = one.step(&dec);
            let b = four.step(&dec);
            assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "gap differs at {it}");
            for (x, y) in one.s().iter().zip(four.s()) {
                assert_eq!(x.to_bits(), y.to_bits(), "dual differs at {it}");
            }
            for (x, y) in one.w().iter().zip(four.w()) {
                assert_eq!(x.to_bits(), y.to_bits(), "primal differs at {it}");
            }
        }
    }

    #[test]
    fn gauss_seidel_thread_counts_are_bitwise_identical() {
        let dec = random_grid_decomposition(4, 4, 777);
        let mut one = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 1,
            ..Default::default()
        });
        let mut four = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 4,
            ..Default::default()
        });
        assert!(one.uses_gauss_seidel() && four.uses_gauss_seidel());
        for it in 0..40 {
            let a = one.step(&dec);
            let b = four.step(&dec);
            assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "GS gap differs at {it}");
            for (x, y) in one.s().iter().zip(four.s()) {
                assert_eq!(x.to_bits(), y.to_bits(), "GS dual differs at {it}");
            }
        }
    }

    #[test]
    fn warm_duals_match_cold_duals_on_the_minimizer() {
        // Translated-corral warm starts change the trajectory, never the
        // answer: same minimal minimizer, bitwise-equal set.
        let mut rng = Pcg64::seeded(53);
        for p in [8usize, 10] {
            let dec = random_star_decomposition(p, &mut rng);
            let brute = brute_force_sfm(&dec, 1e-9);
            for warm in [true, false] {
                let mut solver = BlockProxSolver::new(&dec, DecomposeOptions {
                    threads: 1,
                    warm_duals: warm,
                    ..Default::default()
                });
                run(&mut solver, &dec, 800, 1e-10);
                assert!(solver.gap() < 1e-10, "warm={warm}: gap {}", solver.gap());
                assert!(in_base_polytope(&dec, solver.s(), 1e-7), "warm={warm}");
                assert_eq!(
                    sup_level_set(solver.w(), 0.0),
                    brute.minimal,
                    "warm={warm}: wrong minimal minimizer"
                );
            }
        }
    }

    #[test]
    fn reset_mapped_threads_contraction_through_components() {
        let mut rng = Pcg64::seeded(53);
        let p = 10;
        let dec = random_star_decomposition(p, &mut rng);
        let kept: Vec<usize> = (0..p).collect();
        let mut scaled = ScaledFn::new(&dec, &[], kept.clone());
        let mut solver = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 1,
            ..Default::default()
        });
        for _ in 0..8 {
            solver.step(&scaled);
        }
        // Certify element 2 active, elements 5 and 8 inactive.
        let new_kept: Vec<usize> =
            kept.iter().copied().filter(|&i| ![2, 5, 8].contains(&i)).collect();
        let w_surv: Vec<f64> = new_kept.iter().map(|&i| solver.w()[i]).collect();
        let mut map = ContractionMap::new();
        scaled.contract(&[2], &new_kept, &mut map);
        solver.reset_mapped(&scaled, &w_surv, &map);
        assert_eq!(solver.s().len(), new_kept.len());
        // Feasible in the contracted polytope, valid gap, and the solver
        // still converges to the reduced optimum.
        assert!(in_base_polytope(&scaled, solver.s(), 1e-7));
        assert!(solver.gap() >= -1e-9);
        let mut gap = f64::INFINITY;
        for _ in 0..500 {
            gap = solver.step(&scaled).gap;
            if gap < 1e-9 {
                break;
            }
        }
        assert!(gap < 1e-9, "stalled after contraction: gap {gap}");
        let brute = brute_force_sfm(&scaled, 1e-9);
        let a = sup_level_set(solver.w(), 0.0);
        let mut set = vec![false; new_kept.len()];
        for &i in &a {
            set[i] = true;
        }
        assert!((scaled.eval(&set) - brute.minimum).abs() < 1e-6);
    }

    #[test]
    fn reset_mapped_contracts_chain_components() {
        // Same contraction drill on a fully-grouped grid: chain reductions
        // (boundary modular + severed links) must stay exact.
        let dec = random_grid_decomposition(3, 3, 808);
        let p = 9;
        let kept: Vec<usize> = (0..p).collect();
        let mut scaled = ScaledFn::new(&dec, &[], kept.clone());
        let mut solver = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 2,
            ..Default::default()
        });
        for _ in 0..6 {
            solver.step(&scaled);
        }
        let new_kept: Vec<usize> =
            kept.iter().copied().filter(|&i| ![1, 4].contains(&i)).collect();
        let w_surv: Vec<f64> = new_kept.iter().map(|&i| solver.w()[i]).collect();
        let mut map = ContractionMap::new();
        scaled.contract(&[4], &new_kept, &mut map);
        solver.reset_mapped(&scaled, &w_surv, &map);
        assert!(in_base_polytope(&scaled, solver.s(), 1e-7), "chain y left B(F̂)");
        assert!(solver.gap() >= -1e-9);
        let mut gap = f64::INFINITY;
        for _ in 0..500 {
            gap = solver.step(&scaled).gap;
            if gap < 1e-9 {
                break;
            }
        }
        assert!(gap < 1e-9, "chain contraction stalled: gap {gap}");
        let brute = brute_force_sfm(&scaled, 1e-9);
        let a = sup_level_set(solver.w(), 0.0);
        let mut set = vec![false; new_kept.len()];
        for &i in &a {
            set[i] = true;
        }
        assert!((scaled.eval(&set) - brute.minimum).abs() < 1e-6);
    }

    #[test]
    fn solve_decomposed_matches_brute_force() {
        let mut rng = Pcg64::seeded(59);
        for p in [7usize, 9, 11] {
            let dec = random_star_decomposition(p, &mut rng);
            let brute = brute_force_sfm(&dec, 1e-9);
            let report = solve_decomposed(
                &dec,
                &IaesOptions { eps: 1e-9, ..Default::default() },
                DecomposeOptions { threads: 2, ..Default::default() },
            )
            .unwrap();
            assert!(
                (report.minimum - brute.minimum).abs() < 1e-6,
                "p={p}: decomposed {} vs brute {}",
                report.minimum,
                brute.minimum
            );
            assert_eq!(report.block_threads, Some(2), "worker count missing");
        }
    }

    #[test]
    fn decomposed_checkpoint_resume_reaches_the_minimizer() {
        // Mid-solve snapshot on the block path: truncate, resume in a
        // fresh engine + fresh block solver, land on the brute minimum.
        use crate::screening::checkpoint::{CheckpointConf, CheckpointSink};
        let mut rng = Pcg64::seeded(67);
        for (p, threads) in [(9usize, 1usize), (11, 4)] {
            let dec = random_star_decomposition(p, &mut rng);
            let brute = brute_force_sfm(&dec, 1e-9);
            let base = IaesOptions { eps: 1e-9, ..Default::default() };
            let sink = CheckpointSink::in_memory();
            let truncated = IaesOptions {
                max_iters: 3,
                checkpoint: Some(CheckpointConf::new(sink.clone(), 1)),
                ..base.clone()
            };
            let dopts = DecomposeOptions { threads, ..Default::default() };
            solve_decomposed(&dec, &truncated, dopts).unwrap();
            let Some(ck) = sink.latest() else {
                continue; // converged before the first boundary was due
            };
            ck.validate().unwrap();
            assert!(
                ck.solver.as_ref().is_some_and(|s| !s.components.is_empty()),
                "decomposed snapshot must carry component duals"
            );
            // Safety of the snapshotted certificates against brute force.
            for &a in &ck.active {
                assert!(brute.minimal.contains(&a), "ckpt active {a} unsafe");
            }
            for &i in &ck.inactive {
                assert!(!brute.maximal.contains(&i), "ckpt inactive {i} unsafe");
            }
            // Round-trip through the wire format, as a real resume would.
            let ck = SolveCheckpoint::from_jsonl(&ck.to_jsonl()).unwrap();
            let report = solve_decomposed_resumed(&dec, &base, dopts, ck).unwrap();
            assert!(
                (report.minimum - brute.minimum).abs() < 1e-6,
                "p={p} t={threads}: resumed {} vs brute {}",
                report.minimum,
                brute.minimum
            );
            assert_eq!(report.block_threads, Some(threads));
        }
    }

    #[test]
    fn block_restore_rejects_mismatched_snapshots() {
        let mut rng = Pcg64::seeded(71);
        let dec = random_star_decomposition(8, &mut rng);
        let mut solver = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 1,
            ..Default::default()
        });
        for _ in 0..4 {
            solver.step(&dec);
        }
        let state = solver.export_state().expect("block solver exports state");
        assert_eq!(state.kind, "block-prox");
        assert_eq!(state.components.len(), solver.num_components());
        // Tampered aggregate dual → integrity gate.
        let mut bad = state.clone();
        bad.dual[0] += 0.5;
        let w0 = vec![0.0; dec.ground_size()];
        let err = solver.restore(&dec, &w0, &bad).unwrap_err();
        assert!(err.to_string().contains("deviates from snapshot"), "got: {err}");
        // Wrong component count → named rejection.
        let mut bad = state.clone();
        bad.components.pop();
        let err = solver.restore(&dec, &w0, &bad).unwrap_err();
        assert!(err.to_string().contains("components"), "got: {err}");
        // A faithful snapshot restores and the solver still converges.
        solver.restore(&dec, &w0, &state).unwrap();
        assert!(in_base_polytope(&dec, solver.s(), 1e-7));
        run(&mut solver, &dec, 800, 1e-10);
        assert!(solver.gap() < 1e-10, "gap {}", solver.gap());
        let brute = brute_force_sfm(&dec, 1e-9);
        assert_eq!(sup_level_set(solver.w(), 0.0), brute.minimal);
    }

    #[test]
    fn default_threads_resolve_to_cores_capped_by_components() {
        let mut rng = Pcg64::seeded(61);
        let dec = random_star_decomposition(6, &mut rng);
        let solver = BlockProxSolver::new(&dec, DecomposeOptions::default());
        let cores =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert_eq!(
            solver.num_threads(),
            cores.min(dec.num_components()).max(1),
            "threads = 0 must mean all cores, capped by component count"
        );
        // An explicit oversubscription is capped too.
        let solver = BlockProxSolver::new(&dec, DecomposeOptions {
            threads: 64,
            ..Default::default()
        });
        assert!(solver.num_threads() <= dec.num_components());
    }

    #[test]
    fn cardinality_components_use_pav_path() {
        // A sum of overlapping cardinality terms + modular tilt solved by
        // the closed-form path only (no generic component at all).
        let mut rng = Pcg64::seeded(61);
        let p = 10;
        let h = 7;
        let g1: Vec<f64> = (0..=h).map(|k| 1.1 * (k as f64).sqrt()).collect();
        let g2: Vec<f64> = (0..=h).map(|k| 0.6 * (k as f64).sqrt()).collect();
        let dec = DecomposableFn::new(
            p,
            vec![
                Component::cardinality(g1, rng.uniform_vec(h, -0.8, 0.8), (0..h).collect()),
                Component::cardinality(
                    g2,
                    rng.uniform_vec(h, -0.8, 0.8),
                    (p - h..p).collect(),
                ),
                Component::modular(rng.uniform_vec(p, -1.0, 1.0), (0..p).collect()),
            ],
        );
        let brute = brute_force_sfm(&dec, 1e-9);
        let report = solve_decomposed(
            &dec,
            &IaesOptions { eps: 1e-9, ..Default::default() },
            DecomposeOptions { threads: 1, ..Default::default() },
        )
        .unwrap();
        assert!((report.minimum - brute.minimum).abs() < 1e-6);
    }
}
