//! Cholesky factorizations: batch, and incrementally extended/downdated.
//!
//! Two consumers drive the design:
//!
//! * [`Cholesky`] — factor a full SPD matrix once and solve. Used by the
//!   baseline min-norm affine-minimization step and by tests.
//! * [`IncrementalCholesky`] — maintain `L` with `A = L Lᵀ` under two
//!   operations: `push` (append one row/column — O(n²)) and `remove`
//!   (delete one row/column, restoring triangularity with Givens
//!   rotations — O(n²)). Used by (a) the Gaussian-process
//!   mutual-information oracle, which needs log-determinants of *nested*
//!   principal minors along a greedy order, and (b) the optimized
//!   min-norm-point corral, which adds one base vertex per major cycle and
//!   evicts vertices whose affine coefficient hits zero.

use super::Mat;

/// Batch Cholesky factorization `A = L Lᵀ` (lower-triangular `L`).
#[derive(Clone, Debug)]
pub struct Cholesky {
    /// Lower-triangular factor, row-major dense (upper part zero).
    pub l: Mat,
}

impl Cholesky {
    /// Factor an SPD matrix. Adds `jitter` to the diagonal if a pivot is
    /// non-positive (returns `None` only if even the jittered pivot fails).
    pub fn factor(a: &Mat, jitter: f64) -> Option<Self> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    let mut d = s;
                    if d <= 0.0 {
                        d = s + jitter;
                    }
                    if d <= 0.0 {
                        return None;
                    }
                    l[(i, i)] = d.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Some(Cholesky { l })
    }

    /// Solve `A x = b` via forward/back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        let mut y = b.to_vec();
        // L y = b
        for i in 0..n {
            let mut s = y[i];
            let row = self.l.row(i);
            for k in 0..i {
                s -= row[k] * y[k];
            }
            y[i] = s / row[i];
        }
        // Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.l[(k, i)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
        y
    }

    /// `log det A = 2 Σ log L_ii`.
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

/// Incrementally maintained Cholesky factor of a growing/shrinking SPD
/// matrix, stored as **packed lower-triangular rows in one contiguous
/// `Vec<f64>`** (row `i` at offset `i(i+1)/2`, length `i+1`).
///
/// The flat layout is what makes the solver hot loop allocation-free:
/// `push` appends to the packed vector (amortized zero-alloc once the
/// high-water capacity is reached), `remove` compacts in place, and
/// [`reset`](Self::reset) empties the factor while keeping the capacity —
/// the per-pass factors of the GP mutual-information oracle and the
/// min-norm corral Gram factor both reuse one buffer for their entire
/// lifetime. All operations perform the same floating-point arithmetic in
/// the same order as the classic ragged-row implementation they replace.
#[derive(Clone, Debug, Default)]
pub struct IncrementalCholesky {
    /// Packed rows: `data[off(i) + j] = L[i][j]` for `j <= i`.
    data: Vec<f64>,
    /// Current dimension.
    n: usize,
    /// Scratch for [`retain`](Self::retain): the staged row-deleted
    /// trapezoid (reused across calls — batched downdates stay
    /// allocation-free at the high-water mark).
    work: Vec<f64>,
    /// Scratch: staged-row offsets, parallel to `work`.
    work_offs: Vec<usize>,
}

/// Offset of packed row `i`.
#[inline]
fn off(i: usize) -> usize {
    i * (i + 1) / 2
}

impl IncrementalCholesky {
    /// Empty factor (0×0 matrix).
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty factor with room for dimension `dim` without reallocating.
    pub fn with_capacity(dim: usize) -> Self {
        IncrementalCholesky {
            data: Vec::with_capacity(off(dim + 1)),
            ..Default::default()
        }
    }

    /// Current dimension.
    #[inline]
    pub fn dim(&self) -> usize {
        self.n
    }

    /// Empty the factor, retaining the allocated capacity.
    pub fn reset(&mut self) {
        self.data.clear();
        self.n = 0;
    }

    /// `L[i][j]` for `j <= i`.
    #[inline]
    pub fn l(&self, i: usize, j: usize) -> f64 {
        debug_assert!(j <= i && i < self.n);
        self.data[off(i) + j]
    }

    /// Append one row/column of the underlying matrix: `cross[j] = A[n, j]`
    /// for existing indices `j`, `diag = A[n, n]`. Returns the new diagonal
    /// entry of `L` (useful for log-det accumulation), or `None` if the
    /// extended matrix is not positive definite even after `jitter` (the
    /// factor is left unchanged in that case).
    pub fn push(&mut self, cross: &[f64], diag: f64, jitter: f64) -> Option<f64> {
        let n = self.n;
        assert_eq!(cross.len(), n);
        let start = self.data.len();
        debug_assert_eq!(start, off(n));
        for j in 0..n {
            let rj = off(j);
            let mut s = cross[j];
            // dot of the new row's prefix (already appended) with row j
            for k in 0..j {
                s -= self.data[start + k] * self.data[rj + k];
            }
            let v = s / self.data[rj + j];
            self.data.push(v);
        }
        let mut d =
            diag - self.data[start..start + n].iter().map(|v| v * v).sum::<f64>();
        if d <= 0.0 {
            d += jitter;
        }
        if d <= 0.0 {
            self.data.truncate(start); // roll back the partial row
            return None;
        }
        let ld = d.sqrt();
        self.data.push(ld);
        self.n += 1;
        Some(ld)
    }

    /// Remove row/column `k`, restoring lower-triangular form with Givens
    /// rotations (the classic `choldelete`). O((n−k)²), fully in place.
    pub fn remove(&mut self, k: usize) {
        let n = self.n;
        assert!(k < n);
        // Drop row k's storage; rows below shift down one index but keep
        // their old (one-too-long) lengths until the final compaction.
        self.data.drain(off(k)..off(k + 1));
        // Working offset of new row j (old row j+1, which has j+2 entries):
        // off(k) + Σ_{i=k..j-1} (i+2) = off(j) + j − k.
        let woff = |j: usize| off(j) + j - k;
        let m = n - 1; // new dimension
        for j in k..m {
            // Givens rotation zeroing the out-of-triangle entry of row j.
            let a = self.data[woff(j) + j];
            let b = self.data[woff(j) + j + 1];
            let r = (a * a + b * b).sqrt();
            let (c, s) = if r == 0.0 { (1.0, 0.0) } else { (a / r, b / r) };
            // Apply rotation to rows j.. on columns (j, j+1).
            for i in j..m {
                let o = woff(i);
                let a = self.data[o + j];
                let b = self.data[o + j + 1];
                self.data[o + j] = c * a + s * b;
                self.data[o + j + 1] = -s * a + c * b;
            }
            // Row j's (j+1)-th entry is now ~0; it is dropped by the
            // compaction below.
            debug_assert!(
                self.data[woff(j) + j + 1].abs()
                    < 1e-8 * (1.0 + self.data[woff(j) + j].abs())
            );
            // Keep the diagonal positive (Givens may flip sign).
            if self.data[woff(j) + j] < 0.0 {
                for i in j..m {
                    let o = woff(i);
                    self.data[o + j] = -self.data[o + j];
                }
            }
        }
        // Compact: final row j keeps entries 0..=j of working row j.
        let mut write = off(k);
        for j in k..m {
            let src = woff(j);
            debug_assert!(write <= src);
            self.data.copy_within(src..src + j + 1, write);
            write += j + 1;
        }
        self.data.truncate(write);
        self.n = m;
    }

    /// Batched downdate: keep only the rows/columns at the (ascending,
    /// unique) indices in `keep` — equivalent to calling
    /// [`remove`](Self::remove) for every dropped index, but in **one**
    /// compaction sweep instead of one O(n²) restructuring per eviction.
    ///
    /// Deleting rows of `L` leaves an m×n lower-trapezoidal `L'` with
    /// `L' L'ᵀ` still equal to the kept principal submatrix; a single
    /// right-multiplied Givens sweep re-triangularizes it (`L'' = L' Q`),
    /// touching each surviving row once per excess column. The min-norm
    /// minor cycles use this for batch corral evictions, and the
    /// projected-corral IAES restart uses it to drop whole groups of
    /// atoms at once. Allocation-free once the internal scratch reaches
    /// its high-water size.
    pub fn retain(&mut self, keep: &[usize]) {
        let n = self.n;
        let m = keep.len();
        if m == 0 {
            self.data.clear();
            self.n = 0;
            return;
        }
        debug_assert!(keep.windows(2).all(|w| w[0] < w[1]), "keep not ascending");
        assert!(*keep.last().unwrap() < n, "keep index out of range");
        if m == n {
            return; // nothing removed
        }
        // Stage the kept rows with their full original column spans:
        // work row j = L[keep[j]][0..=keep[j]].
        self.work.clear();
        self.work_offs.clear();
        for &r in keep {
            self.work_offs.push(self.work.len());
            self.work.extend_from_slice(&self.data[off(r)..off(r) + r + 1]);
        }
        // Re-triangularize: for each row j, rotate column pairs (j, c) to
        // fold the excess entries c = j+1..=keep[j] into column j. Rows
        // above j are already reduced (support ≤ their own index < j), so
        // rotations only touch rows j..m.
        for j in 0..m {
            let end = keep[j];
            for c in (j + 1)..=end {
                let oj = self.work_offs[j];
                let a = self.work[oj + j];
                let b = self.work[oj + c];
                if b == 0.0 {
                    continue;
                }
                let r = (a * a + b * b).sqrt();
                let (cos, sin) = if r == 0.0 { (1.0, 0.0) } else { (a / r, b / r) };
                for i in j..m {
                    let o = self.work_offs[i];
                    let a = self.work[o + j];
                    let b = self.work[o + c];
                    self.work[o + j] = cos * a + sin * b;
                    self.work[o + c] = -sin * a + cos * b;
                }
                self.work[oj + c] = 0.0; // exact zero by construction
            }
            // Keep the diagonal positive (Givens may flip sign).
            if self.work[self.work_offs[j] + j] < 0.0 {
                for i in j..m {
                    let o = self.work_offs[i];
                    self.work[o + j] = -self.work[o + j];
                }
            }
        }
        // Write back packed: final row j keeps entries 0..=j.
        self.data.clear();
        for j in 0..m {
            let o = self.work_offs[j];
            self.data.extend_from_slice(&self.work[o..o + j + 1]);
        }
        self.n = m;
    }

    /// Solve `A x = b` with the current factor (allocating convenience).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = Vec::new();
        self.solve_into(b, &mut x);
        x
    }

    /// Solve `A x = b` into a caller-owned buffer — no allocation once the
    /// buffer capacity suffices (the min-norm minor cycles call this every
    /// iteration).
    pub fn solve_into(&self, b: &[f64], x: &mut Vec<f64>) {
        let n = self.n;
        assert_eq!(b.len(), n);
        x.clear();
        x.extend_from_slice(b);
        for i in 0..n {
            let row = off(i);
            let mut s = x[i];
            for k in 0..i {
                s -= self.data[row + k] * x[k];
            }
            x[i] = s / self.data[row + i];
        }
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in (i + 1)..n {
                s -= self.data[off(k) + i] * x[k];
            }
            x[i] = s / self.data[off(i) + i];
        }
    }

    /// `log det` of the current matrix.
    pub fn logdet(&self) -> f64 {
        (0..self.n).map(|i| self.data[off(i) + i].ln()).sum::<f64>() * 2.0
    }

    /// Reconstruct the dense matrix `L Lᵀ` (tests / debugging).
    pub fn reconstruct(&self) -> Mat {
        let n = self.n;
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let m = i.min(j) + 1;
                let mut s = 0.0;
                for k in 0..m {
                    s += self.data[off(i) + k] * self.data[off(j) + k];
                }
                a[(i, j)] = s;
            }
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seeded(seed);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        // A = G Gᵀ + n * I  (well conditioned)
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += g[(i, k)] * g[(j, k)];
                }
                a[(i, j)] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn factor_and_solve() {
        let a = random_spd(8, 1);
        let ch = Cholesky::factor(&a, 0.0).unwrap();
        let x_true: Vec<f64> = (0..8).map(|i| (i as f64) - 3.5).collect();
        let b = a.matvec(&x_true);
        let x = ch.solve(&b);
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-9, "{u} vs {v}");
        }
    }

    #[test]
    fn logdet_matches_2x2() {
        let mut a = Mat::zeros(2, 2);
        a[(0, 0)] = 4.0;
        a[(1, 1)] = 9.0;
        a[(0, 1)] = 2.0;
        a[(1, 0)] = 2.0;
        let ch = Cholesky::factor(&a, 0.0).unwrap();
        assert!((ch.logdet() - (4.0f64 * 9.0 - 4.0).ln()).abs() < 1e-12);
    }

    #[test]
    fn incremental_matches_batch() {
        let n = 10;
        let a = random_spd(n, 2);
        let mut inc = IncrementalCholesky::new();
        for i in 0..n {
            let cross: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
            inc.push(&cross, a[(i, i)], 0.0).unwrap();
        }
        let batch = Cholesky::factor(&a, 0.0).unwrap();
        for i in 0..n {
            for j in 0..=i {
                assert!(
                    (inc.l(i, j) - batch.l[(i, j)]).abs() < 1e-9,
                    "L[{i}][{j}]: {} vs {}",
                    inc.l(i, j),
                    batch.l[(i, j)]
                );
            }
        }
        assert!((inc.logdet() - batch.logdet()).abs() < 1e-9);
    }

    #[test]
    fn incremental_solve_matches() {
        let n = 7;
        let a = random_spd(n, 3);
        let mut inc = IncrementalCholesky::new();
        for i in 0..n {
            let cross: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
            inc.push(&cross, a[(i, i)], 0.0).unwrap();
        }
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
        let b = a.matvec(&x_true);
        let x = inc.solve(&b);
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn remove_restores_submatrix_factor() {
        let n = 9;
        let a = random_spd(n, 4);
        for k in [0usize, 3, 8] {
            let mut inc = IncrementalCholesky::new();
            for i in 0..n {
                let cross: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
                inc.push(&cross, a[(i, i)], 0.0).unwrap();
            }
            inc.remove(k);
            // Build the submatrix of A without row/col k and compare
            // reconstruction.
            let keep: Vec<usize> = (0..n).filter(|&i| i != k).collect();
            let recon = inc.reconstruct();
            for (ii, &i) in keep.iter().enumerate() {
                for (jj, &j) in keep.iter().enumerate() {
                    assert!(
                        (recon[(ii, jj)] - a[(i, j)]).abs() < 1e-8,
                        "k={k} A'[{ii},{jj}]"
                    );
                }
            }
        }
    }

    fn factor_of(a: &Mat) -> IncrementalCholesky {
        let mut inc = IncrementalCholesky::new();
        for i in 0..a.rows {
            let cross: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
            inc.push(&cross, a[(i, i)], 0.0).unwrap();
        }
        inc
    }

    #[test]
    fn retain_matches_kept_submatrix() {
        let n = 10;
        let a = random_spd(n, 21);
        for keep in [
            vec![0usize, 1, 2, 3, 4, 5, 6, 7, 8, 9], // no-op
            vec![0, 2, 4, 6, 8],
            vec![1, 3, 9],
            vec![5],
            vec![0, 1, 2, 7, 8, 9],
        ] {
            let mut inc = factor_of(&a);
            inc.retain(&keep);
            assert_eq!(inc.dim(), keep.len());
            let recon = inc.reconstruct();
            for (ii, &i) in keep.iter().enumerate() {
                for (jj, &j) in keep.iter().enumerate() {
                    assert!(
                        (recon[(ii, jj)] - a[(i, j)]).abs() < 1e-8,
                        "keep {keep:?}: A'[{ii},{jj}] {} vs {}",
                        recon[(ii, jj)],
                        a[(i, j)]
                    );
                }
            }
            // Positive diagonal (sign fix applied).
            for j in 0..inc.dim() {
                assert!(inc.l(j, j) > 0.0, "non-positive diagonal");
            }
        }
    }

    #[test]
    fn retain_empty_resets() {
        let a = random_spd(5, 22);
        let mut inc = factor_of(&a);
        inc.retain(&[]);
        assert_eq!(inc.dim(), 0);
        // Still usable afterwards.
        inc.push(&[], 4.0, 0.0).unwrap();
        assert_eq!(inc.dim(), 1);
        assert!((inc.l(0, 0) - 2.0).abs() < 1e-15);
    }

    #[test]
    fn retain_agrees_with_sequential_removes() {
        let n = 12;
        let a = random_spd(n, 23);
        let mut rng = Pcg64::seeded(404);
        for _trial in 0..20 {
            let keep: Vec<usize> = (0..n).filter(|_| rng.bernoulli(0.6)).collect();
            if keep.is_empty() {
                continue;
            }
            let mut batched = factor_of(&a);
            batched.retain(&keep);
            let mut seq = factor_of(&a);
            // Remove dropped indices from the highest down so earlier
            // indices stay valid.
            for k in (0..n).rev() {
                if !keep.contains(&k) {
                    seq.remove(k);
                }
            }
            assert_eq!(batched.dim(), seq.dim());
            let rb = batched.reconstruct();
            let rs = seq.reconstruct();
            for i in 0..batched.dim() {
                for j in 0..batched.dim() {
                    assert!(
                        (rb[(i, j)] - rs[(i, j)]).abs() < 1e-7,
                        "batched vs sequential at ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn retain_then_solve_and_push_stay_consistent() {
        let n = 9;
        let a = random_spd(n, 24);
        let keep = [0usize, 3, 4, 7];
        let mut inc = factor_of(&a);
        inc.retain(&keep);
        // Solve against the kept submatrix.
        let m = keep.len();
        let mut sub = Mat::zeros(m, m);
        for (ii, &i) in keep.iter().enumerate() {
            for (jj, &j) in keep.iter().enumerate() {
                sub[(ii, jj)] = a[(i, j)];
            }
        }
        let x_true: Vec<f64> = (0..m).map(|i| (i as f64) - 1.0).collect();
        let b = sub.matvec(&x_true);
        let x = inc.solve(&b);
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-8);
        }
        // Push after retain keeps working.
        let cross = vec![0.1; m];
        inc.push(&cross, 10.0, 0.0).unwrap();
        assert_eq!(inc.dim(), m + 1);
    }

    #[test]
    fn reset_reuses_capacity_and_matches_fresh_factor() {
        let n = 9;
        let a = random_spd(n, 11);
        let batch = Cholesky::factor(&a, 0.0).unwrap();
        let mut inc = IncrementalCholesky::with_capacity(n);
        for _round in 0..3 {
            inc.reset();
            assert_eq!(inc.dim(), 0);
            for i in 0..n {
                let cross: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
                inc.push(&cross, a[(i, i)], 0.0).unwrap();
            }
            for i in 0..n {
                for j in 0..=i {
                    assert!((inc.l(i, j) - batch.l[(i, j)]).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn solve_into_matches_solve() {
        let n = 6;
        let a = random_spd(n, 12);
        let mut inc = IncrementalCholesky::new();
        for i in 0..n {
            let cross: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
            inc.push(&cross, a[(i, i)], 0.0).unwrap();
        }
        let b: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let x1 = inc.solve(&b);
        let mut x2 = vec![9.0; 2]; // wrong size + garbage: must be reset
        inc.solve_into(&b, &mut x2);
        assert_eq!(x1, x2);
    }

    #[test]
    fn failed_push_leaves_factor_unchanged() {
        // Exact-arithmetic rank deficiency: the third variable is 2× the
        // first, so its Schur complement is exactly 0 and the push must
        // fail and roll back (small integers → no rounding anywhere).
        let mut inc = IncrementalCholesky::new();
        inc.push(&[], 4.0, 0.0).unwrap(); // L = [2]
        inc.push(&[2.0], 9.0, 0.0).unwrap();
        let before = inc.clone();
        assert!(inc.push(&[8.0, 4.0], 16.0, 0.0).is_none());
        assert_eq!(inc.dim(), 2);
        for i in 0..2 {
            for j in 0..=i {
                assert_eq!(inc.l(i, j), before.l(i, j));
            }
        }
        // The factor still works after the rolled-back push.
        inc.push(&[1.0, 1.0], 7.0, 0.0).unwrap();
        assert_eq!(inc.dim(), 3);
    }

    #[test]
    fn repeated_push_remove_stays_consistent() {
        let n = 12;
        let a = random_spd(n, 5);
        let mut inc = IncrementalCholesky::new();
        let mut members: Vec<usize> = Vec::new();
        let mut rng = Pcg64::seeded(99);
        for step in 0..60 {
            if members.len() < 2 || (members.len() < n && rng.bernoulli(0.6)) {
                // push a random non-member
                let candidates: Vec<usize> =
                    (0..n).filter(|i| !members.contains(i)).collect();
                let v = candidates[rng.below(candidates.len())];
                let cross: Vec<f64> = members.iter().map(|&j| a[(v, j)]).collect();
                inc.push(&cross, a[(v, v)], 0.0).unwrap();
                members.push(v);
            } else {
                let k = rng.below(members.len());
                inc.remove(k);
                members.remove(k);
            }
            let recon = inc.reconstruct();
            for (ii, &i) in members.iter().enumerate() {
                for (jj, &j) in members.iter().enumerate() {
                    assert!(
                        (recon[(ii, jj)] - a[(i, j)]).abs() < 1e-7,
                        "step {step}"
                    );
                }
            }
        }
    }
}
