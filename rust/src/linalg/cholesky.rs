//! Cholesky factorizations: batch, and incrementally extended/downdated.
//!
//! Two consumers drive the design:
//!
//! * [`Cholesky`] — factor a full SPD matrix once and solve. Used by the
//!   baseline min-norm affine-minimization step and by tests.
//! * [`IncrementalCholesky`] — maintain `L` with `A = L Lᵀ` under two
//!   operations: `push` (append one row/column — O(n²)) and `remove`
//!   (delete one row/column, restoring triangularity with Givens
//!   rotations — O(n²)). Used by (a) the Gaussian-process
//!   mutual-information oracle, which needs log-determinants of *nested*
//!   principal minors along a greedy order, and (b) the optimized
//!   min-norm-point corral, which adds one base vertex per major cycle and
//!   evicts vertices whose affine coefficient hits zero.

use super::Mat;

/// Batch Cholesky factorization `A = L Lᵀ` (lower-triangular `L`).
#[derive(Clone, Debug)]
pub struct Cholesky {
    /// Lower-triangular factor, row-major dense (upper part zero).
    pub l: Mat,
}

impl Cholesky {
    /// Factor an SPD matrix. Adds `jitter` to the diagonal if a pivot is
    /// non-positive (returns `None` only if even the jittered pivot fails).
    pub fn factor(a: &Mat, jitter: f64) -> Option<Self> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    let mut d = s;
                    if d <= 0.0 {
                        d = s + jitter;
                    }
                    if d <= 0.0 {
                        return None;
                    }
                    l[(i, i)] = d.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Some(Cholesky { l })
    }

    /// Solve `A x = b` via forward/back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        let mut y = b.to_vec();
        // L y = b
        for i in 0..n {
            let mut s = y[i];
            let row = self.l.row(i);
            for k in 0..i {
                s -= row[k] * y[k];
            }
            y[i] = s / row[i];
        }
        // Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.l[(k, i)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
        y
    }

    /// `log det A = 2 Σ log L_ii`.
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

/// Incrementally maintained Cholesky factor of a growing/shrinking SPD
/// matrix. Rows are stored as ragged vectors (`row[i].len() == i + 1`).
#[derive(Clone, Debug, Default)]
pub struct IncrementalCholesky {
    rows: Vec<Vec<f64>>,
}

impl IncrementalCholesky {
    /// Empty factor (0×0 matrix).
    pub fn new() -> Self {
        Self { rows: Vec::new() }
    }

    /// Current dimension.
    #[inline]
    pub fn dim(&self) -> usize {
        self.rows.len()
    }

    /// `L[i][j]` for `j <= i`.
    #[inline]
    pub fn l(&self, i: usize, j: usize) -> f64 {
        self.rows[i][j]
    }

    /// Append one row/column of the underlying matrix: `cross[j] = A[n, j]`
    /// for existing indices `j`, `diag = A[n, n]`. Returns the new diagonal
    /// entry of `L` (useful for log-det accumulation), or `None` if the
    /// extended matrix is not positive definite even after `jitter`.
    pub fn push(&mut self, cross: &[f64], diag: f64, jitter: f64) -> Option<f64> {
        let n = self.dim();
        assert_eq!(cross.len(), n);
        let mut new_row = Vec::with_capacity(n + 1);
        for j in 0..n {
            let mut s = cross[j];
            let rj = &self.rows[j];
            // dot of new_row[..j] with rows[j][..j]
            for k in 0..j {
                s -= new_row[k] * rj[k];
            }
            new_row.push(s / rj[j]);
        }
        let mut d = diag - new_row.iter().map(|v| v * v).sum::<f64>();
        if d <= 0.0 {
            d += jitter;
        }
        if d <= 0.0 {
            return None;
        }
        let ld = d.sqrt();
        new_row.push(ld);
        self.rows.push(new_row);
        Some(ld)
    }

    /// Remove row/column `k`, restoring lower-triangular form with Givens
    /// rotations (the classic `choldelete`). O((n−k)²).
    pub fn remove(&mut self, k: usize) {
        let n = self.dim();
        assert!(k < n);
        self.rows.remove(k);
        // Rows that were below k now each carry one extra entry (their old
        // length). Apply Givens rotations on column pairs (j, j+1) to zero
        // the out-of-triangle element on row j (new indexing).
        for j in k..self.rows.len() {
            // Row j currently has length j + 2 (old row j+1 had j+2 entries).
            let (c, s);
            {
                let row = &self.rows[j];
                let a = row[j];
                let b = row[j + 1];
                let r = (a * a + b * b).sqrt();
                if r == 0.0 {
                    c = 1.0;
                    s = 0.0;
                } else {
                    c = a / r;
                    s = b / r;
                }
            }
            // Apply rotation to rows j.. on columns (j, j+1).
            for i in j..self.rows.len() {
                let row = &mut self.rows[i];
                let a = row[j];
                let b = row[j + 1];
                row[j] = c * a + s * b;
                row[j + 1] = -s * a + c * b;
            }
            // Row j's (j+1)-th entry is now ~0; truncate it.
            let rj = &mut self.rows[j];
            debug_assert!(rj[j + 1].abs() < 1e-8 * (1.0 + rj[j].abs()));
            rj.truncate(j + 1);
            // Keep the diagonal positive (Givens may flip sign).
            if self.rows[j][j] < 0.0 {
                for i in j..self.rows.len() {
                    self.rows[i][j] = -self.rows[i][j];
                }
            }
        }
    }

    /// Solve `A x = b` with the current factor.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.dim();
        assert_eq!(b.len(), n);
        let mut y = b.to_vec();
        for i in 0..n {
            let row = &self.rows[i];
            let mut s = y[i];
            for k in 0..i {
                s -= row[k] * y[k];
            }
            y[i] = s / row[i];
        }
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.rows[k][i] * y[k];
            }
            y[i] = s / self.rows[i][i];
        }
        y
    }

    /// `log det` of the current matrix.
    pub fn logdet(&self) -> f64 {
        self.rows.iter().enumerate().map(|(i, r)| r[i].ln()).sum::<f64>() * 2.0
    }

    /// Reconstruct the dense matrix `L Lᵀ` (tests / debugging).
    pub fn reconstruct(&self) -> Mat {
        let n = self.dim();
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let m = i.min(j) + 1;
                let mut s = 0.0;
                for k in 0..m {
                    s += self.rows[i].get(k).copied().unwrap_or(0.0)
                        * self.rows[j].get(k).copied().unwrap_or(0.0);
                }
                a[(i, j)] = s;
            }
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seeded(seed);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        // A = G Gᵀ + n * I  (well conditioned)
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += g[(i, k)] * g[(j, k)];
                }
                a[(i, j)] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn factor_and_solve() {
        let a = random_spd(8, 1);
        let ch = Cholesky::factor(&a, 0.0).unwrap();
        let x_true: Vec<f64> = (0..8).map(|i| (i as f64) - 3.5).collect();
        let b = a.matvec(&x_true);
        let x = ch.solve(&b);
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-9, "{u} vs {v}");
        }
    }

    #[test]
    fn logdet_matches_2x2() {
        let mut a = Mat::zeros(2, 2);
        a[(0, 0)] = 4.0;
        a[(1, 1)] = 9.0;
        a[(0, 1)] = 2.0;
        a[(1, 0)] = 2.0;
        let ch = Cholesky::factor(&a, 0.0).unwrap();
        assert!((ch.logdet() - (4.0f64 * 9.0 - 4.0).ln()).abs() < 1e-12);
    }

    #[test]
    fn incremental_matches_batch() {
        let n = 10;
        let a = random_spd(n, 2);
        let mut inc = IncrementalCholesky::new();
        for i in 0..n {
            let cross: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
            inc.push(&cross, a[(i, i)], 0.0).unwrap();
        }
        let batch = Cholesky::factor(&a, 0.0).unwrap();
        for i in 0..n {
            for j in 0..=i {
                assert!(
                    (inc.l(i, j) - batch.l[(i, j)]).abs() < 1e-9,
                    "L[{i}][{j}]: {} vs {}",
                    inc.l(i, j),
                    batch.l[(i, j)]
                );
            }
        }
        assert!((inc.logdet() - batch.logdet()).abs() < 1e-9);
    }

    #[test]
    fn incremental_solve_matches() {
        let n = 7;
        let a = random_spd(n, 3);
        let mut inc = IncrementalCholesky::new();
        for i in 0..n {
            let cross: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
            inc.push(&cross, a[(i, i)], 0.0).unwrap();
        }
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
        let b = a.matvec(&x_true);
        let x = inc.solve(&b);
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn remove_restores_submatrix_factor() {
        let n = 9;
        let a = random_spd(n, 4);
        for k in [0usize, 3, 8] {
            let mut inc = IncrementalCholesky::new();
            for i in 0..n {
                let cross: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
                inc.push(&cross, a[(i, i)], 0.0).unwrap();
            }
            inc.remove(k);
            // Build the submatrix of A without row/col k and compare
            // reconstruction.
            let keep: Vec<usize> = (0..n).filter(|&i| i != k).collect();
            let recon = inc.reconstruct();
            for (ii, &i) in keep.iter().enumerate() {
                for (jj, &j) in keep.iter().enumerate() {
                    assert!(
                        (recon[(ii, jj)] - a[(i, j)]).abs() < 1e-8,
                        "k={k} A'[{ii},{jj}]"
                    );
                }
            }
        }
    }

    #[test]
    fn repeated_push_remove_stays_consistent() {
        let n = 12;
        let a = random_spd(n, 5);
        let mut inc = IncrementalCholesky::new();
        let mut members: Vec<usize> = Vec::new();
        let mut rng = Pcg64::seeded(99);
        for step in 0..60 {
            if members.len() < 2 || (members.len() < n && rng.bernoulli(0.6)) {
                // push a random non-member
                let candidates: Vec<usize> =
                    (0..n).filter(|i| !members.contains(i)).collect();
                let v = candidates[rng.below(candidates.len())];
                let cross: Vec<f64> = members.iter().map(|&j| a[(v, j)]).collect();
                inc.push(&cross, a[(v, v)], 0.0).unwrap();
                members.push(v);
            } else {
                let k = rng.below(members.len());
                inc.remove(k);
                members.remove(k);
            }
            let recon = inc.reconstruct();
            for (ii, &i) in members.iter().enumerate() {
                for (jj, &j) in members.iter().enumerate() {
                    assert!(
                        (recon[(ii, jj)] - a[(i, j)]).abs() < 1e-7,
                        "step {step}"
                    );
                }
            }
        }
    }
}
